"""Paper-grounded replay-health probes.

The paper's central claim is that AMPER preserves PER's sampling
distribution (Fig. 7: KL of sampled-priority histograms vs exact PER),
and the async runtime's correctness claim is that priority staleness
stays bounded.  Offline, ``benchmarks/fig7_sampling_error.py`` checks
the first; this module makes both *continuous*:

* :data:`BINS` / :func:`kl_nats` / :func:`chi_square` are the canonical
  sampled-priority binning and divergence definitions.  The Fig. 7
  benchmark imports them from here, so the live gauge and the offline
  study are the same computation by construction.
* :class:`SamplingErrorMonitor` keeps a windowed histogram of sampled
  priority values and reports KL / chi-square against the exact PER law
  (``P(i) = p_i / sum p``) as live gauges — Fig. 7 as a dashboard line.
* :func:`make_replay_probe` builds a jitted probe that *re-derives* one
  draw's CSP off the hot path: given the same state and PRNG key the
  pipeline used, it reproduces the CSP build and uniform pick exactly
  (all fr_modes are bit-identical), yielding exact match-count, CSP
  occupancy, fallback and sampled priorities for that draw without
  adding anything to the fused sampling dispatch.
* :class:`ReplayHealth` wires probe outputs into registry instruments
  (``csp_count``, ``csp_occupancy``, ``csp_match_count``,
  ``fallback_draws``, ``replay_live``, ``sampling_kl_nats``,
  ``sampling_chi2``).

Everything here is host-side except the probe function itself, which is
a *separate* jitted computation run at a caller-chosen cadence — the
production sampling path is never touched.
"""
from __future__ import annotations

from collections import deque
from typing import Optional

import numpy as np

from repro.obs.metrics import Registry

# Sampled-PRIORITY histogram over (0, 1): Fig. 7(a) compares the
# distributions of sampled priority values, not per-item frequencies.
BINS = 64


def priority_bin_counts(values: np.ndarray) -> np.ndarray:
    """The canonical binning: counts of sampled priorities over (0,1)."""
    return np.histogram(np.asarray(values), bins=BINS, range=(0.0, 1.0))[0]


def kl_nats(p_counts: np.ndarray, q_counts: np.ndarray) -> float:
    """Total KL over the sample (binned counts, Laplace smoothed).

    Reported as total nats over the sample (``n * KL(p || q)``) to match
    the magnitudes in the paper's Fig. 7.
    """
    p_counts = np.asarray(p_counts, dtype=float)
    q_counts = np.asarray(q_counts, dtype=float)
    n_samples = p_counts.sum()
    p = (p_counts + 0.5) / (p_counts.sum() + 0.5 * len(p_counts))
    q = (q_counts + 0.5) / (q_counts.sum() + 0.5 * len(q_counts))
    return float(n_samples * np.sum(p * np.log(p / q)))


def chi_square(p_counts: np.ndarray, q_counts: np.ndarray) -> float:
    """Pearson chi-square of observed counts vs the reference
    distribution (same Laplace smoothing as :func:`kl_nats`)."""
    p_counts = np.asarray(p_counts, dtype=float)
    q_counts = np.asarray(q_counts, dtype=float)
    n = p_counts.sum()
    if n == 0:
        return 0.0
    q = (q_counts + 0.5) / (q_counts.sum() + 0.5 * len(q_counts))
    expected = n * q
    return float(np.sum((p_counts - expected) ** 2 / expected))


class SamplingErrorMonitor:
    """Windowed sampling-error monitor: Fig. 7 as a live gauge.

    Keeps bin counts of the last ``window`` observed draws (each draw is
    one batch of sampled priority values) and compares them against a
    reference distribution — by default the *exact PER law*, whose bin
    masses are ``sum of p_i per bin`` (``P(i) = p_i / sum p``, so the
    sampled-priority density of bin b is its priority mass).  Divergences
    use the same :func:`kl_nats` / :func:`chi_square` the offline Fig. 7
    benchmark uses, on the same binning, so online and offline numbers
    agree exactly on identical draws.
    """

    def __init__(self, registry: Optional[Registry] = None,
                 window: int = 200, prefix: str = "sampling"):
        self.window = int(window)
        self._draws: deque[np.ndarray] = deque()
        self._counts = np.zeros(BINS, dtype=float)
        self._ref = np.ones(BINS, dtype=float)  # uniform until told better
        self._kl_gauge = self._chi2_gauge = self._n_gauge = None
        if registry is not None:
            self._kl_gauge = registry.gauge(
                f"{prefix}_kl_nats",
                help="windowed KL of sampled priorities vs exact PER law "
                     "(total nats, Fig. 7 convention)")
            self._chi2_gauge = registry.gauge(
                f"{prefix}_chi2",
                help="windowed chi-square of sampled priorities vs ref law")
            self._n_gauge = registry.gauge(
                f"{prefix}_window_samples",
                help="samples currently inside the monitor window")

    def set_reference_counts(self, q_counts: np.ndarray) -> None:
        """Install reference bin counts/masses (any scale — divergences
        normalise q internally)."""
        self._ref = np.asarray(q_counts, dtype=float).copy()

    def set_reference_priorities(self, priorities: np.ndarray) -> None:
        """Derive the exact-PER-law reference from a live priority
        vector: bin mass b = sum of priorities falling in bin b."""
        p = np.asarray(priorities, dtype=float)
        p = p[p > 0]
        self.set_reference_counts(
            np.histogram(p, bins=BINS, range=(0.0, 1.0), weights=p)[0])

    def observe(self, sampled_priorities: np.ndarray) -> None:
        """Record one draw's sampled priority values and refresh gauges."""
        c = priority_bin_counts(sampled_priorities).astype(float)
        self._draws.append(c)
        self._counts += c
        while len(self._draws) > self.window:
            self._counts -= self._draws.popleft()
        if self._kl_gauge is not None:
            self._kl_gauge.set(self.kl())
            self._chi2_gauge.set(self.chi_square())
            self._n_gauge.set(self._counts.sum())

    @property
    def counts(self) -> np.ndarray:
        return self._counts.copy()

    def kl(self) -> float:
        return kl_nats(self._counts, self._ref)

    def chi_square(self) -> float:
        return chi_square(self._counts, self._ref)


def make_replay_probe(sampler, batch: int):
    """A jitted CSP probe for AMPER-style samplers, or None.

    Given the exact ``(state, key)`` the production draw used, the probe
    replays the key tree of :meth:`AmperSampler.sample` — split into
    (csp, pick), build the CSP, uniform-pick with fallback — so its
    outputs describe *that* draw exactly (every fr_mode is bit-identical
    to this reference path by the sampler's own contract).  Returns
    ``(match_count, csp_count, live, fallback, sampled_priorities,
    ref_mass)`` as device arrays; ``ref_mass`` is the exact-PER-law bin
    mass of the live priority vector for :class:`SamplingErrorMonitor`.

    Samplers without ``build_csp`` (PER baselines, uniform) get a
    reduced probe reporting live size and sampled priorities only.

    Priorities are normalised by the sampler's ``cfg.v_max`` (1 when the
    sampler has none, e.g. the Fig. 7 study's U[0,1] priorities) so the
    (0, 1) binning covers the live priority scale; values at/above the
    scale land in the top bin on BOTH the observed and reference sides.
    """
    import jax
    import jax.numpy as jnp

    v_max = float(getattr(getattr(sampler, "cfg", None), "v_max", 0.0)
                  or 1.0)

    def _ref_mass(prio):
        p = prio / v_max
        b = jnp.clip((p * BINS).astype(jnp.int32), 0, BINS - 1)
        return jnp.zeros(BINS, jnp.float32).at[b].add(
            jnp.where(p > 0, p, 0.0))

    if hasattr(sampler, "build_csp"):
        from repro.core.amper import sample_from_csp

        @jax.jit
        def probe(state, key):
            kcsp, kpick = jax.random.split(key)
            csp = sampler.build_csp(state, kcsp)
            live = jnp.sum(state.valid.astype(jnp.int32))
            idx = sample_from_csp(csp, kpick, batch, live)
            prio = sampler.priorities(state)
            match = jnp.sum(csp.selected.astype(jnp.int32))
            return (match, csp.count, live,
                    (csp.count == 0).astype(jnp.int32),
                    prio[idx] / v_max, _ref_mass(prio))

        return probe

    @jax.jit
    def probe(state, key):
        prio = sampler.priorities(state)
        live = jnp.sum((prio > 0).astype(jnp.int32))
        idx = sampler.sample(state, key, batch)
        zero = jnp.int32(0)
        return zero, zero, live, zero, prio[idx] / v_max, _ref_mass(prio)

    return probe


class ReplayHealth:
    """Bridges probe outputs into registry instruments.

    Construct once per run, call :meth:`update` at a chosen cadence with
    the same ``(state, key)`` a production draw used.  The probe runs as
    its own jitted computation (off the hot path); the host-side gauge
    writes are lock-free registry updates.
    """

    def __init__(self, registry: Registry, sampler, batch: int,
                 window: int = 200):
        self._probe = make_replay_probe(sampler, batch)
        self._csp_capacity = getattr(
            getattr(sampler, "cfg", None), "csp_capacity", 0)
        self._has_csp = hasattr(sampler, "build_csp")
        self.monitor = SamplingErrorMonitor(registry, window=window)
        r = registry
        self._g_count = r.gauge("csp_count", help="CSP fill for last probed draw")
        self._g_occ = r.gauge("csp_occupancy",
                              help="CSP fill / csp_capacity (0..1)")
        self._g_match = r.gauge("csp_match_count",
                                help="TCAM match count before compaction")
        self._g_live = r.gauge("replay_live", help="live replay rows")
        self._c_fallback = r.counter(
            "fallback_draws", help="probed draws that fell back to uniform")
        self._c_probes = r.counter("probe_draws", help="probed draws")

    def update(self, state, key) -> dict:
        """Probe one draw; returns the host-side probe readings."""
        match, count, live, fallback, p_sel, ref = self._probe(state, key)
        match = int(match)
        count = int(count)
        live = int(live)
        fallback = int(fallback)
        self._g_live.set(live)
        if self._has_csp:
            self._g_count.set(count)
            self._g_match.set(match)
            if self._csp_capacity:
                self._g_occ.set(count / self._csp_capacity)
        self._c_probes.add()
        if fallback:
            self._c_fallback.add()
        self.monitor.set_reference_counts(np.asarray(ref))
        # Clip into [0, 1] so normalised priorities at exactly the scale
        # ceiling bin with the reference's top-bin clamp (np.histogram's
        # final bin is right-closed).
        self.monitor.observe(np.clip(np.asarray(p_sel), 0.0, 1.0))
        return {"match_count": match, "csp_count": count, "live": live,
                "fallback": fallback, "kl_nats": self.monitor.kl()}
