"""``python -m repro.obs.report <metrics.jsonl>`` — run summary.

Renders the last snapshot of a JSONL metrics log as a table (plus the
event timeline with ``--events``): the quick "how healthy was this
run" view without loading anything heavier than the log itself.
"""
from __future__ import annotations

import argparse
import sys

from repro.obs.exporters import read_jsonl


def _fmt_val(v) -> str:
    if isinstance(v, float):
        return f"{v:.6g}"
    return str(v)


def render(records: list[dict], show_events: bool = False) -> str:
    snapshots = [r for r in records if r.get("kind") == "snapshot"]
    events = [r for r in records if r.get("kind") == "event"]
    lines: list[str] = []
    if not snapshots and not events:
        return "empty metrics log\n"
    if snapshots:
        last = snapshots[-1]
        lines.append(f"snapshots: {len(snapshots)}   "
                     f"last ts: {last.get('ts', '?')}")
        lines.append("")
        metrics = last.get("metrics", {})
        width = max((len(k) for k in metrics), default=10)
        for name in sorted(metrics):
            v = metrics[name]
            if isinstance(v, dict):
                body = "  ".join(f"{k}={_fmt_val(x)}"
                                 for k, x in v.items() if x is not None)
            else:
                body = _fmt_val(v)
            lines.append(f"  {name:<{width}}  {body}")
    if events:
        lines.append("")
        lines.append(f"events: {len(events)}")
        if show_events:
            for e in events:
                fields = {k: v for k, v in e.items()
                          if k not in ("kind", "schema", "ts", "event")}
                body = "  ".join(f"{k}={_fmt_val(v)}"
                                 for k, v in fields.items())
                lines.append(f"  [{e.get('ts', 0):.3f}] "
                             f"{e.get('event', '?')}  {body}")
    return "\n".join(lines) + "\n"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="Summarise a repro.obs JSONL metrics log.")
    ap.add_argument("path", help="metrics JSONL file")
    ap.add_argument("--events", action="store_true",
                    help="also print the event timeline")
    args = ap.parse_args(argv)
    try:
        records = read_jsonl(args.path)
    except OSError as e:
        print(f"error: {e}", file=sys.stderr)
        return 1
    sys.stdout.write(render(records, show_events=args.events))
    return 0


if __name__ == "__main__":
    sys.exit(main())
