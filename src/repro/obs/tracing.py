"""Span-based wall-time tracing over the metrics registry.

``span("csp_rebuild")`` wraps a host-side region and records its wall
time into the histogram ``span_csp_rebuild_ms`` of the *current*
registry.  Three properties make it safe to leave in library code:

* **disabled is one branch.**  With the current registry disabled (the
  process default), entering a span resolves to a shared no-op object;
  nothing is allocated or timed.
* **trace-safe.**  Library functions like ``ReplayBuffer.sample`` or
  ``AmperSampler.build_csp`` run both eagerly (tests, notebooks,
  benchmarks) and under ``jax.jit``.  Under a jit trace the region's
  wall time is *compile* time, not run time — recording it would poison
  the histograms with one bogus multi-second sample per compile — and
  host callbacks have no place on the hot path.  Spans therefore no-op
  whenever ``jax.core.trace_state_clean()`` is False.  Instrumentation
  is host-side only either way, so it can never add an XLA dispatch to
  a jitted program (pinned by the tier-1 guard in tests/test_obs.py).
* **profiler-integrated.**  With ``profile=True`` on the registry's
  telemetry config (or ``obs.configure(profile=True)``), spans also
  open a ``jax.profiler.TraceAnnotation`` so they show up as named
  regions in TensorBoard/perfetto traces next to the XLA ops they
  bracket.
"""
from __future__ import annotations

import threading
import time
from typing import Optional

from repro.obs.metrics import Registry, TIME_BUCKETS_MS

# The process-wide current registry.  Disabled by default: every span
# and module-level instrument is a cheap no-op until obs.configure()
# (or a ReplayService run with telemetry) installs an enabled one.
_default_registry = Registry(enabled=False)
_state = threading.local()
_global_registry: Registry = _default_registry
_profile = False


def get_registry() -> Registry:
    """The active registry (thread-local override, then process global)."""
    reg = getattr(_state, "registry", None)
    return reg if reg is not None else _global_registry


def set_registry(registry: Optional[Registry], profile: bool = False
                 ) -> Optional[Registry]:
    """Install ``registry`` as the process-wide current registry
    (None restores the disabled default).  Returns the previously
    installed registry (None if it was the default) so callers can
    restore it when their run ends."""
    global _global_registry, _profile
    prev = _global_registry
    _global_registry = registry if registry is not None else _default_registry
    _profile = profile
    return None if prev is _default_registry else prev


class use_registry:
    """Context manager: route this THREAD's spans/instruments to ``reg``."""

    def __init__(self, reg: Registry):
        self._reg = reg

    def __enter__(self):
        self._prev = getattr(_state, "registry", None)
        _state.registry = self._reg
        return self._reg

    def __exit__(self, *exc):
        _state.registry = self._prev
        return False


class _NullSpan:
    """Shared no-op span (disabled registry or inside a jax trace)."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class _Span:
    __slots__ = ("_hist", "_annotation", "_t0")

    def __init__(self, hist, annotation):
        self._hist = hist
        self._annotation = annotation

    def __enter__(self):
        if self._annotation is not None:
            self._annotation.__enter__()
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self._hist.observe((time.perf_counter() - self._t0) * 1e3)
        if self._annotation is not None:
            self._annotation.__exit__(*exc)
        return False


def _trace_state_clean() -> bool:
    try:
        import jax.core

        return jax.core.trace_state_clean()
    except Exception:  # pragma: no cover - ancient/future jax
        return True


def span(name: str, registry: Registry | None = None):
    """Wall-time span context manager -> histogram ``span_<name>_ms``.

    No-op (a shared null object) when the resolved registry is disabled
    or the caller is executing inside a jax trace (see module docstring).
    """
    reg = registry if registry is not None else get_registry()
    if not reg.enabled or not _trace_state_clean():
        return _NULL_SPAN
    hist = reg.histogram(f"span_{name}_ms",
                         help=f"wall time of {name} (ms)",
                         bounds=TIME_BUCKETS_MS)
    annotation = None
    if _profile:
        try:
            import jax.profiler

            annotation = jax.profiler.TraceAnnotation(name)
        except Exception:  # pragma: no cover
            annotation = None
    return _Span(hist, annotation)
