"""repro.obs — low-overhead telemetry for the replay fabric.

Four layers, each usable alone:

* :mod:`repro.obs.metrics` — the registry.  Counters / gauges /
  histograms with per-thread lock-free accumulation (one private cell
  per writer thread; the only locks are cell creation and
  snapshot-on-read merge), cumulative Prometheus-style semantics, and
  ``Snapshot.diff`` for per-run views.
* :mod:`repro.obs.tracing` — ``span("name")`` wall-time spans recording
  into ``span_<name>_ms`` histograms of the current registry; no-ops
  when the registry is disabled or inside a ``jax.jit`` trace, and can
  open ``jax.profiler.TraceAnnotation`` regions when profiling.
* :mod:`repro.obs.probes` — paper-grounded replay health: the canonical
  Fig. 7 KL/chi-square math (``BINS``/``kl_nats``), the windowed
  :class:`~repro.obs.probes.SamplingErrorMonitor` (Fig. 7 as a live
  gauge), and the jitted CSP draw probe behind
  :class:`~repro.obs.probes.ReplayHealth`.
* :mod:`repro.obs.exporters` — structured JSONL event log
  (:class:`~repro.obs.exporters.JsonlExporter`), Prometheus text
  exposition (:func:`~repro.obs.exporters.prometheus_text` /
  ``write_prometheus`` / :class:`~repro.obs.exporters.PrometheusServer`),
  and ``python -m repro.obs.report`` to summarise a JSONL log.

Instrument catalog (what a telemetry-enabled ReplayService run emits):

=========================  =========  ========================================
name                       kind       meaning
=========================  =========  ========================================
frames_total               counter    environment frames appended to replay
blocks_total               counter    transition blocks absorbed by the core
learner_steps_total        counter    optimizer steps taken
feedback_enqueued_total    counter    priority-feedback packets enqueued
feedback_applied_total     counter    priority-feedback packets applied
fallback_draws             counter    probed draws that fell back to uniform
probe_draws                counter    health-probe draws taken
checkpoint_full_bytes      counter    bytes written by full checkpoints
checkpoint_delta_bytes     counter    bytes written by delta checkpoints
staleness_steps            histogram  feedback staleness in learner steps
                                      (exact p50/p95/p99 via INT_BUCKETS)
work_queue_depth           histogram  actor->replay queue depth per drain
batch_queue_depth          histogram  prefetch->learner queue depth per step
snapshot_pause_us          histogram  COW snapshot capture pause (microsec)
span_rollout_ms            histogram  actor rollout wall time
span_slab_draw_ms          histogram  prefetch slab draw wall time
span_learn_ms              histogram  learner step wall time
span_add_block_ms          histogram  replay-core block absorb wall time
span_apply_feedback_ms     histogram  priority feedback apply wall time
span_csp_rebuild_ms        histogram  AMPER CSP build wall time (eager path)
span_replay_sample_ms      histogram  ReplayBuffer.sample wall time (eager)
span_checkpoint_save_ms    histogram  CheckpointManager.save wall time
checkpoint_chain_len       gauge      delta-chain length since last full
csp_count                  gauge      CSP fill for the last probed draw
csp_occupancy              gauge      CSP fill / csp_capacity (0..1)
csp_match_count            gauge      TCAM match count before compaction
replay_live                gauge      live replay rows
sampling_kl_nats           gauge      windowed KL vs exact PER law (Fig. 7)
sampling_chi2              gauge      windowed chi-square vs exact PER law
sampling_window_samples    gauge      samples inside the monitor window
=========================  =========  ========================================

Disabled (the process default) every record call is one attribute
check, and instrumentation is host-side only, so the jitted sampling
paths keep their exact dispatch counts — pinned by tests/test_obs.py
against the committed BENCH_sampling.json.
"""
from typing import NamedTuple, Optional

from repro.obs.exporters import (JsonlExporter, PrometheusServer,
                                 parse_prometheus, prometheus_text,
                                 read_jsonl, write_prometheus)
from repro.obs.metrics import (INT_BUCKETS, TIME_BUCKETS_MS, US_BUCKETS,
                               Counter, Gauge, Histogram, Registry,
                               Snapshot, hist_stats)
from repro.obs.probes import (BINS, ReplayHealth, SamplingErrorMonitor,
                              chi_square, kl_nats, make_replay_probe,
                              priority_bin_counts)
from repro.obs.tracing import (get_registry, set_registry, span,
                               use_registry)


class Telemetry(NamedTuple):
    """Telemetry spec consumed by ``ReplayService`` and the examples.

    Attributes:
      registry: use this registry instead of a fresh per-run one (pass a
        long-lived registry to aggregate across runs; RunResult.metrics
        stays per-run via snapshot diffs either way).
      metrics_out: JSONL event-log path (appended; see JsonlExporter).
      prometheus_out: write the Prometheus text exposition here when the
        run finishes.
      probe_every: replay-health probe cadence in prefetch slab draws
        (0 disables the probe; each probe re-derives one draw's CSP off
        the hot path and refreshes the Fig. 7 KL gauge).
      window: SamplingErrorMonitor window, in probed draws.
      profile: also open jax.profiler.TraceAnnotation regions for spans.
    """

    registry: Optional[Registry] = None
    metrics_out: Optional[str] = None
    prometheus_out: Optional[str] = None
    probe_every: int = 16
    window: int = 200
    profile: bool = False


__all__ = [
    "BINS", "Counter", "Gauge", "Histogram", "INT_BUCKETS",
    "JsonlExporter", "PrometheusServer", "Registry", "ReplayHealth",
    "SamplingErrorMonitor", "Snapshot", "TIME_BUCKETS_MS", "Telemetry",
    "US_BUCKETS", "chi_square", "get_registry", "hist_stats", "kl_nats",
    "make_replay_probe", "parse_prometheus", "priority_bin_counts",
    "prometheus_text", "read_jsonl", "set_registry", "span",
    "use_registry", "write_prometheus",
]
