"""Exporters: structured JSONL event log + Prometheus-style text.

JSONL is the run log — one self-describing event per line (``kind``:
``snapshot`` | ``event``), so a crashed run keeps every flushed line and
downstream tools (``python -m repro.obs.report``, the CI artifact
uploads) can stream-parse without loading the file.  Prometheus text is
the scrape surface — a file for sidecar collectors plus an optional
zero-dependency HTTP endpoint for a real scraper.
"""
from __future__ import annotations

import http.server
import json
import math
import os
import threading
import time
from typing import Any

from repro.obs.metrics import Registry, Snapshot

SCHEMA_VERSION = 1


def _jsonable(x: Any) -> Any:
    if isinstance(x, dict):
        return {str(k): _jsonable(v) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [_jsonable(v) for v in x]
    if isinstance(x, float):
        return None if math.isnan(x) or math.isinf(x) else x
    if isinstance(x, (str, int, bool)) or x is None:
        return x
    if hasattr(x, "item"):  # numpy / jax scalars
        return _jsonable(x.item())
    return str(x)


class JsonlExporter:
    """Append-only JSONL metrics log (thread-safe, line-buffered)."""

    def __init__(self, path: str):
        self.path = path
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        from repro.analysis.locks import make_lock

        self._lock = make_lock("obs.jsonl_exporter")
        self._f = open(path, "a", buffering=1)
        self.lines_written = 0

    def _write(self, payload: dict) -> None:
        line = json.dumps(_jsonable(payload), separators=(",", ":"))
        with self._lock:
            self._f.write(line + "\n")
            self.lines_written += 1

    def write_snapshot(self, snapshot: Snapshot,
                       extra: dict | None = None) -> None:
        """One ``kind=snapshot`` line: every instrument's summary view
        (histograms as count/mean/min/max/p50/p95/p99 stats)."""
        payload = {"kind": "snapshot", "schema": SCHEMA_VERSION,
                   "ts": snapshot.ts, "metrics": snapshot.summary()}
        if extra:
            payload.update(extra)
        self._write(payload)

    def write_event(self, name: str, **fields) -> None:
        """One ``kind=event`` line for discrete occurrences (checkpoint
        written, preemption, run start/end)."""
        self._write({"kind": "event", "schema": SCHEMA_VERSION,
                     "ts": time.time(), "event": name, **fields})

    def close(self) -> None:
        with self._lock:
            if not self._f.closed:
                self._f.close()


def read_jsonl(path: str) -> list[dict]:
    """Parse a JSONL metrics log (skipping torn trailing lines)."""
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                out.append(json.loads(line))
            except json.JSONDecodeError:
                continue  # torn final line of a killed run
    return out


def _prom_name(name: str) -> str:
    return "".join(c if c.isalnum() or c == "_" else "_" for c in name)


def _fmt(v: float) -> str:
    if isinstance(v, float) and (math.isnan(v) or math.isinf(v)):
        return "NaN" if math.isnan(v) else ("+Inf" if v > 0 else "-Inf")
    return repr(float(v))


def prometheus_text(registry: Registry, prefix: str = "repro_") -> str:
    """Render the registry in the Prometheus text exposition format.

    Histograms emit cumulative ``_bucket{le=...}`` series plus ``_sum``
    and ``_count``, counters emit ``_total`` — the standard shapes, so
    any Prometheus-compatible scraper/parser consumes this directly.
    """
    lines: list[str] = []
    for name, inst in sorted(registry.instruments().items()):
        pname = prefix + _prom_name(name)
        data = inst.read()
        if inst.kind == "counter":
            lines.append(f"# HELP {pname}_total {inst.help or name}")
            lines.append(f"# TYPE {pname}_total counter")
            lines.append(f"{pname}_total {_fmt(data['value'])}")
        elif inst.kind == "gauge":
            lines.append(f"# HELP {pname} {inst.help or name}")
            lines.append(f"# TYPE {pname} gauge")
            lines.append(f"{pname} {_fmt(data['value'])}")
        else:  # histogram
            lines.append(f"# HELP {pname} {inst.help or name}")
            lines.append(f"# TYPE {pname} histogram")
            cum = 0
            for bound, c in zip(inst.bounds, data["buckets"]):
                cum += c
                lines.append(f'{pname}_bucket{{le="{_fmt(bound)}"}} {cum}')
            cum += data["buckets"][-1]
            lines.append(f'{pname}_bucket{{le="+Inf"}} {cum}')
            lines.append(f"{pname}_sum {_fmt(data['sum'])}")
            lines.append(f"{pname}_count {data['count']}")
    return "\n".join(lines) + "\n"


def write_prometheus(registry: Registry, path: str,
                     prefix: str = "repro_") -> str:
    """Atomic write of the current scrape text to ``path``."""
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        f.write(prometheus_text(registry, prefix=prefix))
    os.replace(tmp, path)
    return path


def parse_prometheus(text: str) -> dict[str, float]:
    """Minimal parser of the exposition format (series name -> value);
    the round-trip half of the export schema tests."""
    out: dict[str, float] = {}
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        name, _, value = line.rpartition(" ")
        try:
            out[name] = float(value)
        except ValueError:
            continue
    return out


class PrometheusServer:
    """Zero-dependency scrape endpoint (stdlib http.server, daemon
    thread).  ``GET /metrics`` serves the live registry."""

    def __init__(self, registry: Registry, port: int = 0,
                 host: str = "127.0.0.1", prefix: str = "repro_"):
        reg = registry

        class Handler(http.server.BaseHTTPRequestHandler):
            def do_GET(self):  # noqa: N802 (stdlib API name)
                if self.path.rstrip("/") not in ("", "/metrics"):
                    self.send_error(404)
                    return
                body = prometheus_text(reg, prefix=prefix).encode()
                self.send_response(200)
                self.send_header("Content-Type",
                                 "text/plain; version=0.0.4")
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def log_message(self, *a):  # silence per-request stderr spam
                pass

        self._server = http.server.ThreadingHTTPServer((host, port), Handler)
        self.port = self._server.server_address[1]
        self._thread = threading.Thread(
            target=self._server.serve_forever, name="obs-prometheus",
            daemon=True)
        self._thread.start()

    def close(self) -> None:
        self._server.shutdown()
        self._server.server_close()
        self._thread.join(timeout=5.0)
