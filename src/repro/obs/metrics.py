"""Metrics registry: counters / gauges / histograms with per-thread
lock-free accumulation and snapshot-on-read merge.

The write path is what runs inside the replay fabric's hot loops —
actor, prefetch, learner, replay-core and snapshot-writer threads all
record concurrently — so it must neither lock nor contend:

* every instrument keeps one *cell* per writer thread; a thread only
  ever mutates its own cell, so the write path is a plain attribute
  update under the GIL (no lock, no CAS, no cross-thread cache traffic);
* the registry-level lock is taken only when a thread touches an
  instrument for the FIRST time (cell creation) and when a reader
  snapshots — reads merge all cells into one immutable
  :class:`Snapshot`, so a half-updated cell is at worst one event
  stale, never torn (counts are ints, bucket counts are per-slot adds).

This is safe alongside the COW snapshotter and the replay/writer/actor
threads by construction: nothing here blocks them, and nothing they own
is read other than through the merge.

Per-run views come from snapshot *diffs*: instruments are cumulative
(Prometheus-style), and ``Snapshot.diff(base)`` subtracts counters and
histogram buckets so a caller that spans several runs over one registry
(warmup + measurement, or a long-lived service) can report per-run
numbers without resetting anything.
"""
from __future__ import annotations

import bisect
import math
import threading
import time
from typing import Any, Iterable

# Default wall-time buckets for span histograms: 10us .. ~5.6s in
# quarter-decade steps (spans record milliseconds; slower outliers land
# in the overflow bucket, whose percentile reads back the observed max).
TIME_BUCKETS_MS = tuple(
    0.01 * (10 ** 0.25) ** i for i in range(24)
)
# Microsecond buckets for sub-millisecond pauses (COW snapshot capture).
US_BUCKETS = tuple(1.0 * (10 ** 0.25) ** i for i in range(21))
# Small-integer buckets (exact up to 64) for discrete quantities like
# feedback staleness in learner steps or queue depths.
INT_BUCKETS = tuple(range(65)) + tuple(128 * 2 ** i for i in range(8))


class _Cell:
    """One writer thread's private accumulator (no locks ever)."""

    __slots__ = ("count", "total", "vmin", "vmax", "buckets")

    def __init__(self, n_buckets: int = 0):
        self.count = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf
        self.buckets = [0] * n_buckets if n_buckets else None


class Instrument:
    """Base: per-thread cells keyed by thread id, created under the
    registry lock, written lock-free afterwards."""

    kind = "instrument"

    def __init__(self, registry: "Registry", name: str, help: str = ""):
        self._registry = registry
        self.name = name
        self.help = help
        # Append-only: a dead thread's cell stays merged forever (cells
        # are cumulative), and thread-id reuse can't alias two threads
        # onto one cell.  Bounded by writer threads over registry life.
        self._cells: list[_Cell] = []
        self._local = threading.local()

    def _new_cell(self) -> _Cell:
        return _Cell()

    def _cell(self) -> _Cell:
        cell = getattr(self._local, "cell", None)
        if cell is None:
            cell = self._new_cell()
            with self._registry._lock:
                self._cells.append(cell)
            self._local.cell = cell
        return cell

    def _merged_cells(self) -> list[_Cell]:
        with self._registry._lock:
            return list(self._cells)


class Counter(Instrument):
    """Monotone event count (optionally weighted)."""

    kind = "counter"

    def add(self, value: float = 1.0) -> None:
        if not self._registry.enabled:
            return
        cell = self._cell()
        cell.count += 1
        cell.total += value

    @property
    def value(self) -> float:
        return sum(c.total for c in self._merged_cells())

    def read(self) -> dict:
        cells = self._merged_cells()
        return {"value": sum(c.total for c in cells),
                "events": sum(c.count for c in cells)}


class Gauge(Instrument):
    """Last-written value (per thread, merged by freshest write)."""

    kind = "gauge"

    def _new_cell(self) -> _Cell:
        cell = _Cell()
        cell.total = math.nan
        return cell

    def set(self, value: float) -> None:
        if not self._registry.enabled:
            return
        cell = self._cell()
        cell.total = float(value)
        cell.count += 1
        cell.vmax = time.monotonic()  # freshness stamp for the merge

    @property
    def value(self) -> float:
        best, best_t = math.nan, -math.inf
        for c in self._merged_cells():
            if c.count and c.vmax > best_t:
                best, best_t = c.total, c.vmax
        return best

    def read(self) -> dict:
        return {"value": self.value}


class Histogram(Instrument):
    """Fixed-bound bucket histogram with exact count/sum/min/max.

    ``bounds`` are inclusive upper edges of the first ``len(bounds)``
    buckets plus an implicit overflow bucket, so percentile estimates
    come from cumulative bucket counts (exact whenever the recorded
    values land on integer bounds, as staleness and queue depths do).
    """

    kind = "histogram"

    def __init__(self, registry: "Registry", name: str, help: str = "",
                 bounds: Iterable[float] = TIME_BUCKETS_MS):
        super().__init__(registry, name, help)
        self.bounds = tuple(float(b) for b in bounds)

    def _new_cell(self) -> _Cell:
        return _Cell(n_buckets=len(self.bounds) + 1)

    def observe(self, value: float) -> None:
        if not self._registry.enabled:
            return
        value = float(value)
        cell = self._cell()
        cell.count += 1
        cell.total += value
        if value < cell.vmin:
            cell.vmin = value
        if value > cell.vmax:
            cell.vmax = value
        cell.buckets[bisect.bisect_left(self.bounds, value)] += 1

    def observe_n(self, value: float, n: int) -> None:
        """Record ``n`` identical observations in O(1) (e.g. a feedback
        slab whose S batches all share one staleness)."""
        if not self._registry.enabled or n <= 0:
            return
        value = float(value)
        cell = self._cell()
        cell.count += n
        cell.total += value * n
        if value < cell.vmin:
            cell.vmin = value
        if value > cell.vmax:
            cell.vmax = value
        cell.buckets[bisect.bisect_left(self.bounds, value)] += n

    def read(self) -> dict:
        cells = self._merged_cells()
        buckets = [0] * (len(self.bounds) + 1)
        count, total = 0, 0.0
        vmin, vmax = math.inf, -math.inf
        for c in cells:
            count += c.count
            total += c.total
            vmin = min(vmin, c.vmin)
            vmax = max(vmax, c.vmax)
            for i, b in enumerate(c.buckets):
                buckets[i] += b
        return {"count": count, "sum": total,
                "min": vmin if count else math.nan,
                "max": vmax if count else math.nan,
                "buckets": buckets}

    def percentile(self, q: float) -> float:
        return _hist_percentile(self.read(), self.bounds, q)


def _hist_percentile(data: dict, bounds: tuple, q: float) -> float:
    """Percentile estimate from cumulative bucket counts.

    Returns the upper bound of the bucket holding the q-quantile
    (clamped to the observed max), so integer-valued series recorded on
    integer bounds read back exactly; the overflow bucket reports the
    exact observed max.
    """
    count = data["count"]
    if not count:
        return math.nan
    rank = q * count
    seen = 0
    for i, b in enumerate(data["buckets"]):
        seen += b
        if seen >= rank and b:
            if i >= len(bounds):
                return data["max"]
            return min(bounds[i], data["max"])
    return data["max"]


def hist_stats(data: dict, bounds: tuple) -> dict:
    """Summary view (count/mean/min/max/p50/p95/p99) of a histogram read."""
    count = data["count"]
    return {
        "count": count,
        "mean": data["sum"] / count if count else 0.0,
        "min": data["min"] if count else 0.0,
        "max": data["max"] if count else 0.0,
        "p50": _hist_percentile(data, bounds, 0.50) if count else 0.0,
        "p95": _hist_percentile(data, bounds, 0.95) if count else 0.0,
        "p99": _hist_percentile(data, bounds, 0.99) if count else 0.0,
    }


class Snapshot:
    """Immutable point-in-time merge of every instrument in a registry."""

    def __init__(self, data: dict[str, dict], meta: dict[str, dict],
                 ts: float):
        self.data = data      # name -> instrument read()
        self.meta = meta      # name -> {"kind": ..., "bounds": ...}
        self.ts = ts

    def diff(self, base: "Snapshot | None") -> "Snapshot":
        """Per-run view: subtract a base snapshot's counters and
        histogram buckets; gauges keep their current value."""
        if base is None:
            return self
        out: dict[str, dict] = {}
        for name, cur in self.data.items():
            kind = self.meta[name]["kind"]
            prev = base.data.get(name)
            if prev is None or kind == "gauge":
                out[name] = dict(cur)
                continue
            if kind == "counter":
                out[name] = {"value": cur["value"] - prev["value"],
                             "events": cur["events"] - prev["events"]}
            else:  # histogram: bucket-wise subtraction; min/max are
                # only valid for the union window, keep current's.
                out[name] = {
                    "count": cur["count"] - prev["count"],
                    "sum": cur["sum"] - prev["sum"],
                    "min": cur["min"], "max": cur["max"],
                    "buckets": [a - b for a, b in
                                zip(cur["buckets"], prev["buckets"])],
                }
        return Snapshot(out, self.meta, self.ts)

    def summary(self) -> dict:
        """JSON-friendly rendering: histograms become stats dicts."""
        out = {}
        for name, d in self.data.items():
            kind = self.meta[name]["kind"]
            if kind == "histogram":
                out[name] = hist_stats(d, self.meta[name]["bounds"])
            else:
                out[name] = d
        return out


class Registry:
    """Named instrument container.

    ``enabled=False`` turns every record call into one attribute check —
    the zero-dispatch, near-zero-cost disabled mode the tier-1 guard
    pins (instrumentation is host-side only; it can never add XLA
    dispatches either way).
    """

    def __init__(self, enabled: bool = True):
        from repro.analysis.locks import make_lock

        self.enabled = enabled
        self._lock = make_lock("obs.registry")
        self._instruments: dict[str, Instrument] = {}

    def _get(self, name: str, factory) -> Any:
        inst = self._instruments.get(name)
        if inst is None:
            with self._lock:
                inst = self._instruments.get(name)
                if inst is None:
                    inst = factory()
                    self._instruments[name] = inst
        return inst

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(name, lambda: Counter(self, name, help))

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(name, lambda: Gauge(self, name, help))

    def histogram(self, name: str, help: str = "",
                  bounds: Iterable[float] = TIME_BUCKETS_MS) -> Histogram:
        return self._get(
            name, lambda: Histogram(self, name, help, bounds=bounds))

    def instruments(self) -> dict[str, Instrument]:
        with self._lock:
            return dict(self._instruments)

    def snapshot(self) -> Snapshot:
        insts = self.instruments()
        data = {name: inst.read() for name, inst in insts.items()}
        meta = {name: {"kind": inst.kind,
                       "bounds": getattr(inst, "bounds", None)}
                for name, inst in insts.items()}
        return Snapshot(data, meta, ts=time.time())
