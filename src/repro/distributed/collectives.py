"""Explicit collectives: compressed cross-pod gradient sync.

Within a pod, gradients are reduce-scattered by the SPMD partitioner over
the fast ICI ("data"/"model" axes).  ACROSS pods the links are slow
(DCN), so the framework optionally takes manual control of the "pod"
axis with shard_map and psums an int8 error-feedback payload instead of
fp32 — 4x fewer cross-pod bytes, convergence preserved by the error
feedback (train/optimizer.py).

``grad_fn_with_pod_sync`` wraps a per-pod gradient function: the "pod"
mesh axis becomes Manual (per-pod batch shard in, identical synced grads
out), while "data"/"model" stay Auto so the inner model code still
shards the usual way.
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.distributed.sharding import axis_size
from repro.train import optimizer as opt_mod


def psum_int8_mean(grads: Any, axis: str) -> Any:
    """Quantize -> psum int8 payload -> dequantize -> mean over pods.

    int8 sums across <=127 pods fit int32 accumulators; we psum the int32
    widened payload (the wire format is int8 — the HLO all-reduce operand
    is the narrow tensor, which is what the collective-bytes analysis
    counts).
    """
    n = axis_size(axis)

    def one(g):
        q, s = opt_mod.quantize_int8(g.astype(jnp.float32))
        q_sum = jax.lax.psum(q.astype(jnp.int32), axis)
        s_max = jax.lax.pmax(s, axis)  # conservative shared scale
        return (q_sum.astype(jnp.float32) * s_max) / n

    return jax.tree.map(one, grads)


def grad_fn_with_pod_sync(grad_fn: Callable, mesh, param_specs: Any,
                          batch_specs: Any, compress: bool = True) -> Callable:
    """Wrap grad_fn(params, batch) -> grads with manual pod-axis sync.

    params are replicated over "pod" (sharded over data/model by their own
    specs); batch is sharded over "pod"; the returned grads are identical
    on every pod (mean), so the optimizer step stays pure SPMD.
    """
    if "pod" not in mesh.axis_names:
        return grad_fn

    strip = lambda spec_tree: jax.tree.map(
        lambda s: P(*[_strip_pod(a) for a in s]), spec_tree,
        is_leaf=lambda x: isinstance(x, P))
    inner_param_specs = strip(param_specs)
    inner_batch_specs = batch_specs  # leading dim carries "pod": shard_map splits it

    def body(params, batch):
        g = grad_fn(params, batch)
        if compress:
            return psum_int8_mean(g, "pod")
        return jax.tree.map(
            lambda t: jax.lax.pmean(t.astype(jnp.float32), "pod"), g)

    return shard_map(
        body, mesh=mesh,
        in_specs=(inner_param_specs, inner_batch_specs),
        out_specs=inner_param_specs,
        check_rep=False,
        auto=frozenset(a for a in mesh.axis_names if a != "pod"),
    )


def _strip_pod(axis_entry):
    if axis_entry is None:
        return None
    if isinstance(axis_entry, str):
        return None if axis_entry == "pod" else axis_entry
    t = tuple(a for a in axis_entry if a != "pod")
    return t if t else None
