"""Logical-axis sharding rules (MaxText-style) + constraint helpers.

Models declare per-param logical axes (ParamSpec.axes) and annotate
activations with :func:`logical_constraint`.  A :class:`ShardingRules`
context maps logical names -> mesh axes; the same model definition then
runs on the production (pod, data, model) mesh, a single-pod mesh, or an
unsharded CPU smoke test (no context active -> constraints are no-ops).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any, Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> mesh axis (or tuple of mesh axes)
TRAIN_RULES: dict[str, Any] = {
    "batch": ("pod", "data"),
    "embed": ("pod", "data"),     # FSDP: params sharded over the data axes
    "embed_out": None,
    "qkv": "model",               # TP over fused head*head_dim features
    "kv": "model",
    "heads": "model",
    "mlp": "model",
    "experts": "model",           # EP
    "expert_mlp": None,           # per-expert hidden: EP already covers it
    "vocab": "model",
    # Sequence parallelism: saved layer activations (the remat carries)
    # shard over "model" as well as batch over "data" — without this an
    # 88-layer 4k x 256 train step saves 88 x (B_loc, S, D) = 217 GB/dev.
    "seq": "model",
    "seq_out": None,            # logits seq dim (vocab already takes "model")
    "tokens": ("pod", "data"),  # flat (B*S) token dim in MoE dispatch
    "kv_seq": None,
    "layers": None,               # scan dim: never sharded
}

SERVE_RULES: dict[str, Any] = {
    **TRAIN_RULES,
    "embed": None,                # no FSDP at serving time: TP only
    "kv_seq": "model",            # split-KV decode: cache seq over model
}

# Pure-FSDP (ZeRO-3) training: NO tensor parallelism — params fully
# sharded over every mesh axis and all-gathered just-in-time; batch over
# (data, model).  Trades the per-layer TP activation all-reduces for
# param gathers: the winning config when activations >> params traffic
# is false, i.e. large models at moderate sequence length.
TRAIN_RULES_FSDP: dict[str, Any] = {
    **TRAIN_RULES,
    "batch": ("data", "model"),
    "embed": ("pod", "data", "model"),
    "qkv": None, "kv": None, "heads": None, "mlp": None, "vocab": None,
    "experts": "model",           # EP stays: expert weights shard by expert
    "seq": None,
}

RULE_PRESETS = {"tp": TRAIN_RULES, "fsdp": TRAIN_RULES_FSDP,
                "serve": SERVE_RULES}


class ShardingRules:
    def __init__(self, mesh: Mesh, rules: dict[str, Any]):
        self.mesh = mesh
        self.rules = dict(rules)

    def spec(self, axes: Sequence[Optional[str]]) -> P:
        """PartitionSpec for a logical-axes tuple, dropping mesh axes the
        current mesh does not have (e.g. no 'pod' on the single-pod mesh)."""
        parts = []
        for ax in axes:
            m = self.rules.get(ax) if ax else None
            if m is None:
                parts.append(None)
                continue
            ms = (m,) if isinstance(m, str) else tuple(m)
            ms = tuple(a for a in ms if a in self.mesh.axis_names)
            parts.append(ms if len(ms) > 1 else (ms[0] if ms else None))
        return P(*parts)

    def sharding(self, axes: Sequence[Optional[str]]) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(axes))


_ACTIVE = threading.local()


@contextlib.contextmanager
def use_rules(rules: ShardingRules):
    prev = getattr(_ACTIVE, "rules", None)
    _ACTIVE.rules = rules
    try:
        yield rules
    finally:
        _ACTIVE.rules = prev


def active_rules() -> Optional[ShardingRules]:
    return getattr(_ACTIVE, "rules", None)


def axis_size(name: str) -> jax.Array:
    """Number of shards along one mesh axis, from inside shard_map/pmap.

    ``jax.lax.axis_size`` does not exist in the pinned JAX version;
    ``psum(1, name)`` is the portable equivalent (costless: XLA folds a
    constant all-reduce to the static mesh extent).
    """
    return jax.lax.psum(jax.numpy.int32(1), name)


def logical_constraint(x: jax.Array, axes: Sequence[Optional[str]]) -> jax.Array:
    """with_sharding_constraint by logical axes; no-op without a context."""
    rules = active_rules()
    if rules is None:
        return x
    return jax.lax.with_sharding_constraint(x, rules.sharding(axes))


def tree_pspecs(axes_tree: Any, rules: ShardingRules) -> Any:
    """Map a param-axes tree to PartitionSpecs."""
    return jax.tree.map(
        lambda axes: rules.spec(axes), axes_tree,
        is_leaf=lambda x: isinstance(x, tuple))


def tree_shardings(axes_tree: Any, rules: ShardingRules) -> Any:
    return jax.tree.map(
        lambda axes: rules.sharding(axes), axes_tree,
        is_leaf=lambda x: isinstance(x, tuple))
