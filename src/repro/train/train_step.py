"""Train-step factory: grad accumulation, mixed precision, metrics.

``make_train_step(model, optimizer)`` returns a pure (state, batch) ->
(state, metrics) function ready for jit with in/out shardings derived
from the model's logical axes.  Microbatching scans over batch slices
accumulating fp32 grads (sequential grad accumulation — the standard
memory/throughput trade at large global batch).
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp


class TrainState(NamedTuple):
    step: jax.Array
    params: Any
    opt_state: Any


def init_train_state(model, optimizer, key) -> TrainState:
    params = model.init_params(key)
    return TrainState(step=jnp.zeros((), jnp.int32), params=params,
                      opt_state=optimizer.init(params))


def abstract_train_state(model, optimizer) -> TrainState:
    params = model.abstract_params()
    f32 = lambda t: jax.tree.map(
        lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), t)
    from repro.train.optimizer import AdamWState
    master = f32(params) if optimizer.mixed_precision else None
    return TrainState(
        step=jax.ShapeDtypeStruct((), jnp.int32), params=params,
        opt_state=AdamWState(m=f32(params), v=f32(params),
                             count=jax.ShapeDtypeStruct((), jnp.int32),
                             master=master))


def train_state_axes(model, optimizer=None) -> TrainState:
    """Logical-axes tree for the full train state (for shardings)."""
    axes = model.param_axes()
    from repro.train.optimizer import AdamWState
    mixed = bool(optimizer is not None and optimizer.mixed_precision)
    return TrainState(step=(), params=axes,
                      opt_state=AdamWState(m=axes, v=axes, count=(),
                                           master=axes if mixed else None))


def make_train_step(model, optimizer, *, microbatches: int = 1,
                    grad_fn_override=None):
    """Returns train_step(state, batch) -> (state, metrics)."""

    def loss_fn(params, batch):
        loss, metrics = model.loss(params, batch)
        return loss, metrics

    def grads_of(params, batch):
        (loss, metrics), g = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        return g, dict(metrics, loss=loss)

    def accumulate(params, batch):
        if microbatches == 1:
            return grads_of(params, batch)
        split = jax.tree.map(
            lambda t: t.reshape((microbatches, t.shape[0] // microbatches)
                                + t.shape[1:]), batch)

        def body(carry, mb):
            acc, msum = carry
            g, metrics = grads_of(params, mb)
            acc = jax.tree.map(lambda a, gg: a + gg.astype(jnp.float32), acc, g)
            msum = jax.tree.map(lambda a, b: a + b, msum, metrics)
            return (acc, msum), None

        zeros_g = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        m_struct = jax.eval_shape(
            lambda mb: grads_of(params, mb)[1],
            jax.tree.map(lambda t: t[0], split))
        zeros_m = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), m_struct)
        (acc, msum), _ = jax.lax.scan(body, (zeros_g, zeros_m), split)
        g = jax.tree.map(lambda a: a / microbatches, acc)
        metrics = jax.tree.map(lambda a: a / microbatches, msum)
        return g, metrics

    def train_step(state: TrainState, batch) -> tuple[TrainState, dict]:
        if grad_fn_override is not None:
            g = grad_fn_override(state.params, batch)
            metrics = {}
        else:
            g, metrics = accumulate(state.params, batch)
        new_params, opt_state, opt_metrics = optimizer.update(
            g, state.opt_state, state.params)
        metrics = {**metrics, **opt_metrics}
        return TrainState(step=state.step + 1, params=new_params,
                          opt_state=opt_state), metrics

    return train_step
