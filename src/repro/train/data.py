"""Data pipeline with AMPER prioritized sequence replay.

The LM-side integration of the paper (DESIGN.md §Arch-applicability):
training sequences live in a replay table with per-sequence priorities
(EMA of the sequence's last loss — the LM analogue of |TD error|).  Each
step the sampler (uniform / PER / AMPER-k / AMPER-fr — the full paper
menu) draws the global batch, the step runs, and fresh per-sequence
losses are written back.  The sample -> train -> update cycle is exactly
Fig. 1 with the target network replaced by the LM.

The token source is a deterministic synthetic corpus (seeded Zipf
mixture) so every run — and every resume — is bitwise reproducible
without external data; swap `corpus_tokens` for a memmap of real tokens
in production.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.samplers import make_sampler


def corpus_tokens(n_seqs: int, seq_len: int, vocab: int, seed: int = 0) -> np.ndarray:
    """Deterministic synthetic corpus: per-sequence Zipf unigram mixtures."""
    rng = np.random.default_rng(seed)
    base = rng.zipf(1.5, size=(n_seqs, seq_len)).astype(np.int64)
    return (base % vocab).astype(np.int32)


class ReplayDataState(NamedTuple):
    sampler_state: object
    loss_ema: jax.Array     # float32[n_seqs]
    seen: jax.Array         # int32[n_seqs]


class PrioritizedSeqData:
    """Priority-sampled sequence replay over a fixed token table."""

    def __init__(self, tokens: np.ndarray, batch: int, *,
                 sampler: str = "amper-fr", alpha: float = 0.6,
                 v_max: float = 12.0, m: int = 20, lam_fr: float = 2.0,
                 csp_ratio: float = 0.15, seed: int = 0):
        self.tokens = jnp.asarray(tokens)
        self.n_seqs, self.seq_len = tokens.shape
        self.batch = batch
        self.alpha = alpha
        self.v_max = v_max
        self.sampler = make_sampler(
            sampler, self.n_seqs, m=m, lam_fr=lam_fr, csp_ratio=csp_ratio,
            v_max=v_max, min_csp=batch, knn_mode="bisect")

    def init(self) -> ReplayDataState:
        st = self.sampler.init()
        # every sequence starts at max priority => replayed at least once
        st = self.sampler.update(
            st, jnp.arange(self.n_seqs),
            jnp.full((self.n_seqs,), self.v_max, jnp.float32))
        return ReplayDataState(
            sampler_state=st,
            loss_ema=jnp.full((self.n_seqs,), self.v_max, jnp.float32),
            seen=jnp.zeros((self.n_seqs,), jnp.int32))

    @functools.partial(jax.jit, static_argnums=0)
    def sample(self, state: ReplayDataState, key: jax.Array):
        """-> (idx int32[batch], batch dict)."""
        idx = self.sampler.sample(state.sampler_state, key, self.batch)
        seq = self.tokens[idx]
        batch = {
            "tokens": seq[:, :-1],
            "targets": seq[:, 1:],
            "loss_mask": jnp.ones((self.batch, self.seq_len - 1), jnp.float32),
        }
        return idx, batch

    @functools.partial(jax.jit, static_argnums=0)
    def update(self, state: ReplayDataState, idx: jax.Array,
               seq_loss: jax.Array) -> ReplayDataState:
        """Write back fresh per-sequence losses (the LM 'TD errors')."""
        # first write replaces (init value is a v_max placeholder);
        # subsequent writes smooth with an EMA.
        old = state.loss_ema[idx]
        blended = jnp.where(state.seen[idx] > 0,
                            0.5 * old + 0.5 * seq_loss, seq_loss)
        ema = state.loss_ema.at[idx].set(blended)
        prio = jnp.clip(ema[idx], 0.0, self.v_max) ** self.alpha
        st = self.sampler.update(state.sampler_state, idx, prio)
        return ReplayDataState(
            sampler_state=st, loss_ema=ema,
            seen=state.seen.at[idx].add(1))
