"""AdamW + global-norm clipping + LR schedules, from scratch.

Also hosts the int8 error-feedback gradient compressor used for the
cross-pod gradient sync (distributed/collectives.py wires it into a
manual-"pod"-axis shard_map): per-tensor symmetric int8 quantization with
the quantization error carried to the next step, which keeps SGD/Adam
convergence while cutting cross-pod (DCN) gradient bytes 4x.
"""
from __future__ import annotations

import math
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    m: Any
    v: Any
    count: jax.Array
    master: Any = None   # fp32 master copies when params are low-precision


class AdamW:
    """AdamW with optional mixed precision.

    ``mixed_precision=True`` expects LOW-precision (bf16) model params:
    fp32 master weights live in the optimizer state, the update runs in
    fp32 against the master, and the bf16 params are re-derived each
    step.  This halves every FSDP param all-gather and grad
    reduce-scatter on the wire — the collective-bound hillclimb lever.
    """

    def __init__(self, lr: Callable[[jax.Array], jax.Array] | float,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1, clip_norm: float = 1.0,
                 mixed_precision: bool = False):
        self.lr = lr if callable(lr) else (lambda step: jnp.float32(lr))
        self.b1, self.b2, self.eps = b1, b2, eps
        self.weight_decay = weight_decay
        self.clip_norm = clip_norm
        self.mixed_precision = mixed_precision

    def init(self, params) -> AdamWState:
        zeros = lambda t: jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), t)
        master = (jax.tree.map(lambda p: p.astype(jnp.float32), params)
                  if self.mixed_precision else None)
        return AdamWState(m=zeros(params), v=zeros(params),
                          count=jnp.zeros((), jnp.int32), master=master)

    def update(self, grads, state: AdamWState, params):
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        gnorm = global_norm(grads)
        if self.clip_norm:
            scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(gnorm, 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)
        count = state.count + 1
        b1, b2 = self.b1, self.b2
        m = jax.tree.map(lambda mm, g: b1 * mm + (1 - b1) * g, state.m, grads)
        v = jax.tree.map(lambda vv, g: b2 * vv + (1 - b2) * g * g, state.v, grads)
        c = count.astype(jnp.float32)
        mhat_scale = 1.0 / (1 - b1 ** c)
        vhat_scale = 1.0 / (1 - b2 ** c)
        lr = self.lr(count)
        ref = state.master if self.mixed_precision else params

        def upd(p_ref, mm, vv):
            u = (mm * mhat_scale) / (jnp.sqrt(vv * vhat_scale) + self.eps)
            u = u + self.weight_decay * p_ref.astype(jnp.float32)
            return p_ref.astype(jnp.float32) - lr * u

        new_master = jax.tree.map(upd, ref, m, v)
        if self.mixed_precision:
            new_params = jax.tree.map(
                lambda nm, p: nm.astype(p.dtype), new_master, params)
            st = AdamWState(m=m, v=v, count=count, master=new_master)
        else:
            new_params = jax.tree.map(
                lambda nm, p: nm.astype(p.dtype), new_master, params)
            st = AdamWState(m=m, v=v, count=count, master=None)
        return new_params, st, {"grad_norm": gnorm, "lr": lr}


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def cosine_schedule(peak_lr: float, warmup: int, total: int,
                    floor_frac: float = 0.1) -> Callable:
    def lr(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(warmup, 1)
        t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (floor_frac + (1 - floor_frac) * 0.5 * (1 + jnp.cos(math.pi * t)))
        return jnp.where(step < warmup, warm, cos)
    return lr


# ---------------------------------------------------------------------------
# int8 error-feedback compression (cross-pod gradient sync)
# ---------------------------------------------------------------------------

def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-tensor symmetric int8. Returns (q, scale)."""
    amax = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12)
    scale = amax / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def ef_compress_tree(grads, error):
    """Error-feedback int8 round-trip of a gradient tree.

    Returns (quantized_tree [(q, scale) leaves], new_error_tree).  The
    caller psums the int8 payload across the pod axis; the residual
    (g+e) - dq(q) is carried to the next step so compression noise does
    not bias the long-run gradient estimate (EF-SGD / EF21).
    """
    def one(g, e):
        t = g.astype(jnp.float32) + e
        q, s = quantize_int8(t)
        back = dequantize_int8(q, s)
        return (q, s), t - back

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(error)
    qs, errs = zip(*(one(g, e) for g, e in zip(flat_g, flat_e)))
    return (jax.tree.unflatten(treedef, [q for q in qs]),
            jax.tree.unflatten(treedef, list(errs)))
