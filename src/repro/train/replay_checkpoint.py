"""Replay-aware checkpointing: exact-resume serialization of the replay stack.

The generic layer (:mod:`repro.train.checkpoint`) serializes any pytree;
this module adds what the replay subsystem needs on top:

* **Sampler-state coverage.**  Every registry sampler's state (uniform /
  sum-tree / cumsum / AMPER-k / AMPER-fr and the sharded kinds) is a pure
  pytree, so :func:`replay_target` builds the abstract restore target
  straight from ``ReplayBuffer.init`` via ``jax.eval_shape`` — no
  per-sampler serialization code, and the generic layer's name/dtype
  manifest validation catches a checkpoint of one sampler kind restored
  into another.

* **Elastic sharded restore.**  Checkpoints store every array dense (the
  save gathers to host), so "repartitioning the priority table and
  storage arcs onto a different shard count" is a device_put with the
  *target* sampler's ``NamedSharding``: :func:`replay_shardings` walks
  any snapshot tree and assigns the buffer's capacity-dim sharding to
  every capacity-leading leaf (storage leaves, write stamps, priority
  tables) and a replicated sharding to the rest.  A table saved on 8
  shards restores onto 2 — or onto one CPU device — with
  membership-exact priorities (pinned in ``tests/test_replay_checkpoint``).

* **Whole-ReplayState save/restore** (:func:`save_replay` /
  :func:`restore_replay`) including the hidden exact-resume state the
  async runtime relies on: per-slot write stamps, the global add counter,
  ``max_priority``, the ring position, and (for ``n_step > 1`` buffers)
  the :class:`~repro.core.replay_buffer.NStepAccumulator` window — ring
  of in-flight transitions, saturation count, and cursor — all live in
  ``ReplayState`` and round-trip bitwise, so a resumed n-step run keeps
  aggregating mid-window exactly where the killed one stopped (pinned in
  ``tests/test_replay_checkpoint.py`` / ``tests/test_resume.py``).
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec

from repro.train import checkpoint as ck


def replay_target(rb, example_transition: Any):
    """Abstract ``ReplayState`` tree (ShapeDtypeStructs) for ``rb``.

    This is the restore target: building it from the *target* buffer's
    ``init`` means restore validates the checkpoint against the sampler
    kind and capacity actually configured now, not whatever wrote it.
    """
    return jax.eval_shape(rb.init, example_transition)


def replay_shardings(rb, target: Any):
    """Sharding tree for ``target`` under ``rb``'s mesh placement.

    Every leaf whose leading dim equals the buffer capacity follows the
    sampler's capacity-dim ``NamedSharding`` (storage, write stamps,
    priority table); every other leaf is replicated on the same mesh.
    Returns ``None`` when the buffer is unsharded (single device), which
    the generic restore treats as plain host->default-device puts.
    """
    sh = getattr(rb, "storage_sharding", None)
    if sh is None:
        return None
    replicated = NamedSharding(sh.mesh, PartitionSpec())

    def leaf_sharding(leaf):
        shape = np.shape(leaf)
        return sh if (len(shape) >= 1 and shape[0] == rb.capacity) else replicated

    return jax.tree.map(leaf_sharding, target)


def save_replay(directory: str, step: int, state: Any,
                meta: dict | None = None) -> str:
    """Durable atomic save of a ``ReplayState`` (or any snapshot tree
    containing one).  Device arrays are gathered dense on host, so the
    checkpoint is shard-count agnostic."""
    return ck.save(directory, step, state, meta=meta)


def restore_replay(directory: str, step: int, rb,
                   example_transition: Any):
    """Restore a ``ReplayState`` into ``rb``'s configured placement.

    ``rb`` may be built over a different mesh / shard count (or none)
    than the buffer that saved the checkpoint: the priority table and
    storage arcs are repartitioned by the device_put, membership-exactly.
    """
    target = replay_target(rb, example_transition)
    return ck.restore(directory, step, target,
                      replay_shardings(rb, target))
