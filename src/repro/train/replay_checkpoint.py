"""Replay-aware checkpointing: exact-resume serialization of the replay stack.

The generic layer (:mod:`repro.train.checkpoint`) serializes any pytree;
this module adds what the replay subsystem needs on top:

* **Sampler-state coverage.**  Every registry sampler's state (uniform /
  sum-tree / cumsum / AMPER-k / AMPER-fr and the sharded kinds) is a pure
  pytree, so :func:`replay_target` builds the abstract restore target
  straight from ``ReplayBuffer.init`` via ``jax.eval_shape`` — no
  per-sampler serialization code, and the generic layer's name/dtype
  manifest validation catches a checkpoint of one sampler kind restored
  into another.

* **Elastic sharded restore.**  Checkpoints store every array dense (the
  save gathers to host), so "repartitioning the priority table and
  storage arcs onto a different shard count" is a device_put with the
  *target* sampler's ``NamedSharding``: :func:`replay_shardings` walks
  any snapshot tree and assigns the buffer's capacity-dim sharding to
  every capacity-leading leaf (storage leaves, write stamps, priority
  tables) and a replicated sharding to the rest.  A table saved on 8
  shards restores onto 2 — or onto one CPU device — with
  membership-exact priorities (pinned in ``tests/test_replay_checkpoint``).

* **Exact dirty sets for incremental saves.**  :func:`replay_marks`
  captures the ring write position + global add counter at a snapshot;
  :func:`replay_dirty` turns the next state plus those marks (and any
  out-of-band priority-feedback rows) into the per-leaf dirty tree the
  generic layer's ``save_incremental`` consumes — storage and stamps
  dirty only on the written ring arc, priority tables on arc ∪ touched
  rows — so steady-state checkpoints write KBs of delta instead of the
  full dense dump.

* **Whole-ReplayState save/restore** (:func:`save_replay` /
  :func:`restore_replay`) including the hidden exact-resume state the
  async runtime relies on: per-slot write stamps, the global add counter,
  ``max_priority``, the ring position, and (for ``n_step > 1`` buffers)
  the :class:`~repro.core.replay_buffer.NStepAccumulator` window — ring
  of in-flight transitions, saturation count, and cursor — all live in
  ``ReplayState`` and round-trip bitwise, so a resumed n-step run keeps
  aggregating mid-window exactly where the killed one stopped (pinned in
  ``tests/test_replay_checkpoint.py`` / ``tests/test_resume.py``).
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec

from repro.core.replay_buffer import ReplayState, dirty_arcs, rows_to_ranges
from repro.train import checkpoint as ck


_U32 = 1 << 32


def replay_marks(state: Any) -> dict:
    """Host watermarks of ``state`` identifying what a later delta save
    must cover: the ring write position, the global add counter (masked
    to its unsigned 32-bit value — the device word is a wrapping int32)
    and its rollover generation.  Capture at (or right after) each save;
    feed back to :func:`replay_dirty` at the next one."""
    return {"pos": int(state.pos),
            "total_adds": int(state.total_adds) & (_U32 - 1),
            "add_gen": int(state.add_gen)}


def replay_dirty(rb, state: Any, marks: dict,
                 priority_rows=None) -> Any:
    """Exact dirty tree for ``state`` relative to the ``marks`` snapshot.

    * storage leaves and the write-stamp table are dirty exactly on the
      ring arc written since ``marks`` (``total_adds`` delta starting at
      the marked ``pos`` — two ranges when the arc wraps);
    * capacity-dim sampler leaves (priority tables, AMPER pq/valid) are
      dirty on that arc plus ``priority_rows`` (host iterable of slot
      indices touched by out-of-band priority feedback since the base);
    * sampler leaves without a capacity leading dim (e.g. a sum-tree's
      internal nodes, static scalars) can't be row-tracked — always full;
    * scalars (pos/size/max_priority/total_adds) and the n-step
      accumulator window are tiny — always full.

    The result flattens leaf-for-leaf against ``state`` and plugs
    straight into ``checkpoint.save_incremental`` / the manager's
    ``dirty=``.
    """
    capacity = rb.capacity
    # The add counter is a wrapping int32: difference the unsigned views
    # mod 2^32 so a delta spanning the signed rollover stays exact.  An
    # identical counter with a bumped generation means a full 2^32-add
    # lap between snapshots — everything is dirty.
    now = int(state.total_adds) & (_U32 - 1)
    base = int(marks["total_adds"]) & (_U32 - 1)
    n_new = (now - base) % _U32
    gen_delta = (int(state.add_gen) - int(marks.get("add_gen", 0))) % _U32
    if n_new == 0 and gen_delta:
        n_new = capacity
    arcs = dirty_arcs(capacity, marks["pos"], n_new)
    arc_spec: Any = ck.Rows(arcs) if arcs else False
    prio_ranges = arcs + rows_to_ranges(priority_rows or [])
    prio_spec: Any = ck.Rows(prio_ranges) if prio_ranges else False

    def sampler_leaf(leaf):
        shape = np.shape(leaf)
        return (prio_spec if (len(shape) >= 1 and shape[0] == capacity)
                else True)

    return ReplayState(
        storage=jax.tree.map(lambda _: arc_spec, state.storage),
        sampler_state=jax.tree.map(sampler_leaf, state.sampler_state),
        pos=True,
        size=True,
        max_priority=True,
        write_stamp=arc_spec,
        total_adds=True,
        write_gen=arc_spec,
        add_gen=True,
        nstep=(None if state.nstep is None
               else ck.dirty_like(state.nstep, True)),
    )


def replay_target(rb, example_transition: Any):
    """Abstract ``ReplayState`` tree (ShapeDtypeStructs) for ``rb``.

    This is the restore target: building it from the *target* buffer's
    ``init`` means restore validates the checkpoint against the sampler
    kind and capacity actually configured now, not whatever wrote it.
    """
    return jax.eval_shape(rb.init, example_transition)


def replay_shardings(rb, target: Any):
    """Sharding tree for ``target`` under ``rb``'s mesh placement.

    Every leaf whose leading dim equals the buffer capacity follows the
    sampler's capacity-dim ``NamedSharding`` (storage, write stamps,
    priority table); every other leaf is replicated on the same mesh.
    Returns ``None`` when the buffer is unsharded (single device), which
    the generic restore treats as plain host->default-device puts.
    """
    sh = getattr(rb, "storage_sharding", None)
    if sh is None:
        return None
    replicated = NamedSharding(sh.mesh, PartitionSpec())

    def leaf_sharding(leaf):
        shape = np.shape(leaf)
        return sh if (len(shape) >= 1 and shape[0] == rb.capacity) else replicated

    return jax.tree.map(leaf_sharding, target)


def save_replay(directory: str, step: int, state: Any,
                meta: dict | None = None) -> str:
    """Durable atomic save of a ``ReplayState`` (or any snapshot tree
    containing one).  Device arrays are gathered dense on host, so the
    checkpoint is shard-count agnostic."""
    return ck.save(directory, step, state, meta=meta)


def restore_replay(directory: str, step: int, rb,
                   example_transition: Any):
    """Restore a ``ReplayState`` into ``rb``'s configured placement.

    ``rb`` may be built over a different mesh / shard count (or none)
    than the buffer that saved the checkpoint: the priority table and
    storage arcs are repartitioned by the device_put, membership-exactly.
    """
    target = replay_target(rb, example_transition)
    return ck.restore(directory, step, target,
                      replay_shardings(rb, target))
