"""Fault-tolerant checkpointing: atomic saves, retention, elastic restore.

Design (single-controller; the multi-host generalisation saves one shard
file per process and an index, orbax-style — documented in DESIGN.md):

* ``save`` writes ``step_<n>.tmp/`` then os.replace()-renames to
  ``step_<n>/`` — a crash mid-write never corrupts the latest checkpoint.
* arrays are stored as one ``.npz`` plus a JSON manifest of the pytree
  structure + dtypes, so restore works WITHOUT the original code object.
* ``restore`` device_puts each leaf with the *target* sharding: restoring
  onto a different mesh (elastic rescale 256 -> 512 chips, or CPU debug)
  is just a different sharding argument — checkpoints are mesh-agnostic.
* ``CheckpointManager`` keeps the newest ``keep`` checkpoints, resumes
  from the latest valid one, and installs a SIGTERM hook (preemption)
  that flushes a final checkpoint before exit.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import signal
from typing import Any, Optional

import jax
import ml_dtypes
import numpy as np

# dtypes numpy's npz format cannot store natively -> saved as a same-width
# integer view, with the true dtype recorded in the manifest.
_VIEW_DTYPES = {
    "bfloat16": np.uint16,
    "float8_e4m3fn": np.uint8,
    "float8_e5m2": np.uint8,
}


def _to_storable(arr: np.ndarray) -> np.ndarray:
    name = arr.dtype.name
    if name in _VIEW_DTYPES:
        return arr.view(_VIEW_DTYPES[name])
    return arr


def _from_storable(arr: np.ndarray, dtype_name: str) -> np.ndarray:
    if dtype_name in _VIEW_DTYPES:
        return arr.view(getattr(ml_dtypes, dtype_name))
    return arr


def _flatten_with_names(tree: Any):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return names, leaves, treedef


def save(directory: str, step: int, tree: Any) -> str:
    """Atomic checkpoint write. Returns the final path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:010d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    names, leaves, _ = _flatten_with_names(tree)
    raw = [np.asarray(leaf) for leaf in leaves]
    arrays = {f"a{i}": _to_storable(a) for i, a in enumerate(raw)}
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    manifest = {
        "step": step,
        "names": names,
        "dtypes": [a.dtype.name for a in raw],
        "shapes": [list(a.shape) for a in raw],
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def available_steps(directory: str) -> list[int]:
    if not os.path.isdir(directory):
        return []
    out = []
    for d in os.listdir(directory):
        m = re.fullmatch(r"step_(\d+)", d)
        if m and os.path.exists(os.path.join(directory, d, "manifest.json")):
            out.append(int(m.group(1)))
    return sorted(out)


def restore(directory: str, step: int, target: Any,
            shardings: Any = None) -> Any:
    """Load into the structure of ``target`` (arrays or ShapeDtypeStructs).

    ``shardings``: optional pytree of NamedShardings (elastic resharding —
    the saved mesh is irrelevant, each leaf is device_put with the target
    sharding).
    """
    path = os.path.join(directory, f"step_{step:010d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    with np.load(os.path.join(path, "arrays.npz")) as data:
        arrays = [_from_storable(data[f"a{i}"], manifest["dtypes"][i])
                  for i in range(len(data.files))]
    names, leaves, treedef = _flatten_with_names(target)
    if len(arrays) != len(leaves):
        raise ValueError(f"checkpoint has {len(arrays)} leaves, "
                         f"target expects {len(leaves)}")
    shard_leaves = (jax.tree.leaves(shardings) if shardings is not None
                    else [None] * len(leaves))
    out = []
    for arr, tgt, sh in zip(arrays, leaves, shard_leaves):
        arr = arr.astype(tgt.dtype)
        if tuple(arr.shape) != tuple(tgt.shape):
            raise ValueError(f"shape mismatch {arr.shape} vs {tgt.shape}")
        out.append(jax.device_put(arr, sh) if sh is not None else jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3,
                 save_interval: int = 100):
        self.directory = directory
        self.keep = keep
        self.save_interval = save_interval
        self._preempted = False

    def install_preemption_hook(self):
        def handler(signum, frame):
            self._preempted = True
        signal.signal(signal.SIGTERM, handler)

    @property
    def preempted(self) -> bool:
        return self._preempted

    def should_save(self, step: int) -> bool:
        return self._preempted or (step > 0 and step % self.save_interval == 0)

    def save(self, step: int, tree: Any) -> str:
        path = save(self.directory, step, tree)
        self._gc()
        return path

    def latest_step(self) -> Optional[int]:
        steps = available_steps(self.directory)
        return steps[-1] if steps else None

    def restore_latest(self, target: Any, shardings: Any = None):
        step = self.latest_step()
        if step is None:
            return None, None
        return step, restore(self.directory, step, target, shardings)

    def _gc(self):
        steps = available_steps(self.directory)
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:010d}"),
                          ignore_errors=True)
