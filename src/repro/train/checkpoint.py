"""Fault-tolerant checkpointing: atomic + durable saves, incremental
delta chains, retention, validated elastic restore.

Design (single-controller; the multi-host generalisation saves one shard
file per process and an index, orbax-style — documented in DESIGN.md):

* ``save`` writes ``step_<n>.tmp/`` then os.replace()-renames to
  ``step_<n>/`` — a crash mid-write never corrupts the latest checkpoint.
  Every payload file is fsync'd, and so are the tmp dir and the parent
  dir around the rename, so the checkpoint survives power loss, not just
  process death (the rename alone is NOT durable on ext4/xfs without the
  directory fsync).
* arrays are stored as one ``.npz`` plus a JSON manifest of the pytree
  structure + dtypes, so restore works WITHOUT the original code object.
  ``save(..., meta=...)`` embeds an arbitrary JSON-able dict in the
  manifest (counters, config fingerprints); ``load_meta`` reads it back
  without touching the arrays.
* ``save_incremental`` writes a single ``step_<n>.ckpt`` file (manifest
  embedded in the npz) holding only the leaves — and, for ring-style
  arrays, only the leading-dim row ranges — that changed since
  ``base_step``.  Each delta names its base in the manifest, forming a
  chain that ``restore`` replays transparently; with ``base_step=None``
  the same single-file container is a self-contained full checkpoint.
  One payload file means two fsyncs per save instead of four, which is
  what makes per-interval checkpointing cheap enough for the replay
  service's production cadence (see benchmarks/bench_replay.py).
* ``restore`` validates the manifest's leaf names and dtypes against the
  target tree and fails with a readable diff — leaves are never matched
  by position alone, so restoring a checkpoint into the wrong structure
  (different sampler kind, refactored params tree) is a loud error, not
  silently transposed arrays.
* ``restore`` device_puts each leaf with the *target* sharding: restoring
  onto a different mesh (elastic rescale 256 -> 512 chips, or CPU debug)
  is just a different sharding argument — checkpoints are mesh-agnostic.
* ``CheckpointManager`` keeps the newest ``keep`` checkpoints (plus any
  older checkpoints a retained delta chain still depends on), resumes
  from the latest valid one, compacts delta chains with a periodic full
  save every ``full_every`` saves, garbage-collects ``step_*.tmp``
  litter from crashed saves, and exposes a preemption flag that a
  SIGTERM hook sets when installable (main thread) and that worker
  threads reach through ``request_preemption()`` or the polled
  ``PREEMPT`` sentinel file.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import signal
import threading
from typing import Any, Optional

import jax
import ml_dtypes
import numpy as np

# dtypes numpy's npz format cannot store natively -> saved as a same-width
# integer view, with the true dtype recorded in the manifest.
_VIEW_DTYPES = {
    "bfloat16": np.uint16,
    "float8_e4m3fn": np.uint8,
    "float8_e5m2": np.uint8,
}

PREEMPT_SENTINEL = "PREEMPT"


def _to_storable(arr: np.ndarray) -> np.ndarray:
    name = arr.dtype.name
    if name in _VIEW_DTYPES:
        return arr.view(_VIEW_DTYPES[name])
    return arr


def _from_storable(arr: np.ndarray, dtype_name: str) -> np.ndarray:
    if dtype_name in _VIEW_DTYPES:
        return arr.view(getattr(ml_dtypes, dtype_name))
    return arr


def _path_key_str(k: Any) -> str:
    """Normalize every pytree key type to its bare component string.

    DictKey/FlattenedIndexKey carry ``.key``, SequenceKey ``.idx``,
    GetAttrKey ``.name`` — falling through to ``str(k)`` renders
    attr-keyed nodes (NamedTuples, registered dataclasses) with a
    leading dot (``.params``), which made manifest names depend on the
    container kind instead of the field name.
    """
    for attr in ("key", "idx", "name"):
        if hasattr(k, attr):
            return str(getattr(k, attr))
    return str(k)


def _flatten_with_names(tree: Any):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = ["/".join(_path_key_str(k) for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return names, leaves, treedef


class Rows:
    """Dirty spec for one leaf: the leading-dim row ranges that changed.

    ``ranges`` is a list of half-open ``(start, stop)`` pairs — a ring
    arc that wraps the capacity boundary is two ranges.  Used as a leaf
    value inside a dirty tree (see :func:`save_incremental`); the other
    two spec values are plain bools (True = whole leaf, False = skip).
    """

    __slots__ = ("ranges",)

    def __init__(self, ranges):
        self.ranges = [(int(s), int(e)) for s, e in ranges]

    def __repr__(self):
        return f"Rows({self.ranges!r})"


def dirty_like(tree: Any, flag: Any = True) -> Any:
    """A dirty tree marking every leaf of ``tree`` with ``flag``."""
    return jax.tree.map(lambda _: flag, tree)


def _normalize_ranges(ranges, n_rows: int):
    """Sorted, merged, bounds-checked half-open ranges over [0, n_rows)."""
    out = []
    for s, e in sorted((int(s), int(e)) for s, e in ranges):
        if s < 0 or e > n_rows:
            raise ValueError(
                f"dirty range ({s}, {e}) outside leading dim {n_rows}")
        if e <= s:
            continue
        if out and s <= out[-1][1]:
            out[-1] = (out[-1][0], max(out[-1][1], e))
        else:
            out.append((s, e))
    return out


def _fsync_file(path: str) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _fsync_dir(path: str) -> None:
    fd = os.open(path, os.O_RDONLY | getattr(os, "O_DIRECTORY", 0))
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _leaf_dtype_name(leaf: Any) -> str:
    if hasattr(leaf, "dtype"):
        return np.dtype(leaf.dtype).name
    # Python scalars (e.g. a static int field of a sampler-state
    # NamedTuple) canonicalize the way jit would (int -> int32 under
    # default x64-disabled config), so a live init() tree and a post-jit
    # tree validate identically.
    return jax.numpy.asarray(leaf).dtype.name


def _leaf_storable(leaf: Any) -> np.ndarray:
    if isinstance(leaf, (bool, int, float, complex)):
        return np.asarray(jax.numpy.asarray(leaf))
    return np.asarray(leaf)


def save(directory: str, step: int, tree: Any,
         meta: dict | None = None) -> str:
    """Atomic, durable checkpoint write. Returns the final path.

    ``meta``: optional JSON-able dict stored in the manifest (host-side
    counters, PRNG stream positions, config fingerprints) — read back
    cheaply with :func:`load_meta`.
    """
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:010d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    names, leaves, _ = _flatten_with_names(tree)
    raw = [_leaf_storable(leaf) for leaf in leaves]
    arrays = {f"a{i}": _to_storable(a) for i, a in enumerate(raw)}
    arrays_path = os.path.join(tmp, "arrays.npz")
    with open(arrays_path, "wb") as f:
        np.savez(f, **arrays)
        f.flush()
        os.fsync(f.fileno())
    manifest = {
        "step": step,
        "names": names,
        "dtypes": [a.dtype.name for a in raw],
        "shapes": [list(a.shape) for a in raw],
    }
    if meta is not None:
        manifest["meta"] = meta
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    # The rename is only durable once the directory entries themselves
    # are on disk: fsync the tmp dir (its two new files), then the
    # parent (the rename).
    _fsync_dir(tmp)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    _fsync_dir(directory)
    return final


def _file_path(directory: str, step: int) -> str:
    return os.path.join(directory, f"step_{step:010d}.ckpt")


def _dir_path(directory: str, step: int) -> str:
    return os.path.join(directory, f"step_{step:010d}")


def checkpoint_exists(directory: str, step: int) -> bool:
    return (os.path.exists(_file_path(directory, step))
            or os.path.exists(os.path.join(_dir_path(directory, step),
                                           "manifest.json")))


def save_incremental(directory: str, step: int, tree: Any,
                     base_step: int | None = None, dirty: Any = None,
                     meta: dict | None = None) -> str:
    """Single-file durable save of the leaves changed since ``base_step``.

    ``dirty`` is a pytree with the same structure as ``tree`` whose
    leaves are dirty specs: ``True`` (save the whole leaf), ``False``
    (unchanged since the base — skip), or a :class:`Rows` of leading-dim
    row ranges (ring arcs / touched priority rows; only those slices are
    written).  Build it with :func:`dirty_like` + ``Rows`` so the
    structures stay aligned.  With ``base_step=None`` (and ``dirty``
    omitted) every leaf is saved — the same container then holds a
    self-contained full checkpoint, which is also the fast path the
    manager uses for compaction.

    The whole checkpoint (manifest included) is ONE ``step_<n>.ckpt``
    npz, fsync'd and atomically renamed: two fsyncs per save instead of
    the directory layout's four.  The manifest records ``base_step`` and
    the per-leaf delta spec; :func:`restore` replays the chain.
    """
    os.makedirs(directory, exist_ok=True)
    if base_step is None and dirty is not None:
        raise ValueError("dirty spec without a base_step: an incremental "
                         "save needs the base it is relative to")
    if base_step is not None:
        if base_step >= step:
            raise ValueError(f"base_step {base_step} must precede step {step}")
        if not checkpoint_exists(directory, base_step):
            raise ValueError(f"incremental save at step {step}: base step "
                             f"{base_step} not found in {directory}")
    names, leaves, _ = _flatten_with_names(tree)
    if dirty is None:
        dleaves = [True] * len(leaves)
    else:
        dleaves = jax.tree_util.tree_flatten(
            dirty, is_leaf=lambda x: isinstance(x, (bool, Rows)))[0]
        if len(dleaves) != len(leaves):
            raise ValueError(
                f"dirty tree has {len(dleaves)} leaves, tree has "
                f"{len(leaves)}; build it with dirty_like(subtree, flag) "
                f"so the structures align")
    arrays, spec, dtypes, shapes = {}, [], [], []
    for i, (leaf, d) in enumerate(zip(leaves, dleaves)):
        # Manifest dtype/shape come from metadata alone — a skipped leaf
        # must cost zero device->host transfer, and a Rows leaf only the
        # transfer of its arc slices (this, not the npz write, dominates
        # the steady-state delta save for large ring buffers).
        dtypes.append(_leaf_dtype_name(leaf))
        shape = list(np.shape(leaf))
        shapes.append(shape)
        if d is False:
            spec.append(None)
            continue
        if d is True:
            spec.append(True)
            arrays[f"d{i}"] = _to_storable(_leaf_storable(leaf))
            continue
        if not isinstance(d, Rows):
            raise ValueError(f"dirty leaf {names[i]}: expected bool or "
                             f"Rows, got {type(d).__name__}")
        if not shape:
            raise ValueError(f"dirty leaf {names[i]}: Rows spec on a "
                             f"rank-0 leaf")
        ranges = _normalize_ranges(d.ranges, shape[0])
        if not ranges:
            spec.append(None)
            continue
        spec.append([[s, e] for s, e in ranges])
        # One whole-leaf transfer, sliced host-side: slicing the device
        # array instead (leaf[s:e]) dispatches an XLA slice that
        # recompiles for every distinct arc geometry, which costs far
        # more than the extra bytes on the wire.
        stored = _to_storable(_leaf_storable(leaf))
        arrays[f"d{i}"] = np.concatenate(
            [stored[s:e] for s, e in ranges], axis=0)
    manifest = {"step": step, "names": names, "dtypes": dtypes,
                "shapes": shapes, "delta": spec}
    if base_step is not None:
        manifest["base_step"] = base_step
    if meta is not None:
        manifest["meta"] = meta
    arrays["__manifest__"] = np.frombuffer(
        json.dumps(manifest).encode("utf-8"), np.uint8)
    final = _file_path(directory, step)
    if os.path.exists(_dir_path(directory, step)):
        raise ValueError(f"step {step} already exists as a directory "
                         f"checkpoint; refusing to shadow it with a file")
    tmp = final + ".tmp"
    with open(tmp, "wb") as f:
        np.savez(f, **arrays)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, final)
    _fsync_dir(directory)
    return final


def available_steps(directory: str) -> list[int]:
    if not os.path.isdir(directory):
        return []
    out = []
    for d in os.listdir(directory):
        m = re.fullmatch(r"step_(\d+)", d)
        if m and os.path.exists(os.path.join(directory, d, "manifest.json")):
            out.append(int(m.group(1)))
            continue
        m = re.fullmatch(r"step_(\d+)\.ckpt", d)
        if m:
            out.append(int(m.group(1)))
    return sorted(set(out))


def gc_stale_tmp(directory: str) -> list[str]:
    """Remove ``step_*.tmp`` litter left behind by crashed saves.

    Covers both layouts: ``step_<n>.tmp/`` directories from the full
    dir-layout save and ``step_<n>.ckpt.tmp`` files from single-file
    saves.  Only call when no save is concurrently in flight in this
    directory (the manager calls it at construction and right after each
    completed save). Returns the removed paths.
    """
    if not os.path.isdir(directory):
        return []
    removed = []
    for d in os.listdir(directory):
        path = os.path.join(directory, d)
        if re.fullmatch(r"step_\d+\.tmp", d):
            shutil.rmtree(path, ignore_errors=True)
            removed.append(path)
        elif re.fullmatch(r"step_\d+\.ckpt\.tmp", d):
            try:
                os.unlink(path)
                removed.append(path)
            except OSError:
                pass
    return removed


def load_manifest(directory: str, step: int) -> dict:
    file_path = _file_path(directory, step)
    if os.path.exists(file_path):
        with np.load(file_path) as data:
            return json.loads(data["__manifest__"].tobytes().decode("utf-8"))
    path = os.path.join(_dir_path(directory, step), "manifest.json")
    with open(path) as f:
        return json.load(f)


def load_meta(directory: str, step: int) -> dict:
    """The ``meta`` dict stored at save time ({} if none was)."""
    return load_manifest(directory, step).get("meta", {})


def _validate_manifest(manifest: dict, names: list[str],
                       leaves: list[Any], path: str) -> None:
    """Leaf-name + dtype agreement between checkpoint and target tree.

    Position-only matching silently loads array i into leaf i even when
    the structures diverge (e.g. a checkpoint of one sampler kind
    restored into another with the same leaf count); fail with a diff of
    the first mismatches instead.
    """
    saved_names = manifest.get("names")
    if saved_names is not None and saved_names != names:
        diffs = []
        for i in range(max(len(saved_names), len(names))):
            s = saved_names[i] if i < len(saved_names) else "<absent>"
            t = names[i] if i < len(names) else "<absent>"
            if s != t:
                diffs.append(f"  leaf {i}: checkpoint={s!r} target={t!r}")
            if len(diffs) >= 10:
                diffs.append("  ...")
                break
        raise ValueError(
            f"checkpoint {path} does not match the target tree structure "
            f"({len(saved_names)} vs {len(names)} leaves):\n"
            + "\n".join(diffs))
    saved_dtypes = manifest.get("dtypes", [])
    mismatches = []
    for i, (name, leaf) in enumerate(zip(names, leaves)):
        want = _leaf_dtype_name(leaf)
        got = saved_dtypes[i] if i < len(saved_dtypes) else "<absent>"
        if got != want:
            mismatches.append(f"  {name}: checkpoint={got} target={want}")
        if len(mismatches) >= 10:
            mismatches.append("  ...")
            break
    if mismatches:
        raise ValueError(
            f"checkpoint {path} dtype mismatch against target tree:\n"
            + "\n".join(mismatches))


def _read_arrays(directory: str, step: int):
    """(manifest, {array_key: ndarray}) for either on-disk layout."""
    file_path = _file_path(directory, step)
    if os.path.exists(file_path):
        with np.load(file_path) as data:
            manifest = json.loads(
                data["__manifest__"].tobytes().decode("utf-8"))
            arrays = {k: data[k] for k in data.files if k != "__manifest__"}
        return manifest, arrays
    path = _dir_path(directory, step)
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    with np.load(os.path.join(path, "arrays.npz")) as data:
        arrays = {k: data[k] for k in data.files}
    return manifest, arrays


def _materialize(directory: str, step: int):
    """Replay the delta chain ending at ``step``.

    Returns ``(final_manifest, leaves)`` with leaves in storable form
    (dtype views not yet restored).  Walks ``base_step`` links back to a
    full checkpoint (single-file full or legacy dir layout), then applies
    each delta oldest → newest: ``True`` specs replace the leaf, range
    specs copy-and-overwrite the named leading-dim slices, ``None`` specs
    leave the base leaf untouched.
    """
    chain = []
    seen: set[int] = set()
    s = step
    while True:
        if s in seen:
            raise ValueError(f"delta chain at step {step} cycles on "
                             f"step {s} in {directory}")
        seen.add(s)
        manifest, arrays = _read_arrays(directory, s)
        chain.append((s, manifest, arrays))
        base = manifest.get("base_step")
        if base is None:
            break
        s = base
    chain.reverse()
    leaves = None
    names = None
    for s, manifest, arrays in chain:
        spec = manifest.get("delta")
        if leaves is None:
            if spec is None:  # legacy dir layout: full a{i} arrays
                leaves = [arrays[f"a{i}"]
                          for i in range(len(manifest["names"]))]
            else:
                if any(sp is not True for sp in spec):
                    raise ValueError(
                        f"delta chain root at step {s} is itself "
                        f"incremental — the chain has no full base")
                leaves = [arrays[f"d{i}"] for i in range(len(spec))]
            names = manifest["names"]
            continue
        if manifest["names"] != names:
            raise ValueError(
                f"delta at step {s} was saved against a different tree "
                f"structure than its chain base (leaf names differ)")
        for i, sp in enumerate(spec):
            if sp is None:
                continue
            if sp is True:
                leaves[i] = arrays[f"d{i}"]
                continue
            dst = np.array(leaves[i])
            src = arrays[f"d{i}"]
            off = 0
            for rs, rng_e in sp:
                n = rng_e - rs
                dst[rs:rng_e] = src[off:off + n]
                off += n
            leaves[i] = dst
    return chain[-1][1], leaves


def restore(directory: str, step: int, target: Any,
            shardings: Any = None) -> Any:
    """Load into the structure of ``target`` (arrays or ShapeDtypeStructs).

    Handles both layouts transparently: a legacy full directory
    checkpoint loads directly, a single-file incremental checkpoint has
    its delta chain replayed back to the nearest full save first.

    The manifest's leaf names and dtypes are validated against ``target``
    first — a structural mismatch raises with a readable diff instead of
    silently loading arrays by position.

    ``shardings``: optional pytree of NamedShardings (elastic resharding —
    the saved mesh is irrelevant, each leaf is device_put with the target
    sharding, so a table saved on 8 shards restores onto 2, or onto one
    CPU device, unchanged).
    """
    path = _file_path(directory, step)
    if not os.path.exists(path):
        path = _dir_path(directory, step)
    manifest, raw = _materialize(directory, step)
    arrays = [_from_storable(a, manifest["dtypes"][i])
              for i, a in enumerate(raw)]
    names, leaves, treedef = _flatten_with_names(target)
    if len(arrays) != len(leaves):
        raise ValueError(f"checkpoint has {len(arrays)} leaves, "
                         f"target expects {len(leaves)}")
    _validate_manifest(manifest, names, leaves, path)
    shard_leaves = (jax.tree.leaves(shardings) if shardings is not None
                    else [None] * len(leaves))
    if len(shard_leaves) != len(leaves):
        # jax pytrees drop None leaves, so a shardings tree with Nones
        # would silently misalign with the target — fail loudly instead.
        raise ValueError(
            f"shardings tree has {len(shard_leaves)} leaves, target has "
            f"{len(leaves)}; use a replicated sharding (not None) for "
            f"leaves that should not be partitioned")
    out = []
    for arr, tgt, sh in zip(arrays, leaves, shard_leaves):
        if tuple(arr.shape) != tuple(np.shape(tgt)):
            raise ValueError(f"shape mismatch {arr.shape} vs {np.shape(tgt)}")
        out.append(jax.device_put(arr, sh) if sh is not None
                   else jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


class CheckpointManager:
    """Retention + resume + preemption plumbing around :func:`save`.

    The preemption flag has three writers, so it works from any thread
    and any process topology:

    * ``install_preemption_hook()`` — SIGTERM handler; only installable
      on the main thread (``signal.signal`` raises ``ValueError``
      elsewhere), so off the main thread it silently degrades to the
      polled mechanisms below and returns False.
    * ``request_preemption()`` — direct flag set, for same-process
      callers (e.g. a watchdog thread or a test).
    * a ``PREEMPT`` sentinel file in the checkpoint directory — the
      cross-process polled fallback; ``preempted`` checks it on read,
      so an operator (or an orchestrator without signal delivery into
      the worker thread) can ``touch <dir>/PREEMPT``.  The sentinel is
      one-shot: a freshly constructed manager consumes (deletes) it, so
      the relaunch after a sentinel-triggered exit resumes instead of
      immediately preempting itself again.
    """

    def __init__(self, directory: str, keep: int = 3,
                 save_interval: int = 100, full_every: int = 8):
        if keep < 1:
            raise ValueError(f"keep must be >= 1, got {keep} (keep=0 would "
                             f"leave nothing to resume from)")
        if save_interval < 1:
            raise ValueError(f"save_interval must be >= 1, got "
                             f"{save_interval}")
        if full_every < 1:
            raise ValueError(f"full_every must be >= 1, got {full_every}")
        self.directory = directory
        self.keep = keep
        self.save_interval = save_interval
        self.full_every = full_every
        self._preempted = False
        # step -> base_step links, so the per-save GC's chain walk does
        # not re-open on-disk manifests (an npz read per retained step
        # per save); misses fall back to load_manifest.
        self._bases: dict[int, Optional[int]] = {}
        gc_stale_tmp(directory)
        # Resume the delta chain: the next dirty-aware save extends from
        # the latest on-disk step unless the chain is already full_every
        # deltas deep.
        steps = available_steps(directory)
        self._last_step: Optional[int] = steps[-1] if steps else None
        self._chain_len = (self._chain_len_of(self._last_step)
                           if self._last_step is not None else 0)
        try:
            os.unlink(self._sentinel_path)  # consume a stale sentinel
        except OSError:
            pass

    def _base_of(self, step: int) -> Optional[int]:
        if step in self._bases:
            return self._bases[step]
        try:
            base = load_manifest(self.directory, step).get("base_step")
        except (OSError, KeyError, ValueError):
            base = None
        self._bases[step] = base
        return base

    def _chain_len_of(self, step: int) -> int:
        n, s, seen = 0, step, set()
        while s is not None and s not in seen:
            seen.add(s)
            base = self._base_of(s)
            if base is None:
                break
            n += 1
            s = base
        return n

    def install_preemption_hook(self, signum: int = signal.SIGTERM) -> bool:
        """Install the SIGTERM handler if possible; returns whether it was.

        ``signal.signal`` raises ``ValueError`` off the main thread (the
        async runtime's learner runs on a worker thread), so the fallback
        is the polled flag: ``request_preemption()`` or the ``PREEMPT``
        sentinel file still flip ``preempted``.
        """
        def handler(signum, frame):
            self._preempted = True

        if threading.current_thread() is not threading.main_thread():
            return False
        try:
            signal.signal(signum, handler)
        except ValueError:
            return False
        return True

    def request_preemption(self) -> None:
        """Thread-safe direct preemption request (no signal needed)."""
        self._preempted = True

    @property
    def _sentinel_path(self) -> str:
        return os.path.join(self.directory, PREEMPT_SENTINEL)

    @property
    def preempted(self) -> bool:
        if not self._preempted and os.path.exists(self._sentinel_path):
            self._preempted = True
        return self._preempted

    def should_save(self, step: int) -> bool:
        return self.preempted or (step > 0 and step % self.save_interval == 0)

    def save(self, step: int, tree: Any, meta: dict | None = None,
             dirty: Any = None, force_full: bool = False) -> str:
        """Single-file save; incremental when a dirty spec is given.

        With ``dirty=None`` (or no usable base) this writes a full
        self-contained ``step_<n>.ckpt``.  With a dirty tree it writes a
        delta against the previous save, compacting with a full save
        every ``full_every`` saves so restore never replays an unbounded
        chain.
        """
        from repro import obs  # deferred: keep the train layer import-light

        base = self._last_step
        full = (force_full or dirty is None or base is None
                or base >= step
                or self._chain_len >= self.full_every - 1
                or not checkpoint_exists(self.directory, base))
        with obs.span("checkpoint_save"):
            if full:
                path = save_incremental(self.directory, step, tree, meta=meta)
                self._chain_len = 0
                self._bases[step] = None
            else:
                path = save_incremental(self.directory, step, tree,
                                        base_step=base, dirty=dirty, meta=meta)
                self._chain_len += 1
                self._bases[step] = base
        self._last_step = step
        reg = obs.get_registry()
        if reg.enabled:
            try:
                nbytes = os.path.getsize(path)
            except OSError:
                nbytes = 0
            reg.counter(
                "checkpoint_full_bytes" if full else "checkpoint_delta_bytes",
                help="bytes written by full/delta checkpoint saves",
            ).add(nbytes)
            reg.gauge("checkpoint_chain_len",
                      help="delta-chain length since the last full save"
                      ).set(self._chain_len)
        self._gc()
        return path

    def latest_step(self) -> Optional[int]:
        steps = available_steps(self.directory)
        return steps[-1] if steps else None

    def restore_latest(self, target: Any, shardings: Any = None):
        step = self.latest_step()
        if step is None:
            return None, None
        return step, restore(self.directory, step, target, shardings)

    def latest_meta(self) -> dict:
        step = self.latest_step()
        return load_meta(self.directory, step) if step is not None else {}

    def _gc(self):
        gc_stale_tmp(self.directory)
        steps = available_steps(self.directory)
        # Not steps[:-keep]: that is the empty slice for keep=0 (deleting
        # everything) and wraps negative when keep exceeds len(steps)
        # (dropping steps that should be retained) — clamp explicitly.
        retained = set(steps[max(len(steps) - self.keep, 0):])
        # A retained delta is useless without its chain: retain every
        # transitive base too.
        frontier = list(retained)
        while frontier:
            s = frontier.pop()
            base = self._base_of(s)
            if base is not None and base not in retained:
                retained.add(base)
                frontier.append(base)
        for s in steps:
            if s in retained:
                continue
            shutil.rmtree(_dir_path(self.directory, s), ignore_errors=True)
            try:
                os.unlink(_file_path(self.directory, s))
            except OSError:
                pass
            self._bases.pop(s, None)
