"""Fault-tolerant checkpointing: atomic + durable saves, retention,
validated elastic restore.

Design (single-controller; the multi-host generalisation saves one shard
file per process and an index, orbax-style — documented in DESIGN.md):

* ``save`` writes ``step_<n>.tmp/`` then os.replace()-renames to
  ``step_<n>/`` — a crash mid-write never corrupts the latest checkpoint.
  Every payload file is fsync'd, and so are the tmp dir and the parent
  dir around the rename, so the checkpoint survives power loss, not just
  process death (the rename alone is NOT durable on ext4/xfs without the
  directory fsync).
* arrays are stored as one ``.npz`` plus a JSON manifest of the pytree
  structure + dtypes, so restore works WITHOUT the original code object.
  ``save(..., meta=...)`` embeds an arbitrary JSON-able dict in the
  manifest (counters, config fingerprints); ``load_meta`` reads it back
  without touching the arrays.
* ``restore`` validates the manifest's leaf names and dtypes against the
  target tree and fails with a readable diff — leaves are never matched
  by position alone, so restoring a checkpoint into the wrong structure
  (different sampler kind, refactored params tree) is a loud error, not
  silently transposed arrays.
* ``restore`` device_puts each leaf with the *target* sharding: restoring
  onto a different mesh (elastic rescale 256 -> 512 chips, or CPU debug)
  is just a different sharding argument — checkpoints are mesh-agnostic.
* ``CheckpointManager`` keeps the newest ``keep`` checkpoints, resumes
  from the latest valid one, garbage-collects ``step_*.tmp`` litter from
  crashed saves, and exposes a preemption flag that a SIGTERM hook sets
  when installable (main thread) and that worker threads reach through
  ``request_preemption()`` or the polled ``PREEMPT`` sentinel file.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import signal
import threading
from typing import Any, Optional

import jax
import ml_dtypes
import numpy as np

# dtypes numpy's npz format cannot store natively -> saved as a same-width
# integer view, with the true dtype recorded in the manifest.
_VIEW_DTYPES = {
    "bfloat16": np.uint16,
    "float8_e4m3fn": np.uint8,
    "float8_e5m2": np.uint8,
}

PREEMPT_SENTINEL = "PREEMPT"


def _to_storable(arr: np.ndarray) -> np.ndarray:
    name = arr.dtype.name
    if name in _VIEW_DTYPES:
        return arr.view(_VIEW_DTYPES[name])
    return arr


def _from_storable(arr: np.ndarray, dtype_name: str) -> np.ndarray:
    if dtype_name in _VIEW_DTYPES:
        return arr.view(getattr(ml_dtypes, dtype_name))
    return arr


def _flatten_with_names(tree: Any):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return names, leaves, treedef


def _fsync_file(path: str) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _fsync_dir(path: str) -> None:
    fd = os.open(path, os.O_RDONLY | getattr(os, "O_DIRECTORY", 0))
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _leaf_dtype_name(leaf: Any) -> str:
    if hasattr(leaf, "dtype"):
        return np.dtype(leaf.dtype).name
    # Python scalars (e.g. a static int field of a sampler-state
    # NamedTuple) canonicalize the way jit would (int -> int32 under
    # default x64-disabled config), so a live init() tree and a post-jit
    # tree validate identically.
    return jax.numpy.asarray(leaf).dtype.name


def _leaf_storable(leaf: Any) -> np.ndarray:
    if isinstance(leaf, (bool, int, float, complex)):
        return np.asarray(jax.numpy.asarray(leaf))
    return np.asarray(leaf)


def save(directory: str, step: int, tree: Any,
         meta: dict | None = None) -> str:
    """Atomic, durable checkpoint write. Returns the final path.

    ``meta``: optional JSON-able dict stored in the manifest (host-side
    counters, PRNG stream positions, config fingerprints) — read back
    cheaply with :func:`load_meta`.
    """
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:010d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    names, leaves, _ = _flatten_with_names(tree)
    raw = [_leaf_storable(leaf) for leaf in leaves]
    arrays = {f"a{i}": _to_storable(a) for i, a in enumerate(raw)}
    arrays_path = os.path.join(tmp, "arrays.npz")
    with open(arrays_path, "wb") as f:
        np.savez(f, **arrays)
        f.flush()
        os.fsync(f.fileno())
    manifest = {
        "step": step,
        "names": names,
        "dtypes": [a.dtype.name for a in raw],
        "shapes": [list(a.shape) for a in raw],
    }
    if meta is not None:
        manifest["meta"] = meta
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    # The rename is only durable once the directory entries themselves
    # are on disk: fsync the tmp dir (its two new files), then the
    # parent (the rename).
    _fsync_dir(tmp)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    _fsync_dir(directory)
    return final


def available_steps(directory: str) -> list[int]:
    if not os.path.isdir(directory):
        return []
    out = []
    for d in os.listdir(directory):
        m = re.fullmatch(r"step_(\d+)", d)
        if m and os.path.exists(os.path.join(directory, d, "manifest.json")):
            out.append(int(m.group(1)))
    return sorted(out)


def gc_stale_tmp(directory: str) -> list[str]:
    """Remove ``step_*.tmp`` litter left behind by crashed saves.

    Only call when no save is concurrently in flight in this directory
    (the manager calls it at construction and right after each completed
    save). Returns the removed paths.
    """
    if not os.path.isdir(directory):
        return []
    removed = []
    for d in os.listdir(directory):
        if re.fullmatch(r"step_\d+\.tmp", d):
            path = os.path.join(directory, d)
            shutil.rmtree(path, ignore_errors=True)
            removed.append(path)
    return removed


def load_manifest(directory: str, step: int) -> dict:
    path = os.path.join(directory, f"step_{step:010d}", "manifest.json")
    with open(path) as f:
        return json.load(f)


def load_meta(directory: str, step: int) -> dict:
    """The ``meta`` dict stored at save time ({} if none was)."""
    return load_manifest(directory, step).get("meta", {})


def _validate_manifest(manifest: dict, names: list[str],
                       leaves: list[Any], path: str) -> None:
    """Leaf-name + dtype agreement between checkpoint and target tree.

    Position-only matching silently loads array i into leaf i even when
    the structures diverge (e.g. a checkpoint of one sampler kind
    restored into another with the same leaf count); fail with a diff of
    the first mismatches instead.
    """
    saved_names = manifest.get("names")
    if saved_names is not None and saved_names != names:
        diffs = []
        for i in range(max(len(saved_names), len(names))):
            s = saved_names[i] if i < len(saved_names) else "<absent>"
            t = names[i] if i < len(names) else "<absent>"
            if s != t:
                diffs.append(f"  leaf {i}: checkpoint={s!r} target={t!r}")
            if len(diffs) >= 10:
                diffs.append("  ...")
                break
        raise ValueError(
            f"checkpoint {path} does not match the target tree structure "
            f"({len(saved_names)} vs {len(names)} leaves):\n"
            + "\n".join(diffs))
    saved_dtypes = manifest.get("dtypes", [])
    mismatches = []
    for i, (name, leaf) in enumerate(zip(names, leaves)):
        want = _leaf_dtype_name(leaf)
        got = saved_dtypes[i] if i < len(saved_dtypes) else "<absent>"
        if got != want:
            mismatches.append(f"  {name}: checkpoint={got} target={want}")
        if len(mismatches) >= 10:
            mismatches.append("  ...")
            break
    if mismatches:
        raise ValueError(
            f"checkpoint {path} dtype mismatch against target tree:\n"
            + "\n".join(mismatches))


def restore(directory: str, step: int, target: Any,
            shardings: Any = None) -> Any:
    """Load into the structure of ``target`` (arrays or ShapeDtypeStructs).

    The manifest's leaf names and dtypes are validated against ``target``
    first — a structural mismatch raises with a readable diff instead of
    silently loading arrays by position.

    ``shardings``: optional pytree of NamedShardings (elastic resharding —
    the saved mesh is irrelevant, each leaf is device_put with the target
    sharding, so a table saved on 8 shards restores onto 2, or onto one
    CPU device, unchanged).
    """
    path = os.path.join(directory, f"step_{step:010d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    with np.load(os.path.join(path, "arrays.npz")) as data:
        arrays = [_from_storable(data[f"a{i}"], manifest["dtypes"][i])
                  for i in range(len(data.files))]
    names, leaves, treedef = _flatten_with_names(target)
    if len(arrays) != len(leaves):
        raise ValueError(f"checkpoint has {len(arrays)} leaves, "
                         f"target expects {len(leaves)}")
    _validate_manifest(manifest, names, leaves, path)
    shard_leaves = (jax.tree.leaves(shardings) if shardings is not None
                    else [None] * len(leaves))
    if len(shard_leaves) != len(leaves):
        # jax pytrees drop None leaves, so a shardings tree with Nones
        # would silently misalign with the target — fail loudly instead.
        raise ValueError(
            f"shardings tree has {len(shard_leaves)} leaves, target has "
            f"{len(leaves)}; use a replicated sharding (not None) for "
            f"leaves that should not be partitioned")
    out = []
    for arr, tgt, sh in zip(arrays, leaves, shard_leaves):
        if tuple(arr.shape) != tuple(np.shape(tgt)):
            raise ValueError(f"shape mismatch {arr.shape} vs {np.shape(tgt)}")
        out.append(jax.device_put(arr, sh) if sh is not None
                   else jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


class CheckpointManager:
    """Retention + resume + preemption plumbing around :func:`save`.

    The preemption flag has three writers, so it works from any thread
    and any process topology:

    * ``install_preemption_hook()`` — SIGTERM handler; only installable
      on the main thread (``signal.signal`` raises ``ValueError``
      elsewhere), so off the main thread it silently degrades to the
      polled mechanisms below and returns False.
    * ``request_preemption()`` — direct flag set, for same-process
      callers (e.g. a watchdog thread or a test).
    * a ``PREEMPT`` sentinel file in the checkpoint directory — the
      cross-process polled fallback; ``preempted`` checks it on read,
      so an operator (or an orchestrator without signal delivery into
      the worker thread) can ``touch <dir>/PREEMPT``.  The sentinel is
      one-shot: a freshly constructed manager consumes (deletes) it, so
      the relaunch after a sentinel-triggered exit resumes instead of
      immediately preempting itself again.
    """

    def __init__(self, directory: str, keep: int = 3,
                 save_interval: int = 100):
        self.directory = directory
        self.keep = keep
        self.save_interval = save_interval
        self._preempted = False
        gc_stale_tmp(directory)
        try:
            os.unlink(self._sentinel_path)  # consume a stale sentinel
        except OSError:
            pass

    def install_preemption_hook(self, signum: int = signal.SIGTERM) -> bool:
        """Install the SIGTERM handler if possible; returns whether it was.

        ``signal.signal`` raises ``ValueError`` off the main thread (the
        async runtime's learner runs on a worker thread), so the fallback
        is the polled flag: ``request_preemption()`` or the ``PREEMPT``
        sentinel file still flip ``preempted``.
        """
        def handler(signum, frame):
            self._preempted = True

        if threading.current_thread() is not threading.main_thread():
            return False
        try:
            signal.signal(signum, handler)
        except ValueError:
            return False
        return True

    def request_preemption(self) -> None:
        """Thread-safe direct preemption request (no signal needed)."""
        self._preempted = True

    @property
    def _sentinel_path(self) -> str:
        return os.path.join(self.directory, PREEMPT_SENTINEL)

    @property
    def preempted(self) -> bool:
        if not self._preempted and os.path.exists(self._sentinel_path):
            self._preempted = True
        return self._preempted

    def should_save(self, step: int) -> bool:
        return self.preempted or (step > 0 and step % self.save_interval == 0)

    def save(self, step: int, tree: Any, meta: dict | None = None) -> str:
        path = save(self.directory, step, tree, meta=meta)
        self._gc()
        return path

    def latest_step(self) -> Optional[int]:
        steps = available_steps(self.directory)
        return steps[-1] if steps else None

    def restore_latest(self, target: Any, shardings: Any = None):
        step = self.latest_step()
        if step is None:
            return None, None
        return step, restore(self.directory, step, target, shardings)

    def latest_meta(self) -> dict:
        step = self.latest_step()
        return load_meta(self.directory, step) if step is not None else {}

    def _gc(self):
        gc_stale_tmp(self.directory)
        steps = available_steps(self.directory)
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:010d}"),
                          ignore_errors=True)
