"""Jit'd public wrappers for the Pallas kernels.

Handles padding to TPU tile boundaries, dtype plumbing, and the
interpret-mode switch (kernels execute in Python on CPU backends so the
whole suite validates without TPU silicon; on TPU backends they lower to
Mosaic).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import decode_attention as _da
from repro.kernels import flash_attention as _fa
from repro.kernels import tcam_match as _tm

LANES = _tm.LANES


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def _auto_block_rows(n: int) -> int:
    """Largest sensible row-block for an n-element table.

    Small tables (e.g. one shard of a sharded replay ring) would otherwise
    pad to the full 64x128 default tile; capping the block at the table's
    own row count keeps the padding (and the interpret-mode cost on CPU)
    proportional to the input.  Rounded up to a multiple of 8 rows so the
    (block_rows, 128) int32 block always satisfies Mosaic's (8, 128)
    sublane tiling when the kernel really compiles on TPU.
    """
    rows = -(-n // LANES)
    return min(_tm.DEFAULT_BLOCK_ROWS, max(8, 8 * (-(-rows // 8))))


def _pad_table(pq: jax.Array, valid: jax.Array, block_rows: int):
    """Pad a flat int32 table to (R, 128) with R % block_rows == 0."""
    n = pq.shape[0]
    tile = block_rows * LANES
    n_pad = -n % tile
    pq = jnp.pad(pq, (0, n_pad), constant_values=-1)
    valid = jnp.pad(valid, (0, n_pad), constant_values=False)
    rows = (n + n_pad) // LANES
    return pq.reshape(rows, LANES), valid.reshape(rows, LANES), n


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def tcam_match(pq: jax.Array, query: jax.Array, mask: jax.Array, *,
               block_rows: int | None = None,
               interpret: bool | None = None) -> jax.Array:
    """Single ternary-CAM query over a flat int32[n] table -> bool[n]."""
    interpret = _interpret_default() if interpret is None else interpret
    block_rows = _auto_block_rows(pq.shape[0]) if block_rows is None else block_rows
    pq2, _, n = _pad_table(pq, jnp.ones_like(pq, jnp.bool_), block_rows)
    out = _tm.tcam_match(pq2, jnp.asarray(query, jnp.int32),
                         jnp.asarray(mask, jnp.int32),
                         block_rows=block_rows, interpret=interpret)
    return out.reshape(-1)[:n]


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def multi_query_match(pq: jax.Array, valid: jax.Array, lo: jax.Array,
                      hi: jax.Array, *,
                      block_rows: int | None = None,
                      interpret: bool | None = None):
    """Fused m-range AMPER search over a flat table.

    Returns (sel bool[n], counts int32[m]).  Padding rows carry pq = -1
    (matches no non-negative range) and valid = False.
    """
    interpret = _interpret_default() if interpret is None else interpret
    block_rows = _auto_block_rows(pq.shape[0]) if block_rows is None else block_rows
    pq2, valid2, n = _pad_table(pq, valid, block_rows)
    sel, counts = _tm.multi_query_match(
        pq2, valid2, lo.astype(jnp.int32), hi.astype(jnp.int32),
        block_rows=block_rows, interpret=interpret)
    return sel.reshape(-1)[:n], counts


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bkv",
                                             "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int | None = None,
                    bq: int = 128, bkv: int = 128,
                    interpret: bool | None = None) -> jax.Array:
    """Blockwise attention with seq/head-dim padding to tile boundaries."""
    interpret = _interpret_default() if interpret is None else interpret
    b, hq, s, d = q.shape
    s_pad = -s % max(bq, bkv)
    d_pad = -d % LANES
    if s_pad or d_pad:
        pad4 = ((0, 0), (0, 0), (0, s_pad), (0, d_pad))
        # Pre-scale q so the kernel's 1/sqrt(d_padded) equals the true
        # 1/sqrt(d): zero-padding the head dim leaves q.k unchanged, only
        # the softmax temperature needs the correction, applied to q.
        if d_pad:
            q = q * (((d + d_pad) / d) ** 0.5)
        q = jnp.pad(q, pad4)
        k = jnp.pad(k, pad4)
        v = jnp.pad(v, pad4)
    # Padded KV columns sit at positions >= s, so causal/window geometry
    # masks them for every real q row.  Non-causal inputs must be aligned.
    if not causal and s_pad:
        raise ValueError("non-causal flash path requires tile-aligned seq")
    out = _fa.flash_attention_fwd(q, k, v, causal=causal, window=window,
                                  bq=bq, bkv=bkv, interpret=interpret)
    if d_pad or s_pad:
        out = out[:, :, :s, :d]
    return out


@functools.partial(jax.jit, static_argnames=("bkv", "interpret"))
def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     cur_len, *, bkv: int = 512,
                     interpret: bool | None = None) -> jax.Array:
    """Single-token cache attention; pads S and D to tile boundaries."""
    interpret = _interpret_default() if interpret is None else interpret
    b, hkv, group, d = q.shape
    s_len = k.shape[2]
    s_pad = -s_len % bkv
    d_pad = -d % LANES
    if d_pad:
        q = q * (((d + d_pad) / d) ** 0.5)  # keep true softmax temperature
        q = jnp.pad(q, ((0, 0), (0, 0), (0, 0), (0, d_pad)))
    if s_pad or d_pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, s_pad), (0, d_pad)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, s_pad), (0, d_pad)))
    out = _da.decode_attention_fwd(q, k, v, jnp.asarray(cur_len, jnp.int32),
                                   bkv=min(bkv, k.shape[2]),
                                   interpret=interpret)
    return out[..., :d]
