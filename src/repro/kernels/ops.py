"""Jit'd public wrappers for the Pallas kernels.

Handles padding to TPU tile boundaries, dtype plumbing, and the
interpret-mode switch (kernels execute in Python on CPU backends so the
whole suite validates without TPU silicon; on TPU backends they lower to
Mosaic).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import amper_sample as _as
from repro.kernels import decode_attention as _da
from repro.kernels import flash_attention as _fa
from repro.kernels import tcam_match as _tm
from repro.kernels.common import (LANES, auto_block_rows as _auto_block_rows,
                                  force_interpret,
                                  interpret_default as _interpret_default,
                                  pad_table as _pad_table)

__all__ = ["LANES", "force_interpret", "tcam_match", "multi_query_match",
           "amper_sample", "rank_select", "flash_attention",
           "decode_attention"]


def _jit_kernel(fn, *, static=()):
    """``jax.jit`` with ``interpret=None`` resolved OUTSIDE the trace cache.

    The interpret default depends on ambient state (backend +
    :func:`force_interpret` override), so it must be folded into the jit
    cache key as the actual bool.  Resolving it inside the jitted body
    would let the first call under ``force_interpret`` poison the cached
    entry for ``interpret=None`` with the wrong lowering.
    """
    jitted = jax.jit(fn, static_argnames=tuple(static) + ("interpret",))

    @functools.wraps(fn)
    def wrapper(*args, interpret=None, **kwargs):
        if interpret is None:
            interpret = _interpret_default()
        return jitted(*args, interpret=interpret, **kwargs)

    return wrapper


@functools.partial(_jit_kernel, static=("block_rows",))
def tcam_match(pq: jax.Array, query: jax.Array, mask: jax.Array, *,
               block_rows: int | None = None,
               interpret: bool = False) -> jax.Array:
    """Single ternary-CAM query over a flat int32[n] table -> bool[n]."""
    block_rows = _auto_block_rows(pq.shape[0]) if block_rows is None else block_rows
    pq2, _, n = _pad_table(pq, jnp.ones_like(pq, jnp.bool_), block_rows)
    out = _tm.tcam_match(pq2, jnp.asarray(query, jnp.int32),
                         jnp.asarray(mask, jnp.int32),
                         block_rows=block_rows, interpret=interpret)
    return out.reshape(-1)[:n]


@functools.partial(_jit_kernel, static=("block_rows",))
def multi_query_match(pq: jax.Array, valid: jax.Array, lo: jax.Array,
                      hi: jax.Array, *,
                      block_rows: int | None = None,
                      interpret: bool = False):
    """Fused m-range AMPER search over a flat table.

    Returns (sel bool[n], counts int32[m]).  Padding rows carry pq = -1
    (matches no non-negative range) and valid = False.
    """
    block_rows = _auto_block_rows(pq.shape[0]) if block_rows is None else block_rows
    pq2, valid2, n = _pad_table(pq, valid, block_rows)
    sel, counts = _tm.multi_query_match(
        pq2, valid2, lo.astype(jnp.int32), hi.astype(jnp.int32),
        block_rows=block_rows, interpret=interpret)
    return sel.reshape(-1)[:n], counts


@functools.partial(_jit_kernel, static=("batch", "csp_capacity",
                                        "block_rows"))
def amper_sample(pq: jax.Array, valid: jax.Array, lo: jax.Array,
                 hi: jax.Array, shift: jax.Array, key: jax.Array,
                 *, batch: int, csp_capacity: int,
                 block_rows: int | None = None,
                 interpret: bool = False):
    """The whole AMPER-fr draw fused into one Pallas dispatch.

    match + CSP count + in-kernel key split + threefry draw + rank gather
    over a flat int32[n] table; bit-identical to the reference
    ``_compact`` + ``sample_from_csp`` pipeline under the same
    (shift, key) randomness.

    Args:
      pq, valid: flat int32[n] / bool[n] table.
      lo, hi: int32[m] inclusive range bounds per group.
      shift: int32 scalar compaction rotation (``randint(kroll, (), 0, n)``).
      key: typed PRNG key of the pick key (the kernel performs the
        pick/fallback ``split`` itself, bit-exact with ``jax.random``).
      batch: number of draws (static).
      csp_capacity: CSP buffer capacity (static).

    Returns:
      (idx int32[batch], stats int32[4] = [members, members below shift,
      live rows, truncated CSP count]).
    """
    block_rows = _auto_block_rows(pq.shape[0]) if block_rows is None else block_rows
    pq2, valid2, n = _pad_table(pq, valid, block_rows)
    idx, stats = _as.amper_sample(
        pq2, valid2, lo.astype(jnp.int32), hi.astype(jnp.int32),
        jnp.asarray(shift, jnp.int32),
        jax.random.key_data(key).astype(jnp.uint32),
        batch=batch, csp_capacity=csp_capacity, n_real=n,
        block_rows=block_rows, interpret=interpret)
    return idx, stats


@functools.partial(_jit_kernel, static=("block_rows",))
def rank_select(pq: jax.Array, valid: jax.Array, lo: jax.Array,
                hi: jax.Array, rank: jax.Array, *,
                block_rows: int | None = None,
                interpret: bool = False):
    """Index of each rank-th member of the fused m-range match (one pass).

    Streaming replacement for ``nonzero``-compaction + gather on the
    sharded per-shard pick path.  Ranks >= member count return 0 (callers
    mask by ownership).  Returns (idx int32[batch], count int32 scalar).
    """
    block_rows = _auto_block_rows(pq.shape[0]) if block_rows is None else block_rows
    pq2, valid2, _n = _pad_table(pq, valid, block_rows)
    return _as.rank_select(pq2, valid2, lo.astype(jnp.int32),
                           hi.astype(jnp.int32), rank.astype(jnp.int32),
                           block_rows=block_rows, interpret=interpret)


@functools.partial(_jit_kernel, static=("causal", "window", "bq", "bkv"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int | None = None,
                    bq: int = 128, bkv: int = 128,
                    interpret: bool = False) -> jax.Array:
    """Blockwise attention with seq/head-dim padding to tile boundaries."""
    b, hq, s, d = q.shape
    s_pad = -s % max(bq, bkv)
    d_pad = -d % LANES
    if s_pad or d_pad:
        pad4 = ((0, 0), (0, 0), (0, s_pad), (0, d_pad))
        # Pre-scale q so the kernel's 1/sqrt(d_padded) equals the true
        # 1/sqrt(d): zero-padding the head dim leaves q.k unchanged, only
        # the softmax temperature needs the correction, applied to q.
        if d_pad:
            q = q * (((d + d_pad) / d) ** 0.5)
        q = jnp.pad(q, pad4)
        k = jnp.pad(k, pad4)
        v = jnp.pad(v, pad4)
    # Padded KV columns sit at positions >= s, so causal/window geometry
    # masks them for every real q row.  Non-causal inputs must be aligned.
    if not causal and s_pad:
        raise ValueError("non-causal flash path requires tile-aligned seq")
    out = _fa.flash_attention_fwd(q, k, v, causal=causal, window=window,
                                  bq=bq, bkv=bkv, interpret=interpret)
    if d_pad or s_pad:
        out = out[:, :, :s, :d]
    return out


@functools.partial(_jit_kernel, static=("bkv",))
def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     cur_len, *, bkv: int = 512,
                     interpret: bool = False) -> jax.Array:
    """Single-token cache attention; pads S and D to tile boundaries."""
    b, hkv, group, d = q.shape
    s_len = k.shape[2]
    s_pad = -s_len % bkv
    d_pad = -d % LANES
    if d_pad:
        q = q * (((d + d_pad) / d) ** 0.5)  # keep true softmax temperature
        q = jnp.pad(q, ((0, 0), (0, 0), (0, 0), (0, d_pad)))
    if s_pad or d_pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, s_pad), (0, d_pad)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, s_pad), (0, d_pad)))
    out = _da.decode_attention_fwd(q, k, v, jnp.asarray(cur_len, jnp.int32),
                                   bkv=min(bkv, k.shape[2]),
                                   interpret=interpret)
    return out[..., :d]
