"""Pallas TPU kernels: TCAM-style match (the paper's search op) + attention.

Each kernel has a pure-jnp oracle in ref.py and a jit'd wrapper in ops.py;
tests/test_kernels.py sweeps shapes/dtypes and asserts allclose.
"""
