"""Fused AMPER-fr sampling: the whole draw in one Pallas dispatch.

The reference path (``fr_mode="broadcast"``) runs Algorithm 1 as separate
XLA ops: quantized m-range TCAM match -> stream compaction of the CSP
(``nonzero`` after a random rotation) -> uniform counter draw -> index
gather.  This kernel is the paper's Fig. 3 pipeline as ONE pass machine:

* phase 0 streams the (rows, 128) priority table once, evaluating the
  m-range match per tile and accumulating three scalars in SMEM — the
  CSP member count, the count of members below the rotation point, and
  the live-row count;
* between phases it draws the batch in-kernel: a threefry2x32 counter
  PRNG (bit-exact with ``jax.random.bits``) keyed by the caller's pick /
  fallback subkeys, reduced mod the CSP count;
* phase 1 streams the table a second time, rank-selecting each drawn
  CSP member directly from the match mask (hierarchical row/lane select
  via one-hot matmuls) — the compacted CSP index buffer never exists.

Bit-identity with the reference is exact, not statistical.  The key
identity: the reference rolls the selection mask by a random ``shift``
before ``nonzero``-compacting, so ``csp.indices[u]`` is the member with
*cyclic* rank u — which equals the member with ordinary (index-order)
rank ``(u + s_shift) % total`` where ``s_shift`` counts members at
indices below ``shift``.  Rank-selecting that member from the mask in
index order therefore reproduces the compacted buffer's answer without
materialising it, including under capacity truncation (the draw is
``bits % min(total, csp_capacity)``, always a valid cyclic rank).

The one-hot row/lane gathers run as f32 matmuls (MXU-friendly); they are
exact for integers below 2^24, which bounds ``frac_bits <= 24`` (the
default).  ``interpret=True`` off-TPU executes the identical program in
Python, so CPU CI pins the exact kernel logic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import DEFAULT_BLOCK_ROWS, LANES

MAX_FRAC_BITS = 24  # one-hot f32 matmul gathers are exact below 2^24

_ROTATIONS = ((13, 15, 26, 6), (17, 29, 16, 24))


def _rotl(x, d):
    return (x << jnp.uint32(d)) | (x >> jnp.uint32(32 - d))


def _threefry2x32(k0, k1, x0, x1):
    """Threefry-2x32 block cipher on uint32 lanes (bit-exact with
    ``jax.random``'s threefry, 20 rounds with the standard key schedule)."""
    ks = (k0, k1, k0 ^ k1 ^ jnp.uint32(0x1BD11BDA))
    x0 = x0 + ks[0]
    x1 = x1 + ks[1]
    for i in range(5):
        for r in _ROTATIONS[i % 2]:
            x0 = x0 + x1
            x1 = _rotl(x1, r)
            x1 = x0 ^ x1
        x0 = x0 + ks[(i + 1) % 3]
        x1 = x1 + ks[(i + 2) % 3] + jnp.uint32(i + 1)
    return x0, x1


def counter_bits(key_data: jax.Array, j: jax.Array, n: jax.Array) -> jax.Array:
    """``jax.random.bits(key, (n,), uint32)`` evaluated at positions ``j``.

    jax's threefry layout runs counters ``0..n-1`` (odd n padded with one
    trailing 0) split into two halves (x0 = first half, x1 = second); the
    output is the concatenation of the two cipher outputs.  Each lane here
    recomputes its own pair, so the whole draw is a map — no slicing, no
    cross-lane traffic, safe inside a kernel at any alignment.

    ``j`` may be any uint32 array of positions < n; ``n`` is a traced
    scalar (int32).  Positions >= n return the padded-counter stream.
    """
    k0 = key_data[0]
    k1 = key_data[1]
    n = n.astype(jnp.uint32)
    h = (n + (n & jnp.uint32(1))) >> jnp.uint32(1)  # ceil(n/2)
    j = j.astype(jnp.uint32)
    in_lo = j < h
    p = jnp.where(in_lo, j, j - h)
    x0 = p
    x1 = jnp.where(h + p < n, h + p, jnp.uint32(0))  # odd-n trailing pad
    o0, o1 = _threefry2x32(k0, k1, x0, x1)
    return jnp.where(in_lo, o0, o1)


def _match_tile(p, valid, lo_ref, hi_ref, m: int):
    """OR of the m inclusive range matches on one (block_rows, 128) tile."""
    sel = jnp.zeros(p.shape, jnp.bool_)
    for i in range(m):
        sel = sel | ((p >= lo_ref[i]) & (p <= hi_ref[i]))
    return sel & valid


def amper_sample_kernel(lo_ref, hi_ref, shift_ref, key_ref,
                        p_ref, valid_ref, idx_ref, stats_ref,
                        acc_ref, draw_ref,
                        *, m: int, batch: int, csp_capacity: int,
                        block_rows: int, n_real: int):
    """Grid (2, nblk), executed sequentially (TPU grid order).

    acc_ref (SMEM int32[4]): [total members, members below shift, live
    rows, running member prefix before the current phase-1 block].
    draw_ref (VMEM int32[2, batch_pad]): row 0 = target ordinary ranks,
    row 1 = fallback raw indices.
    """
    phase = pl.program_id(0)
    b = pl.program_id(1)
    nblk = pl.num_programs(1)
    bp = draw_ref.shape[1]

    rows2d = jax.lax.broadcasted_iota(jnp.int32, (block_rows, LANES), 0)
    lanes2d = jax.lax.broadcasted_iota(jnp.int32, (block_rows, LANES), 1)
    gidx = (b * block_rows + rows2d) * LANES + lanes2d  # global flat index

    @pl.when((phase == 0) & (b == 0))
    def _init():
        acc_ref[0] = 0
        acc_ref[1] = 0
        acc_ref[2] = 0
        acc_ref[3] = 0

    @pl.when(phase == 0)
    def _count():
        sel = _match_tile(p_ref[...], valid_ref[...], lo_ref, hi_ref, m)
        shift = shift_ref[0]
        acc_ref[0] += jnp.sum(sel.astype(jnp.int32))
        acc_ref[1] += jnp.sum((sel & (gidx < shift)).astype(jnp.int32))
        acc_ref[2] += jnp.sum(valid_ref[...].astype(jnp.int32))

    @pl.when((phase == 1) & (b == 0))
    def _draw():
        total = acc_ref[0]
        s_shift = acc_ref[1]
        live = acc_ref[2]
        count = jnp.minimum(total, csp_capacity)
        j = jax.lax.broadcasted_iota(jnp.uint32, (1, bp), 1)
        nb = jnp.int32(batch)
        # In-kernel jax.random.split(key): under the original threefry
        # impl, split(key, 2).key_data == bits(key, (4,)) paired up, so
        # the pick / fallback subkeys are four more cipher evaluations —
        # the host never touches raw key data.
        four = jnp.uint32(4)
        pk = (counter_bits(key_ref, jnp.uint32(0), four),
              counter_bits(key_ref, jnp.uint32(1), four))
        fk = (counter_bits(key_ref, jnp.uint32(2), four),
              counter_bits(key_ref, jnp.uint32(3), four))
        pick = counter_bits(pk, j, nb)
        fb = counter_bits(fk, j, nb)
        # same arithmetic as amper.pick_uniform: bits mod max(bound, 1)
        u = (pick % jnp.maximum(count, 1).astype(jnp.uint32)).astype(jnp.int32)
        rank = (u + s_shift) % jnp.maximum(total, 1)
        draw_ref[0:1, :] = rank
        draw_ref[1:2, :] = (fb % jnp.maximum(live, 1).astype(jnp.uint32)
                            ).astype(jnp.int32)
        idx_ref[...] = jnp.zeros_like(idx_ref)
        stats_ref[0] = total
        stats_ref[1] = s_shift
        stats_ref[2] = live
        stats_ref[3] = count

    @pl.when(phase == 1)
    def _select():
        sel = _match_tile(p_ref[...], valid_ref[...], lo_ref, hi_ref, m)
        sel_f = sel.astype(jnp.float32)
        base = acc_ref[3]
        rowsum = jnp.sum(sel.astype(jnp.int32), axis=1)  # (block_rows,)
        blk_cnt = jnp.sum(rowsum)
        # inclusive row cumsum via triangular mask-sum (exact: counts < 2^24)
        r_i = jax.lax.broadcasted_iota(jnp.int32, (block_rows, block_rows), 0)
        r_j = jax.lax.broadcasted_iota(jnp.int32, (block_rows, block_rows), 1)
        tri_rows = (r_i <= r_j).astype(jnp.float32)  # [i, j] = i <= j
        row_ck = jnp.dot(rowsum.astype(jnp.float32)[None, :], tri_rows,
                         preferred_element_type=jnp.float32)[0]  # inclusive

        rank = draw_ref[0:1, :][0]                       # (bp,)
        lr = rank - base                                 # local rank in block
        hit = (lr >= 0) & (lr < blk_cnt)
        lr_f = jnp.clip(lr, 0, jnp.maximum(blk_cnt - 1, 0)).astype(jnp.float32)
        # row r holds local member lr iff exclusive_ck[r] <= lr < inclusive
        below = (row_ck[None, :] <= lr_f[:, None]).astype(jnp.float32)
        t_row = jnp.sum(below, axis=1)                   # (bp,) f32 row id
        onehot = (jax.lax.broadcasted_iota(jnp.float32, (bp, block_rows), 1)
                  == t_row[:, None]).astype(jnp.float32)
        excl = row_ck - rowsum.astype(jnp.float32)       # exclusive cumsum
        row_base = jnp.dot(onehot, excl[:, None],
                           preferred_element_type=jnp.float32)[:, 0]
        selrow = jnp.dot(onehot, sel_f,
                         preferred_element_type=jnp.float32)  # (bp, LANES)
        rem = lr_f - row_base
        l_i = jax.lax.broadcasted_iota(jnp.float32, (LANES, LANES), 0)
        l_j = jax.lax.broadcasted_iota(jnp.float32, (LANES, LANES), 1)
        tri_lanes = (l_i <= l_j).astype(jnp.float32)
        lane_ck = jnp.dot(selrow, tri_lanes,
                          preferred_element_type=jnp.float32)  # inclusive
        t_lane = jnp.sum((lane_ck <= rem[:, None]).astype(jnp.float32), axis=1)
        flat = ((b * block_rows) + t_row) * LANES + t_lane
        idx_ref[...] += jnp.where(hit[None, :], flat[None, :].astype(jnp.int32),
                                  0)
        acc_ref[3] = base + blk_cnt

    @pl.when((phase == 1) & (b == nblk - 1))
    def _finish():
        total = acc_ref[0]
        fb = draw_ref[1:2, :]
        idx_ref[...] = jnp.where(total > 0, idx_ref[...], fb)


def amper_sample(pq: jax.Array, valid: jax.Array, lo: jax.Array,
                 hi: jax.Array, shift: jax.Array, key_data: jax.Array,
                 *, batch: int, csp_capacity: int,
                 n_real: int, block_rows: int = DEFAULT_BLOCK_ROWS,
                 interpret: bool = False) -> tuple[jax.Array, jax.Array]:
    """One fused dispatch: m-range match + CSP count + draw + rank gather.

    Args:
      pq: int32[R, 128] quantized priority table (R multiple of block_rows;
        padding rows carry -1 / invalid).
      valid: bool[R, 128].
      lo, hi: int32[m] inclusive range bounds per group.
      shift: int32 scalar — the compaction rotation (from the roll key).
      key_data: uint32[2] raw threefry key of the UN-SPLIT pick key; the
        kernel derives the pick and fallback subkeys itself (bit-exact
        with ``jax.random.split``).
      batch: draws per call (static).
      csp_capacity: CSP buffer capacity (static; truncates the count).
      n_real: flat length of the unpadded table (static; only documents
        that real rows precede padding — padding never matches).

    Returns:
      (idx int32[batch] flat indices, stats int32[4] = [members, members
      below shift, live rows, truncated CSP count]).
    """
    rows = pq.shape[0]
    m = lo.shape[0]
    nblk = rows // block_rows
    bp = -(-batch // LANES) * LANES  # batch padded to the lane width
    idx, stats = pl.pallas_call(
        functools.partial(amper_sample_kernel, m=m, batch=batch,
                          csp_capacity=csp_capacity, block_rows=block_rows,
                          n_real=n_real),
        grid=(2, nblk),
        in_specs=[
            pl.BlockSpec((m,), lambda p, b: (0,)),
            pl.BlockSpec((m,), lambda p, b: (0,)),
            pl.BlockSpec((1,), lambda p, b: (0,)),
            pl.BlockSpec((2,), lambda p, b: (0,)),
            pl.BlockSpec((block_rows, LANES), lambda p, b: (b, 0)),
            pl.BlockSpec((block_rows, LANES), lambda p, b: (b, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bp), lambda p, b: (0, 0)),
            pl.BlockSpec((4,), lambda p, b: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, bp), jnp.int32),
            jax.ShapeDtypeStruct((4,), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.SMEM((4,), jnp.int32),
            pltpu.VMEM((2, bp), jnp.int32),
        ],
        interpret=interpret,
    )(lo, hi, shift.reshape(1), key_data, pq, valid)
    return idx[0, :batch], stats


def rank_select_kernel(rank_ref, p_ref, valid_ref, lo_ref, hi_ref,
                       idx_ref, cnt_ref, acc_ref,
                       *, m: int, block_rows: int):
    """Grid (nblk,): index of the rank-th CSP member, in index order.

    The sharded per-shard pick: replaces ``nonzero``-compaction + gather
    with a single streaming pass.  Ranks >= member count return 0 (the
    caller masks by ownership, exactly as the reference clips).
    """
    b = pl.program_id(0)
    nblk = pl.num_programs(0)
    bp = rank_ref.shape[1]

    @pl.when(b == 0)
    def _init():
        acc_ref[0] = 0
        idx_ref[...] = jnp.zeros_like(idx_ref)

    sel = _match_tile(p_ref[...], valid_ref[...], lo_ref, hi_ref, m)
    sel_f = sel.astype(jnp.float32)
    base = acc_ref[0]
    rowsum = jnp.sum(sel.astype(jnp.int32), axis=1)
    blk_cnt = jnp.sum(rowsum)
    r_i = jax.lax.broadcasted_iota(jnp.int32, (block_rows, block_rows), 0)
    r_j = jax.lax.broadcasted_iota(jnp.int32, (block_rows, block_rows), 1)
    tri_rows = (r_i <= r_j).astype(jnp.float32)
    row_ck = jnp.dot(rowsum.astype(jnp.float32)[None, :], tri_rows,
                     preferred_element_type=jnp.float32)[0]

    rank = rank_ref[0:1, :][0]
    lr = rank - base
    hit = (lr >= 0) & (lr < blk_cnt)
    lr_f = jnp.clip(lr, 0, jnp.maximum(blk_cnt - 1, 0)).astype(jnp.float32)
    below = (row_ck[None, :] <= lr_f[:, None]).astype(jnp.float32)
    t_row = jnp.sum(below, axis=1)
    onehot = (jax.lax.broadcasted_iota(jnp.float32, (bp, block_rows), 1)
              == t_row[:, None]).astype(jnp.float32)
    excl = row_ck - rowsum.astype(jnp.float32)
    row_base = jnp.dot(onehot, excl[:, None],
                       preferred_element_type=jnp.float32)[:, 0]
    selrow = jnp.dot(onehot, sel_f, preferred_element_type=jnp.float32)
    rem = lr_f - row_base
    l_i = jax.lax.broadcasted_iota(jnp.float32, (LANES, LANES), 0)
    l_j = jax.lax.broadcasted_iota(jnp.float32, (LANES, LANES), 1)
    tri_lanes = (l_i <= l_j).astype(jnp.float32)
    lane_ck = jnp.dot(selrow, tri_lanes, preferred_element_type=jnp.float32)
    t_lane = jnp.sum((lane_ck <= rem[:, None]).astype(jnp.float32), axis=1)
    flat = ((b * block_rows) + t_row) * LANES + t_lane
    idx_ref[...] += jnp.where(hit[None, :], flat[None, :].astype(jnp.int32), 0)
    acc_ref[0] = base + blk_cnt

    @pl.when(b == nblk - 1)
    def _count():
        cnt_ref[0] = acc_ref[0]


def rank_select(pq: jax.Array, valid: jax.Array, lo: jax.Array,
                hi: jax.Array, rank: jax.Array, *,
                block_rows: int = DEFAULT_BLOCK_ROWS,
                interpret: bool = False) -> tuple[jax.Array, jax.Array]:
    """Flat index of each rank-th member of the m-range match, one pass.

    Args:
      pq, valid: (R, 128) padded table view.
      lo, hi: int32[m] inclusive range bounds.
      rank: int32[batch] 0-based member ranks (index order).
    Returns:
      (idx int32[batch] — 0 where rank >= count, cnt int32 scalar member
      count).
    """
    rows = pq.shape[0]
    m = lo.shape[0]
    nblk = rows // block_rows
    batch = rank.shape[0]
    bp = -(-batch // LANES) * LANES
    rank2 = jnp.pad(rank, (0, bp - batch)).reshape(1, bp)
    idx, cnt = pl.pallas_call(
        functools.partial(rank_select_kernel, m=m, block_rows=block_rows),
        grid=(nblk,),
        in_specs=[
            pl.BlockSpec((1, bp), lambda b: (0, 0)),
            pl.BlockSpec((block_rows, LANES), lambda b: (b, 0)),
            pl.BlockSpec((block_rows, LANES), lambda b: (b, 0)),
            pl.BlockSpec((m,), lambda b: (0,)),
            pl.BlockSpec((m,), lambda b: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((1, bp), lambda b: (0, 0)),
            pl.BlockSpec((1,), lambda b: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, bp), jnp.int32),
            jax.ShapeDtypeStruct((1,), jnp.int32),
        ],
        scratch_shapes=[pltpu.SMEM((1,), jnp.int32)],
        interpret=interpret,
    )(rank2, pq, valid, lo, hi)
    return idx[0, :batch], cnt[0]
