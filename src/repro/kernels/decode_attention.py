"""Pallas TPU kernel: single-token (decode) attention over a KV cache.

The decode cells' hot-spot: one query position against an S-long cache,
memory-bound at (params + cache)/HBM_bw.  This kernel streams the cache
through (bkv, d) VMEM tiles with online-softmax scratch — the in-chip
half of split-KV decoding (the cross-chip half is the psum combine the
SPMD partitioner inserts when the cache's S axis is sharded over
"model"; see models/attention.py::decode_attention).

GQA/MQA: q arrives grouped as (B, Hkv, group, D); each grid step loads
one kv head's tile once and serves all `group` query heads from it —
the memory-traffic-optimal schedule for MQA decode.

Masking: positions >= cur_len are dead (cache tail); cur_len is read
from an SMEM-style (1,) operand.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                   *, bkv: int, nkv: int, scale: float):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full(m_scr.shape, NEG_INF, jnp.float32)
        l_scr[...] = jnp.zeros(l_scr.shape, jnp.float32)
        acc_scr[...] = jnp.zeros(acc_scr.shape, jnp.float32)

    q = q_ref[0, 0].astype(jnp.float32) * scale       # (group, d)
    k = k_ref[0, 0].astype(jnp.float32)               # (bkv, d)
    v = v_ref[0, 0].astype(jnp.float32)               # (bkv, d)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (group, bkv)

    kpos = ki * bkv + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(kpos < len_ref[0], s, NEG_INF)

    m_prev, l_prev = m_scr[...], l_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(p.astype(v.dtype), v)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == nkv - 1)
    def _finalize():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def decode_attention_fwd(q: jax.Array, k: jax.Array, v: jax.Array,
                         cur_len: jax.Array, *, bkv: int = 512,
                         interpret: bool = False) -> jax.Array:
    """q: [B, Hkv, group, D]; k, v: [B, Hkv, S, D]; cur_len: int32 scalar.

    Returns [B, Hkv, group, D] attention output (q.dtype).  S must be a
    multiple of bkv (the ops.py wrapper pads; padded rows are masked by
    cur_len).
    """
    b, hkv, group, d = q.shape
    s_len = k.shape[2]
    nkv = s_len // bkv
    scale = 1.0 / (d ** 0.5)

    from jax.experimental.pallas import tpu as pltpu
    kernel = functools.partial(_decode_kernel, bkv=bkv, nkv=nkv, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=(b, hkv, nkv),
        in_specs=[
            pl.BlockSpec((1,), lambda bb, h, ki: (0,)),
            pl.BlockSpec((1, 1, group, d), lambda bb, h, ki: (bb, h, 0, 0)),
            pl.BlockSpec((1, 1, bkv, d), lambda bb, h, ki: (bb, h, ki, 0)),
            pl.BlockSpec((1, 1, bkv, d), lambda bb, h, ki: (bb, h, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, group, d), lambda bb, h, ki: (bb, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hkv, group, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, d), jnp.float32),
        ],
        interpret=interpret,
    )(cur_len.reshape(1), q, k, v)
