"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each kernel test sweeps shapes/dtypes and asserts allclose against these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tcam_match_ref(pq: jax.Array, query: jax.Array, mask: jax.Array) -> jax.Array:
    """Oracle for kernels.tcam_match.tcam_match."""
    return jnp.bitwise_and(jnp.bitwise_xor(pq, query), jnp.bitwise_not(mask)) == 0


def multi_query_match_ref(pq: jax.Array, valid: jax.Array, lo: jax.Array,
                          hi: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Oracle for kernels.tcam_match.multi_query_match (flat pq[n])."""
    match = (pq[None, :] >= lo[:, None]) & (pq[None, :] <= hi[:, None])
    match = match & valid[None, :]
    sel = jnp.any(match, axis=0)
    counts = jnp.sum(match.astype(jnp.int32), axis=1)
    return sel, counts


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: int | None = None) -> jax.Array:
    """Oracle for kernels.flash_attention (materialised softmax, f32)."""
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    s_mat = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                       k.astype(jnp.float32)) / (d ** 0.5)
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), jnp.bool_)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= (qpos - kpos) < window
    s_mat = jnp.where(mask, s_mat, -1e30)
    p = jax.nn.softmax(s_mat, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def decode_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                         cur_len) -> jax.Array:
    """Oracle for kernels.decode_attention. q:[B,Hkv,g,D]; k,v:[B,Hkv,S,D]."""
    b, hkv, g, d = q.shape
    s_len = k.shape[2]
    s = jnp.einsum("bkgd,bksd->bkgs", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / (d ** 0.5)
    mask = jnp.arange(s_len) < cur_len
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bksd->bkgd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
