"""Shared table-layout helpers for the Pallas kernels.

Every priority-table kernel views the flat int32 table as (rows, 128) so
the last dim matches the VPU lane width, pads the row count to a multiple
of the block size, and decides interpret-vs-Mosaic from the backend.
Those three decisions used to be duplicated between ``kernels.ops`` and
``kernels.tcam_match``; this module is now the single owner, used by the
TCAM kernels and the fused ``amper_sample`` kernel alike.
"""
from __future__ import annotations

import contextlib

import jax
import jax.numpy as jnp

LANES = 128
DEFAULT_BLOCK_ROWS = 64  # (64, 128) int32 tile = 32 KiB VMEM per operand

# Tri-state interpret override: None = auto (backend != "tpu").  Used by
# the dispatch-count instrumentation, which traces kernels with
# interpret=False so the jaxpr shows one ``pallas_call`` per kernel launch
# instead of the interpreter's unrolled emulation ops.
_INTERPRET_OVERRIDE: bool | None = None


def interpret_default() -> bool:
    """Should kernels run in interpret mode?  (True off-TPU, unless
    overridden by :func:`force_interpret`.)"""
    if _INTERPRET_OVERRIDE is not None:
        return _INTERPRET_OVERRIDE
    return jax.default_backend() != "tpu"


@contextlib.contextmanager
def force_interpret(value: bool | None):
    """Temporarily pin the interpret-mode default (None restores auto).

    Tracing (``jax.make_jaxpr``) under ``force_interpret(False)`` never
    executes the kernel, so it is safe on any backend — that is how the
    benchmark counts real XLA dispatches on CPU CI.

    Caveat: this override is NOT part of jax's trace-cache key (which is
    function identity + avals + jax config state), so a jaxpr traced
    under the override can be replayed by a later call to the same
    function object outside it.  Callers that trace under an override
    they don't want to execute must ``jax.clear_caches()`` afterwards
    (``benchmarks.bench_samplers.dispatch_count`` does).
    """
    global _INTERPRET_OVERRIDE
    prev = _INTERPRET_OVERRIDE
    _INTERPRET_OVERRIDE = value
    try:
        yield
    finally:
        _INTERPRET_OVERRIDE = prev


def auto_block_rows(n: int) -> int:
    """Largest sensible row-block for an n-element table.

    Small tables (e.g. one shard of a sharded replay ring) would otherwise
    pad to the full 64x128 default tile; capping the block at the table's
    own row count keeps the padding (and the interpret-mode cost on CPU)
    proportional to the input.  Rounded up to a multiple of 8 rows so the
    (block_rows, 128) int32 block always satisfies Mosaic's (8, 128)
    sublane tiling when the kernel really compiles on TPU.
    """
    rows = -(-n // LANES)
    return min(DEFAULT_BLOCK_ROWS, max(8, 8 * (-(-rows // 8))))


def pad_table(pq: jax.Array, valid: jax.Array, block_rows: int):
    """Pad a flat int32 table to (R, 128) with R % block_rows == 0.

    Padding rows carry pq = -1 (matches no non-negative range) and
    valid = False, so they are invisible to every membership law.
    Returns (pq2d, valid2d, n) with n the original flat length.
    """
    n = pq.shape[0]
    tile = block_rows * LANES
    n_pad = -n % tile
    pq = jnp.pad(pq, (0, n_pad), constant_values=-1)
    valid = jnp.pad(valid, (0, n_pad), constant_values=False)
    rows = (n + n_pad) // LANES
    return pq.reshape(rows, LANES), valid.reshape(rows, LANES), n
