"""Pallas TPU kernel: blockwise (flash) attention forward.

The serving/prefill hot-spot of every attention arch in the zoo.  Online
softmax over KV blocks with scratch-carried running (max, denom, acc) —
the canonical TPU flash schedule: grid (batch, q_heads, q_blocks,
kv_blocks), kv innermost so the (bq, d) accumulator lives in VMEM across
the whole kv sweep, with q/k/v streamed through (block, d) VMEM tiles.

Supports causal masking, sliding-window (SWA) masking, and GQA/MQA via
the k/v BlockSpec index map (q head h reads kv head h * kv_heads //
q_heads) — no materialised head broadcast, which is what makes MQA decode
memory-traffic-optimal.

Training uses the differentiable jnp blockwise path in models/attention.py;
this kernel is the inference fast path and is validated against
kernels/ref.py in interpret mode for every (dtype, shape, window) cell in
tests/test_kernels.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_fwd_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                      bq: int, bkv: int, nkv: int, causal: bool,
                      window: int | None, scale: float):
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full(m_scr.shape, NEG_INF, jnp.float32)
        l_scr[...] = jnp.zeros(l_scr.shape, jnp.float32)
        acc_scr[...] = jnp.zeros(acc_scr.shape, jnp.float32)

    q = q_ref[0, 0].astype(jnp.float32) * scale   # (bq, d)
    k = k_ref[0, 0].astype(jnp.float32)           # (bkv, d)
    v = v_ref[0, 0].astype(jnp.float32)           # (bkv, d)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bkv)

    qi = pl.program_id(2)
    qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
    kpos = ki * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
    mask = jnp.ones((bq, bkv), jnp.bool_)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(p.astype(v.dtype), v)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == nkv - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_attention_fwd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: int | None = None,
                        bq: int = 128, bkv: int = 128,
                        interpret: bool = False) -> jax.Array:
    """Blockwise attention forward.

    Args:
      q: [B, Hq, S, D]; k, v: [B, Hkv, S, D] with Hq % Hkv == 0.
      S must be a multiple of max(bq, bkv); D should be a multiple of 128
      on real TPU (the ops.py wrapper pads).
    Returns:
      [B, Hq, S, D] attention output in q.dtype.
    """
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    nq, nkv = s // bq, s // bkv
    scale = 1.0 / (d ** 0.5)

    kernel = functools.partial(
        _flash_fwd_kernel, bq=bq, bkv=bkv, nkv=nkv, causal=causal,
        window=window, scale=scale)

    from jax.experimental.pallas import tpu as pltpu
    return pl.pallas_call(
        kernel,
        grid=(b, hq, nq, nkv),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda bb, h, qi, ki: (bb, h, qi, 0)),
            pl.BlockSpec((1, 1, bkv, d), lambda bb, h, qi, ki: (bb, h // group, ki, 0)),
            pl.BlockSpec((1, 1, bkv, d), lambda bb, h, qi, ki: (bb, h // group, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda bb, h, qi, ki: (bb, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
