"""Pallas TPU kernel: ternary-CAM match over an int32 priority table.

This is the TPU incarnation of the paper's TCAM search (Fig. 3 / Fig. 6(c)).
A TCAM compares the query against every stored row in O(1) wall-clock by
physics; the TPU equivalent is streaming (8,128) int32 tiles HBM->VMEM and
XOR/AND/compare-ing them on the VPU — 1024 lanes per cycle, arithmetic
intensity ~1 op/byte, i.e. perfectly memory-bound streaming with zero
irregular access (exactly what the sum tree is not).

Two kernels:

* :func:`tcam_match_kernel` — single ternary query ``(p ^ q) & ~mask == 0``
  over the whole table.  Bit-faithful to the exact-match TCAM sensing.

* :func:`multi_query_kernel` — the fused AMPER search: all m group queries
  in ONE pass over HBM, emitting the OR'd selection mask plus per-group
  match counts (the C_{Δi} the paper's CSP sizing needs).  Queries are
  expressed as inclusive int32 ranges [lo_i, hi_i]; a prefix query with
  don't-care mask M is exactly the range [q & ~M, (q & ~M) | M] (see
  quantize.prefix_range), so this one kernel serves the faithful prefix
  mode, the beyond-paper exact-radius mode, AND the group histogram
  (ranges = group boundaries).

The priority table is viewed as (rows, 128) so the last dim matches the
VPU lane width; callers pad to a multiple of (block_rows * 128).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import DEFAULT_BLOCK_ROWS, LANES


def tcam_match_kernel(q_ref, mask_ref, p_ref, out_ref):
    """One ternary query against a (block_rows, 128) tile."""
    p = p_ref[...]
    q = q_ref[0]
    m = mask_ref[0]
    out_ref[...] = jnp.bitwise_and(jnp.bitwise_xor(p, q), jnp.bitwise_not(m)) == 0


def tcam_match(pq: jax.Array, query: jax.Array, mask: jax.Array,
               *, block_rows: int = DEFAULT_BLOCK_ROWS,
               interpret: bool = False) -> jax.Array:
    """Ternary match of one (query, mask) against pq viewed as (R, 128).

    Args:
      pq: int32[R, 128] quantized priority table (R multiple of block_rows).
      query, mask: int32 scalars (arrays of shape ()).
    Returns:
      bool[R, 128] match mask.
    """
    rows = pq.shape[0]
    grid = (rows // block_rows,)
    return pl.pallas_call(
        tcam_match_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANES), jnp.bool_),
        interpret=interpret,
    )(query.reshape(1), mask.reshape(1), pq)


def multi_query_kernel(lo_ref, hi_ref, p_ref, valid_ref, sel_ref, cnt_ref, *, m: int):
    """Fused m-range match on one tile: OR'd selection + per-group counts.

    cnt_ref is (1, m) per grid step; the caller sums over grid steps.  The
    in-kernel loop over m is unrolled (m is small, <= 32) so each tile is
    read from VMEM once and compared m times — the VPU analogue of issuing
    m TCAM searches while the array is precharged.
    """
    p = p_ref[...]
    valid = valid_ref[...]
    sel = jnp.zeros(p.shape, jnp.bool_)
    counts = jnp.zeros((m,), jnp.int32)
    for i in range(m):
        match = (p >= lo_ref[i]) & (p <= hi_ref[i]) & valid
        sel = sel | match
        counts = counts.at[i].set(jnp.sum(match.astype(jnp.int32)))
    sel_ref[...] = sel
    cnt_ref[0, :] = counts


def multi_query_match(pq: jax.Array, valid: jax.Array, lo: jax.Array,
                      hi: jax.Array, *, block_rows: int = DEFAULT_BLOCK_ROWS,
                      interpret: bool = False) -> tuple[jax.Array, jax.Array]:
    """All m range queries in one pass over the (R, 128) table.

    Returns (sel bool[R,128], counts int32[m]).
    """
    rows = pq.shape[0]
    m = lo.shape[0]
    nblk = rows // block_rows
    sel, cnt = pl.pallas_call(
        functools.partial(multi_query_kernel, m=m),
        grid=(nblk,),
        in_specs=[
            pl.BlockSpec((m,), lambda i: (0,)),
            pl.BlockSpec((m,), lambda i: (0,)),
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((1, m), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, LANES), jnp.bool_),
            jax.ShapeDtypeStruct((nblk, m), jnp.int32),
        ],
        interpret=interpret,
    )(lo, hi, pq, valid)
    return sel, jnp.sum(cnt, axis=0)
