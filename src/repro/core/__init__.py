"""Core AMPER library: the paper's contribution as composable JAX modules."""
from repro.core.amper import (
    AmperConfig,
    AmperSampler,
    AmperState,
    CspResult,
    UniformSampler,
    build_csp_fr,
    build_csp_k,
    sample_from_csp,
)
from repro.core.per import CumsumPER, SumTreePER, importance_weights
from repro.core.replay_buffer import ReplayBuffer, ReplayState
from repro.core.sharded import ShardedAmperSampler, ShardedPERSampler
from repro.core.samplers import (
    Sampler,
    available_samplers,
    make_sampler,
    register_sampler,
)

# NOTE: fixed-point helpers live in repro.core.quantize; they are NOT
# re-exported here because the function name `quantize` would shadow the
# submodule attribute and break `import repro.core.quantize as qz`.

__all__ = [
    "AmperConfig", "AmperSampler", "AmperState", "CspResult", "UniformSampler",
    "build_csp_fr", "build_csp_k", "sample_from_csp",
    "CumsumPER", "SumTreePER", "importance_weights",
    "ReplayBuffer", "ReplayState",
    "ShardedAmperSampler", "ShardedPERSampler",
    "Sampler", "available_samplers", "make_sampler", "register_sampler",
]
