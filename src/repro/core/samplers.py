"""Unified sampler protocol + registry.

Every priority sampler in the system (uniform / PER sum-tree / PER
cumsum / AMPER-k / AMPER-fr) implements the same five-method state
machine, and everything that consumes one — the replay buffer, the DQN
agent, the LM data pipeline, the benchmarks — should construct it
through ONE factory instead of hand-rolling `if kind == ...` ladders.
This module is that single seam:

* :class:`Sampler` — the formal structural protocol (init / update /
  sample / priorities / total).  All concrete samplers already satisfy
  it; the protocol is ``runtime_checkable`` so tests can assert it.
* :func:`register_sampler` — decorator adding a builder to the registry,
  so new samplers (future PRs: rank-based PER, multi-host replay
  services) plug in without touching any call site.
* :func:`make_sampler` — the registry-backed factory.  Builders accept
  one unified kwargs vocabulary and ignore hyper-parameters they don't
  consume, so a call site can forward its whole config dict regardless
  of which sampler the user picked.

The sharded fronts promised by PR 1 exist: ``"amper-fr-sharded"`` and
``"per-sharded"`` build :class:`repro.core.sharded.ShardedAmperSampler` /
:class:`~repro.core.sharded.ShardedPERSampler`, whose priority tables live
partitioned over a ``jax.sharding.Mesh`` (pass ``mesh=``; defaults to a
1-D mesh over every visible device).  They satisfy the same protocol, so
the replay buffer and the DQN agent use them unchanged.

Shared kwargs vocabulary (all optional):
  m, lam_fr, csp_ratio, v_max, knn_mode, fr_mode, exact_radius,
  frac_bits  — AMPER hyper-parameters (Algorithm 1); ``fr_mode`` picks
  the search implementation (broadcast / interval / window / kernel /
  fused — "fused" runs the whole draw as one Pallas dispatch, see
  :mod:`repro.kernels.amper_sample`), all bit-identical;
  csp_capacity — overrides the csp_ratio-derived CSP size;
  min_csp      — floor for the derived CSP size (usually the train batch);
  mesh, axis_names, local_csp_capacity — sharded samplers only: the mesh
  to partition the priority table over, which of its axes to use, and the
  per-shard CSP buffer override.
"""
from __future__ import annotations

from typing import Any, Callable, Protocol, runtime_checkable

import jax


@runtime_checkable
class Sampler(Protocol):
    """Structural interface every replay-priority sampler implements.

    State is an opaque pytree produced by :meth:`init`; all methods are
    pure and jit/vmap/shard-compatible.
    """

    def init(self) -> Any:
        """Fresh sampler state (empty table)."""
        ...

    def update(self, state: Any, idx: jax.Array, priority: jax.Array) -> Any:
        """Write ``priority[i]`` (already |td|^alpha-exponentiated) at
        row ``idx[i]``.  ``idx`` may be any batch of DISTINCT indices."""
        ...

    def sample(self, state: Any, key: jax.Array, batch: int) -> jax.Array:
        """Draw ``batch`` int32 row indices by the sampler's law."""
        ...

    def priorities(self, state: Any) -> jax.Array:
        """Dense float32[capacity] view of the stored priorities."""
        ...

    def total(self, state: Any) -> jax.Array:
        """Sum of stored priorities (the PER normaliser)."""
        ...


def masked_update(sampler: Sampler, state: Any, idx: jax.Array,
                  priority: jax.Array, valid: jax.Array) -> Any:
    """Out-of-band (deferred) priority write for any registry sampler.

    Rows with ``valid[i] == False`` are rewritten with their *current*
    priority — a no-op write — so a stale deferred update (the slot was
    recycled between sample and feedback) never clobbers fresh state.

    ``idx`` may contain duplicates (priority sampling draws with
    replacement): every occurrence of a row is rewritten with the value
    of that row's last VALID occurrence (its current priority if none is
    valid), so all duplicate scatter writes carry identical values and
    the scatter's winner is irrelevant — sequential last-write-wins
    semantics on every backend, without requiring the protocol's
    distinct-indices contract.
    """
    import jax.numpy as jnp

    prios = sampler.priorities(state)
    rank = jnp.arange(1, idx.shape[0] + 1, dtype=jnp.int32)
    last_valid = jnp.zeros(prios.shape[0], jnp.int32).at[idx].max(
        jnp.where(valid, rank, 0))
    winner = last_valid[idx]  # per position: rank of its row's winner
    value = jnp.where(
        winner > 0,
        priority.astype(jnp.float32)[jnp.maximum(winner - 1, 0)],
        prios[idx])
    return sampler.update(state, idx, value)


def abstract_state(sampler: Sampler) -> Any:
    """Abstract (ShapeDtypeStruct) pytree of ``sampler.init()``.

    Sampler states are pure pytrees, so this is the checkpoint-restore
    target for ANY registry kind — the serialization layer
    (:mod:`repro.train.replay_checkpoint`) needs no per-sampler code.
    """
    return jax.eval_shape(sampler.init)


_REGISTRY: dict[str, Callable[..., Sampler]] = {}


def register_sampler(name: str, *aliases: str):
    """Decorator: register ``builder(capacity, **kw) -> Sampler`` under
    ``name`` (plus aliases).  Re-registration replaces — last wins — so
    downstream code can override a builder without forking this module."""

    def deco(builder: Callable[..., Sampler]):
        for n in (name, *aliases):
            _REGISTRY[n] = builder
        return builder

    return deco


def available_samplers() -> list[str]:
    return sorted(_REGISTRY)


def make_sampler(kind: str, capacity: int, **kw) -> Sampler:
    """Build a sampler by registry name.

    Unknown hyper-parameters in ``kw`` are ignored by builders that don't
    consume them (see module docstring), so one call site can serve every
    registered kind.
    """
    try:
        builder = _REGISTRY[kind]
    except KeyError:
        raise ValueError(
            f"unknown sampler kind: {kind!r} "
            f"(available: {available_samplers()})") from None
    return builder(capacity, **kw)


# --- built-in builders -------------------------------------------------------
# Local imports inside the builders keep this module import-light and break
# the core.amper -> core.samplers -> core.amper cycle.


@register_sampler("uniform")
def _build_uniform(capacity: int, **_unused) -> Sampler:
    from repro.core.amper import UniformSampler

    return UniformSampler(capacity)


@register_sampler("per-sumtree")
def _build_sumtree(capacity: int, **_unused) -> Sampler:
    from repro.core.per import SumTreePER

    return SumTreePER(capacity)


@register_sampler("per-cumsum", "per")
def _build_cumsum(capacity: int, **_unused) -> Sampler:
    from repro.core.per import CumsumPER

    return CumsumPER(capacity)


def _amper_config(capacity: int, *, m: int = 20,
                  lam_fr: float = 2.0, csp_ratio: float = 0.15,
                  lam: float | None = None, v_max: float = 1.0,
                  csp_capacity: int | None = None,
                  min_csp: int = 64, knn_mode: str = "bisect",
                  fr_mode: str = "broadcast", exact_radius: bool = False,
                  frac_bits: int | None = None, **_unused):
    """The one place the unified kwargs vocabulary becomes an AmperConfig."""
    from repro.core.amper import AmperConfig
    import repro.core.quantize as qz

    return AmperConfig(
        capacity=capacity, m=m, lam_fr=lam_fr,
        lam=csp_ratio / 2.0 if lam is None else lam,
        v_max=v_max,
        csp_capacity=(csp_capacity if csp_capacity is not None
                      else max(int(capacity * csp_ratio), min_csp)),
        frac_bits=qz.DEFAULT_FRAC_BITS if frac_bits is None else frac_bits,
        knn_mode=knn_mode, fr_mode=fr_mode, exact_radius=exact_radius)


def _build_amper(variant: str, capacity: int, **kw) -> Sampler:
    from repro.core.amper import AmperSampler

    return AmperSampler(_amper_config(capacity, **kw), variant=variant)


@register_sampler("amper-fr")
def _build_amper_fr(capacity: int, **kw) -> Sampler:
    return _build_amper("fr", capacity, **kw)


@register_sampler("amper-k")
def _build_amper_k(capacity: int, **kw) -> Sampler:
    return _build_amper("k", capacity, **kw)


def _default_mesh():
    """1-D mesh over every visible device (the zero-config sharded case)."""
    return jax.make_mesh((jax.device_count(),), ("data",))


@register_sampler("amper-fr-sharded")
def _build_amper_fr_sharded(capacity: int, *, mesh=None,
                            axis_names=("pod", "data"),
                            local_csp_capacity: int | None = None,
                            **kw) -> Sampler:
    from repro.core.sharded import ShardedAmperSampler

    return ShardedAmperSampler(
        _amper_config(capacity, **kw), mesh if mesh is not None else _default_mesh(),
        axis_names=axis_names, local_csp_capacity=local_csp_capacity)


@register_sampler("per-sharded")
def _build_per_sharded(capacity: int, *, mesh=None,
                       axis_names=("pod", "data"), **_unused) -> Sampler:
    from repro.core.sharded import ShardedPERSampler

    return ShardedPERSampler(
        capacity, mesh if mesh is not None else _default_mesh(),
        axis_names=axis_names)
