"""Analytical latency model of the AMPER accelerator (Sec. 4.2, Table 2).

We have no TCAM silicon, so the paper's circuit-level component latencies
(Table 2, 45 nm CMOS) parameterise an analytical end-to-end model that
regenerates Fig. 9's curves and the 55x-270x speedup headline.  The model
follows the dataflow of Fig. 6(a):

  per group i:   URNG draw -> query generation -> parallel TCAM search
                 -> candidate writes into the CSP buffer
  per batch:     URNG draws + CSP buffer reads

TCAM arrays are 64x64 (one priority per row); all arrays are searched in
parallel, so search latency is independent of replay size.  The serial
terms are the per-group query pipeline and, dominating at large CSP sizes,
the candidate-set-buffer write throughput (the paper's Fig. 9(c) linearity).
"""
from __future__ import annotations

from dataclasses import dataclass

# Table 2 component latencies (ns).
TCAM_SEARCH_EXACT_NS = 0.58
TCAM_SEARCH_BEST_NS = 1.0
TCAM_WRITE_NS = 2.0
CSB_READ_NS = 0.78
CSB_WRITE_NS = 0.78
URNG_NS = 1.71
QG_KNN_NS = 3.57
QG_FRNN_NS = 2.02

TCAM_ROWS = 64


@dataclass(frozen=True)
class HwConfig:
    er_size: int          # replay entries (one TCAM row each)
    m: int = 20           # groups
    csp_ratio: float = 0.15
    batch: int = 64

    @property
    def n_arrays(self) -> int:
        return -(-self.er_size // TCAM_ROWS)

    @property
    def csp_size(self) -> int:
        return int(self.er_size * self.csp_ratio)


def latency_fr_ns(cfg: HwConfig) -> float:
    """AMPER-fr end-to-end sampling latency (ns).

    One exact-match search per group finds ALL candidates of that group in
    parallel; every matched candidate is written to the CSP buffer.
    """
    per_group = URNG_NS + QG_FRNN_NS + TCAM_SEARCH_EXACT_NS
    csp_writes = cfg.csp_size * CSB_WRITE_NS
    batch_reads = cfg.batch * (URNG_NS + CSB_READ_NS)
    return cfg.m * per_group + csp_writes + batch_reads


def latency_k_ns(cfg: HwConfig) -> float:
    """AMPER-k end-to-end sampling latency (ns).

    Best-match sensing returns ONE nearest neighbour per search, so each
    group needs N_i sequential searches; sum_i N_i == CSP size.  Each hit
    is written to the CSP buffer as it is found.
    """
    per_group_fixed = URNG_NS + QG_KNN_NS
    searches = cfg.csp_size * TCAM_SEARCH_BEST_NS
    csp_writes = cfg.csp_size * CSB_WRITE_NS
    batch_reads = cfg.batch * (URNG_NS + CSB_READ_NS)
    return cfg.m * per_group_fixed + searches + csp_writes + batch_reads


def latency_update_ns(cfg: HwConfig) -> float:
    """Priority update: one TCAM row write per sampled transition."""
    return cfg.batch * TCAM_WRITE_NS


def speedup_vs_gpu(cfg: HwConfig, gpu_per_batch_us: float, variant: str = "fr") -> float:
    """Speedup over a measured GPU PER per-batch sampling latency (us)."""
    ns = latency_fr_ns(cfg) if variant == "fr" else latency_k_ns(cfg)
    return gpu_per_batch_us * 1e3 / ns
