"""Prioritized Experience Replay baselines.

Two implementations of the sum-based priority sampling the paper
describes in Sec. 2.1 / Fig. 2:

* :class:`SumTreePER` -- the faithful array-backed sum tree with O(log n)
  stochastic descent per sample and O(log n) scatter-add per update.  This
  is the baseline whose tree-traversal cost the paper attacks; we keep it
  bit-faithful (fori_loop descent, per-level delta propagation) so the
  Fig. 4-style latency breakdown can be reproduced.

* :class:`CumsumPER` -- the TPU/vector-idiomatic equivalent: prefix-sum +
  vectorised ``searchsorted``.  Mathematically identical sampling law,
  O(n) fully-parallel work, no irregular access.  This is what priority
  sampling *should* look like on a vector machine and serves as the
  "strong baseline" in benchmarks.

Both sample with the PER law P(i) = p_i / sum_k p_k where the stored
p_i are already exponentiated (p = |td|^alpha), and both support
stratified sampling (one uniform per batch segment, as in Schaul et al.)
and importance-sampling weights w_i = (N * P(i))^-beta / max_j w_j.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


class SumTreeState(NamedTuple):
    """Array-backed sum tree. tree[1] is the root; leaves at [leaf0, leaf0+n)."""

    tree: jax.Array  # float32[2 * n_pow2]
    n_leaves: jax.Array  # int32 scalar (strongly typed: a weak python int
    # leaf makes every downstream jit retrace when a device value arrives)


class SumTreePER:
    """Faithful sum-tree PER (Fig. 2(c))."""

    def __init__(self, capacity: int):
        self.capacity = capacity
        self.n_pow2 = _next_pow2(capacity)
        self.depth = self.n_pow2.bit_length() - 1  # levels below the root

    def init(self) -> SumTreeState:
        return SumTreeState(
            tree=jnp.zeros(2 * self.n_pow2, jnp.float32),
            n_leaves=jnp.asarray(self.capacity, jnp.int32),
        )

    def total(self, state: SumTreeState) -> jax.Array:
        return state.tree[1]

    def priorities(self, state: SumTreeState) -> jax.Array:
        leaf0 = self.n_pow2
        return jax.lax.dynamic_slice(state.tree, (leaf0,), (self.capacity,))

    def update(self, state: SumTreeState, idx: jax.Array, priority: jax.Array) -> SumTreeState:
        """Set priorities at ``idx`` (int32[b]) to ``priority`` (float32[b]).

        Propagates per-level deltas with scatter-add, which is exactly the
        leaf-to-root walk of the textbook implementation but batched; shared
        ancestors accumulate both deltas, preserving correctness.
        """
        tree = state.tree
        leaf = idx + self.n_pow2
        old = tree[leaf]
        delta = priority.astype(jnp.float32) - old
        # Duplicate indices within one batch would double-apply deltas; PER
        # updates are on distinct sampled indices, but we guard anyway by
        # keeping only the last occurrence of each leaf.
        order = jnp.arange(idx.shape[0])
        last = jnp.zeros(self.capacity, jnp.int32).at[idx].max(order + 1)
        keep = last[idx] == order + 1
        delta = jnp.where(keep, delta, 0.0)
        node = leaf
        for _ in range(self.depth + 1):  # leaf level up to and including root
            tree = tree.at[node].add(delta)
            node = node // 2
        return SumTreeState(tree=tree, n_leaves=state.n_leaves)

    def sample(self, state: SumTreeState, key: jax.Array, batch: int,
               stratified: bool = True) -> jax.Array:
        """Draw ``batch`` leaf indices by stochastic descent (Fig. 2(c))."""
        total = jnp.maximum(state.tree[1], 1e-12)
        u = jax.random.uniform(key, (batch,))
        if stratified:
            seg = total / batch
            target = (jnp.arange(batch) + u) * seg
        else:
            target = u * total

        def descend(carry, _):
            node, rem = carry
            left = 2 * node
            lsum = state.tree[left]
            go_left = rem < lsum
            node = jnp.where(go_left, left, left + 1)
            rem = jnp.where(go_left, rem, rem - lsum)
            return (node, rem), None

        (node, _), _ = jax.lax.scan(
            descend, (jnp.ones((batch,), jnp.int32), target), None, length=self.depth
        )
        return jnp.clip(node - self.n_pow2, 0, self.capacity - 1)


class CumsumState(NamedTuple):
    priorities: jax.Array  # float32[capacity]


class CumsumPER:
    """Vector-machine PER: cumulative sum + searchsorted (same sampling law)."""

    def __init__(self, capacity: int):
        self.capacity = capacity

    def init(self) -> CumsumState:
        return CumsumState(priorities=jnp.zeros(self.capacity, jnp.float32))

    def total(self, state: CumsumState) -> jax.Array:
        return jnp.sum(state.priorities)

    def priorities(self, state: CumsumState) -> jax.Array:
        return state.priorities

    def update(self, state: CumsumState, idx: jax.Array, priority: jax.Array) -> CumsumState:
        return CumsumState(priorities=state.priorities.at[idx].set(priority))

    def sample(self, state: CumsumState, key: jax.Array, batch: int,
               stratified: bool = True) -> jax.Array:
        c = jnp.cumsum(state.priorities)
        total = jnp.maximum(c[-1], 1e-12)
        u = jax.random.uniform(key, (batch,))
        if stratified:
            target = (jnp.arange(batch) + u) * (total / batch)
        else:
            target = u * total
        idx = jnp.searchsorted(c, target, side="right")
        return jnp.clip(idx, 0, self.capacity - 1).astype(jnp.int32)


def beta_schedule(beta0: float, beta_end: float, step: jax.Array,
                  horizon: int) -> jax.Array:
    """Linearly annealed IS exponent β(t), per Schaul et al. Sec. 3.4.

    PER's importance-sampling correction is only unbiased at β = 1; the
    paper anneals β from its initial value to 1 over training so the
    correction is full-strength by convergence.  ``step`` may be traced
    (the schedule runs inside jitted train steps); past ``horizon`` the
    value clamps at ``beta_end``.
    """
    frac = jnp.clip(step / jnp.maximum(horizon, 1), 0.0, 1.0)
    return beta0 + (beta_end - beta0) * frac


def importance_from_selected(p_sel: jax.Array, total: jax.Array,
                             size: jax.Array,
                             beta: float | jax.Array) -> jax.Array:
    """PER IS weights from already-gathered priorities (Schaul et al. Eq. 2).

    The ONE weight formula every sampling path shares: the reference
    XLA pipeline and the fused Pallas kernel both gather ``p_sel`` (the
    priorities of the sampled rows) and hand it here with the same
    normalisation constant ``total`` — hoisted out of the per-draw path
    so the two cannot drift.  Bit-identical indices therefore imply
    bit-identical weights.
    """
    total = jnp.maximum(total, 1e-12)
    p = jnp.maximum(p_sel, 1e-12) / total
    w = (size.astype(jnp.float32) * p) ** (-beta)
    return w / jnp.maximum(jnp.max(w), 1e-12)


def importance_weights(priorities: jax.Array, idx: jax.Array, size: jax.Array,
                       beta: float | jax.Array) -> jax.Array:
    """PER importance-sampling weights, max-normalised (Schaul et al. Eq. 2).

    ``beta`` may be a traced scalar (annealed schedules thread it through
    jitted sampling)."""
    return importance_from_selected(priorities[idx], jnp.sum(priorities),
                                    size, beta)
