"""Fixed-point priority quantization and TCAM prefix-mask generation.

The paper stores each priority as an INT-32 TCAM row (Sec. 4.2: "Each
priority entry is represented with INT-32 bits"). We mirror that exactly:
priorities in ``[0, v_max]`` are mapped to non-negative int32 fixed point
with ``frac_bits`` fractional bits relative to ``v_max``:

    q(p) = round(p / v_max * 2**frac_bits)

``frac_bits`` defaults to 24 so that group radii ``Delta_i`` (Eqn. 4) and
bit masks never overflow the positive int32 range even for v_max-sized
values, while retaining ~1.5e-8 * v_max resolution -- far below any
TD-error noise floor.

The prefix-based query strategy (Fig. 6(b2)) is reproduced bit-exactly:
given a radius ``delta`` the mask generator finds the position ``p`` of the
leftmost '1' in ``delta`` and declares bit ``p`` and everything below it
don't-care.  A stored word matches iff its remaining (prefix) bits equal
the query's:  ``(stored ^ query) & ~mask == 0``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

DEFAULT_FRAC_BITS = 24


def quantize(p: jax.Array, v_max: float, frac_bits: int = DEFAULT_FRAC_BITS) -> jax.Array:
    """Map float priorities in [0, v_max] to int32 fixed point.

    The top code is 2**frac_bits - 1 (all ones), NOT 2**frac_bits: a
    saturated priority must remain inside the largest prefix-aligned
    block below the range ceiling, otherwise v_max-clipped priorities sit
    one past every possible TCAM prefix query and become unmatchable
    (observed as INVERTED prioritization in the DQN integration).
    """
    top = (1 << frac_bits) - 1
    scale = top / v_max
    q = jnp.round(jnp.clip(p, 0.0, v_max) * scale)
    return jnp.minimum(q, top).astype(jnp.int32)


def dequantize(q: jax.Array, v_max: float, frac_bits: int = DEFAULT_FRAC_BITS) -> jax.Array:
    """Inverse of :func:`quantize` (up to rounding)."""
    scale = v_max / ((1 << frac_bits) - 1)
    return q.astype(jnp.float32) * scale


def prefix_mask(delta: jax.Array) -> jax.Array:
    """Don't-care mask for radius ``delta`` (int32), per Fig. 6(b2).

    Bits at and below the leftmost '1' of ``delta`` are don't-care (mask=1).
    ``delta == 0`` yields mask 0 (exact match).  Matches the paper's OR-gate
    mask generator: for an 8-bit example with leftmost '1' at position 4,
    the mask is 0001_1111.
    """
    delta = delta.astype(jnp.int32)
    nbits = 32
    # position of leftmost '1'; clz(0) == 32 -> p_pos == -1 -> mask == 0.
    p_pos = (nbits - 1) - jax.lax.clz(jnp.maximum(delta, 0))
    # (1 << (p_pos + 1)) - 1, guarded for p_pos == -1 and p_pos == 31.
    shifted = jnp.where(p_pos >= 31, jnp.int32(-1), (jnp.int32(1) << (p_pos + 1)) - 1)
    return jnp.where(delta <= 0, jnp.int32(0), shifted)


def ternary_match(stored: jax.Array, query: jax.Array, mask: jax.Array) -> jax.Array:
    """Exact-match TCAM semantics with don't-care bits.

    ``stored`` is any int32 array; ``query``/``mask`` broadcast against it.
    A row matches iff every non-masked bit XNORs to 1.
    """
    return jnp.bitwise_and(jnp.bitwise_xor(stored, query), jnp.bitwise_not(mask)) == 0


def prefix_range(query: jax.Array, mask: jax.Array) -> tuple[jax.Array, jax.Array]:
    """[lo, hi] int32 range accepted by a prefix query (inclusive).

    Useful for reasoning about the power-of-2 approximation error: the
    accepted range is ``[query & ~mask, (query & ~mask) | mask]``.
    """
    lo = jnp.bitwise_and(query, jnp.bitwise_not(mask))
    hi = jnp.bitwise_or(lo, mask)
    return lo, hi
