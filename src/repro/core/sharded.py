"""Multi-device AMPER: priorities sharded over the mesh (shard_map).

At production scale the replay/priority table does not fit one device
(e.g. 2^30 sequence priorities = 4 GiB of int32 plus the experiences
themselves), and the sampling step must not funnel the table through one
host.  AMPER's structure makes the distributed version embarrassingly
cheap — this is the paper's insight transferring to the *mesh* level:

  * the m ternary-match queries are pure map operations -> run locally on
    each shard, zero communication;
  * stream compaction is local;
  * the only global state is the per-shard match COUNT (one int32 per
    shard -> all_gather of 4 bytes * shards);
  * batch selection maps each uniform draw to (shard, offset) via the
    gathered count prefix-sum; each element is owned by exactly one shard
    and materialised with a psum.

Total communication per sampled batch: one all-gather of shard counts and
one psum of the b selected indices — O(shards + b) scalars, versus the
sum-tree's O(b log n) serialised dependent lookups.  A sum tree cannot be
sharded this way at all: every descent touches the root.

Contrast baseline :func:`sharded_sample_per` (cumsum PER) is provided for
the benchmarks: it needs the global prefix-sum of all n priorities (an
expensive scan across shards) — implemented hierarchically (local cumsum +
all_gather of shard totals) which is the best-known vector form.
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

import repro.core.quantize as qz
from repro.core.amper import AmperConfig, fr_queries, fr_radii, group_representatives
from repro.distributed.sharding import axis_size


def _flat_axis_index(axis_names: Sequence[str]) -> jax.Array:
    """Row-major linear index of this shard over possibly-multiple mesh axes."""
    idx = jnp.int32(0)
    for name in axis_names:
        idx = idx * axis_size(name) + jax.lax.axis_index(name)
    return idx


def _n_shards(axis_names: Sequence[str]) -> jax.Array:
    n = jnp.int32(1)
    for name in axis_names:
        n = n * axis_size(name)
    return n


def _local_match_fr(pq_local: jax.Array, valid_local: jax.Array, v_rep: jax.Array,
                    cfg: AmperConfig) -> jax.Array:
    """m-query ternary match on this shard's slice (no communication)."""
    if cfg.fr_mode == "interval":
        from repro.core.amper import _interval_membership, fr_intervals
        lo, hi = fr_intervals(v_rep, cfg)
        return _interval_membership(pq_local, lo, hi) & valid_local
    if cfg.fr_mode == "window":
        from repro.core.amper import _window_membership, fr_intervals
        lo, hi = fr_intervals(v_rep, cfg)
        return _window_membership(pq_local, lo, hi, cfg) & valid_local
    if cfg.exact_radius:
        vq = qz.quantize(v_rep, cfg.v_max, cfg.frac_bits)
        radius = fr_radii(v_rep, cfg)
        match = jnp.abs(pq_local[None, :] - vq[:, None]) <= radius[:, None]
    else:
        vq, mask = fr_queries(v_rep, cfg)
        match = qz.ternary_match(pq_local[None, :], vq[:, None], mask[:, None])
    return jnp.any(match, axis=0) & valid_local


def sharded_sample_fr(mesh: jax.sharding.Mesh, cfg: AmperConfig, batch: int,
                      axis_names: Sequence[str] = ("pod", "data"),
                      local_csp_capacity: int | None = None):
    """Build a jit-able sharded AMPER-fr sampler over ``mesh``.

    Returns fn(pq, valid, key) -> int32[batch] global indices, where pq and
    valid are sharded over ``axis_names`` on their leading dim.
    """
    axis_names = tuple(a for a in axis_names if a in mesh.axis_names)
    local_cap = local_csp_capacity or max(cfg.csp_capacity // max(
        functools.reduce(lambda a, b: a * b,
                         (mesh.shape[a] for a in axis_names), 1), 1), 1)

    def body(pq_local, valid_local, key):
        n_local = pq_local.shape[0]
        kq, kpick = jax.random.split(key)
        v_rep = group_representatives(kq, cfg)  # identical on all shards
        selected = _local_match_fr(pq_local, valid_local, v_rep, cfg)
        (loc_idx,) = jnp.nonzero(selected, size=local_cap, fill_value=0)
        loc_count = jnp.minimum(jnp.sum(selected.astype(jnp.int32)), local_cap)

        counts = jax.lax.all_gather(loc_count, axis_names, tiled=False)
        counts = counts.reshape(-1)  # (n_shards,)
        cum = jnp.cumsum(counts)
        total = cum[-1]

        # Identical draws on every shard (same key): u in [0, total).
        u = jax.random.randint(kpick, (batch,), 0, jnp.maximum(total, 1))
        owner = jnp.searchsorted(cum, u, side="right").astype(jnp.int32)
        start = cum - counts  # exclusive prefix
        offset = u - start[jnp.clip(owner, 0, counts.shape[0] - 1)]

        me = _flat_axis_index(axis_names)
        mine = owner == me
        local_pick = loc_idx[jnp.clip(offset, 0, local_cap - 1)].astype(jnp.int32)
        contrib = jnp.where(mine, local_pick + me * n_local, 0)
        picked = jax.lax.psum(contrib, axis_names)

        # Fallback: empty CSP -> uniform over the global table.
        fb = jax.random.randint(kpick, (batch,), 0, n_local * _n_shards(axis_names))
        return jnp.where(total > 0, picked, fb).astype(jnp.int32)

    spec = P(axis_names)
    return shard_map(
        body, mesh=mesh,
        in_specs=(spec, spec, P()),
        out_specs=P(),
        check_rep=False,
    )


def sharded_sample_per(mesh: jax.sharding.Mesh, batch: int,
                       axis_names: Sequence[str] = ("pod", "data")):
    """Contrast baseline: hierarchical cumsum PER on the same sharded table.

    Local prefix-sum + all_gather of shard totals + global draw -> each
    shard binary-searches the draws that land in its range.
    """
    axis_names = tuple(a for a in axis_names if a in mesh.axis_names)

    def body(p_local, key):
        n_local = p_local.shape[0]
        local_cum = jnp.cumsum(p_local)
        local_total = local_cum[-1]
        totals = jax.lax.all_gather(local_total, axis_names, tiled=False).reshape(-1)
        cum_tot = jnp.cumsum(totals)
        grand = jnp.maximum(cum_tot[-1], 1e-12)

        u = jax.random.uniform(key, (batch,)) * grand
        owner = jnp.searchsorted(cum_tot, u, side="right").astype(jnp.int32)
        start = cum_tot - totals
        me = _flat_axis_index(axis_names)
        mine = owner == me
        local_u = u - start[jnp.clip(owner, 0, totals.shape[0] - 1)]
        loc = jnp.searchsorted(local_cum, local_u, side="right")
        loc = jnp.clip(loc, 0, n_local - 1).astype(jnp.int32)
        contrib = jnp.where(mine, loc + me * n_local, 0)
        return jax.lax.psum(contrib, axis_names).astype(jnp.int32)

    spec = P(axis_names)
    return shard_map(body, mesh=mesh, in_specs=(spec, P()), out_specs=P(),
                     check_rep=False)
