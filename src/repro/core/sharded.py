"""Sharded replay: AMPER/PER priority sampling over a ``jax.sharding.Mesh``.

At production scale the replay/priority table does not fit one device
(e.g. 2^30 sequence priorities = 4 GiB of int32 plus the experiences
themselves), and the sampling step must not funnel the table through one
host.  AMPER's structure makes the distributed version embarrassingly
cheap — this is the paper's insight transferring to the *mesh* level:

  * the m ternary-match queries are pure map operations -> run locally on
    each shard, zero communication;
  * stream compaction is local;
  * the only global state is the per-shard match COUNT (one int32 per
    shard -> all_gather of 4 bytes * shards);
  * batch selection maps each uniform draw to (shard, offset) via the
    gathered count prefix-sum; each element is owned by exactly one shard
    and materialised with a psum.

Total communication per sampled batch: one all-gather of shard counts and
one psum of the b selected indices — O(shards + b) scalars, versus the
sum-tree's O(b log n) serialised dependent lookups.  A sum tree cannot be
sharded this way at all: every descent touches the root.

Contrast baseline: hierarchical cumsum PER on the same sharded table.  It
needs the global prefix-sum of all n priorities — implemented as local
cumsum + all_gather of shard totals, the best-known vector form.

Two access levels:

* :func:`sharded_sample_fr` / :func:`sharded_sample_per` — free-standing
  jit-able sampling functions (the raw sampling law, used by the
  benchmarks and the low-level tests).

* :class:`ShardedAmperSampler` / :class:`ShardedPERSampler` — full
  five-method :class:`repro.core.samplers.Sampler` implementations whose
  state lives sharded on the mesh (``with_sharding_constraint`` keeps the
  priority table distributed through init and the scatter updates).  They
  are registered as ``"amper-fr-sharded"`` / ``"per-sharded"`` in
  :mod:`repro.core.samplers`, so the replay buffer, the DQN agent and the
  benchmarks construct them through the one ``make_sampler`` seam.

The per-shard match path honours every ``AmperConfig.fr_mode`` including
``"kernel"``: the fused Pallas :func:`repro.kernels.ops.multi_query_match`
kernel runs on each shard's local slice (one HBM pass per shard; interpret
mode off-TPU), i.e. the paper's TCAM search executes inside the sampling
pipeline, sharded.  ``fr_mode="fused"`` additionally replaces each shard's
``nonzero``-compaction + gather pick with the streaming
:func:`repro.kernels.ops.rank_select` kernel — same draws, same owners,
bit-identical indices, one pass instead of a materialised index buffer.
"""
from __future__ import annotations

import functools
import operator
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

import repro.core.quantize as qz
from repro.core.amper import (AmperConfig, AmperSampler, AmperState,
                              fr_intervals, fr_queries, fr_radii,
                              group_representatives)
from repro.distributed.sharding import axis_size


def _flat_axis_index(axis_names: Sequence[str]) -> jax.Array:
    """Row-major linear index of this shard over possibly-multiple mesh axes."""
    idx = jnp.int32(0)
    for name in axis_names:
        idx = idx * axis_size(name) + jax.lax.axis_index(name)
    return idx


def _n_shards(axis_names: Sequence[str]) -> jax.Array:
    n = jnp.int32(1)
    for name in axis_names:
        n = n * axis_size(name)
    return n


def resolve_axes(mesh: Mesh, axis_names: Sequence[str]) -> tuple[str, ...]:
    """The subset of ``axis_names`` present on ``mesh`` (order preserved)."""
    axes = tuple(a for a in axis_names if a in mesh.axis_names)
    if not axes:
        raise ValueError(
            f"none of the sharding axes {tuple(axis_names)} exist on mesh "
            f"axes {mesh.axis_names}")
    return axes


def _mesh_shards(mesh: Mesh, axes: Sequence[str]) -> int:
    return int(functools.reduce(operator.mul,
                                (mesh.shape[a] for a in axes), 1))


def _local_match_fr(pq_local: jax.Array, valid_local: jax.Array, v_rep: jax.Array,
                    cfg: AmperConfig) -> jax.Array:
    """m-query ternary match on this shard's slice (no communication)."""
    if cfg.fr_mode == "interval":
        from repro.core.amper import _interval_membership
        lo, hi = fr_intervals(v_rep, cfg)
        return _interval_membership(pq_local, lo, hi) & valid_local
    if cfg.fr_mode == "window":
        from repro.core.amper import _window_membership
        lo, hi = fr_intervals(v_rep, cfg)
        return _window_membership(pq_local, lo, hi, cfg) & valid_local
    if cfg.fr_mode in ("kernel", "fused"):
        # Fused Pallas kernel: all m range queries in ONE pass over this
        # shard's slice of HBM (interpret mode off-TPU).  A prefix query
        # with don't-care mask M is exactly the range [q&~M, (q&~M)|M],
        # so membership is bit-identical to the broadcast mode.
        from repro.kernels import ops as kops
        lo, hi = fr_intervals(v_rep, cfg)
        sel, _counts = kops.multi_query_match(pq_local, valid_local, lo, hi)
        return sel
    if cfg.exact_radius:
        vq = qz.quantize(v_rep, cfg.v_max, cfg.frac_bits)
        radius = fr_radii(v_rep, cfg)
        match = jnp.abs(pq_local[None, :] - vq[:, None]) <= radius[:, None]
    else:
        vq, mask = fr_queries(v_rep, cfg)
        match = qz.ternary_match(pq_local[None, :], vq[:, None], mask[:, None])
    return jnp.any(match, axis=0) & valid_local


def _fr_sample_body(cfg: AmperConfig, batch: int, axis_names: tuple[str, ...],
                    local_cap: int):
    """The per-shard AMPER-fr sampling program (shared by the free function
    and :class:`ShardedAmperSampler`)."""

    def body(pq_local, valid_local, key):
        n_local = pq_local.shape[0]
        kq, kpick = jax.random.split(key)
        kpick, kfb = jax.random.split(kpick)  # fallback gets its OWN key
        v_rep = group_representatives(kq, cfg)  # identical on all shards
        if cfg.fr_mode == "fused":
            # Fused pick: the rank-select kernel turns each owned draw
            # straight into its member index in one pass over the shard's
            # slice — no compacted index buffer.  Membership (and hence
            # counts, owners, offsets) reuses the multi-query kernel, so
            # the whole draw is bit-identical to the reference modes:
            # rank r in index order IS nonzero(selected)[r].
            from repro.kernels import ops as kops
            selected = _local_match_fr(pq_local, valid_local, v_rep, cfg)
            loc_count = jnp.minimum(
                jnp.sum(selected.astype(jnp.int32)), local_cap)

            def pick_local(offset):
                lo, hi = fr_intervals(v_rep, cfg)
                idx, _cnt = kops.rank_select(pq_local, valid_local, lo, hi,
                                             offset)
                return idx
        else:
            selected = _local_match_fr(pq_local, valid_local, v_rep, cfg)
            (loc_idx,) = jnp.nonzero(selected, size=local_cap, fill_value=0)
            loc_count = jnp.minimum(
                jnp.sum(selected.astype(jnp.int32)), local_cap)

            def pick_local(offset):
                return loc_idx[jnp.clip(offset, 0, local_cap - 1)]

        counts = jax.lax.all_gather(loc_count, axis_names, tiled=False)
        counts = counts.reshape(-1)  # (n_shards,)
        cum = jnp.cumsum(counts)
        total = cum[-1]

        # Identical draws on every shard (same key): u in [0, total).
        u = jax.random.randint(kpick, (batch,), 0, jnp.maximum(total, 1))
        owner = jnp.searchsorted(cum, u, side="right").astype(jnp.int32)
        start = cum - counts  # exclusive prefix
        offset = u - start[jnp.clip(owner, 0, counts.shape[0] - 1)]

        me = _flat_axis_index(axis_names)
        mine = owner == me
        local_pick = pick_local(offset).astype(jnp.int32)
        contrib = jnp.where(mine, local_pick + me * n_local, 0)
        picked = jax.lax.psum(contrib, axis_names)

        # Fallback: empty CSP -> uniform over the global table.
        fb = jax.random.randint(kfb, (batch,), 0, n_local * _n_shards(axis_names))
        return jnp.where(total > 0, picked, fb).astype(jnp.int32)

    return body


def _local_csp_capacity(mesh: Mesh, axes: Sequence[str], cfg: AmperConfig,
                        override: int | None) -> int:
    if override is not None:
        return override
    return max(cfg.csp_capacity // max(_mesh_shards(mesh, axes), 1), 1)


def sharded_sample_fr(mesh: Mesh, cfg: AmperConfig, batch: int,
                      axis_names: Sequence[str] = ("pod", "data"),
                      local_csp_capacity: int | None = None):
    """Build a jit-able sharded AMPER-fr sampler over ``mesh``.

    Returns fn(pq, valid, key) -> int32[batch] global indices, where pq and
    valid are sharded over ``axis_names`` on their leading dim.
    """
    axes = resolve_axes(mesh, axis_names)
    local_cap = _local_csp_capacity(mesh, axes, cfg, local_csp_capacity)
    spec = P(axes)
    return shard_map(
        _fr_sample_body(cfg, batch, axes, local_cap), mesh=mesh,
        in_specs=(spec, spec, P()),
        out_specs=P(),
        check_rep=False,
    )


def _per_sample_body(batch: int, axis_names: tuple[str, ...]):
    """Per-shard hierarchical-cumsum PER sampling program."""

    def body(p_local, key):
        n_local = p_local.shape[0]
        local_cum = jnp.cumsum(p_local)
        local_total = local_cum[-1]
        totals = jax.lax.all_gather(local_total, axis_names, tiled=False).reshape(-1)
        cum_tot = jnp.cumsum(totals)
        grand = jnp.maximum(cum_tot[-1], 1e-12)

        u = jax.random.uniform(key, (batch,)) * grand
        owner = jnp.searchsorted(cum_tot, u, side="right").astype(jnp.int32)
        start = cum_tot - totals
        me = _flat_axis_index(axis_names)
        mine = owner == me
        local_u = u - start[jnp.clip(owner, 0, totals.shape[0] - 1)]
        loc = jnp.searchsorted(local_cum, local_u, side="right")
        loc = jnp.clip(loc, 0, n_local - 1).astype(jnp.int32)
        contrib = jnp.where(mine, loc + me * n_local, 0)
        return jax.lax.psum(contrib, axis_names).astype(jnp.int32)

    return body


def sharded_sample_per(mesh: Mesh, batch: int,
                       axis_names: Sequence[str] = ("pod", "data")):
    """Contrast baseline: hierarchical cumsum PER on the same sharded table.

    Local prefix-sum + all_gather of shard totals + global draw -> each
    shard binary-searches the draws that land in its range.
    """
    axes = resolve_axes(mesh, axis_names)
    spec = P(axes)
    return shard_map(_per_sample_body(batch, axes), mesh=mesh,
                     in_specs=(spec, P()), out_specs=P(),
                     check_rep=False)


def repartition(sampler, state):
    """Move a sampler state onto ``sampler``'s mesh placement.

    The elastic-restore primitive: a state that lives dense on host, on
    one device, or partitioned over a DIFFERENT shard count is device_put
    leaf-by-leaf with the target sampler's capacity-dim ``NamedSharding``
    — values (and therefore CSP membership / sampling law) are unchanged,
    only the partitioning moves.  Works for any sampler exposing
    ``.sharding``; for unsharded samplers it is the identity.
    """
    sh = getattr(sampler, "sharding", None)
    if sh is None:
        return state
    return jax.tree.map(lambda x: jax.device_put(jnp.asarray(x), sh), state)


# --- mesh-native Sampler implementations -------------------------------------


class ShardedAmperSampler(AmperSampler):
    """AMPER-fr with the priority table sharded over a mesh.

    Implements the five-method :class:`repro.core.samplers.Sampler`
    protocol; state arrays carry a ``NamedSharding`` over ``axis_names``
    on their leading (capacity) dim, maintained through :meth:`init` and
    the :meth:`update` scatter by ``with_sharding_constraint``.  Sampling
    runs the O(shards + batch)-communication law of
    :func:`sharded_sample_fr`; :meth:`priorities` / :meth:`total` are the
    dense views the replay buffer's importance weights need (XLA keeps
    them distributed — the table is never funnelled through one host).

    Registry name: ``"amper-fr-sharded"``.
    """

    def __init__(self, cfg: AmperConfig, mesh: Mesh,
                 axis_names: Sequence[str] = ("pod", "data"),
                 local_csp_capacity: int | None = None):
        super().__init__(cfg, variant="fr")
        self.mesh = mesh
        self.axis_names = resolve_axes(mesh, axis_names)
        self.n_shards = _mesh_shards(mesh, self.axis_names)
        if cfg.capacity % self.n_shards:
            raise ValueError(
                f"capacity {cfg.capacity} not divisible by the "
                f"{self.n_shards} shards of mesh axes {self.axis_names}")
        self.spec = P(self.axis_names)
        self.sharding = NamedSharding(mesh, self.spec)
        self.local_csp_capacity = _local_csp_capacity(
            mesh, self.axis_names, cfg, local_csp_capacity)
        self._sample_fns: dict[int, callable] = {}

    def _shard(self, x: jax.Array) -> jax.Array:
        return jax.lax.with_sharding_constraint(x, self.sharding)

    def init(self) -> AmperState:
        st = super().init()
        return AmperState(pq=self._shard(st.pq), valid=self._shard(st.valid))

    def update(self, state: AmperState, idx: jax.Array,
               priority: jax.Array) -> AmperState:
        st = super().update(state, idx, priority)
        return AmperState(pq=self._shard(st.pq), valid=self._shard(st.valid))

    def _sample_fn(self, batch: int):
        fn = self._sample_fns.get(batch)
        if fn is None:
            fn = shard_map(
                _fr_sample_body(self.cfg, batch, self.axis_names,
                                self.local_csp_capacity),
                mesh=self.mesh,
                in_specs=(self.spec, self.spec, P()), out_specs=P(),
                check_rep=False)
            self._sample_fns[batch] = fn
        return fn

    def sample(self, state: AmperState, key: jax.Array, batch: int,
               stratified: bool = True) -> jax.Array:
        del stratified  # CSP sampling is uniform by construction
        from repro.obs import span  # deferred: keep core import-light

        # No-op under jit; times the eager sharded dispatch path.
        with span("sharded_sample"):
            return self._sample_fn(batch)(state.pq, state.valid, key)

    def membership(self, state: AmperState, key: jax.Array) -> jax.Array:
        """Global bool[capacity] CSP membership for ``key`` (test/analysis
        hook; bit-identical to ``build_csp_fr(...).selected`` single-device)."""

        def body(pq_local, valid_local, k):
            kq, _ = jax.random.split(k)
            v_rep = group_representatives(kq, self.cfg)
            return _local_match_fr(pq_local, valid_local, v_rep, self.cfg)

        fn = shard_map(body, mesh=self.mesh,
                       in_specs=(self.spec, self.spec, P()),
                       out_specs=self.spec, check_rep=False)
        return fn(state.pq, state.valid, key)


class ShardedPERState(NamedTuple):
    priorities: jax.Array  # float32[capacity], sharded on the leading dim


class ShardedPERSampler:
    """Hierarchical-cumsum PER with the priority table sharded over a mesh.

    The contrast baseline to :class:`ShardedAmperSampler` at mesh scale:
    sampling needs the global prefix structure, realised as local cumsum +
    all_gather of shard totals (O(n/shards) local work, O(shards) comms).
    Same five-method protocol; registry name ``"per-sharded"``.  Draws are
    non-stratified (each shard consumes the identical global uniforms).
    """

    def __init__(self, capacity: int, mesh: Mesh,
                 axis_names: Sequence[str] = ("pod", "data")):
        self.capacity = capacity
        self.mesh = mesh
        self.axis_names = resolve_axes(mesh, axis_names)
        self.n_shards = _mesh_shards(mesh, self.axis_names)
        if capacity % self.n_shards:
            raise ValueError(
                f"capacity {capacity} not divisible by the "
                f"{self.n_shards} shards of mesh axes {self.axis_names}")
        self.spec = P(self.axis_names)
        self.sharding = NamedSharding(mesh, self.spec)
        self._sample_fns: dict[int, callable] = {}

    def _shard(self, x: jax.Array) -> jax.Array:
        return jax.lax.with_sharding_constraint(x, self.sharding)

    def init(self) -> ShardedPERState:
        return ShardedPERState(
            priorities=self._shard(jnp.zeros(self.capacity, jnp.float32)))

    def total(self, state: ShardedPERState) -> jax.Array:
        return jnp.sum(state.priorities)

    def priorities(self, state: ShardedPERState) -> jax.Array:
        return state.priorities

    def update(self, state: ShardedPERState, idx: jax.Array,
               priority: jax.Array) -> ShardedPERState:
        return ShardedPERState(priorities=self._shard(
            state.priorities.at[idx].set(priority.astype(jnp.float32))))

    def _sample_fn(self, batch: int):
        fn = self._sample_fns.get(batch)
        if fn is None:
            fn = shard_map(_per_sample_body(batch, self.axis_names),
                           mesh=self.mesh, in_specs=(self.spec, P()),
                           out_specs=P(), check_rep=False)
            self._sample_fns[batch] = fn
        return fn

    def sample(self, state: ShardedPERState, key: jax.Array, batch: int,
               stratified: bool = True) -> jax.Array:
        del stratified  # sharded law draws global (non-stratified) uniforms
        return self._sample_fn(batch)(state.priorities, key)
