"""AMPER: associative-memory-based prioritized experience replay (Algorithm 1).

Implements both paper variants as shape-static, jit/shard-friendly JAX:

* :func:`build_csp_fr` -- AMPER-fr: one ternary prefix match per group
  (Fig. 6(b2)/(c)), the faithful TPU mapping of the exact-match TCAM search.
  ``exact_radius=True`` swaps the power-of-2 prefix approximation for an
  exact ``|p - V| <= Delta`` range compare at identical vector cost — the
  beyond-paper variant (a VPU, unlike a TCAM, range-compares for free).

* :func:`build_csp_k` -- AMPER-k: the N_i nearest stored priorities per
  group representative (Eqn. 1).  The oracle path selects via a full sort;
  the fast path (`knn_mode="bisect"`) finds a per-group radius by bisecting
  on the count returned by parallel range matches — the TPU-native
  replacement for the paper's k sequential best-match TCAM sensings.

The CSP is a fixed-capacity index buffer (stream compaction with
``jnp.nonzero(size=...)``), so the whole sampler jits, vmaps and shards.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

import repro.core.quantize as qz


class AmperConfig(NamedTuple):
    """Hyper-parameters of Algorithm 1.

    Attributes:
      capacity: replay size n (number of priority rows).
      m: number of groups (paper sweeps 2..20; Fig. 9 uses 20).
      lam: scaling factor (lambda) for AMPER-k, Eqn. 1.
      lam_fr: scaling factor (lambda') for AMPER-fr, Eqn. 4.
      v_max: static maximum priority value V_max.
      csp_capacity: static CSP buffer size (paper: CSP ratio * capacity;
        Fig. 9 uses ratio 0.15).
      frac_bits: fixed-point fraction bits for int32 quantization.
      exact_radius: AMPER-fr only — use exact range compare instead of the
        prefix-mask power-of-2 approximation (beyond-paper mode).
      knn_mode: "sort" (oracle top-N_i), "bisect" (radius bisection) or
        "hist" (shared cumulative histogram — 2 table passes).
      fr_mode: "broadcast" ((m,N) compare, the faithful m-query search),
        "interval" (merged-interval stabbing, one table pass), "window"
        (per-row neighbour-group gather, O(ceil(2*lam')) ops/row),
        "kernel" (fused Pallas multi-query kernel, one HBM pass;
        interpret mode off-TPU) or "fused" (the whole draw — match, CSP
        count, threefry pick, rank gather — in ONE Pallas dispatch via
        :func:`repro.kernels.ops.amper_sample`; membership queries fall
        back to the "kernel" path).  All five produce bit-identical CSP
        membership, sampled indices and importance weights.
    """

    capacity: int
    m: int = 20
    lam: float = 0.05
    lam_fr: float = 1.0
    v_max: float = 1.0
    csp_capacity: int = 1500
    frac_bits: int = qz.DEFAULT_FRAC_BITS
    exact_radius: bool = False
    knn_mode: str = "sort"
    fr_mode: str = "broadcast"


class CspResult(NamedTuple):
    """Stream-compacted candidate set of priorities."""

    indices: jax.Array  # int32[csp_capacity], -1 padded
    count: jax.Array    # int32 scalar, number of valid entries
    selected: jax.Array  # bool[capacity] membership mask (for analysis/tests)


def group_representatives(key: jax.Array, cfg: AmperConfig) -> jax.Array:
    """Line 3 of Algorithm 1: V(g_i) ~ U[ V_max*i/m, V_max*(i+1)/m )."""
    i = jnp.arange(cfg.m, dtype=jnp.float32)
    lo = cfg.v_max * i / cfg.m
    width = cfg.v_max / cfg.m
    return lo + width * jax.random.uniform(key, (cfg.m,))


def group_counts(pq: jax.Array, valid: jax.Array, cfg: AmperConfig) -> jax.Array:
    """Line 5: C(g_i) — histogram of stored priorities over the m groups."""
    width_q = (1 << cfg.frac_bits) // cfg.m
    g = jnp.clip(pq // jnp.maximum(width_q, 1), 0, cfg.m - 1)
    return jnp.zeros(cfg.m, jnp.int32).at[g].add(valid.astype(jnp.int32))


def _compact(selected: jax.Array, csp_capacity: int,
             key: jax.Array | None = None) -> CspResult:
    """Stream compaction of a membership mask into a fixed-size index buffer.

    If the match count exceeds the buffer capacity, plain ``nonzero``
    keeps the lowest indices — a systematic bias toward whichever rows
    the hardware scans first.  With ``key`` we start the scan at a random
    rotation, so truncation drops a uniformly-random contiguous arc
    instead of always the same rows (unbiased in expectation).
    """
    n = selected.shape[0]
    if key is not None:
        shift = jax.random.randint(key, (), 0, n)
        rolled = jnp.roll(selected, -shift)
        (idx,) = jnp.nonzero(rolled, size=csp_capacity, fill_value=-1)
        idx = jnp.where(idx >= 0, (idx + shift) % n, -1)
    else:
        (idx,) = jnp.nonzero(selected, size=csp_capacity, fill_value=-1)
    count = jnp.minimum(jnp.sum(selected.astype(jnp.int32)), csp_capacity)
    return CspResult(indices=idx.astype(jnp.int32), count=count, selected=selected)


def fr_queries(v_rep: jax.Array, cfg: AmperConfig) -> tuple[jax.Array, jax.Array]:
    """AMPER-fr query generator (Fig. 6(b2)): (query, dont-care mask) per group.

    Delta_i = round(lambda'/m * V(g_i))   [Eqn. 4, in quantized units]
    mask_i  = bits at/below leading '1' of Delta_i.
    """
    vq = qz.quantize(v_rep, cfg.v_max, cfg.frac_bits)
    delta_q = jnp.round((cfg.lam_fr / cfg.m) * vq.astype(jnp.float32)).astype(jnp.int32)
    mask = qz.prefix_mask(delta_q)
    return vq, mask


def fr_radii(v_rep: jax.Array, cfg: AmperConfig) -> jax.Array:
    """Exact (non-power-of-2) radii for the beyond-paper range-compare mode."""
    vq = qz.quantize(v_rep, cfg.v_max, cfg.frac_bits)
    return jnp.round((cfg.lam_fr / cfg.m) * vq.astype(jnp.float32)).astype(jnp.int32)


def build_csp_fr(pq: jax.Array, valid: jax.Array, key: jax.Array,
                 cfg: AmperConfig) -> CspResult:
    """AMPER-fr CSP construction (Algorithm 1, lines 2-3, 9-12).

    Args:
      pq: int32[capacity] quantized priorities.
      valid: bool[capacity] — slot currently holds a real experience with
        non-zero priority.
      key: PRNG key for the group representatives.
    """
    if cfg.fr_mode in ("kernel", "fused"):
        # "fused" only differs on the *sampling* path (AmperSampler.sample
        # dispatches the whole draw as one kernel); explicit CSP builds
        # share the fused-membership kernel.
        return build_csp_fr_kernel(pq, valid, key, cfg)
    kv, kroll = jax.random.split(key)
    v_rep = group_representatives(kv, cfg)
    if cfg.fr_mode == "interval":
        lo, hi = fr_intervals(v_rep, cfg)
        selected = _interval_membership(pq, lo, hi) & valid
        return _compact(selected, cfg.csp_capacity, kroll)
    if cfg.fr_mode == "window":
        lo, hi = fr_intervals(v_rep, cfg)
        selected = _window_membership(pq, lo, hi, cfg) & valid
        return _compact(selected, cfg.csp_capacity, kroll)
    if cfg.exact_radius:
        vq = qz.quantize(v_rep, cfg.v_max, cfg.frac_bits)
        radius = fr_radii(v_rep, cfg)
        match = jnp.abs(pq[None, :] - vq[:, None]) <= radius[:, None]
    else:
        vq, mask = fr_queries(v_rep, cfg)
        match = qz.ternary_match(pq[None, :], vq[:, None], mask[:, None])
    selected = jnp.any(match, axis=0) & valid
    return _compact(selected, cfg.csp_capacity, kroll)


def knn_sizes(v_rep: jax.Array, counts: jax.Array, cfg: AmperConfig) -> jax.Array:
    """Eqn. 1: N_i = round(lambda * V(g_i) * C(g_i))."""
    return jnp.round(cfg.lam * v_rep * counts.astype(jnp.float32)).astype(jnp.int32)


def _knn_select_sort(pq: jax.Array, valid: jax.Array, vq: jax.Array,
                     n_i: jax.Array) -> jax.Array:
    """Oracle kNN: per group, mark the N_i nearest valid priorities.

    Returns bool[m, capacity].  Ties at the radius boundary are broken by
    index (stable sort), matching a deterministic hardware scan order.
    """
    big = jnp.int32(2**30)
    dist = jnp.abs(pq[None, :] - vq[:, None])
    dist = jnp.where(valid[None, :], dist, big)
    rank = jnp.argsort(jnp.argsort(dist, axis=1), axis=1)  # rank of each slot
    return (rank < n_i[:, None]) & valid[None, :]


def _knn_select_bisect(pq: jax.Array, valid: jax.Array, vq: jax.Array,
                       n_i: jax.Array, frac_bits: int) -> jax.Array:
    """TPU-native kNN: bisect on radius until count(|p-V|<=r) >= N_i.

    log2(range) parallel count passes replace the paper's N_i sequential
    best-match sensings.  Over-selection at the final radius is trimmed by
    index order so |subset| == N_i exactly.
    """
    big = jnp.int32(2**30)
    dist = jnp.where(valid[None, :], jnp.abs(pq[None, :] - vq[:, None]), big)

    def body(carry, _):
        lo, hi = carry  # int32[m] bounds on radius
        mid = (lo + hi) // 2
        cnt = jnp.sum(dist <= mid[:, None], axis=1)
        lo = jnp.where(cnt < n_i, mid + 1, lo)
        hi = jnp.where(cnt >= n_i, mid, hi)
        return (lo, hi), None

    lo = jnp.zeros_like(n_i)
    hi = jnp.full_like(n_i, 1 << frac_bits)
    (radius, _), _ = jax.lax.scan(body, (lo, hi), None, length=frac_bits + 1)
    within = dist <= radius[:, None]
    # Trim over-selection (ties at the radius): keep the first N_i by index.
    order = jnp.cumsum(within.astype(jnp.int32), axis=1)
    return within & (order <= n_i[:, None])


def fr_intervals(v_rep: jax.Array, cfg: AmperConfig) -> tuple[jax.Array, jax.Array]:
    """The m accepted ranges [lo_i, hi_i] of AMPER-fr (prefix or exact)."""
    vq = qz.quantize(v_rep, cfg.v_max, cfg.frac_bits)
    if cfg.exact_radius:
        r = fr_radii(v_rep, cfg)
        return vq - r, vq + r
    _, mask = fr_queries(v_rep, cfg)
    return qz.prefix_range(vq, mask)


def _interval_membership(pq: jax.Array, lo: jax.Array, hi: jax.Array) -> jax.Array:
    """Is pq inside the union of [lo_i, hi_i]?  One searchsorted pass.

    Interval-stabbing formulation: sort the 2m boundary events, prefix-sum
    the open/close weights to get coverage depth at each boundary, then a
    single binary search per row reads off whether its depth is > 0.
    O(N log m) compares and exactly one pass over the table — versus the
    (m, N) broadcast compare that materialises m bitmasks.  This is the
    roofline-floor version of the TCAM search for the selection-only
    (AMPER-fr) case.
    """
    m = lo.shape[0]
    # events: +1 at lo, -1 at hi+1
    pts = jnp.concatenate([lo, hi + 1])
    wts = jnp.concatenate([jnp.ones(m, jnp.int32), -jnp.ones(m, jnp.int32)])
    order = jnp.argsort(pts)
    pts, wts = pts[order], wts[order]
    depth = jnp.cumsum(wts)  # coverage depth AFTER each event point
    idx = jnp.searchsorted(pts, pq, side="right") - 1
    return jnp.where(idx >= 0, depth[jnp.clip(idx, 0, 2 * m - 1)] > 0, False)


def _window_membership(pq: jax.Array, lo: jax.Array, hi: jax.Array,
                       cfg: AmperConfig) -> jax.Array:
    """Neighbour-window membership: O(ceil(2*lam')) ops/row, no (m,N) temps.

    Group i's accepted block has width <= 2*Delta_i <= 2*lam'*group_width
    and contains V(g_i) which lies IN group i, so a row in value-group g
    can only be matched by groups within ceil(2*lam') of g.  Gather those
    2c+1 candidate bounds per row and compare — the (m, N) broadcast the
    faithful search materialises never exists.
    """
    m = cfg.m
    width_q = max((1 << cfg.frac_bits) // m, 1)
    g = jnp.clip(pq // width_q, 0, m - 1)
    c = int(-(-2 * cfg.lam_fr // 1))  # ceil(2*lam')
    sel = jnp.zeros(pq.shape, jnp.bool_)
    for j in range(-c, c + 1):
        gi = jnp.clip(g + j, 0, m - 1)
        sel = sel | ((pq >= lo[gi]) & (pq <= hi[gi]))
    return sel


def build_csp_fr_kernel(pq: jax.Array, valid: jax.Array, key: jax.Array,
                        cfg: AmperConfig) -> CspResult:
    """AMPER-fr via the fused Pallas multi-query kernel (one HBM pass).

    Bit-identical to :func:`build_csp_fr`: a prefix query with don't-care
    mask M is exactly the inclusive range [q & ~M, (q & ~M) | M].
    """
    from repro.kernels import ops as kops  # deferred: kernels are optional

    kv, kroll = jax.random.split(key)
    v_rep = group_representatives(kv, cfg)
    if cfg.exact_radius:
        vq = qz.quantize(v_rep, cfg.v_max, cfg.frac_bits)
        radius = fr_radii(v_rep, cfg)
        lo, hi = vq - radius, vq + radius
    else:
        vq, mask = fr_queries(v_rep, cfg)
        lo, hi = qz.prefix_range(vq, mask)
    sel, _counts = kops.multi_query_match(pq, valid, lo, hi)
    return _compact(sel, cfg.csp_capacity, kroll)


def _knn_select_hist(pq: jax.Array, valid: jax.Array, vq: jax.Array,
                     n_i: jax.Array, frac_bits: int,
                     hist_bins: int = 4096) -> jax.Array:
    """Histogram kNN: ~2 passes over the table instead of ~26.

    One shared cumulative VALUE histogram F (single pass over pq) turns
    count(|p - V| <= r) into F(V+r) - F(V-r): the per-group radius
    bisection then runs on 4 KiB of histogram instead of re-scanning the
    table per probe.  One final match pass selects; over-selection from
    bin granularity is trimmed by scan order so |subset| == N_i exactly.
    """
    top = 1 << frac_bits
    shift = frac_bits - (hist_bins.bit_length() - 1)
    bucket = jnp.clip(pq >> shift, 0, hist_bins - 1)
    hist = jnp.zeros(hist_bins, jnp.int32).at[bucket].add(valid.astype(jnp.int32))
    cum = jnp.cumsum(hist)  # F(b) = count of pq with bucket <= b

    def count_within(radius):
        # LOWER bound: count only buckets fully inside [V-r, V+r], so the
        # bisected radius can only over-select; the exact trim below then
        # cuts back to N_i precisely.
        binsz = 1 << shift
        lo_b = jnp.clip((vq - radius + binsz - 1) >> shift, 0, hist_bins)
        hi_b = jnp.clip(((vq + radius + 1) >> shift) - 1, -1, hist_bins - 1)
        below = jnp.where(lo_b > 0, cum[jnp.clip(lo_b - 1, 0, hist_bins - 1)], 0)
        inside = cum[jnp.clip(hi_b, 0, hist_bins - 1)] - below
        return jnp.where(hi_b >= lo_b, inside, 0)

    def body(carry, _):
        lo, hi = carry
        mid = (lo + hi) // 2
        cnt = count_within(mid)
        lo = jnp.where(cnt < n_i, mid + 1, lo)
        hi = jnp.where(cnt >= n_i, mid, hi)
        return (lo, hi), None

    lo = jnp.zeros_like(n_i)
    hi = jnp.full_like(n_i, top)
    (radius, _), _ = jax.lax.scan(body, (lo, hi), None, length=frac_bits + 1)
    big = jnp.int32(2**30)
    dist = jnp.where(valid[None, :], jnp.abs(pq[None, :] - vq[:, None]), big)
    within = dist <= radius[:, None]
    order = jnp.cumsum(within.astype(jnp.int32), axis=1)
    return within & (order <= n_i[:, None])


def build_csp_k(pq: jax.Array, valid: jax.Array, key: jax.Array,
                cfg: AmperConfig) -> CspResult:
    """AMPER-k CSP construction (Algorithm 1, lines 2-8)."""
    kv, kroll = jax.random.split(key)
    v_rep = group_representatives(kv, cfg)
    vq = qz.quantize(v_rep, cfg.v_max, cfg.frac_bits)
    counts = group_counts(pq, valid, cfg)
    n_i = knn_sizes(v_rep, counts, cfg)
    if cfg.knn_mode == "bisect":
        sel = _knn_select_bisect(pq, valid, vq, n_i, cfg.frac_bits)
    elif cfg.knn_mode == "hist":
        sel = _knn_select_hist(pq, valid, vq, n_i, cfg.frac_bits)
    else:
        sel = _knn_select_sort(pq, valid, vq, n_i)
    selected = jnp.any(sel, axis=0) & valid
    return _compact(selected, cfg.csp_capacity, kroll)


def pick_uniform(bits: jax.Array, bound) -> jax.Array:
    """Uniform int32 draw in [0, max(bound, 1)) from raw uint32 bits.

    The ONE reduction law shared by the reference sampler and the fused
    Pallas kernel's in-kernel threefry draw, so both paths map identical
    bits to identical indices.  Plain modulo: the bias is bound/2^32
    (< 1e-6 for any real CSP), invisible to the chi-square gates.
    """
    b = jnp.maximum(jnp.asarray(bound, jnp.int32), 1).astype(jnp.uint32)
    return (bits % b).astype(jnp.int32)


def sample_from_csp(csp: CspResult, key: jax.Array, batch: int,
                    fallback_size: jax.Array) -> jax.Array:
    """Algorithm 1 lines 14-17: uniform sample of the CSP.

    If the CSP came up empty (possible early in training when all
    priorities sit in one group and the representative misses), fall back
    to uniform over the live buffer — the same degenerate behaviour a
    hardware CSP buffer underflow would trigger.

    Draws reduce raw ``jax.random.bits`` through :func:`pick_uniform`
    (not ``randint``) so the fused kernel, recomputing the same threefry
    stream in-kernel, reproduces them bit-for-bit.
    """
    k_pick, k_fb = jax.random.split(key)
    u = pick_uniform(jax.random.bits(k_pick, (batch,), jnp.uint32), csp.count)
    picked = csp.indices[u]
    fallback = pick_uniform(jax.random.bits(k_fb, (batch,), jnp.uint32),
                            fallback_size)
    return jnp.where(csp.count > 0, picked, fallback).astype(jnp.int32)


class AmperState(NamedTuple):
    """Sampler state: quantized priorities + validity mask."""

    pq: jax.Array     # int32[capacity]
    valid: jax.Array  # bool[capacity]


class AmperSampler:
    """Unified AMPER sampler ('fr' or 'k' variant) with the PER-like API.

    Priorities passed to :meth:`update` are the already-exponentiated
    p = |td|^alpha values, exactly as for the PER baselines, so samplers
    are drop-in interchangeable in the replay buffer and the data pipeline.
    """

    def __init__(self, cfg: AmperConfig, variant: str = "fr"):
        if variant not in ("fr", "k"):
            raise ValueError(f"unknown AMPER variant: {variant!r}")
        self.cfg = cfg
        self.variant = variant

    def init(self) -> AmperState:
        return AmperState(
            pq=jnp.zeros(self.cfg.capacity, jnp.int32),
            valid=jnp.zeros(self.cfg.capacity, jnp.bool_),
        )

    def total(self, state: AmperState) -> jax.Array:
        return jnp.sum(
            qz.dequantize(state.pq, self.cfg.v_max, self.cfg.frac_bits)
            * state.valid
        )

    def priorities(self, state: AmperState) -> jax.Array:
        return qz.dequantize(state.pq, self.cfg.v_max, self.cfg.frac_bits) * state.valid

    def update(self, state: AmperState, idx: jax.Array, priority: jax.Array) -> AmperState:
        """Priority write — a single TCAM row write in hardware (Sec. 3.4.3)."""
        pq = state.pq.at[idx].set(qz.quantize(priority, self.cfg.v_max, self.cfg.frac_bits))
        valid = state.valid.at[idx].set(priority > 0)
        return AmperState(pq=pq, valid=valid)

    def build_csp(self, state: AmperState, key: jax.Array) -> CspResult:
        from repro.obs import span  # deferred: keep core import-light

        fn = build_csp_fr if self.variant == "fr" else build_csp_k
        # No-op under jit (the usual path); times the eager CSP rebuild
        # in tests/benchmarks/probes.
        with span("csp_rebuild"):
            return fn(state.pq, state.valid, key, self.cfg)

    def sample(self, state: AmperState, key: jax.Array, batch: int,
               stratified: bool = True) -> jax.Array:
        del stratified  # CSP sampling is uniform by construction
        kcsp, kpick = jax.random.split(key)
        if self.variant == "fr" and self.cfg.fr_mode == "fused":
            return self._sample_fused(state, kcsp, kpick, batch)
        csp = self.build_csp(state, kcsp)
        live = jnp.sum(state.valid.astype(jnp.int32))
        return sample_from_csp(csp, kpick, batch, live)

    def _sample_fused(self, state: AmperState, kcsp: jax.Array,
                      kpick: jax.Array, batch: int) -> jax.Array:
        """One Pallas dispatch for the whole draw (fr_mode="fused").

        The key tree mirrors the reference exactly — kcsp -> (kv, kroll)
        for representatives and the compaction rotation; kpick goes to the
        kernel whole, which performs the reference's (k_pick, k_fb) split
        in-kernel — so the in-kernel threefry consumes the very streams
        the reference would, and indices come out bit-identical.
        """
        from repro.kernels import ops as kops  # deferred: kernels are optional

        cfg = self.cfg
        if cfg.frac_bits > 24:
            raise ValueError(
                f"fr_mode='fused' needs frac_bits <= 24 (one-hot f32 "
                f"gathers are exact below 2^24), got {cfg.frac_bits}")
        kv, kroll = jax.random.split(kcsp)
        v_rep = group_representatives(kv, cfg)
        lo, hi = fr_intervals(v_rep, cfg)
        shift = jax.random.randint(kroll, (), 0, cfg.capacity)
        idx, _stats = kops.amper_sample(
            state.pq, state.valid, lo, hi, shift, kpick,
            batch=batch, csp_capacity=cfg.csp_capacity)
        return idx


def make_sampler(kind: str, capacity: int, **kw):
    """Deprecated alias for :func:`repro.core.samplers.make_sampler`."""
    from repro.core import samplers  # local import to avoid cycles

    return samplers.make_sampler(kind, capacity, **kw)


class UniformState(NamedTuple):
    priorities: jax.Array  # kept so the API is uniform; ignored for sampling
    valid: jax.Array


class UniformSampler:
    """Uniform ER — the paper's weak baseline."""

    def __init__(self, capacity: int):
        self.capacity = capacity

    def init(self) -> UniformState:
        return UniformState(
            priorities=jnp.zeros(self.capacity, jnp.float32),
            valid=jnp.zeros(self.capacity, jnp.bool_),
        )

    def total(self, state: UniformState) -> jax.Array:
        return jnp.sum(state.priorities * state.valid)

    def priorities(self, state: UniformState) -> jax.Array:
        return state.priorities * state.valid

    def update(self, state: UniformState, idx, priority) -> UniformState:
        return UniformState(
            priorities=state.priorities.at[idx].set(priority),
            valid=state.valid.at[idx].set(priority > 0),
        )

    def sample(self, state: UniformState, key, batch: int, stratified: bool = True):
        del stratified
        live = jnp.maximum(jnp.sum(state.valid.astype(jnp.int32)), 1)
        return jax.random.randint(key, (batch,), 0, live).astype(jnp.int32)
