"""Functional experience-replay buffer (Fig. 1's ER memory).

Stores an arbitrary transition pytree in a ring buffer with a pluggable
priority sampler (uniform / PER sum-tree / PER cumsum / AMPER-k / AMPER-fr,
or their mesh-sharded counterparts).
Everything is pure and jit-able; the buffer state is a pytree that can be
donated through a training step or sharded across a mesh.

The buffer is mesh-aware through the sampler: when the sampler carries a
``sharding`` (the ``*-sharded`` registry kinds expose a ``NamedSharding``
over the capacity dim), every storage leaf is kept partitioned the same
way, so transitions live on the shard that owns their priority row and the
ring-arc ``add_batch`` scatter respects the shard layout (each shard writes
only the arc slice it owns; no leaf is ever gathered to one device).

New experiences enter with the current maximum priority (the standard PER
convention: ensures every transition is replayed at least once); sampled
transitions get their priority rewritten from the fresh TD error after the
train step — the store / sample / update cycle of Fig. 1.

For the async runtime (:mod:`repro.runtime`) the buffer additionally
tracks a per-slot *write stamp*: the global add counter at the slot's
last write, plus a *generation* word counting signed-int32 rollovers of
that counter, so the pair ``(stamp, gen)`` identifies a write uniquely
for 2^64 adds.  A deferred priority update that arrives after the slot
was recycled by newer experience must not clobber the newcomer's
priority; passing the sample-time stamp pairs
(:meth:`ReplayBuffer.stamps`, shape ``[..., 2]``) to
:meth:`ReplayBuffer.update_priorities` turns it into an out-of-band
write that silently drops exactly those stale rows — including slots
recycled an exact multiple of 2^32 adds apart, which a single int32
stamp would false-accept.

With ``n_step > 1`` the buffer stores *n-step* transitions: a per-env
:class:`NStepAccumulator` (its state rides inside ``ReplayState``, so it
checkpoints with the buffer) converts the incoming 1-step stream into
n-step rows — ``reward`` becomes the discounted n-step return truncated
at the first episode boundary inside the window, ``next_obs`` the
observation the TD target bootstraps from (``gamma**n_step`` at the
learner), and ``done`` whether any step of the window ended.  The
emitted rows keep the 1-step schema, so storage layout, samplers, and
checkpoints are unchanged.  The async runtime feeds its own per-actor
accumulator (each actor is an independent env stream) and hands the
buffer pre-aggregated rows via ``add_block(..., aggregated=True)``.

Frame-deduplicated pixel storage
--------------------------------

Passing a :class:`FrameStore` switches the buffer to pixel-native
storage: each transition stores its observation ONCE as a raw uint8
frame (``frame: uint8[capacity, H, W]``) instead of two float stacks
(``obs`` + ``next_obs``, each ``float32[H, W, history_len]`` — a ~2 *
history_len * 4 blowup).  ``sample`` materializes the float
``history_len``-stacked ``obs``/``next_obs`` batches on the fly by
gathering backward along the ring arc (the tensorpack
``ReplayMemory``/``recent_state`` pattern), masking frames that cross an
episode boundary, the ring write head, or the unwritten warm-up region
to zero — bit-identical to what a naive float buffer would have stored.
The ``(idx, transitions, is_weights)`` contract and every sampler are
unchanged; n-step aggregation happens at *sample time* (the stored
stream stays 1-step), so construct the buffer with ``n_step=1`` and put
the n-step window in ``FrameStore(n_step=...)``.

Frame chaining needs ring adjacency: the transition ``stride`` slots
before slot ``i`` must be the previous timestep of the *same* env
stream.  That holds for a single writer stream of ``stride`` lockstep
envs (the sync runtime, or the async runtime with one actor) and is
validated by stamp-difference checks at gather time, so foreign rows
degrade to masked frames/terminals rather than silent corruption.  One
semantic caveat: a time-limit truncation's pre-reset next observation is
never stored (the next slot already holds the fresh episode's reset
frame), so the frame path treats every ``done`` as terminal — the
truncation-bootstrap distinction lives on the float path.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.per import importance_from_selected
from repro.core.samplers import masked_update


class NStepState(NamedTuple):
    """Per-env-stream window of the last ``n`` 1-step transitions.

    All envs of one stream step in lockstep, so one scalar cursor pair
    serves the whole ``[num_envs]`` batch; ``ring`` leaves lead with
    ``[n, num_envs]``.
    """

    ring: Any         # transition pytree, leaves [n, num_envs, ...]
    count: jax.Array  # int32 pushes so far, saturating at n
    pos: jax.Array    # int32 next ring slot (== oldest entry once full)


class NStepAccumulator:
    """Pure, jittable n-step transition aggregator (per env stream).

    Push one vectorized 1-step transition batch per call; once the
    window holds ``n`` steps, each push also emits the n-step transition
    whose *first* step is the oldest window entry:

      ``reward``     = sum_k gamma^k r_k, truncated at the first ``done``
                       inside the window (steps past it belong to the
                       next episode and must not leak in);
      ``next_obs``   = the pre-reset observation of the truncating step
                       (or of the newest step when no episode ended);
      ``done``       = did any window step end the episode;
      ``terminated`` = should the TD target *not* bootstrap (emitted only
                       when the input rows carry the key).  A time-limit
                       truncation exactly at the window's last step keeps
                       ``terminated = 0``: the emitted reward covers all
                       n steps and ``next_obs`` is the pre-reset
                       observation, so the learner's fixed ``gamma**n``
                       bootstrap is exactly right.  A ``done`` *inside*
                       the window (truncation or not) sets
                       ``terminated = 1`` — the learner's discount is
                       fixed at ``gamma**n``, so a shorter horizon
                       cannot bootstrap at the right scale and the
                       conservative terminal treatment is the unbiased
                       choice among the expressible ones.

    The learner bootstraps the un-terminated case with ``gamma**n``.
    Emission validity is a traced scalar (all envs warm up in lockstep),
    so callers gate the ring write with one ``lax.cond``.
    """

    def __init__(self, n_step: int, gamma: float):
        if n_step < 2:
            raise ValueError(f"NStepAccumulator needs n_step >= 2, got "
                             f"{n_step} (use the buffer directly for 1)")
        self.n = n_step
        self.gamma = gamma

    def init(self, example_transition: Any, num_envs: int) -> NStepState:
        ring = jax.tree.map(
            lambda x: jnp.zeros((self.n, num_envs) + jnp.shape(x),
                                jnp.asarray(x).dtype),
            example_transition)
        return NStepState(ring=ring, count=jnp.int32(0), pos=jnp.int32(0))

    def push(self, state: NStepState, transitions: Any
             ) -> tuple[NStepState, Any, jax.Array]:
        """-> (state, emitted n-step rows [num_envs, ...], valid scalar).

        ``emitted`` holds garbage until ``valid`` (count reached n);
        gate the write on it.
        """
        ring = jax.tree.map(lambda buf, x: buf.at[state.pos].set(x),
                            state.ring, transitions)
        pos = (state.pos + 1) % self.n
        count = jnp.minimum(state.count + 1, self.n)
        new = NStepState(ring=ring, count=count, pos=pos)
        # Window in chronological order: once full, `pos` is the oldest.
        order = (pos + jnp.arange(self.n, dtype=jnp.int32)) % self.n
        w = jax.tree.map(lambda buf: buf[order], ring)
        d = w["done"]                                    # [n, E]
        cont = jnp.cumprod(1.0 - d, axis=0)              # alive after k
        cont_before = jnp.concatenate(
            [jnp.ones_like(cont[:1]), cont[:-1]], axis=0)
        disc = (self.gamma ** jnp.arange(self.n, dtype=jnp.float32))[:, None]
        reward = jnp.sum(disc * cont_before * w["reward"], axis=0)
        done = 1.0 - cont[-1]
        any_done = jnp.any(d > 0.5, axis=0)
        first_done = jnp.argmax(d > 0.5, axis=0)         # 0 when none
        horizon = jnp.where(any_done, first_done, self.n - 1)
        next_obs = jax.vmap(lambda col, h: col[h], in_axes=(1, 0))(
            w["next_obs"], horizon)
        emitted = {"obs": w["obs"][0], "action": w["action"][0],
                   "reward": reward, "next_obs": next_obs, "done": done}
        if "terminated" in w:
            # Bootstrap only when the window either ran done-free or was
            # cut by a truncation exactly at its last step (see class
            # docstring for why mid-window truncations stay terminal).
            emitted["terminated"] = jnp.where(
                any_done,
                jnp.where(first_done == self.n - 1,
                          w["terminated"][self.n - 1], 1.0),
                0.0)
        return new, emitted, count >= self.n


class FrameStore(NamedTuple):
    """Configuration of the frame-deduplicated pixel storage mode.

    history_len: frames stacked into one observation (the conv head's
      channel dim).
    frame_shape: shape of one stored frame, e.g. ``(H, W)``.
    stride: ring distance between consecutive timesteps of one env — the
      writer's lockstep width (``num_envs`` when a vectorized step is
      written as one arc).
    n_step: n-step return aggregated at sample time (the stored stream
      stays 1-step).
    gamma: discount for the sample-time n-step return.
    scale: uint8 -> float conversion factor; actors must use the same
      expression (``frame.astype(float32) * scale``) so materialized
      stacks are bit-identical to what the policy saw.
    """

    history_len: int
    frame_shape: tuple
    stride: int = 1
    n_step: int = 1
    gamma: float = 0.99
    scale: float = 1.0 / 255.0


_FRAME_KEYS = ("frame", "action", "reward", "done")


class ReplayState(NamedTuple):
    storage: Any          # pytree of arrays with leading dim = capacity
    sampler_state: Any    # state of the priority sampler
    pos: jax.Array        # int32 next write slot
    size: jax.Array       # int32 live count
    max_priority: jax.Array  # float32 running max (for new entries)
    write_stamp: jax.Array   # int32[capacity] global add counter at last
    #                          write of each slot (-1 = never written)
    total_adds: jax.Array    # int32 transitions ever written (wraps; see
    #                          add_gen)
    write_gen: jax.Array     # int32[capacity] rollover generation of the
    #                          slot's stamp — (write_stamp, write_gen)
    #                          identifies a write uniquely for 2^64 adds
    add_gen: jax.Array       # int32 rollovers of total_adds so far
    nstep: Any = None        # NStepState when n_step > 1, else None


class ReplayBuffer:
    """Ring buffer + priority sampler.

    Args:
      capacity: number of transitions.
      sampler: object exposing init/update/sample/priorities (see core.amper).
      alpha: PER exponent; priorities stored as (|td| + eps)^alpha.
      beta: importance-sampling exponent for weight computation.
      n_step: store n-step transitions (1 = the classic 1-step buffer).
        With ``n_step > 1``, ``add_batch`` expects exactly ``num_envs``
        rows per call (one lockstep vectorized env step) and routes them
        through the in-state :class:`NStepAccumulator`.
      gamma: discount used for the n-step return (ignored for n_step=1).
      num_envs: env-stream width the accumulator is sized for.
      frame_store: switch to frame-deduplicated uint8 pixel storage (see
        module docstring).  Requires ``n_step == 1`` here — the frame
        path aggregates n-step returns at sample time from
        ``FrameStore.n_step`` — and a storage schema containing at least
        ``frame`` (uint8, ``frame_shape``), ``action``, ``reward`` and
        ``done``.
    """

    def __init__(self, capacity: int, sampler, alpha: float = 0.6,
                 beta: float = 0.4, eps: float = 1e-2, n_step: int = 1,
                 gamma: float = 0.99, num_envs: int = 1,
                 frame_store: FrameStore | None = None):
        self.capacity = capacity
        self.sampler = sampler
        self.alpha = alpha
        self.beta = beta
        self.eps = eps
        self.n_step = n_step
        self.num_envs = num_envs
        self.frame_store = frame_store
        if frame_store is not None:
            if n_step != 1:
                raise ValueError(
                    "frame-store buffers aggregate n-step returns at "
                    "sample time: construct with n_step=1 and set "
                    f"FrameStore(n_step={n_step}) instead")
            if frame_store.history_len < 1 or frame_store.n_step < 1 \
                    or frame_store.stride < 1:
                raise ValueError(f"invalid FrameStore config: {frame_store}")
            span = (frame_store.history_len + frame_store.n_step) \
                * frame_store.stride
            if span >= capacity:
                raise ValueError(
                    f"capacity {capacity} too small for FrameStore "
                    f"window span {span} (stack + n-step would always "
                    "cross the write head)")
        self.accumulator = (NStepAccumulator(n_step, gamma)
                            if n_step > 1 else None)
        # Mesh-native samplers advertise the NamedSharding of their
        # priority table; storage follows it on the capacity dim.
        self.storage_sharding = getattr(sampler, "sharding", None)

    def nstep_init(self, example_transition: Any):
        """Fresh accumulator state for an independent env stream (the
        async runtime gives each actor its own), or None for n_step=1."""
        if self.accumulator is None:
            return None
        return self.accumulator.init(example_transition, self.num_envs)

    def _constrain(self, storage: Any) -> Any:
        if self.storage_sharding is None:
            return storage
        return jax.tree.map(
            lambda x: jax.lax.with_sharding_constraint(x, self.storage_sharding),
            storage)

    def init(self, example_transition: Any) -> ReplayState:
        if self.frame_store is not None:
            missing = [k for k in _FRAME_KEYS
                       if k not in example_transition]
            if missing:
                raise ValueError(
                    f"frame-store schema missing keys {missing}: needs "
                    f"at least {list(_FRAME_KEYS)}")
            frame = jnp.asarray(example_transition["frame"])
            if frame.dtype != jnp.uint8:
                raise ValueError(
                    f"frame leaf must be uint8, got {frame.dtype}")
            if tuple(frame.shape) != tuple(self.frame_store.frame_shape):
                raise ValueError(
                    f"frame leaf shape {tuple(frame.shape)} != "
                    f"FrameStore.frame_shape "
                    f"{tuple(self.frame_store.frame_shape)}")
        storage = self._constrain(jax.tree.map(
            lambda x: jnp.zeros((self.capacity,) + jnp.shape(x), jnp.asarray(x).dtype),
            example_transition,
        ))
        return ReplayState(
            storage=storage,
            sampler_state=self.sampler.init(),
            pos=jnp.int32(0),
            size=jnp.int32(0),
            max_priority=jnp.float32(1.0),
            write_stamp=self._constrain(
                jnp.full((self.capacity,), -1, jnp.int32)),
            total_adds=jnp.int32(0),
            write_gen=self._constrain(
                jnp.zeros((self.capacity,), jnp.int32)),
            add_gen=jnp.int32(0),
            nstep=self.nstep_init(example_transition),
        )

    def add(self, state: ReplayState, transition: Any) -> ReplayState:
        """Store one transition at the ring position with max priority."""
        return self.add_batch(
            state, jax.tree.map(lambda x: jnp.asarray(x)[None], transition))

    def _write_arc(self, state: ReplayState, transitions: Any) -> ReplayState:
        """Raw ring-arc write of B already-final rows (no accumulation)."""
        b = jax.tree.leaves(transitions)[0].shape[0]
        if b > self.capacity:
            raise ValueError(
                f"add_batch of {b} transitions exceeds capacity "
                f"{self.capacity}: ring slots would collide within one write")
        idx = (state.pos + jnp.arange(b, dtype=jnp.int32)) % self.capacity
        storage = self._constrain(jax.tree.map(
            lambda buf, x: buf.at[idx].set(x), state.storage, transitions
        ))
        sampler_state = self.sampler.update(
            state.sampler_state, idx,
            jnp.broadcast_to(state.max_priority, (b,))
        )
        # int32 arithmetic wraps; the generation words track each signed
        # rollover so (stamp, gen) stays unique across 2^63 adds.
        lo = state.total_adds
        stamps = lo + jnp.arange(b, dtype=jnp.int32)
        row_gen = state.add_gen + (stamps < lo).astype(jnp.int32)
        new_total = lo + jnp.int32(b)
        return ReplayState(
            storage=storage,
            sampler_state=sampler_state,
            pos=(state.pos + b) % self.capacity,
            size=jnp.minimum(state.size + b, self.capacity),
            max_priority=state.max_priority,
            write_stamp=self._constrain(state.write_stamp.at[idx].set(stamps)),
            total_adds=new_total,
            write_gen=self._constrain(
                state.write_gen.at[idx].set(row_gen)),
            add_gen=state.add_gen + (new_total < lo).astype(jnp.int32),
            nstep=state.nstep,
        )

    def add_batch(self, state: ReplayState, transitions: Any) -> ReplayState:
        """Store B transitions (leading dim B on every leaf) in one shot.

        The write slots are the contiguous ring arc
        ``(pos + arange(B)) % capacity`` — distinct as long as
        B <= capacity, so one batched sampler priority write replaces B
        sequential ones and every sampler's scatter semantics stay
        well-defined across the wraparound.

        With ``n_step > 1`` the rows are one lockstep vectorized env
        step (B must equal ``num_envs``); they enter the in-state
        accumulator and the *emitted* n-step rows are written instead —
        nothing reaches the ring until the window has warmed up.
        """
        if self.accumulator is None:
            return self._write_arc(state, transitions)
        b = jax.tree.leaves(transitions)[0].shape[0]
        if b != self.num_envs:
            raise ValueError(
                f"n_step={self.n_step} add_batch expects one vectorized "
                f"env step of num_envs={self.num_envs} rows, got {b} "
                f"(pre-aggregated rows go through add_block(..., "
                f"aggregated=True))")
        nstate, emitted, valid = self.accumulator.push(
            state.nstep, transitions)
        state = state._replace(nstep=nstate)
        return jax.lax.cond(
            valid, lambda s: self._write_arc(s, emitted), lambda s: s, state)

    def add_block(self, state: ReplayState, block: Any,
                  aggregated: bool = False) -> ReplayState:
        """Store a ``[T, B, ...]`` rollout block in chronological order.

        This is the runtime's block-enqueue entry point: an actor hands
        over a whole chunk of T vectorized steps at once, and the flatten
        preserves time-major order so the ring arc matches T sequential
        ``add_batch`` calls exactly.

        ``aggregated=True`` marks the rows as already n-step (the async
        actors run their own per-stream accumulator), bypassing the
        buffer's accumulator; with ``n_step > 1`` and raw rows the block
        is scanned through ``add_batch`` one timestep at a time instead
        of the single flattened write.
        """
        t, b = jax.tree.leaves(block)[0].shape[:2]
        if self.accumulator is not None and not aggregated:
            state, _ = jax.lax.scan(
                lambda s, tr: (self.add_batch(s, tr), None), state, block)
            return state
        flat = jax.tree.map(
            lambda x: x.reshape((t * b,) + x.shape[2:]), block)
        return self._write_arc(state, flat)

    def _stack_frames(self, state: ReplayState, slot0: jax.Array,
                      ref: jax.Array, base_ok: jax.Array) -> jax.Array:
        """Materialize ``history_len``-stacks ending at ``slot0``.

        Chains backward ``stride`` ring slots per frame; every link must
        (a) carry the stamp exactly ``stride`` adds older than its
        successor — wrap-safe int32 difference, so a slot recycled by the
        write head or belonging to a foreign stream fails the check —
        (b) be a written slot (the ring fills ``[0, size)`` in order),
        and (c) not close an episode (its ``done`` would make the next
        frame a reset observation).  Broken links zero the remaining
        older frames, which is exactly the zero-padding a naive float
        buffer records at episode starts / warm-up.
        """
        fs = self.frame_store
        st, lo = state.storage, state.write_stamp
        nd = len(fs.frame_shape)

        def as_mask(ok):
            return ok.astype(jnp.float32).reshape(ok.shape + (1,) * nd)

        frames = []
        ok = base_ok
        for j in range(fs.history_len):
            slot = (slot0 - j * fs.stride) % self.capacity
            if j > 0:
                ok = (ok
                      & (lo[slot] - ref == jnp.int32(-j * fs.stride))
                      & (slot < state.size)
                      & (st["done"][slot] < 0.5))
            frames.append(st["frame"][slot].astype(jnp.float32)
                          * fs.scale * as_mask(ok))
        return jnp.stack(frames[::-1], axis=-1)   # oldest -> newest

    def materialize(self, state: ReplayState, idx: jax.Array) -> dict:
        """Frame mode: build the float batch a naive buffer would return.

        For each anchor slot: the stacked ``obs`` ending at the anchor's
        frame, the sample-time n-step return, and the stacked
        ``next_obs`` ending ``n_step * stride`` slots later.  Windows
        cut by an episode boundary, the ring write head, or unwritten
        warm-up slots are masked to terminal (``terminated = 1``,
        ``next_obs = 0``) — the TD target then reduces to the observed
        return, which never fabricates data; the write-head exclusions
        touch at most ``(history_len + n_step) * stride / capacity`` of
        the ring.
        """
        fs = self.frame_store
        st, lo = state.storage, state.write_stamp
        anchor = idx.astype(jnp.int32) % self.capacity
        ref = lo[anchor]
        written = anchor < state.size
        obs = self._stack_frames(state, anchor, ref, written)

        # Sample-time n-step return along the forward arc; `enter`
        # carries "window still inside the anchor's episode and backed
        # by in-sequence rows".
        enter = written.astype(jnp.float32)
        reward = jnp.zeros(anchor.shape, jnp.float32)
        for k in range(fs.n_step):
            slot = (anchor + k * fs.stride) % self.capacity
            avail = ((lo[slot] - ref == jnp.int32(k * fs.stride))
                     & (slot < state.size))
            use = enter * avail.astype(jnp.float32)
            reward = reward + use * float(fs.gamma ** k) * st["reward"][slot]
            enter = use * (1.0 - st["done"][slot])
        boot = (anchor + fs.n_step * fs.stride) % self.capacity
        has_boot = ((enter > 0.5)
                    & (lo[boot] - ref == jnp.int32(fs.n_step * fs.stride))
                    & (boot < state.size))
        next_obs = self._stack_frames(state, boot, lo[boot], has_boot)
        term = 1.0 - has_boot.astype(jnp.float32)
        return {"obs": obs, "action": st["action"][anchor],
                "reward": reward, "next_obs": next_obs,
                "done": term, "terminated": term}

    def sample(self, state: ReplayState, key: jax.Array, batch: int,
               beta: float | jax.Array | None = None):
        """Returns (indices, transitions, is_weights).

        ``beta`` overrides the constructor's constant IS exponent for
        this draw — the hook annealed schedules (β→1 over training, per
        Schaul et al.) thread through; may be a traced scalar.

        In frame mode ``transitions`` is the materialized float batch
        (see :meth:`materialize`); the stored uint8 frames never leave
        the buffer.
        """
        from repro.obs import span  # deferred: keep core import-light

        # No-op under jit; times eager draws (tests/benchmarks/probes).
        with span("replay_sample"):
            idx = self.sampler.sample(state.sampler_state, key, batch)
        if self.frame_store is not None:
            batch_tree = self.materialize(state, idx)
        else:
            batch_tree = jax.tree.map(lambda buf: buf[idx], state.storage)
        prios = self.sampler.priorities(state.sampler_state)
        # Shared weight formula (one normalisation constant for the
        # reference and fused paths — see per.importance_from_selected).
        w = importance_from_selected(prios[idx], jnp.sum(prios),
                                     jnp.maximum(state.size, 1),
                                     self.beta if beta is None else beta)
        return idx, batch_tree, w

    def stamps(self, state: ReplayState, idx: jax.Array) -> jax.Array:
        """Write stamp pairs ``int32[..., 2]`` (counter, generation) of
        ``idx`` at sample time (pass back to :meth:`update_priorities`
        for a stale-safe deferred update)."""
        return jnp.stack(
            [state.write_stamp[idx], state.write_gen[idx]], axis=-1)

    def update_priorities(self, state: ReplayState, idx: jax.Array,
                          td_error: jax.Array,
                          stamp: jax.Array | None = None) -> ReplayState:
        """Rewrite priorities from fresh TD errors (Sec. 3.4.3: plain write).

        With ``stamp`` (the ``[..., 2]`` pairs captured by
        :meth:`stamps` when the batch was sampled) this becomes the
        runtime's out-of-band entry point: rows whose slot has been
        overwritten by newer experience since the sample are dropped
        instead of clobbering the newcomer's priority.  Matching both
        words keeps the check exact across int32 rollovers of the add
        counter (a slot recycled exactly 2^32 adds later repeats its
        stamp but not its generation).
        """
        p = (jnp.abs(td_error) + self.eps) ** self.alpha
        if stamp is None:
            sampler_state = self.sampler.update(state.sampler_state, idx, p)
            p_max = jnp.max(p)
        else:
            valid = ((state.write_stamp[idx] == stamp[..., 0])
                     & (state.write_gen[idx] == stamp[..., 1]))
            sampler_state = masked_update(
                self.sampler, state.sampler_state, idx, p, valid)
            p_max = jnp.max(jnp.where(valid, p, 0.0))
        return state._replace(
            sampler_state=sampler_state,
            max_priority=jnp.maximum(state.max_priority, p_max),
        )


def dirty_arcs(capacity: int, base_pos: int, n_new: int) -> list[tuple[int, int]]:
    """Half-open ring row ranges written since a base snapshot.

    ``base_pos`` is the write position captured at the base snapshot and
    ``n_new`` the transitions written since (callers derive it from the
    wrapping int32 add counter via a mod-2^32 difference — see
    ``train.replay_checkpoint.replay_dirty``); both come from plain host
    ints read off captured states, so the arc is exact, not an estimate.
    Wrapping the capacity boundary yields two ranges; ``n_new >=
    capacity`` means every row was rewritten and the whole leading dim
    is dirty.  Host-side helper for the incremental checkpoint layer
    (train/replay_checkpoint.py).
    """
    base_pos, n_new = int(base_pos), int(n_new)
    if n_new <= 0:
        return []
    if n_new >= capacity:
        return [(0, capacity)]
    end = base_pos + n_new
    if end <= capacity:
        return [(base_pos, end)]
    return [(base_pos, capacity), (0, end - capacity)]


def rows_to_ranges(rows) -> list[tuple[int, int]]:
    """Collapse a host iterable of touched row indices into sorted,
    merged half-open ranges — the shape the checkpoint layer's ``Rows``
    dirty spec takes."""
    out: list[tuple[int, int]] = []
    for r in sorted({int(r) for r in rows}):
        if out and r == out[-1][1]:
            out[-1] = (out[-1][0], r + 1)
        else:
            out.append((r, r + 1))
    return out
