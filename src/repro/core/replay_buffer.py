"""Functional experience-replay buffer (Fig. 1's ER memory).

Stores an arbitrary transition pytree in a ring buffer with a pluggable
priority sampler (uniform / PER sum-tree / PER cumsum / AMPER-k / AMPER-fr).
Everything is pure and jit-able; the buffer state is a pytree that can be
donated through a training step or sharded across a mesh.

New experiences enter with the current maximum priority (the standard PER
convention: ensures every transition is replayed at least once); sampled
transitions get their priority rewritten from the fresh TD error after the
train step — the store / sample / update cycle of Fig. 1.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class ReplayState(NamedTuple):
    storage: Any          # pytree of arrays with leading dim = capacity
    sampler_state: Any    # state of the priority sampler
    pos: jax.Array        # int32 next write slot
    size: jax.Array       # int32 live count
    max_priority: jax.Array  # float32 running max (for new entries)


class ReplayBuffer:
    """Ring buffer + priority sampler.

    Args:
      capacity: number of transitions.
      sampler: object exposing init/update/sample/priorities (see core.amper).
      alpha: PER exponent; priorities stored as (|td| + eps)^alpha.
      beta: importance-sampling exponent for weight computation.
    """

    def __init__(self, capacity: int, sampler, alpha: float = 0.6,
                 beta: float = 0.4, eps: float = 1e-2):
        self.capacity = capacity
        self.sampler = sampler
        self.alpha = alpha
        self.beta = beta
        self.eps = eps

    def init(self, example_transition: Any) -> ReplayState:
        storage = jax.tree.map(
            lambda x: jnp.zeros((self.capacity,) + jnp.shape(x), jnp.asarray(x).dtype),
            example_transition,
        )
        return ReplayState(
            storage=storage,
            sampler_state=self.sampler.init(),
            pos=jnp.int32(0),
            size=jnp.int32(0),
            max_priority=jnp.float32(1.0),
        )

    def add(self, state: ReplayState, transition: Any) -> ReplayState:
        """Store one transition at the ring position with max priority."""
        storage = jax.tree.map(
            lambda buf, x: buf.at[state.pos].set(x), state.storage, transition
        )
        sampler_state = self.sampler.update(
            state.sampler_state, state.pos[None], state.max_priority[None]
        )
        return ReplayState(
            storage=storage,
            sampler_state=sampler_state,
            pos=(state.pos + 1) % self.capacity,
            size=jnp.minimum(state.size + 1, self.capacity),
            max_priority=state.max_priority,
        )

    def sample(self, state: ReplayState, key: jax.Array, batch: int):
        """Returns (indices, transitions, is_weights)."""
        idx = self.sampler.sample(state.sampler_state, key, batch)
        batch_tree = jax.tree.map(lambda buf: buf[idx], state.storage)
        prios = self.sampler.priorities(state.sampler_state)
        total = jnp.maximum(jnp.sum(prios), 1e-12)
        p_sel = jnp.maximum(prios[idx], 1e-12) / total
        w = (jnp.maximum(state.size, 1).astype(jnp.float32) * p_sel) ** (-self.beta)
        w = w / jnp.maximum(jnp.max(w), 1e-12)
        return idx, batch_tree, w

    def update_priorities(self, state: ReplayState, idx: jax.Array,
                          td_error: jax.Array) -> ReplayState:
        """Rewrite priorities from fresh TD errors (Sec. 3.4.3: plain write)."""
        p = (jnp.abs(td_error) + self.eps) ** self.alpha
        sampler_state = self.sampler.update(state.sampler_state, idx, p)
        return state._replace(
            sampler_state=sampler_state,
            max_priority=jnp.maximum(state.max_priority, jnp.max(p)),
        )
