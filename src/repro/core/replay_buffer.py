"""Functional experience-replay buffer (Fig. 1's ER memory).

Stores an arbitrary transition pytree in a ring buffer with a pluggable
priority sampler (uniform / PER sum-tree / PER cumsum / AMPER-k / AMPER-fr,
or their mesh-sharded counterparts).
Everything is pure and jit-able; the buffer state is a pytree that can be
donated through a training step or sharded across a mesh.

The buffer is mesh-aware through the sampler: when the sampler carries a
``sharding`` (the ``*-sharded`` registry kinds expose a ``NamedSharding``
over the capacity dim), every storage leaf is kept partitioned the same
way, so transitions live on the shard that owns their priority row and the
ring-arc ``add_batch`` scatter respects the shard layout (each shard writes
only the arc slice it owns; no leaf is ever gathered to one device).

New experiences enter with the current maximum priority (the standard PER
convention: ensures every transition is replayed at least once); sampled
transitions get their priority rewritten from the fresh TD error after the
train step — the store / sample / update cycle of Fig. 1.

For the async runtime (:mod:`repro.runtime`) the buffer additionally
tracks a per-slot *write stamp* (the global add counter at the slot's
last write).  A deferred priority update that arrives after the slot was
recycled by newer experience must not clobber the newcomer's priority;
passing the sample-time stamps to :meth:`ReplayBuffer.update_priorities`
turns it into an out-of-band write that silently drops exactly those
stale rows.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.per import importance_weights
from repro.core.samplers import masked_update


class ReplayState(NamedTuple):
    storage: Any          # pytree of arrays with leading dim = capacity
    sampler_state: Any    # state of the priority sampler
    pos: jax.Array        # int32 next write slot
    size: jax.Array       # int32 live count
    max_priority: jax.Array  # float32 running max (for new entries)
    write_stamp: jax.Array   # int32[capacity] global add counter at last
    #                          write of each slot (-1 = never written)
    total_adds: jax.Array    # int32 transitions ever written


class ReplayBuffer:
    """Ring buffer + priority sampler.

    Args:
      capacity: number of transitions.
      sampler: object exposing init/update/sample/priorities (see core.amper).
      alpha: PER exponent; priorities stored as (|td| + eps)^alpha.
      beta: importance-sampling exponent for weight computation.
    """

    def __init__(self, capacity: int, sampler, alpha: float = 0.6,
                 beta: float = 0.4, eps: float = 1e-2):
        self.capacity = capacity
        self.sampler = sampler
        self.alpha = alpha
        self.beta = beta
        self.eps = eps
        # Mesh-native samplers advertise the NamedSharding of their
        # priority table; storage follows it on the capacity dim.
        self.storage_sharding = getattr(sampler, "sharding", None)

    def _constrain(self, storage: Any) -> Any:
        if self.storage_sharding is None:
            return storage
        return jax.tree.map(
            lambda x: jax.lax.with_sharding_constraint(x, self.storage_sharding),
            storage)

    def init(self, example_transition: Any) -> ReplayState:
        storage = self._constrain(jax.tree.map(
            lambda x: jnp.zeros((self.capacity,) + jnp.shape(x), jnp.asarray(x).dtype),
            example_transition,
        ))
        return ReplayState(
            storage=storage,
            sampler_state=self.sampler.init(),
            pos=jnp.int32(0),
            size=jnp.int32(0),
            max_priority=jnp.float32(1.0),
            write_stamp=self._constrain(
                jnp.full((self.capacity,), -1, jnp.int32)),
            total_adds=jnp.int32(0),
        )

    def add(self, state: ReplayState, transition: Any) -> ReplayState:
        """Store one transition at the ring position with max priority."""
        return self.add_batch(
            state, jax.tree.map(lambda x: jnp.asarray(x)[None], transition))

    def add_batch(self, state: ReplayState, transitions: Any) -> ReplayState:
        """Store B transitions (leading dim B on every leaf) in one shot.

        The write slots are the contiguous ring arc
        ``(pos + arange(B)) % capacity`` — distinct as long as
        B <= capacity, so one batched sampler priority write replaces B
        sequential ones and every sampler's scatter semantics stay
        well-defined across the wraparound.
        """
        b = jax.tree.leaves(transitions)[0].shape[0]
        if b > self.capacity:
            raise ValueError(
                f"add_batch of {b} transitions exceeds capacity "
                f"{self.capacity}: ring slots would collide within one write")
        idx = (state.pos + jnp.arange(b, dtype=jnp.int32)) % self.capacity
        storage = self._constrain(jax.tree.map(
            lambda buf, x: buf.at[idx].set(x), state.storage, transitions
        ))
        sampler_state = self.sampler.update(
            state.sampler_state, idx,
            jnp.broadcast_to(state.max_priority, (b,))
        )
        stamps = state.total_adds + jnp.arange(b, dtype=jnp.int32)
        return ReplayState(
            storage=storage,
            sampler_state=sampler_state,
            pos=(state.pos + b) % self.capacity,
            size=jnp.minimum(state.size + b, self.capacity),
            max_priority=state.max_priority,
            write_stamp=self._constrain(state.write_stamp.at[idx].set(stamps)),
            total_adds=state.total_adds + b,
        )

    def add_block(self, state: ReplayState, block: Any) -> ReplayState:
        """Store a ``[T, B, ...]`` rollout block in chronological order.

        This is the runtime's block-enqueue entry point: an actor hands
        over a whole chunk of T vectorized steps at once, and the flatten
        preserves time-major order so the ring arc matches T sequential
        ``add_batch`` calls exactly.
        """
        t, b = jax.tree.leaves(block)[0].shape[:2]
        flat = jax.tree.map(
            lambda x: x.reshape((t * b,) + x.shape[2:]), block)
        return self.add_batch(state, flat)

    def sample(self, state: ReplayState, key: jax.Array, batch: int,
               beta: float | jax.Array | None = None):
        """Returns (indices, transitions, is_weights).

        ``beta`` overrides the constructor's constant IS exponent for
        this draw — the hook annealed schedules (β→1 over training, per
        Schaul et al.) thread through; may be a traced scalar.
        """
        idx = self.sampler.sample(state.sampler_state, key, batch)
        batch_tree = jax.tree.map(lambda buf: buf[idx], state.storage)
        prios = self.sampler.priorities(state.sampler_state)
        w = importance_weights(prios, idx, jnp.maximum(state.size, 1),
                               self.beta if beta is None else beta)
        return idx, batch_tree, w

    def stamps(self, state: ReplayState, idx: jax.Array) -> jax.Array:
        """Write stamps of ``idx`` at sample time (pass back to
        :meth:`update_priorities` for a stale-safe deferred update)."""
        return state.write_stamp[idx]

    def update_priorities(self, state: ReplayState, idx: jax.Array,
                          td_error: jax.Array,
                          stamp: jax.Array | None = None) -> ReplayState:
        """Rewrite priorities from fresh TD errors (Sec. 3.4.3: plain write).

        With ``stamp`` (the :meth:`stamps` captured when the batch was
        sampled) this becomes the runtime's out-of-band entry point: rows
        whose slot has been overwritten by newer experience since the
        sample are dropped instead of clobbering the newcomer's priority.
        """
        p = (jnp.abs(td_error) + self.eps) ** self.alpha
        if stamp is None:
            sampler_state = self.sampler.update(state.sampler_state, idx, p)
            p_max = jnp.max(p)
        else:
            valid = state.write_stamp[idx] == stamp
            sampler_state = masked_update(
                self.sampler, state.sampler_state, idx, p, valid)
            p_max = jnp.max(jnp.where(valid, p, 0.0))
        return state._replace(
            sampler_state=sampler_state,
            max_priority=jnp.maximum(state.max_priority, p_max),
        )
