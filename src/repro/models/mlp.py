"""Dense MLP variants: SwiGLU (llama-style), GELU (whisper), GeGLU (gemma),
and the RWKV channel-mix."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import ParamSpec


def mlp_specs(kind: str, d: int, f: int, stacked: int | None) -> dict:
    lead = (stacked,) if stacked else ()
    lx = ("layers",) if stacked else ()
    if kind in ("swiglu", "geglu"):
        return {
            "w_gate": ParamSpec(lead + (d, f), lx + ("embed", "mlp")),
            "w_up": ParamSpec(lead + (d, f), lx + ("embed", "mlp")),
            "w_down": ParamSpec(lead + (f, d), lx + ("mlp", "embed")),
        }
    if kind == "gelu":
        return {
            "w_up": ParamSpec(lead + (d, f), lx + ("embed", "mlp")),
            "b_up": ParamSpec(lead + (f,), lx + ("mlp",), init="zeros"),
            "w_down": ParamSpec(lead + (f, d), lx + ("mlp", "embed")),
            "b_down": ParamSpec(lead + (d,), lx + ("embed",), init="zeros"),
        }
    if kind == "rwkv_cmix":
        return {
            "mu_k": ParamSpec(lead + (d,), lx + ("embed",), init="ones"),
            "w_k": ParamSpec(lead + (d, f), lx + ("embed", "mlp")),
            "w_v": ParamSpec(lead + (f, d), lx + ("mlp", "embed")),
            "mu_r": ParamSpec(lead + (d,), lx + ("embed",), init="ones"),
            "w_r": ParamSpec(lead + (d, d), lx + ("embed", "embed_out")),
        }
    raise ValueError(kind)


def mlp_apply(kind: str, p: dict, x: jax.Array,
              x_prev: jax.Array | None = None) -> jax.Array:
    """x: [B,S,D]. x_prev: shifted sequence for rwkv channel mix."""
    if kind == "swiglu":
        return (jax.nn.silu(x @ p["w_gate"].astype(x.dtype))
                * (x @ p["w_up"].astype(x.dtype))) @ p["w_down"].astype(x.dtype)
    if kind == "geglu":
        return (jax.nn.gelu(x @ p["w_gate"].astype(x.dtype), approximate=True)
                * (x @ p["w_up"].astype(x.dtype))) @ p["w_down"].astype(x.dtype)
    if kind == "gelu":
        h = jax.nn.gelu(x @ p["w_up"].astype(x.dtype)
                        + p["b_up"].astype(x.dtype), approximate=True)
        return h @ p["w_down"].astype(x.dtype) + p["b_down"].astype(x.dtype)
    if kind == "rwkv_cmix":
        if x_prev is None:
            x_prev = token_shift(x)
        xk = x + (x_prev - x) * p["mu_k"].astype(x.dtype)
        xr = x + (x_prev - x) * p["mu_r"].astype(x.dtype)
        kk = jnp.square(jax.nn.relu(xk @ p["w_k"].astype(x.dtype)))
        return jax.nn.sigmoid(xr @ p["w_r"].astype(x.dtype)) * (
            kk @ p["w_v"].astype(x.dtype))
    raise ValueError(kind)


def token_shift(x: jax.Array) -> jax.Array:
    """RWKV token shift: x_{t-1} with zero at t=0. x: [B,S,D]."""
    return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
