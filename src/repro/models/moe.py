"""Fine-grained Mixture-of-Experts (DeepSeekMoE style).

n shared experts always active + E routed experts with top-k softmax
gating.  Dispatch is capacity-limited scatter/gather (Mesh-TF positions
via cumsum) — no (T, E, C) one-hot is ever materialised, so the layer
scales to 10^6 tokens; experts shard over the "model" mesh axis (EP) and
tokens over ("pod","data") (DP), with XLA SPMD inserting the all-to-all
at the dispatch boundary.

Aux losses: load-balance (Switch-style) + router-z, returned as metrics.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import axis_size, logical_constraint
from repro.models.common import ParamSpec
from repro.models import mlp as mlp_mod


def moe_specs(cfg, stacked: int | None) -> dict:
    lead = (stacked,) if stacked else ()
    lx = ("layers",) if stacked else ()
    D, E, Fe = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    out = {
        "router": ParamSpec(lead + (D, E), lx + ("embed", None), scale=0.1),
        "w_gate": ParamSpec(lead + (E, D, Fe), lx + ("experts", "embed", "expert_mlp")),
        "w_up": ParamSpec(lead + (E, D, Fe), lx + ("experts", "embed", "expert_mlp")),
        "w_down": ParamSpec(lead + (E, Fe, D), lx + ("experts", "expert_mlp", "embed")),
    }
    if cfg.n_shared_experts:
        fs = cfg.moe_d_ff * cfg.n_shared_experts
        out["shared"] = mlp_mod.mlp_specs("swiglu", D, fs, stacked)
    return out


def _capacity(n_tokens: int, cfg) -> int:
    cap = int(n_tokens * cfg.moe_top_k * cfg.capacity_factor / cfg.n_experts)
    return max(cap, cfg.moe_top_k)


def moe_apply_shard_map(cfg, p: dict, x: jax.Array) -> Tuple[jax.Array, dict]:
    """Explicit-collective MoE: manual mesh axes for the dispatch.

    Auto-SPMD lowers the scatter-add dispatch to an all-reduce of the
    whole (E*C, D) buffer (~2x30 GB/layer on deepseek-moe; EXPERIMENTS.md
    §Perf A-series).  Here the dispatch runs inside shard_map:

      * each (pod,data) shard builds its LOCAL (E, Cl, D) capacity slice
        (positions are shard-local prefix sums — free);
      * each "model" shard all-gathers ONLY ITS E/|model| experts' slices
        over (pod,data)  -> (E_loc, G*Cl, D): ~1.9 GB/layer;
      * expert FFNs run non-replicated on the expert owner;
      * combine all-gathers each token group's OWN capacity slice over
        "model" -> (E, Cl, D): ~1.0 GB/layer.

    ~3 GB/layer of all-gather replaces ~60 GB/layer of all-reduce.
    Activated via cfg.moe_dispatch == "shard_map" when a mesh is active.
    """
    from jax.experimental.shard_map import shard_map
    from repro.distributed.sharding import active_rules
    from jax.sharding import PartitionSpec as P

    rules = active_rules()
    mesh = rules.mesh
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.moe_top_k
    T = B * S
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    G = 1
    for a in dp_axes:
        G *= mesh.shape[a]
    n_model = mesh.shape.get("model", 1)
    if T % G or E % n_model:
        return moe_apply_scatter(cfg, p, x)  # fallback: shapes don't tile
    Cl = max(-(-_capacity(T, cfg) // G), K)
    E_loc = E // n_model

    def body(xt, router, wg, wu, wd):
        # xt: (Tl, D) local tokens (replicated over "model")
        Tl = xt.shape[0]
        logits = xt.astype(jnp.float32) @ router.astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, expert_ids = jax.lax.top_k(probs, K)
        gate_vals = gate_vals / jnp.maximum(
            jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)
        flat_e = expert_ids.T.reshape(Tl * K)          # k-major
        eq = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
        pos = jnp.take_along_axis(jnp.cumsum(eq, 0) - eq,
                                  flat_e[:, None], 1)[:, 0]
        keep = pos < Cl
        slot = jnp.where(keep, flat_e * Cl + pos, E * Cl)
        token_of = jnp.tile(jnp.arange(Tl), K)
        buf = jnp.zeros((E * Cl + 1, D), x.dtype).at[slot].add(
            jnp.where(keep[:, None], xt[token_of], 0))
        buf = buf[:-1].reshape(E, Cl, D)

        # my experts' slices from every token group: (E_loc, G*Cl, D)
        me = jax.lax.axis_index("model")
        mine = jax.lax.dynamic_slice_in_dim(buf, me * E_loc, E_loc, 0)
        gathered = jax.lax.all_gather(mine, dp_axes, axis=1, tiled=True)

        h = jnp.einsum("ecd,edf->ecf", gathered, wg.astype(x.dtype))
        u = jnp.einsum("ecd,edf->ecf", gathered, wu.astype(x.dtype))
        eo = jnp.einsum("ecf,efd->ecd", jax.nn.silu(h) * u,
                        wd.astype(x.dtype))              # (E_loc, G*Cl, D)

        # my token group's slice from every expert owner: (E, Cl, D)
        g_lin = jnp.int32(0)
        for a in dp_axes:
            g_lin = g_lin * axis_size(a) + jax.lax.axis_index(a)
        my_slice = jax.lax.dynamic_slice_in_dim(
            eo.reshape(E_loc, G, Cl, D).transpose(1, 0, 2, 3),  # (G,E_loc,Cl,D)
            g_lin, 1, 0)[0]                                     # (E_loc, Cl, D)
        eo_all = jax.lax.all_gather(my_slice, "model", axis=0,
                                    tiled=True)                 # (E, Cl, D)

        picked = eo_all.reshape(E * Cl, D)[jnp.minimum(slot, E * Cl - 1)]
        contrib = jnp.where(keep[:, None],
                            picked * gate_vals.T.reshape(-1)[:, None].astype(x.dtype), 0)
        out = jnp.zeros((Tl, D), x.dtype).at[token_of].add(contrib)

        me_probs = jnp.mean(probs, axis=0)
        ce = jnp.mean(jax.nn.one_hot(expert_ids, E).sum(1), axis=0)
        stats = jnp.stack([E * jnp.sum(me_probs * ce) / K,
                           jnp.mean(jax.nn.logsumexp(logits, -1) ** 2),
                           1.0 - jnp.mean(keep.astype(jnp.float32))])
        stats = jax.lax.pmean(stats, dp_axes + ("model",))
        return out, stats

    tok_spec = P(dp_axes if len(dp_axes) > 1 else (dp_axes[0] if dp_axes else None))
    out, stats = shard_map(
        body, mesh=mesh,
        in_specs=(P(tok_spec[0], None), P(None, None),
                  P("model", None, None), P("model", None, None),
                  P("model", None, None)),
        out_specs=(P(tok_spec[0], None), P()),
        check_rep=False,
    )(x.reshape(T, D), p["router"], p["w_gate"], p["w_up"], p["w_down"])

    out = out.reshape(B, S, D)
    if cfg.n_shared_experts:
        out = out + mlp_mod.mlp_apply("swiglu", p["shared"],
                                      x.reshape(T, D)).reshape(B, S, D)
    metrics = {"moe_lb_loss": stats[0], "moe_z_loss": stats[1],
               "moe_drop_frac": stats[2]}
    return out, metrics


def moe_apply(cfg, p: dict, x: jax.Array) -> Tuple[jax.Array, dict]:
    """x: [B,S,D] -> (out [B,S,D], metrics). Dispatch-mode switch."""
    from repro.distributed.sharding import active_rules
    if (getattr(cfg, "moe_dispatch", "scatter") == "shard_map"
            and active_rules() is not None):
        return moe_apply_shard_map(cfg, p, x)
    return moe_apply_scatter(cfg, p, x)


def moe_apply_scatter(cfg, p: dict, x: jax.Array) -> Tuple[jax.Array, dict]:
    """x: [B,S,D] -> (out [B,S,D], metrics)."""
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.moe_top_k
    T = B * S
    C = _capacity(T, cfg)
    # Token groups = data shards: the position-in-expert prefix sums run
    # WITHIN a group, so they are shard-local (no cross-device scan), and
    # each group owns its own capacity slice of every expert — per-shard
    # capacity quotas, the standard SPMD dropping semantics.
    G = cfg.moe_groups if (cfg.moe_groups and T % cfg.moe_groups == 0
                           and T >= cfg.moe_groups * K) else 1
    Tg = T // G
    Cg = max(-(-C // G), K)
    xt = logical_constraint(x.reshape(T, D), ("tokens", None))

    logits = (xt.astype(jnp.float32) @ p["router"].astype(jnp.float32))  # (T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, K)                      # (T,K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    # --- per-group capacity-limited positions (k=0 slots first) ---
    flat_e = expert_ids.reshape(G, Tg, K).transpose(0, 2, 1).reshape(G, K * Tg)
    eq = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)           # (G, KTg, E)
    eq = logical_constraint(eq, ("tokens", None, None))
    pos_in_e = jnp.cumsum(eq, axis=1) - eq                    # local prefix
    pos = jnp.take_along_axis(pos_in_e, flat_e[..., None], axis=2)[..., 0]
    keep = pos < Cg
    g_idx = jnp.arange(G, dtype=jnp.int32)[:, None]
    # Expert-major slot layout with per-group capacity slices.  (A group-
    # major layout + transpose-reshard was tried to turn the dispatch into
    # a pure all-to-all, but XLA lowered the resharding transposes into
    # collective-permute storms 2.5x worse — see EXPERIMENTS.md §Perf A3;
    # the explicit shard_map all-to-all dispatch is the documented next
    # step.)
    slot = jnp.where(keep, flat_e * (G * Cg) + g_idx * Cg + pos, E * G * Cg)

    # token id (global) of each (group, k, t') dispatch entry
    token_of = (g_idx * Tg + jnp.tile(jnp.arange(Tg), K)[None]).reshape(-1)
    slot = slot.reshape(-1)
    keep = keep.reshape(-1)
    gathered = logical_constraint(
        jnp.where(keep[:, None], xt[token_of], 0), ("tokens", None))
    xin = jnp.zeros((E * G * Cg + 1, D), x.dtype).at[slot].add(gathered)
    expert_in = logical_constraint(xin[:-1].reshape(E, G * Cg, D),
                                   ("experts", None, None))

    h = jnp.einsum("ecd,edf->ecf", expert_in, p["w_gate"].astype(x.dtype))
    u = jnp.einsum("ecd,edf->ecf", expert_in, p["w_up"].astype(x.dtype))
    eo = jnp.einsum("ecf,efd->ecd", jax.nn.silu(h) * u,
                    p["w_down"].astype(x.dtype))
    eo = logical_constraint(eo, ("experts", None, None))

    flat_gate = gate_vals.reshape(G, Tg, K).transpose(0, 2, 1).reshape(-1)
    picked = eo.reshape(E * G * Cg, D)[jnp.minimum(slot, E * G * Cg - 1)]
    contrib = jnp.where(keep[:, None], picked * flat_gate[:, None].astype(x.dtype), 0)
    contrib = logical_constraint(contrib, ("tokens", None))
    out = jnp.zeros((T, D), x.dtype).at[token_of].add(contrib)

    if cfg.n_shared_experts:
        out = out + mlp_mod.mlp_apply("swiglu", p["shared"], xt)

    # --- aux metrics ---
    me = jnp.mean(probs, axis=0)                              # router mass
    ce = jnp.mean(jax.nn.one_hot(expert_ids, E).sum(1), axis=0)  # pick rate
    lb_loss = E * jnp.sum(me * ce) / K
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    dropped = 1.0 - jnp.mean(keep.astype(jnp.float32))
    metrics = {"moe_lb_loss": lb_loss, "moe_z_loss": z_loss,
               "moe_drop_frac": dropped}
    return out.reshape(B, S, D), metrics
