"""Decoder-only LM assembly: one scan-over-layers covering every family.

Block kinds:  attn (GQA/MQA/MLA, optional SWA / per-layer global mix),
rwkv (Finch time-mix + channel-mix), hybrid (parallel attn + mamba heads,
hymba-style).  MLP kinds: dense (swiglu/gelu/geglu), MoE (fine-grained
shared+routed), rwkv channel-mix.

Layers are stored stacked (leading "layers" dim) and consumed by
``lax.scan`` with per-layer ``jax.remat`` — HLO size, compile time and
activation memory are all depth-independent.  `first_dense_layers`
(deepseek) live in a second, smaller stack so both scans stay homogeneous.

Three entry points per model:
  forward()      full-seq logits (training, and the prefill_32k cells)
  prefill()      forward + cache construction (serving)
  decode_step()  one token with cache (the decode_32k / long_500k cells)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import logical_constraint
from repro.models import attention as attn_mod
from repro.models import mamba as mamba_mod
from repro.models import mlp as mlp_mod
from repro.models import moe as moe_mod
from repro.models import rwkv6 as rwkv_mod
from repro.models.common import (ParamSpec, apply_norm, norm_spec,
                                 scan_layers, softcap)

GLOBAL_WINDOW = jnp.int32(2**30)  # "no window" sentinel for dynamic-window archs


# ---------------------------------------------------------------------------
# Param specs
# ---------------------------------------------------------------------------

def _mix_specs(cfg, L: int) -> dict:
    if cfg.block_kind == "rwkv":
        return rwkv_mod.rwkv_specs(cfg, L)
    if cfg.block_kind == "hybrid":
        d_inner = cfg.ssm_expand * cfg.d_model
        return {
            "attn": attn_mod.gqa_specs(cfg, L),
            "mamba": mamba_mod.mamba_specs(cfg, L, cfg.d_model, d_inner),
            "norm_attn": norm_spec(cfg.norm_kind, cfg.d_model, L),
            "norm_mamba": norm_spec(cfg.norm_kind, cfg.d_model, L),
        }
    if cfg.attn_kind == "mla":
        return attn_mod.mla_specs(cfg, L)
    return attn_mod.gqa_specs(cfg, L)


def _mlp_specs(cfg, L: int, dense: bool) -> dict:
    if cfg.n_experts and not dense:
        return moe_mod.moe_specs(cfg, L)
    kind = cfg.mlp_kind if cfg.mlp_kind != "rwkv_cmix" else "rwkv_cmix"
    d_ff = cfg.d_ff
    return mlp_mod.mlp_specs(kind, cfg.d_model, d_ff, L)


def _block_specs(cfg, L: int, dense_mlp: bool) -> dict:
    return {
        "norm1": norm_spec(cfg.norm_kind, cfg.d_model, L),
        "mix": _mix_specs(cfg, L),
        "norm2": norm_spec(cfg.norm_kind, cfg.d_model, L),
        "mlp": _mlp_specs(cfg, L, dense_mlp),
    }


def lm_param_specs(cfg) -> dict:
    n_dense = cfg.first_dense_layers if cfg.n_experts else 0
    n_stack = cfg.n_layers - n_dense
    specs = {
        "embed": ParamSpec((cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
                           init="embed"),
        "blocks": _block_specs(cfg, n_stack, dense_mlp=False),
        "final_norm": norm_spec(cfg.norm_kind, cfg.d_model),
    }
    if n_dense:
        specs["dense_blocks"] = _block_specs(cfg, n_dense, dense_mlp=True)
    if not cfg.tie_embeddings:
        specs["lm_head"] = ParamSpec((cfg.d_model, cfg.vocab_size),
                                     ("embed", "vocab"))
    return specs


def layer_windows(cfg) -> Optional[jax.Array]:
    """Per-layer attention windows, or None if attention is uniform.

    hymba: SWA everywhere except `global_attn_layers` (first/mid/last).
    """
    if not cfg.global_attn_layers:
        return None
    w = [GLOBAL_WINDOW if i in cfg.global_attn_layers else cfg.sliding_window
         for i in range(cfg.n_layers)]
    return jnp.asarray(w, jnp.int32)


# ---------------------------------------------------------------------------
# One block (full-sequence path)
# ---------------------------------------------------------------------------

def _static_skip_info(cfg, causal, window, prefix_len):
    """Static mask geometry for causal block-skipping (None = no skip)."""
    if (not getattr(cfg, "attn_block_skip", True) or not causal
            or prefix_len is not None
            or not (window is None or isinstance(window, int))):
        return None
    return (True, window)


def _mix_apply(cfg, lp, h, positions, window, prefix_len):
    """Returns (mix_out, aux_state_or_None)."""
    causal = cfg.is_causal_lm
    if cfg.block_kind == "rwkv":
        y, state = rwkv_mod.rwkv_apply(cfg, lp, h)
        return y, state
    mask_fn = attn_mod.make_mask_fn(causal, window, prefix_len)
    skip = _static_skip_info(cfg, causal, window, prefix_len)
    if cfg.block_kind == "hybrid":
        a = attn_mod.gqa_apply(cfg, lp["attn"], h, positions, mask_fn,
                               skip_info=skip)
        m, _ = mamba_mod.mamba_apply(cfg, lp["mamba"], h)
        a = apply_norm(cfg.norm_kind, a, lp["norm_attn"])
        m = apply_norm(cfg.norm_kind, m, lp["norm_mamba"])
        return 0.5 * (a + m), None
    if cfg.attn_kind == "mla":
        return attn_mod.mla_apply(cfg, lp, h, positions, mask_fn,
                                  skip_info=skip), None
    return attn_mod.gqa_apply(cfg, lp, h, positions, mask_fn,
                              skip_info=skip), None


def _mlp_apply(cfg, lp, h, dense_mlp: bool):
    if cfg.n_experts and not dense_mlp:
        return moe_mod.moe_apply(cfg, lp, h)
    if cfg.mlp_kind == "rwkv_cmix":
        return mlp_mod.mlp_apply("rwkv_cmix", lp, h), {}
    return mlp_mod.mlp_apply(cfg.mlp_kind, lp, h), {}


def block_apply(cfg, lp, x, positions, window, prefix_len, dense_mlp=False):
    x = logical_constraint(x, ("batch", "seq", None))
    h = apply_norm(cfg.norm_kind, x, lp["norm1"])
    mix, _ = _mix_apply(cfg, lp["mix"], h, positions, window, prefix_len)
    x = x + mix
    h2 = apply_norm(cfg.norm_kind, x, lp["norm2"])
    out, metrics = _mlp_apply(cfg, lp["mlp"], h2, dense_mlp)
    return x + out, metrics


def _scan_blocks(cfg, blocks, x, positions, prefix_len, windows, dense_mlp):
    """scan over stacked layer params with remat."""
    def body(carry, xs):
        lp, window = xs
        y, metrics = block_apply(cfg, lp, carry, positions, window,
                                 prefix_len, dense_mlp)
        return y, metrics

    n_layers = jax.tree.leaves(blocks)[0].shape[0]
    if windows is None:
        win_xs = jnp.full((n_layers,), -1, jnp.int32)  # ignored sentinel

        def body_nw(carry, xs):
            lp, _ = xs
            w = cfg.sliding_window  # static (None or int)
            y, metrics = block_apply(cfg, lp, carry, positions, w,
                                     prefix_len, dense_mlp)
            return y, metrics
        fn = body_nw
    else:
        win_xs = windows
        fn = body
    if cfg.remat:
        fn = jax.remat(fn, prevent_cse=False)
    x, metrics = scan_layers(fn, x, (blocks, win_xs),
                             unroll=cfg.unroll_layers)
    return x, jax.tree.map(jnp.mean, metrics)


# ---------------------------------------------------------------------------
# Full forward (training / prefill_32k lowering)
# ---------------------------------------------------------------------------

def embed_tokens(cfg, params, tokens):
    x = params["embed"].astype(_adtype(cfg))[tokens]
    if getattr(cfg, "scale_embed", False):
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    return x


def _adtype(cfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def unembed(cfg, params, x):
    if cfg.tie_embeddings:
        logits = x @ params["embed"].astype(x.dtype).T
    else:
        logits = x @ params["lm_head"].astype(x.dtype)
    logits = softcap(logits.astype(jnp.float32), cfg.logit_softcap)
    return logical_constraint(logits, ("batch", "seq_out", "vocab"))


def forward_hidden(cfg, params, tokens, *, extra_embeds=None):
    """tokens -> final-norm hidden states [B,S,D] (+ block metrics)."""
    x = embed_tokens(cfg, params, tokens)
    prefix_len = None
    if extra_embeds is not None:
        x = jnp.concatenate([extra_embeds.astype(x.dtype), x], axis=1)
        prefix_len = extra_embeds.shape[1]
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    windows = layer_windows(cfg)

    metrics = {}
    if "dense_blocks" in params:
        x, m0 = _scan_blocks(cfg, params["dense_blocks"], x, positions,
                             prefix_len, None, dense_mlp=True)
        metrics.update(m0)
    x, m1 = _scan_blocks(cfg, params["blocks"], x, positions, prefix_len,
                         windows, dense_mlp=False)
    metrics.update(m1)
    x = apply_norm(cfg.norm_kind, x, params["final_norm"])
    return x, metrics


def forward(cfg, params, tokens, *, extra_embeds=None):
    """tokens:[B,S_text] (+ optional [B,P,D] prefix embeds) -> logits [B,S,V]."""
    x, metrics = forward_hidden(cfg, params, tokens,
                                extra_embeds=extra_embeds)
    return unembed(cfg, params, x), metrics


def blockwise_nll(cfg, params, x, targets):
    """Streaming cross-entropy: never materialises the [B,S,V] logits.

    Online logsumexp over vocab chunks of size cfg.ce_block — the memory
    -bound hillclimb lever for small-d / huge-vocab archs where the CE
    chain dominates HBM traffic.  The chunk loop is a remat'd scan, so
    backward recomputes each chunk's logits instead of storing them.
    """
    B, S, D = x.shape
    V, block = cfg.vocab_size, cfg.ce_block
    pad = -V % block
    nblk = (V + pad) // block
    if cfg.tie_embeddings:
        W = params["embed"].astype(x.dtype).T       # (D, V)
    else:
        W = params["lm_head"].astype(x.dtype)
    W = jnp.pad(W, ((0, 0), (0, pad)))
    Wc = W.reshape(D, nblk, block).transpose(1, 0, 2)  # (nblk, D, block)

    def body(carry, inp):
        m, s, tgt = carry
        i, Wb = inp
        logits = (x @ Wb).astype(jnp.float32)       # (B, S, block)
        col_ok = i * block + jnp.arange(block) < V
        logits = jnp.where(col_ok, logits, -1e30)
        logits = softcap(logits, cfg.logit_softcap)
        m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
        s = s * jnp.exp(m - m_new) + jnp.sum(
            jnp.exp(logits - m_new[..., None]), axis=-1)
        loc = targets - i * block
        hit = (loc >= 0) & (loc < block)
        tgt_l = jnp.take_along_axis(
            logits, jnp.clip(loc, 0, block - 1)[..., None], axis=-1)[..., 0]
        tgt = jnp.where(hit, tgt_l, tgt)
        return (m_new, s, tgt), None

    init = (jnp.full((B, S), -1e30, jnp.float32),
            jnp.zeros((B, S), jnp.float32),
            jnp.full((B, S), -1e30, jnp.float32))
    (m, s, tgt), _ = jax.lax.scan(jax.remat(body), init,
                                  (jnp.arange(nblk), Wc))
    return jnp.log(jnp.maximum(s, 1e-30)) + m - tgt


def lm_loss(cfg, params, batch):
    """batch: {tokens, targets, loss_mask, [patch_embeds]} -> (loss, metrics)."""
    extra = batch.get("patch_embeds")
    targets = batch["targets"]
    x, metrics = forward_hidden(cfg, params, batch["tokens"],
                                extra_embeds=extra)
    if extra is not None:  # hidden over [prefix + text]; train on text only
        x = x[:, extra.shape[1]:]
    if cfg.ce_block:
        nll = blockwise_nll(cfg, params, x, targets)
    else:
        logits = unembed(cfg, params, x)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    mask = batch["loss_mask"].astype(jnp.float32)
    loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    if "moe_lb_loss" in metrics:
        loss = loss + 0.01 * metrics["moe_lb_loss"] + 1e-3 * metrics["moe_z_loss"]
    metrics = dict(metrics, nll=loss)
    return loss, metrics


# ---------------------------------------------------------------------------
# KV / state caches
# ---------------------------------------------------------------------------

def _layer_cache_struct(cfg, batch: int, max_len: int, dtype):
    """Shapes for ONE layer's cache (leading 'layers' dim added by caller)."""
    H = cfg.d_model // cfg.rwkv_head_dim if cfg.block_kind == "rwkv" else 0
    if cfg.block_kind == "rwkv":
        N = cfg.rwkv_head_dim
        return {
            "state": jnp.zeros((batch, H, N, N), jnp.float32),
            "x_prev": jnp.zeros((batch, 1, cfg.d_model), dtype),
            "cx_prev": jnp.zeros((batch, 1, cfg.d_model), dtype),
        }
    if cfg.block_kind == "hybrid":
        d_inner = cfg.ssm_expand * cfg.d_model
        return {
            "k": jnp.zeros((batch, cfg.n_kv_heads, max_len, cfg.head_dim), dtype),
            "v": jnp.zeros((batch, cfg.n_kv_heads, max_len, cfg.head_dim), dtype),
            "mamba_h": jnp.zeros((batch, d_inner, cfg.ssm_state), jnp.float32),
            "mamba_conv": jnp.zeros((batch, cfg.ssm_conv - 1, d_inner), dtype),
        }
    if cfg.attn_kind == "mla":
        lat = cfg.kv_lora_rank + cfg.qk_rope_dim
        return {"latent": jnp.zeros((batch, max_len, lat), dtype)}
    return {
        "k": jnp.zeros((batch, cfg.n_kv_heads, max_len, cfg.head_dim), dtype),
        "v": jnp.zeros((batch, cfg.n_kv_heads, max_len, cfg.head_dim), dtype),
    }


def cache_axes(cfg) -> dict:
    """Logical axes for each cache leaf (leading 'layers' added by caller).

    KV sequence gets the 'kv_seq' logical axis -> split-KV decode when the
    serve rules map it to 'model'."""
    if cfg.block_kind == "rwkv":
        return {"state": ("batch", "heads", None, None),
                "x_prev": ("batch", None, "embed_act"),
                "cx_prev": ("batch", None, "embed_act")}
    if cfg.block_kind == "hybrid":
        return {"k": ("batch", None, "kv_seq", None),
                "v": ("batch", None, "kv_seq", None),
                "mamba_h": ("batch", "qkv", None),
                "mamba_conv": ("batch", None, "qkv")}
    if cfg.attn_kind == "mla":
        return {"latent": ("batch", "kv_seq", None)}
    return {"k": ("batch", None, "kv_seq", None),
            "v": ("batch", None, "kv_seq", None)}


def init_cache(cfg, batch: int, max_len: int):
    """Stacked (n_layers-leading) cache pytree + shared length scalar."""
    dtype = _adtype(cfg)
    n_dense = cfg.first_dense_layers if cfg.n_experts else 0
    one = _layer_cache_struct(cfg, batch, max_len, dtype)

    def stack(n):
        return jax.tree.map(lambda t: jnp.broadcast_to(t, (n,) + t.shape), one)

    cache = {"blocks": stack(cfg.n_layers - n_dense), "len": jnp.int32(0)}
    if n_dense:
        cache["dense_blocks"] = stack(n_dense)
    return cache


# ---------------------------------------------------------------------------
# Decode (one token) and prefill
# ---------------------------------------------------------------------------

def _mix_decode(cfg, lp, h, cache_l, pos, window, prefix_len):
    """h:[B,1,D]; cache_l: one layer's cache (+ externally managed 'len')."""
    causal = True
    mask_fn = attn_mod.make_mask_fn(causal, window, prefix_len)
    if cfg.block_kind == "rwkv":
        y, st = rwkv_mod.rwkv_decode(cfg, lp,  h,
                                     {"state": cache_l["state"],
                                      "x_prev": cache_l["x_prev"]})
        return y, {**cache_l, "state": st["state"], "x_prev": st["x_prev"]}
    if cfg.block_kind == "hybrid":
        a, kv = attn_mod.gqa_decode(cfg, lp["attn"], h,
                                    {"k": cache_l["k"], "v": cache_l["v"],
                                     "len": pos}, mask_fn)
        m, mc = mamba_mod.mamba_apply(cfg, lp["mamba"], h,
                                      cache={"h": cache_l["mamba_h"],
                                             "conv": cache_l["mamba_conv"]})
        a = apply_norm(cfg.norm_kind, a, lp["norm_attn"])
        m = apply_norm(cfg.norm_kind, m, lp["norm_mamba"])
        return 0.5 * (a + m), {**cache_l, "k": kv["k"], "v": kv["v"],
                               "mamba_h": mc["h"], "mamba_conv": mc["conv"]}
    if cfg.attn_kind == "mla":
        y, st = attn_mod.mla_decode(cfg, lp, h,
                                    {"latent": cache_l["latent"], "len": pos},
                                    mask_fn)
        return y, {**cache_l, "latent": st["latent"]}
    y, st = attn_mod.gqa_decode(cfg, lp, h, {"k": cache_l["k"],
                                             "v": cache_l["v"], "len": pos},
                                mask_fn)
    return y, {**cache_l, "k": st["k"], "v": st["v"]}


def block_decode(cfg, lp, x, cache_l, pos, window, prefix_len, dense_mlp=False):
    h = apply_norm(cfg.norm_kind, x, lp["norm1"])
    mix, cache_l = _mix_decode(cfg, lp["mix"], h, cache_l, pos, window, prefix_len)
    x = x + mix
    h2 = apply_norm(cfg.norm_kind, x, lp["norm2"])
    if cfg.block_kind == "rwkv":
        out = mlp_mod.mlp_apply("rwkv_cmix", lp["mlp"], h2,
                                x_prev=cache_l["cx_prev"])
        cache_l = {**cache_l, "cx_prev": h2}
    else:
        out, _ = _mlp_apply(cfg, lp["mlp"], h2, dense_mlp)
    return x + out, cache_l


def decode_step(cfg, params, tokens, cache):
    """tokens:[B,1] -> (logits [B,1,V], cache'). The serve_step lowering."""
    x = embed_tokens(cfg, params, tokens)
    pos = cache["len"]
    windows = layer_windows(cfg)

    def scan_stack(x, blocks, block_cache, dense_mlp):
        n = jax.tree.leaves(blocks)[0].shape[0]
        win_xs = windows if windows is not None else jnp.full((n,), -1, jnp.int32)

        def body(carry, xs):
            lp, cl, w = xs
            w_arg = w if windows is not None else cfg.sliding_window
            y, cl2 = block_decode(cfg, lp, carry, cl, pos, w_arg, None,
                                  dense_mlp)
            return y, cl2

        return scan_layers(body, x, (blocks, block_cache, win_xs),
                           unroll=cfg.unroll_layers)

    new_cache = dict(cache)
    if "dense_blocks" in params:
        x, nc = scan_stack(x, params["dense_blocks"], cache["dense_blocks"],
                           dense_mlp=True)
        new_cache["dense_blocks"] = nc
    x, nc = scan_stack(x, params["blocks"], cache["blocks"], dense_mlp=False)
    new_cache["blocks"] = nc
    new_cache["len"] = pos + 1
    x = apply_norm(cfg.norm_kind, x, params["final_norm"])
    return unembed(cfg, params, x), new_cache


def _mix_prefill(cfg, lp, h, positions, window, prefix_len, max_len):
    """Full-seq mix that also returns this layer's cache (padded to max_len)."""
    causal = cfg.is_causal_lm
    mask_fn = attn_mod.make_mask_fn(causal, window, prefix_len)
    S = h.shape[1]
    pad = max_len - S

    def pad_kv(t):  # [B,H,S,D] -> [B,H,max_len,D]
        return jnp.pad(t, ((0, 0), (0, 0), (0, pad), (0, 0)))

    if cfg.block_kind == "rwkv":
        y, state = rwkv_mod.rwkv_apply(cfg, lp, h)
        return y, {"state": state, "x_prev": h[:, -1:]}
    skip = _static_skip_info(cfg, causal, window, prefix_len)
    if cfg.block_kind == "hybrid":
        a, (k, v) = attn_mod.gqa_apply(cfg, lp["attn"], h, positions, mask_fn,
                                       return_kv=True, skip_info=skip)
        m, mc = mamba_mod.mamba_apply(cfg, lp["mamba"], h, return_cache=True)
        a = apply_norm(cfg.norm_kind, a, lp["norm_attn"])
        m = apply_norm(cfg.norm_kind, m, lp["norm_mamba"])
        return 0.5 * (a + m), {"k": pad_kv(k), "v": pad_kv(v),
                               "mamba_h": mc["h"], "mamba_conv": mc["conv"]}
    if cfg.attn_kind == "mla":
        y, lat = attn_mod.mla_apply(cfg, lp, h, positions, mask_fn,
                                    return_latent=True, skip_info=skip)
        return y, {"latent": jnp.pad(lat, ((0, 0), (0, pad), (0, 0)))}
    y, (k, v) = attn_mod.gqa_apply(cfg, lp, h, positions, mask_fn,
                                   return_kv=True, skip_info=skip)
    return y, {"k": pad_kv(k), "v": pad_kv(v)}


def block_prefill(cfg, lp, x, positions, window, prefix_len, max_len,
                  dense_mlp=False):
    h = apply_norm(cfg.norm_kind, x, lp["norm1"])
    mix, cache_l = _mix_prefill(cfg, lp["mix"], h, positions, window,
                                prefix_len, max_len)
    x = x + mix
    h2 = apply_norm(cfg.norm_kind, x, lp["norm2"])
    if cfg.block_kind == "rwkv":
        out = mlp_mod.mlp_apply("rwkv_cmix", lp["mlp"], h2)
        cache_l["cx_prev"] = h2[:, -1:]
    else:
        out, _ = _mlp_apply(cfg, lp["mlp"], h2, dense_mlp)
    return x + out, cache_l


def prefill(cfg, params, tokens, max_len: int, *, extra_embeds=None):
    """Prompt -> (logits for the last position [B,V], full cache).

    This is the lowering target of the prefill_32k cells."""
    x = embed_tokens(cfg, params, tokens)
    prefix_len = None
    if extra_embeds is not None:
        x = jnp.concatenate([extra_embeds.astype(x.dtype), x], axis=1)
        prefix_len = extra_embeds.shape[1]
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    windows = layer_windows(cfg)

    def scan_stack(x, blocks, dense_mlp):
        n = jax.tree.leaves(blocks)[0].shape[0]
        win_xs = windows if windows is not None else jnp.full((n,), -1, jnp.int32)

        def body(carry, xs):
            lp, w = xs
            w_arg = w if windows is not None else cfg.sliding_window
            y, cl = block_prefill(cfg, lp, carry, positions, w_arg,
                                  prefix_len, max_len, dense_mlp)
            return y, cl

        if cfg.remat:
            body = jax.remat(body, prevent_cse=False)
        return scan_layers(body, x, (blocks, win_xs),
                           unroll=cfg.unroll_layers)

    cache = {"len": jnp.int32(S)}
    if "dense_blocks" in params:
        x, cache["dense_blocks"] = scan_stack(x, params["dense_blocks"], True)
    x, cache["blocks"] = scan_stack(x, params["blocks"], False)
    x = apply_norm(cfg.norm_kind, x, params["final_norm"])
    logits = unembed(cfg, params, x[:, -1:])
    return logits[:, 0], cache
