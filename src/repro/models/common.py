"""Shared model machinery: param specs w/ logical axes, norms, RoPE.

Parameters are plain dict pytrees. Each leaf is declared by a
:class:`ParamSpec` carrying its shape, init and **logical axis names**;
``distributed.sharding`` maps logical axes to mesh axes, which is how one
model definition serves every mesh (single pod, multi pod, smoke CPU).

Stacked-layer params carry a leading "layers" axis and are consumed by
``lax.scan`` — HLO size and compile time are depth-independent.
"""
from __future__ import annotations

import math
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class ParamSpec(NamedTuple):
    shape: tuple
    axes: tuple                 # logical axis name (or None) per dim
    init: str = "normal"        # normal | zeros | ones | embed
    scale: float = 1.0          # extra multiplier on the init std
    dtype: Any = jnp.float32


def _init_leaf(key: jax.Array, spec: ParamSpec) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    if spec.init == "embed":
        std = 1.0 * spec.scale
        return (jax.random.normal(key, spec.shape) * std).astype(spec.dtype)
    # fan-in scaled normal over the last-but-one dim (works for stacked too)
    fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
    std = spec.scale / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, spec.shape) * std).astype(spec.dtype)


def init_params(key: jax.Array, specs: Any) -> Any:
    """Materialise a ParamSpec pytree into arrays (deterministic per path)."""
    leaves, treedef = jax.tree.flatten(specs, is_leaf=lambda x: isinstance(x, ParamSpec))
    keys = jax.random.split(key, len(leaves))
    return jax.tree.unflatten(treedef, [_init_leaf(k, s) for k, s in zip(keys, leaves)])


def abstract_params(specs: Any) -> Any:
    """ShapeDtypeStruct tree for AOT lowering (no allocation)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype),
        specs, is_leaf=lambda x: isinstance(x, ParamSpec))


def param_axes(specs: Any) -> Any:
    """Logical-axes tree parallel to the param tree."""
    return jax.tree.map(lambda s: s.axes, specs,
                        is_leaf=lambda x: isinstance(x, ParamSpec))


# ---------------------------------------------------------------------------
# Norms & activations
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + weight.astype(jnp.float32))).astype(dt)


def layer_norm(x: jax.Array, weight: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def apply_norm(kind: str, x: jax.Array, p: dict) -> jax.Array:
    if kind == "layernorm":
        return layer_norm(x, p["scale"], p["bias"])
    return rms_norm(x, p["scale"])


def norm_spec(kind: str, d: int, stacked: int | None = None) -> dict:
    lead = (stacked,) if stacked else ()
    lax_ = ("layers",) if stacked else ()
    out = {"scale": ParamSpec(lead + (d,), lax_ + ("embed",),
                              init="zeros" if kind == "rmsnorm" else "ones")}
    if kind == "layernorm":
        out["bias"] = ParamSpec(lead + (d,), lax_ + ("embed",), init="zeros")
    return out


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, D] with D even; positions: broadcastable to [..., S]."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                      # (d/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, d/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_embedding(positions: jax.Array, d: int) -> jax.Array:
    """Whisper-style fixed sinusoidal position embedding."""
    half = d // 2
    freqs = jnp.exp(-math.log(10_000.0) * jnp.arange(half, dtype=jnp.float32) / max(half - 1, 1))
    args = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(args), jnp.cos(args)], axis=-1)


def softcap(logits: jax.Array, cap: float) -> jax.Array:
    if not cap:
        return logits
    return jnp.tanh(logits / cap) * cap


def scan_layers(body, carry, xs, *, unroll: bool = False):
    """lax.scan over stacked layers, or a python loop when ``unroll``.

    The unrolled variant produces depth-proportional HLO and exists for
    the roofline analysis build only: XLA's HloCostAnalysis counts a
    while-loop body once regardless of trip count, so scan-built
    executables under-report flops/bytes/collective traffic by ~n_layers.
    Both variants are numerically identical.
    """
    if not unroll:
        return jax.lax.scan(body, carry, xs)
    n = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        carry, y = body(carry, jax.tree.map(lambda t: t[i], xs))
        ys.append(y)
    stacked = jax.tree.map(lambda *e: jnp.stack(e), *ys)
    return carry, stacked
