"""Selective SSM (Mamba) head used by the hymba hybrid block.

Classic S6: input-dependent (Delta, B, C) with diagonal A; recurrence

    h_t = exp(Delta_t * A) h_{t-1} + Delta_t * B_t * x_t      (per channel)
    y_t = C_t . h_t + D * x_t

State: [B, d_inner, d_state] (d_state = cfg.ssm_state, e.g. 16) — O(1) in
sequence length, which is what lets hymba run the long_500k decode cell.
The short depthwise conv of the reference implementation is kept (k=4).
Training uses lax.scan over time (hymba's d_state=16 keeps the scan's
elementwise work negligible next to the projections).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import ParamSpec


def mamba_specs(cfg, stacked: int | None, d_in: int, d_inner: int) -> dict:
    lead = (stacked,) if stacked else ()
    lx = ("layers",) if stacked else ()
    Ns, Kc = cfg.ssm_state, cfg.ssm_conv
    dt_rank = max(d_in // 16, 1)
    return {
        "w_in": ParamSpec(lead + (d_in, 2 * d_inner), lx + ("embed", "qkv")),
        "conv_w": ParamSpec(lead + (Kc, d_inner), lx + (None, "qkv"), scale=0.5),
        "conv_b": ParamSpec(lead + (d_inner,), lx + ("qkv",), init="zeros"),
        "w_bdt": ParamSpec(lead + (d_inner, 2 * Ns + dt_rank), lx + ("qkv", None)),
        "w_dt": ParamSpec(lead + (dt_rank, d_inner), lx + (None, "qkv"), scale=0.1),
        "dt_bias": ParamSpec(lead + (d_inner,), lx + ("qkv",), init="zeros"),
        "a_log": ParamSpec(lead + (d_inner, Ns), lx + ("qkv", None), init="zeros"),
        "d_skip": ParamSpec(lead + (d_inner,), lx + ("qkv",), init="ones"),
        "w_out": ParamSpec(lead + (d_inner, d_in), lx + ("qkv", "embed")),
    }


def _conv1d(x, w, b, cache=None):
    """Depthwise causal conv. x:[B,S,Di], w:[K,Di]. cache:[B,K-1,Di] or None."""
    K = w.shape[0]
    if cache is None:
        pad = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    else:
        pad = cache.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i].astype(x.dtype) for i in range(K))
    new_cache = xp[:, -(K - 1):] if K > 1 else pad
    return out + b.astype(x.dtype), new_cache


def _ssm_scan(u, dt, B_in, C_in, a_log, d_skip, state):
    """u/dt:[B,S,Di]; B_in/C_in:[B,S,Ns]; state:[B,Di,Ns] -> (y, state)."""
    A = -jnp.exp(a_log.astype(jnp.float32))          # (Di,Ns), negative

    def step(h, inp):
        u_t, dt_t, b_t, c_t = inp                    # (B,Di),(B,Di),(B,Ns),(B,Ns)
        dA = jnp.exp(dt_t[..., None] * A[None])      # (B,Di,Ns)
        dBu = (dt_t * u_t)[..., None] * b_t[:, None, :]
        h = dA * h + dBu
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    xs = jax.tree.map(lambda t: t.transpose(1, 0, 2).astype(jnp.float32),
                      (u, dt, B_in, C_in))
    state, ys = jax.lax.scan(step, state.astype(jnp.float32), xs)
    y = ys.transpose(1, 0, 2) + u.astype(jnp.float32) * d_skip.astype(jnp.float32)
    return y, state


def mamba_apply(cfg, p, x, *, cache=None, return_cache: bool = False):
    """x:[B,S,D] -> (y:[B,S,D], cache'). cache={"h","conv"} or None (train).

    return_cache=True with cache=None returns a fresh cache from a
    full-sequence run (the prefill path)."""
    B, S, D = x.shape
    d_inner = p["w_in"].shape[-1] // 2
    Ns = cfg.ssm_state

    xz = x @ p["w_in"].astype(x.dtype)
    u, z = jnp.split(xz, 2, axis=-1)
    conv_cache = None if cache is None else cache["conv"]
    u, new_conv = _conv1d(u, p["conv_w"], p["conv_b"], conv_cache)
    u = jax.nn.silu(u)

    bdt = u @ p["w_bdt"].astype(x.dtype)
    B_in, C_in, dt_low = jnp.split(bdt, [Ns, 2 * Ns], axis=-1)
    dt = jax.nn.softplus(dt_low @ p["w_dt"].astype(x.dtype)
                         + p["dt_bias"].astype(x.dtype))

    state = (jnp.zeros((B, d_inner, Ns), jnp.float32) if cache is None
             else cache["h"])
    y, state = _ssm_scan(u, dt, B_in, C_in, p["a_log"], p["d_skip"], state)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = y @ p["w_out"].astype(x.dtype)
    if cache is None and not return_cache:
        return out, None
    return out, {"h": state, "conv": new_conv.astype(x.dtype)}
