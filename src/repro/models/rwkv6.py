"""RWKV-6 "Finch" time-mix: linear attention with data-dependent decay.

State per head is an (N x N) outer-product memory updated per token:

    S_t[i,j] = w_t[i] * S_{t-1}[i,j] + k_t[i] * v_t[j]
    y_t[j]   = sum_i r_t[i] * (S_{t-1}[i,j] + u[i] * k_t[i] * v_t[j])

with per-channel, data-dependent decay w_t = exp(-exp(w_raw_t)) produced
by a LoRA on the token-shifted input (the Finch contribution).

Two execution modes (cfg.rwkv_mode):
  * "recurrent": exact lax.scan over time — O(1) state, the decode path
    and the correctness oracle.
  * "chunked": intra-chunk pairwise matmuls + inter-chunk state passing —
    the TPU/MXU path.  All exponents are differences of the in-chunk
    cumulative log-decay, with log-decay clamped to [-2.5, -1e-4] and
    chunk <= 32 so every factor stays inside fp32 range (|L| span <= 80).
    Validated against "recurrent" in tests to 1e-4.

long_500k runnability comes from here: decode state is O(H*N^2), not O(S).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import mlp as mlp_mod
from repro.models.common import ParamSpec, rms_norm

LW_MIN, LW_MAX = -2.5, -1e-4
DECAY_LORA = 64


def rwkv_specs(cfg, stacked: int | None) -> dict:
    lead = (stacked,) if stacked else ()
    lx = ("layers",) if stacked else ()
    D = cfg.d_model
    H = D // cfg.rwkv_head_dim
    N = cfg.rwkv_head_dim
    return {
        "mu": ParamSpec(lead + (5, D), lx + (None, "embed"), init="ones"),
        "w_base": ParamSpec(lead + (D,), lx + ("embed",), init="zeros"),
        "w_lora_a": ParamSpec(lead + (D, DECAY_LORA), lx + ("embed", None), scale=0.1),
        "w_lora_b": ParamSpec(lead + (DECAY_LORA, D), lx + (None, "embed"), scale=0.1),
        "wr": ParamSpec(lead + (D, D), lx + ("embed", "qkv")),
        "wk": ParamSpec(lead + (D, D), lx + ("embed", "qkv")),
        "wv": ParamSpec(lead + (D, D), lx + ("embed", "qkv")),
        "wg": ParamSpec(lead + (D, D), lx + ("embed", "qkv")),
        "u": ParamSpec(lead + (H, N), lx + ("heads", None), init="zeros"),
        "ln_x": ParamSpec(lead + (D,), lx + ("embed",), init="zeros"),
        "wo": ParamSpec(lead + (D, D), lx + ("qkv", "embed")),
    }


def _rkvwg(cfg, p, x, x_prev):
    """Token-shift lerp + projections. x:[B,S,D] -> r,k,v,g:[B,H,S,N], lw:[B,H,S,N]."""
    B, S, D = x.shape
    H, N = D // cfg.rwkv_head_dim, cfg.rwkv_head_dim
    mu = p["mu"].astype(x.dtype)  # (5,D)
    xs = [x + (x_prev - x) * mu[i] for i in range(5)]
    xr, xk, xv, xw, xg = xs
    r = xr @ p["wr"].astype(x.dtype)
    k = xk @ p["wk"].astype(x.dtype)
    v = xv @ p["wv"].astype(x.dtype)
    g = xg @ p["wg"].astype(x.dtype)
    w_raw = (p["w_base"].astype(jnp.float32)
             + jnp.tanh(xw.astype(jnp.float32) @ p["w_lora_a"].astype(jnp.float32))
             @ p["w_lora_b"].astype(jnp.float32))
    lw = -jnp.exp(w_raw)                       # log decay, negative
    lw = jnp.clip(lw, LW_MIN, LW_MAX)

    def heads(t):
        return t.reshape(B, S, H, N).transpose(0, 2, 1, 3)

    return heads(r), heads(k), heads(v), g, heads(lw)


def wkv_recurrent(r, k, v, lw, u, state):
    """Exact recurrence. r/k/v/lw: [B,H,S,N]; u: [H,N]; state: [B,H,N,N].

    Returns (y [B,H,S,N], new_state)."""
    def step(S_c, inp):
        r_t, k_t, v_t, lw_t = inp  # each (B,H,N)
        kv = k_t[..., :, None] * v_t[..., None, :]          # (B,H,N,N)
        y = jnp.einsum("bhi,bhij->bhj", r_t,
                       S_c + u[None, :, :, None] * kv)
        S_n = jnp.exp(lw_t)[..., :, None] * S_c + kv
        return S_n, y

    xs = jax.tree.map(lambda t: t.transpose(2, 0, 1, 3).astype(jnp.float32),
                      (r, k, v, lw))
    state, ys = jax.lax.scan(step, state.astype(jnp.float32), xs)
    return ys.transpose(1, 2, 0, 3), state


def wkv_chunked(r, k, v, lw, u, state, chunk: int):
    """Chunked-parallel WKV6; math in the module docstring.

    Intra-chunk pair matrix A[t,j] = sum_i r_t[i] k_j[i] e^{L[t-1,i]-L[j,i]}
    (strictly j<t), diagonal handled by the bonus term; inter-chunk via the
    decayed state.  All in fp32.
    """
    B, H, S, N = r.shape
    C = chunk
    assert S % C == 0, (S, C)
    nc = S // C
    f32 = jnp.float32

    def to_chunks(t):
        return t.reshape(B, H, nc, C, N).transpose(2, 0, 1, 3, 4).astype(f32)

    rc, kc, vc, lwc = map(to_chunks, (r, k, v, lw))

    def chunk_step(S_c, inp):
        rr, kk, vv, ll = inp                      # (B,H,C,N)
        L = jnp.cumsum(ll, axis=2)                # inclusive cumulative log-decay
        L_prev = L - ll                           # L_{t-1} (exclusive)
        L_last = L[:, :, -1:, :]                  # (B,H,1,N)

        r_in = rr * jnp.exp(L_prev)               # bounded <= |r|
        k_out = kk * jnp.exp(L_last - L)          # bounded <= |k|
        k_in = kk * jnp.exp(-L)                   # up to e^{80}: fp32-safe
        # pairwise scores, strictly lower-triangular
        A = jnp.einsum("bhti,bhji->bhtj", r_in, k_in)
        tri = jnp.tril(jnp.ones((C, C), bool), k=-1)
        A = jnp.where(tri[None, None], A, 0.0)
        y_intra = jnp.einsum("bhtj,bhjn->bhtn", A, vv)
        # diagonal (bonus) term: (sum_i r_t[i] u[i] k_t[i]) * v_t
        y_diag = (rr * u[None, :, None, :] * kk).sum(-1, keepdims=True) * vv
        y_inter = jnp.einsum("bhti,bhin->bhtn", r_in, S_c)
        S_n = jnp.exp(L_last)[..., 0, :][..., :, None] * S_c + jnp.einsum(
            "bhti,bhtn->bhin", k_out, vv)
        return S_n, y_intra + y_diag + y_inter

    state, ys = jax.lax.scan(jax.remat(chunk_step), state.astype(f32),
                             (rc, kc, vc, lwc))
    y = ys.transpose(1, 2, 0, 3, 4).reshape(B, H, S, N)
    return y, state


def rwkv_apply(cfg, p, x, *, x_prev=None, state=None):
    """Full-sequence time-mix. x:[B,S,D] -> (y [B,S,D], final_state)."""
    B, S, D = x.shape
    H, N = D // cfg.rwkv_head_dim, cfg.rwkv_head_dim
    if x_prev is None:
        x_prev = mlp_mod.token_shift(x)
    if state is None:
        state = jnp.zeros((B, H, N, N), jnp.float32)
    r, k, v, g, lw = _rkvwg(cfg, p, x, x_prev)
    u = p["u"].astype(jnp.float32)
    if cfg.rwkv_mode == "chunked" and S % cfg.rwkv_chunk == 0 and S > 1:
        y, state = wkv_chunked(r, k, v, lw, u, state, cfg.rwkv_chunk)
    else:
        y, state = wkv_recurrent(r, k, v, lw, u, state)
    y = y.transpose(0, 2, 1, 3).reshape(B, S, D)
    y = rms_norm(y.astype(x.dtype), p["ln_x"])
    y = y * jax.nn.silu(g)
    return y @ p["wo"].astype(x.dtype), state


def rwkv_decode(cfg, p, x, cache: dict):
    """Single-token decode: O(1) state, no KV growth (the 500k story).

    cache: {"state": [B,H,N,N] f32, "x_prev": [B,1,D], "cx_prev": [B,1,D]}
    (cx_prev is consumed by the channel-mix in transformer.py).
    """
    y, state = rwkv_apply(cfg, p, x, x_prev=cache["x_prev"], state=cache["state"])
    new_cache = dict(cache)
    new_cache["state"] = state
    new_cache["x_prev"] = x
    return y, new_cache
