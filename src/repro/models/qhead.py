"""Q-network heads for the DQN agent family (paper Sec. 2.4 test vehicle).

The agent layer composes two orthogonal axes (see :mod:`repro.rl.dqn`):
the *head* (this module) maps observations to Q-values, and the
*target rule* (vanilla max vs Double-DQN argmax decoupling) turns those
Q-values into TD targets.  Heads are pure init/apply pairs over plain
pytrees, so they jit, vmap (``train_many`` runs whole training sweeps
data-parallel over seeds) and checkpoint with zero glue:

* ``"mlp"``     — the 3-layer MLP of the paper's setup (Sec. 4.1.2),
  bit-identical to the pre-family network so existing learning pins
  keep their trajectories.
* ``"dueling"`` — Wang et al.'s dueling decomposition: a shared trunk
  feeding separate state-value and advantage streams, recombined as
  ``Q(s, a) = V(s) + A(s, a) - mean_a A(s, a)`` (the identifiable form).
* ``"conv"`` / ``"conv-dueling"`` — the pixel-tier counterparts: a
  MinAtar-scale conv trunk (one 3x3 VALID conv to 16 channels, ReLU,
  flatten, dense) feeding the same output structure.  Built for
  ``[H, W, C]`` observations where C is the frame-stack depth
  materialized by the replay buffer's frame store.

Vector heads accept a single observation ``[obs_dim]`` or a batch
``[B, obs_dim]``; conv heads accept ``[H, W, C]`` or ``[B, H, W, C]``.
All return Q-values with ``n_actions`` on the last axis — the contract
the actor's argmax and the learner's ``take_along_axis`` rely on.

``make_qhead`` takes an ``obs_shape`` tuple (``(obs_dim,)`` for vector
heads); a bare int is accepted for back-compat with pre-pixel call
sites, as is the deprecated ``obs_dim=`` keyword alias.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

HEAD_KINDS = ("mlp", "dueling", "conv", "conv-dueling")

CONV_CHANNELS = 16
CONV_K = 3


def mlp_init(key, sizes):
    """He-initialised dense stack (ReLU between layers, linear output)."""
    params = []
    for a, b in zip(sizes[:-1], sizes[1:]):
        k1, key = jax.random.split(key)
        params.append({
            "w": jax.random.normal(k1, (a, b)) * (2.0 / a) ** 0.5,
            "b": jnp.zeros(b),
        })
    return params


def mlp_apply(params, x):
    for i, layer in enumerate(params):
        x = x @ layer["w"] + layer["b"]
        if i < len(params) - 1:
            x = jax.nn.relu(x)
    return x


def conv_init(key, in_channels: int):
    """He-initialised 3x3 VALID conv, ``in_channels -> CONV_CHANNELS``."""
    fan_in = CONV_K * CONV_K * in_channels
    return {
        "w": jax.random.normal(key, (CONV_K, CONV_K, in_channels,
                                     CONV_CHANNELS))
        * (2.0 / fan_in) ** 0.5,
        "b": jnp.zeros(CONV_CHANNELS),
    }


def conv_apply(params, x):
    """[B, H, W, C] -> [B, H-2, W-2, CONV_CHANNELS], ReLU'd."""
    y = jax.lax.conv_general_dilated(
        x, params["w"], window_strides=(1, 1), padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return jax.nn.relu(y + params["b"])


def _flat_conv_dim(obs_shape) -> int:
    h, w, _ = obs_shape
    if h <= CONV_K - 1 or w <= CONV_K - 1:
        raise ValueError(
            f"obs_shape {obs_shape} too small for a {CONV_K}x{CONV_K} "
            "VALID conv")
    return (h - CONV_K + 1) * (w - CONV_K + 1) * CONV_CHANNELS


def _batched(apply):
    """Wrap a batch-only conv apply so a single [H, W, C] obs also works."""

    def wrapped(params, x):
        if x.ndim == 3:
            return apply(params, x[None])[0]
        return apply(params, x)

    return wrapped


class QHead(NamedTuple):
    """An init/apply pair mapping observations to Q-values."""

    kind: str
    init: Callable[[jax.Array], Any]     # key -> params pytree
    apply: Callable[[Any, jax.Array], jax.Array]  # (params, obs) -> q


def make_qhead(kind: str, obs_shape=None, hidden: int = 128,
               n_actions: int = 2, *, obs_dim=None) -> QHead:
    """Build a Q-head by kind (see :data:`HEAD_KINDS`).

    ``obs_shape`` is a shape tuple: ``(obs_dim,)`` for the vector heads,
    ``(H, W, C)`` for the conv heads.  A bare int (or the deprecated
    ``obs_dim=`` keyword) is normalized to a 1-tuple.
    """
    if obs_shape is None:
        obs_shape = obs_dim
    if obs_shape is None:
        raise ValueError("make_qhead requires obs_shape")
    if isinstance(obs_shape, int):
        obs_shape = (obs_shape,)
    obs_shape = tuple(int(d) for d in obs_shape)

    if kind in ("mlp", "dueling"):
        if len(obs_shape) != 1:
            raise ValueError(
                f"{kind!r} head needs a flat (obs_dim,) shape, got "
                f"{obs_shape}; use a conv head for pixel observations")
        (flat,) = obs_shape
    elif kind in ("conv", "conv-dueling"):
        if len(obs_shape) != 3:
            raise ValueError(
                f"{kind!r} head needs an (H, W, C) shape, got {obs_shape}")
        flat = _flat_conv_dim(obs_shape)

    if kind == "mlp":
        def init(key):
            return mlp_init(key, [flat, hidden, hidden, n_actions])

        return QHead(kind=kind, init=init, apply=mlp_apply)

    if kind == "dueling":
        def init(key):
            k_trunk, k_v, k_a = jax.random.split(key, 3)
            return {
                "trunk": mlp_init(k_trunk, [flat, hidden, hidden]),
                "value": mlp_init(k_v, [hidden, 1]),
                "adv": mlp_init(k_a, [hidden, n_actions]),
            }

        def apply(params, x):
            h = x
            for layer in params["trunk"]:
                h = jax.nn.relu(h @ layer["w"] + layer["b"])
            v = mlp_apply(params["value"], h)
            a = mlp_apply(params["adv"], h)
            return v + a - jnp.mean(a, axis=-1, keepdims=True)

        return QHead(kind=kind, init=init, apply=apply)

    if kind == "conv":
        def init(key):
            k_c, k_d = jax.random.split(key)
            return {
                "conv": conv_init(k_c, obs_shape[-1]),
                "dense": mlp_init(k_d, [flat, hidden, n_actions]),
            }

        def apply(params, x):
            h = conv_apply(params["conv"], x)
            h = h.reshape(h.shape[0], -1)
            return mlp_apply(params["dense"], h)

        return QHead(kind=kind, init=init, apply=_batched(apply))

    if kind == "conv-dueling":
        def init(key):
            k_c, k_t, k_v, k_a = jax.random.split(key, 4)
            return {
                "conv": conv_init(k_c, obs_shape[-1]),
                "trunk": mlp_init(k_t, [flat, hidden]),
                "value": mlp_init(k_v, [hidden, 1]),
                "adv": mlp_init(k_a, [hidden, n_actions]),
            }

        def apply(params, x):
            h = conv_apply(params["conv"], x)
            h = h.reshape(h.shape[0], -1)
            for layer in params["trunk"]:
                h = jax.nn.relu(h @ layer["w"] + layer["b"])
            v = mlp_apply(params["value"], h)
            a = mlp_apply(params["adv"], h)
            return v + a - jnp.mean(a, axis=-1, keepdims=True)

        return QHead(kind=kind, init=init, apply=_batched(apply))

    raise ValueError(
        f"unknown Q-head kind: {kind!r} (available: {list(HEAD_KINDS)})")
