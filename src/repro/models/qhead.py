"""Q-network heads for the DQN agent family (paper Sec. 2.4 test vehicle).

The agent layer composes two orthogonal axes (see :mod:`repro.rl.dqn`):
the *head* (this module) maps observations to Q-values, and the
*target rule* (vanilla max vs Double-DQN argmax decoupling) turns those
Q-values into TD targets.  Heads are pure init/apply pairs over plain
pytrees, so they jit, vmap (``train_many`` runs whole training sweeps
data-parallel over seeds) and checkpoint with zero glue:

* ``"mlp"``     — the 3-layer MLP of the paper's setup (Sec. 4.1.2),
  bit-identical to the pre-family network so existing learning pins
  keep their trajectories.
* ``"dueling"`` — Wang et al.'s dueling decomposition: a shared trunk
  feeding separate state-value and advantage streams, recombined as
  ``Q(s, a) = V(s) + A(s, a) - mean_a A(s, a)`` (the identifiable form).

Both accept a single observation ``[obs_dim]`` or a batch
``[B, obs_dim]`` and return Q-values with ``n_actions`` on the last
axis — the contract the actor's argmax and the learner's
``take_along_axis`` rely on.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

HEAD_KINDS = ("mlp", "dueling")


def mlp_init(key, sizes):
    """He-initialised dense stack (ReLU between layers, linear output)."""
    params = []
    for a, b in zip(sizes[:-1], sizes[1:]):
        k1, key = jax.random.split(key)
        params.append({
            "w": jax.random.normal(k1, (a, b)) * (2.0 / a) ** 0.5,
            "b": jnp.zeros(b),
        })
    return params


def mlp_apply(params, x):
    for i, layer in enumerate(params):
        x = x @ layer["w"] + layer["b"]
        if i < len(params) - 1:
            x = jax.nn.relu(x)
    return x


class QHead(NamedTuple):
    """An init/apply pair mapping observations to Q-values."""

    kind: str
    init: Callable[[jax.Array], Any]     # key -> params pytree
    apply: Callable[[Any, jax.Array], jax.Array]  # (params, obs) -> q


def make_qhead(kind: str, obs_dim: int, hidden: int,
               n_actions: int) -> QHead:
    """Build a Q-head by kind (``"mlp"`` or ``"dueling"``)."""
    if kind == "mlp":
        def init(key):
            return mlp_init(key, [obs_dim, hidden, hidden, n_actions])

        return QHead(kind=kind, init=init, apply=mlp_apply)

    if kind == "dueling":
        def init(key):
            k_trunk, k_v, k_a = jax.random.split(key, 3)
            return {
                "trunk": mlp_init(k_trunk, [obs_dim, hidden, hidden]),
                "value": mlp_init(k_v, [hidden, 1]),
                "adv": mlp_init(k_a, [hidden, n_actions]),
            }

        def apply(params, x):
            h = x
            for layer in params["trunk"]:
                h = jax.nn.relu(h @ layer["w"] + layer["b"])
            v = mlp_apply(params["value"], h)
            a = mlp_apply(params["adv"], h)
            return v + a - jnp.mean(a, axis=-1, keepdims=True)

        return QHead(kind=kind, init=init, apply=apply)

    raise ValueError(
        f"unknown Q-head kind: {kind!r} (available: {list(HEAD_KINDS)})")
