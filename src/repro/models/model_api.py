"""Unified model API: one façade over every family in the zoo.

``Model.from_config(cfg)`` gives: param specs/init/axes, the training
loss, prefill and decode entry points, cache constructors, and
``input_specs(shape)`` — ShapeDtypeStruct stand-ins for every input of
every assigned (arch x shape) cell, which is what the multi-pod dry-run
lowers against (no allocation).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.models import common, encdec, transformer


class ShapeCell(NamedTuple):
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPE_CELLS = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: Any

    @staticmethod
    def from_config(cfg) -> "Model":
        return Model(cfg)

    # ---------------- params ----------------
    def param_specs(self):
        if self.cfg.family == "audio":
            specs = encdec.encdec_param_specs(self.cfg)
        else:
            specs = transformer.lm_param_specs(self.cfg)
        if self.cfg.param_dtype == "bfloat16":
            specs = jax.tree.map(
                lambda sp: sp._replace(dtype=jnp.bfloat16)
                if sp.dtype == jnp.float32 else sp,
                specs, is_leaf=lambda x: isinstance(x, common.ParamSpec))
        return specs

    def init_params(self, key):
        return common.init_params(key, self.param_specs())

    def abstract_params(self):
        return common.abstract_params(self.param_specs())

    def param_axes(self):
        return common.param_axes(self.param_specs())

    # ---------------- training ----------------
    def loss(self, params, batch):
        if self.cfg.family == "audio":
            return encdec.encdec_loss(self.cfg, params, batch)
        return transformer.lm_loss(self.cfg, params, batch)

    # ---------------- serving ----------------
    def prefill(self, params, batch, max_len: int):
        if self.cfg.family == "audio":
            return encdec.prefill(self.cfg, params, batch["frames"],
                                  batch["tokens"][:, :1], max_len)
        return transformer.prefill(self.cfg, params, batch["tokens"], max_len,
                                   extra_embeds=batch.get("patch_embeds"))

    def decode_step(self, params, tokens, cache):
        if self.cfg.family == "audio":
            return encdec.decode_step(self.cfg, params, tokens, cache)
        return transformer.decode_step(self.cfg, params, tokens, cache)

    def init_cache(self, batch: int, max_len: int):
        if self.cfg.family == "audio":
            return encdec.init_cache(self.cfg, batch, max_len)
        return transformer.init_cache(self.cfg, batch, max_len)

    def cache_axes(self):
        cfg = self.cfg
        if cfg.family == "audio":
            return encdec.encdec_cache_axes(cfg)
        one = transformer.cache_axes(cfg)
        n_dense = cfg.first_dense_layers if cfg.n_experts else 0
        stacked = {k: ("layers",) + v for k, v in one.items()}
        out = {"blocks": stacked, "len": ()}
        if n_dense:
            out["dense_blocks"] = stacked
        return out

    # ---------------- dry-run input specs ----------------
    def input_specs(self, shape_name: str) -> dict:
        """ShapeDtypeStruct stand-ins for the given assigned shape cell."""
        cfg = self.cfg
        cell = SHAPE_CELLS[shape_name]
        B, S = cell.global_batch, cell.seq_len
        dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
        tok = lambda b, s: jax.ShapeDtypeStruct((b, s), jnp.int32)
        f = lambda *sh: jax.ShapeDtypeStruct(sh, dt)

        if cell.kind == "train":
            if cfg.family == "audio":
                return {"tokens": tok(B, S), "targets": tok(B, S),
                        "loss_mask": jax.ShapeDtypeStruct((B, S), jnp.float32),
                        "frames": f(B, cfg.enc_seq, cfg.d_model)}
            batch = {"tokens": tok(B, S), "targets": tok(B, S),
                     "loss_mask": jax.ShapeDtypeStruct((B, S), jnp.float32)}
            if cfg.vis_prefix_len:
                st = S - cfg.vis_prefix_len  # total positions == seq_len
                batch.update(tokens=tok(B, st), targets=tok(B, st),
                             loss_mask=jax.ShapeDtypeStruct((B, st), jnp.float32),
                             patch_embeds=f(B, cfg.vis_prefix_len, cfg.d_model))
            return batch
        if cell.kind == "prefill":
            if cfg.family == "audio":
                return {"tokens": tok(B, S), "frames": f(B, cfg.enc_seq, cfg.d_model)}
            batch = {"tokens": tok(B, S)}
            if cfg.vis_prefix_len:
                batch = {"tokens": tok(B, S - cfg.vis_prefix_len),
                         "patch_embeds": f(B, cfg.vis_prefix_len, cfg.d_model)}
            return batch
        # decode: one new token against a seq_len cache
        cache = jax.eval_shape(lambda: self.init_cache(B, S))
        return {"tokens": tok(B, 1), "cache": cache}
