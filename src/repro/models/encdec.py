"""Whisper-style encoder-decoder backbone (audio frontend is a stub).

Per the assignment, ``input_specs()`` provides precomputed log-mel frame
EMBEDDINGS (B, enc_seq, D) — the conv frontend is out of scope.  The
backbone is faithful: pre-LN transformer, GELU MLPs, LayerNorm,
bidirectional encoder self-attention, causal decoder self-attention +
cross-attention, sinusoidal positions (whisper uses sinusoidal encoder /
learned decoder positions; we use sinusoidal for both so the backbone is
length-agnostic at the assigned 4k/32k decoder shapes — noted in
DESIGN.md).

Decode caches: per decoder layer, self-attn K/V plus the cross-attn K/V
computed ONCE from the encoder output at prefill.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models import mlp as mlp_mod
from repro.models.common import (ParamSpec, apply_norm, norm_spec,
                                 scan_layers, sinusoidal_embedding)
from repro.models.transformer import _adtype, unembed


def encdec_param_specs(cfg) -> dict:
    L_enc, L_dec, D = cfg.n_enc_layers, cfg.n_layers, cfg.d_model
    enc_block = {
        "norm1": norm_spec(cfg.norm_kind, D, L_enc),
        "attn": attn_mod.gqa_specs(cfg, L_enc),
        "norm2": norm_spec(cfg.norm_kind, D, L_enc),
        "mlp": mlp_mod.mlp_specs("gelu", D, cfg.d_ff, L_enc),
    }
    dec_block = {
        "norm1": norm_spec(cfg.norm_kind, D, L_dec),
        "self": attn_mod.gqa_specs(cfg, L_dec),
        "norm_x": norm_spec(cfg.norm_kind, D, L_dec),
        "cross": attn_mod.gqa_specs(cfg, L_dec),
        "norm2": norm_spec(cfg.norm_kind, D, L_dec),
        "mlp": mlp_mod.mlp_specs("gelu", D, cfg.d_ff, L_dec),
    }
    return {
        "embed": ParamSpec((cfg.vocab_size, D), ("vocab", "embed"), init="embed"),
        "enc_blocks": enc_block,
        "enc_norm": norm_spec(cfg.norm_kind, D),
        "dec_blocks": dec_block,
        "dec_norm": norm_spec(cfg.norm_kind, D),
    }


def encode(cfg, params, frames):
    """frames: [B, enc_seq, D] stub embeddings -> encoder states."""
    B, T, D = frames.shape
    x = frames.astype(_adtype(cfg))
    x = x + sinusoidal_embedding(jnp.arange(T), D).astype(x.dtype)[None]
    positions = jnp.broadcast_to(jnp.arange(T), (B, T))
    mask_fn = attn_mod.make_mask_fn(False, None, None)

    def body(carry, lp):
        h = apply_norm(cfg.norm_kind, carry, lp["norm1"])
        a = attn_mod.gqa_apply(cfg, lp["attn"], h, positions, mask_fn,
                               rope=False)
        x = carry + a
        h2 = apply_norm(cfg.norm_kind, x, lp["norm2"])
        return x + mlp_mod.mlp_apply("gelu", lp["mlp"], h2), None

    if cfg.remat:
        body = jax.remat(body, prevent_cse=False)
    x, _ = scan_layers(body, x, params["enc_blocks"],
                       unroll=cfg.unroll_layers)
    return apply_norm(cfg.norm_kind, x, params["enc_norm"])


def _cross_kv(cfg, lp_cross, enc_out):
    """Encoder states -> per-layer cross K/V (no rope, whisper-style)."""
    B, T, D = enc_out.shape
    Hkv, Hd = cfg.n_kv_heads, cfg.head_dim
    k = (enc_out @ lp_cross["wk"].astype(enc_out.dtype)).reshape(B, T, Hkv, Hd)
    v = (enc_out @ lp_cross["wv"].astype(enc_out.dtype)).reshape(B, T, Hkv, Hd)
    return k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3)


def _dec_block(cfg, lp, x, positions, enc_out, mask_fn, cross_mask_fn):
    h = apply_norm(cfg.norm_kind, x, lp["norm1"])
    x = x + attn_mod.gqa_apply(cfg, lp["self"], h, positions, mask_fn,
                               rope=False)
    hx = apply_norm(cfg.norm_kind, x, lp["norm_x"])
    ck, cv = _cross_kv(cfg, lp["cross"], enc_out)
    x = x + attn_mod.gqa_apply(cfg, lp["cross"], hx, positions, cross_mask_fn,
                               rope=False, kv_override=(ck, cv))
    h2 = apply_norm(cfg.norm_kind, x, lp["norm2"])
    return x + mlp_mod.mlp_apply("gelu", lp["mlp"], h2)


def forward(cfg, params, tokens, frames):
    """Teacher-forced decoder logits [B, S, V]."""
    enc_out = encode(cfg, params, frames)
    B, S = tokens.shape
    x = params["embed"].astype(_adtype(cfg))[tokens]
    x = x + sinusoidal_embedding(jnp.arange(S), cfg.d_model).astype(x.dtype)[None]
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    mask_fn = attn_mod.make_mask_fn(True, None, None)
    cross_mask_fn = attn_mod.make_mask_fn(False, None, None)

    def body(carry, lp):
        return _dec_block(cfg, lp, carry, positions, enc_out, mask_fn,
                          cross_mask_fn), None

    if cfg.remat:
        body = jax.remat(body, prevent_cse=False)
    x, _ = scan_layers(body, x, params["dec_blocks"],
                       unroll=cfg.unroll_layers)
    x = apply_norm(cfg.norm_kind, x, params["dec_norm"])
    return unembed(cfg, params, x)


def encdec_loss(cfg, params, batch):
    logits = forward(cfg, params, batch["tokens"], batch["frames"])
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, batch["targets"][..., None], -1)[..., 0]
    mask = batch["loss_mask"].astype(jnp.float32)
    loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return loss, {"nll": loss}


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------

def init_cache(cfg, batch: int, max_len: int):
    dt = _adtype(cfg)
    Hkv, Hd, L = cfg.n_kv_heads, cfg.head_dim, cfg.n_layers

    def z(*shape):
        return jnp.zeros(shape, dt)

    return {
        "self_k": z(L, batch, Hkv, max_len, Hd),
        "self_v": z(L, batch, Hkv, max_len, Hd),
        "cross_k": z(L, batch, Hkv, cfg.enc_seq, Hd),
        "cross_v": z(L, batch, Hkv, cfg.enc_seq, Hd),
        "len": jnp.int32(0),
    }


def encdec_cache_axes(cfg) -> dict:
    return {"self_k": ("layers", "batch", None, "kv_seq", None),
            "self_v": ("layers", "batch", None, "kv_seq", None),
            "cross_k": ("layers", "batch", None, "kv_seq", None),
            "cross_v": ("layers", "batch", None, "kv_seq", None),
            "len": ()}


def prefill(cfg, params, frames, bos_tokens, max_len: int):
    """Encode + compute cross K/V for every decoder layer + first token.

    bos_tokens: [B, 1]."""
    enc_out = encode(cfg, params, frames)

    def kv_body(_, lp_cross):
        return None, _cross_kv(cfg, lp_cross, enc_out)

    _, (ck, cv) = jax.lax.scan(kv_body, None, params["dec_blocks"]["cross"])
    cache = init_cache(cfg, bos_tokens.shape[0], max_len)
    cache["cross_k"], cache["cross_v"] = ck, cv
    logits, cache = decode_step(cfg, params, bos_tokens, cache)
    return logits, cache


def decode_step(cfg, params, tokens, cache):
    """One decoder token against cached self/cross K/V."""
    B = tokens.shape[0]
    pos = cache["len"]
    x = params["embed"].astype(_adtype(cfg))[tokens]
    x = x + sinusoidal_embedding(pos[None, None], cfg.d_model).astype(x.dtype)
    mask_fn = attn_mod.make_mask_fn(True, None, None)
    cross_mask = attn_mod.make_mask_fn(False, None, None)

    def body(carry, xs):
        lp, sk, sv, ck, cv = xs
        h = apply_norm(cfg.norm_kind, carry, lp["norm1"])
        a, st = attn_mod.gqa_decode(cfg, lp["self"], h,
                                    {"k": sk, "v": sv, "len": pos}, mask_fn,
                                    rope=False)
        x = carry + a
        hx = apply_norm(cfg.norm_kind, x, lp["norm_x"])
        qx = attn_mod.gqa_project(cfg, lp["cross"], hx,
                                  jnp.zeros((B, 1), jnp.int32), rope=False)[0]
        o = attn_mod.decode_attention(qx, ck, cv, jnp.int32(cfg.enc_seq),
                                      cross_mask)
        o = o.reshape(B, 1, cfg.n_heads * cfg.head_dim)
        x = x + o @ lp["cross"]["wo"].astype(x.dtype)
        h2 = apply_norm(cfg.norm_kind, x, lp["norm2"])
        x = x + mlp_mod.mlp_apply("gelu", lp["mlp"], h2)
        return x, (st["k"], st["v"])

    x, (nk, nv) = scan_layers(
        body, x, (params["dec_blocks"], cache["self_k"], cache["self_v"],
                  cache["cross_k"], cache["cross_v"]),
        unroll=cfg.unroll_layers)
    new_cache = dict(cache, self_k=nk, self_v=nv, len=pos + 1)
    x = apply_norm(cfg.norm_kind, x, params["dec_norm"])
    return unembed(cfg, params, x), new_cache
