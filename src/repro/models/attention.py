"""Attention blocks: GQA/MQA (+SWA, prefix-LM) and MLA, train & decode paths.

The differentiable training/prefill path is *chunked* (online-softmax over
KV blocks inside a ``lax.scan``, with ``jax.remat`` on the inner step so
the backward pass recomputes per-block probabilities instead of storing
S^2 residuals).  The Pallas flash kernel (kernels/flash_attention.py) is
the serving fast path; both agree with kernels/ref.py.

Masks are expressed as position predicates so causal, sliding-window
(possibly per-layer dynamic, for hymba's global/SWA mix) and prefix-LM
(paligemma) all flow through one code path.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.models import common
from repro.models.common import ParamSpec

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Mask predicates
# ---------------------------------------------------------------------------

def make_mask_fn(causal: bool, window, prefix_len) -> Callable:
    """Returns mask_fn(qpos, kpos) -> bool. window/prefix_len may be traced."""

    def mask_fn(qpos: jax.Array, kpos: jax.Array) -> jax.Array:
        ok = jnp.ones(jnp.broadcast_shapes(qpos.shape, kpos.shape), jnp.bool_)
        if causal:
            ok &= qpos >= kpos
        if window is not None:
            ok &= (qpos - kpos) < window
        if prefix_len is not None:
            ok |= kpos < prefix_len  # bidirectional over the prefix
            ok &= kpos <= jnp.maximum(qpos, prefix_len - 1) if causal else ok
        return ok

    return mask_fn


# ---------------------------------------------------------------------------
# Chunked (memory-efficient, differentiable) attention
# ---------------------------------------------------------------------------

def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                      mask_fn: Callable, *, bq: int, bkv: int,
                      q_offset: int = 0,
                      skip_info: Optional[tuple] = None) -> jax.Array:
    """Online-softmax attention. q:[B,Hq,S,D] k,v:[B,Hkv,Skv,Dv].

    Memory per step is O(bq*bkv); the inner step is remat'd so backward
    never materialises S^2.  GQA handled by reshaping q into
    (Hkv, group) — no key/value broadcast is materialised.

    skip_info=(causal, window): STATIC mask geometry.  When given (and
    self-attention, q_offset==0), q block i only visits kv blocks in its
    causal/window reach — a python loop with per-block static bounds, so
    fully-masked blocks are never computed (−50% FLOPs causal at S=S_kv,
    more with a window).  Numerically identical to the full sweep.
    """
    B, Hq, S, D = q.shape
    Hkv, Skv, Dv = k.shape[1], k.shape[2], v.shape[3]
    group = Hq // Hkv
    scale = 1.0 / (D ** 0.5)

    s_pad = -S % bq
    skv_pad = -Skv % bkv
    q = jnp.pad(q, ((0, 0), (0, 0), (0, s_pad), (0, 0)))
    k = jnp.pad(k, ((0, 0), (0, 0), (0, skv_pad), (0, 0)))
    v = jnp.pad(v, ((0, 0), (0, 0), (0, skv_pad), (0, 0)))
    nq, nkv = (S + s_pad) // bq, (Skv + skv_pad) // bkv

    qs = q.reshape(B, Hkv, group, nq, bq, D).transpose(3, 0, 1, 2, 4, 5) * scale
    ks = k.reshape(B, Hkv, nkv, bkv, D).transpose(2, 0, 1, 3, 4)
    vs = v.reshape(B, Hkv, nkv, bkv, Dv).transpose(2, 0, 1, 3, 4)
    kpos_pad = jnp.arange(nkv * bkv).reshape(nkv, bkv) >= Skv  # padded kv

    def kv_step_for(qpos, qblk):
        def kv_step(carry, inp):
            ki, kblk, vblk, kpad = inp
            m_prev, l_prev, acc = carry
            s = jnp.einsum("bkgqd,bkud->bkgqu", qblk.astype(jnp.float32),
                           kblk.astype(jnp.float32))
            kpos = ki * bkv + jnp.arange(bkv)
            mask = mask_fn(qpos[:, None], kpos[None, :]) & ~kpad[None, :]
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m_prev - m_new)
            l_new = alpha * l_prev + jnp.sum(p, axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bkgqu,bkud->bkgqd", p, vblk.astype(jnp.float32))
            return (m_new, l_new, acc), None
        return kv_step

    def init_carry():
        return (jnp.full((B, Hkv, group, bq), NEG_INF, jnp.float32),
                jnp.zeros((B, Hkv, group, bq), jnp.float32),
                jnp.zeros((B, Hkv, group, bq, Dv), jnp.float32))

    can_skip = (skip_info is not None and skip_info[0] is True
                and (skip_info[1] is None or isinstance(skip_info[1], int))
                and q_offset == 0 and S == Skv)
    if can_skip:
        window = skip_info[1]
        outs = []
        for qi in range(nq):
            hi = min(-(-((qi + 1) * bq) // bkv), nkv)
            lo = 0 if window is None else max(0, (qi * bq - window) // bkv)
            qpos = qi * bq + jnp.arange(bq)
            (m, l, acc), _ = jax.lax.scan(
                jax.remat(kv_step_for(qpos, qs[qi])), init_carry(),
                (jnp.arange(lo, hi), ks[lo:hi], vs[lo:hi], kpos_pad[lo:hi]))
            outs.append(acc / jnp.maximum(l, 1e-30)[..., None])
        out = jnp.stack(outs)  # (nq, B, Hkv, g, bq, Dv)
    else:
        def one_q_block(args):
            qi, qblk = args
            qpos = q_offset + qi * bq + jnp.arange(bq)
            (m, l, acc), _ = jax.lax.scan(
                jax.remat(kv_step_for(qpos, qblk)), init_carry(),
                (jnp.arange(nkv), ks, vs, kpos_pad))
            return acc / jnp.maximum(l, 1e-30)[..., None]

        out = jax.lax.map(one_q_block, (jnp.arange(nq), qs))
    out = out.transpose(1, 2, 3, 0, 4, 5).reshape(B, Hq, nq * bq, Dv)
    return out[:, :, :S].astype(q.dtype)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     cur_len: jax.Array, mask_fn: Callable) -> jax.Array:
    """Single-position attention against a cache. q:[B,Hq,1,D] caches:[B,Hkv,Smax,D].

    The KV sequence axis may be sharded over the "model" mesh axis —
    the max/sum reductions then compile to the split-KV (flash-decoding)
    collective schedule automatically.
    """
    B, Hq, _, D = q.shape
    Hkv, Smax = k_cache.shape[1], k_cache.shape[2]
    group = Hq // Hkv
    qpos = cur_len - 1
    kpos = jnp.arange(Smax)
    mask = mask_fn(qpos[None], kpos) & (kpos < cur_len)

    qg = q.reshape(B, Hkv, group, D)
    s = jnp.einsum("bkgd,bksd->bkgs", qg.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) / (D ** 0.5)
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bksd->bkgd", p, v_cache.astype(jnp.float32))
    return o.reshape(B, Hq, 1, v_cache.shape[-1]).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA block
# ---------------------------------------------------------------------------

def gqa_specs(cfg, stacked: int | None) -> dict:
    lead = (stacked,) if stacked else ()
    lx = ("layers",) if stacked else ()
    D, Hq, Hkv, Hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    return {
        "wq": ParamSpec(lead + (D, Hq * Hd), lx + ("embed", "qkv")),
        "wk": ParamSpec(lead + (D, Hkv * Hd), lx + ("embed", "kv")),
        "wv": ParamSpec(lead + (D, Hkv * Hd), lx + ("embed", "kv")),
        "wo": ParamSpec(lead + (Hq * Hd, D), lx + ("qkv", "embed")),
    }


def gqa_project(cfg, p, x, positions, *, rope: bool = True):
    """x:[B,S,D] -> q:[B,Hq,S,Hd], k/v:[B,Hkv,S,Hd] (roped)."""
    B, S, _ = x.shape
    Hq, Hkv, Hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ p["wq"].astype(x.dtype)).reshape(B, S, Hq, Hd).transpose(0, 2, 1, 3)
    k = (x @ p["wk"].astype(x.dtype)).reshape(B, S, Hkv, Hd).transpose(0, 2, 1, 3)
    v = (x @ p["wv"].astype(x.dtype)).reshape(B, S, Hkv, Hd).transpose(0, 2, 1, 3)
    if rope:
        q = common.apply_rope(q, positions[:, None], cfg.rope_theta)
        k = common.apply_rope(k, positions[:, None], cfg.rope_theta)
    return q, k, v


def gqa_apply(cfg, p, x, positions, mask_fn, *, rope: bool = True,
              kv_override=None, return_kv: bool = False, skip_info=None):
    """Full-sequence GQA/MQA/MHA attention (training / prefill).

    kv_override: (k, v) from an encoder for cross-attention.
    return_kv: also return (k, v) for cache construction at prefill.
    """
    B, S, D = x.shape
    q, k, v = gqa_project(cfg, p, x, positions, rope=rope)
    if kv_override is not None:
        k, v = kv_override
    out = chunked_attention(q, k, v, mask_fn, bq=min(cfg.q_block, S),
                            bkv=min(cfg.kv_block, k.shape[2]),
                            skip_info=None if kv_override is not None
                            else skip_info)
    out = out.transpose(0, 2, 1, 3).reshape(B, S, cfg.n_heads * cfg.head_dim)
    out = out @ p["wo"].astype(x.dtype)
    if return_kv:
        return out, (k, v)
    return out


def gqa_decode(cfg, p, x, cache: dict, mask_fn, *, rope: bool = True):
    """One-token decode. x:[B,1,D]; cache: {k:[B,Hkv,Smax,Hd], v:..., len}."""
    B = x.shape[0]
    Hq, Hkv, Hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    pos = cache["len"]  # int32 scalar: tokens already in cache
    positions = jnp.broadcast_to(pos, (B, 1))
    q, k, v = gqa_project(cfg, p, x, positions, rope=rope)
    k_cache = jax.lax.dynamic_update_slice(cache["k"], k, (0, 0, pos, 0))
    v_cache = jax.lax.dynamic_update_slice(cache["v"], v, (0, 0, pos, 0))
    out = decode_attention(q, k_cache, v_cache, pos + 1, mask_fn)
    out = out.reshape(B, 1, Hq * Hd) @ p["wo"].astype(x.dtype)
    return out, {"k": k_cache, "v": v_cache, "len": pos + 1}


# ---------------------------------------------------------------------------
# MLA block (deepseek-v2): latent-compressed KV
# ---------------------------------------------------------------------------

def mla_specs(cfg, stacked: int | None) -> dict:
    lead = (stacked,) if stacked else ()
    lx = ("layers",) if stacked else ()
    D, H = cfg.d_model, cfg.n_heads
    r, nope, rdim, vdim = cfg.kv_lora_rank, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    return {
        "wq": ParamSpec(lead + (D, H * (nope + rdim)), lx + ("embed", "qkv")),
        "wkv_a": ParamSpec(lead + (D, r + rdim), lx + ("embed", None)),
        "kv_norm": ParamSpec(lead + (r,), lx + (None,), init="zeros"),
        "wkv_b": ParamSpec(lead + (r, H * (nope + vdim)), lx + (None, "qkv")),
        "wo": ParamSpec(lead + (H * vdim, D), lx + ("qkv", "embed")),
    }


def _mla_qkv(cfg, p, x, positions):
    B, S, D = x.shape
    H = cfg.n_heads
    r, nope, rdim, vdim = cfg.kv_lora_rank, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    q = (x @ p["wq"].astype(x.dtype)).reshape(B, S, H, nope + rdim)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = common.apply_rope(q_rope.transpose(0, 2, 1, 3), positions[:, None],
                               cfg.rope_theta).transpose(0, 2, 1, 3)

    kv = x @ p["wkv_a"].astype(x.dtype)
    c_kv, k_rope = kv[..., :r], kv[..., r:]
    c_kv = common.rms_norm(c_kv, p["kv_norm"])
    k_rope = common.apply_rope(k_rope[:, None], positions[:, None],
                               cfg.rope_theta)[:, 0]  # (B,S,rdim) shared head
    return q_nope, q_rope, c_kv, k_rope


def _mla_expand_kv(cfg, p, c_kv, dtype):
    """Latent -> per-head K_nope and V."""
    H = cfg.n_heads
    nope, vdim = cfg.qk_nope_dim, cfg.v_head_dim
    kv = (c_kv.astype(dtype) @ p["wkv_b"].astype(dtype))
    kv = kv.reshape(*c_kv.shape[:-1], H, nope + vdim)
    return kv[..., :nope], kv[..., nope:]


def mla_apply(cfg, p, x, positions, mask_fn, *, return_latent: bool = False,
              skip_info=None):
    B, S, D = x.shape
    H = cfg.n_heads
    nope, rdim, vdim = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(cfg, p, x, positions)
    k_nope, v = _mla_expand_kv(cfg, p, c_kv, x.dtype)
    q = jnp.concatenate([q_nope, q_rope], axis=-1).transpose(0, 2, 1, 3)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None], (B, S, H, rdim))],
        axis=-1).transpose(0, 2, 1, 3)
    v = v.transpose(0, 2, 1, 3)
    out = chunked_attention(q, k, v, mask_fn, bq=min(cfg.q_block, S),
                            bkv=min(cfg.kv_block, S), skip_info=skip_info)
    out = out.transpose(0, 2, 1, 3).reshape(B, S, H * vdim)
    out = out @ p["wo"].astype(x.dtype)
    if return_latent:
        return out, jnp.concatenate([c_kv, k_rope], axis=-1)
    return out


def mla_decode(cfg, p, x, cache: dict, mask_fn):
    """MLA decode caches ONLY the latent (r + rdim per token) — the point
    of MLA: decode_32k cache is 576 B/token instead of H*(nope+v)*2."""
    B = x.shape[0]
    H = cfg.n_heads
    nope, rdim, vdim = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    pos = cache["len"]
    positions = jnp.broadcast_to(pos, (B, 1))
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(cfg, p, x, positions)
    lat = jnp.concatenate([c_kv, k_rope], axis=-1)  # (B,1,r+rdim)
    lat_cache = jax.lax.dynamic_update_slice(cache["latent"], lat, (0, pos, 0))
    c_all, kr_all = lat_cache[..., :cfg.kv_lora_rank], lat_cache[..., cfg.kv_lora_rank:]
    k_nope, v = _mla_expand_kv(cfg, p, c_all, x.dtype)  # (B,Smax,H,·)
    Smax = k_nope.shape[1]
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(kr_all[:, :, None], (B, Smax, H, rdim))],
        axis=-1).transpose(0, 2, 1, 3)
    q = jnp.concatenate([q_nope, q_rope], axis=-1).transpose(0, 2, 1, 3)
    out = decode_attention(q, k, v.transpose(0, 2, 1, 3), pos + 1, mask_fn)
    out = out.reshape(B, 1, H * vdim) @ p["wo"].astype(x.dtype)
    return out, {"latent": lat_cache, "len": pos + 1}
