"""Asynchronous actor–learner replay runtime.

Decouples experience generation (:mod:`~repro.runtime.actor`), priority
sampling (:mod:`~repro.runtime.pipeline`), and learning
(:mod:`~repro.runtime.learner`) into overlapped pipeline stages behind
the :class:`~repro.runtime.service.ReplayService` façade.  This is the
layer where AMPER-vs-PER sampling latency becomes visible as end-to-end
learner steps/sec instead of a microbenchmark.
"""
from repro.runtime.actor import ActorPool, TransitionBlock, make_rollout
from repro.runtime.learner import Feedback, Learner, make_slab_learner
from repro.runtime.pipeline import (BatchSlab, PrefetchPipeline,
                                    make_slab_sampler)
from repro.runtime.service import ReplayService, RunResult

__all__ = [
    "ActorPool", "BatchSlab", "Feedback", "Learner", "PrefetchPipeline",
    "ReplayService", "RunResult", "TransitionBlock", "make_rollout",
    "make_slab_learner", "make_slab_sampler",
]
