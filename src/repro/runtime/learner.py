"""Learner loop with a deferred priority-feedback queue.

The learner consumes prefetched :class:`~repro.runtime.pipeline.BatchSlab`s
and applies one fused jitted call per slab — a ``lax.scan`` of S TD
gradient steps built from the DQN's ``learn`` piece — so the per-step
Python dispatch cost is amortized S-fold.  The slab's batch and weight
buffers are donated to that call (they are consumed exactly once).

Priority feedback is *deferred*: instead of writing TD errors back into
the sampler state inline (which would serialize the learner behind the
replay service), each slab's ``(seq0, idx, |td|, stamp, version)`` record
is enqueued and the replay thread applies it out-of-band via the
buffer's stamped ``update_priorities`` — one jitted apply per slab, rows
in learner-step order.  Sequence numbers make the exactly-once /
in-order contract testable; the sample-time version makes staleness
(learner steps between draw and priority write) measurable.

Target-network sync and params publication to the actor pool happen at
slab granularity on the host: ``target_sync`` is rounded up to the next
slab boundary, and every completed slab publishes the fresh params
snapshot (a Python reference swap — actors pick it up at their next
chunk).
"""
from __future__ import annotations

import collections
import queue
import threading
import time
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.obs import get_registry, span
from repro.runtime.pipeline import BatchSlab


class Feedback(NamedTuple):
    """One slab's deferred priority updates, learner -> replay thread.

    Carries S batches (slab rows in learner-step order) so the replay
    thread applies them in one jitted call; row j corresponds to global
    batch sequence number ``seq0 + j``.
    """

    seq0: int           # global batch sequence number of row 0 (FIFO)
    idx: jax.Array      # int32[S, batch] sampled replay rows
    td: jax.Array       # float32[S, batch] fresh TD errors
    stamp: jax.Array    # int32[S, batch, 2] (counter, gen) write stamps
    #                     captured at sample time
    version: int        # learner steps completed when the slab was drawn


def make_slab_learner(dqn) -> Callable:
    """Build the jittable fused slab step
    ``(params, target, m, v, step0, batch, weights) ->
    (params, m, v, td [S, batch], loss [S])``."""
    learn = dqn.learn

    def learn_slab(params, target_params, opt_m, opt_v, step0, batch,
                   weights):
        def body(carry, inp):
            params, m, v = carry
            b, w, i = inp
            params, m, v, td, loss = learn(
                params, target_params, m, v, step0 + i, b, w)
            return (params, m, v), (td, loss)

        s = weights.shape[0]
        (params, opt_m, opt_v), (td, loss) = jax.lax.scan(
            body, (params, opt_m, opt_v),
            (batch, weights, jnp.arange(s, dtype=jnp.int32)))
        return params, opt_m, opt_v, td, loss

    return learn_slab


class Learner:
    """Drives the fused slab step; runs on the service's caller thread."""

    def __init__(self, learn_fn: Callable, *, in_q: queue.Queue,
                 feedback_put: Callable[[Feedback], bool],
                 publish: Callable[[Any], None], target_sync: int,
                 stop: threading.Event, start_steps: int = 0,
                 on_slab: Callable[..., bool] | None = None):
        self._learn = learn_fn            # jitted fused slab step
        self._in_q = in_q
        self._feedback_put = feedback_put
        self._publish = publish
        self._target_sync = max(int(target_sync), 1)
        self._stop = stop
        # Checkpoint hook: called after every completed slab (feedback
        # enqueued, params published) with the live (params, target,
        # opt_m, opt_v); returning True stops the run early — the
        # preemption exit used by the snapshot orchestrator.
        self._on_slab = on_slab
        self.steps_done = start_steps     # learner steps (batches) applied
        self.finished = False             # all feedback for the run emitted
        # Live optimizer moments, exposed for the final checkpoint after
        # the run ends (Python reference swaps, no copies).
        self.opt_m = None
        self.opt_v = None
        # Last loss per slab, kept as device arrays (no host sync) and
        # bounded so multi-million-step runs don't grow without limit.
        self.losses: collections.deque = collections.deque(maxlen=256)
        self.first_step_time: float | None = None

    def run(self, params, target_params, opt_m, opt_v,
            n_steps: int) -> tuple[Any, Any]:
        """Consume slabs until ``n_steps`` learner steps are done (rounded
        up to a whole slab).  Returns (params, target_params)."""
        self.opt_m, self.opt_v = opt_m, opt_v
        steps_c = get_registry().counter(
            "learner_steps_total", help="optimizer steps taken")
        try:
            while self.steps_done < n_steps and not self._stop.is_set():
                slab = self._get_slab()
                if slab is None:
                    break
                if self.first_step_time is None:
                    self.first_step_time = time.perf_counter()
                with span("learn"):
                    params, opt_m, opt_v, td, loss = self._learn(
                        params, target_params, opt_m, opt_v,
                        jnp.int32(self.steps_done), slab.batch, slab.weights)
                self.opt_m, self.opt_v = opt_m, opt_v
                s = int(td.shape[0])
                steps_c.add(s)
                self._feedback_put(Feedback(
                    seq0=slab.seq0, idx=slab.idx, td=td,
                    stamp=slab.stamp, version=slab.version))
                prev = self.steps_done
                self.steps_done = prev + s
                # Keep the device array: a float() here would host-sync
                # the critical path once per slab.
                self.losses.append(loss[-1])
                if (self.steps_done // self._target_sync
                        > prev // self._target_sync):
                    target_params = params
                self._publish(params)
                if self._on_slab is not None and self._on_slab(
                        params, target_params, opt_m, opt_v):
                    break
        finally:
            # The replay thread's exit condition requires finished=True;
            # set it even when the learn step raises, or the replay-core
            # thread would spin for the rest of the process lifetime.
            self.finished = True
        return params, target_params

    def _get_slab(self) -> BatchSlab | None:
        while not self._stop.is_set():
            try:
                return self._in_q.get(timeout=0.05)
            except queue.Empty:
                continue
        return None
