"""Double-buffered device prefetch of sampled batch slabs.

The pipeline thread draws batch N+1 while the learner consumes batch N:
it snapshots the replay service's current buffer state (an immutable
pytree, so the snapshot is a free Python reference), samples a *slab* of
S batches in one jitted vmap call — one dispatch instead of S, which is
what makes host-side sampling keep up with the learner on CPU — and
pushes the slab into a bounded queue of depth ``prefetch_depth`` (2 =
classic double buffering).  Any registry sampler works, including the
mesh-sharded ``amper-fr-sharded``: the pipeline only calls
``ReplayBuffer.sample`` under jit.

Each slab row carries the sample-time write stamps (for the stale-safe
deferred priority update) and the learner-step version at draw time (for
staleness accounting).  Batches are optionally ``device_put`` onto a
target device here, off the learner's critical path; the learner's jit
then donates the batch buffers, so a consumed batch's memory is recycled
into the next step's outputs instead of round-tripping the allocator.
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Any, Callable, NamedTuple

import jax

from repro.runtime import prng
from repro.runtime.actor import put_with_stop


class BatchSlab(NamedTuple):
    """S prefetched batches, stacked on a leading slab axis."""

    seq0: int           # global batch sequence number of row 0
    idx: jax.Array      # int32[S, batch] sampled replay rows
    batch: Any          # pytree, leaves [S, batch, ...]
    weights: jax.Array  # float32[S, batch] importance weights
    stamp: jax.Array    # int32[S, batch] write stamps at sample time
    version: int        # learner steps completed when this slab was drawn


def make_slab_sampler(replay, batch: int, slab: int) -> Callable:
    """Build the jittable ``(buffer_state, key) -> (idx, batch, w, stamp)``
    slab draw: ONE ``S*batch`` draw of the sampler's law reshaped to
    ``[S, batch]``.

    The PER samplers draw stratified (one uniform per segment of the
    cumulative mass), so the S*batch rows are split by *interleaving*
    strata — batch j takes flat rows {j, S+j, 2S+j, ...} — which makes
    every batch a stratified sample spanning the full priority range (a
    row-major reshape would hand each batch one contiguous 1/S slice of
    the mass).  For AMPER (uniform over its CSP) the split is immaterial,
    and sharing one draw sets the CSP rebuild cadence to one rebuild per
    S batches — the candidate set the paper rebuilds per sampling event
    is shared by the slab, which is exactly the replay policy an AM
    accelerator would run when the host prefetches ahead (see README
    "Async runtime" on how this interacts with staleness).  Importance
    weights are max-normalized over the whole slab rather than per batch
    (the PER normalizer is a heuristic either way).
    """

    def sample_slab(state, key):
        idx, tree, w = replay.sample(state, key, batch * slab)
        # [S*batch, ...] -> [S, batch, ...] with strata interleaved:
        # slab row j = flat rows {j, S+j, 2S+j, ...}.
        shape = lambda x: x.reshape(
            (batch, slab) + x.shape[1:]).swapaxes(0, 1)
        return (shape(idx), jax.tree.map(shape, tree), shape(w),
                shape(replay.stamps(state, idx)))

    return sample_slab


class PrefetchPipeline(threading.Thread):
    """Prefetch thread: snapshot -> slab draw -> bounded queue."""

    def __init__(self, sample_fn: Callable, state_fn: Callable, *,
                 out_q: queue.Queue, stop: threading.Event,
                 base_key: jax.Array, slab: int, min_size: int,
                 device=None):
        super().__init__(name="replay-prefetch", daemon=True)
        self._sample = sample_fn          # jitted slab draw
        self._state_fn = state_fn         # () -> (buffer_state, version)
        self._out_q = out_q
        self._stop_evt = stop
        self._base_key = base_key
        self._slab = slab
        self._min_size = min_size
        self._device = device
        self.slabs_done = 0
        self.error: BaseException | None = None

    def run(self) -> None:
        try:
            self._loop()
        except BaseException as e:
            self.error = e
            self._stop_evt.set()

    def _loop(self) -> None:
        seq, draw, warm = 0, 0, False
        while not self._stop_evt.is_set():
            state, version = self._state_fn()
            if not warm:  # size only grows; skip the device sync once warm
                if int(state.size) < self._min_size:
                    time.sleep(0.002)  # buffer not yet sampleable
                    continue
                warm = True
            idx, batch, weights, stamp = self._sample(
                state, prng.sample_key(self._base_key, draw))
            if self._device is not None:
                batch, weights = jax.device_put(
                    (batch, weights), self._device)
            slab = BatchSlab(seq0=seq, idx=idx, batch=batch,
                             weights=weights, stamp=stamp, version=version)
            if not put_with_stop(self._out_q, slab, self._stop_evt):
                return
            seq += self._slab
            draw += 1
            self.slabs_done = draw
