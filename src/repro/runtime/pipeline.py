"""Double-buffered device prefetch of sampled batch slabs.

The pipeline thread draws batch N+1 while the learner consumes batch N:
it snapshots the replay service's current buffer state (an immutable
pytree, so the snapshot is a free Python reference), samples a *slab* of
S batches in one jitted vmap call — one dispatch instead of S, which is
what makes host-side sampling keep up with the learner on CPU — and
pushes the slab into a bounded queue of depth ``prefetch_depth`` (2 =
classic double buffering).  Any registry sampler works, including the
mesh-sharded ``amper-fr-sharded``: the pipeline only calls
``ReplayBuffer.sample`` under jit.

Each slab row carries the sample-time write stamps (for the stale-safe
deferred priority update) and the learner-step version at draw time (for
staleness accounting).  Batches are optionally ``device_put`` onto a
target device here, off the learner's critical path; the learner's jit
then donates the batch buffers, so a consumed batch's memory is recycled
into the next step's outputs instead of round-tripping the allocator.
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.obs import span
from repro.runtime import prng
from repro.runtime.actor import PauseGate


class BatchSlab(NamedTuple):
    """S prefetched batches, stacked on a leading slab axis."""

    seq0: int           # global batch sequence number of row 0
    idx: jax.Array      # int32[S, batch] sampled replay rows
    batch: Any          # pytree, leaves [S, batch, ...]
    weights: jax.Array  # float32[S, batch] importance weights
    stamp: jax.Array    # int32[S, batch, 2] (counter, gen) write stamps
    #                     captured at sample time
    version: int        # learner steps completed when this slab was drawn


def make_slab_sampler(replay, batch: int, slab: int) -> Callable:
    """Build the jittable ``(buffer_state, key) -> (idx, batch, w, stamp)``
    slab draw: ONE ``S*batch`` draw of the sampler's law reshaped to
    ``[S, batch]``.

    The PER samplers draw stratified (one uniform per segment of the
    cumulative mass), so the S*batch rows are split by *interleaving*
    strata — batch j takes flat rows {j, S+j, 2S+j, ...} — which makes
    every batch a stratified sample spanning the full priority range (a
    row-major reshape would hand each batch one contiguous 1/S slice of
    the mass).  For AMPER (uniform over its CSP) the split is immaterial,
    and sharing one draw sets the CSP rebuild cadence to one rebuild per
    S batches — the candidate set the paper rebuilds per sampling event
    is shared by the slab, which is exactly the replay policy an AM
    accelerator would run when the host prefetches ahead (see README
    "Async runtime" on how this interacts with staleness).  Importance
    weights are max-normalized over the whole slab rather than per batch
    (the PER normalizer is a heuristic either way).
    """

    def sample_slab(state, key, beta):
        idx, tree, w = replay.sample(state, key, batch * slab, beta=beta)
        # [S*batch, ...] -> [S, batch, ...] with strata interleaved:
        # slab row j = flat rows {j, S+j, 2S+j, ...}.
        shape = lambda x: x.reshape(
            (batch, slab) + x.shape[1:]).swapaxes(0, 1)
        return (shape(idx), jax.tree.map(shape, tree), shape(w),
                shape(replay.stamps(state, idx)))

    return sample_slab


class PrefetchPipeline(threading.Thread):
    """Prefetch thread: snapshot -> slab draw -> bounded queue."""

    def __init__(self, sample_fn: Callable, state_fn: Callable, *,
                 out_q: queue.Queue, stop: threading.Event,
                 base_key: jax.Array, slab: int, min_size: int,
                 device=None, beta_fn: Callable[[int], float] | None = None,
                 gate: PauseGate | None = None, start_draw: int = 0,
                 start_seq: int = 0,
                 probe: Callable[[Any, jax.Array], None] | None = None,
                 probe_every: int = 0):
        super().__init__(name="replay-prefetch", daemon=True)
        self._sample = sample_fn          # jitted slab draw
        self._state_fn = state_fn         # () -> (buffer_state, version)
        self._out_q = out_q
        self._stop_evt = stop
        self._base_key = base_key
        self._slab = slab
        self._min_size = min_size
        self._device = device
        # version -> IS exponent: the annealed-β schedule evaluated at the
        # learner step this slab was drawn for (constant when disabled).
        self._beta_fn = beta_fn
        self._gate = gate
        # Resume counters: ``draws`` is the PRNG stream position (every
        # performed draw consumed sample_key(base_key, draw), delivered
        # or not), ``seq`` the global batch sequence of the next slab.
        self._start_draw = start_draw
        self._start_seq = start_seq
        # Replay-health probe: called with the exact (state, key) of one
        # in every ``probe_every`` slab draws, AFTER the draw itself, so
        # the probe can re-derive that draw's CSP/sampled-priority facts
        # (see repro.obs.probes) without touching the production path.
        self._probe = probe
        self._probe_every = max(int(probe_every), 0) if probe else 0
        self.draws = start_draw
        self.slabs_done = 0
        # IS exponent the latest slab draw used (None until the first
        # draw, or when no beta_fn is wired) — the annealed value the
        # service surfaces in its metrics dict.
        self.last_beta: float | None = None
        self.error: BaseException | None = None

    def run(self) -> None:
        try:
            self._loop()
        except BaseException as e:
            self.error = e
            self._stop_evt.set()

    def _try_put(self, slab) -> bool:
        """One bounded put attempt; abandon to the gate/stop checks."""
        try:
            self._out_q.put(slab, timeout=0.05)
            return True
        except queue.Full:
            return False

    def _loop(self) -> None:
        seq, draw, warm = self._start_seq, self._start_draw, False
        pending = None
        while not self._stop_evt.is_set():
            if self._gate is not None:
                # Park holding any undelivered slab: the learner stops
                # consuming during a snapshot, so a blocking put here
                # would deadlock the quiesce.  The pending slab is
                # delivered after resume — sequence numbers stay gapless.
                self._gate.wait_if_paused(self._stop_evt)
            if pending is None:
                state, version = self._state_fn()
                if not warm:  # size only grows; skip the device sync once warm
                    if int(state.size) < self._min_size:
                        time.sleep(0.002)  # buffer not yet sampleable
                        continue
                    warm = True
                # None (a leafless pytree, so still one jit trace) lets
                # replay.sample fall back to its constructor constant.
                beta = (jnp.float32(self._beta_fn(version))
                        if self._beta_fn is not None else None)
                key = prng.sample_key(self._base_key, draw)
                with span("slab_draw"):
                    idx, batch, weights, stamp = self._sample(
                        state, key, beta)
                # Publish β only once the draw has returned: a draw that
                # raises must not leave metrics reporting the β of a
                # slab that never existed.
                if beta is not None:
                    self.last_beta = float(beta)
                if self._probe_every and draw % self._probe_every == 0:
                    self._probe(state, key)
                draw += 1
                self.draws = draw
                if self._device is not None:
                    batch, weights = jax.device_put(
                        (batch, weights), self._device)
                pending = BatchSlab(seq0=seq, idx=idx, batch=batch,
                                    weights=weights, stamp=stamp,
                                    version=version)
            if self._try_put(pending):
                pending = None
                seq += self._slab
                self.slabs_done += 1
