"""Host-thread actor pool driving jitted ``VectorEnv`` rollout chunks.

Each actor owns an independent ``VectorEnv`` state (its own reset key,
its own episode accounting) and repeatedly runs one jitted rollout chunk
— ``chunk_len`` vectorized epsilon-greedy steps composed from the DQN's
``act`` piece inside a ``lax.scan`` — then enqueues the resulting
``[chunk_len, num_envs]`` transition block for the replay service.  The
Python thread only dispatches the chunk and moves the result between
queues; all math happens inside XLA, which releases the GIL, so actors
overlap with the learner and the prefetch pipeline.

Exploration schedule note: each actor drives ``eps`` with its *local*
step counter, so with A actors the schedule advances per actor-iteration
rather than per global frame — the standard per-worker schedule of
distributed DQN variants.
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import span
from repro.runtime import prng


class TransitionBlock(NamedTuple):
    """One rollout chunk handed from an actor to the replay service.

    With n-step replay the rows are already aggregated by the actor's
    own :class:`~repro.core.replay_buffer.NStepAccumulator` (each actor
    is an independent env stream, so the buffer's shared accumulator
    cannot serve them); the leading dim is then the number of *emitted*
    n-step rows — ``chunk_len`` once warm, fewer for the chunk that
    spans the warm-up, and ``transitions`` is None when the whole chunk
    fell inside it.  ``frames`` always counts raw env frames.
    """

    transitions: Any            # pytree, leaves [emitted, num_envs, ...]
    frames: int                 # chunk_len * num_envs
    actor_id: int
    chunk_id: int
    completed_returns: np.ndarray  # episodes that finished in this chunk


def put_with_stop(q: queue.Queue, item, stop: threading.Event,
                  timeout: float = 0.05) -> bool:
    """Blocking put that aborts (returns False) once ``stop`` is set."""
    while not stop.is_set():
        try:
            q.put(item, timeout=timeout)
            return True
        except queue.Full:
            continue
    return False


class PauseGate:
    """Cooperative quiesce point for the pipeline threads (optional).

    An orchestrator calls :meth:`pause`; each worker thread parks at its
    next :meth:`wait_if_paused` call (registering itself, so
    :meth:`wait_parked` can await full quiescence) and stays parked until
    :meth:`resume`.  Parking happens only at loop boundaries — after a
    worker's in-flight queue put has completed — so a fully-parked
    pipeline has every produced item already in a queue where a
    non-parking drainer can consume it.

    The replay service's checkpoints no longer use this: snapshots are
    copy-on-write (``service._CowSnapshotter`` captures immutable state
    references without pausing anything), so the service constructs its
    pool and prefetcher with ``gate=None``.  The gate remains available
    as a general quiesce utility for callers that do need a full stop
    (e.g. debugging a live pipeline).
    """

    def __init__(self):
        from repro.analysis.locks import make_condition

        self._cond = make_condition("runtime.pause_gate")
        self._paused = False
        self._parked = 0

    @property
    def paused(self) -> bool:
        return self._paused

    def pause(self) -> None:
        with self._cond:
            self._paused = True

    def resume(self) -> None:
        with self._cond:
            self._paused = False
            self._cond.notify_all()

    def wait_if_paused(self, stop: threading.Event) -> None:
        """Worker side: park here while the gate is paused."""
        if not self._paused:
            return
        with self._cond:
            self._parked += 1
            self._cond.notify_all()
            try:
                while self._paused and not stop.is_set():
                    self._cond.wait(timeout=0.05)
            finally:
                self._parked -= 1
                self._cond.notify_all()

    def wait_parked(self, n: int, stop: threading.Event,
                    timeout: float = 60.0) -> bool:
        """Orchestrator side: block until ``n`` workers are parked."""
        deadline = time.monotonic() + timeout
        with self._cond:
            while self._parked < n:
                if stop.is_set() or time.monotonic() > deadline:
                    return False
                self._cond.wait(timeout=0.05)
        return True


def make_rollout(dqn, chunk_len: int) -> Callable:
    """Build the jittable chunk function
    ``(params, env_state, obs, step0, ep_ret, nstep, key) ->
    (env_state, obs, ep_ret, nstep, transitions, valid, finished)``
    where ``transitions`` leaves lead with ``[chunk_len, num_envs]``,
    ``valid`` is ``bool[chunk_len]`` (always True for 1-step; for n-step
    it gates rows emitted before the accumulator warmed up — envs run in
    lockstep, so validity is per-timestep, not per-env) and ``finished``
    is ``float32[chunk_len, num_envs]`` holding completed episode
    returns (NaN where no episode ended).  ``nstep`` threads the actor's
    own per-stream accumulator state (None when ``cfg.n_step == 1``)."""
    act = dqn.act
    acc = dqn.replay.accumulator   # None for n_step == 1

    def rollout(params, env_state, obs, step0, ep_ret, nstep, key):
        def body(carry, i):
            env_state, obs, ep_ret, ns = carry
            env_state, obs, tr = act(
                params, env_state, obs, step0 + i, jax.random.fold_in(key, i))
            ret = ep_ret + tr["reward"]
            done = tr["done"] > 0.5
            finished = jnp.where(done, ret, jnp.nan)
            if acc is not None:
                ns, out, valid = acc.push(ns, tr)
            else:
                out, valid = tr, jnp.bool_(True)
            return ((env_state, obs, jnp.where(done, 0.0, ret), ns),
                    (out, valid, finished))

        carry, (transitions, valid, finished) = jax.lax.scan(
            body, (env_state, obs, ep_ret, nstep),
            jnp.arange(chunk_len, dtype=jnp.int32))
        env_state, obs, ep_ret, nstep = carry
        return env_state, obs, ep_ret, nstep, transitions, valid, finished

    return rollout


class Actor(threading.Thread):
    """One host thread: params snapshot -> rollout chunk -> block queue."""

    def __init__(self, actor_id: int, dqn, rollout: Callable,
                 params_fn: Callable[[], Any], out_q: queue.Queue,
                 stop: threading.Event, base_key: jax.Array, chunk_len: int,
                 budget_fn: Callable[[], bool] | None = None,
                 gate: PauseGate | None = None,
                 resume_state: dict | None = None):
        super().__init__(name=f"replay-actor-{actor_id}", daemon=True)
        self.actor_id = actor_id
        self._dqn = dqn
        self._rollout = rollout
        self._params_fn = params_fn
        self._out_q = out_q
        self._stop_evt = stop
        self._base_key = base_key
        self._chunk_len = chunk_len
        self._budget_fn = budget_fn
        self._gate = gate
        self._resume_state = resume_state
        self.chunks_done = (0 if resume_state is None
                            else int(resume_state["chunk"]))
        self.error: BaseException | None = None
        # Exact-resume snapshot slot: REPLACED (never mutated) with a
        # fresh dict after every completed chunk's enqueue, so a reader
        # on any thread — the COW snapshotter captures it live, without
        # parking this actor — always sees a self-consistent
        # chunk-boundary state.  The PRNG stream is captured by the two
        # integers: chunk c's rollout key is fold_in(roll_key, c) and
        # never depends on wall history.
        self.run_state: dict | None = None

    def run(self) -> None:
        try:
            self._loop()
        except BaseException as e:  # surfaced by the service after join
            self.error = e
            self._stop_evt.set()

    def _publish_run_state(self, env_state, obs, ep_ret, nstep, step, chunk):
        self.run_state = {"env_state": env_state, "obs": obs,
                          "ep_ret": ep_ret, "nstep": nstep,
                          "step": step, "chunk": chunk}

    def _loop(self) -> None:
        dqn, chunk_len = self._dqn, self._chunk_len
        k_reset, k_roll = prng.actor_keys(self._base_key, self.actor_id)
        if self._resume_state is None:
            env_state = dqn.venv.reset(k_reset)
            obs = dqn.init_obs(env_state)  # raw obs, or seeded frame stack
            ep_ret = jnp.zeros(dqn.cfg.num_envs)
            # This actor's own n-step window (None for n_step == 1): an
            # independent env stream must not share the buffer's.
            nstep = dqn.replay.nstep_init(dqn.example_transition)
            step, chunk = 0, 0
        else:
            # Exact continuation: env state, episode accounting, the
            # n-step window, and the PRNG stream position (chunk counter)
            # come from the snapshot; chunk_key(k_roll, chunk) resumes
            # the same key stream an uninterrupted run would have
            # consumed next.
            rs = self._resume_state
            env_state, obs, ep_ret = rs["env_state"], rs["obs"], rs["ep_ret"]
            nstep = rs.get("nstep")
            step, chunk = int(rs["step"]), int(rs["chunk"])
        self._publish_run_state(env_state, obs, ep_ret, nstep, step, chunk)
        while not self._stop_evt.is_set():
            if self._gate is not None:
                self._gate.wait_if_paused(self._stop_evt)
            # Replay-ratio throttle: don't burn host cores producing frames
            # the learner can't consume (matters on small CPU hosts).
            while (self._budget_fn is not None and not self._budget_fn()
                   and not self._stop_evt.is_set()
                   and not (self._gate is not None and self._gate.paused)):
                self._stop_evt.wait(0.002)
            if self._gate is not None and self._gate.paused:
                continue  # park at the loop-top gate before rolling out
            if self._stop_evt.is_set():
                return
            with span("rollout"):
                (env_state, obs, ep_ret, nstep, transitions, valid,
                 finished) = self._rollout(
                    self._params_fn(), env_state, obs, jnp.int32(step),
                    ep_ret, nstep, prng.chunk_key(k_roll, chunk))
            fin = np.asarray(finished).ravel()
            # n-step warm-up: invalid rows form a prefix (the window only
            # fills once), so drop them host-side — the replay thread
            # writes only real n-step rows.  One extra jit trace for the
            # single shorter chunk that spans the warm-up.
            n_valid = int(np.asarray(valid).sum())
            if n_valid == 0:
                transitions = None
            elif n_valid < chunk_len:
                transitions = jax.tree.map(
                    lambda x: x[chunk_len - n_valid:], transitions)
            block = TransitionBlock(
                transitions=transitions,
                frames=chunk_len * dqn.cfg.num_envs,
                actor_id=self.actor_id, chunk_id=chunk,
                completed_returns=fin[~np.isnan(fin)])
            if not put_with_stop(self._out_q, ("block", block), self._stop_evt):
                return
            step += chunk_len
            chunk += 1
            self.chunks_done = chunk
            self._publish_run_state(env_state, obs, ep_ret, nstep, step,
                                    chunk)


class ActorPool:
    """A fixed pool of :class:`Actor` threads sharing one block queue."""

    def __init__(self, dqn, rollout: Callable, *, num_actors: int,
                 params_fn: Callable[[], Any], out_q: queue.Queue,
                 stop: threading.Event, base_key: jax.Array, chunk_len: int,
                 budget_fn: Callable[[], bool] | None = None,
                 gate: PauseGate | None = None,
                 resume_states: list | None = None):
        self.actors = [
            Actor(i, dqn, rollout, params_fn, out_q, stop, base_key,
                  chunk_len, budget_fn, gate=gate,
                  resume_state=(resume_states[i] if resume_states else None))
            for i in range(num_actors)
        ]

    @property
    def chunks_done(self) -> int:
        return sum(a.chunks_done for a in self.actors)

    def run_states(self) -> list:
        """Per-actor exact-resume snapshots (valid while parked/joined)."""
        return [a.run_state for a in self.actors]

    def start(self) -> None:
        for a in self.actors:
            a.start()

    def join(self, timeout: float | None = None) -> None:
        for a in self.actors:
            a.join(timeout)

    def raise_errors(self) -> None:
        for a in self.actors:
            if a.error is not None:
                raise RuntimeError(
                    f"actor {a.actor_id} failed") from a.error
