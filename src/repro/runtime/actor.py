"""Host-thread actor pool driving jitted ``VectorEnv`` rollout chunks.

Each actor owns an independent ``VectorEnv`` state (its own reset key,
its own episode accounting) and repeatedly runs one jitted rollout chunk
— ``chunk_len`` vectorized epsilon-greedy steps composed from the DQN's
``act`` piece inside a ``lax.scan`` — then enqueues the resulting
``[chunk_len, num_envs]`` transition block for the replay service.  The
Python thread only dispatches the chunk and moves the result between
queues; all math happens inside XLA, which releases the GIL, so actors
overlap with the learner and the prefetch pipeline.

Exploration schedule note: each actor drives ``eps`` with its *local*
step counter, so with A actors the schedule advances per actor-iteration
rather than per global frame — the standard per-worker schedule of
distributed DQN variants.
"""
from __future__ import annotations

import queue
import threading
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.runtime import prng


class TransitionBlock(NamedTuple):
    """One rollout chunk handed from an actor to the replay service."""

    transitions: Any            # pytree, leaves [chunk_len, num_envs, ...]
    frames: int                 # chunk_len * num_envs
    actor_id: int
    chunk_id: int
    completed_returns: np.ndarray  # episodes that finished in this chunk


def put_with_stop(q: queue.Queue, item, stop: threading.Event,
                  timeout: float = 0.05) -> bool:
    """Blocking put that aborts (returns False) once ``stop`` is set."""
    while not stop.is_set():
        try:
            q.put(item, timeout=timeout)
            return True
        except queue.Full:
            continue
    return False


def make_rollout(dqn, chunk_len: int) -> Callable:
    """Build the jittable chunk function
    ``(params, env_state, obs, step0, ep_ret, key) ->
    (env_state, obs, ep_ret, transitions, finished)``
    where ``transitions`` leaves lead with ``[chunk_len, num_envs]`` and
    ``finished`` is ``float32[chunk_len, num_envs]`` holding completed
    episode returns (NaN where no episode ended)."""
    act = dqn.act

    def rollout(params, env_state, obs, step0, ep_ret, key):
        def body(carry, i):
            env_state, obs, ep_ret = carry
            env_state, obs, tr = act(
                params, env_state, obs, step0 + i, jax.random.fold_in(key, i))
            ret = ep_ret + tr["reward"]
            done = tr["done"] > 0.5
            finished = jnp.where(done, ret, jnp.nan)
            return (env_state, obs, jnp.where(done, 0.0, ret)), (tr, finished)

        (env_state, obs, ep_ret), (transitions, finished) = jax.lax.scan(
            body, (env_state, obs, ep_ret),
            jnp.arange(chunk_len, dtype=jnp.int32))
        return env_state, obs, ep_ret, transitions, finished

    return rollout


class Actor(threading.Thread):
    """One host thread: params snapshot -> rollout chunk -> block queue."""

    def __init__(self, actor_id: int, dqn, rollout: Callable,
                 params_fn: Callable[[], Any], out_q: queue.Queue,
                 stop: threading.Event, base_key: jax.Array, chunk_len: int,
                 budget_fn: Callable[[], bool] | None = None):
        super().__init__(name=f"replay-actor-{actor_id}", daemon=True)
        self.actor_id = actor_id
        self._dqn = dqn
        self._rollout = rollout
        self._params_fn = params_fn
        self._out_q = out_q
        self._stop_evt = stop
        self._base_key = base_key
        self._chunk_len = chunk_len
        self._budget_fn = budget_fn
        self.chunks_done = 0
        self.error: BaseException | None = None

    def run(self) -> None:
        try:
            self._loop()
        except BaseException as e:  # surfaced by the service after join
            self.error = e
            self._stop_evt.set()

    def _loop(self) -> None:
        dqn, chunk_len = self._dqn, self._chunk_len
        k_reset, k_roll = prng.actor_keys(self._base_key, self.actor_id)
        env_state = dqn.venv.reset(k_reset)
        obs = dqn.venv.obs(env_state)
        ep_ret = jnp.zeros(dqn.cfg.num_envs)
        step, chunk = 0, 0
        while not self._stop_evt.is_set():
            # Replay-ratio throttle: don't burn host cores producing frames
            # the learner can't consume (matters on small CPU hosts).
            while (self._budget_fn is not None and not self._budget_fn()
                   and not self._stop_evt.is_set()):
                self._stop_evt.wait(0.002)
            if self._stop_evt.is_set():
                return
            env_state, obs, ep_ret, transitions, finished = self._rollout(
                self._params_fn(), env_state, obs, jnp.int32(step), ep_ret,
                prng.chunk_key(k_roll, chunk))
            fin = np.asarray(finished).ravel()
            block = TransitionBlock(
                transitions=transitions,
                frames=chunk_len * dqn.cfg.num_envs,
                actor_id=self.actor_id, chunk_id=chunk,
                completed_returns=fin[~np.isnan(fin)])
            if not put_with_stop(self._out_q, ("block", block), self._stop_evt):
                return
            step += chunk_len
            chunk += 1
            self.chunks_done = chunk


class ActorPool:
    """A fixed pool of :class:`Actor` threads sharing one block queue."""

    def __init__(self, dqn, rollout: Callable, *, num_actors: int,
                 params_fn: Callable[[], Any], out_q: queue.Queue,
                 stop: threading.Event, base_key: jax.Array, chunk_len: int,
                 budget_fn: Callable[[], bool] | None = None):
        self.actors = [
            Actor(i, dqn, rollout, params_fn, out_q, stop, base_key,
                  chunk_len, budget_fn)
            for i in range(num_actors)
        ]

    def start(self) -> None:
        for a in self.actors:
            a.start()

    def join(self, timeout: float | None = None) -> None:
        for a in self.actors:
            a.join(timeout)

    def raise_errors(self) -> None:
        for a in self.actors:
            if a.error is not None:
                raise RuntimeError(
                    f"actor {a.actor_id} failed") from a.error
