"""`ReplayService` — the async actor–learner replay façade.

Wires the pipeline stages into one serving-shaped system:

    actors (threads, jitted rollout chunks)
        └── transition blocks ──> replay thread (ring writes, canonical
                                  buffer state, priority feedback applies)
                                        └── state snapshots ──> prefetch
                                                thread (slab sampling)
                                                    └── batch slabs ──>
    learner (caller thread, fused TD steps)
        └── deferred priority feedback ──> replay thread (stamped,
                                           out-of-band, exactly once)

The canonical replay state is owned by ONE thread (the replay thread);
every other stage sees it only as immutable snapshots, so there are no
locks around JAX state — just bounded queues.  ``sync=True`` degrades
the service to a strict synchronous mode: the exact ``agent_step``
iteration of the scan trainer driven step-by-step, which is the
apples-to-apples baseline the async speedup is measured against (and the
mode the equivalence tests pin to the scan trainer's learning curve).

Metrics cover the questions the paper's latency story raises at system
scale: learner steps/sec, environment frames/sec, queue depths (is the
sampler or the actor pool the bottleneck?), and priority-feedback
staleness (how many learner steps old is a priority when it lands).
"""
from __future__ import annotations

import collections
import queue
import threading
import time
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.rl.dqn import DQNConfig, make_dqn
from repro.runtime.actor import ActorPool, make_rollout, put_with_stop
from repro.runtime.learner import Feedback, Learner, make_slab_learner
from repro.runtime.pipeline import PrefetchPipeline, make_slab_sampler


class RunResult(NamedTuple):
    params: Any          # final network params (dqn.evaluate accepts them)
    target_params: Any
    buffer: Any          # final canonical ReplayState
    metrics: dict


class ReplayService:
    """Asynchronous actor–learner replay service (or its strict-sync twin).

    Args:
      cfg: the DQN config (env, sampler, batch, schedules).
      num_actors: actor threads; each steps ``cfg.num_envs`` envs.
      sync: strict synchronous mode — requires ``num_actors=1`` and
        reproduces the scan trainer's iteration exactly.
      chunk_len: env steps per actor rollout chunk (one dispatch).
      slab: batches per prefetch draw / fused learner call.
      prefetch_depth: batch-slab queue depth (2 = double buffering).
      queue_size: transition-block + feedback queue bound (backpressure).
      min_size: buffer fill before sampling starts; defaults to the scan
        trainer's ``learn_start`` worth of frames.
      max_replay_ratio: optional frames-per-learner-step cap; actors
        pause when generation runs this far ahead of consumption (frees
        host cores for the learner on small machines).
      feedback_log: record the exact per-batch feedback sequence trace in
        ``metrics["feedback_seqs"]`` (O(learner steps) memory — for tests
        and debugging; the aggregate staleness stats are always kept).
      device: optional target device for prefetched batches.
    """

    def __init__(self, cfg: DQNConfig, *, num_actors: int = 2,
                 sync: bool = False, chunk_len: int = 32, slab: int = 4,
                 prefetch_depth: int = 2, queue_size: int = 8,
                 min_size: int | None = None,
                 max_replay_ratio: float | None = None,
                 feedback_log: bool = False, device=None):
        if sync and num_actors != 1:
            raise ValueError("sync mode is defined for num_actors=1 "
                             f"(got {num_actors})")
        self.cfg = cfg
        self.sync = sync
        self.num_actors = num_actors
        self.chunk_len = chunk_len
        self.slab = slab
        self.prefetch_depth = prefetch_depth
        self.queue_size = queue_size
        self.device = device
        self.min_size = (min_size if min_size is not None else
                         max(cfg.batch,
                             min(cfg.learn_start * cfg.num_envs,
                                 cfg.replay_size)))
        self.max_replay_ratio = max_replay_ratio
        self.feedback_log = feedback_log
        self.dqn = make_dqn(cfg)
        rb = self.dqn.replay
        # One jitted callable per pipeline stage, built once so repeated
        # run() calls (warmup, then measurement) reuse the compile cache.
        self._rollout = jax.jit(make_rollout(self.dqn, chunk_len))
        self._sample = jax.jit(make_slab_sampler(rb, cfg.batch, slab))
        # The slab's batch/weight buffers are consumed exactly once ->
        # donate them (args 5, 6); params/target stay undonated because
        # actors and the target alias them across calls.  The CPU backend
        # cannot reuse donated buffers and warns, so only donate off-CPU.
        donate = () if jax.default_backend() == "cpu" else (5, 6)
        self._learn = jax.jit(make_slab_learner(self.dqn),
                              donate_argnums=donate)
        self._add_block = jax.jit(rb.add_block)

        def apply_feedback(state, idx, td, stamp):
            # Flatten [S, batch] row-major: masked_update resolves rows
            # duplicated across batches to their last occurrence, so one
            # scatter reproduces sequential-apply semantics (stamps can't
            # change between rows of a slab).
            flat = lambda x: x.reshape(-1)
            return rb.update_priorities(
                state, flat(idx), flat(td), stamp=flat(stamp))

        self._apply_feedback = jax.jit(apply_feedback)
        self._agent_step = jax.jit(self.dqn.agent_step)

    # ------------------------------------------------------------------ #

    def run(self, key: jax.Array, n_steps: int) -> RunResult:
        """Train for ``n_steps`` — scan-trainer iterations in sync mode,
        learner steps (rounded up to a whole slab) in async mode."""
        if self.sync:
            return self._run_sync(key, n_steps)
        return self._run_async(key, n_steps)

    # --- strict synchronous mode -------------------------------------- #

    def _run_sync(self, key: jax.Array, n_steps: int) -> RunResult:
        cfg = self.cfg
        state = self.dqn.init(key)
        # Same step-key derivation as the scan trainer's _train.
        keys = jax.random.split(jax.random.fold_in(key, 1), n_steps)
        returns = []
        t0 = time.perf_counter()
        t_first_learn = None
        for t in range(n_steps):
            if t == cfg.learn_start:
                jax.block_until_ready(state.params)
                t_first_learn = time.perf_counter()
            state, m = self._agent_step(state, keys[t])
            returns.append(m["return_mean"])
        jax.block_until_ready(state.params)
        t_end = time.perf_counter()
        learner_steps = sum(
            1 for t in range(n_steps)
            if t >= cfg.learn_start and t % cfg.train_every == 0)
        learn_wall = (t_end - t_first_learn if t_first_learn is not None
                      else float("nan"))
        curve = np.asarray(jnp.stack(returns)) if returns else np.zeros(0)
        metrics = {
            "mode": "sync",
            "learner_steps": learner_steps,
            "learner_steps_per_sec": (learner_steps / learn_wall
                                      if learner_steps else 0.0),
            "wall_time": t_end - t0,
            "frames": n_steps * cfg.num_envs,
            "frames_per_sec": n_steps * cfg.num_envs / (t_end - t0),
            "return_mean": float(curve[-1]) if len(curve) else 0.0,
            "return_curve": curve,
            "staleness": {"count": 0, "mean": 0.0, "max": 0},
        }
        return RunResult(params=state.params,
                         target_params=state.target_params,
                         buffer=state.buffer, metrics=metrics)

    # --- asynchronous mode -------------------------------------------- #

    def _run_async(self, key: jax.Array, n_steps: int) -> RunResult:
        cfg = self.cfg
        state0 = self.dqn.init(key)
        self._bstate = state0.buffer          # canonical replay state
        params_box = [state0.params]          # actors read, learner swaps
        work_q: queue.Queue = queue.Queue(self.queue_size)
        batch_q: queue.Queue = queue.Queue(self.prefetch_depth)
        stop = threading.Event()
        # Running aggregates, bounded regardless of run length; the exact
        # per-batch sequence trace is opt-in via feedback_log.
        rec = {"frames": 0, "blocks": 0,
               "feedback_seqs": [] if self.feedback_log else None,
               "stale_n": 0, "stale_sum": 0, "stale_max": 0,
               "returns": collections.deque(maxlen=256),
               "depth_n": 0, "work_sum": 0, "batch_sum": 0, "error": None}

        learner = Learner(
            self._learn, in_q=batch_q,
            feedback_put=lambda fb: put_with_stop(
                work_q, ("feedback", fb), stop),
            publish=lambda p: params_box.__setitem__(0, p),
            target_sync=cfg.target_sync, stop=stop)
        replay_thread = threading.Thread(
            target=self._replay_loop, name="replay-core",
            args=(work_q, batch_q, stop, learner, rec), daemon=True)
        budget_fn = None
        if self.max_replay_ratio is not None:
            ratio, head = self.max_replay_ratio, self.min_size

            def budget_fn():
                return (rec["frames"]
                        < head + ratio * max(learner.steps_done, 1))

        pool = ActorPool(
            self.dqn, self._rollout, num_actors=self.num_actors,
            params_fn=lambda: params_box[0], out_q=work_q, stop=stop,
            base_key=key, chunk_len=self.chunk_len, budget_fn=budget_fn)
        prefetch = PrefetchPipeline(
            self._sample,
            state_fn=lambda: (self._bstate, learner.steps_done),
            out_q=batch_q, stop=stop, base_key=key, slab=self.slab,
            min_size=self.min_size, device=self.device)

        def shutdown():
            stop.set()
            pool.join(timeout=10.0)
            prefetch.join(timeout=10.0)
            replay_thread.join(timeout=10.0)

        def raise_worker_errors():
            if rec["error"] is not None:
                raise RuntimeError("replay thread failed") from rec["error"]
            if prefetch.error is not None:
                raise RuntimeError(
                    "prefetch pipeline failed") from prefetch.error
            pool.raise_errors()

        t0 = time.perf_counter()
        replay_thread.start()
        pool.start()
        prefetch.start()
        try:
            params, target_params = learner.run(
                state0.params, state0.target_params,
                state0.opt_m, state0.opt_v, n_steps)
            jax.block_until_ready(params)
            t_end = time.perf_counter()
        except BaseException:
            # Join first, then surface the root cause: a learner failure
            # is often secondary to a worker-thread fault, and raising
            # from it here chains both tracebacks.
            shutdown()
            raise_worker_errors()
            raise
        shutdown()
        raise_worker_errors()

        learn_wall = (t_end - learner.first_step_time
                      if learner.first_step_time else float("nan"))
        wall = t_end - t0
        returns = np.asarray(rec["returns"])
        metrics = {
            "mode": "async",
            "learner_steps": learner.steps_done,
            "learner_steps_per_sec": (learner.steps_done / learn_wall
                                      if learner.steps_done else 0.0),
            "wall_time": wall,
            "frames": rec["frames"],
            "frames_per_sec": rec["frames"] / wall,
            "blocks": rec["blocks"],
            "return_mean": (float(returns[-64:].mean())
                            if returns.size else 0.0),
            "recent_returns": returns[-64:],
            "feedback_seqs": rec["feedback_seqs"],
            "staleness": {
                "count": rec["stale_n"],
                "mean": (rec["stale_sum"] / rec["stale_n"]
                         if rec["stale_n"] else 0.0),
                "max": rec["stale_max"],
            },
            "queue_depth": {
                "work_mean": (rec["work_sum"] / rec["depth_n"]
                              if rec["depth_n"] else 0.0),
                "batch_mean": (rec["batch_sum"] / rec["depth_n"]
                               if rec["depth_n"] else 0.0),
            },
            "losses": [float(l) for l in learner.losses],
        }
        return RunResult(params=params, target_params=target_params,
                         buffer=self._bstate, metrics=metrics)

    def _replay_loop(self, work_q: queue.Queue, batch_q: queue.Queue,
                     stop: threading.Event, learner: Learner,
                     rec: dict) -> None:
        """The one owner of the canonical replay state: applies transition
        blocks and deferred priority feedback in arrival order, publishes
        immutable snapshots for the prefetcher."""
        try:
            bstate = self._bstate
            while True:
                try:
                    tag, item = work_q.get(timeout=0.05)
                except queue.Empty:
                    if stop.is_set() and learner.finished and work_q.empty():
                        return
                    continue
                if tag == "block":
                    bstate = self._add_block(bstate, item.transitions)
                    rec["frames"] += item.frames
                    rec["blocks"] += 1
                    rec["returns"].extend(item.completed_returns.tolist())
                else:  # deferred priority feedback (one slab, S batches)
                    fb: Feedback = item
                    bstate = self._apply_feedback(
                        bstate, fb.idx, fb.td, fb.stamp)
                    s = int(fb.idx.shape[0])
                    if rec["feedback_seqs"] is not None:
                        rec["feedback_seqs"].extend(
                            range(fb.seq0, fb.seq0 + s))
                    stale = learner.steps_done - fb.version
                    rec["stale_n"] += s
                    rec["stale_sum"] += stale * s
                    rec["stale_max"] = max(rec["stale_max"], stale)
                self._bstate = bstate
                rec["depth_n"] += 1
                rec["work_sum"] += work_q.qsize()
                rec["batch_sum"] += batch_q.qsize()
        except BaseException as e:
            rec["error"] = e
            stop.set()
