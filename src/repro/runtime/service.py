"""`ReplayService` — the async actor–learner replay façade.

Wires the pipeline stages into one serving-shaped system:

    actors (threads, jitted rollout chunks)
        └── transition blocks ──> replay thread (ring writes, canonical
                                  buffer state, priority feedback applies)
                                        └── state snapshots ──> prefetch
                                                thread (slab sampling)
                                                    └── batch slabs ──>
    learner (caller thread, fused TD steps)
        └── deferred priority feedback ──> replay thread (stamped,
                                           out-of-band, exactly once)

The canonical replay state is owned by ONE thread (the replay thread);
every other stage sees it only as immutable snapshots, so there are no
locks around JAX state — just bounded queues.  ``sync=True`` degrades
the service to a strict synchronous mode: the exact ``agent_step``
iteration of the scan trainer driven step-by-step, which is the
apples-to-apples baseline the async speedup is measured against (and the
mode the equivalence tests pin to the scan trainer's learning curve).

Durability: pass a :class:`~repro.train.checkpoint.CheckpointManager` to
:meth:`ReplayService.run` and the service checkpoints the WHOLE replay
stack — params, optimizer moments, the canonical ``ReplayState``
(storage, priority tables, write stamps, ``max_priority``, ring
position), per-actor env states and PRNG stream positions, and the
prefetcher's draw counter — and auto-resumes from the latest checkpoint.
Checkpoints are incremental (delta chains over the ring arcs and touched
priority rows actually written since the last save — see
``train/replay_checkpoint.replay_dirty``) and, in async mode,
copy-on-write: nothing pauses.  The replay thread owns the canonical
state as immutable pytrees, so :class:`_CowSnapshotter` captures the
current state *reference* plus host counter watermarks on the learner
thread (microseconds) and serializes on its own thread while actors,
prefetcher, learner and replay thread keep running.  In-flight blocks
and feedback slabs are simply absent from the snapshot; the stamped
exactly-once feedback contract (PR 3) makes that safe on resume.  In
sync mode a killed run resumed from its checkpoint is BIT-IDENTICAL to
an uninterrupted one (pinned by ``tests/test_resume.py``); async resume
is tolerance-level by nature (thread interleaving changes which frames
land first).

Metrics cover the questions the paper's latency story raises at system
scale: learner steps/sec, environment frames/sec, queue depths (is the
sampler or the actor pool the bottleneck?), and priority-feedback
staleness (how many learner steps old is a priority when it lands).
"""
from __future__ import annotations

import collections
import functools
import queue
import threading
import time
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.analysis.locks import tracked_queue
from repro.rl.dqn import DQNConfig, make_dqn
from repro.runtime.actor import ActorPool, make_rollout, put_with_stop
from repro.runtime.learner import Feedback, Learner, make_slab_learner
from repro.runtime.pipeline import PrefetchPipeline, make_slab_sampler
from repro.train import checkpoint as ckpt_mod
from repro.train import replay_checkpoint as rck


class RunResult(NamedTuple):
    params: Any          # final network params (dqn.evaluate accepts them)
    target_params: Any
    buffer: Any          # final canonical ReplayState
    metrics: dict


def _hstats(snap: obs.Snapshot, name: str) -> dict:
    """Histogram summary from a snapshot, zeros when absent/empty."""
    data = snap.data.get(name)
    if not data:
        return {"count": 0, "mean": 0.0, "min": 0.0, "max": 0.0,
                "p50": 0.0, "p95": 0.0, "p99": 0.0}
    return obs.hist_stats(data, snap.meta[name]["bounds"])


def _cval(snap: obs.Snapshot, name: str) -> float:
    data = snap.data.get(name)
    if not data:
        return 0.0
    v = data.get("value", 0.0)
    return 0.0 if v != v else float(v)  # NaN (unset gauge) -> 0


class _RunTelemetry:
    """Per-run observability bundle: registry, instruments, exporters.

    Built at ``run()`` entry and installed as the process-global
    registry for the run's duration, so spans recorded by the runtime
    threads and by the checkpoint/core layers all land in one place;
    :meth:`finish` restores the previous registry.  The service always
    runs with an ENABLED registry (the aggregate staleness/queue-depth
    stats were always kept); the user's Telemetry spec adds exporters
    and the replay-health probe on top.  ``RunResult.metrics`` is
    computed from a snapshot diff against the run-start snapshot, so a
    long-lived caller-supplied registry still yields per-run numbers.
    """

    def __init__(self, spec: obs.Telemetry | None):
        # No spec -> aggregate stats only: no exporters and no health
        # probe (probing spends a jitted dispatch per cadence tick,
        # which un-instrumented runs and perf benchmarks must not pay).
        self.spec = (spec if spec is not None
                     else obs.Telemetry(probe_every=0))
        self.registry = (self.spec.registry if self.spec.registry is not None
                         else obs.Registry(enabled=True))
        r = self.registry
        self.frames = r.counter(
            "frames_total", help="environment frames appended to replay")
        self.blocks = r.counter(
            "blocks_total", help="transition blocks absorbed by the core")
        self.fb_enqueued = r.counter(
            "feedback_enqueued_total",
            help="priority-feedback slabs enqueued")
        self.fb_applied = r.counter(
            "feedback_applied_total", help="priority-feedback slabs applied")
        self.staleness = r.histogram(
            "staleness_steps", bounds=obs.INT_BUCKETS,
            help="priority-feedback staleness in learner steps")
        self.work_depth = r.histogram(
            "work_queue_depth", bounds=obs.INT_BUCKETS,
            help="actor->replay queue depth per drained item")
        self.batch_depth = r.histogram(
            "batch_queue_depth", bounds=obs.INT_BUCKETS,
            help="prefetch->learner queue depth per drained item")
        self.snap_pause = r.histogram(
            "snapshot_pause_us", bounds=obs.US_BUCKETS,
            help="pipeline pause per snapshot: COW capture cost in async "
                 "mode, the blocking save in sync mode (microseconds)")
        self.base = r.snapshot()
        self.exporter = (obs.JsonlExporter(self.spec.metrics_out)
                         if self.spec.metrics_out else None)
        self.health: obs.ReplayHealth | None = None
        self._prev = obs.set_registry(r, profile=self.spec.profile)
        self._finished = False

    def probe_hook(self, sampler, batch: int):
        """Build the pipeline's probe callback (None when probing is
        off).  The callback runs on the prefetch thread at cadence: it
        re-derives the draw's CSP facts, refreshes the health gauges,
        and appends a JSONL snapshot line so the log is a timeline."""
        if self.spec.probe_every <= 0:
            return None
        self.health = obs.ReplayHealth(self.registry, sampler, batch,
                                       window=self.spec.window)

        def hook(state, key):
            self.health.update(state.sampler_state, key)
            if self.exporter is not None:
                self.exporter.write_snapshot(self.diff())

        return hook

    def diff(self) -> obs.Snapshot:
        return self.registry.snapshot().diff(self.base)

    def event(self, name: str, **fields) -> None:
        if self.exporter is not None:
            self.exporter.write_event(name, **fields)

    def finish(self, extra: dict | None = None) -> None:
        """Final JSONL snapshot + Prometheus dump, then restore the
        previously installed global registry.  Idempotent."""
        if self._finished:
            return
        self._finished = True
        if self.exporter is not None:
            self.exporter.write_snapshot(self.diff(), extra=extra)
            self.exporter.close()
        if self.spec.prometheus_out:
            obs.write_prometheus(self.registry, self.spec.prometheus_out)
        obs.set_registry(self._prev)


class ReplayService:
    """Asynchronous actor–learner replay service (or its strict-sync twin).

    Args:
      cfg: the DQN config (env, sampler, batch, schedules).
      num_actors: actor threads; each steps ``cfg.num_envs`` envs.
      sync: strict synchronous mode — requires ``num_actors=1`` and
        reproduces the scan trainer's iteration exactly.
      chunk_len: env steps per actor rollout chunk (one dispatch).
      slab: batches per prefetch draw / fused learner call.
      prefetch_depth: batch-slab queue depth (2 = double buffering).
      queue_size: transition-block + feedback queue bound (backpressure).
      min_size: buffer fill before sampling starts; defaults to the scan
        trainer's ``learn_start`` worth of frames.
      max_replay_ratio: optional frames-per-learner-step cap; actors
        pause when generation runs this far ahead of consumption (frees
        host cores for the learner on small machines).
      feedback_log: record the exact per-batch feedback sequence trace in
        ``metrics["feedback_seqs"]`` (O(learner steps) memory — for tests
        and debugging; the aggregate staleness stats are always kept).
      device: optional target device for prefetched batches.
      telemetry: an :class:`repro.obs.Telemetry` spec.  The service
        always keeps registry-backed run metrics (staleness, queue
        depths, snapshot pauses — the compat ``RunResult.metrics`` view
        is computed from them); the spec adds the JSONL/Prometheus
        exporters and the replay-health probe (live Fig. 7 KL gauge,
        CSP occupancy, fallback rate) on top.
    """

    def __init__(self, cfg: DQNConfig, *, num_actors: int = 2,
                 sync: bool = False, chunk_len: int = 32, slab: int = 4,
                 prefetch_depth: int = 2, queue_size: int = 8,
                 min_size: int | None = None,
                 max_replay_ratio: float | None = None,
                 feedback_log: bool = False, device=None,
                 telemetry: obs.Telemetry | None = None):
        if sync and num_actors != 1:
            raise ValueError("sync mode is defined for num_actors=1 "
                             f"(got {num_actors})")
        self.cfg = cfg
        self.sync = sync
        self.num_actors = num_actors
        self.chunk_len = chunk_len
        self.slab = slab
        self.prefetch_depth = prefetch_depth
        self.queue_size = queue_size
        self.device = device
        self.min_size = (min_size if min_size is not None else
                         max(cfg.batch,
                             min(cfg.learn_start * cfg.num_envs,
                                 cfg.replay_size)))
        self.max_replay_ratio = max_replay_ratio
        self.feedback_log = feedback_log
        self.telemetry = telemetry
        self.dqn = make_dqn(cfg)
        rb = self.dqn.replay
        # Frame-deduplicated storage chains stacks through ring adjacency
        # (slot i-stride must be the previous timestep of the same env
        # stream).  Interleaved blocks from multiple actors would break
        # that invariant on every chunk boundary, so pixel runs are
        # single-actor (the actor is still cfg.num_envs-wide).
        if rb.frame_store is not None and num_actors != 1:
            raise ValueError(
                "frame-store replay requires num_actors=1: stack "
                "materialization relies on single-stream ring adjacency "
                f"(got num_actors={num_actors})")
        # One jitted callable per pipeline stage, built once so repeated
        # run() calls (warmup, then measurement) reuse the compile cache.
        self._rollout = jax.jit(make_rollout(self.dqn, chunk_len))
        self._sample = jax.jit(make_slab_sampler(rb, cfg.batch, slab))
        # The slab's batch/weight buffers are consumed exactly once ->
        # donate them (args 5, 6); params/target stay undonated because
        # actors and the target alias them across calls.  The CPU backend
        # cannot reuse donated buffers and warns, so only donate off-CPU.
        donate = () if jax.default_backend() == "cpu" else (5, 6)
        self._learn = jax.jit(make_slab_learner(self.dqn),
                              donate_argnums=donate)
        # Actors pre-aggregate n-step rows in their own accumulators, so
        # the canonical buffer must not run its accumulator again.
        # The transition block is consumed by exactly this one write, so
        # its buffers are donated (off-CPU, as above).  The replay STATE
        # is never donated here or in the feedback apply: the prefetcher
        # snapshots self._bstate by reference and may be mid-draw on the
        # same buffers when the next write lands — donating the table
        # would invalidate the arrays under it.  XLA still updates the
        # priority rows in place inside the dispatch; donation would only
        # save the copy of the *unchanged* leaves, and correctness wins.
        donate_block = () if jax.default_backend() == "cpu" else (1,)
        self._add_block = jax.jit(
            functools.partial(rb.add_block, aggregated=True),
            donate_argnums=donate_block)

        def apply_feedback(state, idx, td, stamp):
            # Flatten [S, batch] row-major: masked_update resolves rows
            # duplicated across batches to their last occurrence, so one
            # scatter reproduces sequential-apply semantics (stamps can't
            # change between rows of a slab).  Stamps are (counter, gen)
            # pairs — keep their trailing word axis through the flatten.
            flat = lambda x: x.reshape(-1)
            return rb.update_priorities(
                state, flat(idx), flat(td), stamp=stamp.reshape(-1, 2))

        # The feedback slab (idx/td/stamp) is consumed exactly once by
        # this apply — donate those buffers; the state stays undonated
        # (prefetcher aliasing, see above).  The dirty-row log for
        # incremental snapshots takes a HOST copy of fb.idx before the
        # apply runs, so donating idx stays safe.
        donate_fb = () if jax.default_backend() == "cpu" else (1, 2, 3)
        self._apply_feedback = jax.jit(apply_feedback,
                                       donate_argnums=donate_fb)
        self._agent_step = jax.jit(self.dqn.agent_step)
        # (fb_applied_at_append, host idx rows) log the replay thread
        # feeds and the COW snapshotter consumes; None when the run has
        # no checkpoint manager (zero cost on the hot path).
        self._fb_rows: collections.deque | None = None
        # (learned, synced) -> cached non-buffer sync dirty tree.
        self._sync_dirty_tpl: dict = {}

    # ------------------------------------------------------------------ #

    def run(self, key: jax.Array, n_steps: int,
            manager: ckpt_mod.CheckpointManager | None = None) -> RunResult:
        """Train for ``n_steps`` — scan-trainer iterations in sync mode,
        learner steps (rounded up to a whole slab) in async mode.

        With a ``manager`` the run checkpoints periodically (and on
        preemption) and AUTO-RESUMES from the manager's latest
        checkpoint; ``n_steps`` is the absolute target, so a resumed run
        executes only the remainder.  The saved snapshot embeds the run
        key, so the resumed process does not need to pass the same
        ``key`` — but sync mode validates ``n_steps`` (the step-key array
        derivation depends on it).
        """
        if manager is not None:
            manager.install_preemption_hook()  # no-op off the main thread
        tel = _RunTelemetry(self.telemetry)
        try:
            if self.sync:
                result = self._run_sync(key, n_steps, manager, tel)
            else:
                result = self._run_async(key, n_steps, manager, tel)
        finally:
            tel.finish(extra={"mode": "sync" if self.sync else "async"})
        return result

    # --- checkpoint snapshot targets ----------------------------------- #

    def _key_data_struct(self):
        kd = jax.random.key_data(jax.random.key(0))
        return jax.ShapeDtypeStruct(kd.shape, kd.dtype)

    def _sync_target(self):
        return {"key_data": self._key_data_struct(),
                "state": jax.eval_shape(self.dqn.init, jax.random.key(0))}

    def _async_target(self):
        a = jax.eval_shape(self.dqn.init, jax.random.key(0))
        actor_t = {"env_state": a.env_state, "obs": a.obs,
                   "ep_ret": jax.ShapeDtypeStruct((self.cfg.num_envs,),
                                                  jnp.float32),
                   # the actor's own n-step window (None when n_step=1);
                   # same abstract shape as the buffer's in-state one
                   "nstep": a.buffer.nstep}
        return {"key_data": self._key_data_struct(),
                "params": a.params, "target_params": a.target_params,
                "opt_m": a.opt_m, "opt_v": a.opt_v, "buffer": a.buffer,
                "actors": [actor_t for _ in range(self.num_actors)]}

    def _restore(self, manager, target, mode: str, **expected):
        """(step, snapshot, meta) from the latest checkpoint, or Nones.

        The meta is validated BEFORE the arrays load, so a topology
        mismatch (actor count, mode, n_steps) reads as what it is rather
        than a leaf-count error.  The buffer subtree is device_put with
        the CURRENT sampler's mesh placement (``replay_shardings``), so a
        snapshot saved on 8 shards resumes on 2 — or on one device —
        transparently.
        """
        step = manager.latest_step()
        if step is None:
            return None, None, None
        meta = ckpt_mod.load_meta(manager.directory, step)
        self._check_meta(meta, mode, **expected)
        snap = ckpt_mod.restore(
            manager.directory, step, target,
            rck.replay_shardings(self.dqn.replay, target))
        return step, snap, meta

    @staticmethod
    def _check_meta(meta: dict, mode: str, **expected) -> None:
        if meta.get("mode") != mode:
            raise ValueError(f"checkpoint was written by a "
                             f"{meta.get('mode')!r}-mode run, cannot "
                             f"resume in {mode!r} mode")
        for k, want in expected.items():
            # An absent key is as much a topology mismatch as a wrong
            # value — .get(k, want) would silently accept a checkpoint
            # written before the field existed.
            if k not in meta:
                raise ValueError(f"checkpoint meta has no {k!r} field "
                                 f"(expected {k}={want}); it was written "
                                 f"by an incompatible service version")
            if meta[k] != want:
                raise ValueError(f"checkpoint {k}={meta[k]} does not match "
                                 f"this service's {k}={want}")

    # --- strict synchronous mode -------------------------------------- #

    def _run_sync(self, key: jax.Array, n_steps: int,
                  manager: ckpt_mod.CheckpointManager | None,
                  tel: _RunTelemetry) -> RunResult:
        cfg = self.cfg
        start = 0
        state = None
        marks = None       # replay watermarks of the last on-disk save
        if manager is not None:
            step, snap, meta = self._restore(manager, self._sync_target(),
                                             "sync", n_steps=n_steps)
            if step is not None:
                key = jax.random.wrap_key_data(snap["key_data"])
                state, start = snap["state"], int(meta["step"])
                # The restored state IS the manager's latest checkpoint,
                # so the next save can be a delta against it.
                marks = rck.replay_marks(state.buffer)
        if state is None:
            state = self.dqn.init(key)
        # Same step-key derivation as the scan trainer's _train.
        keys = jax.random.split(jax.random.fold_in(key, 1), n_steps)
        returns = []
        preempted_at = None
        prev_save_t = start
        t0 = time.perf_counter()
        t_first_learn = None
        t_end = start
        for t in range(start, n_steps):
            if t == max(cfg.learn_start, start):
                jax.block_until_ready(state.params)
                t_first_learn = time.perf_counter()
            state, m = self._agent_step(state, keys[t])
            returns.append(m["return_mean"])
            t_end = t + 1
            if manager is not None and (manager.should_save(t + 1)
                                        or t + 1 == n_steps):
                dirty = (self._sync_dirty(state, marks, prev_save_t, t + 1)
                         if marks is not None else None)
                # Sync saves block the training loop, so the whole save
                # IS the pipeline pause — record it in the same
                # instrument the async COW capture uses (uniform schema).
                t_save = time.perf_counter()
                manager.save(t + 1,
                             {"key_data": jax.random.key_data(key),
                              "state": state},
                             meta={"mode": "sync", "step": t + 1,
                                   "n_steps": n_steps},
                             dirty=dirty)
                tel.snap_pause.observe(
                    (time.perf_counter() - t_save) * 1e6)
                tel.event("checkpoint", step=t + 1,
                          delta=dirty is not None)
                marks = rck.replay_marks(state.buffer)
                prev_save_t = t + 1
                if manager.preempted and t + 1 < n_steps:
                    preempted_at = t + 1
                    break
        jax.block_until_ready(state.params)
        wall_end = time.perf_counter()
        learner_steps = sum(
            1 for t in range(start, t_end)
            if t >= cfg.learn_start and t % cfg.train_every == 0)
        learn_wall = (wall_end - t_first_learn if t_first_learn is not None
                      else float("nan"))
        curve = np.asarray(jnp.stack(returns)) if returns else np.zeros(0)
        snap = tel.diff()
        pause = _hstats(snap, "snapshot_pause_us")
        metrics = {
            "mode": "sync",
            "learner_steps": learner_steps,
            "learner_steps_per_sec": (learner_steps / learn_wall
                                      if learner_steps else 0.0),
            "wall_time": wall_end - t0,
            "frames": (t_end - start) * cfg.num_envs,
            "frames_per_sec": ((t_end - start) * cfg.num_envs
                               / max(wall_end - t0, 1e-9)),
            "return_mean": float(curve[-1]) if len(curve) else 0.0,
            "return_curve": curve,
            # β the last executed step's draw used — the annealed value,
            # not the frozen constructor default.
            "beta": float(self.dqn.beta_at(max(t_end - 1, 0))),
            # Sync draws apply feedback inline — staleness is zero by
            # construction; the keys exist so both modes share a schema.
            "staleness": {"count": 0, "mean": 0.0, "max": 0,
                          "p50": 0, "p95": 0, "p99": 0},
            "queue_depth": {"work_mean": 0.0, "batch_mean": 0.0},
            "resumed_from": start if start else None,
            "preempted_at": preempted_at,
            # Uniform snapshot/checkpoint schema with async mode: here
            # every save blocks the loop, so count == saved and the
            # pause histogram holds whole save latencies.
            "snapshot": {
                "count": pause["count"],
                "saved": pause["count"],
                "pause_us_mean": pause["mean"],
                "pause_us_max": pause["max"],
                "drain_cycles": 0,
            },
            "checkpoint": self._checkpoint_metrics(snap, manager),
        }
        return RunResult(params=state.params,
                         target_params=state.target_params,
                         buffer=state.buffer, metrics=metrics)

    @staticmethod
    def _checkpoint_metrics(snap: obs.Snapshot, manager) -> dict:
        """Checkpoint overhead view shared by both modes (zeros when the
        run had no manager)."""
        save = _hstats(snap, "span_checkpoint_save_ms")
        return {
            "saves": save["count"],
            "save_ms_mean": save["mean"],
            "save_ms_max": save["max"],
            "full_bytes": _cval(snap, "checkpoint_full_bytes"),
            "delta_bytes": _cval(snap, "checkpoint_delta_bytes"),
            "chain_len": (manager._chain_len if manager is not None else 0),
        }

    def _sync_dirty(self, state, marks: dict, t0: int, t1: int):
        """Dirty tree for the sync snapshot covering steps ``[t0, t1)``.

        The scan step's scheduling is structural — step t learns iff
        ``t >= learn_start and t % train_every == 0`` and target-syncs
        iff ``t % target_sync == 0`` — so whether params / optimizer
        moments / target / priority tables changed in the window is
        decidable host-side without reading a single array.  Storage and
        write stamps are dirty exactly on the ring arc the window's adds
        wrote; priority tables are arc-only when no learning happened
        and full otherwise (the sampled rows live inside the jit).
        Everything small (scalars, env state, episode accounting) is
        always saved.
        """
        cfg = self.cfg
        learned = any(t >= cfg.learn_start and t % cfg.train_every == 0
                      for t in range(t0, t1))
        synced = any(t % cfg.target_sync == 0 for t in range(t0, t1))
        # The non-buffer part of the dirty tree depends only on the two
        # predicates (the state's structure is fixed for the run), so
        # cache it — rebuilding ~6 tree maps per save is measurable at
        # the benchmark's save cadence.
        tpl = self._sync_dirty_tpl.get((learned, synced))
        if tpl is None:
            tpl = jax.tree.map(lambda _: True, state)._replace(
                params=ckpt_mod.dirty_like(state.params, learned),
                target_params=ckpt_mod.dirty_like(state.target_params,
                                                  synced),
                opt_m=ckpt_mod.dirty_like(state.opt_m, learned),
                opt_v=ckpt_mod.dirty_like(state.opt_v, learned))
            self._sync_dirty_tpl[(learned, synced)] = tpl
        bd = rck.replay_dirty(self.dqn.replay, state.buffer, marks)
        if learned:
            bd = bd._replace(sampler_state=ckpt_mod.dirty_like(
                state.buffer.sampler_state, True))
        return {"key_data": True, "state": tpl._replace(buffer=bd)}

    # --- asynchronous mode -------------------------------------------- #

    def _run_async(self, key: jax.Array, n_steps: int,
                   manager: ckpt_mod.CheckpointManager | None,
                   tel: _RunTelemetry) -> RunResult:
        cfg = self.cfg
        start_steps, prefetch_draw, frames0, blocks0 = 0, 0, 0, 0
        actor_resume = None
        snap = None
        resume_marks = None
        if manager is not None:
            step, snap, meta = self._restore(manager, self._async_target(),
                                             "async",
                                             num_actors=self.num_actors)
            if step is not None:
                key = jax.random.wrap_key_data(snap["key_data"])
                start_steps = int(meta["learner_steps"])
                prefetch_draw = int(meta["prefetch_draw"])
                frames0 = int(meta["frames"])
                blocks0 = int(meta["blocks"])
                actor_resume = [
                    {**a, "step": meta["actor_steps"][i],
                     "chunk": meta["actor_chunks"][i]}
                    for i, a in enumerate(snap["actors"])]
        if snap is not None and snap.get("params") is not None:
            params0, target0 = snap["params"], snap["target_params"]
            opt_m0, opt_v0 = snap["opt_m"], snap["opt_v"]
            self._bstate = snap["buffer"]
            # The restored buffer IS the manager's latest on-disk state:
            # the first snapshot of this run can be a delta against it.
            # fb_applied is 0 in THIS run's counter space (fresh log).
            resume_marks = {**rck.replay_marks(self._bstate),
                            "fb_applied": 0}
        else:
            state0 = self.dqn.init(key)
            params0, target0 = state0.params, state0.target_params
            opt_m0, opt_v0 = state0.opt_m, state0.opt_v
            self._bstate = state0.buffer          # canonical replay state
        params_box = [params0]                # actors read, learner swaps
        work_q: queue.Queue = tracked_queue("runtime.work_q", self.queue_size)
        self._work_q = work_q
        batch_q: queue.Queue = tracked_queue(
            "runtime.batch_q", self.prefetch_depth)
        stop = threading.Event()
        self._fb_rows = collections.deque() if manager is not None else None
        # The rec dict is the CONTROL PLANE: counters the COW snapshot
        # consistency contract and the replay-ratio budget read (the
        # publish-state-before-bump ordering in _replay_loop depends on
        # them staying plain same-thread ints).  Pure observability
        # aggregates (staleness, queue depths, snapshot pauses) live in
        # the telemetry registry's lock-free instruments instead.
        rec = {"frames": 0, "blocks": 0,
               "fb_enqueued": 0, "fb_applied": 0,
               "feedback_seqs": [] if self.feedback_log else None,
               "returns": collections.deque(maxlen=256), "error": None}

        def feedback_put(fb):
            ok = put_with_stop(work_q, ("feedback", fb), stop)
            if ok:
                rec["fb_enqueued"] += 1
                tel.fb_enqueued.add()
            return ok

        last_saved = [start_steps]
        snapper: _CowSnapshotter | None = None

        def on_slab(params, target_params, opt_m, opt_v):
            """Checkpoint hook, on the learner (caller) thread.  O(µs):
            the snapshotter only grabs references and counters here; the
            serialization runs on its own thread.  Returns True to stop
            the learner early (preemption)."""
            if manager is None:
                return False
            steps = learner.steps_done
            preempt = manager.preempted
            due = steps - last_saved[0] >= manager.save_interval
            if not (preempt or due):
                return False
            if steps != last_saved[0] and snapper.capture(
                    steps, params, target_params, opt_m, opt_v):
                last_saved[0] = steps
            return preempt and steps < n_steps

        learner = Learner(
            self._learn, in_q=batch_q, feedback_put=feedback_put,
            publish=lambda p: params_box.__setitem__(0, p),
            target_sync=cfg.target_sync, stop=stop,
            start_steps=start_steps, on_slab=on_slab)
        replay_thread = threading.Thread(
            target=self._replay_loop, name="replay-core",
            args=(work_q, batch_q, stop, learner, rec, tel), daemon=True)
        budget_fn = None
        if self.max_replay_ratio is not None:
            ratio, head = self.max_replay_ratio, self.min_size

            def budget_fn():
                return (frames0 + rec["frames"]
                        < head + ratio * max(learner.steps_done, 1))

        # No PauseGate: snapshots are copy-on-write, nothing ever parks.
        pool = ActorPool(
            self.dqn, self._rollout, num_actors=self.num_actors,
            params_fn=lambda: params_box[0], out_q=work_q, stop=stop,
            base_key=key, chunk_len=self.chunk_len, budget_fn=budget_fn,
            resume_states=actor_resume)
        prefetch = PrefetchPipeline(
            self._sample,
            state_fn=lambda: (self._bstate, learner.steps_done),
            out_q=batch_q, stop=stop, base_key=key, slab=self.slab,
            min_size=self.min_size, device=self.device,
            beta_fn=self.dqn.beta_at,
            start_draw=prefetch_draw, start_seq=start_steps,
            probe=tel.probe_hook(self.dqn.replay.sampler,
                                 self.cfg.batch * self.slab),
            probe_every=tel.spec.probe_every)
        if manager is not None:
            snapper = _CowSnapshotter(self, manager, pool, prefetch, key,
                                      rec, frames0, blocks0,
                                      resume_marks=resume_marks, tel=tel)

        def shutdown():
            stop.set()
            pool.join(timeout=10.0)
            prefetch.join(timeout=10.0)
            replay_thread.join(timeout=10.0)
            if snapper is not None:
                snapper.drain()  # finish any in-flight snapshot write

        def raise_worker_errors():
            if rec["error"] is not None:
                raise RuntimeError("replay thread failed") from rec["error"]
            if prefetch.error is not None:
                raise RuntimeError(
                    "prefetch pipeline failed") from prefetch.error
            if snapper is not None and snapper.error is not None:
                raise RuntimeError(
                    "snapshot writer failed") from snapper.error
            pool.raise_errors()

        t0 = time.perf_counter()
        replay_thread.start()
        pool.start()
        prefetch.start()
        try:
            params, target_params = learner.run(
                params0, target0, opt_m0, opt_v0, n_steps)
            jax.block_until_ready(params)
            t_end = time.perf_counter()
        except BaseException:
            # Join first, then surface the root cause: a learner failure
            # is often secondary to a worker-thread fault, and raising
            # from it here chains both tracebacks.
            shutdown()
            raise_worker_errors()
            raise
        shutdown()
        raise_worker_errors()
        preempted_at = None
        if manager is not None:
            if manager.preempted and learner.steps_done < n_steps:
                preempted_at = learner.steps_done
            if learner.steps_done != last_saved[0]:
                # Final checkpoint: threads are joined and the replay
                # thread drained every queue before exiting, so the state
                # is already quiescent — no pause protocol needed.
                self._save_snapshot(manager, learner.steps_done, params,
                                    target_params, learner.opt_m,
                                    learner.opt_v, key, pool, prefetch,
                                    rec, frames0, blocks0)

        learn_wall = (t_end - learner.first_step_time
                      if learner.first_step_time else float("nan"))
        wall = t_end - t0
        returns = np.asarray(rec["returns"])
        snap = tel.diff()
        stale = _hstats(snap, "staleness_steps")
        workd = _hstats(snap, "work_queue_depth")
        batchd = _hstats(snap, "batch_queue_depth")
        pause = _hstats(snap, "snapshot_pause_us")
        metrics = {
            "mode": "async",
            "learner_steps": learner.steps_done - start_steps,
            "total_learner_steps": learner.steps_done,
            "learner_steps_per_sec": (
                (learner.steps_done - start_steps) / learn_wall
                if learner.steps_done > start_steps else 0.0),
            "wall_time": wall,
            "frames": rec["frames"],
            "total_frames": frames0 + rec["frames"],
            # Same zero-wall guard as the sync path: a run that resumes
            # at its target does zero work in epsilon time.
            "frames_per_sec": rec["frames"] / max(wall, 1e-9),
            "blocks": rec["blocks"],
            "return_mean": (float(returns[-64:].mean())
                            if returns.size else 0.0),
            "recent_returns": returns[-64:],
            # β of the prefetcher's latest slab draw (annealed), falling
            # back to the schedule at the last executed learner step
            # (same convention as sync mode) if no draw happened.
            "beta": (prefetch.last_beta if prefetch.last_beta is not None
                     else float(self.dqn.beta_at(
                         max(learner.steps_done - 1, 0)))),
            "feedback_seqs": rec["feedback_seqs"],
            # Compatibility view over the registry's staleness histogram:
            # count/sum are exact, max is exact, and the INT_BUCKETS
            # bounds make the percentiles exact for staleness <= 64.
            "staleness": {
                "count": stale["count"],
                "mean": stale["mean"],
                "max": int(stale["max"]),
                "p50": int(stale["p50"]),
                "p95": int(stale["p95"]),
                "p99": int(stale["p99"]),
            },
            "queue_depth": {
                "work_mean": workd["mean"],
                "batch_mean": batchd["mean"],
            },
            "losses": [float(l) for l in learner.losses],
            "resumed_from": start_steps if start_steps else None,
            "preempted_at": preempted_at,
            # COW snapshot accounting: "pause" is the learner-thread
            # capture cost (reference grab + watermark reads), the only
            # stall a snapshot inflicts on the pipeline.  drain_cycles
            # is the number of full pause→drain quiesce protocols run —
            # structurally zero since the COW rework, kept as a column
            # so the benchmark trajectory records the regime change.
            "snapshot": {
                "count": pause["count"],
                "saved": snapper.saved if snapper is not None else 0,
                "pause_us_mean": pause["mean"],
                "pause_us_max": pause["max"],
                "drain_cycles": 0,
            },
            "checkpoint": self._checkpoint_metrics(snap, manager),
        }
        if tel.health is not None:
            metrics["health"] = {
                "kl_nats": tel.health.monitor.kl(),
                "chi2": tel.health.monitor.chi_square(),
                "csp_occupancy": _cval(snap, "csp_occupancy"),
                "fallback_draws": _cval(snap, "fallback_draws"),
                "probe_draws": _cval(snap, "probe_draws"),
            }
        return RunResult(params=params, target_params=target_params,
                         buffer=self._bstate, metrics=metrics)

    # --- snapshot protocol -------------------------------------------- #

    def _async_dirty(self, bstate, snap: dict, marks: dict, rows):
        """Dirty tree for an async snapshot relative to ``marks``.

        The buffer gets the exact ring-arc + touched-priority-row set;
        every other component (params, optimizer moments, actor states,
        the key) changes every slab or is tiny — always full.
        """
        bd = rck.replay_dirty(self.dqn.replay, bstate, marks,
                              priority_rows=rows)
        return {k: (bd if k == "buffer" else ckpt_mod.dirty_like(v, True))
                for k, v in snap.items()}

    def _save_snapshot(self, manager, steps, params, target_params,
                       opt_m, opt_v, key, pool, prefetch, rec,
                       frames0, blocks0) -> None:
        run_states = pool.run_states()
        snap = {"key_data": jax.random.key_data(key),
                "params": params, "target_params": target_params,
                "opt_m": opt_m, "opt_v": opt_v, "buffer": self._bstate,
                "actors": [{"env_state": rs["env_state"], "obs": rs["obs"],
                            "ep_ret": rs["ep_ret"], "nstep": rs["nstep"]}
                           for rs in run_states]}
        meta = {"mode": "async", "learner_steps": int(steps),
                "num_actors": self.num_actors,
                "prefetch_draw": int(prefetch.draws),
                "frames": int(frames0 + rec["frames"]),
                "blocks": int(blocks0 + rec["blocks"]),
                "actor_steps": [int(rs["step"]) for rs in run_states],
                "actor_chunks": [int(rs["chunk"]) for rs in run_states]}
        manager.save(int(steps), snap, meta=meta)

    def _replay_loop(self, work_q: queue.Queue, batch_q: queue.Queue,
                     stop: threading.Event, learner: Learner,
                     rec: dict, tel: _RunTelemetry) -> None:
        """The one owner of the canonical replay state: applies transition
        blocks and deferred priority feedback in arrival order, publishes
        immutable snapshots for the prefetcher.  Each publish REPLACES
        ``self._bstate`` with a fresh pytree (never mutates), which is
        what lets the COW snapshotter treat any captured reference as a
        consistent checkpoint without pausing this thread."""
        try:
            bstate = self._bstate
            while True:
                try:
                    tag, item = work_q.get(timeout=0.05)
                except queue.Empty:
                    if stop.is_set() and learner.finished and work_q.empty():
                        return
                    continue
                # Ordering contract with the snapshot drain check: publish
                # the new canonical state BEFORE bumping the applied
                # counters, so "counters say drained" implies the saved
                # self._bstate already contains the counted item.
                if tag == "block":
                    if item.transitions is not None:  # None: all rows fell
                        with obs.span("add_block"):    # in n-step warm-up
                            bstate = self._add_block(bstate,
                                                     item.transitions)
                        self._bstate = bstate
                    rec["frames"] += item.frames
                    rec["blocks"] += 1
                    tel.frames.add(item.frames)
                    tel.blocks.add()
                    rec["returns"].extend(item.completed_returns.tolist())
                else:  # deferred priority feedback (one slab, S batches)
                    fb: Feedback = item
                    if self._fb_rows is not None:
                        # Dirty-row log for incremental snapshots: append
                        # BEFORE the apply/publish (host copy — fb.idx is
                        # donated to the apply below), so any feedback
                        # visible in a captured state has its rows in the
                        # log and the COW dirty set is a superset, never
                        # an under-count.  Stale (stamp-dropped) rows get
                        # logged too; marking them dirty just re-writes
                        # identical bytes.
                        self._fb_rows.append(
                            (rec["fb_applied"], np.asarray(fb.idx).ravel()))
                    with obs.span("apply_feedback"):
                        bstate = self._apply_feedback(
                            bstate, fb.idx, fb.td, fb.stamp)
                    self._bstate = bstate
                    s = int(fb.idx.shape[0])
                    if rec["feedback_seqs"] is not None:
                        rec["feedback_seqs"].extend(
                            range(fb.seq0, fb.seq0 + s))
                    # The slab's S batches share one staleness value.
                    tel.staleness.observe_n(
                        learner.steps_done - fb.version, s)
                    rec["fb_applied"] += 1
                    tel.fb_applied.add()
                tel.work_depth.observe(work_q.qsize())
                tel.batch_depth.observe(batch_q.qsize())
        except BaseException as e:
            rec["error"] = e
            stop.set()


class _CowSnapshotter:
    """Copy-on-write checkpoint writer for the async runtime.

    The learner-thread half (:meth:`capture`) grabs immutable pytree
    references and host counter watermarks — no pause gate, no drain.
    The replay thread publishes every new canonical state as a *fresh*
    pytree, so a captured reference is a consistent snapshot by
    construction; a dedicated worker thread serializes it to disk while
    actors, prefetcher, learner and replay thread keep running.

    Consistency contract:

    * **state ⊇ counters.**  Capture reads the applied-feedback counter
      BEFORE the state reference, and the replay thread publishes state
      BEFORE bumping the counter — so the dirty rows computed from the
      previous save's counter watermark are a *superset* of what changed
      between the two states; a superset only re-writes identical bytes.
    * **in-flight work is absent, not torn.**  Blocks and feedback slabs
      still in queues at capture are simply not in the snapshot.  On
      resume the stamped exactly-once feedback contract (PR 3) makes the
      missing applies safe: priorities are one slab staler, which async
      resume tolerates by contract (``tests/test_resume.py`` pins the
      sequence-gaplessness of the resumed run, not frame identity).
    * **one save in flight.**  ``capture`` skips (returns False) while
      the worker is still writing, so manager chain bookkeeping and the
      marks/row-log pruning are strictly serialized.
    """

    def __init__(self, service: ReplayService, manager, pool, prefetch,
                 key, rec: dict, frames0: int, blocks0: int,
                 resume_marks: dict | None = None,
                 tel: _RunTelemetry | None = None):
        self._svc = service
        self._manager = manager
        self._pool = pool
        self._prefetch = prefetch
        self._key = key
        self._rec = rec
        self._tel = tel
        self._frames0 = frames0
        self._blocks0 = blocks0
        # Watermarks of the last successful on-disk save (None -> the
        # next save is full).  Only the worker thread writes this after
        # construction.
        self.marks = resume_marks
        self.saved = 0
        self.error: BaseException | None = None
        # The run key never changes — materialize its raw data once so
        # capture() does not dispatch a jax op per snapshot.
        self._key_data = np.asarray(jax.random.key_data(key))
        self._busy = threading.Event()
        self._q: queue.Queue = tracked_queue("runtime.snapshot_q", 1)
        self._thread = threading.Thread(target=self._worker,
                                        name="replay-snapshot", daemon=True)
        self._thread.start()

    def capture(self, steps, params, target_params, opt_m, opt_v) -> bool:
        """Learner-thread half: O(µs) reference grab — no device syncs,
        no tree walks; the dirty-set computation and the ``int()`` reads
        of the captured buffer's scalars happen on the worker thread
        (the captured pytree is frozen, so they read the same values).
        False = skipped (previous snapshot still writing, an error is
        pending, or an actor has not published its first run state yet).
        """
        if self.error is not None or self._busy.is_set():
            return False
        run_states = self._pool.run_states()
        if any(rs is None for rs in run_states):
            return False
        t0 = time.perf_counter()
        rec = self._rec
        a_now = rec["fb_applied"]      # read BEFORE the state reference
        bstate = self._svc._bstate
        snap = {"key_data": self._key_data,
                "params": params, "target_params": target_params,
                "opt_m": opt_m, "opt_v": opt_v, "buffer": bstate,
                "actors": [{"env_state": rs["env_state"], "obs": rs["obs"],
                            "ep_ret": rs["ep_ret"], "nstep": rs["nstep"]}
                           for rs in run_states]}
        meta = {"mode": "async", "learner_steps": int(steps),
                "num_actors": self._svc.num_actors,
                "prefetch_draw": int(self._prefetch.draws),
                "frames": int(self._frames0 + rec["frames"]),
                "blocks": int(self._blocks0 + rec["blocks"]),
                "actor_steps": [int(rs["step"]) for rs in run_states],
                "actor_chunks": [int(rs["chunk"]) for rs in run_states]}
        # Pause accounting covers the capture work itself; the queue put
        # below wakes the worker, whose overlapped serialization shows
        # up in the benchmark's wall-overhead column, not here.
        pause_us = (time.perf_counter() - t0) * 1e6
        if self._tel is not None:
            self._tel.snap_pause.observe(pause_us)
        self._busy.set()
        self._q.put((int(steps), snap, meta, a_now))
        return True

    def _worker(self) -> None:
        while True:
            job = self._q.get()
            if job is None:
                return
            steps, snap, meta, a_now = job
            try:
                bstate = snap["buffer"]
                dirty = None
                if self.marks is not None:
                    # Reading the row log here (after capture) can only
                    # see MORE entries than existed at capture — extra
                    # rows widen the dirty set, which is always safe.
                    a_base = self.marks["fb_applied"]
                    rows = [r for seq, arr in list(self._svc._fb_rows)
                            if seq >= a_base for r in arr]
                    dirty = self._svc._async_dirty(bstate, snap,
                                                   self.marks, rows)
                next_marks = {**rck.replay_marks(bstate),
                              "fb_applied": a_now}
                self._manager.save(steps, snap, meta=meta, dirty=dirty)
                self.marks = next_marks
                self.saved += 1
                if self._tel is not None:
                    self._tel.event("checkpoint", step=steps,
                                    delta=dirty is not None)
                # Entries older than the new watermark can never be
                # dirty again — prune (popleft racing the replay
                # thread's append is deque-safe).
                log = self._svc._fb_rows
                while log and log[0][0] < next_marks["fb_applied"]:
                    log.popleft()
            except BaseException as e:
                self.error = e   # surfaced by raise_worker_errors
            finally:
                self._busy.clear()

    def drain(self, timeout: float = 120.0) -> None:
        """Wait out any in-flight save, then stop the worker thread.
        After this returns the manager is safe to use from the caller
        (the final quiescent save)."""
        deadline = time.monotonic() + timeout
        while self._busy.is_set() and time.monotonic() < deadline:
            time.sleep(0.002)
        try:
            self._q.put_nowait(None)
        except queue.Full:
            pass
        self._thread.join(timeout=10.0)
