"""PRNG stream discipline for the async runtime.

Every concurrent consumer of randomness — each actor thread and the
prefetch pipeline — folds a distinct stream tag (and, for actors, its
actor id) into the run key before deriving per-chunk / per-draw keys, so
no two threads ever consume the same key and no key is consumed twice
within a thread.  This mirrors the PR 1 ``agent_step`` /
``sample_from_csp`` key-split fixes at the thread level: reuse would
correlate exploration noise across actors (or exploration with
sampling), silently biasing the replay distribution.

Layout (``key`` is the key passed to ``ReplayService.run``):

  actor i     fold_in(fold_in(key, ACTOR_STREAM), i) --split--> (reset, roll)
              chunk c uses fold_in(roll, c); step t in the chunk folds t
  prefetcher  fold_in(fold_in(key, SAMPLE_STREAM), draw_seq)

``ReplayService`` itself uses the run key only through ``dqn.init`` (and
the strict-sync path reproduces the scan trainer's ``fold_in(key, 1)``
step-key derivation exactly), so none of the streams above collide with
the init stream either.
"""
from __future__ import annotations

import jax

ACTOR_STREAM = 0xAC70  # actor-pool stream tag
SAMPLE_STREAM = 0x5A4B  # prefetch-pipeline stream tag


def actor_keys(key: jax.Array, actor_id: int) -> tuple[jax.Array, jax.Array]:
    """-> (env-reset key, rollout stream key) for one actor thread."""
    stream = jax.random.fold_in(jax.random.fold_in(key, ACTOR_STREAM), actor_id)
    k_reset, k_roll = jax.random.split(stream)
    return k_reset, k_roll


def chunk_key(roll_key: jax.Array, chunk_id: int) -> jax.Array:
    """Per-rollout-chunk key within one actor's stream."""
    return jax.random.fold_in(roll_key, chunk_id)


def sample_key(key: jax.Array, draw_seq: int) -> jax.Array:
    """Per-draw key for the prefetch pipeline's sampler calls."""
    return jax.random.fold_in(jax.random.fold_in(key, SAMPLE_STREAM), draw_seq)
