"""Pure-JAX environments: gym classic-control + MinAtar-style pixel envs.

CartPole-v1 / Acrobot-v1 / MountainCar-v0 dynamics transcribed from gym
(Euler / RK4 integration, same constants, same termination), plus two
MinAtar-style 10x10 pixel games (Breakout, Freeway) with uint8
single-plane observations — all fully jittable, so the entire DQN train
loop including the environment runs inside one lax.scan.

Each env exposes::

    obs_shape   tuple — the shape of one observation (``(obs_dim,)`` for
                the vector envs, ``(H, W)`` for the pixel envs)
    n_actions   int
    reset(key) -> state
    obs(state) -> observation (float32 vector or uint8 frame)
    step(state, action, key)
        -> (next_state, obs, reward, done, terminated)

``step`` auto-resets on ``done`` (the returned ``obs`` is the PRE-reset
observation the TD target consumes; ``next_state`` is already the fresh
episode).  ``done`` and ``terminated`` are separate signals: ``done``
ends the episode (termination OR time-limit truncation), while
``terminated`` is True only when the MDP itself ended (pole fell, goal
reached, ball lost).  A transition with ``done=True, terminated=False``
was cut by the time limit and its TD target must still bootstrap — see
``repro.rl.dqn.td_loss``.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

_ENV_REGISTRY: dict[str, Callable[[], Any]] = {}


def register_env(name: str, *aliases: str):
    """Decorator: register an env class under ``name`` (plus aliases) so the
    runtime, examples, and benchmarks can select environments by string.
    Re-registration replaces — last wins — mirroring the sampler registry."""

    def deco(cls):
        for n in (name, *aliases):
            _ENV_REGISTRY[n] = cls
        return cls

    return deco


def available_envs() -> list[str]:
    return sorted(_ENV_REGISTRY)


def make_env(name: str):
    """Build an environment instance by registry name."""
    try:
        cls = _ENV_REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown env: {name!r} (available: {available_envs()})"
        ) from None
    return cls()


class EnvState(NamedTuple):
    x: jax.Array        # physics / game state vector
    t: jax.Array        # steps in current episode


@register_env("cartpole")
class CartPole:
    """CartPole-v1: keep the pole upright; +1 per step; 500-step cap."""

    obs_dim = 4
    obs_shape = (4,)
    n_actions = 2
    max_steps = 500

    GRAV, MC, MP, LEN, F, TAU = 9.8, 1.0, 0.1, 0.5, 10.0, 0.02

    def reset(self, key: jax.Array) -> EnvState:
        x = jax.random.uniform(key, (4,), minval=-0.05, maxval=0.05)
        return EnvState(x=x, t=jnp.int32(0))

    def obs(self, state: EnvState) -> jax.Array:
        return state.x

    def step(self, state: EnvState, action: jax.Array, key: jax.Array):
        x, x_dot, th, th_dot = state.x
        force = jnp.where(action == 1, self.F, -self.F)
        costh, sinth = jnp.cos(th), jnp.sin(th)
        total_m = self.MC + self.MP
        pm_l = self.MP * self.LEN
        temp = (force + pm_l * th_dot**2 * sinth) / total_m
        th_acc = (self.GRAV * sinth - costh * temp) / (
            self.LEN * (4.0 / 3.0 - self.MP * costh**2 / total_m))
        x_acc = temp - pm_l * th_acc * costh / total_m
        new = jnp.stack([x + self.TAU * x_dot, x_dot + self.TAU * x_acc,
                         th + self.TAU * th_dot, th_dot + self.TAU * th_acc])
        t = state.t + 1
        terminated = (jnp.abs(new[0]) > 2.4) | (jnp.abs(new[2]) > 0.2095)
        done = terminated | (t >= self.max_steps)
        reward = jnp.float32(1.0)
        fresh = self.reset(key)
        nxt = EnvState(x=new, t=t)
        next_state = jax.tree.map(
            lambda a, b: jnp.where(done, a, b), fresh, nxt)
        return next_state, self.obs(nxt), reward, done, terminated


@register_env("acrobot")
class Acrobot:
    """Acrobot-v1: swing the tip above the bar; -1 per step until solved."""

    obs_dim = 6
    obs_shape = (6,)
    n_actions = 3
    max_steps = 500

    M1 = M2 = 1.0
    L1 = 1.0
    LC1 = LC2 = 0.5
    I1 = I2 = 1.0
    G = 9.8
    DT = 0.2

    def reset(self, key: jax.Array) -> EnvState:
        x = jax.random.uniform(key, (4,), minval=-0.1, maxval=0.1)
        return EnvState(x=x, t=jnp.int32(0))

    def obs(self, state: EnvState) -> jax.Array:
        th1, th2, d1, d2 = state.x
        return jnp.stack([jnp.cos(th1), jnp.sin(th1), jnp.cos(th2),
                          jnp.sin(th2), d1, d2])

    def _dsdt(self, s, torque):
        th1, th2, dth1, dth2 = s
        m1, m2, l1, lc1, lc2, i1, i2, g = (self.M1, self.M2, self.L1,
                                           self.LC1, self.LC2, self.I1,
                                           self.I2, self.G)
        d1 = m1 * lc1**2 + m2 * (l1**2 + lc2**2 + 2 * l1 * lc2 * jnp.cos(th2)) + i1 + i2
        d2 = m2 * (lc2**2 + l1 * lc2 * jnp.cos(th2)) + i2
        phi2 = m2 * lc2 * g * jnp.cos(th1 + th2 - jnp.pi / 2)
        phi1 = (-m2 * l1 * lc2 * dth2**2 * jnp.sin(th2)
                - 2 * m2 * l1 * lc2 * dth2 * dth1 * jnp.sin(th2)
                + (m1 * lc1 + m2 * l1) * g * jnp.cos(th1 - jnp.pi / 2) + phi2)
        ddth2 = ((torque + d2 / d1 * phi1 - m2 * l1 * lc2 * dth1**2 * jnp.sin(th2)
                  - phi2) / (m2 * lc2**2 + i2 - d2**2 / d1))
        ddth1 = -(d2 * ddth2 + phi1) / d1
        return jnp.stack([dth1, dth2, ddth1, ddth2])

    def step(self, state: EnvState, action: jax.Array, key: jax.Array):
        torque = jnp.float32(action) - 1.0  # {-1, 0, +1}
        # RK4 (gym uses rk4 on [0, dt])
        s = state.x
        h = self.DT
        k1 = self._dsdt(s, torque)
        k2 = self._dsdt(s + h / 2 * k1, torque)
        k3 = self._dsdt(s + h / 2 * k2, torque)
        k4 = self._dsdt(s + h * k3, torque)
        new = s + h / 6 * (k1 + 2 * k2 + 2 * k3 + k4)
        wrap = lambda a: ((a + jnp.pi) % (2 * jnp.pi)) - jnp.pi
        new = new.at[0].set(wrap(new[0])).at[1].set(wrap(new[1]))
        new = new.at[2].set(jnp.clip(new[2], -4 * jnp.pi, 4 * jnp.pi))
        new = new.at[3].set(jnp.clip(new[3], -9 * jnp.pi, 9 * jnp.pi))
        t = state.t + 1
        terminated = -jnp.cos(new[0]) - jnp.cos(new[1] + new[0]) > 1.0
        done = terminated | (t >= self.max_steps)
        reward = jnp.where(terminated, 0.0, -1.0)
        fresh = self.reset(key)
        nxt = EnvState(x=new, t=t)
        next_state = jax.tree.map(lambda a, b: jnp.where(done, a, b), fresh, nxt)
        return next_state, self.obs(nxt), reward, done, terminated


@register_env("mountaincar")
class MountainCar:
    """MountainCar-v0: drive up the right hill; -1 per step; 200-step cap.

    Gym's deterministic point-mass-on-a-curve dynamics (the only
    randomness is the reset position), Euler-integrated with the same
    constants, bounds, and ``position >= 0.5`` goal test.  The sparse
    -1-per-step reward makes it the hard-exploration member of the env
    grid: n-step returns propagate the goal signal ``n`` times faster,
    which is exactly the axis the agent-family benchmarks sweep.
    """

    obs_dim = 2
    obs_shape = (2,)
    n_actions = 3
    max_steps = 200

    MIN_POS, MAX_POS = -1.2, 0.6
    MAX_SPEED = 0.07
    GOAL_POS, GOAL_VEL = 0.5, 0.0
    FORCE, GRAVITY = 0.001, 0.0025

    def reset(self, key: jax.Array) -> EnvState:
        pos = jax.random.uniform(key, (), minval=-0.6, maxval=-0.4)
        return EnvState(x=jnp.stack([pos, jnp.float32(0.0)]),
                        t=jnp.int32(0))

    def obs(self, state: EnvState) -> jax.Array:
        return state.x

    def step(self, state: EnvState, action: jax.Array, key: jax.Array):
        pos, vel = state.x
        vel = vel + (jnp.float32(action) - 1.0) * self.FORCE \
            + jnp.cos(3.0 * pos) * (-self.GRAVITY)
        vel = jnp.clip(vel, -self.MAX_SPEED, self.MAX_SPEED)
        pos = jnp.clip(pos + vel, self.MIN_POS, self.MAX_POS)
        vel = jnp.where((pos <= self.MIN_POS) & (vel < 0), 0.0, vel)
        t = state.t + 1
        terminated = (pos >= self.GOAL_POS) & (vel >= self.GOAL_VEL)
        done = terminated | (t >= self.max_steps)
        reward = jnp.float32(-1.0)
        nxt = EnvState(x=jnp.stack([pos, vel]), t=t)
        fresh = self.reset(key)
        next_state = jax.tree.map(
            lambda a, b: jnp.where(done, a, b), fresh, nxt)
        return next_state, self.obs(nxt), reward, done, terminated


# --- MinAtar-style pixel environments ----------------------------------------
#
# 10x10 single-plane uint8 frames in the spirit of MinAtar (Young &
# Tian, arXiv 1903.03176): the same core game logic at a scale where a
# jittable transcription stays exact and a conv Q-head trains in
# seconds.  Object classes are encoded as distinct intensities on one
# plane (rather than MinAtar's one-hot channel stack) so a single
# ``uint8[capacity, 10, 10]`` ring slot stores a whole observation —
# the workload the frame-deduplicated replay storage is built for.

BRICK, CAR = 90, 128          # background object intensities
PADDLE, CHICKEN = 180, 255    # player intensities (drawn over background)
BALL = 255


@register_env("breakout")
class Breakout:
    """MinAtar-style Breakout: 10x10 grid, 3 brick rows, diagonal ball.

    State vector ``x`` (float32[35]): ``[ball_y, ball_x, dy, dx,
    paddle_x, bricks(3x10 flattened)]``.  Actions: 0 = noop, 1 = paddle
    left, 2 = paddle right.  The ball moves one diagonal cell per step,
    reflecting off the side walls and ceiling; hitting a brick clears it
    (+1 reward) and bounces the ball back without entering the cell;
    reaching the bottom row bounces off the paddle if aligned, else the
    ball is lost (**terminated**).  Clearing the whole wall respawns it.
    Episodes are also truncated (``done`` without ``terminated``) at
    ``max_steps``.
    """

    obs_shape = (10, 10)
    n_actions = 3
    max_steps = 300

    def reset(self, key: jax.Array) -> EnvState:
        k_x, k_d = jax.random.split(key)
        ball_x = jnp.float32(jax.random.randint(k_x, (), 0, 10))
        dx = jnp.where(jax.random.bernoulli(k_d), 1.0, -1.0)
        head = jnp.stack([jnp.float32(4.0), ball_x, jnp.float32(1.0), dx,
                          jnp.float32(4.0)])
        return EnvState(x=jnp.concatenate([head, jnp.ones(30)]),
                        t=jnp.int32(0))

    def obs(self, state: EnvState) -> jax.Array:
        by, bx, _, _, px = state.x[:5]
        bricks = state.x[5:].reshape(3, 10) > 0.5
        g = jnp.zeros((10, 10), jnp.uint8)
        g = g.at[1:4].set(jnp.where(bricks, BRICK, 0).astype(jnp.uint8))
        g = g.at[9, px.astype(jnp.int32)].set(PADDLE)
        g = g.at[by.astype(jnp.int32), bx.astype(jnp.int32)].set(BALL)
        return g

    def step(self, state: EnvState, action: jax.Array, key: jax.Array):
        by, bx, dy, dx, px = state.x[:5]
        bricks = state.x[5:]
        px = jnp.clip(px + jnp.float32(action == 2) - jnp.float32(action == 1),
                      0.0, 9.0)
        ny, nx = by + dy, bx + dx
        # side walls / ceiling: reflect position and flip direction
        dx = jnp.where((nx < 0) | (nx > 9), -dx, dx)
        nx = jnp.where(nx < 0, -nx, jnp.where(nx > 9, 18.0 - nx, nx))
        dy = jnp.where(ny < 0, -dy, dy)
        ny = jnp.where(ny < 0, -ny, ny)
        # brick hit: clear it, +1, bounce back without entering the cell
        in_wall = (ny >= 1) & (ny <= 3)
        bidx = jnp.clip((ny - 1) * 10 + nx, 0, 29).astype(jnp.int32)
        hit = in_wall & (bricks[bidx] > 0.5)
        reward = hit.astype(jnp.float32)
        bricks = bricks.at[bidx].set(jnp.where(hit, 0.0, bricks[bidx]))
        dy = jnp.where(hit, -dy, dy)
        ny = jnp.where(hit, by, ny)
        nx = jnp.where(hit, bx, nx)
        # bottom row: paddle bounce or ball lost
        at_bottom = ny >= 9
        caught = at_bottom & (nx == px)
        dy = jnp.where(caught, -1.0, dy)
        terminated = at_bottom & ~caught
        # cleared wall respawns
        bricks = jnp.where(bricks.sum() < 0.5, jnp.ones(30), bricks)
        t = state.t + 1
        done = terminated | (t >= self.max_steps)
        nxt = EnvState(x=jnp.concatenate(
            [jnp.stack([ny, nx, dy, dx, px]), bricks]), t=t)
        fresh = self.reset(key)
        next_state = jax.tree.map(
            lambda a, b: jnp.where(done, a, b), fresh, nxt)
        return next_state, self.obs(nxt), reward, done, terminated


@register_env("freeway")
class Freeway:
    """MinAtar-style Freeway: cross 8 lanes of traffic, +1 per crossing.

    State vector ``x`` (float32[9]): ``[chicken_y, car_x(8 lanes)]``.
    The chicken lives in column 4 and moves with 0 = noop, 1 = up,
    2 = down.  Lane ``l`` (grid row ``l+1``) carries one car advancing
    one cell every ``PERIOD[l]`` steps in direction ``DIRECTION[l]``
    (wrapping).  A collision sends the chicken back to the bottom row.
    Reaching the top row scores +1 and also restarts the crossing.
    Freeway never terminates — episodes end only by time-limit
    truncation, which makes it the pure ``done-without-terminated``
    member of the env grid.
    """

    obs_shape = (10, 10)
    n_actions = 3
    max_steps = 250

    PERIOD = (1, 2, 3, 4, 4, 3, 2, 1)
    DIRECTION = (1, -1, 1, -1, 1, -1, 1, -1)
    COL = 4  # the chicken's fixed column

    def reset(self, key: jax.Array) -> EnvState:
        cars = jnp.float32(jax.random.randint(key, (8,), 0, 10))
        return EnvState(x=jnp.concatenate([jnp.full((1,), 9.0), cars]),
                        t=jnp.int32(0))

    def obs(self, state: EnvState) -> jax.Array:
        y = state.x[0].astype(jnp.int32)
        cars = state.x[1:].astype(jnp.int32)
        g = jnp.zeros((10, 10), jnp.uint8)
        g = g.at[jnp.arange(1, 9), cars].set(CAR)
        g = g.at[y, self.COL].set(CHICKEN)
        return g

    def step(self, state: EnvState, action: jax.Array, key: jax.Array):
        y = state.x[0]
        cars = state.x[1:]
        t = state.t + 1
        y = jnp.clip(y - jnp.float32(action == 1) + jnp.float32(action == 2),
                     0.0, 9.0)
        period = jnp.asarray(self.PERIOD, jnp.int32)
        direction = jnp.asarray(self.DIRECTION, jnp.float32)
        moves = (t % period == 0).astype(jnp.float32)
        cars = (cars + moves * direction) % 10.0
        # collision: the chicken's row holds a car in its column
        lane = y.astype(jnp.int32) - 1          # grid row l+1 -> lane l
        in_traffic = (y >= 1) & (y <= 8)
        hit = in_traffic & (cars[jnp.clip(lane, 0, 7)] == jnp.float32(self.COL))
        y = jnp.where(hit, 9.0, y)
        scored = y <= 0
        reward = scored.astype(jnp.float32)
        y = jnp.where(scored, 9.0, y)
        terminated = jnp.bool_(False)           # Freeway never terminates
        done = t >= self.max_steps
        nxt = EnvState(x=jnp.concatenate([y[None], cars]), t=t)
        fresh = self.reset(key)
        next_state = jax.tree.map(
            lambda a, b: jnp.where(done, a, b), fresh, nxt)
        return next_state, self.obs(nxt), reward, done, terminated


class VectorEnv:
    """B independent copies of a scalar env, vmapped (the actor front-end).

    Wraps any env with the ``reset(key) / obs(state) / step(state, action,
    key)`` contract.  State is the scalar env's state pytree with a leading
    ``[num_envs]`` axis; ``step`` takes an ``int32[num_envs]`` action batch
    and one key, which it splits into per-env auto-reset keys — so
    ``VectorEnv(env, 1).step(s, a, k)`` is bit-identical to
    ``env.step(s0, a0, jax.random.split(k, 1)[0])``.  Per-env episodes run
    (and auto-reset) fully independently; everything stays jittable, so
    the whole actor fan-out lives inside the training lax.scan.
    """

    def __init__(self, env, num_envs: int):
        if num_envs < 1:
            raise ValueError(f"num_envs must be >= 1, got {num_envs}")
        self.env = env
        self.num_envs = num_envs
        # Vector envs expose (obs_dim,); pixel envs (H, W).  ``obs_dim``
        # is kept for pre-obs_shape call sites (None for pixel envs).
        self.obs_shape = (tuple(env.obs_shape) if hasattr(env, "obs_shape")
                          else (env.obs_dim,))
        self.obs_dim = getattr(env, "obs_dim", None)
        self.n_actions = env.n_actions

    def reset(self, key: jax.Array):
        return jax.vmap(self.env.reset)(jax.random.split(key, self.num_envs))

    def obs(self, state) -> jax.Array:
        return jax.vmap(self.env.obs)(state)

    def step(self, state, actions: jax.Array, key: jax.Array):
        """-> (state, next_obs [B, *obs_shape], reward [B], done [B],
        terminated [B])."""
        keys = jax.random.split(key, self.num_envs)
        return jax.vmap(self.env.step)(state, actions, keys)


# Back-compat alias for pre-registry call sites; prefer `make_env`.
ENVS = _ENV_REGISTRY
