"""Pure-JAX OpenAI-gym classic-control environments (paper Sec. 4.1.2).

CartPole-v1 and Acrobot-v1 dynamics transcribed from gym (Euler / RK4
integration, same constants, same termination), but fully jittable —
the entire DQN train loop including the environment runs inside one
lax.scan, which is what makes the reproduction fast enough on 1 CPU.

Each env exposes: obs_dim, n_actions, reset(key), step(state, action, key)
with auto-reset on termination (returns the fresh state and marks done).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

_ENV_REGISTRY: dict[str, Callable[[], Any]] = {}


def register_env(name: str, *aliases: str):
    """Decorator: register an env class under ``name`` (plus aliases) so the
    runtime, examples, and benchmarks can select environments by string.
    Re-registration replaces — last wins — mirroring the sampler registry."""

    def deco(cls):
        for n in (name, *aliases):
            _ENV_REGISTRY[n] = cls
        return cls

    return deco


def available_envs() -> list[str]:
    return sorted(_ENV_REGISTRY)


def make_env(name: str):
    """Build an environment instance by registry name."""
    try:
        cls = _ENV_REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown env: {name!r} (available: {available_envs()})"
        ) from None
    return cls()


class EnvState(NamedTuple):
    x: jax.Array        # physics state vector
    t: jax.Array        # steps in current episode


@register_env("cartpole")
class CartPole:
    """CartPole-v1: keep the pole upright; +1 per step; 500-step cap."""

    obs_dim = 4
    n_actions = 2
    max_steps = 500

    GRAV, MC, MP, LEN, F, TAU = 9.8, 1.0, 0.1, 0.5, 10.0, 0.02

    def reset(self, key: jax.Array) -> EnvState:
        x = jax.random.uniform(key, (4,), minval=-0.05, maxval=0.05)
        return EnvState(x=x, t=jnp.int32(0))

    def obs(self, state: EnvState) -> jax.Array:
        return state.x

    def step(self, state: EnvState, action: jax.Array, key: jax.Array):
        x, x_dot, th, th_dot = state.x
        force = jnp.where(action == 1, self.F, -self.F)
        costh, sinth = jnp.cos(th), jnp.sin(th)
        total_m = self.MC + self.MP
        pm_l = self.MP * self.LEN
        temp = (force + pm_l * th_dot**2 * sinth) / total_m
        th_acc = (self.GRAV * sinth - costh * temp) / (
            self.LEN * (4.0 / 3.0 - self.MP * costh**2 / total_m))
        x_acc = temp - pm_l * th_acc * costh / total_m
        new = jnp.stack([x + self.TAU * x_dot, x_dot + self.TAU * x_acc,
                         th + self.TAU * th_dot, th_dot + self.TAU * th_acc])
        t = state.t + 1
        done = ((jnp.abs(new[0]) > 2.4) | (jnp.abs(new[2]) > 0.2095)
                | (t >= self.max_steps))
        reward = jnp.float32(1.0)
        fresh = self.reset(key)
        next_state = jax.tree.map(
            lambda a, b: jnp.where(done, a, b), fresh, EnvState(x=new, t=t))
        return next_state, EnvState(x=new, t=t).x, reward, done


@register_env("acrobot")
class Acrobot:
    """Acrobot-v1: swing the tip above the bar; -1 per step until solved."""

    obs_dim = 6
    n_actions = 3
    max_steps = 500

    M1 = M2 = 1.0
    L1 = 1.0
    LC1 = LC2 = 0.5
    I1 = I2 = 1.0
    G = 9.8
    DT = 0.2

    def reset(self, key: jax.Array) -> EnvState:
        x = jax.random.uniform(key, (4,), minval=-0.1, maxval=0.1)
        return EnvState(x=x, t=jnp.int32(0))

    def obs(self, state: EnvState) -> jax.Array:
        th1, th2, d1, d2 = state.x
        return jnp.stack([jnp.cos(th1), jnp.sin(th1), jnp.cos(th2),
                          jnp.sin(th2), d1, d2])

    def _dsdt(self, s, torque):
        th1, th2, dth1, dth2 = s
        m1, m2, l1, lc1, lc2, i1, i2, g = (self.M1, self.M2, self.L1,
                                           self.LC1, self.LC2, self.I1,
                                           self.I2, self.G)
        d1 = m1 * lc1**2 + m2 * (l1**2 + lc2**2 + 2 * l1 * lc2 * jnp.cos(th2)) + i1 + i2
        d2 = m2 * (lc2**2 + l1 * lc2 * jnp.cos(th2)) + i2
        phi2 = m2 * lc2 * g * jnp.cos(th1 + th2 - jnp.pi / 2)
        phi1 = (-m2 * l1 * lc2 * dth2**2 * jnp.sin(th2)
                - 2 * m2 * l1 * lc2 * dth2 * dth1 * jnp.sin(th2)
                + (m1 * lc1 + m2 * l1) * g * jnp.cos(th1 - jnp.pi / 2) + phi2)
        ddth2 = ((torque + d2 / d1 * phi1 - m2 * l1 * lc2 * dth1**2 * jnp.sin(th2)
                  - phi2) / (m2 * lc2**2 + i2 - d2**2 / d1))
        ddth1 = -(d2 * ddth2 + phi1) / d1
        return jnp.stack([dth1, dth2, ddth1, ddth2])

    def step(self, state: EnvState, action: jax.Array, key: jax.Array):
        torque = jnp.float32(action) - 1.0  # {-1, 0, +1}
        # RK4 (gym uses rk4 on [0, dt])
        s = state.x
        h = self.DT
        k1 = self._dsdt(s, torque)
        k2 = self._dsdt(s + h / 2 * k1, torque)
        k3 = self._dsdt(s + h / 2 * k2, torque)
        k4 = self._dsdt(s + h * k3, torque)
        new = s + h / 6 * (k1 + 2 * k2 + 2 * k3 + k4)
        wrap = lambda a: ((a + jnp.pi) % (2 * jnp.pi)) - jnp.pi
        new = new.at[0].set(wrap(new[0])).at[1].set(wrap(new[1]))
        new = new.at[2].set(jnp.clip(new[2], -4 * jnp.pi, 4 * jnp.pi))
        new = new.at[3].set(jnp.clip(new[3], -9 * jnp.pi, 9 * jnp.pi))
        t = state.t + 1
        solved = -jnp.cos(new[0]) - jnp.cos(new[1] + new[0]) > 1.0
        done = solved | (t >= self.max_steps)
        reward = jnp.where(solved, 0.0, -1.0)
        fresh = self.reset(key)
        nxt = EnvState(x=new, t=t)
        next_state = jax.tree.map(lambda a, b: jnp.where(done, a, b), fresh, nxt)
        return next_state, self.obs(nxt), reward, done


@register_env("mountaincar")
class MountainCar:
    """MountainCar-v0: drive up the right hill; -1 per step; 200-step cap.

    Gym's deterministic point-mass-on-a-curve dynamics (the only
    randomness is the reset position), Euler-integrated with the same
    constants, bounds, and ``position >= 0.5`` goal test.  The sparse
    -1-per-step reward makes it the hard-exploration member of the env
    grid: n-step returns propagate the goal signal ``n`` times faster,
    which is exactly the axis the agent-family benchmarks sweep.
    """

    obs_dim = 2
    n_actions = 3
    max_steps = 200

    MIN_POS, MAX_POS = -1.2, 0.6
    MAX_SPEED = 0.07
    GOAL_POS, GOAL_VEL = 0.5, 0.0
    FORCE, GRAVITY = 0.001, 0.0025

    def reset(self, key: jax.Array) -> EnvState:
        pos = jax.random.uniform(key, (), minval=-0.6, maxval=-0.4)
        return EnvState(x=jnp.stack([pos, jnp.float32(0.0)]),
                        t=jnp.int32(0))

    def obs(self, state: EnvState) -> jax.Array:
        return state.x

    def step(self, state: EnvState, action: jax.Array, key: jax.Array):
        pos, vel = state.x
        vel = vel + (jnp.float32(action) - 1.0) * self.FORCE \
            + jnp.cos(3.0 * pos) * (-self.GRAVITY)
        vel = jnp.clip(vel, -self.MAX_SPEED, self.MAX_SPEED)
        pos = jnp.clip(pos + vel, self.MIN_POS, self.MAX_POS)
        vel = jnp.where((pos <= self.MIN_POS) & (vel < 0), 0.0, vel)
        t = state.t + 1
        solved = (pos >= self.GOAL_POS) & (vel >= self.GOAL_VEL)
        done = solved | (t >= self.max_steps)
        reward = jnp.float32(-1.0)
        nxt = EnvState(x=jnp.stack([pos, vel]), t=t)
        fresh = self.reset(key)
        next_state = jax.tree.map(
            lambda a, b: jnp.where(done, a, b), fresh, nxt)
        return next_state, nxt.x, reward, done


class VectorEnv:
    """B independent copies of a scalar env, vmapped (the actor front-end).

    Wraps any env with the ``reset(key) / obs(state) / step(state, action,
    key)`` contract.  State is the scalar env's state pytree with a leading
    ``[num_envs]`` axis; ``step`` takes an ``int32[num_envs]`` action batch
    and one key, which it splits into per-env auto-reset keys — so
    ``VectorEnv(env, 1).step(s, a, k)`` is bit-identical to
    ``env.step(s0, a0, jax.random.split(k, 1)[0])``.  Per-env episodes run
    (and auto-reset) fully independently; everything stays jittable, so
    the whole actor fan-out lives inside the training lax.scan.
    """

    def __init__(self, env, num_envs: int):
        if num_envs < 1:
            raise ValueError(f"num_envs must be >= 1, got {num_envs}")
        self.env = env
        self.num_envs = num_envs
        self.obs_dim = env.obs_dim
        self.n_actions = env.n_actions

    def reset(self, key: jax.Array):
        return jax.vmap(self.env.reset)(jax.random.split(key, self.num_envs))

    def obs(self, state) -> jax.Array:
        return jax.vmap(self.env.obs)(state)

    def step(self, state, actions: jax.Array, key: jax.Array):
        """-> (state, next_obs [B, obs_dim], reward [B], done [B])."""
        keys = jax.random.split(key, self.num_envs)
        return jax.vmap(self.env.step)(state, actions, keys)


# Back-compat alias for pre-registry call sites; prefer `make_env`.
ENVS = _ENV_REGISTRY
