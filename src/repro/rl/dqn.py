"""DQN agent *family* with pluggable experience replay (the paper's test
vehicle, grown to the variants the PER literature reports on).

Architecture follows the paper's setup (Sec. 2.4 / 4.1.2) — epsilon-greedy
exploration, hard target sync, replay memory with uniform / PER /
AMPER-k / AMPER-fr sampling — but the agent layer is composable along
three orthogonal axes, all selected from :class:`DQNConfig` with zero
call-site changes:

* **Q-head** (``repro.models.qhead``): the 3-layer MLP of the paper, or
  the dueling value/advantage decomposition (Wang et al. 2016).  Pixel
  envs (``len(obs_shape) > 1``) promote these to their conv
  counterparts automatically.
* **Target rule**: vanilla ``max_a Q_target`` or Double-DQN's
  argmax-decoupled ``Q_target(s', argmax_a Q_online(s', a))``
  (van Hasselt et al. 2016) — the setup Schaul et al. report PER on.
* **n-step returns** (``n_step=N``): the replay stack itself aggregates
  the 1-step stream into truncated n-step transitions (the accumulator
  lives in :class:`~repro.core.replay_buffer.ReplayState`, so it rides
  through checkpoints), and the learner bootstraps with ``gamma**N``.

``agent="dqn" | "double" | "dueling" | "double-dueling"`` composes the
first two axes.  The ENTIRE loop — environment, replay, sampling, TD
update — is one lax.scan, so a full CartPole run takes seconds on CPU.

Observation contract: agents are built from the env's ``obs_shape``
(``(obs_dim,)`` for the classic-control envs, ``(H, W)`` for the pixel
envs).  Pixel envs switch the replay buffer to frame-deduplicated uint8
storage (:class:`~repro.core.replay_buffer.FrameStore`): each step
stores ONE raw frame, the buffer materializes ``history_len``-stacked
float batches at sample time, and the actor maintains the same uint8
stack as its policy input — both sides convert with the identical
``frame * scale`` expression, so materialized training observations are
bit-identical to what the policy saw.

TD targets bootstrap on ``terminated``, not ``done``: an episode cut by
the env's time limit (``done`` without ``terminated``) is not a real
terminal state, and zeroing its bootstrap would bias Q toward the
truncation horizon on every step-capped env.  The frame path stores no
pre-reset observation, so there ``terminated`` collapses to ``done``
(see the replay-buffer module docstring).

The actor side is batched: ``cfg.num_envs`` independent environments
step in lockstep (``VectorEnv``), every iteration writes a B-transition
arc into the replay ring (`ReplayBuffer.add_batch`) and the samplers
absorb the B priority writes as one batched scatter.  ``num_envs=1``
reproduces the scalar pipeline exactly.  ``train_many`` vmaps the whole
training run over a batch of seeds for sweep-style evaluation.

Scheduling note: ``learn_start`` / ``train_every`` / ``target_sync`` /
``eps_decay_steps`` count scan ITERATIONS, not frames — with B envs each
iteration collects B frames, so one gradient step amortises over B
transitions (the standard vectorized-actor replay ratio).

PER uses importance-sampling weights; AMPER samples uniformly from its
CSP (per the paper) so its weights are 1.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.per import beta_schedule
from repro.core.replay_buffer import FrameStore, ReplayBuffer
from repro.core.samplers import make_sampler
from repro.models.qhead import make_qhead, mlp_apply, mlp_init  # noqa: F401
from repro.rl import envs as envs_mod
from repro.train import checkpoint as ckpt_mod

RETURN_RING = 64  # completed-episode returns kept for the train metric

# agent name -> (Q-head kind, use Double-DQN targets); pixel envs promote
# the head kind to its conv counterpart.
AGENTS = {
    "dqn": ("mlp", False),
    "double": ("mlp", True),
    "dueling": ("dueling", False),
    "double-dueling": ("dueling", True),
}

_CONV_PROMOTION = {"mlp": "conv", "dueling": "conv-dueling"}


@dataclasses.dataclass(frozen=True)
class DQNConfig:
    env: str = "cartpole"
    sampler: str = "per-sumtree"   # any repro.core.samplers registry name
    agent: str = "dqn"             # dqn | double | dueling | double-dueling
    n_step: int = 1                # n-step return horizon (1 = classic)
    num_envs: int = 1
    replay_size: int = 2000
    batch: int = 64
    hidden: int = 128
    history_len: int = 4           # frames per stacked pixel observation
    gamma: float = 0.99
    lr: float = 1e-3
    eps_start: float = 1.0
    eps_end: float = 0.05
    eps_decay_steps: int = 5000
    target_sync: int = 100
    learn_start: int = 200
    train_every: int = 1
    alpha: float = 0.6
    beta: float = 0.4
    # IS-exponent annealing (Schaul et al.: β→1 over training).  beta_end
    # None keeps the constant-β behaviour; beta_anneal_steps None uses
    # eps_decay_steps as the horizon.  Steps are scan iterations for the
    # sync trainers and learner steps for the async runtime.
    beta_end: float | None = None
    beta_anneal_steps: int | None = None
    # AMPER hyper-parameters (paper defaults: m=20, CSP ratio 0.15)
    amper_m: int = 20
    amper_lam_fr: float = 2.0
    amper_csp_ratio: float = 0.15
    v_max: float = 8.0


class AgentState(NamedTuple):
    params: Any
    target_params: Any
    opt_m: Any
    opt_v: Any
    buffer: Any
    env_state: Any               # VectorEnv state, leaves lead with [num_envs]
    obs: jax.Array               # policy input: float32[num_envs, obs_dim],
    #                              or uint8[num_envs, H, W, history_len] for
    #                              pixel envs (the actor's frame stack)
    step: jax.Array
    episode_return: jax.Array    # float32[num_envs] running returns
    last_returns: jax.Array      # ring buffer of completed episode returns
    n_episodes: jax.Array


class DQN(NamedTuple):
    """Everything `make_dqn` builds, by name (no positional unpacking).

    ``act`` / ``learn`` are the pieces the async runtime
    (:mod:`repro.runtime`) composes into overlapped pipeline stages;
    ``agent_step`` is the same two pieces fused into one synchronous
    iteration, and ``train`` wraps that in a lax.scan.
    """

    init: Callable
    agent_step: Callable
    train: Callable          # (key, n_steps) -> (AgentState, metrics)
    train_ckpt: Callable     # (key, n_steps, manager) -> checkpointed train
    train_many: Callable     # (keys [S], n_steps) -> batched states/metrics
    evaluate: Callable       # (params/AgentState, key, n_episodes) -> return
    evaluate_many: Callable  # (batched states, keys [S], n_episodes) -> [S]
    act: Callable            # (params, env_state, obs, step, key)
    #                          -> (env_state, next_obs, transitions)
    learn: Callable          # (params, target, m, v, step, batch, weights)
    #                          -> (params, m, v, td, loss)
    cfg: DQNConfig
    env: Any                 # scalar env instance
    venv: Any                # VectorEnv over cfg.num_envs copies
    replay: Any              # the ReplayBuffer (sampler attached)
    beta_at: Callable        # (step) -> IS exponent under cfg's schedule
    q_apply: Callable        # (params, obs) -> Q-values (the head's apply)
    example_transition: Any  # zero transition pytree (schema of the ring)
    init_obs: Callable       # (venv env_state) -> initial policy input
    #                          (raw obs, or the seeded frame stack)


def make_dqn(cfg: DQNConfig) -> DQN:
    env = envs_mod.make_env(cfg.env)
    venv = envs_mod.VectorEnv(env, cfg.num_envs)
    obs_shape = venv.obs_shape
    pixel = len(obs_shape) > 1
    try:
        head_kind, double = AGENTS[cfg.agent]
    except KeyError:
        raise ValueError(f"unknown agent: {cfg.agent!r} "
                         f"(available: {sorted(AGENTS)})") from None
    if cfg.n_step < 1:
        raise ValueError(f"n_step must be >= 1, got {cfg.n_step}")
    if pixel:
        head_kind = _CONV_PROMOTION[head_kind]
        net_shape = obs_shape + (cfg.history_len,)
    else:
        net_shape = obs_shape
    qhead = make_qhead(head_kind, net_shape, cfg.hidden, env.n_actions)
    q_apply = qhead.apply
    # n-step targets bootstrap the un-terminated window with gamma^n.
    gamma_n = cfg.gamma ** cfg.n_step
    # The completed-return ring must fit one iteration's worst case of
    # num_envs simultaneous finishes, else slots collide within a scatter.
    ring = max(RETURN_RING, cfg.num_envs)
    sampler = make_sampler(
        cfg.sampler, cfg.replay_size,
        m=cfg.amper_m, lam_fr=cfg.amper_lam_fr,
        csp_ratio=cfg.amper_csp_ratio, v_max=cfg.v_max,
        min_csp=cfg.batch, knn_mode="bisect")
    is_per = cfg.sampler.startswith("per")
    frame_store = (FrameStore(history_len=cfg.history_len,
                              frame_shape=obs_shape, stride=cfg.num_envs,
                              n_step=cfg.n_step, gamma=cfg.gamma)
                   if pixel else None)
    rb = ReplayBuffer(cfg.replay_size, sampler, alpha=cfg.alpha,
                      beta=cfg.beta,
                      n_step=1 if pixel else cfg.n_step,
                      gamma=cfg.gamma, num_envs=cfg.num_envs,
                      frame_store=frame_store)
    if pixel:
        # One uint8 frame per transition; obs/next_obs stacks are
        # materialized by the buffer at sample time.
        example_transition = {
            "frame": jnp.zeros(obs_shape, jnp.uint8),
            "action": jnp.int32(0), "reward": jnp.float32(0),
            "done": jnp.float32(0), "terminated": jnp.float32(0)}
    else:
        example_transition = {
            "obs": jnp.zeros(obs_shape), "action": jnp.int32(0),
            "reward": jnp.float32(0), "next_obs": jnp.zeros(obs_shape),
            "done": jnp.float32(0), "terminated": jnp.float32(0)}

    def stack_init(frames):
        """Seed a history stack from one uint8 frame batch: zeros except
        the newest plane — the same padding the frame store materializes
        for an episode's first observation."""
        z = jnp.zeros(frames.shape + (cfg.history_len,), jnp.uint8)
        return z.at[..., -1].set(frames)

    def stack_push(stack, frames, done):
        """Shift one frame in; restart from zero-padding where ``done``."""
        shifted = jnp.concatenate([stack[..., 1:], frames[..., None]],
                                  axis=-1)
        d = jnp.reshape(done, jnp.shape(done)
                        + (1,) * (shifted.ndim - jnp.ndim(done)))
        return jnp.where(d, stack_init(frames), shifted)

    if pixel:
        def q_in(obs):
            # The one uint8 -> float expression shared with
            # ReplayBuffer.materialize (bit-identical policy inputs).
            return obs.astype(jnp.float32) * frame_store.scale

        def init_obs(env_state):
            return stack_init(venv.obs(env_state))
    else:
        def q_in(obs):
            return obs

        def init_obs(env_state):
            return venv.obs(env_state)

    def init(key) -> AgentState:
        k1, k2 = jax.random.split(key)
        params = qhead.init(k1)
        tr = example_transition
        env_state = venv.reset(k2)
        return AgentState(
            params=params, target_params=params,
            opt_m=jax.tree.map(jnp.zeros_like, params),
            opt_v=jax.tree.map(jnp.zeros_like, params),
            buffer=rb.init(tr), env_state=env_state,
            obs=init_obs(env_state), step=jnp.int32(0),
            episode_return=jnp.zeros(cfg.num_envs),
            last_returns=jnp.zeros(ring), n_episodes=jnp.int32(0))

    def td_loss(params, target_params, batch, weights):
        q = q_apply(params, batch["obs"])
        qa = jnp.take_along_axis(q, batch["action"][:, None], 1)[:, 0]
        qn = q_apply(target_params, batch["next_obs"])
        if double:
            # Double DQN: the online net picks the action, the target net
            # evaluates it — decoupling selection from overestimation.
            a_star = jnp.argmax(q_apply(params, batch["next_obs"]), axis=-1)
            boot = jnp.take_along_axis(qn, a_star[:, None], 1)[:, 0]
            boot = jax.lax.stop_gradient(boot)
        else:
            boot = qn.max(-1)
        # Bootstrap through time-limit truncation: only a true MDP
        # terminal (`terminated`) zeroes the tail — a `done` from the
        # step cap is an artifact of the horizon, not of the value.
        target = batch["reward"] + gamma_n * (1 - batch["terminated"]) * boot
        td = qa - jax.lax.stop_gradient(target)
        return jnp.mean(weights * td * td), td

    def adam(params, grads, m, v, step):
        b1, b2, eps = 0.9, 0.999, 1e-8
        m = jax.tree.map(lambda a, g: b1 * a + (1 - b1) * g, m, grads)
        v = jax.tree.map(lambda a, g: b2 * a + (1 - b2) * g * g, v, grads)
        c = step.astype(jnp.float32) + 1
        lr = cfg.lr * jnp.sqrt(1 - 0.999 ** c) / (1 - 0.9 ** c)
        params = jax.tree.map(
            lambda p, mm, vv: p - lr * mm / (jnp.sqrt(vv) + eps), params, m, v)
        return params, m, v

    def beta_at(step):
        """IS exponent at ``step`` (traced-scalar safe).  Constant unless
        the config opts into annealing via ``beta_end``."""
        if cfg.beta_end is None:
            return cfg.beta
        horizon = (cfg.beta_anneal_steps if cfg.beta_anneal_steps is not None
                   else cfg.eps_decay_steps)
        return beta_schedule(cfg.beta, cfg.beta_end, step, horizon)

    def act(params, env_state, obs, step, key):
        """One vectorized epsilon-greedy env step (the actor piece).

        Returns ``(env_state, next_obs, transitions)`` where ``next_obs``
        is the post-auto-reset policy input for the next step (a float
        observation, or the shifted uint8 frame stack for pixel envs) and
        ``transitions`` is the B-row pytree to store — float envs keep
        the pre-reset ``next_obs`` the TD target needs; pixel envs store
        only the current raw frame (the buffer rebuilds both stacks).
        """
        k_coin, k_rand, k_env = jax.random.split(key, 3)
        eps = jnp.clip(
            cfg.eps_start + (cfg.eps_end - cfg.eps_start)
            * step / cfg.eps_decay_steps, cfg.eps_end, cfg.eps_start)
        q = q_apply(params, q_in(obs))                   # [B, n_actions]
        greedy = jnp.argmax(q, axis=-1)
        explore = jax.random.uniform(k_coin, (cfg.num_envs,)) < eps
        randa = jax.random.randint(k_rand, (cfg.num_envs,), 0, env.n_actions)
        action = jnp.where(explore, randa, greedy).astype(jnp.int32)
        env_state, next_obs, reward, done, terminated = venv.step(
            env_state, action, k_env)
        if pixel:
            transitions = {
                "frame": obs[..., -1], "action": action, "reward": reward,
                "done": done.astype(jnp.float32),
                "terminated": terminated.astype(jnp.float32)}
            return env_state, stack_push(obs, venv.obs(env_state),
                                         done), transitions
        transitions = {
            "obs": obs, "action": action, "reward": reward,
            "next_obs": next_obs, "done": done.astype(jnp.float32),
            "terminated": terminated.astype(jnp.float32)}
        return env_state, venv.obs(env_state), transitions

    def learn(params, target_params, opt_m, opt_v, step, batch, weights):
        """One TD gradient step on a sampled batch (the learner piece)."""
        w = weights if is_per else jnp.ones_like(weights)
        (loss, td), grads = jax.value_and_grad(
            td_loss, has_aux=True)(params, target_params, batch, w)
        params, m, v = adam(params, grads, opt_m, opt_v, step)
        return params, m, v, td, loss

    def agent_step(state: AgentState, key) -> tuple[AgentState, dict]:
        k_act, k_sample = jax.random.split(key)
        env_state, obs_next, transitions = act(
            state.params, state.env_state, state.obs, state.step, k_act)
        reward = transitions["reward"]
        done = transitions["done"] > 0.5
        buffer = rb.add_batch(state.buffer, transitions)

        # Per-env episode accounting: each env that finished this step
        # claims the next free slot of the shared completed-return ring
        # (exclusive cumsum orders simultaneous finishes; non-finished envs
        # aim out of range and are dropped by the scatter).
        ep_ret = state.episode_return + reward
        d = done.astype(jnp.int32)
        slot = (state.n_episodes + jnp.cumsum(d) - d) % ring
        last_returns = state.last_returns.at[
            jnp.where(done, slot, ring)].set(ep_ret, mode="drop")
        n_episodes = state.n_episodes + jnp.sum(d)
        episode_return = jnp.where(done, 0.0, ep_ret)

        def do_train(args):
            params, m, v, buffer = args
            idx, batch, w = rb.sample(buffer, k_sample, cfg.batch,
                                      beta=beta_at(state.step))
            params, m, v, td, _ = learn(
                params, state.target_params, m, v, state.step, batch, w)
            buffer = rb.update_priorities(buffer, idx, td)
            return params, m, v, buffer

        should = (state.step >= cfg.learn_start) & (
            state.step % cfg.train_every == 0)
        params, m, v, buffer = jax.lax.cond(
            should, do_train, lambda a: a,
            (state.params, state.opt_m, state.opt_v, buffer))
        target_params = jax.tree.map(
            lambda t, p: jnp.where(state.step % cfg.target_sync == 0, p, t),
            state.target_params, params)

        new = AgentState(params=params, target_params=target_params,
                         opt_m=m, opt_v=v, buffer=buffer,
                         env_state=env_state, obs=obs_next,
                         step=state.step + 1,
                         episode_return=episode_return,
                         last_returns=last_returns, n_episodes=n_episodes)
        metrics = {"return_mean": jnp.where(
            n_episodes > 0,
            last_returns.sum() / jnp.minimum(n_episodes, ring), 0.0),
            # The IS exponent this step's draw actually used — surfaces
            # the annealed schedule instead of the frozen constructor β.
            "beta": jnp.float32(beta_at(state.step))}
        return new, metrics

    def _train(key, n_steps: int):
        state = init(key)
        keys = jax.random.split(jax.random.fold_in(key, 1), n_steps)
        state, metrics = jax.lax.scan(agent_step, state, keys)
        return state, metrics

    train = jax.jit(_train, static_argnames="n_steps")
    # Multi-seed sweep: one compiled program, seeds run data-parallel.
    train_many = jax.jit(jax.vmap(_train, in_axes=(0, None)),
                         static_argnames="n_steps")

    scan_segment = jax.jit(
        lambda state, keys: jax.lax.scan(agent_step, state, keys))

    def train_ckpt(key, n_steps: int, manager: ckpt_mod.CheckpointManager):
        """The scan trainer with periodic checkpoint + exact resume.

        The per-step key array is derived once for the WHOLE run
        (``split(fold_in(key, 1), n_steps)``, exactly as ``train``) and
        the scan runs in ``save_interval`` segments with an atomic
        checkpoint of the full :class:`AgentState` — params, optimizer
        moments, replay buffer, sampler state, env state, and episode
        accounting — between segments.  A killed run resumed from the
        latest checkpoint reaches the same final state as an
        uninterrupted ``train_ckpt`` run, bit for bit (pinned by
        ``tests/test_resume.py``); against the single-scan ``train`` the
        match is float-tolerance only, because XLA compiles the segmented
        and fused programs with different reassociation.

        Because the key derivation depends on ``n_steps``, resuming with
        a different ``n_steps`` would silently change every step key; the
        manifest records it and a mismatch raises.

        Returns ``(state, metrics, done_steps)`` where ``metrics`` covers
        only the steps run by THIS invocation and ``done_steps < n_steps``
        iff the manager was preempted mid-run (a final checkpoint is
        flushed first).
        """
        keys = jax.random.split(jax.random.fold_in(key, 1), n_steps)
        state = None
        start = 0
        latest = manager.latest_step()
        if latest is not None:
            saved = ckpt_mod.load_meta(manager.directory, latest)
            if saved.get("n_steps", n_steps) != n_steps:
                raise ValueError(
                    f"resume with n_steps={n_steps} but checkpoint was "
                    f"written by an n_steps={saved['n_steps']} run; the "
                    f"step-key derivation depends on n_steps, so this "
                    f"would not be an exact resume")
            target = jax.eval_shape(init, jax.random.key(0))
            state = ckpt_mod.restore(manager.directory, latest, target)
            start = latest
        if state is None:  # no checkpoint: only now pay for a fresh init
            state = init(key)
        parts = []
        t = start
        while t < n_steps:
            seg = min(n_steps - t, manager.save_interval)
            state, m = scan_segment(state, keys[t:t + seg])
            parts.append(m)
            t += seg
            if manager.should_save(t) or t == n_steps:
                manager.save(t, state, meta={"n_steps": n_steps, "step": t})
            if manager.preempted and t < n_steps:
                break
        if not parts:  # resumed a run that had already completed
            return state, {"return_mean": jnp.zeros((0,)),
                           "beta": jnp.zeros((0,))}, t
        metrics = jax.tree.map(lambda *xs: jnp.concatenate(xs), *parts)
        return state, metrics, t

    def evaluate(state, key, n_episodes: int = 10) -> jax.Array:
        """Greedy-policy average return (the paper's 'test score').

        Accepts a full :class:`AgentState` or bare network params (what
        the async runtime's :class:`~repro.runtime.service.RunResult`
        carries).
        """
        params = state.params if hasattr(state, "params") else state

        def one_ep(key):
            k0, key = jax.random.split(key)
            env_state = env.reset(k0)
            if pixel:
                obs0 = stack_init(env.obs(env_state))
            else:
                obs0 = env.obs(env_state)

            def body(carry):
                env_state, obs, ret, done, key = carry
                key, k = jax.random.split(key)
                action = jnp.argmax(
                    q_apply(params, q_in(obs))).astype(jnp.int32)
                env_state, obs2, r, d, _term = env.step(env_state, action, k)
                if pixel:
                    nxt = stack_push(obs, env.obs(env_state), d)
                else:
                    nxt = env.obs(env_state)
                return (env_state, nxt, ret + r * (1 - done),
                        jnp.maximum(done, d.astype(jnp.float32)), key)

            def cond(carry):
                return carry[3] < 1

            out = jax.lax.while_loop(
                cond, body,
                (env_state, obs0, jnp.float32(0), jnp.float32(0), key))
            return out[2]

        return jax.vmap(one_ep)(jax.random.split(key, n_episodes)).mean()

    def evaluate_many(states, keys, n_episodes: int = 10) -> jax.Array:
        """Per-seed test scores for a `train_many` output batch."""
        return jax.vmap(lambda s, k: evaluate(s, k, n_episodes))(states, keys)

    return DQN(init=init, agent_step=agent_step, train=train,
               train_ckpt=train_ckpt, train_many=train_many,
               evaluate=evaluate, evaluate_many=evaluate_many, act=act,
               learn=learn, cfg=cfg, env=env, venv=venv, replay=rb,
               beta_at=beta_at, q_apply=q_apply,
               example_transition=example_transition, init_obs=init_obs)
