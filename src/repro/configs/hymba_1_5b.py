"""hymba-1.5b — parallel attention + mamba heads, SWA with 3 global
layers; meta-tokens omitted (DESIGN.md) [arXiv:2411.13676]."""
from repro.configs.base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="hymba-1.5b", family="hybrid",
        n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5,
        d_ff=5504, vocab_size=32001, head_dim=64,
        block_kind="hybrid", ssm_state=16, ssm_expand=2,
        sliding_window=1024, global_attn_layers=(0, 15, 31),
        tie_embeddings=True,
    )
