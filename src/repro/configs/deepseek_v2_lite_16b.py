"""deepseek-v2-lite-16b — MLA (kv_lora=512) + fine-grained MoE.

Pool entry lists both "64e top-6" and "2 shared+160 routed"; we follow
the primary field (64 routed, top-6, 2 shared) — noted in DESIGN.md.
[arXiv:2405.04434]."""
from repro.configs.base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="deepseek-v2-lite-16b", family="moe",
        n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16,
        d_ff=10944, vocab_size=102400,
        attn_kind="mla", kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64,
        v_head_dim=128, head_dim=192,
        n_experts=64, n_shared_experts=2, moe_top_k=6, moe_d_ff=1408,
        first_dense_layers=1, moe_dispatch="shard_map",
        tie_embeddings=False,
    )
