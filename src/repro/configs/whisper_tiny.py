"""whisper-tiny — enc-dec backbone; conv/audio frontend is a stub
(input_specs provides precomputed frame embeddings) [arXiv:2212.04356]."""
from repro.configs.base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="whisper-tiny", family="audio",
        n_layers=4, d_model=384, n_heads=6, n_kv_heads=6,
        d_ff=1536, vocab_size=51865, head_dim=64,
        norm_kind="layernorm", mlp_kind="gelu",
        n_enc_layers=4, enc_seq=1500,
        tie_embeddings=True,
    )
