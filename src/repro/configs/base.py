"""Architecture config schema covering all 10 assigned families.

One frozen dataclass drives the whole zoo; family-specific blocks key off
``attn_kind`` / ``mlp_kind`` / ``block_kind`` so a single scan-over-layers
transformer assembles every arch. Reduced () constructors give the smoke
-test variants.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: int = 0              # 0 -> d_model // n_heads
    block_kind: str = "attn"       # attn | rwkv | hybrid
    attn_kind: str = "gqa"         # gqa | mla
    mlp_kind: str = "swiglu"       # swiglu | gelu | geglu | rwkv_cmix
    norm_kind: str = "rmsnorm"     # rmsnorm | layernorm

    rope_theta: float = 10_000.0
    sliding_window: Optional[int] = None    # uniform SWA window
    global_attn_every: int = 0              # hymba: n layers forced global
    global_attn_layers: Tuple[int, ...] = ()

    # --- MLA (deepseek-v2) ---
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # --- MoE (deepseek fine-grained) ---
    n_experts: int = 0
    n_shared_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0              # per-expert hidden
    first_dense_layers: int = 0    # leading dense-MLP layers
    capacity_factor: float = 1.25
    moe_groups: int = 32           # token groups (= data shards) for
                                   # shard-local dispatch positions
    moe_dispatch: str = "scatter"  # "shard_map": explicit-collective dispatch

    # --- SSM ---
    ssm_state: int = 0             # mamba/rwkv head state size
    rwkv_head_dim: int = 64
    ssm_expand: int = 2            # mamba d_inner = expand * d_model
    ssm_conv: int = 4

    # --- hybrid (hymba) ---
    attn_ratio: float = 0.5        # fraction of d mapped through attention path

    # --- encoder-decoder (whisper) ---
    n_enc_layers: int = 0
    enc_seq: int = 0               # stub frontend frames (whisper: 1500)

    # --- VLM (paligemma) ---
    vis_prefix_len: int = 0        # stub patch embeddings (paligemma: 256)

    # --- training knobs ---
    param_dtype: str = "float32"   # "bfloat16" -> bf16 params + fp32 master
    ce_block: int = 0              # >0: blockwise cross-entropy chunk size
    scale_embed: bool = False      # gemma-style sqrt(d) embedding scale
    tie_embeddings: bool = True
    dtype: str = "bfloat16"        # activation/param compute dtype
    q_block: int = 512             # chunked-attention block sizes
    kv_block: int = 1024
    attn_block_skip: bool = True   # skip fully-masked causal/window blocks
    rwkv_chunk: int = 128
    rwkv_mode: str = "chunked"     # chunked | recurrent
    remat: bool = True
    logit_softcap: float = 0.0
    # analysis-only: python-loop over layers instead of lax.scan, so that
    # XLA cost_analysis (which counts while-bodies ONCE) reports true
    # per-step totals. Production builds keep scan (depth-free HLO).
    unroll_layers: bool = False

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def is_causal_lm(self) -> bool:
        return self.family not in ("audio",)

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic token mixing -> long_500k cell is runnable."""
        return (self.block_kind in ("rwkv", "hybrid")
                or self.sliding_window is not None)

    def reduced(self, **overrides) -> "ArchConfig":
        """Tiny same-family variant for CPU smoke tests."""
        small = dict(
            n_layers=2, d_model=64,
            n_heads=max(2, min(4, self.n_heads)),
            n_kv_heads=1 if self.n_kv_heads == 1 else 2,
            d_ff=128, vocab_size=256, head_dim=16,
        )
        if self.n_experts:
            small.update(n_experts=8, moe_top_k=2, moe_d_ff=32,
                         n_shared_experts=min(self.n_shared_experts, 1))
        if self.kv_lora_rank:
            small.update(kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=8,
                         v_head_dim=16)
        if self.n_enc_layers:
            small.update(n_enc_layers=2, enc_seq=16)
        if self.vis_prefix_len:
            small.update(vis_prefix_len=8)
        if self.sliding_window:
            small.update(sliding_window=32)
        if self.block_kind in ("rwkv", "hybrid"):
            small.update(rwkv_head_dim=16, ssm_state=8)
        small.update(q_block=32, kv_block=32, rwkv_chunk=16)
        small.update(overrides)
        return dataclasses.replace(self, **small)
