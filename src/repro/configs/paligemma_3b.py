"""paligemma-3b — gemma backbone + SigLIP patch-embedding stub
(input_specs provides 256 precomputed patch embeddings); prefix-LM mask
[arXiv:2407.07726]."""
from repro.configs.base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="paligemma-3b", family="vlm",
        n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1,
        d_ff=16384, vocab_size=257216, head_dim=256,
        mlp_kind="geglu", scale_embed=True,
        vis_prefix_len=256,
        tie_embeddings=True,
    )
