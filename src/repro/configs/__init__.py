"""Config registry: ``--arch <id>`` -> ArchConfig, plus the paper's own
DQN/replay configurations."""
from __future__ import annotations

import importlib

from repro.configs.base import ArchConfig

# arch id -> module name
_ARCH_MODULES = {
    "rwkv6-7b": "rwkv6_7b",
    "h2o-danube-3-4b": "h2o_danube3_4b",
    "granite-34b": "granite_34b",
    "phi3-medium-14b": "phi3_medium_14b",
    "stablelm-1.6b": "stablelm_1_6b",
    "whisper-tiny": "whisper_tiny",
    "hymba-1.5b": "hymba_1_5b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "paligemma-3b": "paligemma_3b",
}

ARCH_IDS = tuple(_ARCH_MODULES)


def get_config(arch_id: str) -> ArchConfig:
    if arch_id not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_ARCH_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch_id]}")
    return mod.config()


def get_reduced_config(arch_id: str, **overrides) -> ArchConfig:
    """Tiny same-family variant for CPU smoke tests."""
    return get_config(arch_id).reduced(**overrides)
