"""rwkv6-7b — Finch: attention-free, data-dependent decay [arXiv:2404.05892]."""
from repro.configs.base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="rwkv6-7b", family="ssm",
        n_layers=32, d_model=4096, n_heads=64, n_kv_heads=64,
        d_ff=14336, vocab_size=65536,
        block_kind="rwkv", mlp_kind="rwkv_cmix", norm_kind="layernorm",
        rwkv_head_dim=64, ssm_state=64,
        tie_embeddings=False,
    )
