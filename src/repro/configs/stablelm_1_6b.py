"""stablelm-1.6b — MHA (kv=32) [hf:stabilityai/stablelm-2-1_6b]."""
from repro.configs.base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="stablelm-1.6b", family="dense",
        n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32,
        d_ff=5632, vocab_size=100352, head_dim=64,
        norm_kind="layernorm",
        tie_embeddings=True,
    )
