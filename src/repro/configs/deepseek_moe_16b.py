"""deepseek-moe-16b — fine-grained MoE: 2 shared + 64 routed top-6,
first layer dense (d_ff=10944) [arXiv:2401.06066]."""
from repro.configs.base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="deepseek-moe-16b", family="moe",
        n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16,
        d_ff=10944, vocab_size=102400, head_dim=128,
        n_experts=64, n_shared_experts=2, moe_top_k=6, moe_d_ff=1408,
        first_dense_layers=1, moe_dispatch="shard_map",
        tie_embeddings=False,
    )
