"""Layer 2: import-and-trace checkers over the live library.

Where :mod:`repro.analysis.lint` reads source text, this layer imports
the replay fabric and *traces* its hot paths, checking properties only
visible in the jaxpr:

* ``DISPATCH-BUDGET`` — the fused AMPER-fr draw must stay within the
  dispatch count committed in ``BENCH_sampling.json``.  The counter is
  the fusion-aware jaxpr-equation count (``pallas_call`` = 1 launch,
  pointwise/layout chaff fused away) that the sampling benchmark and
  the telemetry dispatch guard both use — it moved here so the analysis
  gate, ``benchmarks/bench_samplers.py`` and ``tests/test_obs.py`` are
  one implementation.
* ``RECOMPILE`` — the slab-prefetch path must compile ONCE and be
  reused across draws: evolving buffer contents, annealed β values and
  advancing PRNG keys are value changes, not signature changes.  A
  python scalar threaded into the call signature (or a dtype flip)
  silently retraces per step, which is exactly the regression this
  catches.
* ``DTYPE-WIDE`` — no float64/int64 avals and no weak-typed public
  outputs anywhere in the ``core/`` sampler traces (weak outputs cause
  silent downstream retraces; 64-bit leaks double the paper's bandwidth
  story).

All checks trace under ``force_interpret(False)`` so the counted
lowering is the real TPU one (one ``pallas_call``) even on a CPU host;
tracing never executes the kernel, and jax's caches are cleared after,
exactly as ``dispatch_count`` has always done.
"""
from __future__ import annotations

import json
import os

import jax
import jax.extend.core as jex_core
import jax.numpy as jnp

from repro.analysis.findings import Finding

# Batch/CSP-ratio of the committed budget rows (kept in lockstep with
# benchmarks/bench_samplers.py, which imports them from here).
BATCH = 64
CSP_RATIO = 0.15
BUDGET_ROW = "fr-fused/n10000"

# Pointwise / layout primitives XLA reliably fuses into a neighbouring
# kernel: they do not launch dispatches of their own.  Everything NOT in
# this set (RNG, reductions, cumsum, sort, gather/scatter, dot,
# pallas_call, ...) is charged as one dispatch.
FUSIBLE = frozenset({
    "add", "sub", "mul", "div", "rem", "neg", "abs", "sign", "max", "min",
    "and", "or", "xor", "not", "shift_left", "shift_right_logical",
    "shift_right_arithmetic", "integer_pow", "pow", "exp", "log", "sqrt",
    "rsqrt", "floor", "ceil", "round", "clamp", "is_finite",
    "lt", "le", "gt", "ge", "eq", "ne", "select_n", "convert_element_type",
    "broadcast_in_dim", "reshape", "squeeze", "slice", "pad", "transpose",
    "iota", "stop_gradient", "copy",
})


def sub_jaxprs(params):
    """Yield every Jaxpr nested in an equation's params (pjit, scan, cond...)."""
    for v in params.values():
        leaves = v if isinstance(v, (tuple, list)) else (v,)
        for leaf in leaves:
            if isinstance(leaf, jex_core.ClosedJaxpr):
                yield leaf.jaxpr
            elif isinstance(leaf, jex_core.Jaxpr):
                yield leaf


def count_eqns(jaxpr) -> tuple[int, int]:
    """Recursive (total_eqns, launch_eqns) over a jaxpr.

    ``pallas_call`` counts as ONE launch regardless of its inner body —
    that is the whole point of fusing — while structured control flow
    (pjit/scan/cond/while) is charged the cost of its sub-jaxpr instead
    of 1.  ``launch_eqns`` excludes the ``FUSIBLE`` pointwise/layout
    chaff that XLA folds into neighbouring kernels, so it approximates
    kernel launches per draw; ``total_eqns`` is the raw count.
    """
    total = launches = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            total += 1
            launches += 1
            continue
        subs = list(sub_jaxprs(eqn.params))
        if subs:
            for s in subs:
                t, l = count_eqns(s)
                total += t
                launches += l
        else:
            total += 1
            launches += eqn.primitive.name not in FUSIBLE
    return total, launches


def dispatch_count(fn, *args) -> tuple[int, int]:
    """(total_eqns, launch_eqns) traced for ``fn(*args)``, fused kernel = 1.

    Traced under ``force_interpret(False)`` so the count reflects the real
    TPU lowering (one ``pallas_call``) even on a CPU host — tracing never
    executes the kernel, so this is safe off-TPU.

    The override is invisible to jax's global trace cache (keyed on
    function identity + avals), so the poisoned-for-CPU jaxpr traced here
    must not leak into later executions: caches are cleared on exit.
    """
    from repro.kernels.common import force_interpret

    with force_interpret(False):
        closed = jax.make_jaxpr(fn)(*args)
    jax.clear_caches()
    return count_eqns(closed.jaxpr)


# --------------------------------------------------------------------- #
# DISPATCH-BUDGET
# --------------------------------------------------------------------- #

def default_bench_path() -> str:
    """The committed budget file at the repo root (cwd-first so the CI
    job and a repo-root shell both resolve the committed copy)."""
    for cand in ("BENCH_sampling.json",
                 os.path.join(os.path.dirname(__file__), "..", "..", "..",
                              "BENCH_sampling.json")):
        if os.path.exists(cand):
            return cand
    return "BENCH_sampling.json"


def budget_from_bench(bench_path: str, row_name: str = BUDGET_ROW) -> int:
    """The committed dispatches-per-draw budget for ``row_name``."""
    with open(bench_path) as f:
        bench = json.load(f)
    row = next(r for r in bench["rows"] if r[0] == row_name)
    derived = dict(kv.split("=") for kv in row[2].split())
    return int(derived["dispatches"])


def _fused_sampler(n: int):
    from repro.core.amper import AmperConfig, AmperSampler

    cfg = AmperConfig(capacity=n, m=20, lam_fr=2.0, v_max=1.0,
                      csp_capacity=max(int(n * CSP_RATIO), BATCH),
                      fr_mode="fused")
    amp = AmperSampler(cfg, "fr")
    prio = jax.random.uniform(jax.random.key(0), (n,)) + 0.01
    state = amp.update(amp.init(), jnp.arange(n), prio)
    return amp, state


def check_dispatch_budget(bench_path: str | None = None, *,
                          n: int = 10_000) -> list[Finding]:
    """Trace the fused AMPER-fr draw and compare against the committed
    budget.  Over budget = a fusion regression on the paper's 55-270x
    hot path; under budget = an improvement the baseline should absorb
    (flagged too, so the committed number stays honest)."""
    bench_path = bench_path or default_bench_path()
    try:
        budget = budget_from_bench(bench_path)
    except (OSError, StopIteration, KeyError, ValueError) as e:
        return [Finding(
            rule="DISPATCH-BUDGET", path="<trace:amper-fr-fused>", line=0,
            message=f"cannot read committed budget from {bench_path}: {e}")]
    amp, state = _fused_sampler(n)
    key = jax.random.key(1)
    _, dispatches = dispatch_count(
        lambda s, k, a=amp: a.sample(s, k, BATCH), state, key)
    if dispatches > budget:
        return [Finding(
            rule="DISPATCH-BUDGET", path="<trace:amper-fr-fused>", line=0,
            message=f"fused AMPER-fr draw traces to {dispatches} dispatches,"
                    f" over the committed budget of {budget} "
                    f"({BUDGET_ROW} in BENCH_sampling.json)")]
    if dispatches < budget:
        return [Finding(
            rule="DISPATCH-BUDGET", path="<trace:amper-fr-fused>", line=0,
            message=f"fused AMPER-fr draw now traces to {dispatches} "
                    f"dispatches, BELOW the committed {budget} — re-run "
                    f"the sampling benchmark and commit the new "
                    f"BENCH_sampling.json")]
    return []


# --------------------------------------------------------------------- #
# RECOMPILE
# --------------------------------------------------------------------- #

def trace_cache_entries(jit_fn, calls) -> int:
    """Invoke ``jit_fn`` over every argument tuple in ``calls`` and
    return how many distinct traces the jit cache holds afterwards."""
    for args in calls:
        jax.block_until_ready(jit_fn(*args))
    return int(jit_fn._cache_size())


def check_recompile() -> list[Finding]:
    """Drive the slab-prefetch draw exactly as the async service does —
    evolving buffer state, annealed β, advancing draw keys — and require
    ONE compiled trace to serve every call."""
    from repro.core.replay_buffer import ReplayBuffer
    from repro.core.samplers import make_sampler
    from repro.runtime import prng
    from repro.runtime.pipeline import make_slab_sampler

    capacity, batch, slab = 128, 8, 2
    rb = ReplayBuffer(capacity, make_sampler("amper-fr", capacity))
    tr = {"obs": jnp.zeros((4,), jnp.float32),
          "action": jnp.int32(0), "reward": jnp.float32(0.0),
          "next_obs": jnp.zeros((4,), jnp.float32),
          "done": jnp.float32(0.0)}
    state = rb.init(tr)
    fill = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (capacity // 2,) + jnp.shape(x)), tr)
    state = rb.add_batch(state, fill)
    sample = jax.jit(make_slab_sampler(rb, batch, slab))
    key = jax.random.key(0)

    findings = []
    # The β-annealed steady state: new key, new β VALUE, evolving state
    # each draw — one signature, one trace.
    states = [state, rb.add_batch(state, fill),
              rb.update_priorities(state, jnp.arange(8),
                                   jnp.linspace(0.1, 1.0, 8))]
    calls = [(s, prng.sample_key(key, d), jnp.float32(0.4 + 0.1 * d))
             for d, s in enumerate(states)]
    n = trace_cache_entries(sample, calls)
    if n != 1:
        findings.append(Finding(
            rule="RECOMPILE", path="<trace:slab-prefetch>", line=0,
            message=f"slab draw retraced across draws: {n} cache entries "
                    f"for {len(calls)} identical-signature calls (a "
                    f"python scalar or dtype flip in the call signature "
                    f"recompiles per step)"))
    # The β=None constant-β mode is a second *intended* signature (a
    # leafless pytree); it must add exactly one more trace, not one per
    # call.
    none_calls = [(states[0], prng.sample_key(key, 7), None),
                  (states[1], prng.sample_key(key, 8), None)]
    n2 = trace_cache_entries(sample, none_calls)
    if n2 > 2:
        findings.append(Finding(
            rule="RECOMPILE", path="<trace:slab-prefetch>", line=0,
            message=f"constant-β slab draw retraced: {n2} cache entries "
                    f"(expected 2: one annealed-β trace + one β=None "
                    f"trace)"))
    jax.clear_caches()
    return findings


# --------------------------------------------------------------------- #
# DTYPE-WIDE
# --------------------------------------------------------------------- #

_WIDE = {jnp.dtype("float64"), jnp.dtype("int64"), jnp.dtype("uint64"),
         jnp.dtype("complex128")}


def scan_jaxpr_dtypes(jaxpr, label: str) -> list[Finding]:
    """Flag any 64-bit aval produced anywhere inside ``jaxpr``."""
    findings = []
    seen = set()
    def walk(jx):
        for eqn in jx.eqns:
            for var in eqn.outvars:
                aval = getattr(var, "aval", None)
                dt = getattr(aval, "dtype", None)
                try:
                    wide = dt is not None and jnp.dtype(dt) in _WIDE
                except TypeError:  # extended dtypes (key<fry>) have no np dtype
                    wide = False
                if wide:
                    k = (eqn.primitive.name, str(dt))
                    if k not in seen:
                        seen.add(k)
                        findings.append(Finding(
                            rule="DTYPE-WIDE", path=f"<trace:{label}>",
                            line=0,
                            message=f"{eqn.primitive.name} produces {dt} "
                                    f"inside {label}: 64-bit promotion on "
                                    f"the hot path"))
            for s in sub_jaxprs(eqn.params):
                walk(s)
    walk(jaxpr)
    return findings


def _weak_outputs(closed, label: str) -> list[Finding]:
    findings = []
    for i, aval in enumerate(closed.out_avals):
        if getattr(aval, "weak_type", False):
            findings.append(Finding(
                rule="DTYPE-WIDE", path=f"<trace:{label}>", line=0,
                message=f"output {i} of {label} is weak-typed "
                        f"({aval.dtype}): downstream jits retrace when a "
                        f"strongly-typed value arrives instead"))
    return findings


def check_dtype_promotion(
        kinds=("per-cumsum", "per-sumtree", "amper-fr", "amper-k"),
        capacity: int = 256, batch: int = 16) -> list[Finding]:
    """Trace every registry sampler's sample+update in ``core/`` (the
    fused path covers ``kernels/``) and flag 64-bit avals and weak-typed
    public outputs."""
    from repro.core.samplers import make_sampler
    from repro.kernels.common import force_interpret

    findings = []
    key = jax.random.key(0)
    for i, kind in enumerate(kinds):
        k_fill = jax.random.fold_in(key, i)
        sampler = make_sampler(kind, capacity)
        state = sampler.update(
            sampler.init(), jnp.arange(capacity),
            jax.random.uniform(k_fill, (capacity,)) + 0.01)
        idx = jnp.arange(batch, dtype=jnp.int32)
        prio = jnp.linspace(0.1, 1.0, batch)
        with force_interpret(False):
            c_sample = jax.make_jaxpr(
                lambda s, k: sampler.sample(s, k, batch))(state, key)
            c_update = jax.make_jaxpr(sampler.update)(state, idx, prio)
        jax.clear_caches()
        for label, closed in ((f"{kind}.sample", c_sample),
                              (f"{kind}.update", c_update)):
            findings.extend(scan_jaxpr_dtypes(closed.jaxpr, label))
            findings.extend(_weak_outputs(closed, label))
    return findings


def run_trace_checks(bench_path: str | None = None) -> list[Finding]:
    """All layer-2 checks (the CLI's ``--no-trace`` skips these)."""
    return (check_dispatch_budget(bench_path)
            + check_recompile()
            + check_dtype_promotion())
