"""Layer 3: lockdep — a lock-order-graph recorder for the runtime.

The async runtime is *designed* lock-light (lock-free metrics cells,
queue handoff instead of shared state), but the locks that remain — the
service work/batch queue mutexes, the snapshotter handoff queue, the
metrics registry's registration lock, the JSONL exporter's write lock,
the actor pause-gate condition — can still deadlock if two threads ever
take two of them in opposite orders.  That bug class is invisible to
tests until the exact interleaving fires in production.

Linux lockdep's insight: the *order* in which lock pairs are taken is
a static property you can record on ANY interleaving.  Record a digraph
edge A→B whenever B is acquired while A is held; a cycle in that graph
is a potential deadlock even if no run ever deadlocked.

Usage:

* Instrumentation sites call :func:`make_lock` / :func:`make_condition`
  / :func:`tracked_queue` instead of the bare ``threading`` / ``queue``
  constructors.  With no recorder installed (the default) the wrappers
  cost one global read per acquire — nothing else changes.
* Tests / stress runs call :func:`enable` (optionally with a JSONL log
  path), exercise the runtime, then assert ``not recorder.cycles()``.
* Offline, ``python -m repro.analysis --lock-log run.jsonl`` replays a
  recorded acquisition log and reports cycles without importing the
  runtime at all (:func:`check_log`).
"""
from __future__ import annotations

import json
import queue
import threading
from typing import Iterable

from repro.analysis.findings import Finding

# Module-global recorder.  ``None`` means disabled: TrackedLock's hot
# path is then a single global load + ``is None`` test.
_recorder: "LockGraph | None" = None


class TrackedLock:
    """A named wrapper around a ``threading.Lock`` (or compatible).

    Not reentrant — matching the wrapped primitive.  All the waiting
    happens inside the wrapped lock; the recorder sees the acquisition
    only once it succeeded, so recording can never itself deadlock.
    """

    __slots__ = ("name", "_lock")

    def __init__(self, name: str, lock=None):
        self.name = name
        self._lock = lock if lock is not None else threading.Lock()

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        ok = self._lock.acquire(blocking, timeout)
        rec = _recorder
        if ok and rec is not None:
            rec.on_acquire(self.name)
        return ok

    def release(self) -> None:
        rec = _recorder
        if rec is not None:
            rec.on_release(self.name)
        self._lock.release()

    def locked(self) -> bool:
        return self._lock.locked()

    def __enter__(self) -> "TrackedLock":
        self.acquire()
        return self

    def __exit__(self, *exc) -> None:
        self.release()

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"TrackedLock({self.name!r})"


def make_lock(name: str) -> TrackedLock:
    """A tracked ``threading.Lock`` replacement."""
    return TrackedLock(name)


def make_condition(name: str) -> threading.Condition:
    """A ``threading.Condition`` whose underlying lock is tracked.

    ``Condition.wait`` releases and re-acquires through the wrapper's
    plain ``release()``/``acquire()`` (the ``_release_save`` fast path
    only exists on RLocks), so lockdep sees the wait's handoff too.
    """
    return threading.Condition(TrackedLock(name))


def tracked_queue(name: str, maxsize: int = 0) -> queue.Queue:
    """A ``queue.Queue`` whose internal mutex is tracked.

    The queue's three conditions (not_empty / not_full / all_tasks_done)
    are rebuilt on the tracked mutex so every ``put``/``get``/``join``
    acquisition shows up in the lock graph.  This is how the service's
    work/batch queues participate in lockdep without the runtime knowing.
    """
    q = queue.Queue(maxsize)
    mutex = TrackedLock(name, q.mutex)
    q.mutex = mutex
    q.not_empty = threading.Condition(mutex)
    q.not_full = threading.Condition(mutex)
    q.all_tasks_done = threading.Condition(mutex)
    return q


class LockGraph:
    """Per-thread held-lock stacks + the global acquisition-order digraph."""

    def __init__(self, log_path: str | None = None):
        self._tls = threading.local()
        # (held, acquired) -> witness: thread name + full held stack at
        # the moment the edge was first seen.
        self._edges: dict[tuple[str, str], dict] = {}
        # lock name -> total successful acquisitions (coverage signal:
        # an empty edge set is only meaningful if locks actually fired).
        self._counts: dict[str, int] = {}
        # Plain, deliberately untracked guard for the shared structures.
        self._guard = threading.Lock()
        self._log = open(log_path, "a") if log_path else None

    # -- recorder hooks (called by TrackedLock) ------------------------ #

    def _stack(self) -> list[str]:
        st = getattr(self._tls, "stack", None)
        if st is None:
            st = self._tls.stack = []
        return st

    def on_acquire(self, name: str) -> None:
        st = self._stack()
        held = list(st)
        st.append(name)
        new = [(h, name) for h in held
               if h != name and (h, name) not in self._edges]
        thread = threading.current_thread().name
        with self._guard:
            self._counts[name] = self._counts.get(name, 0) + 1
            for edge in new:
                self._edges.setdefault(
                    edge, {"thread": thread, "held": held})
            if self._log is not None:
                self._log.write(json.dumps(
                    {"ev": "acquire", "lock": name, "held": held,
                     "thread": thread}) + "\n")

    def on_release(self, name: str) -> None:
        st = self._stack()
        # Remove the innermost matching hold (out-of-order releases of
        # distinct locks are legal and common with queues).
        for i in range(len(st) - 1, -1, -1):
            if st[i] == name:
                del st[i]
                break
        if self._log is not None:
            with self._guard:
                self._log.write(json.dumps(
                    {"ev": "release", "lock": name,
                     "thread": threading.current_thread().name}) + "\n")

    # -- analysis ------------------------------------------------------ #

    def edges(self) -> set[tuple[str, str]]:
        with self._guard:
            return set(self._edges)

    def counts(self) -> dict[str, int]:
        """Successful acquisitions per lock name."""
        with self._guard:
            return dict(self._counts)

    def witness(self, edge: tuple[str, str]) -> dict:
        with self._guard:
            return dict(self._edges.get(edge, {}))

    def cycles(self) -> list[list[str]]:
        return find_cycles(self.edges())

    def flush(self) -> None:
        if self._log is not None:
            with self._guard:
                self._log.flush()

    def close(self) -> None:
        if self._log is not None:
            with self._guard:
                self._log.close()
                self._log = None


def enable(log_path: str | None = None) -> LockGraph:
    """Install a fresh recorder (replacing any active one)."""
    global _recorder
    old, _recorder = _recorder, LockGraph(log_path)
    if old is not None:
        old.close()
    return _recorder


def disable() -> LockGraph | None:
    """Uninstall the recorder and return it (graph stays inspectable)."""
    global _recorder
    rec, _recorder = _recorder, None
    if rec is not None:
        rec.close()
    return rec


def current() -> LockGraph | None:
    return _recorder


def find_cycles(edges: Iterable[tuple[str, str]]) -> list[list[str]]:
    """Elementary cycles in the acquisition digraph (DFS back-edges).

    The graph has a handful of nodes; each cycle is reported once,
    rotated so its lexicographically-smallest lock comes first.
    """
    adj: dict[str, list[str]] = {}
    for a, b in edges:
        adj.setdefault(a, []).append(b)
        adj.setdefault(b, [])
    seen_cycles: set[tuple[str, ...]] = set()
    cycles: list[list[str]] = []
    state: dict[str, int] = {}  # 0 unvisited / 1 on stack / 2 done
    path: list[str] = []

    def dfs(u: str) -> None:
        state[u] = 1
        path.append(u)
        for v in sorted(adj[u]):
            if state.get(v, 0) == 0:
                dfs(v)
            elif state.get(v) == 1:
                cyc = path[path.index(v):]
                k = min(range(len(cyc)), key=lambda i: cyc[i])
                canon = tuple(cyc[k:] + cyc[:k])
                if canon not in seen_cycles:
                    seen_cycles.add(canon)
                    cycles.append(list(canon))
        path.pop()
        state[u] = 2

    for node in sorted(adj):
        if state.get(node, 0) == 0:
            dfs(node)
    return cycles


def cycle_findings(cycles: list[list[str]],
                   source: str = "<lockdep>") -> list[Finding]:
    out = []
    for cyc in cycles:
        ring = " -> ".join(cyc + [cyc[0]])
        out.append(Finding(
            rule="LOCK-ORDER", path=source, line=0,
            message=f"lock acquisition cycle (potential deadlock): {ring}"))
    return out


def check_log(path: str) -> list[Finding]:
    """Offline lockdep: rebuild the order graph from a recorded JSONL
    acquisition log and report cycles.  Needs only the log — no runtime
    import, so it works on logs shipped from another machine."""
    edges: set[tuple[str, str]] = set()
    findings: list[Finding] = []
    with open(path) as f:
        for lineno, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except ValueError:
                findings.append(Finding(
                    rule="LOCK-ORDER", path=path, line=lineno,
                    message="unparseable lockdep log line"))
                continue
            if rec.get("ev") != "acquire":
                continue
            lock = rec["lock"]
            for held in rec.get("held", ()):
                if held != lock:
                    edges.add((held, lock))
    findings.extend(cycle_findings(find_cycles(edges), source=path))
    return findings
