"""Findings model shared by every analysis layer.

A :class:`Finding` is one violation of one rule at one source location
(trace- and lock-level rules use a pseudo-location naming the checked
entry point).  The CLI aggregates findings from all three layers, then
subtracts two suppression mechanisms:

* inline ``# repro: noqa[RULE]`` (or bare ``# repro: noqa``) on the
  flagged line — for violations that are *intentional at that site*
  (e.g. a determinism test that reuses a PRNG key on purpose);
* a committed baseline JSON — for legacy findings that are accepted
  for now but must not grow.  Baseline entries match on
  ``(rule, path, message)`` as a multiset, NOT on line numbers, so
  unrelated edits moving code around do not resurrect them, while a
  *new* instance of a baselined pattern in the same file still fails.
"""
from __future__ import annotations

import json
import re
from collections import Counter
from dataclasses import asdict, dataclass, field

BASELINE_VERSION = 1

_NOQA_RE = re.compile(
    r"#\s*repro:\s*noqa(?:\[(?P<rules>[A-Za-z0-9_,\- ]+)\])?")


@dataclass(frozen=True)
class Finding:
    """One rule violation.

    ``message`` must be stable under unrelated line-number drift (no
    line numbers inside it) — baseline matching depends on that.
    """

    rule: str
    path: str
    line: int
    message: str
    col: int = 0

    def key(self) -> tuple[str, str, str]:
        return (self.rule, self.path, self.message)

    def render(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule} {self.message}"


def noqa_rules_for_line(source_line: str) -> set[str] | None:
    """Rules suppressed by an inline comment on ``source_line``.

    Returns None when there is no noqa comment, the empty set for a
    blanket ``# repro: noqa`` (suppresses every rule), else the set of
    named rules (upper-cased).
    """
    m = _NOQA_RE.search(source_line)
    if m is None:
        return None
    rules = m.group("rules")
    if rules is None:
        return set()
    return {r.strip().upper() for r in rules.split(",") if r.strip()}


def apply_noqa(findings: list[Finding],
               source_lines: list[str]) -> list[Finding]:
    """Drop findings whose source line carries a matching noqa comment."""
    out = []
    for f in findings:
        if 1 <= f.line <= len(source_lines):
            rules = noqa_rules_for_line(source_lines[f.line - 1])
            if rules is not None and (not rules or f.rule in rules):
                continue
        out.append(f)
    return out


@dataclass
class Baseline:
    """Committed multiset of accepted findings."""

    entries: Counter = field(default_factory=Counter)

    @classmethod
    def load(cls, path: str) -> "Baseline":
        with open(path) as f:
            payload = json.load(f)
        if payload.get("version") != BASELINE_VERSION:
            raise ValueError(
                f"baseline {path} has version {payload.get('version')!r}, "
                f"this tool reads version {BASELINE_VERSION}")
        entries = Counter()
        for e in payload["findings"]:
            entries[(e["rule"], e["path"], e["message"])] += int(
                e.get("count", 1))
        return cls(entries)

    @classmethod
    def from_findings(cls, findings: list[Finding]) -> "Baseline":
        return cls(Counter(f.key() for f in findings))

    def save(self, path: str) -> None:
        findings = [
            {"rule": rule, "path": p, "message": msg, "count": n}
            for (rule, p, msg), n in sorted(self.entries.items())
        ]
        with open(path, "w") as f:
            json.dump({"version": BASELINE_VERSION, "findings": findings},
                      f, indent=1, sort_keys=False)
            f.write("\n")

    def filter(self, findings: list[Finding]) -> list[Finding]:
        """Findings NOT covered by the baseline (multiset subtraction:
        a baselined pattern occurring more often than recorded surfaces
        the extra occurrences)."""
        budget = Counter(self.entries)
        out = []
        for f in sorted(findings, key=lambda f: (f.path, f.line, f.col)):
            if budget[f.key()] > 0:
                budget[f.key()] -= 1
            else:
                out.append(f)
        return out


def findings_json(findings: list[Finding], *, suppressed: int = 0) -> dict:
    """Machine-readable payload for ``--out`` / ``--format json``."""
    counts = Counter(f.rule for f in findings)
    return {
        "version": BASELINE_VERSION,
        "total": len(findings),
        "suppressed": suppressed,
        "counts": dict(sorted(counts.items())),
        "findings": [asdict(f) for f in
                     sorted(findings, key=lambda f: (f.path, f.line, f.col))],
    }
