"""``python -m repro.analysis`` — the gate the CI job runs.

Exit codes: 0 = clean (after noqa + baseline suppression), 1 = findings,
2 = usage/configuration error.

Typical invocations::

    python -m repro.analysis src tests benchmarks \
        --baseline analysis-baseline.json        # the CI gate
    python -m repro.analysis src --no-trace      # fast AST-only pass
    python -m repro.analysis --write-baseline analysis-baseline.json \
        src tests benchmarks                     # accept current findings
    python -m repro.analysis --dead-modules      # unreferenced-module report
    python -m repro.analysis --lock-log run.jsonl  # offline lockdep
    python -m repro.analysis src --format prom   # dashboards
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.analysis.findings import Baseline, Finding, findings_json


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="JAX/concurrency static analysis for the replay fabric")
    p.add_argument("paths", nargs="*",
                   help="files or directories to lint (e.g. src tests "
                        "benchmarks)")
    p.add_argument("--baseline", metavar="JSON",
                   help="committed baseline of accepted findings to subtract")
    p.add_argument("--write-baseline", metavar="JSON",
                   help="write current findings as the new baseline and exit 0")
    p.add_argument("--out", metavar="JSON",
                   help="also write the machine-readable findings JSON here")
    p.add_argument("--format", choices=("text", "json", "prom"),
                   default="text", help="stdout format (default: text)")
    p.add_argument("--no-trace", action="store_true",
                   help="skip the import-and-trace layer (dispatch budget, "
                        "recompile, dtype) — AST lint only")
    p.add_argument("--bench", metavar="JSON",
                   help="dispatch-budget source (default: BENCH_sampling.json)")
    p.add_argument("--dead-modules", action="store_true",
                   help="print the unreferenced-module report and exit "
                        "(report only, never fails)")
    p.add_argument("--lock-log", metavar="JSONL",
                   help="offline lockdep: check a recorded acquisition log "
                        "for lock-order cycles")
    return p


def _emit_prom(findings: list[Finding]) -> str:
    """Per-rule finding counts in the obs Prometheus text format, so the
    analysis gate lands on the same dashboards as the runtime metrics."""
    from repro.obs.exporters import prometheus_text
    from repro.obs.metrics import Registry

    reg = Registry(enabled=True)
    from collections import Counter as _Counter

    counts = _Counter(f.rule for f in findings)
    # Materialize every known rule at 0 so dashboards see a stable
    # series set whether or not the run was clean.
    from repro.analysis import ALL_RULES

    for rule in sorted(set(ALL_RULES) | set(counts)):
        c = reg.counter(
            "analysis.findings." + rule.lower().replace("-", "_"),
            help=f"non-suppressed {rule} findings in the last analysis run")
        for _ in range(counts.get(rule, 0)):
            c.add()
    return prometheus_text(reg)


def main(argv: list[str] | None = None) -> int:
    args = build_parser().parse_args(argv)

    if args.dead_modules:
        from repro.analysis.deadcode import dead_module_report, render_report

        src_root = args.paths[0] if args.paths else "src"
        print(render_report(dead_module_report(src_root)))
        return 0

    findings: list[Finding] = []

    if args.lock_log:
        from repro.analysis.locks import check_log

        try:
            findings.extend(check_log(args.lock_log))
        except OSError as e:
            print(f"error: cannot read lock log: {e}", file=sys.stderr)
            return 2

    if args.paths:
        from repro.analysis.lint import run_lint

        findings.extend(run_lint(args.paths))
        if not args.no_trace:
            from repro.analysis.jaxpr_lint import run_trace_checks

            findings.extend(run_trace_checks(args.bench))
    elif not args.lock_log:
        print("error: no paths given (and no --lock-log/--dead-modules)",
              file=sys.stderr)
        return 2

    if args.write_baseline:
        Baseline.from_findings(findings).save(args.write_baseline)
        print(f"wrote baseline with {len(findings)} finding(s) to "
              f"{args.write_baseline}")
        return 0

    suppressed = 0
    if args.baseline:
        try:
            bl = Baseline.load(args.baseline)
        except (OSError, ValueError, KeyError) as e:
            print(f"error: cannot load baseline: {e}", file=sys.stderr)
            return 2
        kept = bl.filter(findings)
        suppressed = len(findings) - len(kept)
        findings = kept

    findings.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    payload = findings_json(findings, suppressed=suppressed)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(payload, f, indent=1)
            f.write("\n")

    if args.format == "json":
        print(json.dumps(payload, indent=1))
    elif args.format == "prom":
        sys.stdout.write(_emit_prom(findings))
    else:
        for f in findings:
            print(f.render())
        print(f"{len(findings)} finding(s), {suppressed} baselined")
    return 1 if findings else 0


if __name__ == "__main__":  # pragma: no cover
    sys.exit(main())
