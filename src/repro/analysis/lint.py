"""Layer 1: AST lint rules tuned to this codebase's hazard classes.

Every rule encodes a bug class that actually shipped (or nearly shipped)
in this repo's history:

* ``PRNG-REUSE`` — a PRNG key consumed by two ``jax.random.*`` calls
  without an intervening ``split``/reassignment (the PR 1/PR 2 bug
  class: correlated draws from a reused key).
* ``WALL-CLOCK`` — ``time.time()`` used in duration arithmetic (the
  PR 8 bug class: NTP steps and clock smearing corrupt measured
  latencies; use ``time.perf_counter()`` / ``time.monotonic()``).
  Reading ``time.time()`` as a *timestamp* (log provenance) is fine and
  not flagged.
* ``HOST-SYNC`` — host-synchronizing calls (``np.asarray``/``.item()``/
  ``float()`` on traced values/``block_until_ready``/``device_get``)
  inside a ``jax.jit``- or ``pallas_call``-compiled body.  Inside a
  trace these either fail or silently bake a constant at trace time.
* ``DONATED-USE`` — reading a buffer after passing it to a jit with
  ``donate_argnums`` covering that position (donation invalidates the
  buffer; XLA may have already reused its memory).
* ``TRACED-BRANCH`` — Python ``if``/``while`` branching on a traced
  array parameter inside a jitted body (trace-time ConcretizationError,
  or a silently baked-in branch under ``static_argnums`` drift).
  ``is None`` checks, ``.shape``/``.dtype`` attribute access and
  ``len()`` are structural and not flagged.

The analysis is deliberately flow-light: straight-line dataflow per
function scope, both branches of an ``if`` explored independently and
merged conservatively, loop bodies executed twice (so a consume in
iteration 1 flags the reuse in iteration 2).  False positives are
handled by ``# repro: noqa[RULE]`` (see :mod:`repro.analysis.findings`).
"""
from __future__ import annotations

import ast
import os
from dataclasses import dataclass, field

from repro.analysis.findings import Finding, apply_noqa

# jax.random.* callables whose first positional argument is a key they
# CONSUME (drawing twice from one key repeats/correlates the stream).
_KEY_CONSUMERS = frozenset({
    "uniform", "normal", "bits", "randint", "choice", "permutation",
    "categorical", "bernoulli", "gumbel", "exponential", "truncated_normal",
    "laplace", "shuffle", "gamma", "beta", "poisson", "dirichlet", "split",
    "multivariate_normal", "rademacher", "cauchy", "logistic", "t",
    "loggamma", "orthogonal", "ball", "rayleigh", "weibull_min",
})
# Key-deriving calls that are safe to apply repeatedly to one key
# (fold_in with distinct data is the documented stream-derivation
# idiom); they never mark the key consumed.
_KEY_DERIVERS = frozenset({"fold_in", "clone", "key_data", "wrap_key_data"})

_HOST_SYNC_NP = frozenset({"asarray", "array"})
_STATIC_TEST_CALLS = frozenset({
    "len", "isinstance", "getattr", "hasattr", "type", "callable"})


def _dotted(node: ast.AST) -> str | None:
    """Render a Name/Attribute chain as ``a.b.c`` (None if not one)."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _is_jax_random(func_value: ast.AST) -> bool:
    """Does this expression look like the ``jax.random`` module?"""
    if isinstance(func_value, ast.Name):
        return func_value.id in {"random", "jrandom", "jr", "jax_random"}
    if isinstance(func_value, ast.Attribute):
        return func_value.attr == "random"
    return False


def _is_time_time(node: ast.AST, bare_time_fn: bool) -> bool:
    if not isinstance(node, ast.Call):
        return False
    f = node.func
    if isinstance(f, ast.Attribute) and f.attr == "time":
        return isinstance(f.value, ast.Name) and f.value.id == "time"
    if bare_time_fn and isinstance(f, ast.Name) and f.id == "time":
        return True
    return False


@dataclass
class _FlowState:
    """Per-scope dataflow facts."""

    consumed: dict = field(default_factory=dict)   # key name -> consumer fn
    dead: dict = field(default_factory=dict)       # name -> donating wrapper
    timestamps: set = field(default_factory=set)   # names from time.time()

    def copy(self) -> "_FlowState":
        return _FlowState(dict(self.consumed), dict(self.dead),
                          set(self.timestamps))

    def merge(self, other: "_FlowState") -> None:
        """Conservative join after exclusive branches."""
        self.consumed.update(other.consumed)
        self.dead.update(other.dead)
        self.timestamps |= other.timestamps

    def kill(self, name: str) -> None:
        self.consumed.pop(name, None)
        self.dead.pop(name, None)
        self.timestamps.discard(name)


class _ModuleInfo:
    """Module-wide facts the per-scope passes need."""

    def __init__(self, tree: ast.Module, path: str):
        self.path = path
        # ``from time import time`` makes bare ``time()`` the wall clock.
        self.bare_time_fn = any(
            isinstance(n, ast.ImportFrom) and n.module == "time"
            and any(a.name == "time" for a in n.names)
            for n in ast.walk(tree))
        # Function names passed to jax.jit / pallas_call anywhere in the
        # module (``jax.jit(f)``, ``jax.jit(partial(f, ...))``,
        # ``pl.pallas_call(kernel, ...)``) are compiled bodies too.
        self.jitted_names: set[str] = set()
        # name -> donated positional indices, for wrappers assigned as
        # ``f = jax.jit(g, donate_argnums=<literal>)``.
        self.donating_wrappers: dict[str, tuple[int, ...]] = {}
        for node in ast.walk(tree):
            if isinstance(node, ast.Call):
                self._scan_call(node)
            elif isinstance(node, ast.Assign) and len(node.targets) == 1:
                t = node.targets[0]
                if isinstance(t, ast.Name) and isinstance(node.value, ast.Call):
                    donated = _donated_argnums(node.value)
                    if donated is not None:
                        self.donating_wrappers[t.id] = donated

    def _scan_call(self, node: ast.Call) -> None:
        f = node.func
        attr = f.attr if isinstance(f, ast.Attribute) else (
            f.id if isinstance(f, ast.Name) else None)
        if attr not in {"jit", "pallas_call"} or not node.args:
            return
        target = node.args[0]
        if isinstance(target, ast.Call):  # jax.jit(partial(f, ...))
            inner = target.func
            inner_attr = inner.attr if isinstance(inner, ast.Attribute) else (
                inner.id if isinstance(inner, ast.Name) else None)
            if inner_attr == "partial" and target.args:
                target = target.args[0]
        if isinstance(target, ast.Name):
            self.jitted_names.add(target.id)


def _donated_argnums(call: ast.Call) -> tuple[int, ...] | None:
    """Donated indices of a ``jax.jit(...)`` call with a LITERAL
    ``donate_argnums`` (None when not that shape)."""
    f = call.func
    attr = f.attr if isinstance(f, ast.Attribute) else (
        f.id if isinstance(f, ast.Name) else None)
    if attr != "jit":
        return None
    for kw in call.keywords:
        if kw.arg != "donate_argnums":
            continue
        v = kw.value
        if isinstance(v, ast.Constant) and isinstance(v.value, int):
            return (v.value,)
        if isinstance(v, (ast.Tuple, ast.List)) and all(
                isinstance(e, ast.Constant) and isinstance(e.value, int)
                for e in v.elts):
            return tuple(e.value for e in v.elts)
        return None  # non-literal (computed) -> cannot resolve statically
    return None


def _is_jit_decorated(node: ast.AST) -> bool:
    for dec in getattr(node, "decorator_list", []):
        target = dec.func if isinstance(dec, ast.Call) else dec
        name = _dotted(target) or ""
        leaf = name.rsplit(".", 1)[-1]
        if leaf == "jit":
            return True
        if leaf == "partial" and isinstance(dec, ast.Call) and dec.args:
            inner = _dotted(dec.args[0]) or ""
            if inner.rsplit(".", 1)[-1] == "jit":
                return True
    return False


class _ScopeLinter:
    """Runs all dataflow rules over one function (or module) scope."""

    def __init__(self, mod: _ModuleInfo, findings: list[Finding],
                 jitted: bool, params: set[str]):
        self.mod = mod
        self.findings = findings
        self.jitted = jitted
        self.params = params
        self.wrappers = dict(mod.donating_wrappers)

    # ------------------------------------------------------------------ #

    def _emit(self, rule: str, node: ast.AST, message: str) -> None:
        self.findings.append(Finding(
            rule=rule, path=self.mod.path,
            line=getattr(node, "lineno", 1),
            col=getattr(node, "col_offset", 0), message=message))

    # --- statements ---------------------------------------------------- #

    def exec_block(self, stmts, state: _FlowState) -> None:
        for s in stmts:
            self.exec_stmt(s, state)

    def exec_stmt(self, stmt: ast.stmt, state: _FlowState) -> None:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            return  # analyzed as their own scope by the module driver
        if isinstance(stmt, ast.Assign):
            self.visit_expr(stmt.value, state)
            names = [n for t in stmt.targets for n in _target_names(t)]
            for n in names:
                state.kill(n)
            if _is_time_time(stmt.value, self.mod.bare_time_fn):
                state.timestamps.update(names)
            if isinstance(stmt.value, ast.Call):
                donated = _donated_argnums(stmt.value)
                if donated is not None and len(names) == 1:
                    self.wrappers[names[0]] = donated
        elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
            if stmt.value is not None:
                self.visit_expr(stmt.value, state)
            for n in _target_names(stmt.target):
                state.kill(n)
                if stmt.value is not None and _is_time_time(
                        stmt.value, self.mod.bare_time_fn):
                    state.timestamps.add(n)
        elif isinstance(stmt, ast.If):
            self.visit_expr(stmt.test, state)
            self._check_traced_branch(stmt, "if")
            s_body, s_else = state.copy(), state.copy()
            self.exec_block(stmt.body, s_body)
            self.exec_block(stmt.orelse, s_else)
            # A branch that cannot fall through (return/raise/...) does
            # not contribute to the post-if state: a key consumed in an
            # early-return arm is NOT consumed on the fallthrough path.
            live = [s for s, blk in ((s_body, stmt.body),
                                     (s_else, stmt.orelse))
                    if not _terminates(blk)]
            if not live:
                live = [s_body]  # both terminate: post-state unreachable
            first, *rest = live
            state.consumed, state.dead, state.timestamps = (
                first.consumed, first.dead, first.timestamps)
            for s in rest:
                state.merge(s)
        elif isinstance(stmt, (ast.For, ast.AsyncFor)):
            self.visit_expr(stmt.iter, state)
            for _ in range(2):  # second pass exposes cross-iteration reuse
                for n in _target_names(stmt.target):
                    state.kill(n)
                self.exec_block(stmt.body, state)
            self.exec_block(stmt.orelse, state)
        elif isinstance(stmt, ast.While):
            self.visit_expr(stmt.test, state)
            self._check_traced_branch(stmt, "while")
            for _ in range(2):
                self.exec_block(stmt.body, state)
                self.visit_expr(stmt.test, state)
            self.exec_block(stmt.orelse, state)
        elif isinstance(stmt, (ast.With, ast.AsyncWith)):
            for item in stmt.items:
                self.visit_expr(item.context_expr, state)
                if item.optional_vars is not None:
                    for n in _target_names(item.optional_vars):
                        state.kill(n)
            self.exec_block(stmt.body, state)
        elif isinstance(stmt, ast.Try):
            self.exec_block(stmt.body, state)
            for h in stmt.handlers:
                s_h = state.copy()
                self.exec_block(h.body, s_h)
                state.merge(s_h)
            self.exec_block(stmt.orelse, state)
            self.exec_block(stmt.finalbody, state)
        elif isinstance(stmt, ast.Delete):
            for t in stmt.targets:
                for n in _target_names(t):
                    state.kill(n)
        else:
            for child in ast.iter_child_nodes(stmt):
                if isinstance(child, ast.expr):
                    self.visit_expr(child, state)

    # --- expressions ---------------------------------------------------- #

    def visit_expr(self, node: ast.AST, state: _FlowState) -> None:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            return  # separate scope
        if isinstance(node, ast.Call):
            self._visit_call(node, state)
            return
        if isinstance(node, ast.BinOp) and isinstance(
                node.op, (ast.Add, ast.Sub)):
            self._check_wallclock_arith(node, state)
        elif isinstance(node, ast.Compare):
            for side in [node.left] + node.comparators:
                if self._is_timestamp(side, state):
                    self._emit(
                        "WALL-CLOCK", node,
                        "time.time() result compared as a deadline; use "
                        "time.monotonic()/perf_counter() for elapsed-time "
                        "logic")
                    break
        elif isinstance(node, ast.Name) and isinstance(node.ctx, ast.Load):
            if node.id in state.dead:
                self._emit(
                    "DONATED-USE", node,
                    f"'{node.id}' was donated to jitted call "
                    f"'{state.dead[node.id]}' and may be invalidated; "
                    f"copy it first or re-bind the result")
            return
        for child in ast.iter_child_nodes(node):
            self.visit_expr(child, state)

    def _visit_call(self, node: ast.Call, state: _FlowState) -> None:
        # Arguments are evaluated (read) before the call consumes them.
        self.visit_expr(node.func, state)
        for a in node.args:
            self.visit_expr(a, state)
        for kw in node.keywords:
            self.visit_expr(kw.value, state)

        f = node.func
        # PRNG-REUSE: jax.random.<consumer>(key, ...)
        if (isinstance(f, ast.Attribute) and _is_jax_random(f.value)
                and f.attr in _KEY_CONSUMERS and node.args):
            key_arg = node.args[0]
            if isinstance(key_arg, ast.Name):
                name = key_arg.id
                if name in state.consumed:
                    self._emit(
                        "PRNG-REUSE", node,
                        f"key '{name}' already consumed by jax.random."
                        f"{state.consumed[name]}; split it (or fold_in "
                        f"distinct data) before drawing again")
                state.consumed[name] = f.attr
        # DONATED-USE: calling a donate_argnums wrapper kills its args.
        wrapper = None
        if isinstance(f, ast.Name) and f.id in self.wrappers:
            wrapper = f.id
        if wrapper is not None:
            for i in self.wrappers[wrapper]:
                if i < len(node.args) and isinstance(node.args[i], ast.Name):
                    state.dead[node.args[i].id] = wrapper
        # HOST-SYNC (only meaningful inside compiled bodies).
        if self.jitted:
            self._check_host_sync(node)

    # --- rule bodies ---------------------------------------------------- #

    def _is_timestamp(self, node: ast.AST, state: _FlowState) -> bool:
        return (_is_time_time(node, self.mod.bare_time_fn)
                or (isinstance(node, ast.Name) and isinstance(
                    node.ctx, ast.Load) and node.id in state.timestamps))

    def _check_wallclock_arith(self, node: ast.BinOp,
                               state: _FlowState) -> None:
        if self._is_timestamp(node.left, state) or self._is_timestamp(
                node.right, state):
            self._emit(
                "WALL-CLOCK", node,
                "time.time() used in duration arithmetic; use "
                "time.perf_counter() (NTP steps/smearing corrupt "
                "wall-clock deltas)")

    def _check_host_sync(self, node: ast.Call) -> None:
        f = node.func
        if isinstance(f, ast.Attribute):
            if f.attr == "item":
                self._emit("HOST-SYNC", node,
                           ".item() inside a jit/pallas body forces a "
                           "host sync (or bakes a tracer-time constant)")
                return
            if f.attr == "block_until_ready":
                self._emit("HOST-SYNC", node,
                           "block_until_ready inside a jit/pallas body "
                           "is a host sync; call it on the result outside "
                           "the trace")
                return
            if f.attr == "device_get":
                self._emit("HOST-SYNC", node,
                           "jax.device_get inside a jit/pallas body "
                           "transfers to host at trace time")
                return
            if (f.attr in _HOST_SYNC_NP and isinstance(f.value, ast.Name)
                    and f.value.id in {"np", "numpy", "onp"}):
                self._emit("HOST-SYNC", node,
                           f"np.{f.attr} inside a jit/pallas body pulls "
                           f"the traced array to host; use jnp instead")
                return
        if (isinstance(f, ast.Name) and f.id in {"float", "int", "bool"}
                and node.args):
            if any(isinstance(n, ast.Name) and n.id in self.params
                   and isinstance(n.ctx, ast.Load)
                   for n in ast.walk(node.args[0])):
                self._emit(
                    "HOST-SYNC", node,
                    f"{f.id}() on a traced parameter inside a jit/pallas "
                    f"body concretizes at trace time; keep it as an array")

    def _check_traced_branch(self, stmt, kw: str) -> None:
        if not self.jitted:
            return
        if self._test_mentions_param(stmt.test):
            self._emit(
                "TRACED-BRANCH", stmt,
                f"Python '{kw}' branches on a traced array parameter "
                f"inside a jit/pallas body; use lax.cond/select or mark "
                f"the argument static")

    def _test_mentions_param(self, node: ast.AST) -> bool:
        if isinstance(node, ast.Attribute):
            return False  # x.shape / x.dtype / cfg.flag: structural
        if isinstance(node, ast.Call):
            f = node.func
            if isinstance(f, ast.Name) and f.id in _STATIC_TEST_CALLS:
                return False
            return any(self._test_mentions_param(c)
                       for c in ast.iter_child_nodes(node))
        if isinstance(node, ast.Compare) and all(
                isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops):
            return False  # `x is None` — structural pytree dispatch
        if isinstance(node, ast.Name):
            return node.id in self.params
        return any(self._test_mentions_param(c)
                   for c in ast.iter_child_nodes(node))


def _terminates(block: list[ast.stmt]) -> bool:
    """True when the block cannot fall through to the statement after
    the enclosing ``if`` (last statement unconditionally leaves it)."""
    if not block:
        return False
    last = block[-1]
    if isinstance(last, (ast.Return, ast.Raise, ast.Break, ast.Continue)):
        return True
    if isinstance(last, ast.If):
        return bool(_terminates(last.body) and last.orelse
                    and _terminates(last.orelse))
    return False


def _target_names(target: ast.AST) -> list[str]:
    if isinstance(target, ast.Name):
        return [target.id]
    if isinstance(target, (ast.Tuple, ast.List)):
        return [n for e in target.elts for n in _target_names(e)]
    if isinstance(target, ast.Starred):
        return _target_names(target.value)
    return []


def _param_names(node) -> set[str]:
    if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
        a = node.args
        names = [p.arg for p in
                 a.posonlyargs + a.args + a.kwonlyargs]
        if a.vararg:
            names.append(a.vararg.arg)
        if a.kwarg:
            names.append(a.kwarg.arg)
        return {n for n in names if n != "self"}
    return set()


def lint_source(source: str, path: str) -> list[Finding]:
    """Run every AST rule over one file's source; noqa already applied."""
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as e:
        return [Finding(rule="PARSE-ERROR", path=path,
                        line=e.lineno or 1, col=e.offset or 0,
                        message=f"file does not parse: {e.msg}")]
    mod = _ModuleInfo(tree, path)
    findings: list[Finding] = []

    # Collect every function-like scope, tagging compiled ones.  A def
    # nested inside a jitted def runs traced too, so jittedness is
    # inherited lexically.
    scopes: list[tuple[list, bool, set[str]]] = [(tree.body, False, set())]

    def collect(node, jitted_ctx: bool):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                jitted = (jitted_ctx or _is_jit_decorated(child)
                          or child.name in mod.jitted_names)
                scopes.append((child.body, jitted, _param_names(child)))
                collect(child, jitted)
            elif isinstance(child, ast.Lambda):
                scopes.append(([ast.Expr(value=child.body)], jitted_ctx,
                               _param_names(child)))
                collect(child, jitted_ctx)
            else:
                collect(child, jitted_ctx)

    collect(tree, False)
    for body, jitted, params in scopes:
        linter = _ScopeLinter(mod, findings, jitted, params)
        linter.exec_block(body, _FlowState())

    # Loop bodies run twice: dedupe identical findings from one site.
    seen, unique = set(), []
    for f in findings:
        k = (f.rule, f.line, f.col, f.message)
        if k not in seen:
            seen.add(k)
            unique.append(f)
    return apply_noqa(unique, source.splitlines())


def lint_file(path: str, root: str | None = None) -> list[Finding]:
    with open(path, encoding="utf-8") as f:
        source = f.read()
    rel = os.path.relpath(path, root) if root else path
    return lint_source(source, rel)


def iter_python_files(paths, *, exclude_parts=("fixtures",)):
    """Yield .py files under ``paths``; directories named in
    ``exclude_parts`` (lint fixtures: deliberate violations) are
    skipped."""
    for p in paths:
        if os.path.isfile(p):
            if p.endswith(".py"):
                yield p
            continue
        for dirpath, dirnames, filenames in os.walk(p):
            dirnames[:] = sorted(
                d for d in dirnames
                if d not in exclude_parts and d != "__pycache__")
            for fn in sorted(filenames):
                if fn.endswith(".py"):
                    yield os.path.join(dirpath, fn)


def run_lint(paths, root: str | None = None) -> list[Finding]:
    findings: list[Finding] = []
    for path in iter_python_files(paths):
        findings.extend(lint_file(path, root=root))
    return findings
