"""Unreferenced-module report (``--dead-modules``).

Walks the static import graph of the ``repro`` package and reports, in
two sections, modules that nothing reaches:

* **unreferenced** — not reachable from the library's executable entry
  points *nor* from any ``tests/`` / ``benchmarks/`` / ``examples/``
  file: nothing in the repo would notice their deletion.
* **outside_fabric** — unreachable from the entry points (the replay
  fabric never imports them) but kept alive by tests, benchmarks or
  examples; candidates for demotion or doc-only status.

Entry points are every module with an ``if __name__ == "__main__"``
guard plus the fabric roots (service, launcher, analysis CLI).  This is
a *report*, never a gate: it prints, it does not fail the build, and
this PR deletes nothing based on it.
"""
from __future__ import annotations

import ast
import os

FABRIC_ROOTS = (
    "repro.runtime.service",
    "repro.launch.train",
    "repro.analysis.cli",
)


def repro_modules(src_root: str) -> dict[str, str]:
    """Map dotted module name -> file path for everything under
    ``src_root/repro`` (packages map their ``__init__.py``)."""
    out: dict[str, str] = {}
    pkg_root = os.path.join(src_root, "repro")
    for dirpath, dirnames, filenames in os.walk(pkg_root):
        dirnames[:] = [d for d in dirnames if d != "__pycache__"]
        for fn in filenames:
            if not fn.endswith(".py"):
                continue
            path = os.path.join(dirpath, fn)
            rel = os.path.relpath(path, src_root)[:-3]
            parts = rel.split(os.sep)
            if parts[-1] == "__init__":
                parts = parts[:-1]
            out[".".join(parts)] = path
    return out


def _resolve(modules: dict[str, str], dotted: str, names) -> set[str]:
    """Edges for ``from dotted import names`` / ``import dotted``."""
    edges: set[str] = set()
    if dotted in modules:
        edges.add(dotted)
    for n in names:
        child = f"{dotted}.{n}"
        if child in modules:
            edges.add(child)
    # ``import repro.a.b`` also imports the intermediate packages.
    parts = dotted.split(".")
    for i in range(1, len(parts)):
        parent = ".".join(parts[:i])
        if parent in modules:
            edges.add(parent)
    return edges


def module_imports(path: str, name: str,
                   modules: dict[str, str]) -> set[str]:
    """Repro-internal modules statically imported by ``path``.

    Handles absolute and relative forms; imports of a *symbol* from a
    package resolve to the submodule when one exists by that name, else
    to the package itself.
    """
    with open(path, encoding="utf-8") as f:
        try:
            tree = ast.parse(f.read(), filename=path)
        except SyntaxError:
            return set()
    is_pkg = os.path.basename(path) == "__init__.py"
    pkg = name if is_pkg else name.rsplit(".", 1)[0] if "." in name else ""
    edges: set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                if alias.name == "repro" or alias.name.startswith("repro."):
                    edges |= _resolve(modules, alias.name, ())
        elif isinstance(node, ast.ImportFrom):
            if node.level:  # relative: from .x import y
                base_parts = pkg.split(".") if pkg else []
                up = node.level - 1
                if up and base_parts:
                    base_parts = base_parts[:-up] if up < len(base_parts) \
                        else []
                dotted = ".".join(base_parts + (
                    node.module.split(".") if node.module else []))
            else:
                dotted = node.module or ""
            if dotted == "repro" or dotted.startswith("repro."):
                edges |= _resolve(modules, dotted,
                                  [a.name for a in node.names])
    edges.discard(name)
    return edges


def _has_main_guard(path: str) -> bool:
    with open(path, encoding="utf-8") as f:
        src = f.read()
    return "__main__" in src and any(
        isinstance(n, ast.If) and "__main__" in ast.dump(n.test)
        for n in ast.parse(src).body)


def _closure(graph: dict[str, set[str]], roots) -> set[str]:
    seen: set[str] = set()
    stack = [r for r in roots if r in graph]
    while stack:
        m = stack.pop()
        if m in seen:
            continue
        seen.add(m)
        stack.extend(graph.get(m, ()))
    return seen


def dead_module_report(src_root: str = "src",
                       extra_scan=("tests", "benchmarks", "examples"),
                       repo_root: str = ".") -> dict:
    """The two-section report (see module docstring)."""
    modules = repro_modules(src_root)
    graph = {name: module_imports(path, name, modules)
             for name, path in modules.items()}

    roots = set(FABRIC_ROOTS) & set(modules)
    for name, path in modules.items():
        if name.endswith("__main__") or _has_main_guard(path):
            roots.add(name)
    fabric = _closure(graph, roots)

    external_refs: set[str] = set()
    for d in extra_scan:
        top = os.path.join(repo_root, d)
        if not os.path.isdir(top):
            continue
        for dirpath, dirnames, filenames in os.walk(top):
            dirnames[:] = [x for x in dirnames if x != "__pycache__"]
            for fn in filenames:
                if fn.endswith(".py"):
                    external_refs |= module_imports(
                        os.path.join(dirpath, fn), "", modules)
    externally_alive = _closure(graph, external_refs)

    unreferenced = sorted(set(modules) - fabric - externally_alive)
    outside_fabric = sorted((set(modules) - fabric) & externally_alive)
    return {
        "roots": sorted(roots),
        "modules": len(modules),
        "unreferenced": unreferenced,
        "outside_fabric": outside_fabric,
    }


def render_report(report: dict) -> str:
    lines = [
        f"dead-modules report ({report['modules']} modules, "
        f"roots: {', '.join(report['roots'])})",
        "",
        "## unreferenced (nothing in src/tests/benchmarks/examples "
        "imports these)",
    ]
    lines += [f"  {m}" for m in report["unreferenced"]]
    if not report["unreferenced"]:
        lines.append("  (none)")
    lines += [
        "",
        "## outside the replay fabric (reached only from tests/"
        "benchmarks/examples)",
    ]
    lines += [f"  {m}" for m in report["outside_fabric"]]
    if not report["outside_fabric"]:
        lines.append("  (none)")
    lines.append("")
    lines.append("report only — nothing is deleted by this tool.")
    return "\n".join(lines)
