"""Static analysis for the replay fabric (``python -m repro.analysis``).

Three layers, one findings model (:mod:`repro.analysis.findings`):

* :mod:`repro.analysis.lint` — AST lint over source text (PRNG key
  reuse, wall-clock duration math, host syncs under jit, use after
  donation, traced-parameter branching).
* :mod:`repro.analysis.jaxpr_lint` — import-and-trace checks (dispatch
  budget vs ``BENCH_sampling.json``, slab-path recompiles, 64-bit /
  weak-type promotion).
* :mod:`repro.analysis.locks` — lockdep: lock-order-graph recording and
  cycle (potential-deadlock) detection, online or from a JSONL log.

Import cost matters: the runtime imports :mod:`repro.analysis.locks`
for its instrumentation hooks, so this package ``__init__`` must stay
free of jax and of the heavier layers (they are imported lazily by the
CLI).
"""
from repro.analysis.findings import Baseline, Finding  # noqa: F401

# Every rule any layer can emit — the prom exporter materializes these
# at 0 so dashboards keep a stable series set on clean runs.
ALL_RULES = (
    "PRNG-REUSE",
    "WALL-CLOCK",
    "HOST-SYNC",
    "DONATED-USE",
    "TRACED-BRANCH",
    "PARSE-ERROR",
    "DISPATCH-BUDGET",
    "RECOMPILE",
    "DTYPE-WIDE",
    "LOCK-ORDER",
)
