"""Training launcher: AMPER-prioritized LM training with fault tolerance.

Runs any ``--arch`` (full or ``--reduced`` smoke scale) with the
prioritized sequence-replay data pipeline (``--sampler uniform | per |
amper-fr | amper-k``), periodic atomic checkpoints, auto-resume from the
latest checkpoint, and a SIGTERM preemption hook — kill the process mid
-run and relaunching continues bitwise-identically (step-seeded
sampling).

Example:
  PYTHONPATH=src python -m repro.launch.train --arch stablelm-1.6b \
      --reduced --steps 200 --batch 8 --seq-len 128 --sampler amper-fr \
      --ckpt-dir /tmp/run1
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_reduced_config
from repro.distributed import sharding as shd
from repro.launch.mesh import make_debug_mesh
from repro.models.model_api import Model
from repro.train import checkpoint as ckpt_mod
from repro.train import data as data_mod
from repro.train import train_step as ts_mod
from repro.train.optimizer import AdamW, cosine_schedule


def per_sequence_loss(model, params, batch):
    """Per-sequence mean NLL — the replay priorities (LM 'TD errors')."""
    from repro.models import transformer
    cfg = model.cfg
    logits, _ = transformer.forward(cfg, params, batch["tokens"])
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, batch["targets"][..., None], -1)[..., 0]
    m = batch["loss_mask"]
    return (nll * m).sum(-1) / jnp.maximum(m.sum(-1), 1.0)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--n-seqs", type=int, default=2048)
    ap.add_argument("--sampler", default="amper-fr",
                    choices=["uniform", "per", "amper-fr", "amper-k"])
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = (get_reduced_config(args.arch) if args.reduced
           else get_config(args.arch))
    model = Model.from_config(cfg)
    opt = AdamW(cosine_schedule(args.lr, 20, args.steps))
    step_fn = jax.jit(ts_mod.make_train_step(
        model, opt, microbatches=args.microbatches), donate_argnums=0)
    loss_by_seq = jax.jit(lambda p, b: per_sequence_loss(model, p, b))

    tokens = data_mod.corpus_tokens(args.n_seqs, args.seq_len + 1,
                                    cfg.vocab_size, seed=args.seed)
    data = data_mod.PrioritizedSeqData(tokens, args.batch,
                                       sampler=args.sampler)
    data_state = data.init()
    state = ts_mod.init_train_state(model, opt, jax.random.key(args.seed))

    mgr = None
    start_step = 0
    if args.ckpt_dir:
        mgr = ckpt_mod.CheckpointManager(args.ckpt_dir, keep=3,
                                         save_interval=args.ckpt_every)
        mgr.install_preemption_hook()
        latest = mgr.restore_latest((state, data_state))
        if latest[0] is not None:
            start_step, (state, data_state) = latest
            print(f"resumed from step {start_step}")

    t0 = time.perf_counter()
    for step in range(start_step, args.steps):
        key = jax.random.fold_in(jax.random.key(args.seed), step)
        idx, batch = data.sample(data_state, key)
        state, metrics = step_fn(state, batch)
        seq_loss = loss_by_seq(state.params, batch)
        data_state = data.update(data_state, idx, seq_loss)
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {float(metrics['loss']):.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"({(time.perf_counter() - t0):.1f}s)", flush=True)
        if mgr and mgr.should_save(step + 1):
            mgr.save(step + 1, (state, data_state))
            if mgr.preempted:
                print(f"preempted: checkpointed at step {step + 1}, exiting")
                return 0
    if mgr:
        mgr.save(args.steps, (state, data_state))
    print(f"done: {args.steps} steps, final loss "
          f"{float(metrics['loss']):.4f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
