"""Roofline-term extraction from compiled dry-run artifacts.

cost_analysis() gives HLO FLOPs and bytes accessed; collective bytes are
NOT in cost_analysis, so we parse the optimized HLO text and sum operand
sizes of every collective op, weighted by the standard ring-algorithm
traffic factors.  Hardware constants target TPU v5e.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

# --- TPU v5e per-chip constants (assignment-specified) ---
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # B/s
ICI_BW = 50e9                   # B/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
# ring traffic factor x operand bytes (per-device bytes on the wire)
_TRAFFIC_FACTOR = {
    "all-reduce": 2.0,          # reduce-scatter + all-gather
    "all-gather": 1.0,          # output bytes ~ gathered size
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dtype, dims = m.group(1), m.group(2)
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class CollectiveStats:
    counts: dict
    bytes_by_kind: dict

    @property
    def total_bytes(self) -> float:
        return sum(self.bytes_by_kind.values())


def collective_bytes(hlo_text: str) -> CollectiveStats:
    """Sum collective-op traffic from optimized HLO text.

    For each collective instruction, the operand(s) appear in the
    result-type annotation, e.g.::

        %ar = bf16[1024,512] all-reduce(bf16[1024,512] %x), replica_groups=...

    We take the RESULT type(s) (tuple types expand to their elements) as
    the operand size and weight by the ring traffic factor.  'start'
    variants are counted; matching '-done' ops carry no payload.
    """
    counts: dict = {}
    by_kind: dict = {}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"%?\S+\s*=\s*(\([^)]*\)|\S+\[[^\]]*\]\S*)\s+(\S+)\(", line)
        if not m:
            continue
        result_type, opname = m.group(1), m.group(2)
        kind = None
        for c in _COLLECTIVES:
            if opname == c or opname == c + "-start":
                kind = c
                break
        if kind is None:
            continue
        nbytes = _shape_bytes(result_type)
        counts[kind] = counts.get(kind, 0) + 1
        by_kind[kind] = by_kind.get(kind, 0.0) + nbytes * _TRAFFIC_FACTOR[kind]
    return CollectiveStats(counts=counts, bytes_by_kind=by_kind)


@dataclasses.dataclass
class Roofline:
    """All quantities are PER-DEVICE: XLA compiles the per-device SPMD
    program, so cost_analysis()/memory_analysis()/the HLO text all
    describe one device's share (verified empirically: an 8-way-sharded
    matmul reports 2MNK/8 flops)."""

    flops: float                  # per-device HLO flops (+ corrections)
    bytes_accessed: float         # per-device HBM bytes
    coll_bytes_per_dev: float     # per-device collective wire bytes
    n_devices: int
    model_flops: Optional[float] = None   # 6*N*D analytic (GLOBAL)

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS_BF16

    @property
    def t_memory(self) -> float:
        return self.bytes_accessed / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes_per_dev / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def step_time_lower_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def mfu_bound(self) -> Optional[float]:
        """MODEL_FLOPS / (devices * peak * max-term) — roofline fraction."""
        if not self.model_flops:
            return None
        t = self.step_time_lower_bound
        return self.model_flops / (self.n_devices * PEAK_FLOPS_BF16 * t)

    @property
    def useful_flop_ratio(self) -> Optional[float]:
        if not self.model_flops:
            return None
        return self.model_flops / max(self.flops * self.n_devices, 1.0)

    def as_dict(self) -> dict:
        return {
            "flops": self.flops, "bytes_accessed": self.bytes_accessed,
            "coll_bytes_per_dev": self.coll_bytes_per_dev,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops,
            "useful_flop_ratio": self.useful_flop_ratio,
            "roofline_fraction": self.mfu_bound,
        }


def analyze(compiled, mesh, model_flops: Optional[float] = None,
            corrections: Optional[dict] = None) -> dict:
    """Full per-cell report from a compiled executable.

    ``corrections``: analytic {flops, bytes} for inner loops the HLO cost
    model cannot see (see inner_corrections)."""
    ca = compiled.cost_analysis()
    ma = compiled.memory_analysis()
    n_dev = mesh.devices.size
    coll = collective_bytes(compiled.as_text())
    corr = corrections or {"flops": 0.0, "bytes": 0.0}
    # corrections are analytic GLOBAL totals -> convert to per-device.
    roof = Roofline(
        flops=float(ca.get("flops", 0.0)) + corr["flops"] / n_dev,
        bytes_accessed=(float(ca.get("bytes accessed", 0.0))
                        + corr["bytes"] / n_dev),
        coll_bytes_per_dev=coll.total_bytes,
        n_devices=n_dev,
        model_flops=model_flops,
    )
    return {
        "roofline": roof.as_dict(),
        "hlo_flops_raw": float(ca.get("flops", 0.0)),
        "correction_flops": corr["flops"],
        "collectives": {"counts": coll.counts,
                        "bytes_by_kind": coll.bytes_by_kind},
        "memory": {
            "argument_bytes_per_dev": ma.argument_size_in_bytes,
            "output_bytes_per_dev": ma.output_size_in_bytes,
            "temp_bytes_per_dev": ma.temp_size_in_bytes,
            "peak_bytes_per_dev": (ma.argument_size_in_bytes
                                   + ma.temp_size_in_bytes),
        },
    }


def _avg_kv(S: int, window) -> float:
    """Average kv positions visible per causal query (optional window)."""
    if window is None or window >= S:
        return (S + 1) / 2.0
    w = window
    return (w * (w + 1) / 2.0 + (S - w) * w) / S


def inner_corrections(cfg, kind: str, B: int, S: int) -> dict:
    """Analytic flops/bytes for loops XLA's cost model can't see.

    The analysis build unrolls the LAYER loops, but attention q/kv block
    loops, the rwkv chunk loop and the mamba time scan remain lax.scans
    whose bodies HloCostAnalysis counts once.  Their totals are simple
    closed forms, added here.  Train multiplier 4 = fwd + remat-refwd +
    2x bwd (cfg.remat=True); serve = 1.
    """
    mult = 4.0 if (kind == "train" and cfg.remat) else (2.0 if kind == "train" else 1.0)
    bytes_el = 2 if cfg.dtype == "bfloat16" else 4
    flops = 0.0
    nbytes = 0.0
    L = cfg.n_layers

    def attn_terms(n_layers, Hq, Hkv, d_qk, d_v, S_q, kv_avg):
        nonlocal flops, nbytes
        flops += mult * n_layers * 2.0 * B * Hq * S_q * kv_avg * (d_qk + d_v)
        # KV streamed once per q block; q/o streamed once.
        nq = max(S_q // max(cfg.q_block, 1), 1)
        kv_bytes = B * Hkv * kv_avg * (d_qk + d_v) * bytes_el
        qo_bytes = 2 * B * Hq * S_q * d_qk * bytes_el
        nbytes += mult * n_layers * (nq * kv_bytes + qo_bytes)

    if cfg.block_kind == "rwkv":
        H = cfg.d_model // cfg.rwkv_head_dim
        N = cfg.rwkv_head_dim
        if kind == "decode":
            flops += 6.0 * B * H * N * N * L
            nbytes += L * B * H * N * N * 4 * 2  # state read+write
        else:
            C = cfg.rwkv_chunk
            flops += mult * L * B * H * S * (4.0 * C * N + 4.0 * N * N)
            nbytes += mult * L * B * H * (S // C) * N * N * 4 * 2
        return {"flops": flops, "bytes": nbytes}

    Hq, Hkv, Hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    d_qk, d_v = Hd, Hd
    if cfg.attn_kind == "mla":
        Hkv = Hq
        d_qk = cfg.qk_nope_dim + cfg.qk_rope_dim
        d_v = cfg.v_head_dim

    if kind == "decode":
        S_q, ctx = 1, S
        if cfg.global_attn_layers:
            n_glob = len(cfg.global_attn_layers)
            attn_terms(n_glob, Hq, Hkv, d_qk, d_v, 1, ctx)
            attn_terms(L - n_glob, Hq, Hkv, d_qk, d_v, 1,
                       min(ctx, cfg.sliding_window))
        else:
            kv = min(ctx, cfg.sliding_window) if cfg.sliding_window else ctx
            attn_terms(L, Hq, Hkv, d_qk, d_v, 1, kv)
    else:
        if cfg.family == "audio":
            attn_terms(cfg.n_enc_layers, Hq, Hkv, Hd, Hd, cfg.enc_seq,
                       cfg.enc_seq)               # bidirectional encoder
            attn_terms(L, Hq, Hkv, Hd, Hd, S, _avg_kv(S, None))  # dec self
            attn_terms(L, Hq, Hkv, Hd, Hd, S, cfg.enc_seq)       # cross
        elif cfg.global_attn_layers:
            n_glob = len(cfg.global_attn_layers)
            attn_terms(n_glob, Hq, Hkv, d_qk, d_v, S, _avg_kv(S, None))
            attn_terms(L - n_glob, Hq, Hkv, d_qk, d_v, S,
                       _avg_kv(S, cfg.sliding_window))
        else:
            attn_terms(L, Hq, Hkv, d_qk, d_v, S,
                       _avg_kv(S, cfg.sliding_window))

    if cfg.block_kind == "hybrid":
        Di, Ns = cfg.ssm_expand * cfg.d_model, cfg.ssm_state
        steps = 1 if kind == "decode" else S
        flops += mult * L * B * steps * 6.0 * Di * Ns
        nbytes += mult * L * B * steps * Di * Ns * 4 * 2
    return {"flops": flops, "bytes": nbytes}


def analytic_model_flops(cfg, n_tokens: int, kind: str) -> float:
    """6*N*D (dense) / 6*N_active*D (MoE) with the train/serve multiplier."""
    n_active = active_params(cfg)
    mult = 6.0 if kind == "train" else 2.0
    return mult * n_active * n_tokens


def active_params(cfg) -> float:
    """Per-token active parameter count (routed experts count top_k only)."""
    D, F, L, V = cfg.d_model, cfg.d_ff, cfg.n_layers, cfg.vocab_size
    emb = V * D * (1 if cfg.tie_embeddings else 2)
    if cfg.block_kind == "rwkv":
        mix = 4 * D * D + 2 * D * 64
        mlp = 2 * D * F + D * D
        return L * (mix + mlp) + emb
    if cfg.attn_kind == "mla":
        r = cfg.kv_lora_rank
        attn = (D * cfg.n_heads * (cfg.qk_nope_dim + cfg.qk_rope_dim)
                + D * (r + cfg.qk_rope_dim)
                + r * cfg.n_heads * (cfg.qk_nope_dim + cfg.v_head_dim)
                + cfg.n_heads * cfg.v_head_dim * D)
    else:
        attn = (D * cfg.n_heads * cfg.head_dim * 2
                + D * cfg.n_kv_heads * cfg.head_dim * 2)
    if cfg.block_kind == "hybrid":
        di = cfg.ssm_expand * D
        attn += 2 * D * di + di * D + di * (2 * cfg.ssm_state + di // 16)
    if cfg.n_experts:
        Fe = cfg.moe_d_ff
        active_mlp = 3 * D * Fe * (cfg.moe_top_k + cfg.n_shared_experts)
        n_dense = cfg.first_dense_layers
        mlp_total = (L - n_dense) * active_mlp + n_dense * 3 * D * F
        return L * attn + mlp_total + emb
    mlp_mult = 3 if cfg.mlp_kind in ("swiglu", "geglu") else 2
    enc = 0.0
    if cfg.n_enc_layers:
        enc = cfg.n_enc_layers * (attn + mlp_mult * D * F)
        attn = attn * 2  # decoder self + cross
    return L * (attn + mlp_mult * D * F) + emb + enc
