"""Serving launcher: batched prefill + decode with KV caches.

Drives any arch (reduced scale on CPU) through the real serving path:
prefill a batch of prompts, then decode N tokens greedily, reporting
per-token latency.  The full-scale decode path is exercised shape-only
by the dry-run (decode_32k / long_500k cells).

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch hymba-1.5b --reduced \
      --batch 4 --prompt-len 32 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_reduced_config
from repro.models.model_api import Model


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = (get_reduced_config(args.arch) if args.reduced
           else get_config(args.arch))
    model = Model.from_config(cfg)
    params = model.init_params(jax.random.key(args.seed))
    max_len = args.prompt_len + args.gen + 1

    k_tok, k_aud, k_vis = jax.random.split(jax.random.key(args.seed + 1), 3)
    B = args.batch
    prompts = jax.random.randint(k_tok, (B, args.prompt_len), 0,
                                 cfg.vocab_size, dtype=jnp.int32)
    batch = {"tokens": prompts}
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            k_aud, (B, cfg.enc_seq, cfg.d_model), jnp.float32)
    if cfg.vis_prefix_len:
        batch["patch_embeds"] = jax.random.normal(
            k_vis, (B, cfg.vis_prefix_len, cfg.d_model), jnp.float32)
        max_len += cfg.vis_prefix_len

    from repro.serving import Engine
    engine = Engine(model, params)

    t0 = time.perf_counter()
    logits, cache = engine.prefill(batch, max_len)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0
    print(f"prefill: batch={B} prompt={args.prompt_len} "
          f"{t_prefill * 1e3:.1f} ms")

    t0 = time.perf_counter()
    res = engine.generate(batch, args.gen)
    jax.block_until_ready(res.tokens)
    dt = (time.perf_counter() - t0) / args.gen
    print(f"decode: {args.gen} tokens, {dt * 1e3:.2f} ms/token "
          f"({B / dt:.1f} tok/s aggregate)")
    print("sample:", res.tokens[0, :16].tolist())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
