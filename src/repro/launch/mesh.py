"""Production mesh builders.

``make_production_mesh`` is a FUNCTION (not a module constant) so that
importing this module never touches jax device state — required because
the dry-run forces 512 host devices via XLA_FLAGS before first jax init,
while tests/benches must keep seeing 1 CPU device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_devices: int | None = None,
                    model: int = 1) -> jax.sharding.Mesh:
    """Small mesh over whatever devices exist (tests, examples)."""
    n = n_devices or len(jax.devices())
    return jax.make_mesh((n // model, model), ("data", "model"))


def make_replay_mesh(n_shards: int | None = None) -> jax.sharding.Mesh:
    """1-D ``("data",)`` mesh for the sharded replay subsystem.

    ``n_shards`` defaults to every visible device; an explicit smaller
    value builds the mesh over a device prefix, which is how the sharded
    benchmarks sweep shard counts inside one process (XLA_FLAGS must have
    forced enough host devices before first jax init).
    """
    import numpy as np

    devices = jax.devices()
    n = n_shards or len(devices)
    if n > len(devices):
        raise ValueError(f"requested {n} shards but only "
                         f"{len(devices)} devices exist")
    return jax.sharding.Mesh(np.asarray(devices[:n]), ("data",))
