import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first two lines: jax locks the device count on first init.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell we build the REAL jitted step (train_step / prefill /
decode_step) with in/out shardings derived from the logical-axis rules,
``.lower().compile()`` it against ShapeDtypeStruct inputs (no
allocation), and record memory_analysis / cost_analysis / collective
bytes — the inputs to the §Roofline analysis.

Also dry-runs the PAPER'S OWN workload at production scale: the sharded
AMPER-fr sampler over a 2^28-entry priority table on the full mesh.

Usage:
  python -m repro.launch.dryrun --arch granite-34b --shape train_4k --multi-pod
  python -m repro.launch.dryrun --all --out results/dryrun.json
"""
import argparse
import functools
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.distributed import sharding as shd
from repro.launch import hlo_analysis
from repro.launch.mesh import make_production_mesh
from repro.models.model_api import Model, SHAPE_CELLS
from repro.train import train_step as ts_mod
from repro.train.optimizer import AdamW, cosine_schedule


def _divisible_sharding(rules: shd.ShardingRules, spec_axes, aval):
    """NamedSharding, dropping mesh axes that don't divide the dim."""
    pspec = rules.spec(spec_axes)
    parts = []
    for i, entry in enumerate(pspec):
        if entry is None:
            parts.append(None)
            continue
        axes = (entry,) if isinstance(entry, str) else entry
        total = 1
        for a in axes:
            total *= rules.mesh.shape[a]
        parts.append(entry if aval.shape[i] % total == 0 else None)
    return NamedSharding(rules.mesh, P(*parts))


def tree_input_shardings(rules, axes_tree, aval_tree):
    return jax.tree.map(
        lambda axes, aval: _divisible_sharding(rules, axes, aval),
        axes_tree, aval_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None), tuple)) for e in x))


def batch_axes_like(batch_avals, batch_axis=("batch",)):
    """Logical axes for an input batch pytree: shard dim0 over batch."""
    return jax.tree.map(
        lambda a: ("batch",) + (None,) * (len(a.shape) - 1), batch_avals)


def lower_cell(arch: str, shape: str, multi_pod: bool,
               donate: bool = True, unroll: bool = False,
               cfg_overrides: dict | None = None,
               rules_preset: str = "tp"):
    """Returns (lowered, mesh, cfg, model_flops) or a skip marker.

    unroll=True builds the analysis variant (python loop over layers) so
    cost_analysis reports true per-step totals; the production build
    keeps lax.scan (depth-free HLO, the runnability proof).
    """
    import dataclasses as _dc
    cfg = get_config(arch)
    if unroll:
        cfg = _dc.replace(cfg, unroll_layers=True)
    if cfg_overrides:
        cfg = _dc.replace(cfg, **cfg_overrides)
    cell = SHAPE_CELLS[shape]
    if shape == "long_500k" and not cfg.supports_long_context:
        return ("skip", "full attention is O(S^2) at 524288; "
                        "long_500k runs only for SSM/hybrid/SWA archs")
    if shape == "long_500k" and cfg.family == "audio":
        return ("skip", "whisper decoder max context exceeded by design")

    mesh = make_production_mesh(multi_pod=multi_pod)
    model = Model.from_config(cfg)
    is_train = cell.kind == "train"
    rules = shd.ShardingRules(
        mesh, shd.RULE_PRESETS[rules_preset] if is_train
        else shd.SERVE_RULES)
    inputs = model.input_specs(shape)
    n_tokens = cell.global_batch * (cell.seq_len if cell.kind != "decode" else 1)
    model_flops = hlo_analysis.analytic_model_flops(
        cfg, n_tokens, "train" if is_train else "serve")

    with mesh, shd.use_rules(rules):
        if is_train:
            opt = AdamW(cosine_schedule(3e-4, 100, 10_000),
                        mixed_precision=(cfg.param_dtype == "bfloat16"))
            step_fn = ts_mod.make_train_step(model, opt)
            state_aval = ts_mod.abstract_train_state(model, opt)
            state_axes = ts_mod.train_state_axes(model, opt)
            state_sh = tree_input_shardings(rules, state_axes, state_aval)
            batch_sh = tree_input_shardings(
                rules, batch_axes_like(inputs), inputs)
            jitted = jax.jit(step_fn,
                             in_shardings=(state_sh, batch_sh),
                             out_shardings=(state_sh, None),
                             donate_argnums=(0,) if donate else ())
            lowered = jitted.lower(state_aval, inputs)
        elif cell.kind == "prefill":
            params_aval = model.abstract_params()
            params_sh = tree_input_shardings(rules, model.param_axes(),
                                             params_aval)
            batch_sh = tree_input_shardings(
                rules, batch_axes_like(inputs), inputs)
            fn = functools.partial(model.prefill, max_len=cell.seq_len)
            jitted = jax.jit(lambda p, b: fn(p, b),
                             in_shardings=(params_sh, batch_sh))
            lowered = jitted.lower(params_aval, inputs)
        else:  # decode
            params_aval = model.abstract_params()
            params_sh = tree_input_shardings(rules, model.param_axes(),
                                             params_aval)
            cache_aval = inputs["cache"]
            cache_sh = tree_input_shardings(rules, model.cache_axes(),
                                            cache_aval)
            tok_sh = _divisible_sharding(rules, ("batch", None),
                                         inputs["tokens"])
            jitted = jax.jit(model.decode_step,
                             in_shardings=(params_sh, tok_sh, cache_sh),
                             out_shardings=(None, cache_sh),
                             donate_argnums=(2,) if donate else ())
            lowered = jitted.lower(params_aval, inputs["tokens"], cache_aval)
    return lowered, mesh, cfg, model_flops


def _raw_quantities(arch, shape, multi_pod, cfg_overrides,
                    rules_preset="tp") -> dict:
    """Per-device HLO flops / bytes / collective-bytes of one unrolled build."""
    lowered, mesh, _, _ = lower_cell(arch, shape, multi_pod, unroll=True,
                                     cfg_overrides=cfg_overrides,
                                     rules_preset=rules_preset)
    compiled = lowered.compile()
    ca = compiled.cost_analysis()
    coll = hlo_analysis.collective_bytes(compiled.as_text())
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "coll_bytes": coll.total_bytes,
            "coll_counts": coll.counts,
            "coll_by_kind": coll.bytes_by_kind}


def analysis_report(arch, shape, multi_pod, cfg, model_flops,
                    cfg_overrides=None, rules_preset="tp") -> dict:
    """True per-step totals for the roofline.

    Shallow models: one unrolled build at full depth.  Deep models: per-
    step HLO totals are exactly linear in stack depth for a homogeneous
    stack (layer work, grad psums and optimizer update all scale with L;
    embed/loss/head are the intercept), so we compile unrolled builds at
    two small depths and extrapolate — granite-88L analyses in ~2 min
    instead of ~30.  Analytic inner-loop corrections are added at full
    depth afterwards.
    """
    import dataclasses as _dc
    cell = SHAPE_CELLS[shape]
    base_over = dict(cfg_overrides or {})
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dense = cfg.first_dense_layers if cfg.n_experts else 0

    if cfg.n_layers <= 12:
        q = _raw_quantities(arch, shape, multi_pod, base_over, rules_preset)
    else:
        l1, l2 = n_dense + 2, n_dense + 8
        q1 = _raw_quantities(arch, shape, multi_pod,
                             {**base_over, "n_layers": l1}, rules_preset)
        q2 = _raw_quantities(arch, shape, multi_pod,
                             {**base_over, "n_layers": l2}, rules_preset)
        L = cfg.n_layers

        def lin(a, b):
            return a + (b - a) / (l2 - l1) * (L - l1)

        q = {"flops": lin(q1["flops"], q2["flops"]),
             "bytes": lin(q1["bytes"], q2["bytes"]),
             "coll_bytes": lin(q1["coll_bytes"], q2["coll_bytes"]),
             "coll_counts": {k: int(lin(q1["coll_counts"].get(k, 0),
                                        q2["coll_counts"].get(k, 0)))
                             for k in set(q1["coll_counts"])
                             | set(q2["coll_counts"])},
             "coll_by_kind": {k: lin(q1["coll_by_kind"].get(k, 0.0),
                                     q2["coll_by_kind"].get(k, 0.0))
                              for k in set(q1["coll_by_kind"])
                              | set(q2["coll_by_kind"])}}

    corr = hlo_analysis.inner_corrections(cfg, cell.kind, cell.global_batch,
                                          cell.seq_len)
    n_dev = mesh.devices.size
    roof = hlo_analysis.Roofline(
        flops=q["flops"] + corr["flops"] / n_dev,
        bytes_accessed=q["bytes"] + corr["bytes"] / n_dev,
        coll_bytes_per_dev=q["coll_bytes"],
        n_devices=n_dev, model_flops=model_flops)
    return {"roofline": roof.as_dict(),
            "hlo_flops_raw": q["flops"],
            "correction_flops": corr["flops"] / n_dev,
            "collectives": {"counts": q["coll_counts"],
                            "bytes_by_kind": q["coll_by_kind"]}}


def run_cell(arch: str, shape: str, multi_pod: bool,
             analyze: bool = True, cfg_overrides: dict | None = None,
             rules_preset: str = "tp") -> dict:
    """Production (scan) build: compile proof + memory analysis.
    Analysis (unrolled) build: true flops/bytes/collectives -> roofline."""
    t0 = time.perf_counter()
    out = {"arch": arch, "shape": shape,
           "mesh": "2x16x16" if multi_pod else "16x16"}
    cell = SHAPE_CELLS[shape]
    try:
        res = lower_cell(arch, shape, multi_pod,
                         cfg_overrides=cfg_overrides,
                         rules_preset=rules_preset)
        if res[0] == "skip":
            out.update(status="skip", reason=res[1])
            return out
        lowered, mesh, cfg, model_flops = res
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower
        ma = compiled.memory_analysis()
        out.update(
            status="ok", lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            memory={
                "argument_bytes_per_dev": ma.argument_size_in_bytes,
                "output_bytes_per_dev": ma.output_size_in_bytes,
                "temp_bytes_per_dev": ma.temp_size_in_bytes,
                "peak_bytes_per_dev": (ma.argument_size_in_bytes
                                       + ma.temp_size_in_bytes),
            })
        if analyze:
            t1 = time.perf_counter()
            report = analysis_report(arch, shape, multi_pod, cfg,
                                      model_flops, cfg_overrides,
                                      rules_preset)
            out.update(analysis_compile_s=round(time.perf_counter() - t1, 2),
                       **report)
            if cell.kind == "decode":
                # bandwidth floor: params + cache must stream once/token.
                model = Model.from_config(cfg)
                p_bytes = sum(a.size * a.dtype.itemsize for a in
                              jax.tree.leaves(model.abstract_params()))
                c_bytes = sum(a.size * a.dtype.itemsize for a in jax.tree.leaves(
                    jax.eval_shape(lambda: model.init_cache(
                        cell.global_batch, cell.seq_len))))
                n_dev = mesh.devices.size
                floor = (p_bytes + c_bytes) / n_dev
                actual = out["roofline"]["bytes_accessed"]
                out["decode_bandwidth"] = {
                    "floor_bytes_per_dev": floor,
                    "actual_bytes_per_dev": actual,
                    "bandwidth_efficiency": floor / max(actual, 1.0),
                    "floor_latency_s": floor / hlo_analysis.HBM_BW,
                }
    except Exception as e:  # a cell failure is a bug — surface it loudly
        out.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
    return out


def run_amper_cell(multi_pod: bool, table_log2: int = 28,
                   batch: int = 65536) -> dict:
    """The paper's own workload at scale: sharded AMPER-fr sampling."""
    from repro.core.amper import AmperConfig
    from repro.core import sharded as shc
    out = {"arch": "amper-replay", "shape": f"sample_2^{table_log2}",
           "mesh": "2x16x16" if multi_pod else "16x16"}
    t0 = time.perf_counter()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        n = 1 << table_log2
        axes = [a for a in ("pod", "data") if a in mesh.axis_names]
        cfg = AmperConfig(capacity=n, m=20, lam_fr=2.0,
                          csp_capacity=int(n * 0.15))
        fn = shc.sharded_sample_fr(mesh, cfg, batch, axis_names=axes)
        spec = P(tuple(axes))
        pq = jax.ShapeDtypeStruct((n,), jnp.int32,
                                  sharding=NamedSharding(mesh, spec))
        valid = jax.ShapeDtypeStruct((n,), jnp.bool_,
                                     sharding=NamedSharding(mesh, spec))
        key = jax.ShapeDtypeStruct((2,), jnp.uint32)
        with mesh:
            lowered = jax.jit(fn).lower(pq, valid, key)
            compiled = lowered.compile()
        report = hlo_analysis.analyze(compiled, mesh, model_flops=None)
        out.update(status="ok", compile_s=round(time.perf_counter() - t0, 2), **report)
    except Exception as e:
        out.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--amper", action="store_true",
                    help="also dry-run the sharded AMPER sampler")
    ap.add_argument("--rules", default="tp", choices=["tp", "fsdp"],
                    help="train sharding preset (hillclimb knob)")
    ap.add_argument("--set", action="append", default=[],
                    help="cfg override key=value (repeatable), e.g. "
                         "--set param_dtype=bfloat16 --set ce_block=4096")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        try:
            v = eval(v)  # ints/floats/bools/tuples
        except Exception:
            pass
        overrides[k] = v

    cells = []
    if args.all:
        archs, shapes = list(ARCH_IDS), list(SHAPE_CELLS)
    else:
        archs = [args.arch] if args.arch else list(ARCH_IDS)
        shapes = [args.shape] if args.shape else list(SHAPE_CELLS)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    results = []
    for mp in meshes:
        for arch in archs:
            for shape in shapes:
                r = run_cell(arch, shape, mp, cfg_overrides=overrides or None,
                             rules_preset=args.rules)
                results.append(r)
                roof = r.get("roofline", {})
                print(f"[{r['mesh']}] {arch} x {shape}: {r['status']}"
                      + (f" bottleneck={roof.get('bottleneck')}"
                         f" frac={roof.get('roofline_fraction')}"
                         if r["status"] == "ok" else
                         f" ({r.get('reason', r.get('error'))})"),
                      flush=True)
        if args.amper:
            r = run_amper_cell(mp)
            results.append(r)
            print(f"[{r['mesh']}] amper-replay: {r['status']}", flush=True)

    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1, default=str)
        print(f"wrote {args.out}")
    n_err = sum(r["status"] == "error" for r in results)
    print(f"done: {len(results)} cells, {n_err} errors")
    return 1 if n_err else 0


if __name__ == "__main__":
    raise SystemExit(main())
