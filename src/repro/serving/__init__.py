"""Serving: batched prefill/decode engine over the model zoo's caches."""
from repro.serving.engine import Engine, GenerationResult

__all__ = ["Engine", "GenerationResult"]
