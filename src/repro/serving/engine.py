"""Serving engine: batched prefill + decode with pluggable token choice.

Wraps a ``Model`` with the full generation loop used by launch/serve.py
and the serving examples.  The decode loop is jit-per-step (cache
donated, so the ring of buffers never copies); ``generate`` also exposes
greedy / temperature sampling and an early-stop token.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.model_api import Model


class GenerationResult(NamedTuple):
    tokens: jax.Array      # [B, gen_len]
    logits_last: jax.Array  # [B, V] logits of the final step
    cache: Any


class Engine:
    def __init__(self, model: Model, params: Any):
        self.model = model
        self.params = params
        self._decode = jax.jit(model.decode_step, donate_argnums=2)

    def prefill(self, batch: dict, max_len: int):
        """Prompt batch -> (next-token logits [B,V], cache)."""
        return jax.jit(
            functools.partial(self.model.prefill, max_len=max_len)
        )(self.params, batch)

    def generate(self, batch: dict, gen_len: int, *,
                 temperature: float = 0.0,
                 key: Optional[jax.Array] = None,
                 stop_token: Optional[int] = None) -> GenerationResult:
        max_len = batch["tokens"].shape[1] + gen_len + 1
        if self.model.cfg.vis_prefix_len:
            max_len += self.model.cfg.vis_prefix_len
        logits, cache = self.prefill(batch, max_len)
        B = batch["tokens"].shape[0]
        tok = self._choose(logits.reshape(B, -1), temperature, key, 0)
        out = [tok]
        done = jnp.zeros((B,), jnp.bool_)
        for i in range(gen_len - 1):
            logits, cache = self._decode(self.params, tok, cache)
            nxt = self._choose(logits[:, -1], temperature, key, i + 1)
            if stop_token is not None:
                done = done | (tok[:, 0] == stop_token)
                nxt = jnp.where(done[:, None], tok, nxt)
            tok = nxt
            out.append(tok)
        return GenerationResult(tokens=jnp.concatenate(out, axis=1),
                                logits_last=logits[:, -1], cache=cache)

    @staticmethod
    def _choose(logits: jax.Array, temperature: float,
                key: Optional[jax.Array], step: int) -> jax.Array:
        if temperature <= 0.0 or key is None:
            tok = jnp.argmax(logits, axis=-1)
        else:
            tok = jax.random.categorical(
                jax.random.fold_in(key, step), logits / temperature, axis=-1)
        return tok.reshape(-1, 1).astype(jnp.int32)
