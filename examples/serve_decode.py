"""Serving example: batched prefill + decode for any zoo architecture.

Exercises the real KV-cache serving path (dense / SWA / MLA-latent /
SSM-state caches are chosen by the arch automatically).

Run:  PYTHONPATH=src python examples/serve_decode.py --arch hymba-1.5b
      PYTHONPATH=src python examples/serve_decode.py --arch rwkv6-7b
"""
import argparse
import sys

from repro.launch.serve import main as serve_main

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="stablelm-1.6b")
ap.add_argument("--batch", type=int, default=4)
ap.add_argument("--gen", type=int, default=32)
args = ap.parse_args()

sys.exit(serve_main(["--arch", args.arch, "--reduced",
                     "--batch", str(args.batch), "--prompt-len", "32",
                     "--gen", str(args.gen)]))
