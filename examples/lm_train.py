"""End-to-end driver: LM training with AMPER-prioritized sequence replay.

Thin wrapper over the production launcher (repro.launch.train) — train a
reduced-config model for a few hundred steps with checkpointing; kill it
mid-run and rerun to watch it resume exactly.

Run:  PYTHONPATH=src python examples/lm_train.py --steps 200
Full-size configs: drop --reduced (needs a real accelerator).
"""
import argparse
import sys

from repro.launch.train import main as train_main

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="stablelm-1.6b")
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--sampler", default="amper-fr")
ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_train")
ap.add_argument("--full", action="store_true")
args = ap.parse_args()

argv = ["--arch", args.arch, "--steps", str(args.steps),
        "--sampler", args.sampler, "--ckpt-dir", args.ckpt_dir,
        "--batch", "8", "--seq-len", "128", "--ckpt-every", "50"]
if not args.full:
    argv.append("--reduced")
sys.exit(train_main(argv))
