"""Paper reproduction example: DQN on CartPole with every replay sampler.

Trains four agents (uniform / PER / AMPER-k / AMPER-fr) for --steps scan
iterations and prints train/test scores — Fig. 8(c) + Table 1 at laptop
scale.  With --num-envs N each iteration steps N environments in lockstep
and writes N transitions into the replay ring in one batched scatter, so
frames = steps * num_envs.

Run:  PYTHONPATH=src python examples/dqn_cartpole.py --steps 6000
      PYTHONPATH=src python examples/dqn_cartpole.py --num-envs 16
"""
import argparse
import time

import jax

from repro.rl.dqn import DQNConfig, make_dqn
from repro.rl.envs import available_envs

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=6000)
ap.add_argument("--env", default="cartpole", choices=available_envs())
ap.add_argument("--agent", default="dqn",
                choices=("dqn", "double", "dueling", "double-dueling"),
                help="agent variant (Q-head x target rule)")
ap.add_argument("--n-step", type=int, default=1,
                help="n-step return horizon")
ap.add_argument("--num-envs", type=int, default=1,
                help="parallel environments per iteration")
ap.add_argument("--replay", type=int, default=2000)
ap.add_argument("--seed", type=int, default=0)
args = ap.parse_args()

frames = args.steps * args.num_envs
print(f"agent={args.agent} n_step={args.n_step}")
print(f"{'sampler':14s} {'train(last64)':>14s} {'test(10ep)':>11s} "
      f"{'sec':>6s} {'frames/s':>9s}")
for sampler in ("uniform", "per-sumtree", "amper-k", "amper-fr"):
    cfg = DQNConfig(env=args.env, sampler=sampler, agent=args.agent,
                    n_step=args.n_step, replay_size=args.replay,
                    num_envs=args.num_envs,
                    eps_decay_steps=args.steps // 2, learn_start=200)
    dqn = make_dqn(cfg)
    key = jax.random.key(args.seed)
    # AOT-compile so trace/compile cost stays out of the frames/s column
    train_c = dqn.train.lower(key, args.steps).compile()
    t0 = time.perf_counter()
    state, metrics = train_c(key)
    jax.block_until_ready(state)
    dt = time.perf_counter() - t0
    test = float(dqn.evaluate(state, jax.random.key(args.seed + 100), 10))
    print(f"{sampler:14s} {float(metrics['return_mean'][-1]):14.1f} "
          f"{test:11.1f} {dt:6.1f} {frames / dt:9.0f}")
