"""Paper reproduction example: DQN on CartPole with every replay sampler.

Trains four agents (uniform / PER / AMPER-k / AMPER-fr) for --steps env
steps and prints train/test scores — Fig. 8(c) + Table 1 at laptop scale.

Run:  PYTHONPATH=src python examples/dqn_cartpole.py --steps 6000
"""
import argparse
import time

import jax

from repro.rl.dqn import DQNConfig, make_dqn

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=6000)
ap.add_argument("--env", default="cartpole", choices=["cartpole", "acrobot"])
ap.add_argument("--replay", type=int, default=2000)
ap.add_argument("--seed", type=int, default=0)
args = ap.parse_args()

print(f"{'sampler':14s} {'train(last64)':>14s} {'test(10ep)':>11s} {'sec':>6s}")
for sampler in ("uniform", "per-sumtree", "amper-k", "amper-fr"):
    cfg = DQNConfig(env=args.env, sampler=sampler, replay_size=args.replay,
                    eps_decay_steps=args.steps // 2, learn_start=200)
    _, _, train, evaluate = make_dqn(cfg)
    t0 = time.time()
    state, metrics = train(jax.random.key(args.seed), args.steps)
    test = float(evaluate(state, jax.random.key(args.seed + 100), 10))
    print(f"{sampler:14s} {float(metrics['return_mean'][-1]):14.1f} "
          f"{test:11.1f} {time.time() - t0:6.1f}")
