"""Quickstart: AMPER in 60 seconds.

1. Build a priority table, sample with PER and both AMPER variants,
   compare the sampled distributions (the Fig. 7 experiment in miniature).
2. Plug AMPER-fr into a replay buffer and run the store/sample/update
   cycle of Fig. 1.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core.amper import AmperConfig, AmperSampler
from repro.core.per import CumsumPER
from repro.core.replay_buffer import ReplayBuffer

N, BATCH = 10_000, 64
key = jax.random.key(0)
priorities = jax.random.uniform(key, (N,))  # the paper's U[0,1] table

# --- 1. sampling comparison -------------------------------------------------
per = CumsumPER(N)
per_state = per.update(per.init(), jnp.arange(N), priorities)

cfg = AmperConfig(capacity=N, m=20, lam_fr=2.0, v_max=1.0,
                  csp_capacity=1500, knn_mode="bisect")
print(f"{'sampler':12s} {'mean sampled priority':>22s}   (buffer mean "
      f"{float(priorities.mean()):.3f}, ideal PER {2/3:.3f})")
idx = per.sample(per_state, key, 4096)
print(f"{'PER':12s} {float(priorities[idx].mean()):22.3f}")
for variant in ("fr", "k"):
    amp = AmperSampler(cfg, variant)
    st = amp.update(amp.init(), jnp.arange(N), priorities)
    idx = jax.jit(lambda k: amp.sample(st, k, 4096))(key)
    print(f"{'AMPER-' + variant:12s} {float(priorities[idx].mean()):22.3f}")

# --- 2. replay buffer cycle --------------------------------------------------
rb = ReplayBuffer(1024, AmperSampler(cfg._replace(capacity=1024,
                                                  csp_capacity=256), "fr"))
tr = {"obs": jnp.zeros(4), "action": jnp.int32(0), "reward": jnp.float32(0.0)}
state = rb.init(tr)
add = jax.jit(rb.add)
for i in range(256):
    state = add(state, {"obs": jnp.full(4, i, jnp.float32),
                        "action": jnp.int32(i % 2),
                        "reward": jnp.float32(i)})
idx, batch, w = rb.sample(state, key, BATCH)
state = rb.update_priorities(state, idx, batch["reward"] / 256.0)
print(f"\nreplay cycle ok: sampled {BATCH} transitions, "
      f"mean reward {float(batch['reward'].mean()):.1f}, "
      f"priorities updated (max_p={float(state.max_priority):.3f})")
