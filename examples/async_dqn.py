"""Async actor–learner DQN via the pipelined `ReplayService`.

Actors, the priority sampler (prefetching batch N+1 while the learner
consumes batch N), and the learner run as overlapped pipeline stages;
TD-error priority feedback flows back to the sampler out-of-band with
write-stamp staleness guards.  `--sync` degrades to the strict
synchronous mode (the scan trainer's iteration, step by step) for an
apples-to-apples learner-steps/sec comparison.

With ``--ckpt-dir`` the service checkpoints the whole replay stack
(params, optimizer, buffer + sampler state, per-actor env states and
PRNG stream positions) via the pause->drain->snapshot->resume protocol,
flushes a final snapshot on SIGTERM (or a ``PREEMPT`` sentinel file in
the directory), and AUTO-RESUMES from the latest checkpoint on relaunch
— kill this script mid-run and rerun the same command to continue.

Run:  PYTHONPATH=src python examples/async_dqn.py --steps 2000
      PYTHONPATH=src python examples/async_dqn.py --sampler per-sumtree --sync
      PYTHONPATH=src python examples/async_dqn.py --ckpt-dir /tmp/run1
      PYTHONPATH=src python examples/async_dqn.py --metrics-out /tmp/run1.jsonl
"""
import argparse

import jax

from repro.obs import Telemetry
from repro.rl.dqn import DQNConfig
from repro.rl.envs import available_envs
from repro.runtime import ReplayService
from repro.train.checkpoint import CheckpointManager

ap = argparse.ArgumentParser()
ap.add_argument("--env", default="cartpole", choices=available_envs())
ap.add_argument("--sampler", default="amper-fr",
                help="any repro.core.samplers registry name")
ap.add_argument("--agent", default="dqn",
                choices=("dqn", "double", "dueling", "double-dueling"),
                help="agent variant (Q-head x target rule)")
ap.add_argument("--n-step", type=int, default=1,
                help="n-step return horizon (each actor aggregates its "
                     "own stream)")
ap.add_argument("--steps", type=int, default=2000,
                help="learner steps (scan iterations with --sync)")
ap.add_argument("--num-envs", type=int, default=16,
                help="environments per actor")
ap.add_argument("--actors", type=int, default=1, help="actor threads")
ap.add_argument("--chunk", type=int, default=32,
                help="env steps per actor rollout chunk")
ap.add_argument("--slab", type=int, default=8,
                help="batches per prefetch draw / fused learner call")
ap.add_argument("--replay", type=int, default=4000)
ap.add_argument("--sync", action="store_true",
                help="strict synchronous mode (baseline)")
ap.add_argument("--seed", type=int, default=0)
ap.add_argument("--ckpt-dir", default=None,
                help="checkpoint directory (enables snapshot/auto-resume)")
ap.add_argument("--ckpt-every", type=int, default=500,
                help="learner steps between snapshots")
ap.add_argument("--beta-end", type=float, default=None,
                help="anneal the PER IS exponent to this value (e.g. 1.0)")
ap.add_argument("--metrics-out", default=None,
                help="write telemetry (JSONL event log + replay-health "
                     "probes) to this path; Prometheus text lands next "
                     "to it as <path>.prom")
args = ap.parse_args()

REPLAY_RATIO = 4  # frames per learner step, in units of num_envs

# eps decays per actor ITERATION: in async mode actors run ~REPLAY_RATIO
# iterations per learner step, so scale the decay horizon to keep the
# exploration schedule comparable with the --sync baseline.
decay = max(args.steps // 2, 1) * (1 if args.sync else REPLAY_RATIO)
# β anneals in LEARNER steps (the unit beta_at is evaluated in, sync or
# async), so its horizon is --steps — NOT the frame-scaled eps decay.
cfg = DQNConfig(env=args.env, sampler=args.sampler, agent=args.agent,
                n_step=args.n_step, num_envs=args.num_envs,
                replay_size=args.replay, learn_start=50,
                eps_decay_steps=decay, target_sync=100, v_max=8.0,
                beta_end=args.beta_end,
                beta_anneal_steps=args.steps if args.beta_end else None)
tel = (Telemetry(metrics_out=args.metrics_out,
                 prometheus_out=args.metrics_out + ".prom")
       if args.metrics_out else None)
svc = ReplayService(cfg, sync=args.sync,
                    num_actors=1 if args.sync else args.actors,
                    chunk_len=args.chunk, slab=args.slab,
                    max_replay_ratio=REPLAY_RATIO * args.num_envs,
                    telemetry=tel)
key = jax.random.key(args.seed)
manager = (CheckpointManager(args.ckpt_dir, keep=3,
                             save_interval=args.ckpt_every)
           if args.ckpt_dir else None)
if manager is None:
    svc.run(key, 60 if args.sync else 2 * args.slab)   # compile warmup
res = svc.run(key, args.steps, manager=manager)
if manager is not None and res.metrics.get("preempted_at") is not None:
    print(f"preempted: snapshot flushed at step "
          f"{res.metrics['preempted_at']}; rerun to resume")
m = res.metrics
print(f"mode={m['mode']} sampler={args.sampler} env={args.env}")
print(f"learner steps/s = {m['learner_steps_per_sec']:8.0f}   "
      f"({m['learner_steps']} steps, wall {m['wall_time']:.1f}s)")
print(f"env frames/s    = {m['frames_per_sec']:8.0f}   "
      f"({m['frames']} frames)")
if m["mode"] == "async":
    st, qd = m["staleness"], m["queue_depth"]
    print(f"priority staleness: mean={st['mean']:.1f} max={st['max']} "
          f"learner steps behind")
    print(f"queue depth (mean): blocks+feedback={qd['work_mean']:.2f} "
          f"batch slabs={qd['batch_mean']:.2f}")
print(f"train return_mean = {m['return_mean']:.1f}")
test = float(svc.dqn.evaluate(res.params, jax.random.key(args.seed + 100), 10))
print(f"test(10ep)        = {test:.1f}")
if args.metrics_out:
    print(f"telemetry: {args.metrics_out} (+ .prom); inspect with "
          f"`python -m repro.obs.report {args.metrics_out}`")
