"""Benchmark harness: one module per paper table/figure.

``python -m benchmarks.run [--quick]`` runs every benchmark, prints
``name,us_per_call,derived`` CSV rows (plus human-readable logs), and
persists each section's rows as machine-readable ``BENCH_<section>.json``
(see :func:`benchmarks.common.write_bench_json`) so the perf trajectory
is recorded across commits.  Roofline tables come from the dry-run
artifacts: see benchmarks/roofline.py and EXPERIMENTS.md.

The ``sharded`` section runs in a subprocess: it must force 8 host
devices via XLA_FLAGS before first jax init, which this parent process
has already performed by the time the section runs.
"""
from __future__ import annotations

import argparse
import contextlib
import os
import subprocess
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller sizes / fewer seeds")
    ap.add_argument("--only", default=None,
                    help="comma list: fig4,fig7,fig9,table1,samplers,"
                         "sampling,venv,sharded,runtime,replay,storage")
    ap.add_argument("--out", default=".",
                    help="directory for the BENCH_*.json artifacts")
    ap.add_argument("--profile", action="store_true",
                    help="wrap each benched section in jax.profiler.trace; "
                         "traces land under <out>/profile/<section>")
    ap.add_argument("--metrics-out", default=None,
                    help="telemetry JSONL path: enables the repro.obs "
                         "registry for the whole run and writes one "
                         "snapshot per section (spans, counters) there")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None
    failures = []
    written = []

    from benchmarks import common

    exporter = registry = None
    if args.metrics_out:
        from repro import obs

        registry = obs.Registry(enabled=True)
        obs.set_registry(registry)
        exporter = obs.JsonlExporter(args.metrics_out)

    def section(name, fn):
        if only and name not in only:
            return
        print(f"\n=== {name} ===", flush=True)
        try:
            if args.profile:
                import jax

                trace_dir = os.path.join(args.out, "profile", name)
                print(f"profiler trace -> {trace_dir}", flush=True)
                ctx = jax.profiler.trace(trace_dir)
            else:
                ctx = contextlib.nullcontext()
            with ctx:
                rows = fn()
        except Exception:
            failures.append(name)
            traceback.print_exc()
            if exporter:
                exporter.write_event("section_failed", section=name)
            return
        if rows:
            written.append(common.write_bench_json(name, rows,
                                                   out_dir=args.out))
        if exporter:
            exporter.write_snapshot(registry.snapshot(),
                                    extra={"section": name})

    def sharded_subprocess():
        """Fresh process so XLA_FLAGS can force the 8-device host mesh."""
        json_path = os.path.join(args.out, "BENCH_sharded.json")
        cmd = [sys.executable, "-m", "benchmarks.bench_sharded",
               "--json", json_path] + (["--quick"] if args.quick else [])
        out = subprocess.run(cmd, capture_output=True, text=True,
                             timeout=1800, cwd=os.path.dirname(
                                 os.path.dirname(os.path.abspath(__file__))))
        print(out.stdout, end="")
        if out.returncode != 0:
            raise RuntimeError(f"bench_sharded failed:\n{out.stderr[-2000:]}")
        if os.path.exists(json_path):
            written.append(json_path)
        return None  # the child already wrote its own json

    from benchmarks import (bench_replay, bench_runtime, bench_samplers,
                            bench_storage, bench_vector_env, fig4_latency,
                            fig7_sampling_error, fig9_hw_latency,
                            table1_learning)

    section("fig4", lambda: fig4_latency.run(
        sizes=(1000, 10_000) if args.quick else (1000, 10_000, 100_000)))
    section("fig7", lambda: fig7_sampling_error.run(
        n=5000 if args.quick else 10_000,
        m_values=(2, 8) if args.quick else (2, 4, 8, 12)))
    if not args.quick:
        section("fig7d", fig7_sampling_error.run_sizes)
    section("fig9", fig9_hw_latency.main)
    section("table1", lambda: table1_learning.run(
        steps=4000 if args.quick else 6000,
        seeds=(0,) if args.quick else (0, 1)))
    section("samplers", lambda: bench_samplers.run(
        sizes=(10_000, 100_000) if args.quick else
        (10_000, 100_000, 1_000_000)))
    section("sampling", lambda: bench_samplers.run_sampling(
        sizes=(10_000,) if args.quick else (10_000, 100_000)))
    section("venv", lambda: bench_vector_env.run(
        widths=(1, 16) if args.quick else (1, 4, 16, 64),
        steps=1000 if args.quick else 2000))
    section("runtime", lambda: bench_runtime.run(
        steps=200 if args.quick else 400,
        trials=2 if args.quick else 3))
    # replay keeps the full 120-step service runs even in quick mode: a
    # 60-step base is ~50ms of wall, short enough that overhead_frac is
    # mostly measurement noise and checkpoint cadence artifacts.
    section("replay", lambda: bench_replay.run(
        sizes=(10_000,) if args.quick else (10_000, 100_000),
        steps=120))
    section("storage", lambda: bench_storage.run(
        sizes=(10_000,) if args.quick else (10_000, 100_000)))
    section("sharded", sharded_subprocess)

    if exporter:
        exporter.close()
        print(f"\ntelemetry JSONL: {args.metrics_out}")
    if written:
        print(f"\nBENCH artifacts: {written}")
    if failures:
        print(f"\nFAILED sections: {failures}")
        sys.exit(1)
    print("\nall benchmark sections completed")


if __name__ == "__main__":
    main()
