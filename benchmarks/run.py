"""Benchmark harness: one module per paper table/figure.

``python -m benchmarks.run [--quick]`` runs every benchmark and prints
``name,us_per_call,derived`` CSV rows (plus human-readable logs).
Roofline tables come from the dry-run artifacts: see benchmarks/roofline.py
and EXPERIMENTS.md.
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller sizes / fewer seeds")
    ap.add_argument("--only", default=None,
                    help="comma list: fig4,fig7,fig9,table1,samplers,venv")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None
    failures = []

    def section(name, fn):
        if only and name not in only:
            return
        print(f"\n=== {name} ===", flush=True)
        try:
            fn()
        except Exception:
            failures.append(name)
            traceback.print_exc()

    from benchmarks import (bench_samplers, bench_vector_env, fig4_latency,
                            fig7_sampling_error, fig9_hw_latency,
                            table1_learning)

    section("fig4", lambda: fig4_latency.run(
        sizes=(1000, 10_000) if args.quick else (1000, 10_000, 100_000)))
    section("fig7", lambda: fig7_sampling_error.run(
        n=5000 if args.quick else 10_000,
        m_values=(2, 8) if args.quick else (2, 4, 8, 12)))
    if not args.quick:
        section("fig7d", fig7_sampling_error.run_sizes)
    section("fig9", fig9_hw_latency.main)
    section("table1", lambda: table1_learning.run(
        steps=4000 if args.quick else 6000,
        seeds=(0,) if args.quick else (0, 1)))
    section("samplers", lambda: bench_samplers.run(
        sizes=(10_000, 100_000) if args.quick else
        (10_000, 100_000, 1_000_000)))
    section("venv", lambda: bench_vector_env.run(
        widths=(1, 16) if args.quick else (1, 4, 16, 64),
        steps=1000 if args.quick else 2000))

    if failures:
        print(f"\nFAILED sections: {failures}")
        sys.exit(1)
    print("\nall benchmark sections completed")


if __name__ == "__main__":
    main()
