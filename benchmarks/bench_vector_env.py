"""Actor-pipeline throughput: frames/sec vs num_envs.

Runs the FULL batched DQN pipeline (vectorized env step, batched ring
write, priority sampling, TD update) for a fixed number of scan
iterations at several env-batch widths and reports environment frames
per second.  The claim under test: because every per-iteration cost
except the env fan-out is width-independent (one net forward, one
64-batch train step, one batched scatter of B priorities), frames/sec
scales nearly linearly with num_envs until the env math itself
saturates the core — the throughput unlock of the vectorized actor
refactor.
"""
from __future__ import annotations

import argparse
import time

import jax

from benchmarks.common import csv_row
from repro.rl.dqn import DQNConfig, make_dqn


def run(env: str = "cartpole", sampler: str = "amper-fr",
        widths=(1, 4, 16), steps: int = 2000, replay: int = 2000,
        verbose: bool = True):
    rows = []
    for num_envs in widths:
        cfg = DQNConfig(env=env, sampler=sampler, replay_size=replay,
                        num_envs=num_envs, eps_decay_steps=steps // 2,
                        learn_start=200)
        dqn = make_dqn(cfg)
        key = jax.random.key(0)
        train_c = dqn.train.lower(key, steps).compile()  # AOT: no warm-up run
        t0 = time.perf_counter()
        state, _ = train_c(key)
        jax.block_until_ready(state)
        dt = time.perf_counter() - t0
        fps = steps * num_envs / dt
        rows.append({"num_envs": num_envs, "fps": fps, "sec": dt})
        if verbose:
            speedup = fps / rows[0]["fps"]
            print(f"venv {env}/{sampler} num_envs={num_envs:4d} "
                  f"frames/s={fps:10.0f}  ({speedup:4.1f}x vs 1 env)")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--env", default="cartpole")
    ap.add_argument("--sampler", default="amper-fr")
    ap.add_argument("--steps", type=int, default=2000)
    ap.add_argument("--widths", default="1,4,16,64")
    args = ap.parse_args()
    widths = tuple(int(w) for w in args.widths.split(","))
    rows = run(args.env, args.sampler, widths=widths, steps=args.steps)
    for r in rows:
        print(csv_row(f"venv/{args.env}/{args.sampler}/B{r['num_envs']}",
                      r["sec"] * 1e6 / args.steps,
                      f"frames_per_sec={r['fps']:.0f}"))
    # Acceptance: >=4x frames/sec at 16 envs vs 1 on CPU.
    by_width = {r["num_envs"]: r["fps"] for r in rows}
    if 1 in by_width and 16 in by_width:
        assert by_width[16] > 4 * by_width[1], by_width


if __name__ == "__main__":
    main()
