"""Roofline table formatter: reads dry-run cell JSONs -> markdown/CSV.

Usage:
  PYTHONPATH=src python -m benchmarks.roofline results/cells/*.json
  PYTHONPATH=src python -m benchmarks.roofline --md results/cells/*.json
"""
from __future__ import annotations

import argparse
import glob
import json
import sys


def load(paths):
    rows = []
    for p in paths:
        for pat in glob.glob(p):
            with open(pat) as f:
                rows.extend(json.load(f))
    return rows


def fmt_seconds(x):
    if x is None:
        return "-"
    if x >= 1:
        return f"{x:.2f}s"
    return f"{x * 1e3:.1f}ms"


def table(rows, markdown=False):
    hdr = ["arch", "shape", "mesh", "status", "t_comp", "t_mem", "t_coll",
           "bottleneck", "useful", "roof_frac", "peakGB/dev"]
    out = []
    for r in sorted(rows, key=lambda r: (r["mesh"], r["arch"], r["shape"])):
        roof = r.get("roofline", {})
        mem = r.get("memory", {})
        if r["status"] == "ok":
            out.append([
                r["arch"], r["shape"], r["mesh"], "ok",
                fmt_seconds(roof.get("t_compute_s")),
                fmt_seconds(roof.get("t_memory_s")),
                fmt_seconds(roof.get("t_collective_s")),
                roof.get("bottleneck", "-"),
                f"{roof['useful_flop_ratio']:.2f}" if roof.get("useful_flop_ratio") else "-",
                f"{roof['roofline_fraction']:.3f}" if roof.get("roofline_fraction") else "-",
                f"{mem.get('peak_bytes_per_dev', 0) / 1e9:.1f}",
            ])
        else:
            out.append([r["arch"], r["shape"], r["mesh"], r["status"],
                        "-", "-", "-", "-", "-", "-",
                        r.get("reason", r.get("error", ""))[:40]])
    if markdown:
        lines = ["| " + " | ".join(hdr) + " |",
                 "|" + "---|" * len(hdr)]
        lines += ["| " + " | ".join(str(c) for c in row) + " |" for row in out]
        return "\n".join(lines)
    w = [max(len(str(r[i])) for r in [hdr] + out) for i in range(len(hdr))]
    lines = ["  ".join(h.ljust(w[i]) for i, h in enumerate(hdr))]
    lines += ["  ".join(str(c).ljust(w[i]) for i, c in enumerate(row))
              for row in out]
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("paths", nargs="+")
    ap.add_argument("--md", action="store_true")
    args = ap.parse_args()
    rows = load(args.paths)
    print(table(rows, markdown=args.md))
    ok = sum(r["status"] == "ok" for r in rows)
    skip = sum(r["status"] == "skip" for r in rows)
    err = sum(r["status"] == "error" for r in rows)
    print(f"\n{ok} ok / {skip} skip / {err} error", file=sys.stderr)


if __name__ == "__main__":
    main()
