"""Fig. 9 / Table 2 reproduction: AM-hardware latency model + speedups.

Regenerates the paper's end-to-end accelerator latencies from the
Table 2 component delays (we have no TCAM silicon; the analytical model
follows the Fig. 6(a) dataflow exactly) and reproduces:

  * Fig. 9(b): latency ~flat in group number m (search is parallel);
  * Fig. 9(c): latency linear in CSP ratio (CSB write throughput bound);
  * AMPER-fr ~2x faster than AMPER-k (sensing + per-group searches);
  * Fig. 9(a): 55x-270x speedup over a software PER baseline — the
    paper's GPU reference latencies are re-used for the headline, and we
    also report the speedup against OUR measured sum-tree PER on this
    host, which is the honest hardware-free comparison.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import csv_row, time_fn
from repro.core import hwmodel
from repro.core.per import SumTreePER

# The paper reports speedup BANDS, not raw GPU latencies: 55x-170x for
# AMPER-k and 118x-270x for AMPER-fr over sizes 5k/10k/20k.  Inverting
# our Table-2 latency model against those bands recovers the implied GPU
# per-batch sampling latencies below (~0.1-0.7 ms, plausible for sum-tree
# PER on a GTX-1080) — an internal-consistency check of the paper.
PAPER_GPU_US = {5000: 100.0, 10_000: 250.0, 20_000: 700.0}
PAPER_BANDS = {"k": (55.0, 170.0), "fr": (118.0, 270.0)}


def measured_per_us(size: int, batch: int = 64) -> float:
    per = SumTreePER(size)
    state = per.update(per.init(), jnp.arange(size),
                       jax.random.uniform(jax.random.key(0), (size,)) + 0.1)
    sample = jax.jit(lambda s, k: per.sample(s, k, batch))
    update = jax.jit(per.update)
    t = time_fn(sample, state, jax.random.key(1))
    t += time_fn(update, state, jnp.arange(batch, dtype=jnp.int32),
                 jnp.ones(batch))
    return t


def run(verbose: bool = True):
    rows = []
    # --- Fig 9(a): speedups at m=20, csp_ratio=0.15 ---
    for size, gpu_us in PAPER_GPU_US.items():
        cfg = hwmodel.HwConfig(er_size=size, m=20, csp_ratio=0.15)
        fr_ns = hwmodel.latency_fr_ns(cfg)
        k_ns = hwmodel.latency_k_ns(cfg)
        cpu_us = measured_per_us(size)
        row = {
            "size": size, "fr_us": fr_ns / 1e3, "k_us": k_ns / 1e3,
            "speedup_fr_vs_paper_gpu": gpu_us * 1e3 / fr_ns,
            "speedup_k_vs_paper_gpu": gpu_us * 1e3 / k_ns,
            "speedup_fr_vs_our_cpu": cpu_us * 1e3 / fr_ns,
        }
        rows.append(row)
        if verbose:
            print(f"fig9a size={size:6d} AMPER-fr={row['fr_us']:8.2f}us "
                  f"AMPER-k={row['k_us']:8.2f}us "
                  f"speedup(fr) vs paper-GPU={row['speedup_fr_vs_paper_gpu']:6.0f}x "
                  f"vs our-CPU={row['speedup_fr_vs_our_cpu']:6.0f}x")

    # --- Fig 9(b): vary m at fixed CSP ratio ---
    for m in (4, 8, 12, 16, 20):
        cfg = hwmodel.HwConfig(er_size=10_000, m=m, csp_ratio=0.15)
        if verbose:
            print(f"fig9b m={m:3d} fr={hwmodel.latency_fr_ns(cfg)/1e3:7.2f}us "
                  f"k={hwmodel.latency_k_ns(cfg)/1e3:7.2f}us")

    # --- Fig 9(c): vary CSP ratio at fixed m ---
    for ratio in (0.03, 0.06, 0.09, 0.12, 0.15):
        cfg = hwmodel.HwConfig(er_size=10_000, m=20, csp_ratio=ratio)
        if verbose:
            print(f"fig9c ratio={ratio:.2f} "
                  f"fr={hwmodel.latency_fr_ns(cfg)/1e3:7.2f}us "
                  f"k={hwmodel.latency_k_ns(cfg)/1e3:7.2f}us")
    return rows


def main():
    rows = run()
    # paper claims: fr is ~2x faster than k; speedups in the 55x-270x band
    fr_speeds = [r["speedup_fr_vs_paper_gpu"] for r in rows]
    k_speeds = [r["speedup_k_vs_paper_gpu"] for r in rows]
    for r in rows:
        # paper-consistent: fr ~2x faster than k (Table 2 sensing + search counts)
        assert 1.2 < r["k_us"] / r["fr_us"] < 3.0, r
    # implied-GPU speedups land inside (a tolerance of) the claimed bands
    assert min(fr_speeds) > PAPER_BANDS["fr"][0] * 0.8, fr_speeds
    assert max(fr_speeds) < PAPER_BANDS["fr"][1] * 1.5, fr_speeds
    assert min(k_speeds) > PAPER_BANDS["k"][0] * 0.8, k_speeds
    for r in rows:
        print(csv_row(f"fig9/size{r['size']}/fr", r["fr_us"],
                      f"speedup_vs_paper_gpu={r['speedup_fr_vs_paper_gpu']:.0f}x"))
        print(csv_row(f"fig9/size{r['size']}/k", r["k_us"],
                      f"speedup_vs_paper_gpu={r['speedup_k_vs_paper_gpu']:.0f}x"))
    return rows


if __name__ == "__main__":
    main()
