"""Fig. 7 reproduction: AMPER vs PER sampling-error study.

Protocol (Sec. 4.1.1): 10 000 priorities ~ U[0,1]; sample batches of 64
for 100 runs with PER, AMPER-k, AMPER-fr, uniform; compare the sampled
distributions by KL divergence (counts over items, Laplace-smoothed,
reported as total nats over the sample to match the paper's magnitudes).

Claims checked:
  (1) KL(uniform || PER)  >>  KL(AMPER || PER)  ~  KL(PER' || PER) noise;
  (2) KL decreases as m and lambda/lambda' grow (Fig. 7(b)(c));
  (3) trends hold across ER sizes 5k/10k/20k (Fig. 7(d)).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.amper import AmperConfig, AmperSampler
from repro.core.per import CumsumPER
# BINS and the Laplace-smoothed total-nats KL are shared with the live
# sampling-error gauge (repro.obs.probes.SamplingErrorMonitor) so the
# offline Fig. 7 study and the online monitor agree by construction.
from repro.obs.probes import BINS, kl_nats, priority_bin_counts

BATCH, RUNS = 64, 100


def sample_counts(sampler, state, key, prio: np.ndarray) -> np.ndarray:
    counts = np.zeros(BINS)
    fn = jax.jit(lambda s, k: sampler.sample(s, k, BATCH))
    for r in range(RUNS):
        idx = np.asarray(fn(state, jax.random.fold_in(key, r)))
        counts += priority_bin_counts(prio[idx])
    return counts


def run(n: int = 10_000, m_values=(2, 4, 8, 12), lam_values=(0.05, 0.5, 2.0),
        seed: int = 0, verbose: bool = True):
    key = jax.random.key(seed)
    prio = jax.random.uniform(jax.random.fold_in(key, 99), (n,))

    prio_np = np.asarray(prio)
    per = CumsumPER(n)
    per_state = per.update(per.init(), jnp.arange(n), prio)
    q_ref = sample_counts(per, per_state, jax.random.fold_in(key, 1), prio_np)
    q_ref2 = sample_counts(per, per_state, jax.random.fold_in(key, 2), prio_np)
    noise_floor = kl_nats(q_ref2, q_ref)

    uni = np.random.default_rng(seed).integers(0, n, BATCH * RUNS)
    uni_counts = priority_bin_counts(prio_np[uni]).astype(float)
    kl_uniform = kl_nats(uni_counts, q_ref)

    rows = []
    for variant in ("fr", "k"):
        for m in m_values:
            for lam in lam_values:
                cfg = AmperConfig(
                    capacity=n, m=m, lam=lam / 10.0, lam_fr=lam, v_max=1.0,
                    csp_capacity=max(int(0.2 * n), BATCH), knn_mode="bisect")
                s = AmperSampler(cfg, variant)
                st = s.update(s.init(), jnp.arange(n), prio)
                c = sample_counts(s, st, jax.random.fold_in(key, 7), prio_np)
                kl = kl_nats(c, q_ref)
                rows.append({"variant": variant, "m": m, "lam": lam,
                             "kl_nats": kl})
                if verbose:
                    print(f"fig7 amper-{variant} m={m:3d} lam={lam:5.2f} "
                          f"KL={kl:9.1f} nats")
    if verbose:
        print(f"fig7 reference: PER-vs-PER noise={noise_floor:.1f} nats, "
              f"uniform-vs-PER={kl_uniform:.1f} nats")
    return {"noise_floor": noise_floor, "kl_uniform": kl_uniform, "rows": rows}


def run_sizes(sizes=(5000, 10_000, 20_000), m: int = 8, lam: float = 2.0,
              seed: int = 0, verbose: bool = True):
    """Fig. 7(d): the m/CSP-ratio trends hold across ER memory sizes, and
    sampling error improves with larger ER at fixed m and CSP ratio."""
    rows = []
    for n in sizes:
        key = jax.random.key(seed)
        prio = jax.random.uniform(jax.random.fold_in(key, 99), (n,))
        prio_np = np.asarray(prio)
        per = CumsumPER(n)
        ps = per.update(per.init(), jnp.arange(n), prio)
        q_ref = sample_counts(per, ps, jax.random.fold_in(key, 1), prio_np)
        cfg = AmperConfig(capacity=n, m=m, lam=lam / 10.0, lam_fr=lam,
                          v_max=1.0, csp_capacity=max(int(0.15 * n), BATCH),
                          knn_mode="bisect")
        s = AmperSampler(cfg, "k")
        st = s.update(s.init(), jnp.arange(n), prio)
        c = sample_counts(s, st, jax.random.fold_in(key, 7), prio_np)
        kl = kl_nats(c, q_ref)
        rows.append({"n": n, "kl_nats": kl})
        if verbose:
            print(f"fig7d amper-k n={n:6d} m={m} CSP=0.15 KL={kl:9.1f} nats")
    return rows


def main():
    out = run()
    run_sizes()
    best = min(r["kl_nats"] for r in out["rows"])
    assert out["kl_uniform"] > 5 * best, "uniform should be far worse"
    print(f"fig7 summary: best AMPER KL {best:.1f} vs uniform "
          f"{out['kl_uniform']:.1f} (noise {out['noise_floor']:.1f})")


if __name__ == "__main__":
    main()
