"""Table 1 / Fig. 8 reproduction: DQN-family test scores across a
agents × samplers × envs grid.

Smoke-scale protocol (full-scale via --steps): each cell trains one
agent variant (vanilla DQN / Double DQN / Dueling DQN, optionally with
n-step returns) with one replay sampler on one env, averaged over
seeds; test score = greedy-policy return averaged over 10 episodes (the
paper's metric).  Claim: AMPER variants reach scores comparable to PER
*across the whole agent family* — Schaul et al. report PER on Double
DQN, and Predictive PER shows priority-approximation artifacts differ
by agent variant, so the single-vanilla-DQN check of the early repo was
not enough to pin the paper's learning-performance story.

Seeds run data-parallel through ``train_many`` (one compiled program,
vmapped over the seed batch) instead of a Python loop — the many-seed
sweep regime of Schaul et al. / Panahi et al. as a single XLA launch.

``run_parity`` is the acceptance gate: ``DQNConfig(agent="double",
n_step=3, sampler="amper-fr")`` must train CartPole into the same
reward regime as the exact ``per-cumsum`` baseline.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from benchmarks.common import csv_row
from repro.rl.dqn import DQNConfig, make_dqn
from repro.rl.envs import available_envs

SAMPLERS = ("per-sumtree", "amper-k", "amper-fr", "uniform")
AGENTS = ("dqn", "double", "dueling")
ENVS = ("cartpole", "acrobot", "mountaincar")

# Parity gate band (generous at smoke scale; tighten with --steps): the
# AMPER score must stay within (1 - PARITY_RATIO) * |PER score| of the
# PER score.  For positive-return envs (CartPole) this is exactly the
# classic `amper > PARITY_RATIO * per`; phrasing it as a margin keeps
# the gate meaningful on the negative-return envs (Acrobot, MountainCar),
# where a plain ratio inequality inverts.
PARITY_RATIO = 0.4


def within_parity(amper_score: float, per_score: float,
                  ratio: float = PARITY_RATIO) -> bool:
    return amper_score >= per_score - (1.0 - ratio) * abs(per_score)


def jnp_stack_keys(seeds):
    return jax.vmap(jax.random.key)(np.asarray(seeds, np.uint32))


def _cell(env, sampler, agent, n_step, steps, seeds, replay, num_envs):
    cfg = DQNConfig(env=env, sampler=sampler, agent=agent, n_step=n_step,
                    replay_size=replay, num_envs=num_envs,
                    eps_decay_steps=steps // 2, learn_start=200)
    dqn = make_dqn(cfg)
    train_keys = jnp_stack_keys(seeds)
    eval_keys = jnp_stack_keys(tuple(s + 100 for s in seeds))
    states, _ = dqn.train_many(train_keys, steps)
    scores = np.asarray(dqn.evaluate_many(states, eval_keys, 10))
    return float(scores.mean()), float(scores.std())


def run(env: str = "cartpole", steps: int = 6000, seeds=(0, 1, 2),
        replay: int = 2000, num_envs: int = 1, verbose: bool = True,
        agents=("dqn",), n_step: int = 1, samplers=SAMPLERS):
    """One env's agents × samplers grid, rows keyed ``"agent/sampler"``."""
    rows = {}
    for agent in agents:
        for sampler in samplers:
            mean, std = _cell(env, sampler, agent, n_step, steps, seeds,
                              replay, num_envs)
            rows[f"{agent}/{sampler}"] = (mean, std)
            if verbose:
                print(f"table1 {env} {agent:8s} {sampler:12s} "
                      f"test={mean:7.1f} +- {std:.1f}  "
                      f"(n_step={n_step}, seeds={list(seeds)})")
    return rows


def run_grid(envs=ENVS, agents=AGENTS, steps: int = 6000, seeds=(0, 1),
             replay: int = 2000, num_envs: int = 1, n_step: int = 1,
             verbose: bool = True):
    """The full Table-1-style grid: every env × agent × sampler cell."""
    grid = {}
    for env in envs:
        grid[env] = run(env=env, steps=steps, seeds=seeds, replay=replay,
                        num_envs=num_envs, verbose=verbose, agents=agents,
                        n_step=n_step)
    return grid


def run_parity(steps: int = 6000, seeds=(0, 1), replay: int = 2000,
               verbose: bool = True):
    """Acceptance gate: Double DQN + 3-step returns on CartPole — the
    config family PER results are reported on — reaches the same reward
    regime under AMPER-fr's piecewise-constant approximate sampling as
    under the exact per-cumsum law."""
    out = {}
    for sampler in ("per-cumsum", "amper-fr"):
        mean, std = _cell("cartpole", sampler, "double", 3, steps, seeds,
                          replay, 1)
        out[sampler] = (mean, std)
        if verbose:
            print(f"parity cartpole double/n3 {sampler:10s} "
                  f"test={mean:7.1f} +- {std:.1f}")
    assert within_parity(out["amper-fr"][0], out["per-cumsum"][0]), out
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--env", default="cartpole", choices=available_envs())
    ap.add_argument("--steps", type=int, default=6000)
    ap.add_argument("--seeds", type=int, default=2)
    ap.add_argument("--num-envs", type=int, default=1)
    ap.add_argument("--agents", default="dqn,double,dueling",
                    help="comma list of agent variants")
    ap.add_argument("--n-step", type=int, default=1)
    ap.add_argument("--grid", action="store_true",
                    help="full envs x agents x samplers grid")
    ap.add_argument("--parity", action="store_true",
                    help="run only the double/n-step AMPER-vs-PER gate")
    args = ap.parse_args()
    seeds = tuple(range(args.seeds))
    if args.parity:
        run_parity(steps=args.steps, seeds=seeds)
        return
    agents = tuple(args.agents.split(","))
    if args.grid:
        grid = run_grid(steps=args.steps, seeds=seeds, agents=agents,
                        num_envs=args.num_envs, n_step=args.n_step)
        for env, rows in grid.items():
            for k, (mean, std) in rows.items():
                print(csv_row(f"table1/{env}/{k}", 0.0,
                              f"test_score={mean:.1f}+-{std:.1f}"))
        return
    rows = run(args.env, args.steps, seeds=seeds, num_envs=args.num_envs,
               agents=agents, n_step=args.n_step)
    for k, (mean, std) in rows.items():
        print(csv_row(f"table1/{args.env}/{k}", 0.0,
                      f"test_score={mean:.1f}+-{std:.1f}"))
    # Table 1 claim: AMPER within family of PER (generous smoke-scale
    # band) for every agent variant in the run.
    for agent in agents:
        per = rows[f"{agent}/per-sumtree"][0]
        assert within_parity(rows[f"{agent}/amper-fr"][0], per), rows
        assert within_parity(rows[f"{agent}/amper-k"][0], per), rows


if __name__ == "__main__":
    main()
