"""Table 1 / Fig. 8 reproduction: DQN test scores across samplers.

Smoke-scale protocol (full-scale via --steps): CartPole with replay 2000,
PER vs AMPER-k vs AMPER-fr vs uniform, averaged over seeds; test score =
greedy-policy return averaged over 10 episodes (the paper's metric).
Claim: AMPER variants reach scores comparable to PER.

Seeds run data-parallel through ``train_many`` (one compiled program,
vmapped over the seed batch) instead of a Python loop — the many-seed
sweep regime of Schaul et al. / Panahi et al. as a single XLA launch.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from benchmarks.common import csv_row
from repro.rl.dqn import DQNConfig, make_dqn

SAMPLERS = ("per-sumtree", "amper-k", "amper-fr", "uniform")


def jnp_stack_keys(seeds):
    return jax.vmap(jax.random.key)(np.asarray(seeds, np.uint32))


def run(env: str = "cartpole", steps: int = 6000, seeds=(0, 1, 2),
        replay: int = 2000, num_envs: int = 1, verbose: bool = True):
    rows = {}
    train_keys = jnp_stack_keys(seeds)
    eval_keys = jnp_stack_keys(tuple(s + 100 for s in seeds))
    for sampler in SAMPLERS:
        cfg = DQNConfig(env=env, sampler=sampler, replay_size=replay,
                        num_envs=num_envs,
                        eps_decay_steps=steps // 2, learn_start=200)
        dqn = make_dqn(cfg)
        states, _ = dqn.train_many(train_keys, steps)
        scores = np.asarray(dqn.evaluate_many(states, eval_keys, 10))
        rows[sampler] = (float(scores.mean()), float(scores.std()))
        if verbose:
            print(f"table1 {env} {sampler:12s} test={rows[sampler][0]:7.1f} "
                  f"+- {rows[sampler][1]:.1f}  (seeds={list(seeds)})")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--env", default="cartpole")
    ap.add_argument("--steps", type=int, default=6000)
    ap.add_argument("--seeds", type=int, default=2)
    ap.add_argument("--num-envs", type=int, default=1)
    args = ap.parse_args()
    rows = run(args.env, args.steps, seeds=tuple(range(args.seeds)),
               num_envs=args.num_envs)
    for k, (mean, std) in rows.items():
        print(csv_row(f"table1/{args.env}/{k}", 0.0,
                      f"test_score={mean:.1f}+-{std:.1f}"))
    # Table 1 claim: AMPER within family of PER (generous smoke-scale band)
    assert rows["amper-fr"][0] > 0.4 * rows["per-sumtree"][0], rows
    assert rows["amper-k"][0] > 0.4 * rows["per-sumtree"][0], rows


if __name__ == "__main__":
    main()
