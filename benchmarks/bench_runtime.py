"""Async runtime throughput: learner steps/sec, sync vs async, per sampler.

For each sampler the benchmark trains the same CartPole config two ways
through `ReplayService` — the strict synchronous loop (actor step,
sample, learn, priority write serialized, exactly the scan trainer's
iteration) and the async pipeline (actors / prefetched sampling /
learner / deferred priority feedback overlapped) — and reports median
learner steps/sec over interleaved trials (interleaved so host noise
hits both modes equally).  The claim under test: overlapping hides the
sampler's host latency behind the TD update, so async sustains >= 1.5x
the synchronous learner rate at 16 envs on CPU.

Each row also prints the measured host per-batch sampling latency next
to the *modeled* AM-hardware latency from `repro.core.hwmodel` (Table 2
component latencies): the ratio is the paper's 55–270x Fig. 9 claim as
a roofline column — how much sampling headroom a TCAM back-end would
add to exactly this pipeline.
"""
from __future__ import annotations

import argparse
import statistics

import jax

from benchmarks.common import csv_row, time_fn
from repro.core import hwmodel
from repro.rl.dqn import DQNConfig
from repro.runtime import ReplayService


def _am_model_us(cfg: DQNConfig, sampler: str) -> float:
    """Modeled AM-hardware sampling latency (us) for one batch draw."""
    hw = hwmodel.HwConfig(er_size=cfg.replay_size, m=cfg.amper_m,
                          csp_ratio=cfg.amper_csp_ratio, batch=cfg.batch)
    ns = (hwmodel.latency_k_ns(hw) if sampler == "amper-k"
          else hwmodel.latency_fr_ns(hw))
    return ns / 1e3


def run(env: str = "cartpole",
        samplers=("per-sumtree", "amper-fr"), num_envs: int = 16,
        steps: int = 400, trials: int = 3, replay: int = 4000,
        verbose: bool = True):
    rows = []
    key = jax.random.key(0)
    for sampler in samplers:
        cfg = DQNConfig(env=env, sampler=sampler, num_envs=num_envs,
                        replay_size=replay, batch=64, learn_start=50,
                        eps_decay_steps=10 * steps, target_sync=100,
                        v_max=8.0)
        sv = ReplayService(cfg, sync=True, num_actors=1)
        sa = ReplayService(cfg, num_actors=1, chunk_len=32, slab=8,
                           max_replay_ratio=num_envs)
        sv.run(key, cfg.learn_start + 10)      # compile warmup
        last = sa.run(key, 16)
        sync_t, async_t = [], []
        for _ in range(trials):
            sync_t.append(sv.run(key, steps + cfg.learn_start)
                          .metrics["learner_steps_per_sec"])
            last = sa.run(key, steps)
            async_t.append(last.metrics["learner_steps_per_sec"])
        sync_sps = statistics.median(sync_t)
        async_sps = statistics.median(async_t)
        # host per-batch sampling latency on the warm buffer vs the
        # AM-hardware analytical model — the printed roofline column
        rb = sa.dqn.replay
        sample_j = jax.jit(lambda s, k: rb.sample(s, k, cfg.batch)[0])
        host_us = time_fn(sample_j, last.buffer, key)
        model_us = _am_model_us(cfg, sampler)
        row = {
            "sampler": sampler, "num_envs": num_envs,
            "sync_steps_per_sec": sync_sps,
            "async_steps_per_sec": async_sps,
            "speedup": async_sps / sync_sps,
            "staleness_mean": last.metrics["staleness"]["mean"],
            "host_sample_us": host_us,
            "am_model_us": model_us,
            "am_roofline_x": host_us / model_us,
        }
        rows.append(row)
        if verbose:
            print(f"runtime {env}/{sampler:12s} "
                  f"sync={sync_sps:7.0f}/s async={async_sps:7.0f}/s "
                  f"({row['speedup']:4.2f}x)  "
                  f"host_sample={host_us:8.1f}us  "
                  f"AM_model={model_us:6.2f}us  "
                  f"roofline={row['am_roofline_x']:6.0f}x")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--env", default="cartpole")
    ap.add_argument("--samplers", default="per-sumtree,amper-fr")
    ap.add_argument("--num-envs", type=int, default=16)
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--trials", type=int, default=3)
    args = ap.parse_args()
    rows = run(args.env, tuple(args.samplers.split(",")),
               num_envs=args.num_envs, steps=args.steps,
               trials=args.trials)
    for r in rows:
        print(csv_row(
            f"runtime/{args.env}/{r['sampler']}/B{r['num_envs']}",
            1e6 / r["async_steps_per_sec"],
            f"sync_sps={r['sync_steps_per_sec']:.0f};"
            f"async_sps={r['async_steps_per_sec']:.0f};"
            f"speedup={r['speedup']:.2f};"
            f"am_roofline_x={r['am_roofline_x']:.0f}"))
    # Acceptance: async >= 1.5x learner steps/sec at 16 envs on CPU.
    for r in rows:
        assert r["speedup"] >= 1.5, (r["sampler"], r["speedup"])


if __name__ == "__main__":
    main()
