"""Fig. 4 reproduction: DQN per-operation latency breakdown vs ER size.

Profiles the four DQN operations (store / ER-op=sample+update / train /
action) on THIS machine (CPU; the paper used a GTX-1080) for uniform ER
and PER across replay sizes.  The claims that transfer to any
von-Neumann host: (1) PER's ER share grows with replay size; (2) ER ops
dominate PER at >=1e5 entries while uniform stays flat.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import csv_row, time_fn
from repro.core.amper import UniformSampler
from repro.core.per import SumTreePER
from repro.core.replay_buffer import ReplayBuffer
from repro.rl.dqn import mlp_apply, mlp_init

OBS = 4


def build(sampler_kind: str, size: int):
    sampler = (SumTreePER(size) if sampler_kind == "per"
               else UniformSampler(size))
    rb = ReplayBuffer(size, sampler)
    tr = {"obs": jnp.zeros(OBS), "action": jnp.int32(0),
          "reward": jnp.float32(0), "next_obs": jnp.zeros(OBS),
          "done": jnp.float32(0)}
    state = rb.init(tr)
    # pre-fill
    add = jax.jit(rb.add)
    prio = jax.random.uniform(jax.random.key(0), (size,)) + 0.1
    state = state._replace(
        sampler_state=sampler.update(state.sampler_state,
                                     jnp.arange(size), prio),
        size=jnp.int32(size))
    return rb, state, tr


def run(sizes=(1000, 10_000, 100_000), batch: int = 64, verbose=True):
    params = mlp_init(jax.random.key(1), [OBS, 128, 128, 2])
    obs_b = jnp.zeros((batch, OBS))
    act_fn = jax.jit(lambda p, o: jnp.argmax(mlp_apply(p, o[None]), -1))
    train_fn = jax.jit(lambda p, o: jax.grad(
        lambda pp: jnp.mean(mlp_apply(pp, o) ** 2))(p))

    rows = []
    for kind in ("uniform", "per"):
        for size in sizes:
            rb, state, tr = build(kind, size)
            t_store = time_fn(jax.jit(rb.add), state, tr)
            t_sample = time_fn(
                jax.jit(lambda s, k: rb.sample(s, k, batch)[0]),
                state, jax.random.key(2))
            t_update = time_fn(
                jax.jit(rb.update_priorities), state,
                jnp.arange(batch, dtype=jnp.int32),
                jnp.ones(batch) * 0.5)
            t_er = t_sample + t_update
            t_train = time_fn(train_fn, params, obs_b)
            t_action = time_fn(act_fn, params, obs_b[0])
            total = t_store + t_er + t_train + t_action
            row = {"sampler": kind, "size": size, "store_us": t_store,
                   "er_us": t_er, "train_us": t_train,
                   "action_us": t_action, "er_share": t_er / total}
            rows.append(row)
            if verbose:
                print(f"fig4 {kind:8s} size={size:7d} store={t_store:7.1f}us "
                      f"ER={t_er:8.1f}us train={t_train:7.1f}us "
                      f"action={t_action:6.1f}us ER-share={row['er_share']:.0%}")
    return rows


def main():
    rows = run()
    per = {r["size"]: r for r in rows if r["sampler"] == "per"}
    sizes = sorted(per)
    # Fig 4 trend: ER share grows with replay size under PER
    assert per[sizes[-1]]["er_us"] > per[sizes[0]]["er_us"], per
    for r in rows:
        print(csv_row(f"fig4/{r['sampler']}/size{r['size']}",
                      r["er_us"], f"er_share={r['er_share']:.2f}"))


if __name__ == "__main__":
    main()
