"""Pixel storage economics: frame-deduplicated uint8 ring vs the naive
float transition buffer.

Rows answer the two questions that justify the frame-store mode:

* **bytes/transition** — the naive buffer stores ``obs`` AND
  ``next_obs`` as ``float32[H, W, history_len]`` per transition
  (2 * H*W*K * 4 bytes of observation payload); the frame store keeps
  one ``uint8[H, W]`` frame per transition and rebuilds both stacks at
  sample time.  For the MinAtar-scale default (10x10, K=4) that is an
  ~27x reduction — ``reduction_x`` in the rows, measured from the
  actual storage pytree leaf sizes, not the formula.
* **sample bandwidth** — what the sample-time gather costs: media
  microseconds per jitted ``sample(batch)`` draw and the implied
  transitions/second, for both layouts, so the memory win is priced
  against its materialization overhead.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import csv_row, time_fn
from repro.core.replay_buffer import FrameStore, ReplayBuffer
from repro.core.samplers import make_sampler

HW = (10, 10)       # MinAtar-scale frame
K = 4               # history_len


def _obs_bytes(state) -> int:
    """Observation-payload bytes in a storage pytree (everything that
    scales with H*W; the scalar action/reward/done streams are identical
    across layouts and excluded from the ratio)."""
    return sum(leaf.nbytes for leaf in jax.tree.leaves(state.storage)
               if leaf.ndim > 2)


def _naive_rb(cap):
    rb = ReplayBuffer(cap, make_sampler("uniform", cap))
    st = rb.init({"obs": jnp.zeros(HW + (K,), jnp.float32),
                  "next_obs": jnp.zeros(HW + (K,), jnp.float32),
                  "action": jnp.int32(0), "reward": jnp.float32(0),
                  "done": jnp.float32(0)})
    ko, kn = jax.random.split(jax.random.key(0))
    st = rb.add_batch(st, {
        "obs": jax.random.uniform(ko, (cap,) + HW + (K,)),
        "next_obs": jax.random.uniform(kn, (cap,) + HW + (K,)),
        "action": jnp.zeros(cap, jnp.int32),
        "reward": jnp.arange(cap, dtype=jnp.float32),
        "done": jnp.zeros(cap)})
    return rb, jax.block_until_ready(st)


def _frame_rb(cap):
    rb = ReplayBuffer(cap, make_sampler("uniform", cap),
                      frame_store=FrameStore(history_len=K, frame_shape=HW))
    st = rb.init({"frame": jnp.zeros(HW, jnp.uint8),
                  "action": jnp.int32(0), "reward": jnp.float32(0),
                  "done": jnp.float32(0)})
    k = jax.random.key(1)
    st = rb.add_batch(st, {
        "frame": jax.random.randint(k, (cap,) + HW, 0, 256, jnp.uint8),
        "action": jnp.zeros(cap, jnp.int32),
        "reward": jnp.arange(cap, dtype=jnp.float32),
        "done": jnp.zeros(cap)})
    return rb, jax.block_until_ready(st)


def run(sizes=(10_000, 100_000), batch: int = 256):
    rows = []
    for cap in sizes:
        layouts = {"naive-float": _naive_rb(cap),
                   "frame-store": _frame_rb(cap)}
        bt = {}
        for name, (rb, st) in layouts.items():
            bt[name] = _obs_bytes(st) / cap
            sample = jax.jit(
                lambda s, key, rb=rb: rb.sample(s, key, batch)[1])
            us = time_fn(sample, st, jax.random.key(7))
            rows.append({
                "name": f"storage_{name}",
                "capacity": cap,
                "batch": batch,
                "bytes_per_transition": bt[name],
                "sample_us": us,
                "sample_transitions_per_s": batch / (us * 1e-6),
            })
            print(csv_row(f"storage_{name}_{cap}", us,
                          f"{bt[name]:.0f} B/transition"), flush=True)
        reduction = bt["naive-float"] / bt["frame-store"]
        rows.append({"name": "storage_reduction", "capacity": cap,
                     "reduction_x": reduction})
        print(f"reduction @{cap}: {reduction:.1f}x", flush=True)
        assert reduction >= 20.0, (
            f"frame store must cut observation bytes >=20x, got "
            f"{reduction:.1f}x")
    return rows


if __name__ == "__main__":
    from benchmarks.common import write_bench_json

    write_bench_json("storage", run())
