"""Shared benchmark utilities."""
from __future__ import annotations

import json
import os
import platform
import time

import jax


def time_fn(fn, *args, warmup: int = 2, iters: int = 10) -> float:
    """Median wall-clock microseconds per call (blocks on results)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def csv_row(name: str, us_per_call: float, derived: str = "") -> str:
    return f"{name},{us_per_call:.2f},{derived}"


def _jsonable(x):
    """Recursively coerce benchmark rows (numpy/jax scalars, tuples) to
    plain JSON types."""
    if isinstance(x, dict):
        return {str(k): _jsonable(v) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [_jsonable(v) for v in x]
    if isinstance(x, (str, int, float, bool)) or x is None:
        return x
    if hasattr(x, "item"):  # numpy / jax scalars
        return x.item()
    return str(x)


def write_bench_json(section: str, rows, out_dir: str = ".",
                     **extra) -> str:
    """Persist one benchmark section as machine-readable ``BENCH_*.json``.

    The payload records the rows verbatim plus enough provenance (host,
    backend, device count, unix time) to plot a perf trajectory across
    commits.  Returns the written path.
    """
    payload = {
        "section": section,
        "unix_time": time.time(),
        "host": platform.node(),
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "rows": _jsonable(rows),
        **_jsonable(extra),
    }
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"BENCH_{section}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    return path
