"""Sharded replay latency: sample+update wall-time vs shard count.

The mesh-level claim under test (ISSUE 2 / the paper's Sec. 3 argument
lifted to SPMD): AMPER-fr's per-batch communication is O(shards + batch)
scalars (one all-gather of shard match counts + one psum of the picked
indices), while hierarchical-cumsum PER must realise the global prefix
structure every draw.  Neither law needs the table on one host, so both
scale to tables that do not fit a device — this benchmark records how
their sample and priority-update latencies move as the same table is
split over 1/2/4/8 shards.

On CPU the forced host devices share the machine, so absolute numbers
are a software-overhead proxy (collective count, not bandwidth); the
shape of the curve — AMPER flat-ish, PER paying the global cumsum — is
the recorded signal.

Run standalone (forces its own 8 host devices, must be a fresh process):

    python -m benchmarks.bench_sharded --json BENCH_sharded.json

``benchmarks/run.py`` invokes exactly that as a subprocess, because
XLA_FLAGS must be set before the first jax init and the parent process
has usually initialised jax already.
"""
from __future__ import annotations

import argparse
import os
import sys

DEVICE_COUNT = 8


def _force_host_devices(n: int = DEVICE_COUNT) -> None:
    flags = os.environ.get("XLA_FLAGS", "")
    if "host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            flags + f" --xla_force_host_platform_device_count={n}").strip()


def _ensure_repro_importable() -> None:
    """Subprocess-friendly: put <repo>/src on sys.path if needed."""
    try:
        import repro  # noqa: F401
    except ModuleNotFoundError:
        sys.path.insert(0, os.path.join(
            os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
            "src"))


def run(shard_counts=(1, 2, 4, 8), n: int = 1 << 16, batch: int = 256,
        verbose: bool = True):
    """Times sample() and update() for both sharded samplers per shard
    count.  Requires enough devices (call via main() / subprocess)."""
    import jax
    import jax.numpy as jnp

    from benchmarks.common import time_fn
    from repro.core.samplers import make_sampler
    from repro.launch.mesh import make_replay_mesh

    prio = jax.random.uniform(jax.random.key(0), (n,)) + 0.01
    upd_idx = jnp.arange(batch, dtype=jnp.int32) * (n // batch)
    key = jax.random.key(1)
    rows = []
    for shards in shard_counts:
        if shards > jax.device_count():
            if verbose:
                print(f"skip shards={shards}: only {jax.device_count()} devices")
            continue
        mesh = make_replay_mesh(shards)
        for kind in ("amper-fr-sharded", "per-sharded"):
            s = make_sampler(kind, n, v_max=1.0, mesh=mesh,
                             csp_capacity=max(int(n * 0.15), batch))
            st = s.update(s.init(), jnp.arange(n), prio)
            t_sample = time_fn(jax.jit(lambda st_, k, s_=s: s_.sample(st_, k, batch)),
                               st, key)
            t_update = time_fn(jax.jit(lambda st_, i, p, s_=s: s_.update(st_, i, p)),
                               st, upd_idx, prio[:batch])
            rows.append({"kind": kind, "shards": shards, "n": n,
                         "batch": batch, "sample_us": t_sample,
                         "update_us": t_update})
            if verbose:
                print(f"sharded {kind:18s} shards={shards} n={n} "
                      f"sample={t_sample:8.0f}us update={t_update:8.0f}us",
                      flush=True)
    return rows


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", default=None,
                    help="write the machine-readable BENCH json to exactly "
                         "this path")
    ap.add_argument("--shards", default="1,2,4,8")
    args = ap.parse_args(argv)

    _force_host_devices()
    _ensure_repro_importable()
    shard_counts = tuple(int(s) for s in args.shards.split(","))
    n = 1 << 13 if args.quick else 1 << 16
    rows = run(shard_counts=shard_counts, n=n)

    from benchmarks.common import csv_row, write_bench_json
    for r in rows:
        print(csv_row(f"sharded/{r['kind']}/s{r['shards']}/n{r['n']}",
                      r["sample_us"], f"update_us={r['update_us']:.1f}"))
    if args.json:
        out_dir = os.path.dirname(args.json) or "."
        path = write_bench_json("sharded", rows, out_dir=out_dir,
                                n=n, shard_counts=list(shard_counts))
        if os.path.abspath(path) != os.path.abspath(args.json):
            os.replace(path, args.json)
            path = args.json
        print(f"wrote {path}")


if __name__ == "__main__":
    # must run before any jax import in this process
    _force_host_devices()
    main()
