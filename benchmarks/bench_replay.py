"""Durability cost: replay checkpoint save/restore latency, incremental
delta-save throughput, and the copy-on-write snapshot cost of the async
service.

Rows answer the operational questions of the fault-tolerance subsystem:

* how long does one atomic+fsync'd snapshot of a ReplayState take, and
  how does it scale with capacity (save = host gather + npz + fsync;
  restore = npz load + device_put)?  And how much cheaper is a delta
  save covering only a written ring arc (``replay_ckpt_delta_*``)?
* what does periodic checkpointing cost the sync trainer
  (``overhead_frac`` — wall-time overhead relative to an uncheckpointed
  run; incremental single-file saves are what keep it low)?
* what does an async snapshot cost now that it is copy-on-write
  (``snapshot_pause_us`` — the learner-thread capture stall, the ONLY
  pipeline stall a snapshot causes; ``drain_cycles`` counts full
  pause→drain quiesce protocols and is structurally 0 since the COW
  rework — the column tracks the regime change in the trajectory)?
"""
from __future__ import annotations

import os
import tempfile
import time

import jax
import jax.numpy as jnp

from benchmarks.common import csv_row
from repro.core.replay_buffer import ReplayBuffer
from repro.core.samplers import make_sampler
from repro.rl.dqn import DQNConfig
from repro.runtime import ReplayService
from repro.train import checkpoint as ck
from repro.train import replay_checkpoint as rck
from repro.train.checkpoint import CheckpointManager


def _time_host(fn, warmup: int = 1, iters: int = 5) -> float:
    """Median wall-clock microseconds for a host-side (non-jax) call."""
    for _ in range(warmup):
        fn()
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def _populated_state(rb, cap):
    st = rb.init({"obs": jnp.zeros(8), "action": jnp.int32(0),
                  "reward": jnp.float32(0)})
    k = jax.random.key(0)
    st = rb.add_batch(st, {
        "obs": jax.random.normal(k, (cap, 8)),
        "action": jnp.zeros(cap, jnp.int32),
        "reward": jnp.arange(cap, dtype=jnp.float32)})
    return jax.block_until_ready(st)


def _ckpt_rows(sizes):
    rows = []
    for cap in sizes:
        rb = ReplayBuffer(cap, make_sampler("per-cumsum", cap))
        st = _populated_state(rb, cap)
        nbytes = sum(x.nbytes for x in jax.tree.leaves(st))
        with tempfile.TemporaryDirectory() as d:
            t_save = _time_host(lambda: rck.save_replay(d, 1, st))
            t_restore = _time_host(
                lambda: jax.block_until_ready(
                    rck.restore_replay(d, 1, rb, {
                        "obs": jnp.zeros(8), "action": jnp.int32(0),
                        "reward": jnp.float32(0)})))
        for op, us in (("save", t_save), ("restore", t_restore)):
            name = f"replay_ckpt_{op}_n{cap}"
            derived = f"{nbytes / 1e6:.1f}MB {nbytes / max(us, 1):.0f}MB/s"
            print(csv_row(name, us, derived))
            rows.append({"name": name, "us_per_call": us,
                         "bytes": nbytes, "mb_per_s": nbytes / max(us, 1)})
        # Incremental: a delta covering a 1k-row ring arc (the steady
        # state between saves) vs the full dump above.
        arc = min(1024, cap)
        marks = {"pos": 0, "total_adds": int(st.total_adds) - arc}
        dirty = rck.replay_dirty(rb, st, marks)
        with tempfile.TemporaryDirectory() as d:
            ck.save_incremental(d, 1, st)
            step = [2]

            def delta_save():
                ck.save_incremental(d, step[0], st, base_step=1,
                                    dirty=dirty)
                step[0] += 1

            t_delta = _time_host(delta_save)
        name = f"replay_ckpt_delta_n{cap}"
        print(csv_row(name, t_delta, f"{arc}-row arc delta"))
        rows.append({"name": name, "us_per_call": t_delta,
                     "arc_rows": arc, "full_us": t_save})
    return rows


def _median_wall(fn, trials: int = 3) -> float:
    """Median wall seconds over ``trials`` calls (single-shot service
    timings at this scale are ±20% — enough to swamp overhead_frac)."""
    times = []
    for _ in range(trials):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def _service_rows(steps: int):
    cfg = DQNConfig(sampler="amper-fr", num_envs=2, replay_size=512,
                    batch=16, learn_start=8, eps_decay_steps=200,
                    target_sync=50, v_max=8.0)
    rows = []
    # sync: relative checkpoint overhead at interval steps//4
    svc = ReplayService(cfg, sync=True, num_actors=1)
    key = jax.random.key(0)
    svc.run(key, steps)  # warmup/compile
    base = _median_wall(lambda: svc.run(key, steps))

    def ckpt_cycle():
        # fresh dir per trial: reusing one would resume-at-target and
        # measure a no-op run
        with tempfile.TemporaryDirectory() as d:
            svc.run(key, steps,
                    manager=CheckpointManager(
                        d, save_interval=max(steps // 4, 1)))

    ckpt = _median_wall(ckpt_cycle)
    n_saves = 4
    over = (ckpt - base) / n_saves * 1e6
    name = "sync_ckpt_cycle"
    print(csv_row(name, max(over, 0.0),
                  f"overhead {100 * (ckpt - base) / base:.1f}% @ {n_saves} saves"))
    rows.append({"name": name, "us_per_call": over,
                 "overhead_frac": (ckpt - base) / base})

    # async: copy-on-write snapshot cost.  us_per_call is the wall-time
    # overhead per snapshot (serialization overlaps the pipeline, so
    # this can approach 0); snapshot_pause_us is the learner-thread
    # capture stall — the only stall a COW snapshot inflicts.
    asvc = ReplayService(cfg, num_actors=2, chunk_len=4, slab=2,
                         queue_size=4, max_replay_ratio=64)
    asvc.run(key, 2 * asvc.slab)  # warmup/compile
    base = _median_wall(lambda: asvc.run(key, steps))
    results = []

    def snap_cycle():
        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(
                d, save_interval=max(steps // 4, asvc.slab))
            results.append(asvc.run(key, steps, manager=mgr))

    ckpt = _median_wall(snap_cycle)
    # Pause stats aggregate every trial's snapshots, not just the
    # median run's: the tail captures are the interesting ones.
    snaps = [r.metrics["snapshot"] for r in results]
    snap = {
        "saved": snaps[-1]["saved"],
        "pause_us_max": max(s["pause_us_max"] for s in snaps),
        "pause_us_mean": (sum(s["pause_us_mean"] * s["count"] for s in snaps)
                          / max(sum(s["count"] for s in snaps), 1)),
        "drain_cycles": sum(s["drain_cycles"] for s in snaps),
    }
    n_saves = max(snap["saved"], 1)
    over = (ckpt - base) / n_saves * 1e6
    name = "async_snapshot_cycle"
    print(csv_row(name, max(over, 0.0),
                  f"cow capture {snap['pause_us_mean']:.0f}us mean / "
                  f"{snap['pause_us_max']:.0f}us max, {snap['saved']} "
                  f"snapshots, {snap['drain_cycles']} drain cycles"))
    rows.append({"name": name, "us_per_call": over, "cycles": n_saves,
                 "snapshot_pause_us": snap["pause_us_max"],
                 "snapshot_pause_us_mean": snap["pause_us_mean"],
                 "drain_cycles": snap["drain_cycles"]})
    return rows


def run(sizes=(10_000, 100_000), steps: int = 120):
    return _ckpt_rows(sizes) + _service_rows(steps)


if __name__ == "__main__":
    run()
