"""Durability cost: replay checkpoint save/restore latency and the
pause->drain->snapshot->resume overhead of the async service.

Rows answer the operational questions of the fault-tolerance subsystem:

* how long does one atomic+fsync'd snapshot of a ReplayState take, and
  how does it scale with capacity (save = host gather + npz + fsync;
  restore = npz load + device_put)?
* what does periodic checkpointing cost the sync trainer (relative
  overhead at a given interval)?
* what does one full async quiesce cycle cost (pause the actor pool and
  prefetcher, drain blocks + deferred feedback, write, resume)?
"""
from __future__ import annotations

import os
import tempfile
import time

import jax
import jax.numpy as jnp

from benchmarks.common import csv_row
from repro.core.replay_buffer import ReplayBuffer
from repro.core.samplers import make_sampler
from repro.rl.dqn import DQNConfig
from repro.runtime import ReplayService
from repro.train import replay_checkpoint as rck
from repro.train.checkpoint import CheckpointManager


def _time_host(fn, warmup: int = 1, iters: int = 5) -> float:
    """Median wall-clock microseconds for a host-side (non-jax) call."""
    for _ in range(warmup):
        fn()
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def _populated_state(rb, cap):
    st = rb.init({"obs": jnp.zeros(8), "action": jnp.int32(0),
                  "reward": jnp.float32(0)})
    k = jax.random.key(0)
    st = rb.add_batch(st, {
        "obs": jax.random.normal(k, (cap, 8)),
        "action": jnp.zeros(cap, jnp.int32),
        "reward": jnp.arange(cap, dtype=jnp.float32)})
    return jax.block_until_ready(st)


def _ckpt_rows(sizes):
    rows = []
    for cap in sizes:
        rb = ReplayBuffer(cap, make_sampler("per-cumsum", cap))
        st = _populated_state(rb, cap)
        nbytes = sum(x.nbytes for x in jax.tree.leaves(st))
        with tempfile.TemporaryDirectory() as d:
            t_save = _time_host(lambda: rck.save_replay(d, 1, st))
            t_restore = _time_host(
                lambda: jax.block_until_ready(
                    rck.restore_replay(d, 1, rb, {
                        "obs": jnp.zeros(8), "action": jnp.int32(0),
                        "reward": jnp.float32(0)})))
        for op, us in (("save", t_save), ("restore", t_restore)):
            name = f"replay_ckpt_{op}_n{cap}"
            derived = f"{nbytes / 1e6:.1f}MB {nbytes / max(us, 1):.0f}MB/s"
            print(csv_row(name, us, derived))
            rows.append({"name": name, "us_per_call": us,
                         "bytes": nbytes, "mb_per_s": nbytes / max(us, 1)})
    return rows


def _service_rows(steps: int):
    cfg = DQNConfig(sampler="amper-fr", num_envs=2, replay_size=512,
                    batch=16, learn_start=8, eps_decay_steps=200,
                    target_sync=50, v_max=8.0)
    rows = []
    # sync: relative checkpoint overhead at interval steps//4
    svc = ReplayService(cfg, sync=True, num_actors=1)
    key = jax.random.key(0)
    svc.run(key, steps)  # warmup/compile
    t0 = time.perf_counter()
    svc.run(key, steps)
    base = time.perf_counter() - t0
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, save_interval=max(steps // 4, 1))
        t0 = time.perf_counter()
        svc.run(key, steps, manager=mgr)
        ckpt = time.perf_counter() - t0
    n_saves = 4
    over = (ckpt - base) / n_saves * 1e6
    name = "sync_ckpt_cycle"
    print(csv_row(name, max(over, 0.0),
                  f"overhead {100 * (ckpt - base) / base:.1f}% @ {n_saves} saves"))
    rows.append({"name": name, "us_per_call": over,
                 "overhead_frac": (ckpt - base) / base})

    # async: full pause->drain->snapshot->resume cycle cost
    asvc = ReplayService(cfg, num_actors=2, chunk_len=4, slab=2,
                         queue_size=4, max_replay_ratio=64)
    asvc.run(key, 2 * asvc.slab)  # warmup/compile
    t0 = time.perf_counter()
    asvc.run(key, steps)
    base = time.perf_counter() - t0
    with tempfile.TemporaryDirectory() as d:
        interval = max(steps // 4, asvc.slab)
        mgr = CheckpointManager(d, save_interval=interval)
        t0 = time.perf_counter()
        asvc.run(key, steps, manager=mgr)
        ckpt = time.perf_counter() - t0
        n_saves = max(steps // interval, 1)
    over = (ckpt - base) / n_saves * 1e6
    name = "async_snapshot_cycle"
    print(csv_row(name, max(over, 0.0),
                  f"pause+drain+save+resume, {n_saves} cycles"))
    rows.append({"name": name, "us_per_call": over, "cycles": n_saves})
    return rows


def run(sizes=(10_000, 100_000), steps: int = 120):
    return _ckpt_rows(sizes) + _service_rows(steps)


if __name__ == "__main__":
    run()
