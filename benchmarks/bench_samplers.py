"""Per-batch sampling wall-time on THIS host: the software side of the
paper's latency story, plus our TPU-adapted fast paths.

Compares: sum-tree PER (faithful baseline), cumsum PER (vector baseline),
AMPER-fr (XLA), AMPER-fr (fused Pallas kernel path, interpret on CPU),
AMPER-k (bisect).  On CPU the interpret-mode kernel is SLOW (it is a
Python-level simulation) — its numbers validate correctness, not speed;
the XLA AMPER path is the honest CPU speed proxy.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

import repro.core.quantize as qz
from benchmarks.common import csv_row, time_fn
from repro.core.amper import AmperConfig, AmperSampler
from repro.core.hwmodel import HwConfig, latency_fr_ns
from repro.core.per import CumsumPER, SumTreePER

# The fusion-aware jaxpr dispatch counter lives in the analysis package
# now (it is also the DISPATCH-BUDGET gate); re-exported here so the
# benchmark and its existing importers (tests/test_obs.py) keep working.
from repro.analysis.jaxpr_lint import (  # noqa: F401
    BATCH,
    CSP_RATIO,
    FUSIBLE as _FUSIBLE,
    count_eqns as _count_eqns,
    dispatch_count,
    sub_jaxprs as _sub_jaxprs,
)


def run(sizes=(10_000, 100_000, 1_000_000), verbose: bool = True):
    rows = []
    for n in sizes:
        prio = jax.random.uniform(jax.random.key(0), (n,)) + 0.01
        key = jax.random.key(1)

        st = SumTreePER(n)
        s1 = st.update(st.init(), jnp.arange(n), prio)
        t_tree = time_fn(jax.jit(lambda s, k: st.sample(s, k, BATCH)), s1, key)
        tu_tree = time_fn(jax.jit(st.update), s1,
                          jnp.arange(BATCH, dtype=jnp.int32), prio[:BATCH])

        cs = CumsumPER(n)
        s2 = cs.update(cs.init(), jnp.arange(n), prio)
        t_cum = time_fn(jax.jit(lambda s, k: cs.sample(s, k, BATCH)), s2, key)

        cfg = AmperConfig(capacity=n, m=20, lam_fr=2.0, v_max=1.0,
                          csp_capacity=max(int(n * 0.15), BATCH),
                          knn_mode="bisect")
        amper_t = {}
        for label, variant, mode in (("fr", "fr", "broadcast"),
                                     ("fr-fused", "fr", "fused"),
                                     ("k", "k", "broadcast")):
            amp = AmperSampler(cfg._replace(fr_mode=mode), variant)
            s3 = amp.update(amp.init(), jnp.arange(n), prio)
            t = time_fn(jax.jit(lambda s, k, a=amp: a.sample(s, k, BATCH)),
                        s3, key)
            tu = time_fn(jax.jit(amp.update), s3,
                         jnp.arange(BATCH, dtype=jnp.int32), prio[:BATCH])
            amper_t[label] = t
            rows.append((f"amper-{label}/n{n}", t, tu))
        rows.append((f"per-sumtree/n{n}", t_tree, tu_tree))
        rows.append((f"per-cumsum/n{n}", t_cum, 0.0))
        if verbose:
            print(f"bench n={n}: sumtree sample={t_tree:.0f}us "
                  f"update={tu_tree:.0f}us | cumsum={t_cum:.0f}us | "
                  f"amper-fr={amper_t['fr']:.0f}us "
                  f"amper-fr-fused={amper_t['fr-fused']:.0f}us "
                  f"amper-k={amper_t['k']:.0f}us")
    return rows


def _time_update_donated(amp, state, idx, prio, iters: int = 8) -> float:
    """Per-call µs for ``update`` with the priority table donated.

    Donation invalidates the input buffers, so instead of re-timing one
    frozen state we thread the state through a chain of donated calls —
    exactly the steady-state pattern the async runtime uses.
    """
    upd = jax.jit(amp.update, donate_argnums=(0,))
    st = jax.tree.map(jnp.copy, state)
    st = upd(st, idx, prio)          # compile outside the timed region
    jax.block_until_ready(st)
    st = jax.tree.map(jnp.copy, state)
    t0 = time.perf_counter()
    for _ in range(iters):
        st = upd(st, idx, prio)
    jax.block_until_ready(st)
    return (time.perf_counter() - t0) / iters * 1e6


def run_sampling(sizes=(10_000, 100_000), verbose: bool = True):
    """The fused-kernel scorecard: dispatches per draw, per-draw latency,
    and the measured-vs-roofline gap against the paper's analytical
    AMPER-fr hardware model (``hwmodel.latency_fr_ns``).

    ``dispatches`` is the recursive jaxpr-equation count of one ``sample``
    call (pallas_call == 1); it is host-independent, so the >=2x fused
    reduction holds even when this runs on a CPU where the interpret-mode
    kernel's wall-time does not reflect TPU speed.
    """
    rows = []
    key = jax.random.key(1)
    for n in sizes:
        prio = jax.random.uniform(jax.random.key(0), (n,)) + 0.01
        roofline_ns = latency_fr_ns(
            HwConfig(er_size=n, m=20, csp_ratio=CSP_RATIO, batch=BATCH))
        cfg = AmperConfig(capacity=n, m=20, lam_fr=2.0, v_max=1.0,
                          csp_capacity=max(int(n * CSP_RATIO), BATCH))
        for mode in ("broadcast", "kernel", "fused"):
            amp = AmperSampler(cfg._replace(fr_mode=mode), "fr")
            s = amp.update(amp.init(), jnp.arange(n), prio)
            # Distinct lambdas for counting vs timing: the trace cache is
            # keyed on function identity, see dispatch_count.
            eqns, disp = dispatch_count(
                lambda st, k, a=amp: a.sample(st, k, BATCH), s, key)
            t = time_fn(jax.jit(lambda st, k, a=amp: a.sample(st, k, BATCH)),
                        s, key)
            tu_don = _time_update_donated(
                amp, s, jnp.arange(BATCH, dtype=jnp.int32), prio[:BATCH])
            ratio = t * 1e3 / roofline_ns
            rows.append((f"fr-{mode}/n{n}", t,
                         f"dispatches={disp} eqns={eqns} "
                         f"roofline_ns={roofline_ns:.0f} "
                         f"measured_vs_roofline={ratio:.1f} "
                         f"update_donated_us={tu_don:.1f}"))
            if verbose:
                print(f"sampling n={n} fr-{mode}: dispatches={disp} "
                      f"eqns={eqns} sample={t:.0f}us "
                      f"roofline={roofline_ns:.0f}ns "
                      f"gap={ratio:.1f}x update_donated={tu_don:.0f}us")
    return rows


def main():
    for name, t_sample, t_update in run():
        print(csv_row(f"samplers/{name}", t_sample,
                      f"update_us={t_update:.1f}"))
    for name, t_sample, derived in run_sampling():
        print(csv_row(f"sampling/{name}", t_sample, derived))


if __name__ == "__main__":
    main()
