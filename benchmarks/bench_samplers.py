"""Per-batch sampling wall-time on THIS host: the software side of the
paper's latency story, plus our TPU-adapted fast paths.

Compares: sum-tree PER (faithful baseline), cumsum PER (vector baseline),
AMPER-fr (XLA), AMPER-fr (fused Pallas kernel path, interpret on CPU),
AMPER-k (bisect).  On CPU the interpret-mode kernel is SLOW (it is a
Python-level simulation) — its numbers validate correctness, not speed;
the XLA AMPER path is the honest CPU speed proxy.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

import repro.core.quantize as qz
from benchmarks.common import csv_row, time_fn
from repro.core.amper import AmperConfig, AmperSampler
from repro.core.per import CumsumPER, SumTreePER

BATCH = 64


def run(sizes=(10_000, 100_000, 1_000_000), verbose: bool = True):
    rows = []
    for n in sizes:
        prio = jax.random.uniform(jax.random.key(0), (n,)) + 0.01
        key = jax.random.key(1)

        st = SumTreePER(n)
        s1 = st.update(st.init(), jnp.arange(n), prio)
        t_tree = time_fn(jax.jit(lambda s, k: st.sample(s, k, BATCH)), s1, key)
        tu_tree = time_fn(jax.jit(st.update), s1,
                          jnp.arange(BATCH, dtype=jnp.int32), prio[:BATCH])

        cs = CumsumPER(n)
        s2 = cs.update(cs.init(), jnp.arange(n), prio)
        t_cum = time_fn(jax.jit(lambda s, k: cs.sample(s, k, BATCH)), s2, key)

        cfg = AmperConfig(capacity=n, m=20, lam_fr=2.0, v_max=1.0,
                          csp_capacity=max(int(n * 0.15), BATCH),
                          knn_mode="bisect")
        for variant in ("fr", "k"):
            amp = AmperSampler(cfg, variant)
            s3 = amp.update(amp.init(), jnp.arange(n), prio)
            t = time_fn(jax.jit(lambda s, k: amp.sample(s, k, BATCH)), s3, key)
            tu = time_fn(jax.jit(amp.update), s3,
                         jnp.arange(BATCH, dtype=jnp.int32), prio[:BATCH])
            rows.append((f"amper-{variant}/n{n}", t, tu))
        rows.append((f"per-sumtree/n{n}", t_tree, tu_tree))
        rows.append((f"per-cumsum/n{n}", t_cum, 0.0))
        if verbose:
            print(f"bench n={n}: sumtree sample={t_tree:.0f}us "
                  f"update={tu_tree:.0f}us | cumsum={t_cum:.0f}us | "
                  f"amper-fr={rows[-4][1]:.0f}us amper-k={rows[-3][1]:.0f}us")
    return rows


def main():
    for name, t_sample, t_update in run():
        print(csv_row(f"samplers/{name}", t_sample,
                      f"update_us={t_update:.1f}"))


if __name__ == "__main__":
    main()
