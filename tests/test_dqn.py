"""DQN + replay integration: envs behave, agents learn, AMPER ~ PER."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.rl.dqn import DQNConfig, make_dqn
from repro.rl.envs import Acrobot, CartPole


def test_cartpole_dynamics():
    env = CartPole()
    s = env.reset(jax.random.key(0))
    assert s.x.shape == (4,)
    s2, obs, r, done = env.step(s, jnp.int32(1), jax.random.key(1))
    assert float(r) == 1.0 and not bool(done)
    # pushing right increases cart velocity
    assert float(s2.x[1]) > float(s.x[1])


def test_cartpole_terminates_on_angle():
    env = CartPole()
    s = env.reset(jax.random.key(0))
    s = s._replace(x=jnp.array([0.0, 0.0, 0.25, 0.0]))  # beyond 12 deg
    _, _, _, done = env.step(s, jnp.int32(0), jax.random.key(1))
    assert bool(done)


def test_acrobot_reward_structure():
    env = Acrobot()
    s = env.reset(jax.random.key(0))
    _, _, r, done = env.step(s, jnp.int32(0), jax.random.key(1))
    assert float(r) == -1.0 and not bool(done)


@pytest.mark.slow
@pytest.mark.parametrize("sampler", ["per-sumtree", "amper-fr"])
def test_dqn_learns_cartpole(sampler):
    """Paper Fig. 8 claim at smoke scale: both PER and AMPER learn; a
    trained agent beats the random policy by a wide margin."""
    cfg = DQNConfig(env="cartpole", sampler=sampler, replay_size=2000,
                    eps_decay_steps=3000, learn_start=200)
    dqn = make_dqn(cfg)
    state, metrics = dqn.train(jax.random.key(0), 6000)
    test_score = float(dqn.evaluate(state, jax.random.key(9), 10))
    # random policy scores ~20 on CartPole; learned should far exceed
    assert test_score > 80, (sampler, test_score)


@pytest.mark.slow
def test_amper_within_factor_of_per():
    """Table 1 claim at smoke scale: AMPER-fr within a reasonable factor
    of PER's test score on the same seed/budget."""
    scores = {}
    for sampler in ("per-sumtree", "amper-fr"):
        cfg = DQNConfig(env="cartpole", sampler=sampler, replay_size=2000,
                        eps_decay_steps=3000, learn_start=200)
        dqn = make_dqn(cfg)
        state, _ = dqn.train(jax.random.key(0), 6000)
        scores[sampler] = float(dqn.evaluate(state, jax.random.key(9), 10))
    assert scores["amper-fr"] > 0.5 * scores["per-sumtree"], scores
