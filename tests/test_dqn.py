"""DQN + replay integration: envs behave, agents learn, AMPER ~ PER,
and the agent family (Q-heads x target rules x n-step) composes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.qhead import make_qhead
from repro.rl.dqn import AGENTS, DQNConfig, make_dqn
from repro.rl.envs import Acrobot, CartPole


def test_cartpole_dynamics():
    env = CartPole()
    s = env.reset(jax.random.key(0))
    assert s.x.shape == (4,)
    s2, obs, r, done, term = env.step(s, jnp.int32(1), jax.random.key(1))
    assert float(r) == 1.0 and not bool(done) and not bool(term)
    # pushing right increases cart velocity
    assert float(s2.x[1]) > float(s.x[1])


def test_cartpole_terminates_on_angle():
    env = CartPole()
    s = env.reset(jax.random.key(0))
    s = s._replace(x=jnp.array([0.0, 0.0, 0.25, 0.0]))  # beyond 12 deg
    _, _, _, done, term = env.step(s, jnp.int32(0), jax.random.key(1))
    assert bool(done) and bool(term)


def test_acrobot_reward_structure():
    env = Acrobot()
    s = env.reset(jax.random.key(0))
    _, _, r, done, term = env.step(s, jnp.int32(0), jax.random.key(1))
    assert float(r) == -1.0 and not bool(done) and not bool(term)


# --- agent family ------------------------------------------------------------


def test_qhead_shapes_and_batch_broadcast():
    for kind in ("mlp", "dueling"):
        head = make_qhead(kind, obs_dim=4, hidden=16, n_actions=3)
        params = head.init(jax.random.key(0))
        q1 = head.apply(params, jnp.ones(4))          # single obs
        qb = head.apply(params, jnp.ones((5, 4)))     # batch
        assert q1.shape == (3,) and qb.shape == (5, 3)
        np.testing.assert_allclose(np.asarray(qb[0]), np.asarray(q1),
                                   rtol=1e-6)


def test_conv_qhead_shapes_and_batch_broadcast():
    for kind in ("conv", "conv-dueling"):
        head = make_qhead(kind, (10, 10, 4), hidden=16, n_actions=3)
        params = head.init(jax.random.key(0))
        obs = jax.random.uniform(jax.random.key(1), (10, 10, 4))
        q1 = head.apply(params, obs)                   # single obs
        qb = head.apply(params, jnp.broadcast_to(obs, (5, 10, 10, 4)))
        assert q1.shape == (3,) and qb.shape == (5, 3)
        np.testing.assert_allclose(np.asarray(qb[0]), np.asarray(q1),
                                   rtol=1e-5, atol=1e-6)


def test_qhead_shape_validation():
    with pytest.raises(ValueError, match="conv head"):
        make_qhead("mlp", (10, 10, 4), hidden=8, n_actions=2)
    with pytest.raises(ValueError, match=r"\(H, W, C\)"):
        make_qhead("conv", (4,), hidden=8, n_actions=2)
    with pytest.raises(ValueError, match="unknown Q-head kind"):
        make_qhead("transformer", (4,), hidden=8, n_actions=2)


def test_dueling_head_is_identifiable():
    """The dueling recombination subtracts the mean advantage, so a
    constant shift of the advantage stream cannot change Q."""
    head = make_qhead("dueling", obs_dim=4, hidden=16, n_actions=3)
    params = head.init(jax.random.key(1))
    obs = jax.random.normal(jax.random.key(2), (7, 4))
    q = head.apply(params, obs)
    shifted = jax.tree.map(lambda x: x, params)
    shifted["adv"] = [{"w": params["adv"][0]["w"],
                       "b": params["adv"][0]["b"] + 5.0}]
    np.testing.assert_allclose(np.asarray(head.apply(shifted, obs)),
                               np.asarray(q), rtol=1e-4, atol=1e-5)
    # the advantage stream itself is centred out of Q
    assert np.asarray(jnp.abs(q.mean(-1))).max() < 1e3  # sanity: finite


def test_unknown_agent_and_bad_n_step_raise():
    with pytest.raises(ValueError, match="unknown agent"):
        make_dqn(DQNConfig(agent="rainbow"))
    with pytest.raises(ValueError, match="n_step"):
        make_dqn(DQNConfig(n_step=0))
    assert set(AGENTS) == {"dqn", "double", "dueling", "double-dueling"}


def _batch(done, terminated):
    return {
        "obs": jax.random.normal(jax.random.key(1), (4, 4)),
        "action": jnp.zeros(4, jnp.int32),
        "reward": jnp.ones(4),
        "next_obs": jax.random.normal(jax.random.key(2), (4, 4)) * 3.0,
        "done": done, "terminated": terminated}


def test_truncation_bootstraps_termination_does_not():
    """Regression pin for the `(1 - done)` target mask: a transition cut
    by the time limit (`done=1, terminated=0`) must still bootstrap its
    TD target; a real terminal (`terminated=1`) must not.  Under the old
    mask both batches produced identical TDs."""
    dqn = make_dqn(DQNConfig(agent="dqn", num_envs=1, replay_size=64,
                             batch=4))
    params = dqn.init(jax.random.key(0)).params
    zeros = jax.tree.map(jnp.zeros_like, params)
    w = jnp.ones(4)
    step = jnp.int32(0)
    trunc = _batch(done=jnp.ones(4), terminated=jnp.zeros(4))
    term = _batch(done=jnp.ones(4), terminated=jnp.ones(4))
    _, _, _, td_trunc, _ = dqn.learn(params, params, zeros, zeros, step,
                                     trunc, w)
    _, _, _, td_term, _ = dqn.learn(params, params, zeros, zeros, step,
                                    term, w)
    boot = np.asarray(
        dqn.q_apply(params, trunc["next_obs"]).max(-1))
    # td = qa - target; removing the bootstrap raises td by gamma * maxQ
    diff = np.asarray(td_term) - np.asarray(td_trunc)
    np.testing.assert_allclose(diff, dqn.cfg.gamma * boot,
                               rtol=1e-5, atol=1e-6)
    assert np.abs(diff).max() > 1e-4  # the fixture actually exercises it


def test_double_targets_decouple_argmax_from_evaluation():
    """With target == online params the Double-DQN target equals the
    vanilla max target (same td); with decoupled target params whose
    argmax disagrees, the targets must differ."""
    cfg_v = DQNConfig(agent="dqn", num_envs=1, replay_size=64, batch=4)
    cfg_d = DQNConfig(agent="double", num_envs=1, replay_size=64, batch=4)
    dqn_v, dqn_d = make_dqn(cfg_v), make_dqn(cfg_d)
    params = dqn_v.init(jax.random.key(0)).params
    batch = _batch(done=jnp.zeros(4), terminated=jnp.zeros(4))
    w = jnp.ones(4)
    zeros = jax.tree.map(jnp.zeros_like, params)
    step = jnp.int32(0)
    _, _, _, td_v, _ = dqn_v.learn(params, params, zeros, zeros, step,
                                   batch, w)
    _, _, _, td_d, _ = dqn_d.learn(params, params, zeros, zeros, step,
                                   batch, w)
    np.testing.assert_allclose(np.asarray(td_v), np.asarray(td_d),
                               rtol=1e-5, atol=1e-6)
    target = dqn_v.init(jax.random.key(9)).params  # decoupled target net
    qn = dqn_v.q_apply(target, batch["next_obs"])
    a_online = jnp.argmax(dqn_v.q_apply(params, batch["next_obs"]), -1)
    a_target = jnp.argmax(qn, -1)
    assert bool((a_online != a_target).any())  # fixture exercises the split
    _, _, _, td_v2, _ = dqn_v.learn(params, target, zeros, zeros, step,
                                    batch, w)
    _, _, _, td_d2, _ = dqn_d.learn(params, target, zeros, zeros, step,
                                    batch, w)
    # vanilla bootstraps max_a Q_target; double bootstraps the online
    # argmax evaluated under the target net -> <= max, different where
    # the argmaxes split
    boot_v = np.asarray(qn.max(-1))
    boot_d = np.asarray(jnp.take_along_axis(qn, a_online[:, None], 1)[:, 0])
    assert (boot_d <= boot_v + 1e-6).all()
    diff = np.asarray(td_v2) - np.asarray(td_d2)
    split = np.asarray(a_online != a_target)
    assert np.abs(diff[split]).max() > 1e-6


@pytest.mark.parametrize("agent", sorted(AGENTS))
def test_agent_family_trains_smoke(agent):
    """Every family member x n-step composes end-to-end in the scan
    trainer with finite outputs (the learning-quality pins live in the
    slow tier and benchmarks/table1_learning.py)."""
    cfg = DQNConfig(agent=agent, n_step=2, sampler="amper-fr", num_envs=2,
                    replay_size=128, batch=16, learn_start=20,
                    eps_decay_steps=100, target_sync=10, v_max=8.0)
    dqn = make_dqn(cfg)
    state, metrics = dqn.train(jax.random.key(0), 60)
    assert np.isfinite(np.asarray(metrics["return_mean"])).all()
    for leaf in jax.tree.leaves(state.params):
        assert bool(jnp.all(jnp.isfinite(leaf)))
    assert np.isfinite(float(dqn.evaluate(state, jax.random.key(1), 2)))


@pytest.mark.parametrize("env", ["breakout", "freeway"])
def test_pixel_agent_trains_smoke(env):
    """Pixel envs route through the frame store + conv head end-to-end:
    uint8 stacked policy input, frame-deduplicated replay, sample-time
    materialization — finite params and eval after a short run."""
    cfg = DQNConfig(env=env, agent="dqn", sampler="amper-fr", num_envs=2,
                    replay_size=256, batch=16, hidden=32, history_len=4,
                    learn_start=30, eps_decay_steps=100, target_sync=10,
                    v_max=8.0)
    dqn = make_dqn(cfg)
    assert dqn.replay.frame_store is not None
    state, metrics = dqn.train(jax.random.key(0), 80)
    assert state.obs.dtype == jnp.uint8        # actor carries raw stacks
    assert np.isfinite(np.asarray(metrics["return_mean"])).all()
    for leaf in jax.tree.leaves(state.params):
        assert bool(jnp.all(jnp.isfinite(leaf)))
    assert np.isfinite(float(dqn.evaluate(state, jax.random.key(1), 2)))


@pytest.mark.slow
@pytest.mark.parametrize("agent,n_step,sampler",
                         [("double", 3, "amper-fr"),
                          ("double", 3, "per-cumsum"),
                          ("dueling", 1, "amper-fr")])
def test_agent_family_learns_cartpole(agent, n_step, sampler):
    """Family-wide Fig. 8 claim at smoke scale: Double/Dueling variants
    with n-step replay learn CartPole under AMPER just like under exact
    PER (the acceptance config `agent='double', n_step=3`)."""
    cfg = DQNConfig(env="cartpole", agent=agent, n_step=n_step,
                    sampler=sampler, replay_size=2000,
                    eps_decay_steps=3000, learn_start=200)
    dqn = make_dqn(cfg)
    state, _ = dqn.train(jax.random.key(0), 6000)
    score = float(dqn.evaluate(state, jax.random.key(9), 10))
    assert score > 80, (agent, n_step, sampler, score)


@pytest.mark.slow
@pytest.mark.parametrize("sampler", ["per-sumtree", "amper-fr"])
def test_dqn_learns_cartpole(sampler):
    """Paper Fig. 8 claim at smoke scale: both PER and AMPER learn; a
    trained agent beats the random policy by a wide margin."""
    cfg = DQNConfig(env="cartpole", sampler=sampler, replay_size=2000,
                    eps_decay_steps=3000, learn_start=200)
    dqn = make_dqn(cfg)
    state, metrics = dqn.train(jax.random.key(0), 6000)
    test_score = float(dqn.evaluate(state, jax.random.key(9), 10))
    # random policy scores ~20 on CartPole; learned should far exceed
    assert test_score > 80, (sampler, test_score)


@pytest.mark.slow
def test_amper_within_factor_of_per():
    """Table 1 claim at smoke scale: AMPER-fr within a reasonable factor
    of PER's test score on the same seed/budget."""
    scores = {}
    for sampler in ("per-sumtree", "amper-fr"):
        cfg = DQNConfig(env="cartpole", sampler=sampler, replay_size=2000,
                        eps_decay_steps=3000, learn_start=200)
        dqn = make_dqn(cfg)
        state, _ = dqn.train(jax.random.key(0), 6000)
        scores[sampler] = float(dqn.evaluate(state, jax.random.key(9), 10))
    assert scores["amper-fr"] > 0.5 * scores["per-sumtree"], scores
