"""Serving correctness: prefill+decode must reproduce teacher-forced logits."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_reduced_config
from repro.models.model_api import Model
from repro.models import transformer

B, S, MAXLEN = 2, 16, 24


def _reduced(arch):
    return get_reduced_config(arch, dtype="float32", rwkv_mode="recurrent",
                              remat=False, capacity_factor=64.0)


@pytest.mark.parametrize("arch", [a for a in ARCH_IDS if a != "whisper-tiny"])
def test_decode_matches_forward(arch):
    cfg = _reduced(arch)
    m = Model.from_config(cfg)
    params = m.init_params(jax.random.key(1))
    toks = jax.random.randint(jax.random.key(2), (B, S), 0, cfg.vocab_size,
                              dtype=jnp.int32)
    if cfg.vis_prefix_len:
        pe = jax.random.normal(jax.random.key(3),
                               (B, cfg.vis_prefix_len, cfg.d_model))
        full, _ = transformer.forward(cfg, params, toks, extra_embeds=pe)
        last, cache = m.prefill(params, {"tokens": toks, "patch_embeds": pe},
                                MAXLEN + cfg.vis_prefix_len)
        np.testing.assert_allclose(np.asarray(last), np.asarray(full[:, -1]),
                                   atol=3e-4)
        return
    full, _ = transformer.forward(cfg, params, toks)
    # prefill logits at last position
    last, cache = m.prefill(params, {"tokens": toks}, MAXLEN)
    np.testing.assert_allclose(np.asarray(last), np.asarray(full[:, -1]),
                               atol=3e-4, err_msg=f"{arch} prefill")
    # token-by-token decode from empty cache
    cache2 = m.init_cache(B, MAXLEN)
    dec = jax.jit(m.decode_step)
    outs = []
    for t in range(S):
        lg, cache2 = dec(params, toks[:, t:t + 1], cache2)
        outs.append(lg[:, 0])
    np.testing.assert_allclose(np.asarray(jnp.stack(outs, 1)),
                               np.asarray(full), atol=5e-4,
                               err_msg=f"{arch} decode")


def test_whisper_decode_matches_forward():
    from repro.models import encdec
    cfg = _reduced("whisper-tiny")
    m = Model.from_config(cfg)
    params = m.init_params(jax.random.key(1))
    toks = jax.random.randint(jax.random.key(2), (B, S), 0, cfg.vocab_size,
                              dtype=jnp.int32)
    frames = jax.random.normal(jax.random.key(3), (B, cfg.enc_seq, cfg.d_model))
    full = encdec.forward(cfg, params, toks, frames)
    logits0, cache = m.prefill(params, {"frames": frames, "tokens": toks},
                               MAXLEN)
    outs = [logits0[:, 0]]
    dec = jax.jit(m.decode_step)
    for t in range(1, S):
        lg, cache = dec(params, toks[:, t:t + 1], cache)
        outs.append(lg[:, 0])
    np.testing.assert_allclose(np.asarray(jnp.stack(outs, 1)),
                               np.asarray(full), atol=5e-4)


def test_swa_ring_cache_equivalence():
    """Sliding-window arch decodes identically whether the cache holds the
    full history or only the masked window (h2o-danube geometry)."""
    cfg = _reduced("h2o-danube-3-4b")
    m = Model.from_config(cfg)
    params = m.init_params(jax.random.key(4))
    toks = jax.random.randint(jax.random.key(5), (B, 48), 0, cfg.vocab_size,
                              dtype=jnp.int32)
    full, _ = transformer.forward(cfg, params, toks)
    cache = m.init_cache(B, 64)
    dec = jax.jit(m.decode_step)
    outs = []
    for t in range(48):
        lg, cache = dec(params, toks[:, t:t + 1], cache)
        outs.append(lg[:, 0])
    np.testing.assert_allclose(np.asarray(jnp.stack(outs, 1)),
                               np.asarray(full), atol=5e-4)
