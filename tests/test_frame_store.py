"""Frame-deduplicated pixel replay: the uint8 frame store must be an
*exact* drop-in for a naive float buffer.

The core pin is bit-exact materialization: a numpy reference replays the
full add stream (per-env episodes, ring wraparound, warm-up) and builds
the stacked float obs / sample-time n-step return every anchor *should*
produce; ``materialize`` must match it bitwise — including the zero
padding at episode starts, the masking of chains cut by the write head,
and the exact f32 ``frame * scale`` conversion the actor uses.

Plus the pixel-mode system guarantees: uint8 storage round-trips through
the replay checkpoint (incl. elastic 8->2->1 shard restore), every
``fr_mode`` draws bit-identical materialized batches on 1/2/8-shard
meshes, and a killed + resumed sync pixel run equals an uninterrupted
one bitwise.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.replay_buffer import FrameStore, ReplayBuffer
from repro.core.samplers import make_sampler
from repro.rl.dqn import DQNConfig, make_dqn
from repro.runtime import ReplayService
from repro.train import replay_checkpoint as rck
from repro.train.checkpoint import CheckpointManager

HW = (5, 5)


# --- numpy stream reference ---------------------------------------------------


def _gen_stream(seed, n_envs, n_steps, p_done=0.15):
    """Per-env episode streams flattened to global add order: the row
    written at global counter ``t`` is env ``t % n_envs`` at vectorized
    step ``t // n_envs`` (exactly how ``add_batch`` lays out lockstep
    arcs)."""
    rng = np.random.default_rng(seed)
    T = n_envs * n_steps
    return {
        "frame": rng.integers(0, 256, size=(T,) + HW, dtype=np.uint8),
        "action": rng.integers(0, 3, size=T).astype(np.int32),
        "reward": rng.standard_normal(T).astype(np.float32),
        "done": (rng.random(T) < p_done).astype(np.float32),
    }


def _fill(rb, hist, n_envs):
    st = rb.init({"frame": jnp.zeros(HW, jnp.uint8),
                  "action": jnp.int32(0), "reward": jnp.float32(0),
                  "done": jnp.float32(0)})
    T = len(hist["frame"])
    for v in range(T // n_envs):
        rows = slice(v * n_envs, (v + 1) * n_envs)
        st = rb.add_batch(st, {k: jnp.asarray(hist[k][rows])
                               for k in hist})
    return st


def _ref_materialize(hist, cap, fs):
    """Replay the stream on host and build what every anchor slot must
    materialize to.  Mirrors the device arithmetic operation-for-
    operation (f32 accumulation order included) so the comparison can be
    bitwise."""
    T = len(hist["frame"])
    size = min(T, cap)
    K, S, N = fs.history_len, fs.stride, fs.n_step
    scale = np.float32(fs.scale)

    def latest(slot):          # newest stream time resident in `slot`
        return slot + ((T - 1 - slot) // cap) * cap

    def live(t):               # stream time t still in the ring
        return t >= 0 and t >= T - cap

    def stack(slot, base_ok):
        ta = latest(slot) if slot < size else -1
        frames, ok = [], base_ok
        for j in range(K):
            t = ta - j * S
            if j > 0:
                ok = ok and live(t) and hist["done"][t] < 0.5
            f = (hist["frame"][t].astype(np.float32) * scale if ok
                 else np.zeros(HW, np.float32))
            frames.append(f)
        return np.stack(frames[::-1], axis=-1)

    out = {k: [] for k in ("obs", "action", "reward", "next_obs", "done")}
    for slot in range(cap):
        written = slot < size
        ta = latest(slot) if written else -1
        out["obs"].append(stack(slot, written))
        out["action"].append(hist["action"][ta] if written else
                             np.asarray(hist["action"][0]) * 0)
        enter, reward = np.float32(written), np.float32(0.0)
        for k in range(N):
            t = ta + k * S
            avail = written and t < T
            use = enter * np.float32(avail)
            if avail:
                reward = reward + (use * np.float32(float(fs.gamma ** k))
                                   ) * hist["reward"][t]
                enter = use * (np.float32(1.0) - hist["done"][t])
            else:
                enter = np.float32(0.0)
        tb = ta + N * S
        has_boot = bool(enter > 0.5) and tb < T
        out["reward"].append(reward)
        out["next_obs"].append(stack(tb % cap, has_boot) if has_boot
                               else np.zeros(HW + (K,), np.float32))
        out["done"].append(np.float32(not has_boot))
    return {k: np.stack(v) for k, v in out.items()}


@pytest.mark.parametrize("cap,K,n_envs,N,steps", [
    (32, 4, 1, 1, 50),      # single stream, ring wrapped once
    (32, 4, 1, 1, 10),      # warm-up: most of the ring unwritten
    (48, 3, 1, 3, 70),      # sample-time n-step across the wrap
    (40, 4, 2, 2, 18),      # two interleaved env streams (stride=2)
    (64, 2, 4, 1, 40),      # wider stride, short stacks, two laps
])
def test_materialize_bit_exact_vs_stream_reference(cap, K, n_envs, N, steps):
    fs = FrameStore(history_len=K, frame_shape=HW, stride=n_envs,
                    n_step=N, gamma=0.9)
    rb = ReplayBuffer(cap, make_sampler("uniform", cap), frame_store=fs)
    hist = _gen_stream(7 * cap + K, n_envs, steps)
    st = _fill(rb, hist, n_envs)
    got = rb.materialize(st, jnp.arange(cap))
    ref = _ref_materialize(hist, cap, fs)
    np.testing.assert_array_equal(np.asarray(got["obs"]), ref["obs"])
    np.testing.assert_array_equal(np.asarray(got["next_obs"]),
                                  ref["next_obs"])
    np.testing.assert_array_equal(np.asarray(got["reward"]), ref["reward"])
    np.testing.assert_array_equal(np.asarray(got["done"]), ref["done"])
    np.testing.assert_array_equal(np.asarray(got["terminated"]),
                                  ref["done"])
    written = np.arange(cap) < int(st.size)
    np.testing.assert_array_equal(np.asarray(got["action"])[written],
                                  ref["action"][written])


def test_episode_boundary_zero_pads_like_naive_buffer():
    """A stack whose backward chain crosses a ``done`` row zeroes every
    older frame — byte-for-byte the padding a naive float buffer records
    at an episode start."""
    fs = FrameStore(history_len=4, frame_shape=HW)
    rb = ReplayBuffer(32, make_sampler("uniform", 32), frame_store=fs)
    hist = _gen_stream(3, 1, 12, p_done=0.0)
    hist["done"][5] = 1.0                      # one episode cut at t=5
    st = _fill(rb, hist, 1)
    got = np.asarray(rb.materialize(st, jnp.arange(32))["obs"])
    # anchor t=7: chain 7,6 valid; 5 is done -> frames 5,4 masked
    scale = np.float32(1.0 / 255.0)
    expect = np.stack([np.zeros(HW, np.float32),
                       np.zeros(HW, np.float32),
                       hist["frame"][6].astype(np.float32) * scale,
                       hist["frame"][7].astype(np.float32) * scale],
                      axis=-1)
    np.testing.assert_array_equal(got[7], expect)
    # anchor t=5 (the done row itself) keeps its full history
    assert (got[5] != 0).any(axis=(0, 1)).all()


def test_frame_store_config_validation():
    with pytest.raises(ValueError, match="n_step=1"):
        ReplayBuffer(64, make_sampler("uniform", 64), n_step=3,
                     frame_store=FrameStore(4, HW))
    with pytest.raises(ValueError, match="capacity"):
        ReplayBuffer(16, make_sampler("uniform", 16),
                     frame_store=FrameStore(8, HW, stride=2))
    rb = ReplayBuffer(64, make_sampler("uniform", 64),
                      frame_store=FrameStore(4, HW))
    with pytest.raises(ValueError, match="frame"):
        rb.init({"obs": jnp.zeros(4), "reward": jnp.float32(0)})
    with pytest.raises(ValueError, match="uint8"):
        rb.init({"frame": jnp.zeros(HW, jnp.float32),
                 "action": jnp.int32(0), "reward": jnp.float32(0),
                 "done": jnp.float32(0)})


# --- uint8 checkpoint round-trip / elastic restore ---------------------------


def _pixel_rb(sampler):
    return ReplayBuffer(256, sampler,
                        frame_store=FrameStore(history_len=4,
                                               frame_shape=HW, n_step=2))


PIX_EX = {"frame": jnp.zeros(HW, jnp.uint8), "action": jnp.int32(0),
          "reward": jnp.float32(0), "done": jnp.float32(0)}


def test_uint8_replay_checkpoint_roundtrips_bitwise(tmp_path):
    rb = _pixel_rb(make_sampler("amper-fr", 256, v_max=8.0))
    hist = _gen_stream(11, 1, 300)
    st = _fill(rb, hist, 1)
    idx, _, _ = rb.sample(st, jax.random.key(0), 32)
    st = rb.update_priorities(st, idx, jnp.ones(32))
    rck.save_replay(str(tmp_path), 5, st)
    out = rck.restore_replay(str(tmp_path), 5, rb, PIX_EX)
    assert out.storage["frame"].dtype == jnp.uint8
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # restored buffer materializes the identical float batch
    np.testing.assert_array_equal(
        np.asarray(rb.materialize(st, jnp.arange(256))["obs"]),
        np.asarray(rb.materialize(out, jnp.arange(256))["obs"]))


@pytest.mark.parametrize("to_shards", [2, 1])
def test_uint8_elastic_restore_onto_fewer_shards(tmp_path, to_shards):
    """A pixel buffer saved on 8 shards restores onto 2 (and 1) with the
    uint8 frames, stamps, and priorities all bitwise intact — and the
    restored buffer samples identical materialized batches."""
    if jax.device_count() < 8:
        pytest.skip("needs 8 devices")

    def sharded(n):
        mesh = jax.make_mesh((n,), ("data",))
        return _pixel_rb(make_sampler("amper-fr-sharded", 256, mesh=mesh,
                                      axis_names=("data",), v_max=8.0))

    rb8 = sharded(8)
    hist = _gen_stream(13, 1, 300)
    st8 = _fill(rb8, hist, 1)
    rck.save_replay(str(tmp_path), 2, st8)
    rb = sharded(to_shards)
    st = rck.restore_replay(str(tmp_path), 2, rb, PIX_EX)
    assert st.storage["frame"].dtype == jnp.uint8
    np.testing.assert_array_equal(np.asarray(st8.storage["frame"]),
                                  np.asarray(st.storage["frame"]))
    np.testing.assert_array_equal(
        np.asarray(rb8.sampler.priorities(st8.sampler_state)),
        np.asarray(rb.sampler.priorities(st.sampler_state)))
    # membership is shard-count invariant; the drawn offsets are not —
    # so compare the materialized float batch at the SAME anchors.
    np.testing.assert_array_equal(
        np.asarray(rb8.sampler.membership(st8.sampler_state,
                                          jax.random.key(42))),
        np.asarray(rb.sampler.membership(st.sampler_state,
                                         jax.random.key(42))))
    anchors = jnp.arange(256)
    for k in ("obs", "next_obs", "reward", "done"):
        np.testing.assert_array_equal(
            np.asarray(rb8.materialize(st8, anchors)[k]),
            np.asarray(rb.materialize(st, anchors)[k]), err_msg=k)
    # ...and the restored buffer keeps training: full pixel cycle runs
    idx, batch, w = rb.sample(st, jax.random.key(4), 64)
    st = rb.update_priorities(st, idx, jnp.ones(64))
    assert np.isfinite(np.asarray(batch["obs"])).all()
    assert np.isfinite(np.asarray(w)).all()


# --- fr_mode x shard-count bit-identity on the pixel path --------------------


def _pixel_rb_cap(cap, sampler):
    return ReplayBuffer(cap, sampler,
                        frame_store=FrameStore(history_len=4,
                                               frame_shape=HW, n_step=2))


def test_pixel_fr_modes_bit_identical_dense():
    """Acceptance: on the dense single-device sampler, every fr_mode
    (incl. the fused Pallas dispatch) draws bit-identical indices, IS
    weights, AND materialized pixel batches."""
    cap = 512
    hist = _gen_stream(17, 1, 600)
    out = {}
    for mode in ("broadcast", "interval", "window", "kernel", "fused"):
        rb = _pixel_rb_cap(cap, make_sampler("amper-fr", cap, v_max=8.0,
                                             fr_mode=mode))
        st = _fill(rb, hist, 1)
        idx, batch, w = rb.sample(st, jax.random.key(21), 64)
        out[mode] = (np.asarray(idx), np.asarray(batch["obs"]),
                     np.asarray(batch["reward"]), np.asarray(w))
    base = out.pop("broadcast")
    for mode, got in out.items():
        for a, b in zip(base, got):
            np.testing.assert_array_equal(a, b, err_msg=mode)


@pytest.mark.parametrize("n_shards", [1, 2, 8])
def test_pixel_fused_bit_identical_per_mesh(n_shards):
    """Acceptance: fused == broadcast (indices, weights, materialized
    pixel batch) through the frame-store buffer on 1/2/8-shard meshes."""
    if jax.device_count() < 8:
        pytest.skip("needs 8 devices")
    cap = 512
    hist = _gen_stream(19, 1, 600)
    out = {}
    for mode in ("broadcast", "fused"):
        s = make_sampler("amper-fr-sharded", cap, v_max=8.0, fr_mode=mode,
                         mesh=jax.make_mesh((n_shards,), ("data",)))
        rb = _pixel_rb_cap(cap, s)
        st = _fill(rb, hist, 1)
        idx, batch, w = rb.sample(st, jax.random.key(23), 64)
        out[mode] = (np.asarray(idx), np.asarray(batch["obs"]),
                     np.asarray(batch["reward"]), np.asarray(w))
    for a, b in zip(out["broadcast"], out["fused"]):
        np.testing.assert_array_equal(a, b)


# --- sync kill/resume on a pixel env -----------------------------------------


PIX_CFG = DQNConfig(env="breakout", sampler="amper-fr", num_envs=2,
                    replay_size=256, batch=16, learn_start=30,
                    history_len=4, eps_decay_steps=200, target_sync=25)


def test_pixel_sync_kill_resume_bit_identical(tmp_path):
    """Acceptance pin: a checkpointed + killed + resumed pixel run (conv
    head, frame-store replay, amper-fr) equals the uninterrupted run
    bitwise — params and the full uint8 replay state."""
    n = 70
    key = jax.random.key(6)
    svc = ReplayService(PIX_CFG, sync=True, num_actors=1)
    baseline = svc.run(key, n)
    mgr = CheckpointManager(str(tmp_path), save_interval=20)
    mgr.request_preemption()
    r1 = svc.run(key, n, manager=mgr)
    assert r1.metrics["preempted_at"] is not None
    r2 = svc.run(key, n, manager=CheckpointManager(str(tmp_path),
                                                   save_interval=20))
    for a, b in zip(jax.tree.leaves(baseline.params),
                    jax.tree.leaves(r2.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(baseline.buffer),
                    jax.tree.leaves(r2.buffer)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert baseline.buffer.storage["frame"].dtype == jnp.uint8


def test_frame_store_service_requires_single_actor():
    with pytest.raises(ValueError, match="num_actors"):
        ReplayService(PIX_CFG, sync=False, num_actors=2)
