"""Beyond-paper performance knobs: correctness before speed."""
import jax
import jax.numpy as jnp
import numpy as np

import repro.core.quantize as qz
from repro.configs import get_reduced_config
from repro.core.amper import AmperConfig, build_csp_k
from repro.models import transformer
from repro.models.model_api import Model
from repro.train import train_step as ts_mod
from repro.train.optimizer import (AdamW, dequantize_int8, ef_compress_tree,
                                   quantize_int8)


def _batch(cfg, key, b=2, s=32):
    toks = jax.random.randint(key, (b, s + 1), 0, cfg.vocab_size, jnp.int32)
    return {"tokens": toks[:, :-1], "targets": toks[:, 1:],
            "loss_mask": jnp.ones((b, s), jnp.float32)}


def test_blockwise_ce_matches_standard():
    cfg = get_reduced_config("stablelm-1.6b", dtype="float32")
    cfg_b = get_reduced_config("stablelm-1.6b", dtype="float32", ce_block=64)
    m = Model.from_config(cfg)
    params = m.init_params(jax.random.key(0))
    batch = _batch(cfg, jax.random.key(1))
    l1, _ = transformer.lm_loss(cfg, params, batch)
    l2, _ = transformer.lm_loss(cfg_b, params, batch)
    np.testing.assert_allclose(float(l1), float(l2), atol=1e-5)
    g1 = jax.grad(lambda p: transformer.lm_loss(cfg, p, batch)[0])(params)
    g2 = jax.grad(lambda p: transformer.lm_loss(cfg_b, p, batch)[0])(params)
    for a, b2 in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b2), atol=1e-5)


def test_blockwise_ce_nondivisible_vocab():
    cfg_b = get_reduced_config("stablelm-1.6b", dtype="float32",
                               vocab_size=250, ce_block=64)
    cfg = cfg_b.reduced(vocab_size=250, ce_block=0) if False else \
        get_reduced_config("stablelm-1.6b", dtype="float32", vocab_size=250)
    m = Model.from_config(cfg)
    params = m.init_params(jax.random.key(0))
    batch = _batch(cfg, jax.random.key(1))
    l1, _ = transformer.lm_loss(cfg, params, batch)
    l2, _ = transformer.lm_loss(cfg_b, params, batch)
    np.testing.assert_allclose(float(l1), float(l2), atol=1e-5)


def test_mixed_precision_trains():
    cfg = get_reduced_config("stablelm-1.6b", param_dtype="bfloat16")
    m = Model.from_config(cfg)
    opt = AdamW(1e-3, mixed_precision=True)
    state = ts_mod.init_train_state(m, opt, jax.random.key(0))
    assert jax.tree.leaves(state.params)[0].dtype == jnp.bfloat16
    assert jax.tree.leaves(state.opt_state.master)[0].dtype == jnp.float32
    step = jax.jit(ts_mod.make_train_step(m, opt))
    batch = _batch(cfg, jax.random.key(1))
    losses = []
    for _ in range(8):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0]


def test_knn_hist_mode_count_exact():
    n = 20_000
    p = jax.random.uniform(jax.random.key(1), (n,))
    pq = qz.quantize(p, 1.0)
    valid = jnp.ones(n, bool)
    key = jax.random.key(5)
    base = dict(capacity=n, m=12, lam=0.02, v_max=1.0, csp_capacity=n)
    a = build_csp_k(pq, valid, key, AmperConfig(**base, knn_mode="sort"))
    c = build_csp_k(pq, valid, key, AmperConfig(**base, knn_mode="hist"))
    assert int(a.count) == int(c.count)
    # hist members sit at most one 2^12-bin further out in value
    sel_vals = np.sort(np.asarray(p)[np.asarray(c.selected)])
    ref_vals = np.sort(np.asarray(p)[np.asarray(a.selected)])
    np.testing.assert_allclose(sel_vals, ref_vals, atol=2 * (2 ** 12) / (2 ** 24 - 1) + 1e-5)


def test_int8_error_feedback_roundtrip():
    g = {"w": jax.random.normal(jax.random.key(0), (64, 64)) * 0.01}
    e = jax.tree.map(jnp.zeros_like, g)
    total = jax.tree.map(jnp.zeros_like, g)
    acc_err = e
    # EF property: sum of dequantised transmissions tracks sum of grads
    for i in range(20):
        gi = jax.tree.map(lambda x: x * (1 + 0.1 * i), g)
        (q, errs) = ef_compress_tree(gi, acc_err)
        acc_err = errs
        sent = jax.tree.map(lambda qq: dequantize_int8(*qq),
                            q, is_leaf=lambda x: isinstance(x, tuple))
        total = jax.tree.map(lambda t, s_: t + s_, total, sent)
    true_total = jax.tree.map(lambda x: x * sum(1 + 0.1 * i for i in range(20)), g)
    err = float(jnp.max(jnp.abs(total["w"] + acc_err["w"] - true_total["w"])))
    np.testing.assert_allclose(err, 0.0, atol=1e-4)


def test_attn_block_skip_bit_exact():
    """Causal/window block-skipping never changes logits (it only skips
    fully-masked blocks)."""
    from repro.configs import get_reduced_config
    from repro.models import transformer
    from repro.models.model_api import Model
    for arch in ("stablelm-1.6b", "h2o-danube-3-4b"):
        cfg_on = get_reduced_config(arch, dtype="float32",
                                    attn_block_skip=True)
        cfg_off = get_reduced_config(arch, dtype="float32",
                                     attn_block_skip=False)
        m = Model.from_config(cfg_on)
        params = m.init_params(jax.random.key(0))
        toks = jax.random.randint(jax.random.key(1), (2, 96), 0,
                                  cfg_on.vocab_size, dtype=jnp.int32)
        a, _ = transformer.forward(cfg_on, params, toks)
        b, _ = transformer.forward(cfg_off, params, toks)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
