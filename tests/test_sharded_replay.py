"""Sharded replay subsystem: sampling-law equivalence + mesh integration.

Differential/statistical harness for the mesh-native samplers (cf. Panahi
et al.: silently-divergent sampling distributions corrupt learning
results, so the sharded fronts must provably draw by the same law as
their single-device counterparts):

* every ``fr_mode`` (broadcast / interval / window / kernel / fused)
  produces bit-identical CSP membership, including invalid rows and
  saturated top-code priorities;
* ``fr_mode="fused"`` (the single-dispatch Pallas draw) returns
  bit-identical sampled indices AND importance weights vs "broadcast",
  on single-device and 2/8-shard meshes;
* ``ShardedAmperSampler`` membership == single-device ``build_csp_fr``
  exactly, on 1/2/8-shard meshes;
* ``ShardedPERSampler`` agrees with the PER law P(i) = p_i / sum p by
  chi-square, on 1/2/8-shard meshes;
* the empty-CSP fallback path draws uniformly with its own key (the
  correlated-key regression);
* registry + replay buffer + DQN integration on the mesh.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

import repro.core.quantize as qz
from repro.core.amper import AmperConfig, build_csp_fr
from repro.core.replay_buffer import ReplayBuffer
from repro.core.samplers import Sampler, available_samplers, make_sampler

FR_MODES = ("broadcast", "interval", "window", "kernel", "fused")


def _mesh_of(n_shards):
    if jax.device_count() < n_shards:
        pytest.skip(f"needs {n_shards} devices")
    return Mesh(np.asarray(jax.devices()[:n_shards]), ("data",))


def _random_table(seed, n, v_max=1.0, saturate=True, invalidate=True):
    """Priorities incl. v_max-clipped (top-code) rows + invalid rows."""
    k = jax.random.key(seed)
    hi = v_max * (1.25 if saturate else 1.0)
    p = jax.random.uniform(jax.random.fold_in(k, 1), (n,), minval=0.0,
                           maxval=hi)
    valid = (jax.random.bernoulli(jax.random.fold_in(k, 2), 0.85, (n,))
             if invalidate else jnp.ones(n, bool))
    return qz.quantize(p, v_max), valid, p


# --- fr_mode equivalence -----------------------------------------------------


@pytest.mark.parametrize("seed,m,lam_fr", [
    (0, 8, 2.0), (1, 20, 2.0), (2, 2, 3.5), (3, 13, 0.3), (4, 20, 1.0),
])
def test_fr_modes_bit_identical(seed, m, lam_fr):
    """All fr_mode variants select the exact same CSP membership on
    randomized tables with invalid rows and saturated priorities."""
    n = 2048
    pq, valid, _ = _random_table(seed, n)
    key = jax.random.key(100 + seed)
    sel = {}
    for mode in FR_MODES:
        cfg = AmperConfig(capacity=n, m=m, lam_fr=lam_fr, v_max=1.0,
                          csp_capacity=n, fr_mode=mode)
        sel[mode] = np.asarray(build_csp_fr(pq, valid, key, cfg).selected)
    for mode in FR_MODES[1:]:
        np.testing.assert_array_equal(sel[mode], sel["broadcast"],
                                      err_msg=f"fr_mode={mode}")


def test_fr_mode_kernel_through_registry():
    """`make_sampler(..., fr_mode="kernel")` puts the fused Pallas search
    on the sampling hot path and still draws valid prioritized batches."""
    n = 4096
    _, _, p = _random_table(7, n, saturate=False, invalidate=False)
    s = make_sampler("amper-fr", n, v_max=1.0, fr_mode="kernel")
    st = s.update(s.init(), jnp.arange(n), p)
    idx = jax.jit(lambda k: s.sample(st, k, 4096))(jax.random.key(0))
    assert bool(jnp.all((idx >= 0) & (idx < n)))
    assert float(p[idx].mean()) > float(p.mean()) + 0.03


# --- sharded AMPER == single device ------------------------------------------


@pytest.mark.parametrize("n_shards", [1, 2, 8])
@pytest.mark.parametrize("fr_mode", ["broadcast", "kernel", "fused"])
def test_sharded_amper_membership_exact(n_shards, fr_mode):
    """Sharded CSP membership is bit-identical to single-device
    build_csp_fr under the same key, for any shard count."""
    mesh = _mesh_of(n_shards)
    n = 2048
    pq, valid, p = _random_table(11, n)
    s = make_sampler("amper-fr-sharded", n, v_max=1.0, m=8,
                     fr_mode=fr_mode, mesh=mesh)
    st = s.update(s.init(), jnp.arange(n), jnp.where(valid, p, 0.0))
    # the sampler quantizes on update; compare against ITS stored table so
    # the reference sees identical inputs
    key = jax.random.key(21)
    got = np.asarray(s.membership(st, key))
    cfg = s.cfg
    expect = np.asarray(
        build_csp_fr(st.pq, st.valid, key, cfg._replace(fr_mode="broadcast")
                     ).selected)
    np.testing.assert_array_equal(got, expect)


@pytest.mark.parametrize("n_shards", [2, 8])
def test_sharded_amper_draws_within_membership(n_shards):
    """Every drawn index is a CSP member (same key): the count-prefix
    owner/offset selection never fabricates indices."""
    mesh = _mesh_of(n_shards)
    n = 1024
    pq, valid, p = _random_table(13, n)
    s = make_sampler("amper-fr-sharded", n, v_max=1.0, m=8, mesh=mesh)
    st = s.update(s.init(), jnp.arange(n), jnp.where(valid, p, 0.0))
    key = jax.random.key(5)
    members = np.asarray(s.membership(st, key))
    idx = np.asarray(s.sample(st, key, 512))
    assert members[idx].all(), "sampled a non-member row"


# --- fused draw: bit-identical indices AND weights (acceptance) ---------------


@pytest.mark.parametrize("n_shards", [1, 2, 8])
def test_sharded_fused_sample_bit_identical(n_shards):
    """fr_mode='fused' (rank_select pick) draws the exact indices of the
    reference nonzero-compaction pick on real 1/2/8-shard meshes."""
    mesh = _mesh_of(n_shards)
    n = 2048
    pq, valid, p = _random_table(17, n)
    idx = {}
    for mode in ("broadcast", "fused"):
        s = make_sampler("amper-fr-sharded", n, v_max=1.0, m=8,
                         fr_mode=mode, mesh=mesh)
        st = s.update(s.init(), jnp.arange(n), jnp.where(valid, p, 0.0))
        idx[mode] = np.asarray(s.sample(st, jax.random.key(23), 256))
    np.testing.assert_array_equal(idx["fused"], idx["broadcast"])


def test_fused_replay_weights_bit_identical():
    """Acceptance: single-device replay buffer with fr_mode='fused' returns
    bit-identical sampled indices AND importance weights vs 'broadcast'
    (shared weight formula, importance_from_selected)."""
    cap, b = 4096, 512
    out = {}
    for mode in ("broadcast", "fused"):
        s = make_sampler("amper-fr", cap, v_max=4.0, fr_mode=mode)
        rb = ReplayBuffer(cap, s)
        state = rb.init({"obs": jnp.zeros(3), "reward": jnp.float32(0)})
        for i in range(3):
            state = rb.add_batch(
                state, {"obs": jnp.full((b, 3), float(i)),
                        "reward": jnp.arange(b, dtype=jnp.float32)})
        idx, _, w = rb.sample(state, jax.random.key(3), 64)
        out[mode] = (np.asarray(idx), np.asarray(w))
    np.testing.assert_array_equal(out["fused"][0], out["broadcast"][0])
    np.testing.assert_array_equal(out["fused"][1], out["broadcast"][1])


@pytest.mark.parametrize("n_shards", [2, 8])
def test_sharded_fused_replay_weights_bit_identical(n_shards):
    """Acceptance: same bitwise idx+weights guarantee through the sharded
    replay buffer on 2/8-shard meshes."""
    mesh = _mesh_of(n_shards)
    cap, b = 1024, 128
    out = {}
    for mode in ("broadcast", "fused"):
        s = make_sampler("amper-fr-sharded", cap, v_max=4.0,
                         fr_mode=mode, mesh=mesh)
        rb = ReplayBuffer(cap, s)
        state = rb.init({"obs": jnp.zeros(3), "reward": jnp.float32(0)})
        for i in range(3):
            state = rb.add_batch(
                state, {"obs": jnp.full((b, 3), float(i)),
                        "reward": jnp.arange(b, dtype=jnp.float32)})
        idx, _, w = rb.sample(state, jax.random.key(29), 64)
        out[mode] = (np.asarray(idx), np.asarray(w))
    np.testing.assert_array_equal(out["fused"][0], out["broadcast"][0])
    np.testing.assert_array_equal(out["fused"][1], out["broadcast"][1])


# --- sharded PER == single device (distribution) -----------------------------


@pytest.mark.parametrize("n_shards", [1, 2, 8])
def test_sharded_per_chi_square(n_shards):
    """Empirical sharded-PER draw distribution agrees with the law
    P(i) = p_i / sum p (chi-square, generous threshold)."""
    mesh = _mesh_of(n_shards)
    n = 64
    p = jax.random.uniform(jax.random.key(3), (n,)) + 0.1
    s = make_sampler("per-sharded", n, mesh=mesh)
    st = s.update(s.init(), jnp.arange(n), p)
    draws = 1 << 14
    fn = jax.jit(lambda k: s.sample(st, k, draws))
    counts = np.zeros(n)
    n_reps = 4
    for r in range(n_reps):
        idx = np.asarray(fn(jax.random.key(50 + r)))
        counts += np.bincount(idx, minlength=n)
    total_draws = draws * n_reps
    expect = np.asarray(p / p.sum()) * total_draws
    chi2 = float(((counts - expect) ** 2 / expect).sum())
    df = n - 1
    # mean df, std sqrt(2 df); 6 sigma keeps the flake rate negligible
    assert chi2 < df + 6 * np.sqrt(2 * df), (chi2, df)


def test_sharded_per_matches_cumsum_counterpart():
    """Sharded and single-device PER empirical distributions agree with
    each other (two-sample comparison, same table)."""
    mesh = _mesh_of(8)
    n = 64
    p = jax.random.uniform(jax.random.key(4), (n,)) + 0.1
    sh = make_sampler("per-sharded", n, mesh=mesh)
    cs = make_sampler("per-cumsum", n)
    st_sh = sh.update(sh.init(), jnp.arange(n), p)
    st_cs = cs.update(cs.init(), jnp.arange(n), p)
    draws = 1 << 14
    c_sh = np.bincount(np.asarray(sh.sample(st_sh, jax.random.key(1), draws)),
                       minlength=n)
    c_cs = np.bincount(np.asarray(
        cs.sample(st_cs, jax.random.key(2), draws, stratified=False)),
        minlength=n)
    # both ~multinomial(draws, p/sum p): totals per row within noise
    diff = (c_sh - c_cs) / draws
    assert float(np.abs(diff).max()) < 0.02, diff


# --- empty-CSP fallback (correlated-key regression) --------------------------


def test_empty_csp_fallback_on_mesh():
    """All-invalid table -> uniform fallback draws: in range, well spread
    over every shard's segment."""
    mesh = _mesh_of(8)
    n = 1024
    s = make_sampler("amper-fr-sharded", n, v_max=1.0, mesh=mesh)
    st = s.init()  # nothing valid anywhere
    idx = np.asarray(s.sample(st, jax.random.key(0), 1024))
    assert ((idx >= 0) & (idx < n)).all()
    # every shard's 128-row segment receives draws
    seg_counts = np.bincount(idx // (n // 8), minlength=8)
    assert (seg_counts > 0).all(), seg_counts
    assert len(np.unique(idx)) > 512
    # distinct keys -> distinct fallback batches
    idx2 = np.asarray(s.sample(st, jax.random.key(1), 1024))
    assert not np.array_equal(idx, idx2)


def test_fallback_key_not_reused_for_pick():
    """Regression for the correlated-key bug (old sharded_sample_fr fed
    the SAME subkey to the CSP pick draw and the fallback draw): the
    fallback must not equal a draw from the pick subkey."""
    mesh = _mesh_of(8)
    n = 1024
    s = make_sampler("amper-fr-sharded", n, v_max=1.0, mesh=mesh)
    st = s.init()
    key = jax.random.key(9)
    idx = np.asarray(s.sample(st, key, 256))
    _, kpick = jax.random.split(key)
    buggy_fb = np.asarray(jax.random.randint(kpick, (256,), 0, n))
    assert not np.array_equal(idx, buggy_fb), \
        "fallback reproduced the pick-subkey draw — key reuse is back"


# --- registry / replay buffer / DQN integration ------------------------------


def test_registry_lists_sharded_kinds():
    assert {"amper-fr-sharded", "per-sharded"} <= set(available_samplers())


@pytest.mark.parametrize("kind", ["amper-fr-sharded", "per-sharded"])
def test_sharded_sampler_satisfies_protocol(kind, mesh):
    s = make_sampler(kind, 128, v_max=4.0, min_csp=16, mesh=mesh)
    assert isinstance(s, Sampler)
    st = s.update(s.init(), jnp.arange(8), jnp.full(8, 0.5))
    idx = s.sample(st, jax.random.key(0), 16)
    assert idx.shape == (16,) and bool(jnp.all((idx >= 0) & (idx < 128)))
    assert s.priorities(st).shape == (128,)
    assert float(s.total(st)) > 0


@pytest.mark.parametrize("kind", ["amper-fr-sharded", "per-sharded"])
def test_capacity_must_divide_shards(kind, mesh):
    with pytest.raises(ValueError, match="not divisible"):
        make_sampler(kind, 130, mesh=mesh)


@pytest.mark.parametrize("kind", ["amper-fr-sharded", "per-sharded"])
def test_replay_buffer_sharded_wraparound(kind, mesh):
    """Ring-arc writes + priority updates respect the shard layout; the
    storage stays partitioned like the priority table."""
    cap, b = 16, 10
    s = make_sampler(kind, cap, v_max=4.0, min_csp=4, mesh=mesh)
    rb = ReplayBuffer(cap, s)
    state = rb.init({"obs": jnp.zeros(3), "reward": jnp.float32(0)})
    assert state.storage["obs"].sharding == s.sharding
    tr = lambda val: {"obs": jnp.full((b, 3), val),
                      "reward": jnp.arange(b, dtype=jnp.float32)}
    state = rb.add_batch(state, tr(1.0))              # slots 0..9
    state = rb.add_batch(state, tr(2.0))              # slots 10..15, 0..3
    assert int(state.pos) == (2 * b) % cap and int(state.size) == cap
    obs = np.asarray(state.storage["obs"][:, 0])
    np.testing.assert_array_equal(
        obs, [2, 2, 2, 2, 1, 1, 1, 1, 1, 1, 2, 2, 2, 2, 2, 2])
    assert state.storage["obs"].sharding == s.sharding
    prios = np.asarray(rb.sampler.priorities(state.sampler_state))
    assert (prios > 0).all()
    state = rb.update_priorities(state, jnp.array([3, 12]),
                                 jnp.array([5.0, 9.0]))
    p2 = np.asarray(rb.sampler.priorities(state.sampler_state))
    assert p2[3] != prios[3] and p2[12] != prios[12]
    idx, batch, w = rb.sample(state, jax.random.key(0), 8)
    assert batch["obs"].shape == (8, 3) and w.shape == (8,)


@pytest.mark.slow
def test_dqn_sharded_trains_cartpole(mesh):
    """Acceptance: amper-fr-sharded trains CartPole end-to-end on the
    8-device mesh, within tolerance of the single-device amper-fr run."""
    from repro.rl.dqn import DQNConfig, make_dqn

    scores = {}
    for sampler in ("amper-fr", "amper-fr-sharded"):
        cfg = DQNConfig(env="cartpole", sampler=sampler, replay_size=2000,
                        eps_decay_steps=3000, learn_start=200)
        dqn = make_dqn(cfg)
        state, _ = dqn.train(jax.random.key(0), 6000)
        scores[sampler] = float(dqn.evaluate(state, jax.random.key(9), 10))
    assert scores["amper-fr-sharded"] > 80, scores
    assert scores["amper-fr-sharded"] > 0.5 * scores["amper-fr"], scores


@pytest.mark.slow
def test_dqn_per_sharded_smoke(mesh):
    """per-sharded runs the same pipeline (importance weights included)."""
    from repro.rl.dqn import DQNConfig, make_dqn

    cfg = DQNConfig(env="cartpole", sampler="per-sharded", replay_size=2000,
                    eps_decay_steps=500, learn_start=100)
    dqn = make_dqn(cfg)
    state, metrics = dqn.train(jax.random.key(0), 1000)
    assert bool(jnp.all(jnp.isfinite(metrics["return_mean"])))
    assert float(dqn.evaluate(state, jax.random.key(1), 3)) > 0
