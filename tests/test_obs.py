"""Observability subsystem: registry thread-safety with exact counts,
exporter schema round-trips, the online-vs-offline Fig. 7 KL pin, the
zero-dispatch guard for disabled telemetry, and the telemetry-enabled
``ReplayService`` integration (uniform sync/async metrics schema)."""
import json
import math
import os
import threading
import urllib.request

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.obs.metrics import INT_BUCKETS, Registry, _hist_percentile
from repro.obs.probes import (BINS, SamplingErrorMonitor, kl_nats,
                              priority_bin_counts)
from repro.rl.dqn import DQNConfig
from repro.runtime import ReplayService
from repro.train.checkpoint import CheckpointManager


# --- registry: lock-free writers, exact merge --------------------------------

def test_counter_race_exact_counts():
    """4 writer threads x 10k adds each merge to EXACT totals — the
    per-thread-cell design has no lost updates by construction."""
    reg = Registry()
    c = reg.counter("hits")
    h = reg.histogram("vals", bounds=INT_BUCKETS)
    N, T = 10_000, 4

    def work(tid):
        for i in range(N):
            c.add()
            h.observe(tid)  # each thread observes its own id N times

    threads = [threading.Thread(target=work, args=(t,)) for t in range(T)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert c.value == N * T
    assert c.read()["events"] == N * T
    data = h.read()
    assert data["count"] == N * T
    assert data["min"] == 0 and data["max"] == T - 1
    # INT_BUCKETS make small-int series exact: each tid bucket holds N.
    for tid in range(T):
        assert data["buckets"][tid] == N


def test_gauge_freshest_write_wins_across_threads():
    reg = Registry()
    g = reg.gauge("depth")
    assert math.isnan(g.value)  # unset
    g.set(1.0)
    t = threading.Thread(target=lambda: g.set(7.0))
    t.start()
    t.join()
    assert g.value == 7.0  # the later write, from another thread's cell


def test_histogram_percentiles_exact_on_int_bounds():
    reg = Registry()
    h = reg.histogram("staleness_steps", bounds=INT_BUCKETS)
    for v in range(1, 61):  # 1..60: inside the exact 0..64 range
        h.observe(v)
    h.observe_n(3, 0)  # no-op
    assert h.percentile(0.50) == 30
    assert h.percentile(0.95) == 57
    assert h.percentile(1.0) == 60
    assert _hist_percentile(h.read(), h.bounds, 0.01) == 1
    # Past the exact range values fall in coarse power-of-two buckets,
    # whose percentile clamps to the observed max.
    h.observe(100)
    assert h.percentile(1.0) == 100


def test_observe_n_matches_n_observes():
    reg = Registry()
    a = reg.histogram("a", bounds=INT_BUCKETS)
    b = reg.histogram("b", bounds=INT_BUCKETS)
    for _ in range(7):
        a.observe(5)
    b.observe_n(5, 7)
    assert a.read() == b.read()


def test_snapshot_diff_gives_per_run_view():
    reg = Registry()
    c = reg.counter("frames_total")
    h = reg.histogram("lat", bounds=INT_BUCKETS)
    c.add(10)
    h.observe(3)
    base = reg.snapshot()
    c.add(5)
    h.observe(4)
    diff = reg.snapshot().diff(base)
    assert diff.data["frames_total"]["value"] == 5
    assert diff.data["lat"]["count"] == 1
    assert sum(diff.data["lat"]["buckets"]) == 1
    # summary() renders histograms as stats dicts.
    assert diff.summary()["lat"]["p50"] == 4


def test_disabled_registry_records_nothing():
    reg = Registry(enabled=False)
    c = reg.counter("c")
    g = reg.gauge("g")
    h = reg.histogram("h")
    c.add()
    g.set(1.0)
    h.observe(1.0)
    assert c.value == 0 and math.isnan(g.value) and h.read()["count"] == 0


# --- spans -------------------------------------------------------------------

def test_span_disabled_by_default_and_records_when_enabled():
    from repro.obs.tracing import _NULL_SPAN

    # Process default: disabled registry -> shared null span object.
    assert obs.span("anything") is _NULL_SPAN
    reg = Registry()
    with obs.span("unit", registry=reg):
        pass
    data = reg.instruments()["span_unit_ms"].read()
    assert data["count"] == 1 and data["sum"] >= 0.0


def test_span_is_noop_inside_jit_trace():
    """Compile time must never poison the wall-time histograms: spans
    opened while jax is tracing resolve to the null span."""
    reg = Registry()

    def f(x):
        with obs.span("traced_region", registry=reg):
            return x + 1

    jax.make_jaxpr(f)(1.0)
    assert "span_traced_region_ms" not in reg.instruments()
    f(1.0)  # eager call does record
    assert reg.instruments()["span_traced_region_ms"].read()["count"] == 1


def test_use_registry_thread_local_override():
    reg = Registry()
    with obs.use_registry(reg):
        assert obs.get_registry() is reg
        with obs.span("scoped"):
            pass
    assert obs.get_registry() is not reg
    assert reg.instruments()["span_scoped_ms"].read()["count"] == 1


# --- exporters: schema round-trips -------------------------------------------

def test_jsonl_roundtrip(tmp_path):
    reg = Registry()
    reg.counter("frames_total").add(42)
    reg.histogram("lat", bounds=INT_BUCKETS).observe(2)
    reg.gauge("unset_gauge")  # NaN -> null in JSON
    path = str(tmp_path / "m.jsonl")
    exp = obs.JsonlExporter(path)
    exp.write_event("run_start", mode="async")
    exp.write_snapshot(reg.snapshot(), extra={"step": 7})
    exp.close()
    records = obs.read_jsonl(path)
    assert [r["kind"] for r in records] == ["event", "snapshot"]
    ev, snap = records
    assert ev["event"] == "run_start" and ev["mode"] == "async"
    assert ev["schema"] == snap["schema"] == 1
    assert snap["step"] == 7
    m = snap["metrics"]
    assert m["frames_total"]["value"] == 42
    assert m["lat"]["count"] == 1 and m["lat"]["p50"] == 2
    assert m["unset_gauge"]["value"] is None  # NaN sanitised
    # Every line is independently parseable JSON (stream-safe).
    with open(path) as f:
        for line in f:
            json.loads(line)


def test_jsonl_skips_torn_tail(tmp_path):
    path = str(tmp_path / "m.jsonl")
    exp = obs.JsonlExporter(path)
    exp.write_event("ok")
    exp.close()
    with open(path, "a") as f:
        f.write('{"kind": "event", "trunc')  # killed mid-write
    records = obs.read_jsonl(path)
    assert len(records) == 1 and records[0]["event"] == "ok"


def test_prometheus_text_roundtrip(tmp_path):
    reg = Registry()
    reg.counter("frames_total", help="frames").add(17)
    reg.gauge("csp_occupancy").set(0.25)
    h = reg.histogram("lat", bounds=(1.0, 10.0))
    h.observe(0.5)
    h.observe(5.0)
    h.observe(99.0)
    text = obs.prometheus_text(reg)
    series = obs.parse_prometheus(text)
    assert series["repro_frames_total_total"] == 17.0
    assert series["repro_csp_occupancy"] == 0.25
    assert series['repro_lat_bucket{le="1.0"}'] == 1.0
    assert series['repro_lat_bucket{le="10.0"}'] == 2.0
    assert series['repro_lat_bucket{le="+Inf"}'] == 3.0
    assert series["repro_lat_count"] == 3.0
    assert series["repro_lat_sum"] == pytest.approx(104.5)
    path = obs.write_prometheus(reg, str(tmp_path / "metrics.prom"))
    assert obs.parse_prometheus(open(path).read()) == series


def test_prometheus_http_endpoint():
    reg = Registry()
    reg.counter("hits").add(3)
    srv = obs.PrometheusServer(reg)
    try:
        body = urllib.request.urlopen(
            f"http://127.0.0.1:{srv.port}/metrics", timeout=5).read().decode()
        assert obs.parse_prometheus(body)["repro_hits_total"] == 3.0
    finally:
        srv.close()


def test_report_cli_smoke(tmp_path, capsys):
    from repro.obs import report

    reg = Registry()
    reg.counter("frames_total").add(5)
    path = str(tmp_path / "m.jsonl")
    exp = obs.JsonlExporter(path)
    exp.write_event("checkpoint", step=10)
    exp.write_snapshot(reg.snapshot())
    exp.close()
    report.main([path, "--events"])
    out = capsys.readouterr().out
    assert "frames_total" in out and "checkpoint" in out


# --- Fig. 7 pin: online monitor == offline benchmark on identical draws ------

@pytest.mark.tier1
@pytest.mark.stats
def test_online_kl_gauge_matches_fig7_benchmark_on_same_draws():
    """The live SamplingErrorMonitor and the offline Fig. 7 study are the
    same computation: feeding the monitor the exact draws the benchmark
    binned yields bit-identical bin counts and KL."""
    from benchmarks import fig7_sampling_error as fig7
    from repro.core.per import CumsumPER

    n = 2000
    key = jax.random.key(0)
    prio = jax.random.uniform(jax.random.fold_in(key, 99), (n,))
    prio_np = np.asarray(prio)
    per = CumsumPER(n)
    state = per.update(per.init(), jnp.arange(n), prio)

    q_ref = fig7.sample_counts(per, state, jax.random.fold_in(key, 1),
                               prio_np)

    # Replay the benchmark's exact draw loop into the online monitor.
    reg = Registry()
    mon = SamplingErrorMonitor(reg, window=fig7.RUNS)
    mon.set_reference_counts(q_ref)
    fn = jax.jit(lambda s, k: per.sample(s, k, fig7.BATCH))
    counts = np.zeros(BINS)
    k2 = jax.random.fold_in(key, 2)
    for r in range(fig7.RUNS):
        vals = prio_np[np.asarray(fn(state, jax.random.fold_in(k2, r)))]
        counts += priority_bin_counts(vals)
        mon.observe(vals)
    np.testing.assert_array_equal(mon.counts, counts)
    assert mon.kl() == kl_nats(counts, q_ref)  # exact, same code path
    assert mon.kl() == pytest.approx(
        reg.instruments()["sampling_kl_nats"].value)
    # PER-vs-PER on the shared binning sits near the noise floor, far
    # below a uniform sampler's divergence (the Fig. 7 ordering).
    uni = np.random.default_rng(0).integers(0, n, fig7.BATCH * fig7.RUNS)
    kl_uniform = kl_nats(
        priority_bin_counts(prio_np[uni]).astype(float), q_ref)
    assert kl_uniform > 5 * mon.kl()


def test_monitor_window_evicts_old_draws():
    mon = SamplingErrorMonitor(window=2)
    a = np.full(10, 0.1)
    b = np.full(10, 0.9)
    mon.observe(a)
    mon.observe(a)
    mon.observe(b)  # evicts the first draw of `a`
    expected = priority_bin_counts(a) + priority_bin_counts(b)
    np.testing.assert_array_equal(mon.counts, expected.astype(float))


# --- tier-1 guard: disabled telemetry adds ZERO dispatches -------------------

def test_disabled_telemetry_keeps_fused_dispatch_count():
    """Instrumentation is host-side only: the fused AMPER-fr sampling
    path keeps the committed dispatch count (BENCH_sampling.json) with
    telemetry disabled AND enabled — spans no-op inside traces."""
    from benchmarks.bench_samplers import BATCH, CSP_RATIO, dispatch_count
    from repro.core.amper import AmperConfig, AmperSampler

    bench = json.load(open(
        os.path.join(os.path.dirname(__file__), "..",
                     "BENCH_sampling.json")))
    row = next(r for r in bench["rows"] if r[0] == "fr-fused/n10000")
    pinned = int(dict(kv.split("=") for kv in row[2].split())["dispatches"])

    n = 10_000
    cfg = AmperConfig(capacity=n, m=20, lam_fr=2.0, v_max=1.0,
                      csp_capacity=max(int(n * CSP_RATIO), BATCH),
                      fr_mode="fused")
    amp = AmperSampler(cfg, "fr")
    s = amp.update(amp.init(), jnp.arange(n),
                   jax.random.uniform(jax.random.key(0), (n,)) + 0.01)
    key = jax.random.key(1)

    _, disp_off = dispatch_count(
        lambda st, k, a=amp: a.sample(st, k, BATCH), s, key)
    assert disp_off == pinned, (
        f"disabled telemetry changed fused dispatches: {disp_off} != "
        f"{pinned} (committed BENCH_sampling.json)")

    prev = obs.set_registry(Registry(enabled=True))
    try:
        _, disp_on = dispatch_count(
            lambda st, k, a=amp: a.sample(st, k, BATCH), s, key)
    finally:
        obs.set_registry(prev)
    assert disp_on == pinned, (
        f"ENABLED telemetry changed fused dispatches: {disp_on} != {pinned}")


# --- ReplayService integration ----------------------------------------------

def _small_cfg(**kw):
    base = dict(num_envs=2, replay_size=256, batch=16, learn_start=8,
                eps_decay_steps=200, target_sync=50, v_max=8.0)
    base.update(kw)
    return DQNConfig(**base)


def test_service_async_telemetry_jsonl(tmp_path):
    """Telemetry-enabled async run: RunResult keeps the pinned metric
    keys, the JSONL log carries staleness percentiles / CSP occupancy /
    fallback rate, and the Prometheus file parses."""
    jpath = str(tmp_path / "run.jsonl")
    ppath = str(tmp_path / "run.prom")
    tel = obs.Telemetry(metrics_out=jpath, prometheus_out=ppath,
                        probe_every=4, window=50)
    svc = ReplayService(_small_cfg(sampler="amper-fr"), num_actors=2,
                        chunk_len=4, slab=2, max_replay_ratio=64,
                        telemetry=tel)
    res = svc.run(jax.random.key(0), 40)
    m = res.metrics

    # Compatibility view: the pre-registry metric keys survive.
    for k in ("staleness", "queue_depth", "snapshot", "checkpoint"):
        assert k in m, k
    assert m["staleness"]["count"] == 40
    assert {"p50", "p95", "p99"} <= set(m["staleness"])
    assert m["staleness"]["p50"] <= m["staleness"]["p95"] <= \
        m["staleness"]["p99"] <= m["staleness"]["max"]
    assert {"kl_nats", "csp_occupancy", "fallback_draws",
            "probe_draws"} <= set(m["health"])
    assert m["health"]["probe_draws"] >= 1

    records = obs.read_jsonl(jpath)
    snaps = [r for r in records if r["kind"] == "snapshot"]
    assert len(snaps) >= 2  # per-probe timeline + the final snapshot
    final = snaps[-1]
    mm = final["metrics"]
    for name in ("frames_total", "blocks_total", "learner_steps_total",
                 "feedback_applied_total", "staleness_steps",
                 "work_queue_depth", "batch_queue_depth", "csp_occupancy",
                 "sampling_kl_nats", "probe_draws", "span_learn_ms",
                 "span_slab_draw_ms", "span_rollout_ms"):
        assert name in mm, name
    assert mm["staleness_steps"]["count"] == 40
    assert mm["learner_steps_total"]["value"] == 40
    assert 0.0 <= mm["csp_occupancy"]["value"] <= 1.0

    series = obs.parse_prometheus(open(ppath).read())
    assert series["repro_learner_steps_total_total"] == 40.0
    assert "repro_staleness_steps_count" in series

    # The run restored the process default registry on exit.
    assert not obs.get_registry().enabled


def test_service_sync_uniform_schema(tmp_path):
    """Sync mode emits the SAME snapshot/checkpoint schema as async:
    pause stats, drain_cycles, checkpoint bytes split and chain length."""
    manager = CheckpointManager(str(tmp_path / "ckpt"), keep=3,
                                save_interval=20)
    tel = obs.Telemetry(metrics_out=str(tmp_path / "sync.jsonl"),
                        probe_every=0)
    svc = ReplayService(_small_cfg(num_envs=1), sync=True, num_actors=1,
                        telemetry=tel)
    res = svc.run(jax.random.key(0), 60, manager=manager)
    m = res.metrics
    assert m["mode"] == "sync"
    assert set(m["snapshot"]) == {"count", "saved", "pause_us_mean",
                                  "pause_us_max", "drain_cycles"}
    assert m["snapshot"]["count"] == 3  # steps 20/40/60
    assert m["snapshot"]["pause_us_max"] > 0
    ck = m["checkpoint"]
    assert ck["saves"] == 3
    assert ck["full_bytes"] > 0 and ck["delta_bytes"] > 0
    assert ck["chain_len"] >= 1
    # Sync staleness is structurally zero but the schema is uniform.
    assert m["staleness"] == {"count": 0, "mean": 0.0, "max": 0,
                              "p50": 0, "p95": 0, "p99": 0}
    events = [r for r in obs.read_jsonl(str(tmp_path / "sync.jsonl"))
              if r["kind"] == "event" and r["event"] == "checkpoint"]
    assert [e["step"] for e in events] == [20, 40, 60]
    assert [e["delta"] for e in events] == [False, True, True]


def test_service_without_telemetry_unchanged(tmp_path):
    """No Telemetry spec -> no files, no global registry flip, and the
    compatibility metric keys still exist (registry-backed, disabled)."""
    svc = ReplayService(_small_cfg(), num_actors=2, chunk_len=4, slab=2,
                        max_replay_ratio=64)
    res = svc.run(jax.random.key(0), 20)
    assert res.metrics["staleness"]["count"] == 20
    assert "health" not in res.metrics
    assert not obs.get_registry().enabled
    assert os.listdir(tmp_path) == []
