"""Suite-wide configuration.

The host-device override MUST happen here, before any module imports
jax: XLA reads XLA_FLAGS at first backend init, so setting it inside a
test file is import-order fragile (anything importing jax earlier wins).
With 8 forced host devices every test sees the same topology and the
sharded-replay suite runs real multi-device meshes in-process instead of
via subprocesses.
"""
import os

if "host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=8").strip()

import pytest  # noqa: E402


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")


@pytest.fixture(scope="session")
def mesh():
    """2x4 ("pod", "data") mesh over the 8 forced host devices."""
    import jax

    if jax.device_count() < 8:
        pytest.skip("needs 8 devices (XLA_FLAGS was set before jax init?)")
    return jax.make_mesh((2, 4), ("pod", "data"))
