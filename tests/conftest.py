"""Suite-wide configuration.

The host-device override MUST happen here, before any module imports
jax: XLA reads XLA_FLAGS at first backend init, so setting it inside a
test file is import-order fragile (anything importing jax earlier wins).
With 8 forced host devices every test sees the same topology and the
sharded-replay suite runs real multi-device meshes in-process instead of
via subprocesses.

Test taxonomy (see README "Testing"):

* ``tier1`` — fast must-pass gates that run on every push (the
  statistical sampling gates opt in explicitly; everything unmarked is
  tier-1 by default).
* ``slow``  — long-running integration tests (full smoke-scale training
  runs); CI runs them in the separate ``extended`` job.
* ``stats`` — statistical-distribution tests (chi-square / KS); the
  fast ones are double-marked ``tier1`` so the push gate still pins the
  sampling laws, while the heavyweight sweeps stay in ``extended``.

CI selects ``-m "tier1 or not (slow or stats)"`` for the push gate and
``-m "slow or stats"`` for the extended job, so every test runs in
exactly one job (tier1+stats double-marks run in both — they are the
regression gate for the paper's sampling-distribution claim).
"""
import os

if "host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=8").strip()

import pytest  # noqa: E402


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "tier1: fast must-pass gate, runs on every push")
    config.addinivalue_line("markers", "slow: long-running integration test")
    config.addinivalue_line(
        "markers", "stats: statistical-distribution test (chi-square/KS)")
    config.addinivalue_line(
        "markers", "analysis: static-analysis gate tests "
                   "(repro.analysis fixtures, lockdep, trace checks)")


@pytest.fixture(scope="session")
def mesh():
    """2x4 ("pod", "data") mesh over the 8 forced host devices."""
    import jax

    if jax.device_count() < 8:
        pytest.skip("needs 8 devices (XLA_FLAGS was set before jax init?)")
    return jax.make_mesh((2, 4), ("pod", "data"))
