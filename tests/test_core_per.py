"""PER baselines: sum-tree invariants + sampling-law correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.per import CumsumPER, SumTreePER, importance_weights


@pytest.fixture(scope="module")
def priorities():
    return jax.random.uniform(jax.random.key(1), (512,)) + 0.01


def test_sumtree_total_matches_sum(priorities):
    st = SumTreePER(512)
    s = st.update(st.init(), jnp.arange(512), priorities)
    np.testing.assert_allclose(float(st.total(s)), float(priorities.sum()),
                               rtol=1e-5)
    np.testing.assert_allclose(np.asarray(st.priorities(s)),
                               np.asarray(priorities), rtol=1e-6)


def test_sumtree_partial_updates(priorities):
    st = SumTreePER(512)
    s = st.update(st.init(), jnp.arange(512), priorities)
    idx = jnp.array([3, 100, 511], jnp.int32)
    new = jnp.array([5.0, 0.0, 2.5])
    s = st.update(s, idx, new)
    expect = np.asarray(priorities).copy()
    expect[[3, 100, 511]] = [5.0, 0.0, 2.5]
    np.testing.assert_allclose(float(st.total(s)), expect.sum(), rtol=1e-5)


def test_sumtree_duplicate_index_update(priorities):
    st = SumTreePER(512)
    s = st.update(st.init(), jnp.arange(512), priorities)
    idx = jnp.array([7, 7, 7], jnp.int32)
    s = st.update(s, idx, jnp.array([1.0, 2.0, 3.0]))
    # last write wins, tree stays consistent
    np.testing.assert_allclose(float(st.priorities(s)[7]), 3.0, rtol=1e-6)
    np.testing.assert_allclose(float(st.total(s)),
                               float(priorities.sum() - priorities[7] + 3.0),
                               rtol=1e-5)


def test_samplers_follow_priority_law(priorities):
    """Empirical sampling frequency tracks p_i / sum(p) for both PERs."""
    n = 512
    target = np.asarray(priorities / priorities.sum())
    for cls in (SumTreePER, CumsumPER):
        sampler = cls(n)
        s = sampler.update(sampler.init(), jnp.arange(n), priorities)
        idx = jax.jit(lambda k: sampler.sample(s, k, 16384))(jax.random.key(2))
        freq = np.bincount(np.asarray(idx), minlength=n) / 16384
        # high-count regime: correlation should be strong
        corr = np.corrcoef(freq, target)[0, 1]
        assert corr > 0.8, (cls.__name__, corr)
        # sampled mean priority ~ E_p[p] = sum p^2 / sum p
        expect = (target * np.asarray(priorities)).sum()
        got = float(priorities[idx].mean())
        assert abs(got - expect) / expect < 0.05, (cls.__name__, got, expect)


def test_sumtree_cumsum_agree(priorities):
    """Same key, same stratified draws -> identical indices."""
    st, cs = SumTreePER(512), CumsumPER(512)
    s1 = st.update(st.init(), jnp.arange(512), priorities)
    s2 = cs.update(cs.init(), jnp.arange(512), priorities)
    i1 = st.sample(s1, jax.random.key(7), 256)
    i2 = cs.sample(s2, jax.random.key(7), 256)
    assert (np.asarray(i1) == np.asarray(i2)).mean() > 0.98


def test_importance_weights(priorities):
    w = importance_weights(priorities, jnp.arange(512), jnp.int32(512), 0.4)
    assert float(w.max()) <= 1.0 + 1e-6
    assert float(w.min()) > 0.0
    # lower priority -> larger weight
    lo, hi = int(jnp.argmin(priorities)), int(jnp.argmax(priorities))
    assert float(w[lo]) > float(w[hi])


def test_importance_weights_shared_formula(priorities):
    """importance_weights is a thin delegate of importance_from_selected:
    the ONE weight formula both the reference and fused sampling paths
    feed, with the normalisation constant hoisted out of the draw.  Pinned
    bitwise — any drift between the two entry points breaks the fused
    path's weight bit-identity guarantee."""
    from repro.core.per import importance_from_selected
    idx = jnp.asarray([3, 99, 511, 0, 3], jnp.int32)
    for beta in (0.0, 0.4, 1.0):
        a = importance_weights(priorities, idx, jnp.int32(512), beta)
        b = importance_from_selected(priorities[idx], jnp.sum(priorities),
                                     jnp.int32(512), beta)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
