"""Hypothesis property tests on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

import repro.core.quantize as qz
from repro.core.per import CumsumPER, SumTreePER

MAXQ = (1 << 24) - 1


@settings(max_examples=50, deadline=None)
@given(st.floats(0.0, 10.0), st.floats(0.001, 10.0))
def test_quantize_roundtrip(p, v_max):
    q = qz.quantize(jnp.float32(p), v_max)
    back = float(qz.dequantize(q, v_max))
    assert 0 <= int(q) <= MAXQ
    assert abs(back - min(p, v_max)) <= v_max / MAXQ + 1e-6


@settings(max_examples=50, deadline=None)
@given(st.integers(0, MAXQ))
def test_prefix_mask_is_low_bits(delta):
    m = int(qz.prefix_mask(jnp.int32(delta)))
    # mask is of form 2^k - 1 and covers delta
    assert (m & (m + 1)) == 0
    if delta > 0:
        assert m >= delta
        assert m <= 2 * delta - 1 if delta > 0 else m == 0
    else:
        assert m == 0


@settings(max_examples=50, deadline=None)
@given(st.integers(0, MAXQ), st.integers(0, MAXQ))
def test_ternary_match_range_equivalence(value, query):
    """(v ^ q) & ~mask == 0 iff v in [q&~mask, q|mask] — the TCAM/range
    duality the fused kernel relies on."""
    delta = query // 8
    mask = qz.prefix_mask(jnp.int32(delta))
    lo, hi = qz.prefix_range(jnp.int32(query), mask)
    matched = bool(qz.ternary_match(jnp.int32(value), jnp.int32(query), mask))
    in_range = int(lo) <= value <= int(hi)
    assert matched == in_range


@settings(max_examples=25, deadline=None)
@given(st.lists(st.floats(0.0, 5.0), min_size=4, max_size=64),
       st.integers(0, 2**31 - 1))
def test_sumtree_total_invariant(ps, seed):
    n = len(ps)
    tree = SumTreePER(n)
    s = tree.update(tree.init(), jnp.arange(n), jnp.asarray(ps, jnp.float32))
    np.testing.assert_allclose(float(tree.total(s)), sum(ps),
                               rtol=1e-4, atol=1e-4)
    # sampling always returns in-range indices even with zero priorities
    idx = tree.sample(s, jax.random.key(seed % 2**31), 32)
    assert bool(jnp.all((idx >= 0) & (idx < n)))


@settings(max_examples=25, deadline=None)
@given(st.lists(st.floats(0.01, 5.0), min_size=4, max_size=64),
       st.integers(0, 2**31 - 1))
def test_cumsum_sampler_in_support(ps, seed):
    n = len(ps)
    cs = CumsumPER(n)
    s = cs.update(cs.init(), jnp.arange(n), jnp.asarray(ps, jnp.float32))
    idx = cs.sample(s, jax.random.key(seed % 2**31), 64)
    assert bool(jnp.all((idx >= 0) & (idx < n)))


@settings(max_examples=80, deadline=None)
@given(st.integers(0, MAXQ), st.integers(0, MAXQ), st.integers(0, MAXQ))
def test_prefix_mask_range_match_mutual_consistency(value, query, delta):
    """prefix_mask / prefix_range / ternary_match agree for INDEPENDENT
    (query, delta): a row matches iff it lies in [lo, hi]; the query
    itself always lies in its own block; the block is exactly the
    mask-aligned interval of width mask+1."""
    mask = qz.prefix_mask(jnp.int32(delta))
    lo, hi = qz.prefix_range(jnp.int32(query), mask)
    lo_i, hi_i, m_i = int(lo), int(hi), int(mask)
    assert lo_i <= query <= hi_i, "query escaped its own prefix block"
    assert hi_i - lo_i == m_i, "block width != mask span"
    assert lo_i & m_i == 0, "block not aligned to the mask"
    matched = bool(qz.ternary_match(jnp.int32(value), jnp.int32(query), mask))
    assert matched == (lo_i <= value <= hi_i)


@settings(max_examples=50, deadline=None)
@given(st.floats(0.0, 20.0), st.floats(0.0, 20.0), st.floats(0.01, 10.0))
def test_quantize_monotone(p1, p2, v_max):
    """p1 <= p2  ->  q(p1) <= q(p2) (clipping and rounding included)."""
    lo_p, hi_p = min(p1, p2), max(p1, p2)
    assert int(qz.quantize(jnp.float32(lo_p), v_max)) <= \
        int(qz.quantize(jnp.float32(hi_p), v_max))


@settings(max_examples=50, deadline=None)
@given(st.integers(1, MAXQ), st.floats(0.01, 10.0))
def test_top_code_inside_prefix_block(delta, v_max):
    """quantize() docstring invariant: saturation lands AT or just below
    the all-ones code 2^frac-1 — never one past it — so the prefix block
    anchored at the ceiling always ends exactly at 2^frac-1 and
    v_max-clipped priorities stay matchable by top-block queries."""
    top = int(qz.quantize(jnp.float32(10 * v_max), v_max))
    # float32 scale rounding may shave <=2 codes, but never exceeds MAXQ
    # (exceeding it is the unmatchable / inverted-prioritization failure)
    assert MAXQ - 2 <= top <= MAXQ
    mask = qz.prefix_mask(jnp.int32(delta))
    lo, hi = qz.prefix_range(jnp.int32(MAXQ), mask)
    assert int(hi) == MAXQ, "ceiling block fell past the all-ones code"
    assert 0 <= int(lo) <= MAXQ
    # a saturated stored row matches the ceiling query whenever the radius
    # covers the fp shave (mask >= 3 here covers the <=2-code slack)
    if int(mask) >= 3:
        assert bool(qz.ternary_match(jnp.int32(top), jnp.int32(MAXQ), mask))


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 12), st.floats(0.2, 4.0), st.integers(0, 10_000))
def test_csp_members_within_prefix_blocks(m, lam_fr, seed):
    """Every CSP member lies in SOME group's accepted prefix block."""
    from repro.core.amper import AmperConfig, build_csp_fr, fr_queries, \
        group_representatives
    n = 256
    key = jax.random.key(seed)
    p = jax.random.uniform(jax.random.fold_in(key, 1), (n,))
    pq = qz.quantize(p, 1.0)
    cfg = AmperConfig(capacity=n, m=m, lam_fr=lam_fr, v_max=1.0,
                      csp_capacity=n)
    res = build_csp_fr(pq, jnp.ones(n, bool), key, cfg)
    v = group_representatives(jax.random.split(key)[0], cfg)
    vq, mask = fr_queries(v, cfg)
    lo, hi = qz.prefix_range(vq, mask)
    sel = np.asarray(res.selected)
    pqn = np.asarray(pq)
    ok = ((pqn[None, :] >= np.asarray(lo)[:, None])
          & (pqn[None, :] <= np.asarray(hi)[:, None])).any(0)
    assert (sel <= ok).all(), "selected someone outside every block"
