"""Hypothesis property tests on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

import repro.core.quantize as qz
from repro.core.per import CumsumPER, SumTreePER

MAXQ = (1 << 24) - 1


@settings(max_examples=50, deadline=None)
@given(st.floats(0.0, 10.0), st.floats(0.001, 10.0))
def test_quantize_roundtrip(p, v_max):
    q = qz.quantize(jnp.float32(p), v_max)
    back = float(qz.dequantize(q, v_max))
    assert 0 <= int(q) <= MAXQ
    assert abs(back - min(p, v_max)) <= v_max / MAXQ + 1e-6


@settings(max_examples=50, deadline=None)
@given(st.integers(0, MAXQ))
def test_prefix_mask_is_low_bits(delta):
    m = int(qz.prefix_mask(jnp.int32(delta)))
    # mask is of form 2^k - 1 and covers delta
    assert (m & (m + 1)) == 0
    if delta > 0:
        assert m >= delta
        assert m <= 2 * delta - 1 if delta > 0 else m == 0
    else:
        assert m == 0


@settings(max_examples=50, deadline=None)
@given(st.integers(0, MAXQ), st.integers(0, MAXQ))
def test_ternary_match_range_equivalence(value, query):
    """(v ^ q) & ~mask == 0 iff v in [q&~mask, q|mask] — the TCAM/range
    duality the fused kernel relies on."""
    delta = query // 8
    mask = qz.prefix_mask(jnp.int32(delta))
    lo, hi = qz.prefix_range(jnp.int32(query), mask)
    matched = bool(qz.ternary_match(jnp.int32(value), jnp.int32(query), mask))
    in_range = int(lo) <= value <= int(hi)
    assert matched == in_range


@settings(max_examples=25, deadline=None)
@given(st.lists(st.floats(0.0, 5.0), min_size=4, max_size=64),
       st.integers(0, 2**31 - 1))
def test_sumtree_total_invariant(ps, seed):
    n = len(ps)
    tree = SumTreePER(n)
    s = tree.update(tree.init(), jnp.arange(n), jnp.asarray(ps, jnp.float32))
    np.testing.assert_allclose(float(tree.total(s)), sum(ps),
                               rtol=1e-4, atol=1e-4)
    # sampling always returns in-range indices even with zero priorities
    idx = tree.sample(s, jax.random.key(seed % 2**31), 32)
    assert bool(jnp.all((idx >= 0) & (idx < n)))


@settings(max_examples=25, deadline=None)
@given(st.lists(st.floats(0.01, 5.0), min_size=4, max_size=64),
       st.integers(0, 2**31 - 1))
def test_cumsum_sampler_in_support(ps, seed):
    n = len(ps)
    cs = CumsumPER(n)
    s = cs.update(cs.init(), jnp.arange(n), jnp.asarray(ps, jnp.float32))
    idx = cs.sample(s, jax.random.key(seed % 2**31), 64)
    assert bool(jnp.all((idx >= 0) & (idx < n)))


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 12), st.floats(0.2, 4.0), st.integers(0, 10_000))
def test_csp_members_within_prefix_blocks(m, lam_fr, seed):
    """Every CSP member lies in SOME group's accepted prefix block."""
    from repro.core.amper import AmperConfig, build_csp_fr, fr_queries, \
        group_representatives
    n = 256
    key = jax.random.key(seed)
    p = jax.random.uniform(jax.random.fold_in(key, 1), (n,))
    pq = qz.quantize(p, 1.0)
    cfg = AmperConfig(capacity=n, m=m, lam_fr=lam_fr, v_max=1.0,
                      csp_capacity=n)
    res = build_csp_fr(pq, jnp.ones(n, bool), key, cfg)
    v = group_representatives(jax.random.split(key)[0], cfg)
    vq, mask = fr_queries(v, cfg)
    lo, hi = qz.prefix_range(vq, mask)
    sel = np.asarray(res.selected)
    pqn = np.asarray(pq)
    ok = ((pqn[None, :] >= np.asarray(lo)[:, None])
          & (pqn[None, :] <= np.asarray(hi)[:, None])).any(0)
    assert (sel <= ok).all(), "selected someone outside every block"
