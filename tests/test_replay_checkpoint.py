"""Replay-aware checkpointing: every registry sampler's state round-trips
bitwise, hidden exact-resume state (write stamps, add counter,
max_priority, ring position) survives, and sharded checkpoints restore
elastically onto a different shard count with membership-exact
priorities."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.replay_buffer import ReplayBuffer
from repro.core import sharded as sharded_mod
from repro.core.samplers import abstract_state, make_sampler
from repro.train import checkpoint as ck
from repro.train import replay_checkpoint as rck

CAP = 512
EX = {"obs": jnp.zeros(4), "reward": jnp.float32(0)}


def _populated(rb, seed=0, rounds=5):
    """Buffer exercised through add / sample / priority-update cycles so
    every piece of hidden state is non-trivial (incl. ring wraparound)."""
    st = rb.init(EX)
    k = jax.random.key(seed)
    for i in range(rounds):
        st = rb.add_batch(st, {
            "obs": jax.random.normal(jax.random.fold_in(k, i), (200, 4)),
            "reward": jnp.arange(200, dtype=jnp.float32)})
        idx, _, _ = rb.sample(st, jax.random.fold_in(k, 100 + i), 32)
        st = rb.update_priorities(
            st, idx, jax.random.normal(jax.random.fold_in(k, 200 + i), (32,)))
    return st


@pytest.mark.parametrize("kind", ["uniform", "per-sumtree", "per-cumsum",
                                  "amper-k", "amper-fr"])
def test_replay_state_roundtrips_bitwise(kind, tmp_path):
    rb = ReplayBuffer(CAP, make_sampler(kind, CAP, v_max=8.0, min_csp=64))
    st = _populated(rb)
    rck.save_replay(str(tmp_path), 7, st, meta={"sampler": kind})
    out = rck.restore_replay(str(tmp_path), 7, rb, EX)
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # the hidden exact-resume state, explicitly
    assert int(out.pos) == int(st.pos)
    assert int(out.total_adds) == int(st.total_adds)
    assert float(out.max_priority) == float(st.max_priority)
    np.testing.assert_array_equal(np.asarray(out.write_stamp),
                                  np.asarray(st.write_stamp))
    assert ck.load_meta(str(tmp_path), 7)["sampler"] == kind


def test_abstract_state_matches_init():
    for kind in ["uniform", "per-sumtree", "per-cumsum", "amper-fr"]:
        s = make_sampler(kind, 64, v_max=8.0)
        abs_leaves = jax.tree.leaves(abstract_state(s))
        for a, b in zip(abs_leaves, jax.tree.leaves(s.init())):
            assert tuple(np.shape(a)) == tuple(np.shape(b))


def test_wrong_sampler_restore_raises(tmp_path):
    rb = ReplayBuffer(CAP, make_sampler("per-sumtree", CAP))
    rck.save_replay(str(tmp_path), 1, _populated(rb))
    rb2 = ReplayBuffer(CAP, make_sampler("amper-fr", CAP, v_max=8.0))
    with pytest.raises(ValueError):
        rck.restore_replay(str(tmp_path), 1, rb2, EX)


# --- exact dirty sets / incremental saves ------------------------------------


@pytest.mark.parametrize("kind", ["uniform", "per-cumsum", "amper-fr"])
def test_replay_dirty_delta_roundtrips_bitwise(kind, tmp_path):
    """Delta saves driven by replay_marks/replay_dirty restore bitwise
    identical to a full dump — across a wrapping ring arc and
    out-of-band priority-feedback rows."""
    cap = 16
    rb = ReplayBuffer(cap, make_sampler(kind, cap, v_max=8.0, min_csp=4))
    st = rb.init(EX)
    k = jax.random.key(3)
    st = rb.add_batch(st, {"obs": jax.random.normal(k, (12, 4)),
                           "reward": jnp.arange(12, dtype=jnp.float32)})
    rck.save_replay(str(tmp_path), 1, st)  # legacy full base
    marks = rck.replay_marks(st)
    assert marks == {"pos": 12, "total_adds": 12, "add_gen": 0}
    # write 9 more rows: the arc wraps (12..16 then 0..5), and touch
    # priorities on rows the arc does NOT cover
    st = rb.add_batch(st, {"obs": jax.random.normal(jax.random.fold_in(k, 1),
                                                    (9, 4)),
                           "reward": jnp.ones(9)})
    idx = jnp.array([6, 7, 10], jnp.int32)
    st = rb.update_priorities(st, idx, jnp.array([0.5, 2.0, 1.5]))
    dirty = rck.replay_dirty(rb, st, marks, priority_rows=[6, 7, 10])
    ck.save_incremental(str(tmp_path), 2, st, base_step=1, dirty=dirty)
    out = rck.restore_replay(str(tmp_path), 2, rb, EX)
    for name, a, b in zip(ck._flatten_with_names(st)[0],
                          jax.tree.leaves(st), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=name)


def test_replay_dirty_full_wrap_is_whole_ring():
    cap = 8
    rb = ReplayBuffer(cap, make_sampler("per-cumsum", cap))
    st = rb.init(EX)
    for _ in range(4):
        st = rb.add_batch(st, {"obs": jnp.zeros((5, 4)),
                               "reward": jnp.zeros(5)})
    marks = {"pos": 4, "total_adds": 4}  # 16 adds since marks > capacity
    dirty = rck.replay_dirty(rb, st, marks)
    spec = jax.tree.leaves(
        dirty.storage, is_leaf=lambda x: isinstance(x, ck.Rows))[0]
    assert spec.ranges == [(0, cap)]


def test_replay_dirty_wrap_safe_across_int32_rollover():
    """Marks captured just below the signed-int32 add-counter boundary
    plus a state whose counter crossed it must still yield the exact
    9-row wrapped arc — the plain signed difference would be negative
    (an empty dirty set) and the delta save would silently drop rows."""
    cap = 16
    rb = ReplayBuffer(cap, make_sampler("per-cumsum", cap))
    st = rb.init(EX)
    marks = {"pos": 12, "total_adds": (2**31 - 3) & 0xFFFFFFFF,
             "add_gen": 0}
    st = st._replace(pos=jnp.int32(5), size=jnp.int32(cap),
                     total_adds=jnp.int32(-(2**31) + 6),  # 2^31 + 6 unsigned
                     add_gen=jnp.int32(1))
    dirty = rck.replay_dirty(rb, st, marks)
    spec = jax.tree.leaves(
        dirty.storage, is_leaf=lambda x: isinstance(x, ck.Rows))[0]
    assert spec.ranges == [(12, cap), (0, 5)]


def test_replay_dirty_full_lap_detected_by_generation():
    """An identical (masked) add counter with a bumped generation means
    a full 2^32-add lap ran between snapshots: everything is dirty, not
    nothing."""
    cap = 16
    rb = ReplayBuffer(cap, make_sampler("per-cumsum", cap))
    st = rb.init(EX)
    marks = {"pos": 3, "total_adds": 77, "add_gen": 0}
    st = st._replace(pos=jnp.int32(3), size=jnp.int32(cap),
                     total_adds=jnp.int32(77), add_gen=jnp.int32(1))
    dirty = rck.replay_dirty(rb, st, marks)
    spec = jax.tree.leaves(
        dirty.storage, is_leaf=lambda x: isinstance(x, ck.Rows))[0]
    assert spec.ranges == [(0, cap)]


def test_replay_dirty_no_writes_skips_storage(tmp_path):
    """A save with nothing written since the marks stores no storage
    rows at all (the delta is scalars + any touched priority rows)."""
    cap = 16
    rb = ReplayBuffer(cap, make_sampler("uniform", cap))
    st = rb.init(EX)
    st = rb.add_batch(st, {"obs": jnp.zeros((4, 4)), "reward": jnp.zeros(4)})
    rck.save_replay(str(tmp_path), 1, st)
    dirty = rck.replay_dirty(rb, st, rck.replay_marks(st))
    ck.save_incremental(str(tmp_path), 2, st, base_step=1, dirty=dirty)
    man = ck.load_manifest(str(tmp_path), 2)
    obs_i = man["names"].index("storage/obs")
    assert man["delta"][obs_i] is None
    out = rck.restore_replay(str(tmp_path), 2, rb, EX)
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# --- n-step accumulator state ------------------------------------------------


@pytest.mark.parametrize("kind", ["per-cumsum", "amper-fr"])
def test_nstep_replay_state_roundtrips_bitwise(kind, tmp_path):
    """The in-state NStepAccumulator (ring window, count, cursor) must
    round-trip bitwise — a resumed n-step run has to keep aggregating
    mid-window exactly where the killed one stopped."""
    n_envs = 4
    rb = ReplayBuffer(CAP, make_sampler(kind, CAP, v_max=8.0, min_csp=64),
                      n_step=3, gamma=0.97, num_envs=n_envs)
    ex = {"obs": jnp.zeros(4), "action": jnp.int32(0),
          "reward": jnp.float32(0), "next_obs": jnp.zeros(4),
          "done": jnp.float32(0)}
    st = rb.init(ex)
    k = jax.random.key(0)
    # 7 pushes: window warmed up AND mid-cycle (7 % 3 != 0), so the
    # cursor, saturated count, and ring contents are all non-trivial
    for i in range(7):
        st = rb.add_batch(st, {
            "obs": jax.random.normal(jax.random.fold_in(k, i), (n_envs, 4)),
            "action": jnp.full(n_envs, i % 2, jnp.int32),
            "reward": jnp.arange(n_envs, dtype=jnp.float32) + i,
            "next_obs": jax.random.normal(jax.random.fold_in(k, 50 + i),
                                          (n_envs, 4)),
            "done": jnp.where(jnp.arange(n_envs) == i % n_envs, 1.0, 0.0)})
    assert int(st.nstep.count) == 3 and int(st.nstep.pos) == 7 % 3
    rck.save_replay(str(tmp_path), 4, st, meta={"sampler": kind})
    out = rck.restore_replay(str(tmp_path), 4, rb, ex)
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert int(out.nstep.count) == int(st.nstep.count)
    assert int(out.nstep.pos) == int(st.nstep.pos)
    # the restored accumulator keeps emitting the same stream
    nxt = {"obs": jnp.ones((n_envs, 4)), "action": jnp.zeros(n_envs, jnp.int32),
           "reward": jnp.ones(n_envs), "next_obs": jnp.ones((n_envs, 4)),
           "done": jnp.zeros(n_envs)}
    a_after = rb.add_batch(st, nxt)
    b_after = rb.add_batch(out, nxt)
    for a, b in zip(jax.tree.leaves(a_after), jax.tree.leaves(b_after)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_nstep_restore_into_wrong_horizon_raises(tmp_path):
    """A checkpoint written with n_step=3 must not silently load into an
    n_step=1 buffer (the manifest's leaf names differ)."""
    rb3 = ReplayBuffer(CAP, make_sampler("per-cumsum", CAP), n_step=3,
                       num_envs=2)
    ex = {"obs": jnp.zeros(4), "action": jnp.int32(0),
          "reward": jnp.float32(0), "next_obs": jnp.zeros(4),
          "done": jnp.float32(0)}
    st = rb3.init(ex)
    for i in range(4):
        st = rb3.add_batch(st, jax.tree.map(
            lambda x: jnp.ones((2,) + jnp.shape(x), jnp.asarray(x).dtype),
            ex))
    rck.save_replay(str(tmp_path), 1, st)
    rb1 = ReplayBuffer(CAP, make_sampler("per-cumsum", CAP))
    with pytest.raises(ValueError):
        rck.restore_replay(str(tmp_path), 1, rb1, ex)


# --- elastic sharded restore -------------------------------------------------


def _sharded_rb(n_shards):
    mesh = jax.make_mesh((n_shards,), ("data",))
    s = make_sampler("amper-fr-sharded", CAP, mesh=mesh,
                     axis_names=("data",), v_max=8.0)
    return ReplayBuffer(CAP, s)


@pytest.mark.parametrize("to_shards", [2, 1])
def test_sharded_restore_onto_fewer_shards(tmp_path, to_shards):
    """Acceptance pin: a sampler saved on 8 shards restores onto 2 (and
    1) with membership-exact priorities and keeps training."""
    if jax.device_count() < 8:
        pytest.skip("needs 8 devices")
    rb8 = _sharded_rb(8)
    st8 = _populated(rb8)
    rck.save_replay(str(tmp_path), 3, st8)

    rb = _sharded_rb(to_shards)
    st = rck.restore_replay(str(tmp_path), 3, rb, EX)
    np.testing.assert_array_equal(
        np.asarray(rb8.sampler.priorities(st8.sampler_state)),
        np.asarray(rb.sampler.priorities(st.sampler_state)))
    # CSP membership for the same key is identical across shard counts
    m8 = np.asarray(rb8.sampler.membership(st8.sampler_state,
                                           jax.random.key(42)))
    m = np.asarray(rb.sampler.membership(st.sampler_state,
                                         jax.random.key(42)))
    np.testing.assert_array_equal(m8, m)
    # the restored table really is partitioned over the target mesh
    assert (st.sampler_state.pq.sharding.num_devices_indexed_by_this_sharding
            if hasattr(st.sampler_state.pq.sharding, "num_devices_indexed_by_this_sharding")
            else len(st.sampler_state.pq.sharding.device_set)) == to_shards
    # ...and keeps training: a full add/sample/update cycle runs
    st = rb.add_batch(st, {"obs": jnp.ones((32, 4)),
                           "reward": jnp.zeros(32)})
    idx, _, w = rb.sample(st, jax.random.key(9), 16)
    st = rb.update_priorities(st, idx, jnp.ones(16))
    assert np.isfinite(np.asarray(w)).all()


def test_sharded_to_single_device_restore(tmp_path):
    if jax.device_count() < 8:
        pytest.skip("needs 8 devices")
    rb8 = _sharded_rb(8)
    st8 = _populated(rb8)
    rck.save_replay(str(tmp_path), 1, st8)
    rb1 = ReplayBuffer(CAP, make_sampler("amper-fr", CAP, v_max=8.0))
    st1 = rck.restore_replay(str(tmp_path), 1, rb1, EX)
    np.testing.assert_array_equal(
        np.asarray(rb8.sampler.priorities(st8.sampler_state)),
        np.asarray(rb1.sampler.priorities(st1.sampler_state)))


def test_repartition_moves_state_onto_mesh():
    if jax.device_count() < 8:
        pytest.skip("needs 8 devices")
    rb2 = _sharded_rb(2)
    # state built dense on one device, repartitioned onto the 2-mesh
    dense = make_sampler("amper-fr", CAP, v_max=8.0).init()
    moved = sharded_mod.repartition(rb2.sampler, dense)
    assert len(moved.pq.sharding.device_set) == 2
    np.testing.assert_array_equal(np.asarray(dense.pq), np.asarray(moved.pq))
