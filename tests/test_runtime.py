"""Async actor–learner runtime: PRNG stream discipline, strict-sync
equivalence with the scan trainer, deferred-feedback exactness and
staleness, block enqueue, stamped out-of-band priority updates, and the
environment registry."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.replay_buffer import ReplayBuffer
from repro.core.samplers import make_sampler, masked_update
from repro.rl import envs as envs_mod
from repro.rl.dqn import DQNConfig, make_dqn
from repro.runtime import ReplayService, prng


# --- environment registry ----------------------------------------------------

def test_env_registry_builds_by_name():
    assert {"cartpole", "acrobot",
            "mountaincar"} <= set(envs_mod.available_envs())
    env = envs_mod.make_env("cartpole")
    assert env.obs_dim == 4 and env.n_actions == 2
    assert envs_mod.make_env("acrobot").obs_dim == 6
    assert envs_mod.make_env("mountaincar").obs_dim == 2


def test_env_registry_unknown_raises():
    with pytest.raises(ValueError, match="unknown env"):
        envs_mod.make_env("pong")


def test_env_registry_backcompat_alias():
    assert envs_mod.ENVS["cartpole"] is envs_mod.CartPole


# --- PRNG stream discipline --------------------------------------------------

def test_no_key_reuse_across_actors_and_prefetch():
    """Regression: every key any runtime thread consumes is distinct —
    across actors, across chunks within an actor, across prefetch draws,
    and across the actor/prefetch stream boundary."""
    key = jax.random.key(0)
    seen = set()

    def fingerprint(k):
        return tuple(np.asarray(jax.random.key_data(k)).ravel().tolist())

    for actor_id in range(4):
        k_reset, k_roll = prng.actor_keys(key, actor_id)
        for k in (k_reset, *(prng.chunk_key(k_roll, c) for c in range(3))):
            fp = fingerprint(k)
            assert fp not in seen, (actor_id, fp)
            seen.add(fp)
    for draw in range(6):
        fp = fingerprint(prng.sample_key(key, draw))
        assert fp not in seen, ("prefetch", draw)
        seen.add(fp)


# --- block enqueue + stamped out-of-band priority updates --------------------

def _block(t, b, obs_dim=3):
    n = t * b
    return {
        "obs": jnp.arange(n * obs_dim, dtype=jnp.float32).reshape(t, b, obs_dim),
        "reward": jnp.arange(n, dtype=jnp.float32).reshape(t, b),
    }


def test_add_block_matches_sequential_add_batch():
    rb = ReplayBuffer(32, make_sampler("per-cumsum", 32))
    example = {"obs": jnp.zeros(3), "reward": jnp.float32(0)}
    block = _block(t=3, b=4)
    s_blk = rb.add_block(rb.init(example), block)
    s_seq = rb.init(example)
    for t in range(3):
        s_seq = rb.add_batch(s_seq, jax.tree.map(lambda x: x[t], block))
    for a, b_ in zip(jax.tree.leaves(s_blk), jax.tree.leaves(s_seq)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_))


def test_write_stamps_track_global_add_counter():
    rb = ReplayBuffer(8, make_sampler("uniform", 8))
    state = rb.init({"x": jnp.float32(0)})
    assert int(state.total_adds) == 0
    assert (np.asarray(state.write_stamp) == -1).all()
    state = rb.add_batch(state, {"x": jnp.zeros(6)})
    state = rb.add_batch(state, {"x": jnp.zeros(4)})   # wraps: 6,7,0,1
    np.testing.assert_array_equal(
        np.asarray(state.write_stamp), [8, 9, 2, 3, 4, 5, 6, 7])
    assert int(state.total_adds) == 10


def test_stamped_update_drops_recycled_slots():
    """A deferred priority update whose slot was overwritten since the
    sample must not clobber the newcomer's (max-priority) entry."""
    rb = ReplayBuffer(8, make_sampler("per-cumsum", 8))
    state = rb.init({"x": jnp.float32(0)})
    state = rb.add_batch(state, {"x": jnp.zeros(6)})
    idx = jnp.array([0, 5])
    stamp = rb.stamps(state, idx)     # sample-time (counter, gen) pairs
    np.testing.assert_array_equal(np.asarray(stamp), [[0, 0], [5, 0]])
    state = rb.add_batch(state, {"x": jnp.zeros(4)})    # recycles slot 0
    state = rb.update_priorities(
        state, idx, jnp.array([5.0, 9.0]), stamp=stamp)
    prios = np.asarray(rb.sampler.priorities(state.sampler_state))
    alpha_p = lambda td: (abs(td) + rb.eps) ** rb.alpha
    # slot 5 still holds its sampled transition -> updated
    np.testing.assert_allclose(prios[5], alpha_p(9.0), rtol=1e-5)
    # slot 0 was recycled -> keeps the newcomer's max-priority write
    np.testing.assert_allclose(prios[0], 1.0, rtol=1e-5)
    # max_priority tracks only the valid rows
    np.testing.assert_allclose(
        float(state.max_priority), max(1.0, alpha_p(9.0)), rtol=1e-5)


def test_add_counter_rollover_bumps_generation():
    """Drive real add_batch calls across the signed-int32 boundary: the
    generation word increments exactly at the rollover, per-row stamps
    keep their wrapping values, and the (counter, gen) pair stays
    monotone in lexicographic order."""
    rb = ReplayBuffer(8, make_sampler("uniform", 8))
    state = rb.init({"x": jnp.float32(0)})
    state = state._replace(total_adds=jnp.int32(2**31 - 3))
    state = rb.add_batch(state, {"x": jnp.zeros(6)})    # 3 pre, 3 post wrap
    assert int(state.add_gen) == 1
    np.testing.assert_array_equal(
        np.asarray(state.write_stamp[:6]),
        np.array([2**31 - 3, 2**31 - 2, 2**31 - 1,
                  -(2**31), -(2**31) + 1, -(2**31) + 2], np.int64))
    np.testing.assert_array_equal(np.asarray(state.write_gen[:6]),
                                  [0, 0, 0, 1, 1, 1])
    assert int(state.total_adds) == -(2**31) + 3        # wrapped counter


def test_stamp_equality_is_wrap_safe_across_generations():
    """A slot recycled an exact multiple of 2^32 adds after the sample
    repeats its int32 counter word; only the generation word tells the
    writes apart.  The single-word comparison this replaces would
    false-accept the stale feedback and clobber the newcomer."""
    rb = ReplayBuffer(8, make_sampler("per-cumsum", 8))
    state = rb.init({"x": jnp.float32(0)})
    state = rb.add_batch(state, {"x": jnp.zeros(6)})
    idx = jnp.array([0, 5])
    stale = rb.stamps(state, idx)                       # gen-0 stamps
    # Forge the 2^32-adds-later recycling: same counter words, bumped
    # generation on slot 0 (as a full lap of _write_arc would produce).
    state = state._replace(
        write_gen=state.write_gen.at[0].set(1), add_gen=jnp.int32(1))
    state = rb.update_priorities(
        state, idx, jnp.array([5.0, 9.0]), stamp=stale)
    prios = np.asarray(rb.sampler.priorities(state.sampler_state))
    alpha_p = lambda td: (abs(td) + rb.eps) ** rb.alpha
    # slot 5 kept its generation -> the update lands
    np.testing.assert_allclose(prios[5], alpha_p(9.0), rtol=1e-5)
    # slot 0's counter matches but its generation moved on -> dropped
    np.testing.assert_allclose(prios[0], 1.0, rtol=1e-5)


def test_masked_update_is_noop_where_invalid():
    s = make_sampler("per-sumtree", 16)
    st = s.update(s.init(), jnp.arange(4), jnp.array([1.0, 2.0, 3.0, 4.0]))
    st2 = masked_update(s, st, jnp.array([1, 2]), jnp.array([9.0, 9.0]),
                        jnp.array([True, False]))
    prios = np.asarray(s.priorities(st2))
    np.testing.assert_allclose(prios[:4], [1.0, 9.0, 3.0, 4.0], rtol=1e-6)


@pytest.mark.parametrize("kind", ["per-cumsum", "per-sumtree", "uniform"])
def test_masked_update_duplicates_last_occurrence_wins(kind):
    """Priority draws are with replacement, so deferred feedback can hit
    the same row several times in one apply; sequential last-write-wins
    semantics must hold regardless of the backend's scatter winner."""
    s = make_sampler(kind, 8)
    st = s.update(s.init(), jnp.arange(8), jnp.full(8, 1.0))
    idx = jnp.array([3, 5, 3, 3, 5])
    pri = jnp.array([10.0, 20.0, 30.0, 40.0, 50.0])
    valid = jnp.array([True, True, True, True, True])
    prios = np.asarray(s.priorities(masked_update(s, st, idx, pri, valid)))
    np.testing.assert_allclose(prios[3], 40.0, rtol=1e-6)   # last write to 3
    np.testing.assert_allclose(prios[5], 50.0, rtol=1e-6)   # last write to 5
    # a trailing invalid duplicate must not clobber a valid earlier write
    prios2 = np.asarray(s.priorities(masked_update(
        s, st, jnp.array([3, 3]), jnp.array([10.0, 99.0]),
        jnp.array([True, False]))))
    np.testing.assert_allclose(prios2[3], 10.0, rtol=1e-6)


# --- n-step accumulator ------------------------------------------------------

def _nstep_reference(trs, n, gamma):
    """Hand-rolled n-step aggregation over a [T] list of per-env dicts:
    for each window start t (t + n <= T), the discounted return truncated
    at the first done, the bootstrap obs, and the any-done flag."""
    out = []
    for t in range(len(trs) - n + 1):
        w = trs[t:t + n]
        reward, cont = 0.0, 1.0
        h = n - 1
        for k in range(n):
            reward += (gamma ** k) * cont * w[k]["reward"]
            if w[k]["done"] > 0.5:
                h = k
                cont = 0.0
                break
        done = 1.0 if cont == 0.0 else 0.0
        out.append({"obs": w[0]["obs"], "action": w[0]["action"],
                    "reward": reward, "next_obs": w[h]["next_obs"],
                    "done": done})
    return out


def test_nstep_accumulator_matches_reference():
    from repro.core.replay_buffer import NStepAccumulator

    n, gamma, T, E = 3, 0.9, 12, 2
    rng = np.random.default_rng(0)
    acc = NStepAccumulator(n, gamma)
    ex = {"obs": jnp.zeros(2), "action": jnp.int32(0),
          "reward": jnp.float32(0), "next_obs": jnp.zeros(2),
          "done": jnp.float32(0)}
    st = acc.init(ex, E)
    stream = []          # per timestep: [E] transition batch
    for t in range(T):
        stream.append({
            "obs": rng.normal(size=(E, 2)).astype(np.float32),
            "action": rng.integers(0, 2, E).astype(np.int32),
            "reward": rng.normal(size=E).astype(np.float32),
            "next_obs": rng.normal(size=(E, 2)).astype(np.float32),
            "done": (rng.random(E) < 0.3).astype(np.float32)})
    emitted = []
    for t in range(T):
        st, out, valid = acc.push(st, jax.tree.map(jnp.asarray, stream[t]))
        assert bool(valid) == (t >= n - 1)
        if valid:
            emitted.append(jax.tree.map(np.asarray, out))
    for e in range(E):
        per_env = [{k: v[e] for k, v in tr.items()} for tr in stream]
        ref = _nstep_reference(per_env, n, gamma)
        assert len(ref) == len(emitted)
        for i, r in enumerate(ref):
            for k in ("obs", "action", "reward", "next_obs", "done"):
                np.testing.assert_allclose(
                    np.asarray(emitted[i][k])[e], r[k], rtol=1e-5,
                    atol=1e-6, err_msg=f"env {e} window {i} field {k}")


def test_nstep_add_block_matches_sequential_add_batch():
    """Raw-block ingestion must scan the accumulator exactly like T
    sequential vectorized add_batch calls (and skip warm-up rows)."""
    rb = ReplayBuffer(64, make_sampler("per-cumsum", 64), n_step=3,
                      gamma=0.95, num_envs=4)
    ex = {"obs": jnp.zeros(3), "reward": jnp.float32(0),
          "next_obs": jnp.zeros(3), "action": jnp.int32(0),
          "done": jnp.float32(0)}
    key = jax.random.key(0)
    block = {
        "obs": jax.random.normal(jax.random.fold_in(key, 0), (6, 4, 3)),
        "reward": jax.random.normal(jax.random.fold_in(key, 1), (6, 4)),
        "next_obs": jax.random.normal(jax.random.fold_in(key, 2), (6, 4, 3)),
        "action": jnp.zeros((6, 4), jnp.int32),
        "done": (jax.random.uniform(jax.random.fold_in(key, 3),
                                    (6, 4)) < 0.2).astype(jnp.float32)}
    s_blk = rb.add_block(rb.init(ex), block)
    s_seq = rb.init(ex)
    for t in range(6):
        s_seq = rb.add_batch(s_seq, jax.tree.map(lambda x: x[t], block))
    assert int(s_blk.size) == 4 * 4  # 2 warm-up steps emitted nothing
    for a, b_ in zip(jax.tree.leaves(s_blk), jax.tree.leaves(s_seq)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_))


def test_nstep_add_batch_rejects_wrong_width():
    rb = ReplayBuffer(32, make_sampler("uniform", 32), n_step=2, num_envs=4)
    ex = {"obs": jnp.zeros(2), "reward": jnp.float32(0),
          "next_obs": jnp.zeros(2), "action": jnp.int32(0),
          "done": jnp.float32(0)}
    st = rb.init(ex)
    with pytest.raises(ValueError, match="num_envs"):
        rb.add_batch(st, jax.tree.map(
            lambda x: jnp.zeros((3,) + jnp.shape(x), jnp.asarray(x).dtype),
            ex))


# --- strict-sync equivalence -------------------------------------------------

def test_sync_requires_single_actor():
    with pytest.raises(ValueError, match="sync mode"):
        ReplayService(DQNConfig(), sync=True, num_actors=2)


@pytest.mark.parametrize("agent,n_step", [("dqn", 1), ("double", 3),
                                          ("dueling", 2)])
def test_sync_service_matches_scan_trainer(agent, n_step):
    """`ReplayService(sync=True, num_actors=1)` reproduces the lax.scan
    trainer's CartPole learning curve (and final params) within float
    tolerance — the strict synchronous mode is the scan trainer, across
    the whole agent family including n-step replay (acceptance pin)."""
    cfg = DQNConfig(agent=agent, n_step=n_step, num_envs=1, replay_size=512,
                    batch=32, learn_start=100, eps_decay_steps=500,
                    target_sync=50)
    key = jax.random.key(0)
    n = 300
    dqn = make_dqn(cfg)
    state, metrics = dqn.train(key, n)
    res = ReplayService(cfg, sync=True, num_actors=1).run(key, n)
    np.testing.assert_allclose(
        np.asarray(metrics["return_mean"]), res.metrics["return_curve"],
        rtol=1e-4, atol=1e-4)
    for a, b in zip(jax.tree.leaves(state.params),
                    jax.tree.leaves(res.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)
    assert res.metrics["learner_steps"] == n - cfg.learn_start


# --- async mode: deferred feedback contract ----------------------------------

@pytest.mark.parametrize("sampler,agent,n_step",
                         [("per-sumtree", "dqn", 1),
                          ("amper-fr", "dqn", 1),
                          ("amper-fr", "double", 3)])
def test_async_feedback_exactly_once_in_order(sampler, agent, n_step):
    """Every learner batch's deferred priority update is applied exactly
    once, in learner-step order, with non-negative measured staleness —
    including with per-actor n-step aggregation in the rollout path."""
    cfg = DQNConfig(sampler=sampler, agent=agent, n_step=n_step,
                    num_envs=2, replay_size=256, batch=16,
                    learn_start=8, eps_decay_steps=200, target_sync=50,
                    v_max=8.0)
    svc = ReplayService(cfg, num_actors=2, chunk_len=4, slab=2,
                        queue_size=4, max_replay_ratio=64,
                        feedback_log=True)
    res = svc.run(jax.random.key(1), 20)
    m = res.metrics
    assert m["learner_steps"] == 20
    assert m["feedback_seqs"] == list(range(20)), m["feedback_seqs"]
    assert m["staleness"]["count"] == 20
    assert 0 <= m["staleness"]["mean"] <= m["staleness"]["max"]
    assert m["frames"] > 0 and int(res.buffer.size) > 0
    # evaluate accepts the bare params the runtime returns
    score = float(svc.dqn.evaluate(res.params, jax.random.key(2), 2))
    assert np.isfinite(score)
    for leaf in jax.tree.leaves(res.params):
        assert bool(jnp.all(jnp.isfinite(leaf)))


# --- metrics / durability satellites -----------------------------------------


def test_check_meta_missing_key_is_loud():
    """A checkpoint written before a topology field existed must be
    rejected, not silently accepted (.get(k, want) would pass it)."""
    ok = {"mode": "async", "num_actors": 2}
    ReplayService._check_meta(ok, "async", num_actors=2)
    with pytest.raises(ValueError, match="mode"):
        ReplayService._check_meta({"mode": "sync"}, "async")
    with pytest.raises(ValueError, match="num_actors"):
        ReplayService._check_meta({"mode": "async"}, "async", num_actors=2)
    with pytest.raises(ValueError, match="num_actors=3"):
        ReplayService._check_meta({"mode": "async", "num_actors": 3},
                                  "async", num_actors=2)


def test_prefetch_beta_not_published_for_a_draw_that_never_happened():
    """last_beta is the β of the latest *completed* slab draw: a draw
    that raises must leave it untouched (it was being set before the
    sample call, so metrics could report a β no slab ever used)."""
    import queue
    import threading
    from types import SimpleNamespace

    from repro.runtime.pipeline import PrefetchPipeline

    state = SimpleNamespace(size=jnp.int32(64))

    def failing_sample(st, key, beta):
        raise RuntimeError("sampler exploded")

    stop = threading.Event()
    p = PrefetchPipeline(failing_sample, lambda: (state, 0),
                         out_q=queue.Queue(2), stop=stop,
                         base_key=jax.random.key(0), slab=2, min_size=1,
                         beta_fn=lambda v: 0.7)
    p.start()
    p.join(timeout=10.0)
    assert not p.is_alive()
    assert isinstance(p.error, RuntimeError)
    assert p.last_beta is None  # no completed draw -> no published beta
    assert p.draws == 0
    stop.set()
