"""Fault tolerance: atomic checkpointing, retention, bitwise resume."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import checkpoint as ck

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = {**os.environ, "PYTHONPATH": os.path.join(REPO, "src")}


def tree():
    return {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "b": {"c": jnp.int32(7), "d": jnp.ones((5,), jnp.bfloat16)}}


def test_save_restore_roundtrip(tmp_path):
    t = tree()
    ck.save(str(tmp_path), 3, t)
    out = ck.restore(str(tmp_path), 3, jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t))
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_no_tmp_left_behind(tmp_path):
    ck.save(str(tmp_path), 1, tree())
    assert not any(d.endswith(".tmp") for d in os.listdir(tmp_path))


def test_retention_keeps_latest(tmp_path):
    mgr = ck.CheckpointManager(str(tmp_path), keep=2, save_interval=1)
    for s in range(5):
        mgr.save(s, tree())
    assert ck.available_steps(str(tmp_path)) == [3, 4]
    assert mgr.latest_step() == 4


def test_restore_latest_with_manager(tmp_path):
    mgr = ck.CheckpointManager(str(tmp_path), keep=3)
    t = tree()
    mgr.save(7, t)
    step, out = mgr.restore_latest(jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t))
    assert step == 7
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(t["a"]))


def test_corrupt_partial_checkpoint_ignored(tmp_path):
    mgr = ck.CheckpointManager(str(tmp_path), keep=3)
    mgr.save(1, tree())
    # simulate a crash mid-write: tmp dir without manifest
    os.makedirs(tmp_path / "step_0000000002.tmp")
    assert mgr.latest_step() == 1


def test_shape_mismatch_raises(tmp_path):
    ck.save(str(tmp_path), 1, {"a": jnp.zeros((3,))})
    with pytest.raises(ValueError):
        ck.restore(str(tmp_path), 1,
                   {"a": jax.ShapeDtypeStruct((4,), jnp.float32)})


@pytest.mark.slow
def test_train_resume_bitwise(tmp_path):
    """Kill-and-resume produces the SAME final checkpoint as an
    uninterrupted run (step-seeded data + deterministic kernels)."""
    ckdir_a = str(tmp_path / "a")
    ckdir_b = str(tmp_path / "b")
    args = [sys.executable, "-m", "repro.launch.train", "--arch",
            "stablelm-1.6b", "--reduced", "--batch", "4", "--seq-len", "32",
            "--n-seqs", "64", "--sampler", "amper-fr", "--log-every", "100"]
    # uninterrupted 6 steps
    subprocess.run(args + ["--steps", "6", "--ckpt-dir", ckdir_a,
                           "--ckpt-every", "100"],
                   check=True, env=ENV, cwd=REPO, capture_output=True)
    # 3 steps, stop, resume to 6
    subprocess.run(args + ["--steps", "3", "--ckpt-dir", ckdir_b,
                           "--ckpt-every", "100"],
                   check=True, env=ENV, cwd=REPO, capture_output=True)
    subprocess.run(args + ["--steps", "6", "--ckpt-dir", ckdir_b,
                           "--ckpt-every", "100"],
                   check=True, env=ENV, cwd=REPO, capture_output=True)
    import numpy as np
    a = np.load(os.path.join(ckdir_a, "step_0000000006", "arrays.npz"))
    b = np.load(os.path.join(ckdir_b, "step_0000000006", "arrays.npz"))
    assert set(a.files) == set(b.files)
    for f in a.files:
        np.testing.assert_array_equal(a[f], b[f], err_msg=f)
