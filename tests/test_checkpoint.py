"""Fault tolerance: atomic checkpointing, retention, bitwise resume,
manifest validation, preemption plumbing."""
import os
import subprocess
import sys
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import checkpoint as ck

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = {**os.environ, "PYTHONPATH": os.path.join(REPO, "src")}


def tree():
    return {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "b": {"c": jnp.int32(7), "d": jnp.ones((5,), jnp.bfloat16)}}


def test_save_restore_roundtrip(tmp_path):
    t = tree()
    ck.save(str(tmp_path), 3, t)
    out = ck.restore(str(tmp_path), 3, jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t))
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_no_tmp_left_behind(tmp_path):
    ck.save(str(tmp_path), 1, tree())
    assert not any(d.endswith(".tmp") for d in os.listdir(tmp_path))


def test_retention_keeps_latest(tmp_path):
    mgr = ck.CheckpointManager(str(tmp_path), keep=2, save_interval=1)
    for s in range(5):
        mgr.save(s, tree())
    assert ck.available_steps(str(tmp_path)) == [3, 4]
    assert mgr.latest_step() == 4


def test_restore_latest_with_manager(tmp_path):
    mgr = ck.CheckpointManager(str(tmp_path), keep=3)
    t = tree()
    mgr.save(7, t)
    step, out = mgr.restore_latest(jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t))
    assert step == 7
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(t["a"]))


def test_corrupt_partial_checkpoint_ignored(tmp_path):
    mgr = ck.CheckpointManager(str(tmp_path), keep=3)
    mgr.save(1, tree())
    # simulate a crash mid-write: tmp dir without manifest
    os.makedirs(tmp_path / "step_0000000002.tmp")
    assert mgr.latest_step() == 1


def test_shape_mismatch_raises(tmp_path):
    ck.save(str(tmp_path), 1, {"a": jnp.zeros((3,))})
    with pytest.raises(ValueError):
        ck.restore(str(tmp_path), 1,
                   {"a": jax.ShapeDtypeStruct((4,), jnp.float32)})


def test_restore_wrong_names_raises_with_diff(tmp_path):
    """Leaves must never be matched by position alone: same leaf count,
    different structure -> a readable name diff, not transposed loads."""
    ck.save(str(tmp_path), 1, {"a": jnp.zeros(3), "b": jnp.ones(3)})
    with pytest.raises(ValueError, match="'b'.*'c'"):
        ck.restore(str(tmp_path), 1,
                   {"a": jax.ShapeDtypeStruct((3,), jnp.float32),
                    "c": jax.ShapeDtypeStruct((3,), jnp.float32)})


def test_restore_dtype_mismatch_raises(tmp_path):
    ck.save(str(tmp_path), 1, {"a": jnp.zeros(3, jnp.float32)})
    with pytest.raises(ValueError, match="dtype mismatch"):
        ck.restore(str(tmp_path), 1,
                   {"a": jax.ShapeDtypeStruct((3,), jnp.int32)})


def test_restore_namedtuple_field_names_validated(tmp_path):
    """Different NamedTuple state types with the same leaf count must not
    silently cross-load (the sampler-state hazard)."""
    from repro.core.amper import AmperConfig, AmperSampler
    from repro.core.per import SumTreePER

    ck.save(str(tmp_path), 1, SumTreePER(8).init())
    amper = AmperSampler(AmperConfig(capacity=8))
    with pytest.raises(ValueError):
        ck.restore(str(tmp_path), 1, jax.eval_shape(amper.init))


def test_meta_roundtrip(tmp_path):
    ck.save(str(tmp_path), 5, tree(),
            meta={"mode": "async", "draw": 17})
    assert ck.load_meta(str(tmp_path), 5) == {"mode": "async", "draw": 17}
    assert ck.load_meta(str(tmp_path), 5).get("absent") is None


def test_manager_gcs_stale_tmp_dirs(tmp_path):
    """step_*.tmp litter from a crashed save is collected, finished
    checkpoints are untouched."""
    mgr = ck.CheckpointManager(str(tmp_path), keep=3, save_interval=1)
    mgr.save(1, tree())
    os.makedirs(tmp_path / "step_0000000002.tmp")
    mgr.save(3, tree())  # _gc runs after each save
    names = os.listdir(tmp_path)
    assert not any(n.endswith(".tmp") for n in names)
    assert ck.available_steps(str(tmp_path)) == [1, 3]
    # construction-time GC too
    os.makedirs(tmp_path / "step_0000000009.tmp")
    ck.CheckpointManager(str(tmp_path))
    assert not any(n.endswith(".tmp") for n in os.listdir(tmp_path))


def test_preemption_hook_from_worker_thread_degrades(tmp_path):
    """signal.signal raises ValueError off the main thread — the manager
    must NOT: it returns False and stays usable via the polled flag."""
    mgr = ck.CheckpointManager(str(tmp_path))
    out = {}

    def worker():
        out["installed"] = mgr.install_preemption_hook()

    t = threading.Thread(target=worker)
    t.start()
    t.join()
    assert out["installed"] is False
    assert not mgr.preempted
    mgr.request_preemption()
    assert mgr.preempted
    assert mgr.should_save(1)


def test_preemption_sentinel_file_polled(tmp_path):
    mgr = ck.CheckpointManager(str(tmp_path))
    assert not mgr.preempted
    open(os.path.join(str(tmp_path), ck.PREEMPT_SENTINEL), "w").close()
    assert mgr.preempted


def test_preemption_sentinel_is_one_shot(tmp_path):
    """The relaunch after a sentinel-triggered exit must resume, not
    immediately preempt itself: a fresh manager consumes the file."""
    open(os.path.join(str(tmp_path), ck.PREEMPT_SENTINEL), "w").close()
    mgr = ck.CheckpointManager(str(tmp_path))
    assert not mgr.preempted
    assert not os.path.exists(os.path.join(str(tmp_path),
                                           ck.PREEMPT_SENTINEL))


@pytest.mark.slow
def test_train_resume_bitwise(tmp_path):
    """Kill-and-resume produces the SAME final checkpoint as an
    uninterrupted run (step-seeded data + deterministic kernels)."""
    ckdir_a = str(tmp_path / "a")
    ckdir_b = str(tmp_path / "b")
    args = [sys.executable, "-m", "repro.launch.train", "--arch",
            "stablelm-1.6b", "--reduced", "--batch", "4", "--seq-len", "32",
            "--n-seqs", "64", "--sampler", "amper-fr", "--log-every", "100"]
    # uninterrupted 6 steps
    subprocess.run(args + ["--steps", "6", "--ckpt-dir", ckdir_a,
                           "--ckpt-every", "100"],
                   check=True, env=ENV, cwd=REPO, capture_output=True)
    # 3 steps, stop, resume to 6
    subprocess.run(args + ["--steps", "3", "--ckpt-dir", ckdir_b,
                           "--ckpt-every", "100"],
                   check=True, env=ENV, cwd=REPO, capture_output=True)
    subprocess.run(args + ["--steps", "6", "--ckpt-dir", ckdir_b,
                           "--ckpt-every", "100"],
                   check=True, env=ENV, cwd=REPO, capture_output=True)
    import numpy as np
    a = np.load(os.path.join(ckdir_a, "step_0000000006", "arrays.npz"))
    b = np.load(os.path.join(ckdir_b, "step_0000000006", "arrays.npz"))
    assert set(a.files) == set(b.files)
    for f in a.files:
        np.testing.assert_array_equal(a[f], b[f], err_msg=f)
