"""Fault tolerance: atomic checkpointing, retention, bitwise resume,
manifest validation, preemption plumbing."""
import os
import subprocess
import sys
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import checkpoint as ck

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = {**os.environ, "PYTHONPATH": os.path.join(REPO, "src")}


def tree():
    return {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "b": {"c": jnp.int32(7), "d": jnp.ones((5,), jnp.bfloat16)}}


def test_save_restore_roundtrip(tmp_path):
    t = tree()
    ck.save(str(tmp_path), 3, t)
    out = ck.restore(str(tmp_path), 3, jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t))
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_no_tmp_left_behind(tmp_path):
    ck.save(str(tmp_path), 1, tree())
    assert not any(d.endswith(".tmp") for d in os.listdir(tmp_path))


def test_retention_keeps_latest(tmp_path):
    mgr = ck.CheckpointManager(str(tmp_path), keep=2, save_interval=1)
    for s in range(5):
        mgr.save(s, tree())
    assert ck.available_steps(str(tmp_path)) == [3, 4]
    assert mgr.latest_step() == 4


def test_restore_latest_with_manager(tmp_path):
    mgr = ck.CheckpointManager(str(tmp_path), keep=3)
    t = tree()
    mgr.save(7, t)
    step, out = mgr.restore_latest(jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t))
    assert step == 7
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(t["a"]))


def test_corrupt_partial_checkpoint_ignored(tmp_path):
    mgr = ck.CheckpointManager(str(tmp_path), keep=3)
    mgr.save(1, tree())
    # simulate a crash mid-write: tmp dir without manifest
    os.makedirs(tmp_path / "step_0000000002.tmp")
    assert mgr.latest_step() == 1


def test_shape_mismatch_raises(tmp_path):
    ck.save(str(tmp_path), 1, {"a": jnp.zeros((3,))})
    with pytest.raises(ValueError):
        ck.restore(str(tmp_path), 1,
                   {"a": jax.ShapeDtypeStruct((4,), jnp.float32)})


def test_restore_wrong_names_raises_with_diff(tmp_path):
    """Leaves must never be matched by position alone: same leaf count,
    different structure -> a readable name diff, not transposed loads."""
    ck.save(str(tmp_path), 1, {"a": jnp.zeros(3), "b": jnp.ones(3)})
    with pytest.raises(ValueError, match="'b'.*'c'"):
        ck.restore(str(tmp_path), 1,
                   {"a": jax.ShapeDtypeStruct((3,), jnp.float32),
                    "c": jax.ShapeDtypeStruct((3,), jnp.float32)})


def test_restore_dtype_mismatch_raises(tmp_path):
    ck.save(str(tmp_path), 1, {"a": jnp.zeros(3, jnp.float32)})
    with pytest.raises(ValueError, match="dtype mismatch"):
        ck.restore(str(tmp_path), 1,
                   {"a": jax.ShapeDtypeStruct((3,), jnp.int32)})


def test_restore_namedtuple_field_names_validated(tmp_path):
    """Different NamedTuple state types with the same leaf count must not
    silently cross-load (the sampler-state hazard)."""
    from repro.core.amper import AmperConfig, AmperSampler
    from repro.core.per import SumTreePER

    ck.save(str(tmp_path), 1, SumTreePER(8).init())
    amper = AmperSampler(AmperConfig(capacity=8))
    with pytest.raises(ValueError):
        ck.restore(str(tmp_path), 1, jax.eval_shape(amper.init))


def test_meta_roundtrip(tmp_path):
    ck.save(str(tmp_path), 5, tree(),
            meta={"mode": "async", "draw": 17})
    assert ck.load_meta(str(tmp_path), 5) == {"mode": "async", "draw": 17}
    assert ck.load_meta(str(tmp_path), 5).get("absent") is None


def test_manager_gcs_stale_tmp_dirs(tmp_path):
    """step_*.tmp litter from a crashed save is collected, finished
    checkpoints are untouched."""
    mgr = ck.CheckpointManager(str(tmp_path), keep=3, save_interval=1)
    mgr.save(1, tree())
    os.makedirs(tmp_path / "step_0000000002.tmp")
    mgr.save(3, tree())  # _gc runs after each save
    names = os.listdir(tmp_path)
    assert not any(n.endswith(".tmp") for n in names)
    assert ck.available_steps(str(tmp_path)) == [1, 3]
    # construction-time GC too
    os.makedirs(tmp_path / "step_0000000009.tmp")
    ck.CheckpointManager(str(tmp_path))
    assert not any(n.endswith(".tmp") for n in os.listdir(tmp_path))


def test_preemption_hook_from_worker_thread_degrades(tmp_path):
    """signal.signal raises ValueError off the main thread — the manager
    must NOT: it returns False and stays usable via the polled flag."""
    mgr = ck.CheckpointManager(str(tmp_path))
    out = {}

    def worker():
        out["installed"] = mgr.install_preemption_hook()

    t = threading.Thread(target=worker)
    t.start()
    t.join()
    assert out["installed"] is False
    assert not mgr.preempted
    mgr.request_preemption()
    assert mgr.preempted
    assert mgr.should_save(1)


def test_preemption_sentinel_file_polled(tmp_path):
    mgr = ck.CheckpointManager(str(tmp_path))
    assert not mgr.preempted
    open(os.path.join(str(tmp_path), ck.PREEMPT_SENTINEL), "w").close()
    assert mgr.preempted


def test_preemption_sentinel_is_one_shot(tmp_path):
    """The relaunch after a sentinel-triggered exit must resume, not
    immediately preempt itself: a fresh manager consumes the file."""
    open(os.path.join(str(tmp_path), ck.PREEMPT_SENTINEL), "w").close()
    mgr = ck.CheckpointManager(str(tmp_path))
    assert not mgr.preempted
    assert not os.path.exists(os.path.join(str(tmp_path),
                                           ck.PREEMPT_SENTINEL))


def sds(t):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(jnp.shape(x), jnp.asarray(x).dtype), t)


def assert_trees_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x, np.float32),
                                      np.asarray(y, np.float32))


# ---------------------------------------------------------------- incremental


def ring_tree():
    """A tree shaped like replay state: a ring array + scalars."""
    return {"ring": jnp.arange(32, dtype=jnp.float32).reshape(8, 4),
            "prio": jnp.ones((8,), jnp.float32),
            "pos": jnp.int32(0)}


def test_incremental_full_is_self_contained(tmp_path):
    t = tree()
    ck.save_incremental(str(tmp_path), 4, t)
    assert os.path.exists(tmp_path / "step_0000000004.ckpt")
    out = ck.restore(str(tmp_path), 4, sds(t))
    assert_trees_equal(t, out)
    assert jax.tree.leaves(out)[-1].dtype == jnp.bfloat16


def test_incremental_delta_chain_roundtrip(tmp_path):
    d = str(tmp_path)
    t = ring_tree()
    ck.save_incremental(d, 1, t)
    # delta 1: rows 2..5 of the ring rewritten, pos moved
    t2 = {"ring": t["ring"].at[2:5].set(-1.0), "prio": t["prio"],
          "pos": jnp.int32(5)}
    dirty2 = {"ring": ck.Rows([(2, 5)]), "prio": False, "pos": True}
    ck.save_incremental(d, 2, t2, base_step=1, dirty=dirty2)
    # delta 2: a wrapping arc (rows 6..8 and 0..1) plus priority rows
    t3 = {"ring": t2["ring"].at[6:].set(7.0).at[:1].set(9.0),
          "prio": t2["prio"].at[3].set(0.5), "pos": jnp.int32(1)}
    dirty3 = {"ring": ck.Rows([(6, 8), (0, 1)]),
              "prio": ck.Rows([(3, 4)]), "pos": True}
    ck.save_incremental(d, 3, t3, base_step=2, dirty=dirty3)
    for step, want in ((1, t), (2, t2), (3, t3)):
        assert_trees_equal(want, ck.restore(d, step, sds(t)))
    # the deltas really are deltas: step 3 stores 3+1 ring rows, not 8
    with np.load(os.path.join(d, "step_0000000003.ckpt")) as z:
        stored = {k: z[k].shape for k in z.files if k != "__manifest__"}
    names, _, _ = ck._flatten_with_names(t3)
    ring_i = names.index("ring")
    assert stored[f"d{ring_i}"] == (3, 4)


def test_incremental_delta_over_legacy_dir_base(tmp_path):
    """A single-file delta can chain onto a legacy dir-layout full save."""
    d = str(tmp_path)
    t = ring_tree()
    ck.save(d, 1, t)  # dir layout
    t2 = {"ring": t["ring"].at[0:2].set(3.0), "prio": t["prio"],
          "pos": jnp.int32(2)}
    ck.save_incremental(d, 2, t2, base_step=1,
                        dirty={"ring": ck.Rows([(0, 2)]), "prio": False,
                               "pos": True})
    assert_trees_equal(t2, ck.restore(d, 2, sds(t)))


def test_incremental_validation_errors(tmp_path):
    d = str(tmp_path)
    t = ring_tree()
    with pytest.raises(ValueError, match="base_step"):
        ck.save_incremental(d, 2, t, dirty=ck.dirty_like(t))
    with pytest.raises(ValueError, match="not found"):
        ck.save_incremental(d, 2, t, base_step=1, dirty=ck.dirty_like(t))
    ck.save_incremental(d, 5, t)
    with pytest.raises(ValueError, match="precede"):
        ck.save_incremental(d, 5, t, base_step=5, dirty=ck.dirty_like(t))
    with pytest.raises(ValueError, match="leaves"):
        ck.save_incremental(d, 6, t, base_step=5,
                            dirty={"ring": True, "pos": True})
    with pytest.raises(ValueError, match="rank-0"):
        ck.save_incremental(d, 6, t, base_step=5,
                            dirty={"ring": True, "prio": True,
                                   "pos": ck.Rows([(0, 1)])})
    with pytest.raises(ValueError, match="outside"):
        ck.save_incremental(d, 6, t, base_step=5,
                            dirty={"ring": ck.Rows([(4, 99)]), "prio": True,
                                   "pos": True})
    ck.save(d, 7, t)  # dir layout at step 7
    with pytest.raises(ValueError, match="shadow"):
        ck.save_incremental(d, 7, t)


def test_manager_constructor_validates(tmp_path):
    with pytest.raises(ValueError, match="keep"):
        ck.CheckpointManager(str(tmp_path), keep=0)
    with pytest.raises(ValueError, match="save_interval"):
        ck.CheckpointManager(str(tmp_path), save_interval=0)
    with pytest.raises(ValueError, match="full_every"):
        ck.CheckpointManager(str(tmp_path), full_every=0)


def test_manager_delta_chain_compaction_and_gc(tmp_path):
    """Dirty-aware saves chain deltas, compact every ``full_every``
    saves, and GC retains the transitive bases a live chain needs."""
    d = str(tmp_path)
    mgr = ck.CheckpointManager(d, keep=2, save_interval=1, full_every=3)
    t = ring_tree()
    states = {}
    for s in range(1, 8):
        t = {"ring": t["ring"].at[s % 8].set(float(s)), "prio": t["prio"],
             "pos": jnp.int32(s % 8)}
        states[s] = t
        mgr.save(s, t, dirty={"ring": ck.Rows([(s % 8, s % 8 + 1)]),
                              "prio": False, "pos": True})
    # compaction cadence: full at 1 (no base), 4, 7; deltas in between
    for s, base in ((1, None), (2, 1), (3, 2), (4, None), (5, 4), (6, 5),
                    (7, None)):
        if s in ck.available_steps(d):
            assert ck.load_manifest(d, s).get("base_step") == base, s
    # keep=2 -> steps {6, 7} retained; 6 chains to 5 to 4 (retained as
    # bases), the fully-compacted 1..3 chain is gone
    steps = set(ck.available_steps(d))
    assert {6, 7} <= steps
    assert steps.isdisjoint({1, 2, 3})
    assert {4, 5} <= steps  # step 6's chain
    # every retained step restores to its exact saved state
    for s in sorted(steps):
        assert_trees_equal(states[s], ck.restore(d, s, sds(t)))


def test_manager_resumes_chain_across_construction(tmp_path):
    """A fresh manager continues the on-disk delta chain (and its
    compaction count) instead of restarting from zero knowledge."""
    d = str(tmp_path)
    t = ring_tree()
    mgr = ck.CheckpointManager(d, keep=4, save_interval=1, full_every=3)
    mgr.save(1, t)
    mgr.save(2, t, dirty={"ring": ck.Rows([(0, 1)]), "prio": False,
                          "pos": True})
    mgr2 = ck.CheckpointManager(d, keep=4, save_interval=1, full_every=3)
    mgr2.save(3, t, dirty={"ring": ck.Rows([(1, 2)]), "prio": False,
                           "pos": True})
    assert ck.load_manifest(d, 3).get("base_step") == 2
    mgr2.save(4, t, dirty=ck.dirty_like(t, True))
    # the 1<-2<-3 chain is full_every-1 = 2 deltas deep -> compact now
    assert ck.load_manifest(d, 4).get("base_step") is None


def test_crash_between_rmtree_and_replace_resumes(tmp_path, monkeypatch):
    """The dir-layout save's worst crash window: the old final dir is
    already rmtree'd but the tmp rename never happened.  The manager
    must resume from the previous retained step and collect the litter."""
    d = str(tmp_path)
    t = tree()
    ck.save(d, 1, t)
    ck.save(d, 2, t)

    real_replace = os.replace

    def boom(src, dst):
        raise RuntimeError("killed mid-save")

    monkeypatch.setattr(ck.os, "replace", boom)
    with pytest.raises(RuntimeError, match="killed"):
        ck.save(d, 2, tree())  # overwrite save: rmtree ran, rename didn't
    monkeypatch.setattr(ck.os, "replace", real_replace)
    assert "step_0000000002.tmp" in os.listdir(d)  # litter
    assert 2 not in ck.available_steps(d)          # old 2 is gone
    mgr = ck.CheckpointManager(d, keep=3)
    assert mgr.latest_step() == 1                  # previous retained step
    assert not any(n.endswith(".tmp") for n in os.listdir(d))
    step, out = mgr.restore_latest(sds(t))
    assert step == 1
    assert_trees_equal(t, out)


def test_crash_mid_single_file_save_resumes(tmp_path, monkeypatch):
    """Same for the single-file layout: a ``.ckpt.tmp`` left by a crash
    before the rename is litter, never the latest checkpoint."""
    d = str(tmp_path)
    t = tree()
    ck.save_incremental(d, 1, t)

    def boom(src, dst):
        raise RuntimeError("killed mid-save")

    monkeypatch.setattr(ck.os, "replace", boom)
    with pytest.raises(RuntimeError, match="killed"):
        ck.save_incremental(d, 2, t)
    monkeypatch.undo()
    assert "step_0000000002.ckpt.tmp" in os.listdir(d)
    mgr = ck.CheckpointManager(d, keep=3)
    assert mgr.latest_step() == 1
    assert not any(n.endswith(".tmp") for n in os.listdir(d))
    assert ck.gc_stale_tmp(d) == []  # already clean


def test_manifest_names_stable_across_container_kinds(tmp_path):
    """dict / tuple / NamedTuple nodes all contribute bare component
    names (no leading dots, no container-kind artifacts) — the regression
    that made attr-keyed nodes render as ``.field``."""
    from typing import Any, NamedTuple

    class Inner(NamedTuple):
        w: Any
        layers: Any

    t = {"k": (jnp.int32(1), jnp.zeros(2)),
         "m": Inner(w=jnp.ones(3), layers=[jnp.zeros(2), jnp.arange(2)])}
    names, leaves, _ = ck._flatten_with_names(t)
    assert names == ["k/0", "k/1", "m/w", "m/layers/0", "m/layers/1"]
    assert all("." not in n for n in names)
    # and the names survive a save/restore roundtrip as the validation key
    ck.save_incremental(str(tmp_path), 1, t)
    assert ck.load_manifest(str(tmp_path), 1)["names"] == names
    out = ck.restore(str(tmp_path), 1, sds(t))
    assert_trees_equal(t, out)


@pytest.mark.slow
def test_train_resume_bitwise(tmp_path):
    """Kill-and-resume produces the SAME final checkpoint as an
    uninterrupted run (step-seeded data + deterministic kernels)."""
    ckdir_a = str(tmp_path / "a")
    ckdir_b = str(tmp_path / "b")
    args = [sys.executable, "-m", "repro.launch.train", "--arch",
            "stablelm-1.6b", "--reduced", "--batch", "4", "--seq-len", "32",
            "--n-seqs", "64", "--sampler", "amper-fr", "--log-every", "100"]
    # uninterrupted 6 steps
    subprocess.run(args + ["--steps", "6", "--ckpt-dir", ckdir_a,
                           "--ckpt-every", "100"],
                   check=True, env=ENV, cwd=REPO, capture_output=True)
    # 3 steps, stop, resume to 6
    subprocess.run(args + ["--steps", "3", "--ckpt-dir", ckdir_b,
                           "--ckpt-every", "100"],
                   check=True, env=ENV, cwd=REPO, capture_output=True)
    subprocess.run(args + ["--steps", "6", "--ckpt-dir", ckdir_b,
                           "--ckpt-every", "100"],
                   check=True, env=ENV, cwd=REPO, capture_output=True)
    import numpy as np
    # The manager writes single-file checkpoints: compare the raw stored
    # arrays (both runs end on a full save, so the payloads are directly
    # comparable).
    a = np.load(os.path.join(ckdir_a, "step_0000000006.ckpt"))
    b = np.load(os.path.join(ckdir_b, "step_0000000006.ckpt"))
    assert set(a.files) == set(b.files)
    for f in a.files:
        np.testing.assert_array_equal(a[f], b[f], err_msg=f)
