"""End-to-end behaviour: AMPER-prioritized LM training + sharded sampler."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = {**os.environ, "PYTHONPATH": os.path.join(REPO, "src")}


def test_prioritized_lm_training_loss_decreases():
    """quickstart path: tiny LM + AMPER-fr data sampler, loss goes down."""
    from repro.configs import get_reduced_config
    from repro.models.model_api import Model
    from repro.train import data as data_mod
    from repro.train import train_step as ts_mod
    from repro.train.optimizer import AdamW, cosine_schedule

    cfg = get_reduced_config("stablelm-1.6b", dtype="float32")
    model = Model.from_config(cfg)
    opt = AdamW(cosine_schedule(1e-3, 5, 60), weight_decay=0.0)
    step_fn = jax.jit(ts_mod.make_train_step(model, opt))
    tokens = data_mod.corpus_tokens(128, 33, cfg.vocab_size, seed=0)
    data = data_mod.PrioritizedSeqData(tokens, 8, sampler="amper-fr")
    ds = data.init()
    state = ts_mod.init_train_state(model, opt, jax.random.key(0))
    losses = []
    for s in range(40):
        idx, batch = data.sample(ds, jax.random.fold_in(jax.random.key(1), s))
        state, metrics = step_fn(state, batch)
        ds = data.update(ds, idx, jnp.full((8,), float(metrics["loss"])))
        losses.append(float(metrics["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) * 0.8, losses[:3] + losses[-3:]


def test_prioritized_data_prefers_high_loss():
    """Sampler draws high-loss sequences more often (the Fig.1 cycle).

    Losses are drawn from continuous ranges — AMPER's CSP needs group
    occupancy (the paper's own sampling study, Fig. 7, uses a continuous
    uniform distribution); two exact point masses would be a degenerate
    worst case for the frNN radius heuristic.
    """
    from repro.train import data as data_mod
    tokens = data_mod.corpus_tokens(256, 17, 100, seed=1)
    data = data_mod.PrioritizedSeqData(tokens, 16, sampler="amper-fr",
                                       v_max=12.0)
    ds = data.init()
    klo, khi = jax.random.split(jax.random.key(3))
    low = jax.random.uniform(klo, (128,), minval=0.05, maxval=0.5)
    high = jax.random.uniform(khi, (128,), minval=5.0, maxval=10.0)
    ds = data.update(ds, jnp.arange(128), low)
    ds = data.update(ds, jnp.arange(128, 256), high)
    picks = []
    for s in range(40):
        idx, _ = data.sample(ds, jax.random.fold_in(jax.random.key(2), s))
        picks.append(np.asarray(idx))
    frac_high = (np.concatenate(picks) >= 128).mean()
    # PER-exact would give ~0.94; AMPER should strongly prefer high-loss
    assert frac_high > 0.7, frac_high


def test_microbatched_train_step_matches():
    """Grad accumulation == single big batch (same params out)."""
    from repro.configs import get_reduced_config
    from repro.models.model_api import Model
    from repro.train import train_step as ts_mod
    from repro.train.optimizer import AdamW

    cfg = get_reduced_config("stablelm-1.6b", dtype="float32")
    model = Model.from_config(cfg)
    opt = AdamW(1e-3, weight_decay=0.0, clip_norm=0.0)
    toks = jax.random.randint(jax.random.key(3), (8, 33), 0, cfg.vocab_size,
                              dtype=jnp.int32)
    batch = {"tokens": toks[:, :-1], "targets": toks[:, 1:],
             "loss_mask": jnp.ones((8, 32), jnp.float32)}
    s0 = ts_mod.init_train_state(model, opt, jax.random.key(0))
    s1, _ = jax.jit(ts_mod.make_train_step(model, opt))(s0, batch)
    s2, _ = jax.jit(ts_mod.make_train_step(model, opt, microbatches=4))(s0, batch)
    # Tolerance note: on Adam's first step v ~= g^2, so the update is
    # ~ lr * sign(g); elements whose accumulated gradient is near zero are
    # sensitive to fp reassociation between the batch-8 and 4x batch-2
    # reduction orders.  Observed worst case ~9e-5 with lr=1e-3.
    for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s2.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-4)


@pytest.mark.slow
def test_sharded_amper_multi_device(mesh):
    """shard_map AMPER on 8 host devices: prioritization + index validity.

    Runs in-process on the shared mesh fixture (conftest.py forces the 8
    host devices before any jax import, so no subprocess dance is needed).
    """
    from jax.sharding import PartitionSpec as P, NamedSharding
    from repro.core.amper import AmperConfig
    from repro.core import sharded
    import repro.core.quantize as qz

    N = 8192
    cfg = AmperConfig(capacity=N, m=8, lam_fr=2.0, v_max=1.0,
                      csp_capacity=2048)
    p = jax.random.uniform(jax.random.key(1), (N,))
    sh = NamedSharding(mesh, P(("pod", "data")))
    pq_s = jax.device_put(qz.quantize(p, 1.0), sh)
    valid_s = jax.device_put(jnp.ones(N, bool), sh)
    fn = jax.jit(sharded.sharded_sample_fr(mesh, cfg, 2048))
    idx = fn(pq_s, valid_s, jax.random.key(3))
    assert idx.shape == (2048,)
    assert int(idx.min()) >= 0 and int(idx.max()) < N
    sampled_mean = float(p[idx].mean())
    assert sampled_mean > float(p.mean()) + 0.02, sampled_mean
    # PER contrast baseline
    fn2 = jax.jit(sharded.sharded_sample_per(mesh, 2048))
    idx2 = fn2(jax.device_put(p, sh), jax.random.key(3))
    assert float(p[idx2].mean()) > float(p.mean()) + 0.1


@pytest.mark.slow
def test_serve_cli_runs():
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch",
         "stablelm-1.6b", "--reduced", "--batch", "2", "--prompt-len", "8",
         "--gen", "4"],
        env=ENV, cwd=REPO, capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "decode:" in out.stdout
