"""Golden-trajectory regression fixtures for the environment dynamics.

``tests/golden/envs.json`` holds checked-in obs/reward/done sequences
for every registered env at fixed seeds and a fixed action pattern
(generated once from the transcribed-from-gym dynamics).  Any refactor
of the physics — integrator, constants, termination, auto-reset — that
drifts a trajectory fails here instead of silently shifting learning
curves three benchmarks downstream.

The fixture stores the PRE-reset observation stream (``step``'s second
return), i.e. the values the TD target consumes, so auto-reset behavior
is pinned too (via the ``done`` flags).  Fixtures predating the
terminated/truncated split carry no ``terminated`` stream; newer ones
(the pixel envs) pin it as well.
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.rl import envs as envs_mod

GOLDEN = os.path.join(os.path.dirname(__file__), "golden", "envs.json")

with open(GOLDEN) as f:
    _FIXTURES = json.load(f)


@pytest.mark.parametrize("name", sorted(_FIXTURES))
def test_env_matches_golden_trajectory(name):
    env = envs_mod.make_env(name)
    fx = _FIXTURES[name]
    state = env.reset(jax.random.key(0))
    np.testing.assert_allclose(np.asarray(env.obs(state)),
                               np.asarray(fx["reset_obs"]),
                               rtol=1e-6, atol=1e-6)
    for t, a in enumerate(fx["actions"]):
        state, obs, r, d, term = env.step(
            state, jnp.int32(a), jax.random.fold_in(jax.random.key(1), t))
        np.testing.assert_allclose(
            np.asarray(obs), np.asarray(fx["obs"][t]), rtol=1e-5, atol=1e-6,
            err_msg=f"{name} obs drift at step {t}")
        assert float(r) == pytest.approx(fx["reward"][t], abs=1e-6), (name, t)
        assert bool(d) == fx["done"][t], (name, t)
        if "terminated" in fx:
            assert bool(term) == fx["terminated"][t], (name, t)


def test_golden_covers_every_registered_env():
    """Adding an env without a golden trajectory is a test gap — this
    fails until the fixture is regenerated (see module docstring)."""
    assert set(_FIXTURES) == set(envs_mod.available_envs())


@pytest.mark.parametrize("name", envs_mod.available_envs())
def test_env_contract_shapes_and_dtypes(name):
    """Registry-wide contract: reset/step/obs agree on shape AND dtype.

    Pins the CartPole regression where ``step`` returned the raw state
    vector instead of routing through ``obs()`` — indistinguishable for
    identity observations, wrong for every env where obs != state.
    """
    env = envs_mod.make_env(name)
    obs_shape = tuple(env.obs_shape)
    state = env.reset(jax.random.key(0))
    o_reset = env.obs(state)
    assert tuple(o_reset.shape) == obs_shape, name
    state2, o_step, r, d, term = env.step(
        state, jnp.int32(0), jax.random.key(1))
    assert tuple(o_step.shape) == obs_shape, name
    assert o_step.dtype == o_reset.dtype, name
    o_next = env.obs(state2)
    assert tuple(o_next.shape) == obs_shape, name
    assert o_next.dtype == o_reset.dtype, name
    assert r.dtype == jnp.float32, name
    assert d.dtype == jnp.bool_ and term.dtype == jnp.bool_, name
    # terminated implies done, never the reverse (truncation).
    assert bool(d) or not bool(term), name


@pytest.mark.parametrize("name", envs_mod.available_envs())
def test_time_limit_truncation_is_not_termination(name):
    """Step each env with its episode clock forged to one tick below the
    cap: the step must end the episode (``done``) WITHOUT flagging a
    terminal (``terminated``) — from a reset state, one noop step cannot
    reach any env's real terminal condition."""
    env = envs_mod.make_env(name)
    state = env.reset(jax.random.key(0))
    state = state._replace(t=jnp.int32(env.max_steps - 1))
    state2, obs, r, d, term = env.step(state, jnp.int32(0),
                                       jax.random.key(2))
    assert bool(d) and not bool(term), name
    assert int(state2.t) == 0, name  # auto-reset started a fresh episode


def test_mountaincar_dynamics():
    env = envs_mod.make_env("mountaincar")
    s = env.reset(jax.random.key(0))
    assert s.x.shape == (2,)
    assert -0.6 <= float(s.x[0]) <= -0.4 and float(s.x[1]) == 0.0
    s2, obs, r, done, term = env.step(s, jnp.int32(2), jax.random.key(1))
    assert float(r) == -1.0 and not bool(done) and not bool(term)
    # pushing right from rest increases velocity minus gravity pull
    s3, _, _, _, _ = env.step(s, jnp.int32(0), jax.random.key(1))
    assert float(s2.x[1]) > float(s3.x[1])


def test_mountaincar_terminates_at_goal():
    env = envs_mod.make_env("mountaincar")
    s = env.reset(jax.random.key(0))
    s = s._replace(x=jnp.array([0.49, 0.07]))
    _, _, _, done, term = env.step(s, jnp.int32(2), jax.random.key(1))
    assert bool(done) and bool(term)


def test_mountaincar_velocity_and_position_bounds():
    env = envs_mod.make_env("mountaincar")
    s = env.reset(jax.random.key(3))
    for t in range(50):  # slam left: clamp at MIN_POS with vel reset to 0
        s, obs, _, _, _ = env.step(s, jnp.int32(0),
                                   jax.random.fold_in(jax.random.key(4), t))
        assert env.MIN_POS <= float(obs[0]) <= env.MAX_POS
        assert abs(float(obs[1])) <= env.MAX_SPEED + 1e-9


def test_breakout_brick_hit_scores_and_ball_bounces():
    env = envs_mod.make_env("breakout")
    s = env.reset(jax.random.key(0))
    # Place the ball just below the brick wall moving up into it.
    x = s.x.at[0].set(4.0).at[1].set(5.0).at[2].set(-1.0).at[3].set(1.0)
    s = s._replace(x=x)
    s2, obs, r, d, term = env.step(s, jnp.int32(0), jax.random.key(1))
    assert float(r) == 1.0 and not bool(d)
    assert float(jnp.sum(s2.x[5:])) == 29.0          # one brick cleared
    assert float(s2.x[2]) == 1.0                     # dy flipped downward


def test_breakout_missed_ball_terminates():
    env = envs_mod.make_env("breakout")
    s = env.reset(jax.random.key(0))
    # Ball one row above the bottom, paddle far away.
    x = s.x.at[0].set(8.0).at[1].set(1.0).at[2].set(1.0).at[3].set(1.0) \
        .at[4].set(9.0)
    s = s._replace(x=x)
    _, _, r, d, term = env.step(s, jnp.int32(0), jax.random.key(1))
    assert bool(d) and bool(term) and float(r) == 0.0


def test_freeway_scores_at_top_and_never_terminates():
    env = envs_mod.make_env("freeway")
    s = env.reset(jax.random.key(0))
    s = s._replace(x=s.x.at[0].set(1.0))  # one step below the goal row
    s2, obs, r, d, term = env.step(s, jnp.int32(1), jax.random.key(1))
    assert float(r) == 1.0 and not bool(term)
    assert float(s2.x[0]) == 9.0          # crossing restarts at the bottom
