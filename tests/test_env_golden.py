"""Golden-trajectory regression fixtures for the environment dynamics.

``tests/golden/envs.json`` holds checked-in obs/reward/done sequences
for every registered env at fixed seeds and a fixed action pattern
(generated once from the transcribed-from-gym dynamics).  Any refactor
of the physics — integrator, constants, termination, auto-reset — that
drifts a trajectory fails here instead of silently shifting learning
curves three benchmarks downstream.

The fixture stores the PRE-reset observation stream (``step``'s second
return), i.e. the values the TD target consumes, so auto-reset behavior
is pinned too (via the ``done`` flags).
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.rl import envs as envs_mod

GOLDEN = os.path.join(os.path.dirname(__file__), "golden", "envs.json")

with open(GOLDEN) as f:
    _FIXTURES = json.load(f)


@pytest.mark.parametrize("name", sorted(_FIXTURES))
def test_env_matches_golden_trajectory(name):
    env = envs_mod.make_env(name)
    fx = _FIXTURES[name]
    state = env.reset(jax.random.key(0))
    np.testing.assert_allclose(np.asarray(env.obs(state)),
                               np.asarray(fx["reset_obs"]),
                               rtol=1e-6, atol=1e-6)
    for t, a in enumerate(fx["actions"]):
        state, obs, r, d = env.step(
            state, jnp.int32(a), jax.random.fold_in(jax.random.key(1), t))
        np.testing.assert_allclose(
            np.asarray(obs), np.asarray(fx["obs"][t]), rtol=1e-5, atol=1e-6,
            err_msg=f"{name} obs drift at step {t}")
        assert float(r) == pytest.approx(fx["reward"][t], abs=1e-6), (name, t)
        assert bool(d) == fx["done"][t], (name, t)


def test_golden_covers_every_registered_env():
    """Adding an env without a golden trajectory is a test gap — this
    fails until the fixture is regenerated (see module docstring)."""
    assert set(_FIXTURES) == set(envs_mod.available_envs())


def test_mountaincar_dynamics():
    env = envs_mod.make_env("mountaincar")
    s = env.reset(jax.random.key(0))
    assert s.x.shape == (2,)
    assert -0.6 <= float(s.x[0]) <= -0.4 and float(s.x[1]) == 0.0
    s2, obs, r, done = env.step(s, jnp.int32(2), jax.random.key(1))
    assert float(r) == -1.0 and not bool(done)
    # pushing right from rest increases velocity minus gravity pull
    s3, _, _, _ = env.step(s, jnp.int32(0), jax.random.key(1))
    assert float(s2.x[1]) > float(s3.x[1])


def test_mountaincar_terminates_at_goal():
    env = envs_mod.make_env("mountaincar")
    s = env.reset(jax.random.key(0))
    s = s._replace(x=jnp.array([0.49, 0.07]))
    _, _, _, done = env.step(s, jnp.int32(2), jax.random.key(1))
    assert bool(done)


def test_mountaincar_velocity_and_position_bounds():
    env = envs_mod.make_env("mountaincar")
    s = env.reset(jax.random.key(3))
    for t in range(50):  # slam left: clamp at MIN_POS with vel reset to 0
        s, obs, _, _ = env.step(s, jnp.int32(0),
                                jax.random.fold_in(jax.random.key(4), t))
        assert env.MIN_POS <= float(obs[0]) <= env.MAX_POS
        assert abs(float(obs[1])) <= env.MAX_SPEED + 1e-9
