"""Per-arch smoke tests (assignment requirement): reduced config of the
same family, one forward/train step on CPU, output shapes + no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_reduced_config
from repro.models.model_api import Model, SHAPE_CELLS

B, S = 2, 64


def make_batch(cfg, key):
    k_tok, k_aud, k_vis = jax.random.split(key, 3)
    toks = jax.random.randint(k_tok, (B, S), 0, cfg.vocab_size, jnp.int32)
    batch = {"tokens": toks, "targets": toks,
             "loss_mask": jnp.ones((B, S), jnp.float32)}
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            k_aud, (B, cfg.enc_seq, cfg.d_model), jnp.float32)
    if cfg.vis_prefix_len:
        st = S - cfg.vis_prefix_len
        batch.update(
            tokens=toks[:, :st], targets=toks[:, :st],
            loss_mask=jnp.ones((B, st), jnp.float32),
            patch_embeds=jax.random.normal(
                k_vis, (B, cfg.vis_prefix_len, cfg.d_model), jnp.float32))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_assignment(arch):
    cfg = get_config(arch)
    assert cfg.name == arch
    # spot-check the assigned numbers
    expected = {
        "rwkv6-7b": (32, 4096, 14336, 65536),
        "h2o-danube-3-4b": (24, 3840, 10240, 32000),
        "granite-34b": (88, 6144, 24576, 49152),
        "phi3-medium-14b": (40, 5120, 17920, 100352),
        "stablelm-1.6b": (24, 2048, 5632, 100352),
        "whisper-tiny": (4, 384, 1536, 51865),
        "hymba-1.5b": (32, 1600, 5504, 32001),
        "deepseek-moe-16b": (28, 2048, None, 102400),
        "deepseek-v2-lite-16b": (27, 2048, None, 102400),
        "paligemma-3b": (18, 2048, 16384, 257216),
    }[arch]
    assert cfg.n_layers == expected[0]
    assert cfg.d_model == expected[1]
    if expected[2] is not None:
        assert cfg.d_ff == expected[2]
    assert cfg.vocab_size == expected[3]
    if "deepseek" in arch:
        assert cfg.n_experts == 64 and cfg.moe_top_k == 6
        assert cfg.moe_d_ff == 1408 and cfg.n_shared_experts == 2
    if arch == "deepseek-v2-lite-16b":
        assert cfg.kv_lora_rank == 512
    if arch == "hymba-1.5b":
        assert cfg.ssm_state == 16


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_train_step(arch):
    cfg = get_reduced_config(arch, dtype="float32")
    model = Model.from_config(cfg)
    params = model.init_params(jax.random.key(0))
    batch = make_batch(cfg, jax.random.key(1))
    loss, metrics = jax.jit(model.loss)(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: loss={loss}"
    grads = jax.jit(jax.grad(lambda p: model.loss(p, batch)[0]))(params)
    for path, g in jax.tree_util.tree_flatten_with_path(grads)[0]:
        assert bool(jnp.all(jnp.isfinite(g))), f"{arch}: NaN grad at {path}"


@pytest.mark.parametrize("arch", ["stablelm-1.6b", "rwkv6-7b",
                                  "deepseek-moe-16b", "hymba-1.5b"])
def test_reduced_forward_shapes(arch):
    from repro.models import transformer
    cfg = get_reduced_config(arch, dtype="float32")
    model = Model.from_config(cfg)
    params = model.init_params(jax.random.key(0))
    toks = jnp.ones((B, S), jnp.int32)
    logits, _ = transformer.forward(cfg, params, toks)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_long_context_flags():
    """long_500k applicability matches DESIGN.md §Arch-applicability."""
    runnable = {a for a in ARCH_IDS if get_config(a).supports_long_context}
    assert runnable == {"rwkv6-7b", "hymba-1.5b", "h2o-danube-3-4b"}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_input_specs_all_cells(arch):
    """input_specs produce well-formed ShapeDtypeStructs for all 4 cells."""
    cfg = get_config(arch)
    model = Model.from_config(cfg)
    for shape, cell in SHAPE_CELLS.items():
        if shape == "long_500k" and not cfg.supports_long_context:
            continue
        specs = model.input_specs(shape)
        for leaf in jax.tree.leaves(specs):
            assert isinstance(leaf, jax.ShapeDtypeStruct)
            assert all(d > 0 for d in leaf.shape)
