"""Fixture: DONATED-USE — reading a buffer after passing it to a
donate_argnums jit (the donated buffer is invalidated by the call)."""
import jax


def _advance(state, batch):
    return state


step = jax.jit(_advance, donate_argnums=0)


def train_step(state, batch):
    new_state = step(state, batch)
    stale = state  # BUG: ``state`` was donated to ``step``
    return new_state, stale
