"""Fixture: PRNG-REUSE — one key feeds two draws (the PR 1/PR 2 bug class)."""
import jax


def two_draws(key):
    noise = jax.random.uniform(key, (4,))
    jitter = jax.random.normal(key, (4,))  # BUG: key already consumed
    return noise + jitter
