"""Fixture: WALL-CLOCK — time.time() in duration math (the PR 8 bug class)."""
import time


def measure(fn):
    t0 = time.time()
    fn()
    return time.time() - t0  # BUG: wall clock is not monotonic
