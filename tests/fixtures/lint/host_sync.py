"""Fixture: HOST-SYNC — host transfer inside a jitted body."""
import jax
import jax.numpy as jnp


@jax.jit
def bad_mean(x):
    total = jnp.sum(x)
    return total.item() / x.shape[0]  # BUG: .item() syncs under jit
