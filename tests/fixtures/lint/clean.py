"""Fixture: the legit twins of every flagged pattern — must lint clean."""
import time

import jax


def fold_loop(key, n):
    """fold_in derives fresh keys; re-using the parent is fine."""
    outs = []
    for i in range(n):
        k = jax.random.fold_in(key, i)
        outs.append(jax.random.uniform(k, (2,)))
    return outs


def split_then_draw(key):
    k1, k2 = jax.random.split(key)
    return jax.random.uniform(k1, (2,)), jax.random.normal(k2, (2,))


def timed(fn):
    """Durations come from the monotonic clock."""
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def provenance():
    """A wall-clock *timestamp* (no arithmetic) is legitimate."""
    return {"ts": time.time()}


def deliberate_replay(key):
    """Intentional same-key draw, suppressed inline."""
    a = jax.random.uniform(key, (2,))
    b = jax.random.uniform(key, (2,))  # repro: noqa[PRNG-REUSE]
    return a, b


def early_return_draw(key, fast):
    """A draw inside an early-return arm does not poison the fallthrough."""
    if fast:
        return jax.random.uniform(key, (2,))
    return jax.random.normal(key, (4,))


def host_side(x):
    """Host syncs are fine OUTSIDE jit."""
    return float(jax.numpy.sum(x))
