"""Fixture: TRACED-BRANCH — python control flow on a traced parameter."""
import jax


@jax.jit
def clip_positive(x):
    if x > 0:  # BUG: x is a tracer; use jnp.where / lax.cond
        return x
    return 0.0 * x
