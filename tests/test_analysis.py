"""Tests for the ``repro.analysis`` gate — AST lint, trace lint,
lockdep, suppressions, dead-modules, CLI formats."""
import json
import os
import threading

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import ALL_RULES, locks
from repro.analysis.cli import main as cli_main
from repro.analysis.findings import Baseline, Finding, noqa_rules_for_line
from repro.analysis.lint import run_lint

pytestmark = [pytest.mark.analysis, pytest.mark.tier1]

HERE = os.path.dirname(__file__)
REPO = os.path.dirname(HERE)
FIX = os.path.join(HERE, "fixtures", "lint")
BASELINE = os.path.join(REPO, "analysis-baseline.json")


# --- layer 1: AST lint fixtures ---------------------------------------------

FIXTURE_RULES = [
    ("key_reuse.py", "PRNG-REUSE"),
    ("wallclock.py", "WALL-CLOCK"),
    ("host_sync.py", "HOST-SYNC"),
    ("donation.py", "DONATED-USE"),
    ("traced_branch.py", "TRACED-BRANCH"),
]


@pytest.mark.parametrize("fixture,rule", FIXTURE_RULES)
def test_fixture_violation_is_flagged(fixture, rule):
    findings = run_lint([os.path.join(FIX, fixture)])
    assert any(f.rule == rule for f in findings), (fixture, findings)


@pytest.mark.parametrize("fixture,rule", FIXTURE_RULES)
def test_cli_exits_nonzero_on_fixture(fixture, rule):
    assert cli_main([os.path.join(FIX, fixture), "--no-trace"]) == 1


def test_clean_fixture_passes():
    """Legit twins of every flagged pattern (fold_in loops, perf_counter,
    provenance timestamps, noqa'd replay, early-return arms) lint clean."""
    assert run_lint([os.path.join(FIX, "clean.py")]) == []


def test_shipped_tree_is_clean_with_committed_baseline():
    """The clean-pass gate: exactly what CI runs (minus trace checks,
    which have their own tests below)."""
    rc = cli_main([os.path.join(REPO, "src"), os.path.join(REPO, "tests"),
                   os.path.join(REPO, "benchmarks"),
                   "--no-trace", "--baseline", BASELINE])
    assert rc == 0


# --- suppressions -----------------------------------------------------------


def test_noqa_parsing():
    assert noqa_rules_for_line("x = 1") is None
    assert noqa_rules_for_line("x = f(k)  # repro: noqa") == set()
    assert noqa_rules_for_line(
        "x = f(k)  # repro: noqa[PRNG-REUSE, wall-clock]"
    ) == {"PRNG-REUSE", "WALL-CLOCK"}


def test_baseline_roundtrip_and_multiset_filter(tmp_path):
    f1 = Finding("PRNG-REUSE", "a.py", 10, "key reused")
    f2 = Finding("WALL-CLOCK", "b.py", 3, "duration math")
    path = tmp_path / "bl.json"
    Baseline.from_findings([f1, f2]).save(str(path))
    bl = Baseline.load(str(path))
    # line drift does not resurrect a baselined finding
    moved = Finding("PRNG-REUSE", "a.py", 99, "key reused")
    assert bl.filter([moved, f2]) == []
    # but a SECOND instance of the same pattern still surfaces
    dupe = Finding("PRNG-REUSE", "a.py", 120, "key reused")
    assert bl.filter([moved, dupe, f2]) == [dupe]


# --- layer 2: trace lint ----------------------------------------------------


def test_dispatch_budget_matches_committed_bench():
    from repro.analysis.jaxpr_lint import check_dispatch_budget

    assert check_dispatch_budget(os.path.join(
        REPO, "BENCH_sampling.json")) == []


def test_dispatch_budget_fails_when_budget_exceeded(tmp_path):
    """Shrink the committed budget below reality: the rule must fire —
    this is the acceptance path for a future fusion regression."""
    from repro.analysis.jaxpr_lint import check_dispatch_budget

    with open(os.path.join(REPO, "BENCH_sampling.json")) as f:
        bench = json.load(f)
    for row in bench["rows"]:
        if row[0] == "fr-fused/n10000":
            row[2] = row[2].replace(
                "dispatches=" + dict(
                    kv.split("=") for kv in row[2].split())["dispatches"],
                "dispatches=1")
    tight = tmp_path / "bench.json"
    tight.write_text(json.dumps(bench))
    findings = check_dispatch_budget(str(tight))
    assert [f.rule for f in findings] == ["DISPATCH-BUDGET"]
    assert "over the committed budget of 1" in findings[0].message


def test_slab_prefetch_path_has_one_trace():
    from repro.analysis.jaxpr_lint import check_recompile

    assert check_recompile() == []


def test_trace_cache_counter_sees_signature_churn():
    from repro.analysis.jaxpr_lint import trace_cache_entries

    f = jax.jit(lambda x: x + 1)
    calls = [(jnp.zeros((2,), jnp.float32),),
             (jnp.zeros((3,), jnp.float32),)]  # shape change -> retrace
    assert trace_cache_entries(f, calls) == 2
    jax.clear_caches()


def test_dtype_promotion_clean_on_registry_samplers():
    from repro.analysis.jaxpr_lint import check_dtype_promotion

    assert check_dtype_promotion() == []


def test_dtype_scan_flags_wide_and_weak():
    from repro.analysis.jaxpr_lint import _weak_outputs, scan_jaxpr_dtypes

    with jax.experimental.enable_x64():
        closed = jax.make_jaxpr(
            lambda x: jnp.cumsum(x * 2.0))(jnp.arange(4, dtype=jnp.float64))
    wide = scan_jaxpr_dtypes(closed.jaxpr, "x64-fixture")
    assert any(f.rule == "DTYPE-WIDE" and "float64" in f.message
               for f in wide)

    weak_closed = jax.make_jaxpr(lambda x: x + 1.0)(1.0)
    weak = _weak_outputs(weak_closed, "weak-fixture")
    assert any("weak-typed" in f.message for f in weak)


# --- layer 3: lockdep -------------------------------------------------------


def _inversion(rec):
    a, b = locks.make_lock("A"), locks.make_lock("B")
    with a:
        with b:
            pass
    with b:
        with a:
            pass


def test_seeded_lock_inversion_reports_cycle():
    rec = locks.enable()
    try:
        _inversion(rec)
        cycles = rec.cycles()
        assert cycles == [["A", "B"]]
        findings = locks.cycle_findings(cycles)
        assert findings[0].rule == "LOCK-ORDER"
        assert "A -> B -> A" in findings[0].message
    finally:
        locks.disable()


def test_ordered_acquisition_is_acyclic():
    rec = locks.enable()
    try:
        a, b = locks.make_lock("A"), locks.make_lock("B")
        for _ in range(3):
            with a:
                with b:
                    pass
        assert rec.edges() == {("A", "B")}
        assert rec.cycles() == []
    finally:
        locks.disable()


def test_lockdep_offline_log_roundtrip(tmp_path):
    log = tmp_path / "locks.jsonl"
    rec = locks.enable(str(log))
    try:
        _inversion(rec)
        rec.flush()
    finally:
        locks.disable()
    findings = locks.check_log(str(log))
    assert [f.rule for f in findings] == ["LOCK-ORDER"]


def test_cli_lock_log_fixture_exits_nonzero(capsys):
    rc = cli_main(["--lock-log",
                   os.path.join(FIX, "lock_inversion.jsonl")])
    assert rc == 1
    assert "LOCK-ORDER" in capsys.readouterr().out


def test_tracked_queue_and_condition_record_edges():
    rec = locks.enable()
    try:
        q = locks.tracked_queue("q", 2)
        cv = locks.make_condition("cv")
        with cv:
            q.put(1)  # q's mutex acquired while cv held
        q.get()
        q.task_done()
        q.join()
        assert ("cv", "q") in rec.edges()
        assert rec.cycles() == []
    finally:
        locks.disable()


def test_async_service_acquisition_graph_is_acyclic():
    """Lockdep over a real (tiny, churny) async service run: the
    instrumented queues, registry lock, exporter lock and pause gate
    must form an acyclic acquisition order."""
    from repro.rl.dqn import DQNConfig
    from repro.runtime.service import ReplayService

    cfg = DQNConfig(sampler="amper-fr", n_step=1, num_envs=2,
                    replay_size=32, batch=16, learn_start=4,
                    eps_decay_steps=100, target_sync=10, v_max=8.0)
    rec = locks.enable()
    try:
        svc = ReplayService(cfg, num_actors=2, chunk_len=2, slab=2,
                            queue_size=2)
        res = svc.run(jax.random.key(0), 8)
        assert res.metrics["learner_steps"] == 8
        counts = rec.counts()
        # The instrumented primitives all fired...
        assert any(n.startswith("runtime.") for n in counts), counts
        assert "obs.registry" in counts, counts
        # ...and the runtime's acquisition order is deadlock-free.  (A
        # sparse edge set is the DESIGN: the fabric rarely nests locks.)
        cycles = rec.cycles()
        assert cycles == [], f"lock-order cycle in the runtime: {cycles}"
    finally:
        locks.disable()


# --- dead modules -----------------------------------------------------------


def test_dead_modules_report(monkeypatch):
    from repro.analysis.deadcode import dead_module_report, render_report

    monkeypatch.chdir(REPO)
    report = dead_module_report("src")
    # the seed config zoo is the known candidate set
    assert any(m.startswith("repro.configs.")
               for m in report["unreferenced"])
    # the fabric itself is alive
    for mod in ("repro.runtime.service", "repro.core.amper",
                "repro.analysis.lint"):
        assert mod not in report["unreferenced"]
        assert mod not in report["outside_fabric"]
    text = render_report(report)
    assert "report only" in text


# --- output formats ---------------------------------------------------------


def test_prom_format_counts_and_stable_series():
    from repro.analysis.cli import _emit_prom
    from repro.obs.exporters import parse_prometheus

    findings = [Finding("PRNG-REUSE", "a.py", 1, "m1"),
                Finding("PRNG-REUSE", "a.py", 2, "m2"),
                Finding("LOCK-ORDER", "<lockdep>", 0, "m3")]
    metrics = parse_prometheus(_emit_prom(findings))
    assert metrics["repro_analysis_findings_prng_reuse_total"] == 2.0
    assert metrics["repro_analysis_findings_lock_order_total"] == 1.0
    # clean runs still emit every rule's series (at 0)
    clean = parse_prometheus(_emit_prom([]))
    for rule in ALL_RULES:
        name = "repro_analysis_findings_" + rule.lower().replace("-", "_")
        assert clean[name + "_total"] == 0.0


def test_cli_findings_json_artifact(tmp_path):
    out = tmp_path / "findings.json"
    rc = cli_main([os.path.join(FIX, "key_reuse.py"), "--no-trace",
                   "--out", str(out), "--format", "json"])
    assert rc == 1
    payload = json.loads(out.read_text())
    assert payload["total"] == 1
    assert payload["counts"] == {"PRNG-REUSE": 1}
    assert payload["findings"][0]["path"].endswith("key_reuse.py")


def test_cli_write_baseline_then_clean(tmp_path):
    bl = tmp_path / "bl.json"
    fixture = os.path.join(FIX, "key_reuse.py")
    assert cli_main([fixture, "--no-trace",
                     "--write-baseline", str(bl)]) == 0
    assert cli_main([fixture, "--no-trace", "--baseline", str(bl)]) == 0
