"""Statistical regression gates for the replay sampling laws.

Turns ``benchmarks/fig7_sampling_error.py``'s eyeballed claim into
tier-1 chi-square / KS gates at fixed seeds:

* ``per-cumsum`` empirically matches the exact PER law p_i / Σ p
  (priorities enter the sampler already alpha-exponentiated, so this IS
  the p_i^α / Σ p^α law of Schaul et al.) — chi-square on item counts
  and a KS test on the sampled-priority CDF.
* AMPER's draw matches its *quantized piecewise-constant* target: CSP
  membership is a function of the quantized priority value alone
  (deterministic structure pin, AMPER-fr), and the full ``sample()``
  pipeline is uniform over the CSP — a chi-square against the exact
  conditional expectation obtained by enumerating the very CSP-build
  keys ``sample()`` consumes, for amper-fr AND amper-k.

Everything is seed-pinned, so these run deterministically; the fast
gates are double-marked ``tier1`` + ``stats`` (they are the push gate
for the paper's sampling-distribution claim), the heavier sweep is
``stats`` only.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.scipy.special import gammaincc

from repro.core.samplers import make_sampler

BATCH, RUNS = 64, 100
P_MIN = 1e-3  # gate: fail only on catastrophic distribution drift


def chi2_pvalue(stat: float, df: int) -> float:
    """Survival function of chi-square via the regularized upper
    incomplete gamma (no scipy dependency)."""
    return float(gammaincc(df / 2.0, stat / 2.0))


def binned_chi2(observed: np.ndarray, expected: np.ndarray,
                min_expected: float = 10.0) -> tuple[float, int]:
    """Pearson chi-square with items aggregated (in fixed
    expected-probability order, so the binning is data-independent)
    into bins of expected count >= ``min_expected``."""
    order = np.argsort(-expected, kind="stable")
    o, e = observed[order], expected[order]
    bins_o, bins_e = [], []
    co = ce = 0.0
    for oi, ei in zip(o, e):
        co += oi
        ce += ei
        if ce >= min_expected:
            bins_o.append(co)
            bins_e.append(ce)
            co = ce = 0.0
    if ce > 0 or co > 0:  # fold the light tail into the last bin
        if bins_e:
            bins_o[-1] += co
            bins_e[-1] += ce
        else:
            bins_o.append(co)
            bins_e.append(ce)
    o, e = np.asarray(bins_o), np.asarray(bins_e)
    stat = float(np.sum((o - e) ** 2 / np.maximum(e, 1e-12)))
    return stat, max(len(e) - 1, 1)


# --- PER: exact p_i / sum law -------------------------------------------------


@pytest.mark.tier1
@pytest.mark.stats
def test_per_cumsum_matches_exact_law_chi2():
    n = 256
    s = make_sampler("per-cumsum", n)
    p = np.linspace(0.05, 1.0, n, dtype=np.float32)
    st = s.update(s.init(), jnp.arange(n), jnp.asarray(p))
    fn = jax.jit(lambda state, k: s.sample(state, k, BATCH))
    key = jax.random.key(0)
    counts = np.zeros(n)
    for r in range(RUNS):
        np.add.at(counts, np.asarray(fn(st, jax.random.fold_in(key, r))), 1)
    expected = BATCH * RUNS * p / p.sum()
    stat, df = binned_chi2(counts, expected)
    # Stratified draws have sub-multinomial variance, so the statistic
    # can only be conservative here — drift still blows it up.
    assert chi2_pvalue(stat, df) > P_MIN, (stat, df)


@pytest.mark.tier1
@pytest.mark.stats
def test_per_cumsum_ks_on_sampled_priorities():
    """KS distance between the empirical CDF of sampled priority VALUES
    (i.i.d. draws, stratified off) and the exact target CDF."""
    n = 256
    s = make_sampler("per-cumsum", n)
    p = np.linspace(0.05, 1.0, n, dtype=np.float32)
    st = s.update(s.init(), jnp.arange(n), jnp.asarray(p))
    fn = jax.jit(lambda state, k: s.sample(state, k, BATCH, False))
    key = jax.random.key(1)
    counts = np.zeros(n)
    for r in range(RUNS):
        np.add.at(counts, np.asarray(fn(st, jax.random.fold_in(key, r))), 1)
    draws = BATCH * RUNS
    # items are already in ascending priority order (linspace)
    ecdf = np.cumsum(counts) / draws
    cdf = np.cumsum(p / p.sum())
    d = float(np.max(np.abs(ecdf - cdf)))
    # K(alpha=0.01) = 1.63; discrete support makes the bound conservative
    assert d < 1.63 / np.sqrt(draws), d


# --- AMPER: quantized piecewise-constant target -------------------------------


def _amper(kind: str, n: int = 512, n_levels: int = 24, seed: int = 7):
    """Sampler + state over priorities drawn from a small discrete value
    set (so the quantized table has many duplicates — the regime where
    the piecewise-constant structure is observable) with csp_capacity=n
    (no compaction truncation: sample() is then EXACTLY uniform over the
    selected set, making the conditional expectation enumerable)."""
    s = make_sampler(kind, n, v_max=1.0, csp_capacity=n, m=8, lam_fr=2.0,
                     csp_ratio=1.0, knn_mode="bisect")
    key = jax.random.key(seed)
    levels = np.linspace(0.05, 0.95, n_levels, dtype=np.float32)
    prio = levels[np.asarray(
        jax.random.randint(key, (n,), 0, n_levels))]
    st = s.update(s.init(), jnp.arange(n), jnp.asarray(prio))
    return s, st


@pytest.mark.tier1
def test_amper_fr_membership_piecewise_constant_in_quantized_priority():
    """The fr CSP is a union of value ranges: membership must be a
    function of the quantized priority value alone — two rows storing
    the same value are either both in or both out, for any build key."""
    s, st = _amper("amper-fr")
    build = jax.jit(lambda state, k: s.build_csp(state, k).selected)
    pq = np.asarray(st.pq)
    order = np.argsort(pq, kind="stable")
    dup = pq[order][1:] == pq[order][:-1]
    assert dup.any()  # the fixture must actually exercise duplicates
    for r in range(8):
        sel = np.asarray(build(st, jax.random.fold_in(jax.random.key(3), r)))
        sel_o = sel[order]
        np.testing.assert_array_equal(sel_o[1:][dup], sel_o[:-1][dup])


@pytest.mark.tier1
@pytest.mark.stats
@pytest.mark.parametrize("kind", ["amper-fr", "amper-k"])
def test_amper_sample_matches_csp_target_chi2(kind):
    """Full ``sample()`` pipeline vs the piecewise-constant target: the
    expected per-item count is enumerated from the SAME CSP-build keys
    sample() consumes (conditional expectation, zero estimation error),
    so the chi-square isolates the uniform-over-CSP draw."""
    s, st = _amper(kind)
    n = int(st.pq.shape[0])
    build = jax.jit(lambda state, k: s.build_csp(state, k).selected)
    fn = jax.jit(lambda state, k: s.sample(state, k, BATCH))
    key = jax.random.key(11)
    counts = np.zeros(n)
    expected = np.zeros(n)
    for r in range(RUNS):
        kr = jax.random.fold_in(key, r)
        np.add.at(counts, np.asarray(fn(st, kr)), 1)
        kcsp, _ = jax.random.split(kr)
        sel = np.asarray(build(st, kcsp)).astype(np.float64)
        cnt = sel.sum()
        assert cnt > 0, f"empty CSP at draw {r}"
        expected += BATCH * sel / cnt
    np.testing.assert_allclose(expected.sum(), counts.sum())
    stat, df = binned_chi2(counts, expected)
    assert chi2_pvalue(stat, df) > P_MIN, (kind, stat, df)


@pytest.mark.tier1
@pytest.mark.stats
def test_amper_fr_expected_probability_piecewise_constant():
    """The induced per-item law itself is piecewise constant over the
    quantized value: enumerated expected probabilities are equal for
    equal stored values."""
    s, st = _amper("amper-fr")
    n = int(st.pq.shape[0])
    build = jax.jit(lambda state, k: s.build_csp(state, k).selected)
    key = jax.random.key(11)
    expected = np.zeros(n)
    for r in range(RUNS):
        kcsp, _ = jax.random.split(jax.random.fold_in(key, r))
        sel = np.asarray(build(st, kcsp)).astype(np.float64)
        expected += sel / sel.sum()
    pq = np.asarray(st.pq)
    for val in np.unique(pq):
        grp = expected[pq == val]
        np.testing.assert_allclose(grp, grp[0], rtol=1e-12)


@pytest.mark.stats
@pytest.mark.parametrize("kind", ["amper-fr", "amper-k"])
def test_amper_sample_matches_csp_target_chi2_heavy(kind):
    """Extended-job version of the gate: 4x the table, 3x the draws."""
    s, st = _amper(kind, n=2048, n_levels=48, seed=13)
    n = int(st.pq.shape[0])
    build = jax.jit(lambda state, k: s.build_csp(state, k).selected)
    fn = jax.jit(lambda state, k: s.sample(state, k, BATCH))
    key = jax.random.key(17)
    counts = np.zeros(n)
    expected = np.zeros(n)
    for r in range(3 * RUNS):
        kr = jax.random.fold_in(key, r)
        np.add.at(counts, np.asarray(fn(st, kr)), 1)
        kcsp, _ = jax.random.split(kr)
        sel = np.asarray(build(st, kcsp)).astype(np.float64)
        expected += BATCH * sel / sel.sum()
    stat, df = binned_chi2(counts, expected)
    assert chi2_pvalue(stat, df) > P_MIN, (kind, stat, df)
