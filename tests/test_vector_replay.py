"""Vectorized actor pipeline: batched ring writes, VectorEnv, train_many,
and the unified sampler registry."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.replay_buffer import ReplayBuffer
from repro.core.samplers import Sampler, available_samplers, make_sampler
from repro.rl.dqn import DQNConfig, make_dqn
from repro.rl.envs import CartPole, VectorEnv

ALL_KINDS = ("uniform", "per-sumtree", "per-cumsum", "amper-fr", "amper-k")


# --- sampler registry --------------------------------------------------------

def test_registry_lists_all_builtins():
    assert set(ALL_KINDS) <= set(available_samplers())


@pytest.mark.parametrize("kind", ALL_KINDS)
def test_registry_builds_protocol_instances(kind):
    s = make_sampler(kind, 128, v_max=4.0, min_csp=16)
    assert isinstance(s, Sampler)
    st = s.update(s.init(), jnp.arange(8), jnp.full(8, 0.5))
    idx = s.sample(st, jax.random.key(0), 16)
    assert idx.shape == (16,) and bool(jnp.all((idx >= 0) & (idx < 128)))
    assert s.priorities(st).shape == (128,)


def test_registry_unknown_kind_raises():
    with pytest.raises(ValueError, match="unknown sampler"):
        make_sampler("sorcery", 64)


def test_registry_ignores_foreign_hyperparams():
    # one unified kwargs dict must serve every kind
    s = make_sampler("per-sumtree", 64, m=20, lam_fr=2.0, csp_ratio=0.15,
                     v_max=8.0, min_csp=32)
    assert isinstance(s, Sampler)


# --- batched ring writes -----------------------------------------------------

def _tr(b, val=0.0):
    return {"obs": jnp.full((b, 3), val), "reward": jnp.arange(b, dtype=jnp.float32)}


@pytest.mark.parametrize("kind", ALL_KINDS)
def test_add_batch_wraparound(kind):
    """B writes crossing `capacity` land storage AND priorities in the
    right ring slots for every sampler."""
    cap, b = 8, 5
    rb = ReplayBuffer(cap, make_sampler(kind, cap, v_max=4.0, min_csp=4))
    state = rb.init({"obs": jnp.zeros(3), "reward": jnp.float32(0)})
    state = rb.add_batch(state, _tr(b, val=1.0))          # slots 0..4
    assert int(state.pos) == 5 and int(state.size) == 5
    state = rb.add_batch(state, _tr(b, val=2.0))          # slots 5,6,7,0,1
    assert int(state.pos) == (5 + b) % cap
    assert int(state.size) == cap
    obs = np.asarray(state.storage["obs"][:, 0])
    np.testing.assert_array_equal(obs, [2, 2, 1, 1, 1, 2, 2, 2])
    rew = np.asarray(state.storage["reward"])
    np.testing.assert_array_equal(rew, [3, 4, 2, 3, 4, 0, 1, 2])
    # every live slot carries the max-priority write
    prios = np.asarray(rb.sampler.priorities(state.sampler_state))
    assert (prios > 0).all(), prios


def test_add_batch_priorities_in_right_slots():
    """After a wrapping write + a targeted priority update, the updated
    slots (and only they) change."""
    cap = 8
    rb = ReplayBuffer(cap, make_sampler("per-cumsum", cap))
    state = rb.init({"obs": jnp.zeros(3), "reward": jnp.float32(0)})
    state = rb.add_batch(state, _tr(6))
    state = rb.add_batch(state, _tr(4))    # wraps: slots 6,7,0,1
    state = rb.update_priorities(state, jnp.array([7, 1]), jnp.array([5.0, 9.0]))
    prios = np.asarray(rb.sampler.priorities(state.sampler_state))
    alpha_p = lambda td: (abs(td) + rb.eps) ** rb.alpha
    np.testing.assert_allclose(prios[7], alpha_p(5.0), rtol=1e-5)
    np.testing.assert_allclose(prios[1], alpha_p(9.0), rtol=1e-5)
    np.testing.assert_allclose(prios[[2, 3, 4, 5, 6, 0]], 1.0, rtol=1e-5)


def test_add_batch_matches_sequential_adds():
    cap, b = 16, 5
    rb = ReplayBuffer(cap, make_sampler("per-sumtree", cap))
    s_seq = rb.init({"obs": jnp.zeros(3), "reward": jnp.float32(0)})
    batch = _tr(b)
    for i in range(b):
        s_seq = rb.add(s_seq, jax.tree.map(lambda x: x[i], batch))
    s_bat = rb.add_batch(rb.init({"obs": jnp.zeros(3),
                                  "reward": jnp.float32(0)}), batch)
    for a, c in zip(jax.tree.leaves(s_seq), jax.tree.leaves(s_bat)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(c), rtol=1e-6)


def test_add_batch_larger_than_capacity_rejected():
    rb = ReplayBuffer(4, make_sampler("uniform", 4))
    state = rb.init({"obs": jnp.zeros(3), "reward": jnp.float32(0)})
    with pytest.raises(ValueError, match="exceeds capacity"):
        rb.add_batch(state, _tr(5))


# --- VectorEnv ---------------------------------------------------------------

def test_vector_env_num_envs_1_matches_scalar():
    env = CartPole()
    venv = VectorEnv(env, 1)
    k_reset, k_step = jax.random.split(jax.random.key(0))
    vs = venv.reset(k_reset)
    ss = env.reset(jax.random.split(k_reset, 1)[0])
    np.testing.assert_allclose(np.asarray(venv.obs(vs)[0]),
                               np.asarray(env.obs(ss)))
    for t in range(50):
        k = jax.random.fold_in(k_step, t)
        a = jnp.int32(t % 2)
        vs, vobs, vr, vd, vterm = venv.step(vs, a[None], k)
        ss, sobs, sr, sd, sterm = env.step(ss, a, jax.random.split(k, 1)[0])
        np.testing.assert_allclose(np.asarray(vobs[0]), np.asarray(sobs),
                                   rtol=1e-6)
        assert bool(vd[0]) == bool(sd)
        assert bool(vterm[0]) == bool(sterm)
        np.testing.assert_allclose(np.asarray(venv.obs(vs)[0]),
                                   np.asarray(env.obs(ss)), rtol=1e-6)


def test_vector_env_independent_episodes():
    venv = VectorEnv(CartPole(), 8)
    state = venv.reset(jax.random.key(1))
    obs = venv.obs(state)
    assert obs.shape == (8, 4)
    # distinct reset keys -> distinct initial states
    assert len(np.unique(np.asarray(obs[:, 0]))) > 1
    state, next_obs, r, d, term = venv.step(
        state, jnp.zeros(8, jnp.int32), jax.random.key(2))
    assert next_obs.shape == (8, 4) and r.shape == (8,) and d.shape == (8,)


# --- batched agent + multi-seed sweep ---------------------------------------

def test_batched_agent_collects_b_frames_per_step():
    cfg = DQNConfig(num_envs=4, replay_size=64, learn_start=10**6)
    dqn = make_dqn(cfg)
    state, _ = dqn.train(jax.random.key(0), 5)
    assert int(state.buffer.size) == 20           # 5 iterations * 4 envs
    assert int(state.buffer.pos) == 20
    assert state.obs.shape == (4, 4)
    assert state.episode_return.shape == (4,)


def test_train_many_smoke():
    cfg = DQNConfig(num_envs=2, replay_size=256, learn_start=20,
                    eps_decay_steps=100)
    dqn = make_dqn(cfg)
    keys = jax.vmap(jax.random.key)(jnp.arange(3, dtype=jnp.uint32))
    states, metrics = dqn.train_many(keys, 60)
    # one leading seed axis everywhere, finite results, seeds differ
    assert metrics["return_mean"].shape == (3, 60)
    assert bool(jnp.all(jnp.isfinite(metrics["return_mean"])))
    p0 = jax.tree.leaves(states.params)[0]
    assert p0.shape[0] == 3
    assert not np.allclose(np.asarray(p0[0]), np.asarray(p0[1]))
    scores = dqn.evaluate_many(states, keys, 2)
    assert scores.shape == (3,) and bool(jnp.all(jnp.isfinite(scores)))
