"""Crash-resume: a checkpointed, killed, and resumed run must equal an
uninterrupted one — bit-for-bit in sync mode, tolerance-level in async —
plus the β-annealing schedule satellite."""
import dataclasses
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.per import beta_schedule, importance_weights
from repro.core.replay_buffer import ReplayBuffer
from repro.core.samplers import make_sampler
from repro.rl.dqn import DQNConfig, make_dqn
from repro.runtime import ReplayService
from repro.train.checkpoint import CheckpointManager

CFG = DQNConfig(num_envs=2, replay_size=256, batch=16, learn_start=30,
                eps_decay_steps=200, target_sync=25, beta_end=1.0)


def _assert_trees_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# --- sync mode: bit-identical kill/resume ------------------------------------


def test_sync_service_kill_resume_bit_identical(tmp_path):
    """Acceptance pin: checkpointed + killed + resumed == uninterrupted,
    bitwise, for final params AND full replay state."""
    n = 80
    key = jax.random.key(3)
    svc = ReplayService(CFG, sync=True, num_actors=1)
    res_uninterrupted = svc.run(key, n)

    mgr = CheckpointManager(str(tmp_path), save_interval=25)
    mgr.request_preemption()          # "kill" at the first checkpoint
    r1 = svc.run(key, n, manager=mgr)
    assert r1.metrics["preempted_at"] is not None
    assert r1.metrics["preempted_at"] < n

    r2 = svc.run(key, n, manager=CheckpointManager(str(tmp_path),
                                                   save_interval=25))
    assert r2.metrics["resumed_from"] == r1.metrics["preempted_at"]
    _assert_trees_equal(res_uninterrupted.params, r2.params)
    _assert_trees_equal(res_uninterrupted.target_params, r2.target_params)
    _assert_trees_equal(res_uninterrupted.buffer, r2.buffer)


def test_sync_resume_kill_at_random_wall_time(tmp_path):
    """The kill point must not matter: preempt from a watchdog thread at
    an arbitrary wall-clock moment, resume, and still match bitwise."""
    n = 60
    key = jax.random.key(5)
    svc = ReplayService(CFG, sync=True, num_actors=1)
    baseline = svc.run(key, n)
    mgr = CheckpointManager(str(tmp_path), save_interval=10)
    killer = threading.Timer(0.05, mgr.request_preemption)
    killer.start()
    svc.run(key, n, manager=mgr)
    killer.cancel()
    r2 = svc.run(key, n, manager=CheckpointManager(str(tmp_path),
                                                   save_interval=10))
    _assert_trees_equal(baseline.params, r2.params)
    _assert_trees_equal(baseline.buffer, r2.buffer)


def test_sync_resume_with_different_n_steps_raises(tmp_path):
    svc = ReplayService(CFG, sync=True, num_actors=1)
    mgr = CheckpointManager(str(tmp_path), save_interval=10)
    mgr.request_preemption()
    svc.run(jax.random.key(0), 40, manager=mgr)
    with pytest.raises(ValueError, match="n_steps"):
        svc.run(jax.random.key(0), 50,
                manager=CheckpointManager(str(tmp_path)))


def test_train_ckpt_relaunch_after_completion_is_idempotent(tmp_path):
    """Regression: rerunning the documented auto-resume command after
    the run already finished must return the final state, not crash."""
    dqn = make_dqn(CFG)
    key, n = jax.random.key(2), 40
    mgr = CheckpointManager(str(tmp_path), save_interval=20)
    st1, _, done1 = dqn.train_ckpt(key, n, mgr)
    assert done1 == n
    st2, metrics, done2 = dqn.train_ckpt(
        key, n, CheckpointManager(str(tmp_path), save_interval=20))
    assert done2 == n
    assert metrics["return_mean"].shape == (0,)
    _assert_trees_equal(st1, st2)


def test_train_ckpt_kill_resume_bit_identical(tmp_path):
    """Same pin for the scan trainer's checkpoint hook."""
    dqn = make_dqn(CFG)
    key, n = jax.random.key(1), 70
    st_a, _, done = dqn.train_ckpt(
        key, n, CheckpointManager(str(tmp_path / "a"), save_interval=30))
    assert done == n
    mgr = CheckpointManager(str(tmp_path / "b"), save_interval=30)
    mgr.request_preemption()
    _, _, done1 = dqn.train_ckpt(key, n, mgr)
    assert done1 < n
    st_b, _, done2 = dqn.train_ckpt(
        key, n, CheckpointManager(str(tmp_path / "b"), save_interval=30))
    assert done2 == n
    _assert_trees_equal(st_a, st_b)


def test_sync_service_nstep_kill_resume_bit_identical(tmp_path):
    """n-step satellite pin: the in-state accumulator (mid-window ring,
    cursor, count) must round-trip through kill/resume so the resumed
    n-step run is STILL bitwise equal to an uninterrupted one."""
    cfg = dataclasses.replace(CFG, agent="double", n_step=3)
    n = 80
    key = jax.random.key(7)
    svc = ReplayService(cfg, sync=True, num_actors=1)
    base = svc.run(key, n)
    mgr = CheckpointManager(str(tmp_path), save_interval=26)  # mid-window
    mgr.request_preemption()
    r1 = svc.run(key, n, manager=mgr)
    assert r1.metrics["preempted_at"] is not None
    assert r1.metrics["preempted_at"] % 3 != 0  # cut really lands mid-window
    r2 = svc.run(key, n, manager=CheckpointManager(str(tmp_path),
                                                   save_interval=26))
    _assert_trees_equal(base.params, r2.params)
    _assert_trees_equal(base.buffer, r2.buffer)


# --- async mode: snapshot / resume -------------------------------------------


def _async_service(n_step: int = 1, **kw):
    cfg = DQNConfig(sampler="amper-fr", n_step=n_step, num_envs=2,
                    replay_size=256, batch=16, learn_start=8,
                    eps_decay_steps=200, target_sync=50, v_max=8.0,
                    beta_end=1.0)
    return ReplayService(cfg, num_actors=2, chunk_len=4, slab=2,
                         queue_size=4, max_replay_ratio=64, **kw)


def test_async_kill_resume_completes_and_feedback_stays_exact(tmp_path):
    """Kill the async service mid-run, resume from the latest snapshot:
    the resumed run finishes the remaining learner steps, keeps the
    exactly-once/in-order deferred-feedback contract across the resume
    boundary, and produces finite, evaluable params."""
    n = 40
    mgr = CheckpointManager(str(tmp_path), save_interval=8)
    mgr.request_preemption()          # kill at the first slab boundary
    svc = _async_service()
    r1 = svc.run(jax.random.key(1), n, manager=mgr)
    cut = r1.metrics["preempted_at"]
    assert cut is not None and 0 < cut < n

    svc2 = _async_service(feedback_log=True)
    r2 = svc2.run(jax.random.key(1), n,
                  manager=CheckpointManager(str(tmp_path), save_interval=100))
    m = r2.metrics
    assert m["resumed_from"] == cut
    assert m["total_learner_steps"] == n
    # feedback sequence numbers continue gaplessly from the cut point
    assert m["feedback_seqs"] == list(range(cut, n)), m["feedback_seqs"]
    assert int(r2.buffer.size) > 0
    assert int(r2.buffer.total_adds) >= int(r1.buffer.total_adds)
    score = float(svc2.dqn.evaluate(r2.params, jax.random.key(2), 3))
    assert np.isfinite(score)
    for leaf in jax.tree.leaves(r2.params):
        assert bool(jnp.all(jnp.isfinite(leaf)))


def test_async_final_return_within_tolerance_of_uninterrupted(tmp_path):
    """Async resume is not bitwise (thread interleaving differs), but a
    killed+resumed run must land in the same performance regime as an
    uninterrupted one at smoke scale."""
    n = 60
    base = _async_service().run(jax.random.key(4), n)
    mgr = CheckpointManager(str(tmp_path), save_interval=10)
    killer = threading.Timer(0.2, mgr.request_preemption)
    killer.start()
    svc = _async_service()
    svc.run(jax.random.key(4), n, manager=mgr)
    killer.cancel()
    r2 = _async_service().run(
        jax.random.key(4), n,
        manager=CheckpointManager(str(tmp_path), save_interval=1000))
    assert r2.metrics["total_learner_steps"] == n
    # same regime: both losses finite, both evaluable
    s_base = float(_async_service().dqn.evaluate(base.params,
                                                 jax.random.key(8), 3))
    s_res = float(_async_service().dqn.evaluate(r2.params,
                                                jax.random.key(8), 3))
    assert np.isfinite(s_base) and np.isfinite(s_res)


def test_async_periodic_snapshots_do_not_change_liveness(tmp_path):
    """Frequent copy-on-write snapshots must not wedge the pipeline:
    the run completes with snapshots enabled at every slab boundary."""
    mgr = CheckpointManager(str(tmp_path), save_interval=4)
    r = _async_service().run(jax.random.key(2), 20, manager=mgr)
    assert r.metrics["total_learner_steps"] == 20
    assert mgr.latest_step() == 20


def test_async_cow_snapshots_never_quiesce(tmp_path):
    """Acceptance pin for the COW rework: a checkpointed async run
    records ZERO pause→drain quiesce cycles — snapshots only cost the
    learner-thread capture (reference grab + counter watermarks), which
    is recorded per snapshot."""
    mgr = CheckpointManager(str(tmp_path), save_interval=8)
    r = _async_service().run(jax.random.key(6), 32, manager=mgr)
    snap = r.metrics["snapshot"]
    assert snap["drain_cycles"] == 0
    assert snap["count"] >= 1
    assert snap["saved"] >= 1
    assert 0 < snap["pause_us_max"] < 1e6
    assert 0 < snap["pause_us_mean"] <= snap["pause_us_max"]
    # an uncheckpointed run records no snapshot activity
    r0 = _async_service().run(jax.random.key(6), 16)
    assert r0.metrics["snapshot"]["count"] == 0
    assert r0.metrics["snapshot"]["pause_us_max"] == 0.0


def test_async_feedback_contract_across_midflight_snapshots(tmp_path):
    """The stamped exactly-once/in-order feedback contract must hold
    while COW snapshots are taken mid-flight — the snapshotter reads the
    live state the replay thread keeps publishing, and the dirty-row log
    it prunes is the same one feeding the deferred updates."""
    n = 40
    svc = _async_service(feedback_log=True)
    mgr = CheckpointManager(str(tmp_path), save_interval=8)
    r = svc.run(jax.random.key(9), n, manager=mgr)
    m = r.metrics
    assert m["total_learner_steps"] == n
    assert m["snapshot"]["saved"] >= 2  # snapshots really ran mid-flight
    assert m["feedback_seqs"] == list(range(n)), m["feedback_seqs"]
    # and the last on-disk snapshot restores cleanly
    svc2 = _async_service()
    r2 = svc2.run(jax.random.key(9), n,
                  manager=CheckpointManager(str(tmp_path),
                                            save_interval=1000))
    assert r2.metrics["resumed_from"] == mgr.latest_step()
    for leaf in jax.tree.leaves(r2.params):
        assert bool(jnp.all(jnp.isfinite(leaf)))


def test_async_nstep_kill_resume_accumulator_roundtrips(tmp_path):
    """n-step satellite pin (async): each actor's private accumulator
    window is part of the snapshot, so a resumed run keeps aggregating
    mid-window, completes the remaining learner steps, and keeps the
    exactly-once/in-order feedback contract across the boundary."""
    n = 40
    mgr = CheckpointManager(str(tmp_path), save_interval=8)
    mgr.request_preemption()
    r1 = _async_service(n_step=3).run(jax.random.key(1), n, manager=mgr)
    cut = r1.metrics["preempted_at"]
    assert cut is not None and 0 < cut < n
    # white-box: the saved snapshot really carries per-actor window state
    import repro.train.checkpoint as ck
    manifest_names = ck.load_manifest(str(tmp_path),
                                      mgr.latest_step())["names"]
    assert any("nstep" in nm and "actors" in nm for nm in manifest_names), \
        manifest_names

    svc2 = _async_service(n_step=3, feedback_log=True)
    r2 = svc2.run(jax.random.key(1), n,
                  manager=CheckpointManager(str(tmp_path), save_interval=100))
    m = r2.metrics
    assert m["resumed_from"] == cut
    assert m["total_learner_steps"] == n
    assert m["feedback_seqs"] == list(range(cut, n)), m["feedback_seqs"]
    # the restored buffer carries the pre-kill experience forward (new
    # adds are interleaving-dependent, so only monotonicity is pinned)
    assert int(r2.buffer.total_adds) >= int(r1.buffer.total_adds)
    assert int(r2.buffer.size) >= int(r1.buffer.size)
    for leaf in jax.tree.leaves(r2.params):
        assert bool(jnp.all(jnp.isfinite(leaf)))


def test_resume_at_target_reports_finite_rates(tmp_path):
    """Satellite fix: a run that resumes exactly at its target does zero
    work in epsilon wall time — the throughput metrics must come out
    finite (the raw division produced inf/nan)."""
    n = 40
    svc = ReplayService(CFG, sync=True, num_actors=1)
    svc.run(jax.random.key(1), n,
            manager=CheckpointManager(str(tmp_path), save_interval=20))
    r = svc.run(jax.random.key(1), n,
                manager=CheckpointManager(str(tmp_path), save_interval=20))
    assert r.metrics["resumed_from"] == n
    assert np.isfinite(r.metrics["frames_per_sec"])
    assert np.isfinite(r.metrics["learner_steps_per_sec"])


def test_async_resume_actor_count_mismatch_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path), save_interval=8)
    mgr.request_preemption()
    _async_service().run(jax.random.key(1), 20, manager=mgr)
    cfg = _async_service().cfg
    svc3 = ReplayService(cfg, num_actors=3, chunk_len=4, slab=2)
    with pytest.raises(ValueError, match="num_actors"):
        svc3.run(jax.random.key(1), 20,
                 manager=CheckpointManager(str(tmp_path)))


# --- β annealing satellite ---------------------------------------------------


def test_beta_schedule_anneals_to_one():
    assert float(beta_schedule(0.4, 1.0, jnp.int32(0), 100)) == pytest.approx(0.4)
    assert float(beta_schedule(0.4, 1.0, jnp.int32(50), 100)) == pytest.approx(0.7)
    assert float(beta_schedule(0.4, 1.0, jnp.int32(100), 100)) == pytest.approx(1.0)
    assert float(beta_schedule(0.4, 1.0, jnp.int32(10**6), 100)) == pytest.approx(1.0)


def test_dqn_beta_at_defaults_and_annealed():
    frozen = make_dqn(DQNConfig())            # beta_end None -> constant
    assert frozen.beta_at(10**9) == DQNConfig().beta
    annealed = make_dqn(DQNConfig(beta_end=1.0, beta_anneal_steps=100))
    assert float(annealed.beta_at(jnp.int32(100))) == pytest.approx(1.0)
    assert float(annealed.beta_at(jnp.int32(0))) == pytest.approx(0.4)


def test_replay_sample_beta_override_matches_importance_weights():
    rb = ReplayBuffer(64, make_sampler("per-cumsum", 64))
    st = rb.init({"x": jnp.float32(0)})
    st = rb.add_batch(st, {"x": jnp.arange(64, dtype=jnp.float32)})
    st = rb.update_priorities(st, jnp.arange(64),
                              jnp.linspace(0.1, 3.0, 64))
    key = jax.random.key(0)
    for beta in (0.4, 1.0):
        idx, _, w = rb.sample(st, key, 16, beta=jnp.float32(beta))
        prios = rb.sampler.priorities(st.sampler_state)
        expect = importance_weights(prios, idx, jnp.maximum(st.size, 1), beta)
        np.testing.assert_allclose(np.asarray(w), np.asarray(expect),
                                   rtol=1e-6)
    # beta=1 fully compensates: low-priority rows get the largest weights
    idx, _, w1 = rb.sample(st, key, 16, beta=jnp.float32(1.0))
    _, _, w0 = rb.sample(st, key, 16, beta=jnp.float32(0.0))
    np.testing.assert_allclose(np.asarray(w0), 1.0)  # no correction at 0
    assert np.asarray(w1).std() > 0                  # real correction at 1


def test_metrics_surface_annealed_beta_not_frozen_default():
    """Satellite fix: the metrics dict must report the β the draws
    actually used (the annealed schedule), not the frozen constructor
    default — in the scan trainer's per-step metrics, the sync service,
    and the async service (via the prefetcher's latest draw)."""
    n = 60
    cfg = dataclasses.replace(CFG, beta_end=1.0, beta_anneal_steps=50,
                              learn_start=10)
    dqn = make_dqn(cfg)
    _, m = dqn.train(jax.random.key(0), n)
    betas = np.asarray(m["beta"])
    assert betas.shape == (n,)
    np.testing.assert_allclose(betas[0], 0.4, rtol=1e-6)
    np.testing.assert_allclose(betas[-1], 1.0, rtol=1e-6)  # annealed out
    assert (np.diff(betas) >= -1e-7).all()

    svc = ReplayService(cfg, sync=True, num_actors=1)
    res = svc.run(jax.random.key(0), n)
    np.testing.assert_allclose(res.metrics["beta"],
                               float(dqn.beta_at(n - 1)), rtol=1e-6)
    assert res.metrics["beta"] > cfg.beta  # not the frozen default

    r = _async_service().run(jax.random.key(2), 30)
    assert cfg.beta < r.metrics["beta"] <= 1.0


def test_constant_beta_still_reported():
    cfg = dataclasses.replace(CFG, beta_end=None, learn_start=10)
    res = ReplayService(cfg, sync=True, num_actors=1).run(jax.random.key(1),
                                                          30)
    np.testing.assert_allclose(res.metrics["beta"], cfg.beta, rtol=1e-6)
