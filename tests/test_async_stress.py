"""High-churn stress tests for the async replay runtime.

A deliberately tiny buffer, many actor threads, and short rollout
chunks force rapid slot recycling: most sampled rows are overwritten
between the prefetch draw and the deferred priority apply.  Under that
pressure the runtime must keep the stamped ``update_priorities``
contract — every learner batch's feedback applied exactly once, in
learner-step order, and never onto a recycled slot — and the service
must stay live (no wedge, no dropped slabs).

The recycled-slot half of the contract is pinned deterministically at
the buffer level (the race test can't distinguish a stale write from a
legitimate one by value alone), the liveness/ordering half under real
thread contention.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.replay_buffer import ReplayBuffer
from repro.core.samplers import make_sampler
from repro.rl.dqn import DQNConfig
from repro.runtime import ReplayService


# --- deterministic recycled-slot contract under churn -------------------------


@pytest.mark.parametrize("kind", ["per-cumsum", "per-sumtree", "amper-fr"])
def test_stamped_updates_never_land_on_recycled_slots_under_churn(kind):
    """Drive the buffer through many sample -> recycle -> late-feedback
    cycles with full ring wraparound; after each apply, every slot's
    priority must equal the max-priority write if it was recycled since
    the sample, the fed-back value otherwise."""
    cap = 16
    rb = ReplayBuffer(cap, make_sampler(kind, cap, v_max=64.0,
                                        csp_capacity=cap))
    st = rb.init({"x": jnp.float32(0)})
    key = jax.random.key(0)
    rng = np.random.default_rng(1)
    st = rb.add_batch(st, {"x": jnp.zeros(cap)})
    for round_ in range(20):
        idx, _, _ = rb.sample(st, jax.random.fold_in(key, round_), 8)
        stamp = rb.stamps(st, idx)
        before = np.asarray(st.write_stamp).copy()
        # recycle a random arc (0..cap rows) before the feedback lands
        churn = int(rng.integers(0, cap + 1))
        if churn:
            st = rb.add_batch(st, {"x": jnp.full(churn, float(round_))})
        mp_at_add = float(st.max_priority)  # what recycled slots received
        td = jnp.linspace(1.0, 9.0, 8) + round_
        st = rb.update_priorities(st, idx, td, stamp=stamp)
        prios = np.asarray(rb.sampler.priorities(st.sampler_state))
        after = np.asarray(st.write_stamp)
        idx_np, td_np = np.asarray(idx), np.asarray(td)
        expect = {}
        for j, slot in enumerate(idx_np):
            if after[slot] == before[slot]:  # survived -> last valid write
                expect[slot] = (abs(td_np[j]) + rb.eps) ** rb.alpha
        for slot, want in expect.items():
            np.testing.assert_allclose(prios[slot], want, rtol=1e-4,
                                       err_msg=f"round {round_} slot {slot}")
        recycled = set(idx_np[after[idx_np] != before[idx_np]])
        for slot in recycled - set(expect):
            # recycled before the feedback: must hold the newcomer's
            # max-priority entry (as of the add), not the stale TD write
            np.testing.assert_allclose(
                prios[slot], mp_at_add, rtol=1e-4,
                err_msg=f"round {round_} stale write on recycled {slot}")


# --- threaded race: tiny buffer, many actors, rapid recycling -----------------


def _stress_service(n_step: int, sampler: str) -> ReplayService:
    cfg = DQNConfig(sampler=sampler, n_step=n_step, num_envs=2,
                    replay_size=32, batch=16, learn_start=4,
                    eps_decay_steps=100, target_sync=10, v_max=8.0)
    return ReplayService(cfg, num_actors=4, chunk_len=2, slab=2,
                         queue_size=2, feedback_log=True)


@pytest.mark.parametrize("n_step,sampler",
                         [(1, "per-sumtree"), (3, "amper-fr")])
def test_async_high_churn_exactly_once_in_order(n_step, sampler):
    """4 actors race into a 32-slot ring (every ~4 blocks recycles the
    whole buffer, so nearly every deferred update targets a dead slot):
    the run must complete, apply every slab's feedback exactly once in
    order, and keep the buffer invariants intact."""
    n = 40
    svc = _stress_service(n_step, sampler)
    res = svc.run(jax.random.key(5), n)
    m = res.metrics
    assert m["learner_steps"] == n
    assert m["feedback_seqs"] == list(range(n)), m["feedback_seqs"]
    assert m["staleness"]["count"] == n
    assert 0 <= m["staleness"]["mean"] <= m["staleness"]["max"]
    buf = res.buffer
    assert int(buf.size) == 32                       # fully churned
    assert int(buf.total_adds) > 2 * 32              # many recycles
    stamps = np.asarray(buf.write_stamp)
    assert stamps.min() >= 0
    assert stamps.max() == int(buf.total_adds) - 1   # ring write ordering
    assert len(np.unique(stamps)) == 32              # stamps stay distinct
    prios = np.asarray(svc.dqn.replay.sampler.priorities(buf.sampler_state))
    assert np.isfinite(prios).all() and (prios >= 0).all()
    assert float(buf.max_priority) >= 1.0
    for leaf in jax.tree.leaves(res.params):
        assert bool(jnp.all(jnp.isfinite(leaf)))
