"""AMPER algorithm: CSP construction, variants, kernel parity, sampling law."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core.quantize as qz
from repro.core.amper import (AmperConfig, AmperSampler, build_csp_fr,
                              build_csp_fr_kernel, build_csp_k, fr_queries,
                              group_counts, group_representatives, knn_sizes,
                              sample_from_csp)

N = 4096


@pytest.fixture(scope="module")
def table():
    p = jax.random.uniform(jax.random.key(1), (N,))
    return qz.quantize(p, 1.0), jnp.ones(N, jnp.bool_), p


def cfg(**kw):
    base = dict(capacity=N, m=8, lam=0.15, lam_fr=2.0, v_max=1.0,
                csp_capacity=2048)
    base.update(kw)
    return AmperConfig(**base)


def test_group_counts_partition(table):
    pq, valid, _ = table
    counts = group_counts(pq, valid, cfg())
    assert int(counts.sum()) == N


def test_representatives_in_group_range():
    c = cfg(m=16)
    v = group_representatives(jax.random.key(0), c)
    edges = np.arange(17) / 16.0
    v = np.asarray(v)
    assert (v >= edges[:-1]).all() and (v <= edges[1:] + 1e-6).all()


def test_fr_prefix_queries_cover_radius(table):
    """Prefix block always contains V(g_i) and has width >= Delta_i."""
    c = cfg()
    v = group_representatives(jax.random.key(3), c)
    vq, mask = fr_queries(v, c)
    lo, hi = qz.prefix_range(vq, mask)
    delta = jnp.round((c.lam_fr / c.m) * vq.astype(jnp.float32)).astype(jnp.int32)
    assert bool(jnp.all((vq >= lo) & (vq <= hi)))
    assert bool(jnp.all((hi - lo + 1) >= delta)), "block narrower than Delta"
    assert bool(jnp.all((hi - lo + 1) <= 2 * jnp.maximum(delta, 1))), \
        "block wider than 2*Delta (power-of-2 bound)"


def test_fr_selected_matches_semantics(table):
    pq, valid, _ = table
    c = cfg()
    key = jax.random.key(5)
    res = build_csp_fr(pq, valid, key, c)
    v = group_representatives(jax.random.split(key)[0], c)
    vq, mask = fr_queries(v, c)
    lo, hi = qz.prefix_range(vq, mask)
    expect = ((pq[None, :] >= lo[:, None]) & (pq[None, :] <= hi[:, None])).any(0)
    np.testing.assert_array_equal(np.asarray(res.selected), np.asarray(expect))
    # compacted indices are a subset of the selected ones (rotation-start
    # compaction permutes which survive truncation, not membership)
    sel_idx = set(np.nonzero(np.asarray(expect))[0].tolist())
    got = np.asarray(res.indices[:int(res.count)])
    assert set(got.tolist()) <= sel_idx
    assert len(set(got.tolist())) == int(res.count)


def test_fr_kernel_parity(table):
    pq, valid, _ = table
    c = cfg()
    key = jax.random.key(6)
    a = build_csp_fr(pq, valid, key, c)
    b = build_csp_fr_kernel(pq, valid, key, c)
    np.testing.assert_array_equal(np.asarray(a.selected), np.asarray(b.selected))
    assert int(a.count) == int(b.count)


def test_knn_sort_bisect_equivalence(table):
    pq, valid, _ = table
    key = jax.random.key(7)
    a = build_csp_k(pq, valid, key, cfg(knn_mode="sort"))
    b = build_csp_k(pq, valid, key, cfg(knn_mode="bisect"))
    assert int(a.count) == int(b.count)
    # same multiset of selected slots up to distance ties
    sa = np.asarray(a.selected)
    sb = np.asarray(b.selected)
    assert (sa == sb).mean() > 0.99


def test_knn_sizes_eqn1(table):
    """Per-group kNN subset size follows Eqn 1 within rounding."""
    pq, valid, p = table
    c = cfg(knn_mode="sort", csp_capacity=N)
    key = jax.random.key(8)
    v = group_representatives(jax.random.split(key)[0], c)
    counts = group_counts(pq, valid, c)
    n_i = knn_sizes(v, counts, c)
    res = build_csp_k(pq, valid, key, c)
    # total selected <= sum N_i (union can dedup overlapping groups)
    assert int(res.count) <= int(n_i.sum())
    assert int(res.count) >= int(n_i.sum()) * 0.8


def test_exact_radius_superset_quality(table):
    """Beyond-paper mode: |p-V|<=Delta exactly (no power-of-2 error)."""
    pq, valid, _ = table
    c = cfg(exact_radius=True)
    key = jax.random.key(9)
    res = build_csp_fr(pq, valid, key, c)
    v = group_representatives(jax.random.split(key)[0], c)
    vq = qz.quantize(v, 1.0)
    delta = jnp.round((c.lam_fr / c.m) * vq.astype(jnp.float32)).astype(jnp.int32)
    within = (jnp.abs(pq[None, :] - vq[:, None]) <= delta[:, None]).any(0)
    np.testing.assert_array_equal(np.asarray(res.selected), np.asarray(within))


def test_sampler_prioritizes(table):
    """Sampled mean priority must exceed the buffer mean (and approach
    the ideal E_p[p] = 2/3 for uniform priorities)."""
    _, _, p = table
    for variant in ("fr", "k"):
        s = AmperSampler(cfg(knn_mode="bisect"), variant)
        st = s.update(s.init(), jnp.arange(N), p)
        idx = jax.jit(lambda k: s.sample(st, k, 8192))(jax.random.key(10))
        got = float(p[idx].mean())
        assert got > float(p.mean()) + 0.03, (variant, got)


def test_empty_csp_fallback():
    s = AmperSampler(cfg(), "fr")
    st = s.init()  # nothing valid
    idx = s.sample(st, jax.random.key(0), 64)
    assert idx.shape == (64,)
    assert bool(jnp.all((idx >= 0) & (idx < N)))


def test_update_is_plain_write(table):
    """Sec 3.4.3: update = one row write; value round-trips to quantization."""
    _, _, p = table
    s = AmperSampler(cfg(), "fr")
    st = s.update(s.init(), jnp.arange(N), p)
    st = s.update(st, jnp.array([5]), jnp.array([0.123]))
    got = float(s.priorities(st)[5])
    assert abs(got - 0.123) < 1e-5
