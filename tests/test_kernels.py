"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core.quantize as qz
from repro.kernels import ops, ref


@pytest.mark.parametrize("n", [128, 4096, 5000, 16384])
@pytest.mark.parametrize("maskbits", [0, 7, 12, 20])
def test_tcam_match_sweep(n, maskbits):
    pq = jax.random.randint(jax.random.key(n + maskbits), (n,), 0, 1 << 24,
                            dtype=jnp.int32)
    query = pq[n // 2]  # guarantee at least one hit
    mask = jnp.int32((1 << maskbits) - 1)
    out = ops.tcam_match(pq, query, mask)
    expected = ref.tcam_match_ref(pq, query, mask)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expected))
    assert bool(out[n // 2])


@pytest.mark.parametrize("n,m", [(1024, 1), (4096, 8), (9000, 20)])
def test_multi_query_sweep(n, m):
    key = jax.random.key(n * m)
    pq = jax.random.randint(key, (n,), 0, 1 << 24, dtype=jnp.int32)
    valid = jax.random.bernoulli(jax.random.fold_in(key, 1), 0.85, (n,))
    centers = jax.random.randint(jax.random.fold_in(key, 2), (m,), 0, 1 << 24,
                                 dtype=jnp.int32)
    radius = jax.random.randint(jax.random.fold_in(key, 3), (m,), 0, 1 << 20,
                                dtype=jnp.int32)
    lo, hi = centers - radius, centers + radius
    sel, cnt = ops.multi_query_match(pq, valid, lo, hi)
    sel_r, cnt_r = ref.multi_query_match_ref(pq, valid, lo, hi)
    np.testing.assert_array_equal(np.asarray(sel), np.asarray(sel_r))
    np.testing.assert_array_equal(np.asarray(cnt), np.asarray(cnt_r))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,hq,hkv,s,d,causal,window",
    [
        (2, 4, 2, 256, 64, True, None),    # GQA
        (1, 8, 1, 256, 128, True, None),   # MQA
        (2, 4, 4, 256, 128, True, 64),     # MHA + sliding window
        (1, 2, 2, 256, 256, False, None),  # bidirectional (encoder)
        (1, 4, 2, 300, 64, True, None),    # non-tile-aligned seq
    ])
def test_flash_attention_sweep(dtype, b, hq, hkv, s, d, causal, window):
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (b, hq, s, d), dtype)
    k = jax.random.normal(ks[1], (b, hkv, s, d), dtype)
    v = jax.random.normal(ks[2], (b, hkv, s, d), dtype)
    out = ops.flash_attention(q, k, v, causal=causal, window=window)
    expected = ref.attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expected, np.float32),
        atol=tol, rtol=tol)


def test_flash_matches_model_chunked_attention():
    """Pallas kernel == the jnp blockwise training path."""
    from repro.models.attention import chunked_attention, make_mask_fn
    ks = jax.random.split(jax.random.key(3), 3)
    q = jax.random.normal(ks[0], (2, 4, 256, 64))
    k = jax.random.normal(ks[1], (2, 2, 256, 64))
    v = jax.random.normal(ks[2], (2, 2, 256, 64))
    a = ops.flash_attention(q, k, v, causal=True)
    b = chunked_attention(q, k, v, make_mask_fn(True, None, None),
                          bq=64, bkv=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=3e-5)


def test_kernel_amper_parity_large():
    """Fused kernel path drives the same CSP as XLA on a big table."""
    from repro.core.amper import AmperConfig, build_csp_fr, build_csp_fr_kernel
    n = 1 << 15
    p = jax.random.uniform(jax.random.key(4), (n,))
    pq = qz.quantize(p, 1.0)
    valid = jnp.ones(n, bool)
    cfg = AmperConfig(capacity=n, m=20, lam_fr=2.0, csp_capacity=4096)
    key = jax.random.key(5)
    a = build_csp_fr(pq, valid, key, cfg)
    b = build_csp_fr_kernel(pq, valid, key, cfg)
    np.testing.assert_array_equal(np.asarray(a.selected), np.asarray(b.selected))


# --- fused amper_sample: in-kernel PRNG ---------------------------------------


@pytest.mark.parametrize("n", [1, 2, 5, 64, 127, 257])
@pytest.mark.parametrize("seed", [0, 1, 7])
def test_counter_bits_matches_jax_random_bits(n, seed):
    """The kernel's per-lane threefry recomputation is bit-exact with
    jax.random.bits at every size, including odd (trailing-0 padding)."""
    from repro.kernels.amper_sample import counter_bits
    key = jax.random.key(seed)
    kd = jax.random.key_data(key).astype(jnp.uint32)
    expect = jax.random.bits(key, (n,), jnp.uint32)
    got = counter_bits(kd, jnp.arange(n, dtype=jnp.uint32), jnp.uint32(n))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(expect))


def test_counter_bits_split_identity():
    """split(key, 2).key_data == bits(key, (4,)) paired up — the identity
    the kernel uses to derive its pick/fallback subkeys in-kernel."""
    from repro.kernels.amper_sample import counter_bits
    key = jax.random.key(11)
    kd = jax.random.key_data(key).astype(jnp.uint32)
    ks = jax.random.split(key)
    got = counter_bits(kd, jnp.arange(4, dtype=jnp.uint32), jnp.uint32(4))
    np.testing.assert_array_equal(
        np.asarray(got).reshape(2, 2),
        np.asarray(jax.random.key_data(ks)).astype(np.uint32))


# --- fused amper_sample: whole-draw bit-identity + edge cases -----------------


def _fused_vs_reference(n, csp_capacity, batch, seed=0, empty=False):
    """Assert fr_mode='fused' draws the exact indices of the reference."""
    from repro.core.amper import AmperConfig, AmperSampler
    cfg = AmperConfig(capacity=n, m=20, lam_fr=2.0, v_max=1.0,
                      csp_capacity=csp_capacity)
    ref_s = AmperSampler(cfg, "fr")
    fus_s = AmperSampler(cfg._replace(fr_mode="fused"), "fr")
    if empty:
        s_r, s_f = ref_s.init(), fus_s.init()
    else:
        prio = jax.random.uniform(jax.random.key(seed), (n,)) + 0.01
        s_r = ref_s.update(ref_s.init(), jnp.arange(n), prio)
        s_f = fus_s.update(fus_s.init(), jnp.arange(n), prio)
    key = jax.random.key(seed + 100)
    np.testing.assert_array_equal(
        np.asarray(ref_s.sample(s_r, key, batch)),
        np.asarray(fus_s.sample(s_f, key, batch)))


def test_fused_all_invalid_table():
    """Empty table -> both paths take the uniform fallback draw."""
    _fused_vs_reference(2048, 256, 64, empty=True)


def test_fused_csp_saturated_at_capacity():
    """Far more members than csp_capacity: the truncated count governs
    the draw on both paths (cyclic-rank identity under truncation)."""
    _fused_vs_reference(20_000, 64, 32, seed=1)


def test_fused_batch_larger_than_csp():
    _fused_vs_reference(4096, 16, 128, seed=2)


@pytest.mark.parametrize("n", [127, 130, 5000, 10_001])
def test_fused_non_block_multiple_sizes(n):
    """Table sizes that are not multiples of block_rows*128 exercise the
    -1/invalid padding rows."""
    _fused_vs_reference(n, max(8, n // 8), 33, seed=3)


def test_fused_explicit_interpret_flag():
    """ops.amper_sample(interpret=True) == the reference XLA pipeline:
    pins the interpret-mode escape hatch independently of the backend
    default."""
    from repro.core.amper import (AmperConfig, build_csp_fr, fr_intervals,
                                  group_representatives, sample_from_csp)
    n, batch = 5000, 64
    cfg = AmperConfig(capacity=n, m=20, lam_fr=2.0, v_max=1.0,
                      csp_capacity=512)
    p = jax.random.uniform(jax.random.key(6), (n,))
    pq = qz.quantize(p, cfg.v_max)
    valid = jnp.ones(n, bool)
    kcsp, kpick = jax.random.split(jax.random.key(7))
    csp = build_csp_fr(pq, valid, kcsp, cfg)
    live = jnp.sum(valid.astype(jnp.int32))
    expect = sample_from_csp(csp, kpick, batch, live)

    kv, kroll = jax.random.split(kcsp)
    v_rep = group_representatives(kv, cfg)
    lo, hi = fr_intervals(v_rep, cfg)
    shift = jax.random.randint(kroll, (), 0, cfg.capacity)
    idx, stats = ops.amper_sample(pq, valid, lo, hi, shift, kpick,
                                  batch=batch, csp_capacity=cfg.csp_capacity,
                                  interpret=True)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(expect))
    assert int(stats[2]) == n  # live rows


def test_fused_rejects_wide_frac_bits():
    """frac_bits > 24 would overflow the f32 one-hot gathers — refused."""
    from repro.core.amper import AmperConfig, AmperSampler
    cfg = AmperConfig(capacity=1024, frac_bits=30, fr_mode="fused")
    s = AmperSampler(cfg, "fr")
    st = s.update(s.init(), jnp.arange(64), jnp.ones(64) * 0.5)
    with pytest.raises(ValueError, match="frac_bits"):
        s.sample(st, jax.random.key(0), 8)


def test_rank_select_matches_nonzero_oracle():
    """rank_select returns nonzero(selected)[rank] for in-range ranks and
    0 past the member count."""
    n, m = 9000, 12
    key = jax.random.key(21)
    pq = jax.random.randint(key, (n,), 0, 1 << 20, dtype=jnp.int32)
    valid = jax.random.bernoulli(jax.random.fold_in(key, 1), 0.9, (n,))
    centers = jax.random.randint(jax.random.fold_in(key, 2), (m,), 0, 1 << 20,
                                 dtype=jnp.int32)
    radius = jax.random.randint(jax.random.fold_in(key, 3), (m,), 0, 1 << 16,
                                dtype=jnp.int32)
    lo, hi = centers - radius, centers + radius
    sel = np.asarray(((pq[None, :] >= lo[:, None])
                      & (pq[None, :] <= hi[:, None])).any(0) & valid)
    members = np.nonzero(sel)[0]
    assert len(members) > 2, "degenerate oracle"
    ranks = jnp.asarray([0, 1, len(members) // 2, len(members) - 1,
                         len(members), len(members) + 5], jnp.int32)
    idx, cnt = ops.rank_select(pq, valid, lo, hi, ranks)
    assert int(cnt) == len(members)
    idx = np.asarray(idx)
    for r, i in zip(np.asarray(ranks), idx):
        assert i == (members[r] if r < len(members) else 0), (r, i)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,hkv,group,s,d,cur", [
    (2, 2, 4, 1024, 64, 700),    # GQA
    (1, 1, 8, 512, 128, 512),    # MQA, full cache
    (2, 4, 1, 300, 96, 37),      # MHA, ragged S and D
])
def test_decode_attention_sweep(dtype, b, hkv, group, s, d, cur):
    ks = jax.random.split(jax.random.key(1), 3)
    q = jax.random.normal(ks[0], (b, hkv, group, d), dtype)
    k = jax.random.normal(ks[1], (b, hkv, s, d), dtype)
    v = jax.random.normal(ks[2], (b, hkv, s, d), dtype)
    out = ops.decode_attention(q, k, v, cur, bkv=256)
    expected = ref.decode_attention_ref(q, k, v, cur)
    tol = 3e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expected, np.float32),
                               atol=tol, rtol=tol)


def test_decode_kernel_matches_model_path():
    """Pallas decode kernel == models.attention.decode_attention."""
    from repro.models.attention import decode_attention as model_decode, \
        make_mask_fn
    ks = jax.random.split(jax.random.key(2), 3)
    b, hkv, group, s, d = 2, 2, 3, 256, 64
    q4 = jax.random.normal(ks[0], (b, hkv * group, 1, d))
    k = jax.random.normal(ks[1], (b, hkv, s, d))
    v = jax.random.normal(ks[2], (b, hkv, s, d))
    cur = jnp.int32(100)
    a = model_decode(q4, k, v, cur, make_mask_fn(True, None, None))
    qg = q4.reshape(b, hkv, group, d)
    b_out = ops.decode_attention(qg, k, v, cur, bkv=128)
    np.testing.assert_allclose(
        np.asarray(a[:, :, 0]).reshape(b, hkv, group, d),
        np.asarray(b_out), atol=3e-5)
