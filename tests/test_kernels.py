"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core.quantize as qz
from repro.kernels import ops, ref


@pytest.mark.parametrize("n", [128, 4096, 5000, 16384])
@pytest.mark.parametrize("maskbits", [0, 7, 12, 20])
def test_tcam_match_sweep(n, maskbits):
    pq = jax.random.randint(jax.random.key(n + maskbits), (n,), 0, 1 << 24,
                            dtype=jnp.int32)
    query = pq[n // 2]  # guarantee at least one hit
    mask = jnp.int32((1 << maskbits) - 1)
    out = ops.tcam_match(pq, query, mask)
    expected = ref.tcam_match_ref(pq, query, mask)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expected))
    assert bool(out[n // 2])


@pytest.mark.parametrize("n,m", [(1024, 1), (4096, 8), (9000, 20)])
def test_multi_query_sweep(n, m):
    key = jax.random.key(n * m)
    pq = jax.random.randint(key, (n,), 0, 1 << 24, dtype=jnp.int32)
    valid = jax.random.bernoulli(jax.random.fold_in(key, 1), 0.85, (n,))
    centers = jax.random.randint(jax.random.fold_in(key, 2), (m,), 0, 1 << 24,
                                 dtype=jnp.int32)
    radius = jax.random.randint(jax.random.fold_in(key, 3), (m,), 0, 1 << 20,
                                dtype=jnp.int32)
    lo, hi = centers - radius, centers + radius
    sel, cnt = ops.multi_query_match(pq, valid, lo, hi)
    sel_r, cnt_r = ref.multi_query_match_ref(pq, valid, lo, hi)
    np.testing.assert_array_equal(np.asarray(sel), np.asarray(sel_r))
    np.testing.assert_array_equal(np.asarray(cnt), np.asarray(cnt_r))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,hq,hkv,s,d,causal,window",
    [
        (2, 4, 2, 256, 64, True, None),    # GQA
        (1, 8, 1, 256, 128, True, None),   # MQA
        (2, 4, 4, 256, 128, True, 64),     # MHA + sliding window
        (1, 2, 2, 256, 256, False, None),  # bidirectional (encoder)
        (1, 4, 2, 300, 64, True, None),    # non-tile-aligned seq
    ])
def test_flash_attention_sweep(dtype, b, hq, hkv, s, d, causal, window):
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (b, hq, s, d), dtype)
    k = jax.random.normal(ks[1], (b, hkv, s, d), dtype)
    v = jax.random.normal(ks[2], (b, hkv, s, d), dtype)
    out = ops.flash_attention(q, k, v, causal=causal, window=window)
    expected = ref.attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expected, np.float32),
        atol=tol, rtol=tol)


def test_flash_matches_model_chunked_attention():
    """Pallas kernel == the jnp blockwise training path."""
    from repro.models.attention import chunked_attention, make_mask_fn
    ks = jax.random.split(jax.random.key(3), 3)
    q = jax.random.normal(ks[0], (2, 4, 256, 64))
    k = jax.random.normal(ks[1], (2, 2, 256, 64))
    v = jax.random.normal(ks[2], (2, 2, 256, 64))
    a = ops.flash_attention(q, k, v, causal=True)
    b = chunked_attention(q, k, v, make_mask_fn(True, None, None),
                          bq=64, bkv=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=3e-5)


def test_kernel_amper_parity_large():
    """Fused kernel path drives the same CSP as XLA on a big table."""
    from repro.core.amper import AmperConfig, build_csp_fr, build_csp_fr_kernel
    n = 1 << 15
    p = jax.random.uniform(jax.random.key(4), (n,))
    pq = qz.quantize(p, 1.0)
    valid = jnp.ones(n, bool)
    cfg = AmperConfig(capacity=n, m=20, lam_fr=2.0, csp_capacity=4096)
    key = jax.random.key(5)
    a = build_csp_fr(pq, valid, key, cfg)
    b = build_csp_fr_kernel(pq, valid, key, cfg)
    np.testing.assert_array_equal(np.asarray(a.selected), np.asarray(b.selected))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,hkv,group,s,d,cur", [
    (2, 2, 4, 1024, 64, 700),    # GQA
    (1, 1, 8, 512, 128, 512),    # MQA, full cache
    (2, 4, 1, 300, 96, 37),      # MHA, ragged S and D
])
def test_decode_attention_sweep(dtype, b, hkv, group, s, d, cur):
    ks = jax.random.split(jax.random.key(1), 3)
    q = jax.random.normal(ks[0], (b, hkv, group, d), dtype)
    k = jax.random.normal(ks[1], (b, hkv, s, d), dtype)
    v = jax.random.normal(ks[2], (b, hkv, s, d), dtype)
    out = ops.decode_attention(q, k, v, cur, bkv=256)
    expected = ref.decode_attention_ref(q, k, v, cur)
    tol = 3e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expected, np.float32),
                               atol=tol, rtol=tol)


def test_decode_kernel_matches_model_path():
    """Pallas decode kernel == models.attention.decode_attention."""
    from repro.models.attention import decode_attention as model_decode, \
        make_mask_fn
    ks = jax.random.split(jax.random.key(2), 3)
    b, hkv, group, s, d = 2, 2, 3, 256, 64
    q4 = jax.random.normal(ks[0], (b, hkv * group, 1, d))
    k = jax.random.normal(ks[1], (b, hkv, s, d))
    v = jax.random.normal(ks[2], (b, hkv, s, d))
    cur = jnp.int32(100)
    a = model_decode(q4, k, v, cur, make_mask_fn(True, None, None))
    qg = q4.reshape(b, hkv, group, d)
    b_out = ops.decode_attention(qg, k, v, cur, bkv=128)
    np.testing.assert_allclose(
        np.asarray(a[:, :, 0]).reshape(b, hkv, group, d),
        np.asarray(b_out), atol=3e-5)
